"""ELAPS-style measurement layer + calibration + perf-gate tests.

Covers the ISSUE-6 acceptance surface: reps validation, per-rep sample
shape, repetition-controller convergence on synthetic noisy timers, the
calibrate -> register -> JSON -> reload persistence convention (with
corrupt/missing-file fallback), finite model_residual fields in a fast
bench row, and the spread-aware regression gate's pass/fail behavior.
"""
import importlib.util
import itertools
import json
import math
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro import arch
from repro.tune import measure as M
from repro.tune import search

# calibration settings small enough for test budgets (one warm-up + one
# timed rep per micro-bench, tiny operands)
FAST_CAL = dict(gemm_sizes=(16, 32), stream_elems=1 << 16, chain_iters=32,
                reps=1)


# ----------------------------- reps validation ------------------------------

@pytest.mark.parametrize("reps", [0, -1])
def test_measure_wall_time_rejects_nonpositive_reps(reps):
    with pytest.raises(ValueError, match="reps"):
        M.measure_wall_time(lambda: 1.0, reps=reps)
    with pytest.raises(ValueError, match="reps"):
        M.measure(lambda: 1.0, reps=reps)


def test_controller_validates_budgets():
    with pytest.raises(ValueError, match="min_reps"):
        M.repetition_controller(lambda: 1.0, min_reps=0)
    with pytest.raises(ValueError, match="max_reps"):
        M.repetition_controller(lambda: 1.0, min_reps=4, max_reps=2)
    with pytest.raises(ValueError, match="rel_spread"):
        M.repetition_controller(lambda: 1.0, rel_spread=-0.1)
    with pytest.raises(ValueError, match="sample"):
        M.Measurement.from_samples([])


def test_search_reexports_shared_helper():
    # the historical import paths must stay the one shared timing helper
    assert search.measure_wall_time is M.measure_wall_time
    assert search._timeit is M.measure_wall_time


# ------------------------- per-rep samples + stats --------------------------

def test_measure_pinned_reps_sample_shape():
    m = M.measure(lambda x: x * 2.0, jnp.float32(3.0), reps=4)
    assert m.reps == 4 and len(m.samples) == 4
    assert all(s > 0 for s in m.samples)
    assert m.seconds_median == pytest.approx(
        float(np.median(np.asarray(m.samples))))
    assert m.seconds_spread >= 0
    assert set(m.row_fields()) == {"seconds_median", "seconds_spread", "reps"}
    blob = json.loads(json.dumps(m.to_json()))
    assert blob["reps"] == 4 and len(blob["samples"]) == 4


def test_median_robust_to_outlier():
    m = M.Measurement.from_samples([1.0, 1.0, 1.0, 100.0])
    assert m.seconds_median == 1.0
    assert m.seconds_min == 1.0
    assert m.seconds_mean > 1.0


# ------------------------ controller convergence ----------------------------

def test_controller_converges_on_quiet_timer():
    quiet = itertools.cycle([1.00, 1.01, 0.99])
    m = M.repetition_controller(lambda: next(quiet), min_reps=3, max_reps=50,
                                rel_spread=0.10)
    assert m.converged
    assert m.reps == 3                       # stopped at the first check
    assert m.seconds_median == pytest.approx(1.0)


def test_controller_exhausts_budget_on_noisy_timer():
    noisy = itertools.cycle([0.1, 1.0, 10.0])
    m = M.repetition_controller(lambda: next(noisy), min_reps=3, max_reps=7,
                                rel_spread=0.01)
    assert not m.converged
    assert m.reps == 7                       # the rep budget, not beyond
    assert m.seconds_median == pytest.approx(1.0)


def test_controller_keeps_sampling_until_spread_tightens():
    # loud at first, then quiet: the controller must ride past min_reps
    samples = iter([1.0, 5.0, 0.2] + [1.0] * 40)
    m = M.repetition_controller(lambda: next(samples), min_reps=3,
                                max_reps=40, rel_spread=0.05)
    assert m.converged
    assert 3 < m.reps < 40


# -------------------------- model residual ----------------------------------

def test_model_residual_semantics():
    assert M.model_residual(1.0, 1.0) == 0.0
    assert M.model_residual(0.5, 1.0) == pytest.approx(0.5)
    assert M.model_residual(2.0, 1.0) == pytest.approx(-1.0)
    assert math.isnan(M.model_residual(1.0, 0.0))
    assert math.isnan(M.model_residual(1.0, float("nan")))


# ---------------------- calibration + persistence ---------------------------

def test_calibrate_registers_and_fits(tmp_path):
    res = arch.calibrate_full(**FAST_CAL)
    m = res.machine
    assert m.name == "calibrated-cpu"
    assert arch.get("calibrated-cpu") == m
    assert m.pe.peak_flops > 0 and m.memory.hbm_bw > 0
    assert all(d >= 1 for d in m.fpu.depths.values())
    # the fitted machine must explain its own best-rung evidence within the
    # documented tolerance (docs/benchmarking.md)
    assert res.best_residual("gemm") <= arch.CALIBRATION_TOLERANCE
    assert res.best_residual("stream") <= arch.CALIBRATION_TOLERANCE
    for row in res.report:
        assert math.isfinite(row["model_residual"])
        assert row["reps"] >= 1 and row["seconds_median"] > 0
    # report + spec both JSON-serializable
    json.dumps(res.to_json())


def test_calibrate_roundtrip_persistence(tmp_path):
    p = str(tmp_path / "calibrated.json")
    spec = arch.calibrate(path=p, **FAST_CAL)
    assert os.path.exists(p)
    assert arch.MachineSpec.load(p) == spec
    # reload path: no re-measurement, same registered spec
    again = arch.load_or_calibrate(p, **FAST_CAL)
    assert again == spec
    assert arch.get("calibrated-cpu") == spec


def test_load_or_calibrate_missing_file_calibrates(tmp_path):
    p = str(tmp_path / "nope" / "calibrated.json")
    os.makedirs(os.path.dirname(p))
    spec = arch.load_or_calibrate(p, **FAST_CAL)
    assert spec.name == "calibrated-cpu"
    assert os.path.exists(p)                 # fallback wrote the file
    assert arch.MachineSpec.load(p) == spec


def test_load_or_calibrate_corrupt_file_falls_back(tmp_path):
    p = str(tmp_path / "calibrated.json")
    with open(p, "w") as f:
        f.write("{not json")
    spec = arch.load_or_calibrate(p, **FAST_CAL)
    assert spec.name == "calibrated-cpu"
    assert arch.MachineSpec.load(p) == spec  # rewritten, valid again


def test_calibrate_rejects_foreign_backend():
    with pytest.raises(ValueError, match="backend"):
        arch.calibrate(backend="tpu", **FAST_CAL)


# ----------------------- bench rows carry the fields ------------------------

def test_fast_bench_rows_have_measurement_fields(tmp_path):
    from benchmarks import bench_blas

    out = str(tmp_path / "blas.json")
    bench_blas.run(lambda *a: None, fast=True, out=out)
    with open(out) as f:
        doc = json.load(f)
    assert doc["rows"], "fast bench produced no rows"
    # fused_chain pricing rows are modeled-only by design (never timed;
    # the regression gate skips them) - everything else must be measured
    measured = [r for r in doc["rows"] if not r.get("modeled_only")]
    assert measured
    for row in measured:
        for field in ("seconds_median", "seconds_spread", "reps",
                      "model_residual"):
            assert field in row, f"{row['op']} row lacks {field}"
        assert row["reps"] >= 1
        assert row["seconds_median"] > 0
        assert math.isfinite(row["model_residual"])
        assert row["seconds_median"] == pytest.approx(
            row["seconds_per_call"])
    # the per-op resolution fix: factorization rows name their own op
    # (gemm_bias_act resolves through the fused-chain op)
    fact_rows = [r for r in measured if r["op"] != "gemm"]
    assert fact_rows
    for row in fact_rows:
        want = "gemm+epilogue" if row["op"] == "gemm_bias_act" else row["op"]
        assert row["resolution"]["for_op"] == want


# --------------------------- regression gate --------------------------------

def _load_gate():
    path = os.path.join(os.path.dirname(__file__), os.pardir, "scripts",
                        "check_perf_regression.py")
    spec = importlib.util.spec_from_file_location("check_perf_regression",
                                                  os.path.abspath(path))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _doc(med, spread=0.05):
    return {"rows": [{"op": "gemm", "n": 64, "dtype": "float32",
                      "seconds_median": med, "seconds_spread": spread,
                      "reps": 5}]}


def test_gate_passes_identical_and_fails_degraded():
    gate = _load_gate()
    base = _doc(1.0)
    ok, checked, _ = gate.compare(base, _doc(1.0), tol=0.2, spread_k=3.0)
    assert checked == 1 and not ok
    fails, _, _ = gate.compare(base, _doc(10.0), tol=0.2, spread_k=3.0)
    assert len(fails) == 1
    # inside the spread-widened allowance: 1 * (1 + .2 + 3*.05) = 1.35
    ok2, _, _ = gate.compare(base, _doc(1.30), tol=0.2, spread_k=3.0)
    assert not ok2
    fails2, _, _ = gate.compare(base, _doc(1.40), tol=0.2, spread_k=3.0)
    assert len(fails2) == 1


def test_gate_self_test_on_committed_trajectory():
    gate = _load_gate()
    committed = os.path.join(os.path.dirname(__file__), os.pardir,
                             "benchmarks", "out", "blas.json")
    assert gate.self_test(os.path.abspath(committed), tol=0.5,
                          spread_k=3.0) == 0


def test_gate_skips_rows_without_controller_fields():
    gate = _load_gate()
    legacy = {"rows": [{"op": "gemm", "n": 64, "seconds_per_call": 1.0}]}
    fails, checked, skipped = gate.compare(legacy, legacy, tol=0.5,
                                           spread_k=3.0)
    assert not fails and checked == 0 and skipped == 1
