"""TPU codesign layer + jaxpr census."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import codesign as cd
from repro.core import jaxpr_census as jc


def test_optimal_accumulators_fills_latency():
    # large n: optimum ~ add latency (pipeline-slot filling)
    u = cd.optimal_accumulators(1e6, latency=6)
    assert u == 8  # next pow2 >= 6 minimizes steady-state stalls
    # tiny n: overhead pulls it down
    assert cd.optimal_accumulators(4) <= 4


def test_reduction_cost_shape():
    # eq.-2 analogue: cost has the fixed + 1/U + U structure
    n = 1e5
    c1 = cd.reduction_cost(n, 1)
    c8 = cd.reduction_cost(n, 8)
    c64 = cd.reduction_cost(n, 64)
    assert c8 < c1            # filling the pipe helps
    assert c8 <= c64 * 1.01   # oversubscribing stops helping


def test_gemm_plan_constraints():
    p = cd.plan_gemm(4096, 4096, 4096)
    assert p.bm % 128 == 0 and p.bn % 128 == 0 and p.bk % 128 == 0
    assert p.vmem_bytes <= cd.VMEM_BYTES
    assert p.compute_bound          # big square GEMM must be compute bound
    tiny = cd.plan_gemm(64, 64, 64)
    assert tiny.bm == 128 and tiny.bn == 128


def test_gemm_plan_memory_bound_detection():
    p = cd.plan_gemm(8, 8192, 8192)     # skinny: low arithmetic intensity
    assert p.arithmetic_intensity < cd.PEAK_BF16_FLOPS / cd.HBM_BW


def test_attention_plan():
    p = cd.plan_attention(32768, 32768, 128)
    assert p.block_q % 8 == 0 and p.block_k % 128 == 0
    assert p.vmem_bytes <= cd.VMEM_BYTES
    assert p.grid_kv == -(-32768 // p.block_k)


def test_ssd_plan():
    p = cd.plan_ssd(32768, 24, 64, 128)
    assert p.chunk in (64, 128, 256)
    assert p.vmem_bytes <= cd.VMEM_BYTES


@given(m=st.integers(1, 5000), n=st.integers(1, 5000), k=st.integers(1, 5000))
@settings(max_examples=40, deadline=None)
def test_property_gemm_plan_always_valid(m, n, k):
    p = cd.plan_gemm(m, n, k)
    assert p.vmem_bytes <= cd.VMEM_BYTES
    assert p.bm >= 1 and p.bn >= 1 and p.bk >= 1
    # grid covers the padded problem
    assert p.grid[0] * p.bm >= m
    assert p.grid[1] * p.bn >= n
    assert p.grid[2] * p.bk >= k


# ---------------------------------------------------------------------------
# jaxpr census
# ---------------------------------------------------------------------------

def test_census_matmul():
    f = lambda a, b: a @ b
    c = jc.census_of(f, jax.ShapeDtypeStruct((32, 64), jnp.float32),
                     jax.ShapeDtypeStruct((64, 16), jnp.float32))
    assert c.n_i["mul"] == 32 * 64 * 16
    assert c.n_i["add"] == 32 * 64 * 16
    assert c.flops == 2 * 32 * 64 * 16


def test_census_elementwise_and_classes():
    def f(x):
        return jnp.sqrt(x) / (x + 1.0) * jnp.exp(x)
    c = jc.census_of(f, jax.ShapeDtypeStruct((100,), jnp.float32))
    assert c.n_i["sqrt"] == 100
    assert c.n_i["div"] == 100
    assert c.n_i["add"] == 100
    assert c.n_i["exp"] == 100


def test_census_scan_serial_hazards():
    def f(x):
        return jax.lax.scan(lambda c, _: (c * 0.9 + 1.0, None), x,
                            None, length=50)[0]
    c = jc.census_of(f, jax.ShapeDtypeStruct((8,), jnp.float32))
    # loop-carried dependence: hazard ratio ~ 1 on the adder pipe
    assert c.n_h["add"] / c.n_i["add"] > 0.9
    assert c.critical_path > 50


def test_census_to_profile_depths():
    """End-to-end: census a GEMM-like fn -> paper profile -> deep mul pipe,
    and a scan recurrence -> shallow add pipe. The paper's conclusion,
    derived mechanically from jaxprs."""
    gemm = jc.census_of(lambda a, b: a @ b,
                        jax.ShapeDtypeStruct((64, 64), jnp.float32),
                        jax.ShapeDtypeStruct((64, 64), jnp.float32))
    rec = jc.census_of(
        lambda x: jax.lax.scan(lambda c, _: (c + 1.0, None), x, None,
                               length=64)[0],
        jax.ShapeDtypeStruct((4,), jnp.float32))
    d_gemm = gemm.to_profile().optimal_depths()
    d_rec = rec.to_profile().optimal_depths()
    assert d_gemm["add"] > d_rec["add"]


def test_census_model_forward():
    """The census runs on a real model's train-step-sized jaxpr."""
    from repro.models import model_zoo as zoo
    from repro.models.config import ModelConfig
    cfg = ModelConfig("t", "dense", n_layers=2, d_model=32, n_heads=2,
                      n_kv=1, d_ff=64, vocab=64)
    params = jax.eval_shape(lambda k: zoo.init(k, cfg),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    c = jc.census_of(
        lambda p, t: zoo.forward(p, {"tokens": t}, cfg)[0], params,
        jax.ShapeDtypeStruct((2, 16), jnp.int32))
    assert c.n_i["mul"] > 5e4           # matmul volume present
    assert c.n_i["exp"] > 0             # softmax
    prof = c.to_profile()
    assert set(prof.optimal_depths()) <= {"mul", "add", "div", "sqrt"}
