"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret=True."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.codesign import plan_gemm
from repro.kernels import ops, ref
from repro.kernels.dotp import dotp as dotp_kernel
from repro.kernels.flash_attention import attention as fa_kernel
from repro.kernels.gemm import gemm as gemm_kernel
from repro.kernels.ssd_scan import ssd_scan


def _tol(dtype):
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 \
        else dict(atol=5e-4, rtol=5e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,n,k", [(128, 128, 128), (200, 150, 300),
                                   (64, 256, 512), (37, 53, 71)])
def test_gemm_kernel_sweep(rng, m, n, k, dtype):
    a = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32)).astype(dtype)
    b = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32)).astype(dtype)
    got = gemm_kernel(a, b, interpret=True)
    want = ref.gemm(a, b)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_gemm_kernel_uses_plan(rng):
    plan = plan_gemm(256, 256, 256, dtype_bytes=4)
    a = jnp.asarray(rng.normal(size=(256, 256)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(256, 256)).astype(np.float32))
    got = gemm_kernel(a, b, plan=plan, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(a @ b), atol=1e-3,
                               rtol=1e-3)


@pytest.mark.parametrize("n", [128, 1000, 4096, 131])
@pytest.mark.parametrize("u", [1, 4, 8])
def test_dotp_kernel_sweep(rng, n, u):
    x = jnp.asarray(rng.normal(size=n).astype(np.float32))
    y = jnp.asarray(rng.normal(size=n).astype(np.float32))
    got = float(dotp_kernel(x, y, accumulators=u, interpret=True))
    want = float(np.dot(np.asarray(x, np.float64), np.asarray(y, np.float64)))
    assert got == pytest.approx(want, rel=1e-4, abs=1e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2)])
@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 40)])
def test_flash_attention_sweep(rng, dtype, hq, hkv, causal, window):
    b, s, d = 2, 96, 64
    q = jnp.asarray(rng.normal(size=(b, hq, s, d)).astype(np.float32)).astype(dtype)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)).astype(np.float32)).astype(dtype)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)).astype(np.float32)).astype(dtype)
    got = fa_kernel(q, k, v, causal=causal, window=window, block_q=16,
                    block_k=32, interpret=True)
    want = ref.attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_flash_attention_decode(rng):
    b, hq, hkv, s, d = 2, 8, 2, 160, 64
    q = jnp.asarray(rng.normal(size=(b, hq, 1, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)).astype(np.float32))
    got = fa_kernel(q, k, v, causal=True, q_offset=s - 1, block_q=8,
                    block_k=64, interpret=True)
    want = ref.attention(q, k, v, causal=True, q_offset=s - 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-4)


def test_flash_attention_kv_len_mask(rng):
    """Padded cache: only kv_len entries participate."""
    b, h, s, d = 1, 2, 128, 32
    q = jnp.asarray(rng.normal(size=(b, h, 1, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, h, s, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, h, s, d)).astype(np.float32))
    kv_len = 70
    got = fa_kernel(q, k, v, causal=False, kv_len=kv_len, block_q=8,
                    block_k=32, interpret=True)
    want = ref.attention(q, k[:, :, :kv_len], v[:, :, :kv_len], causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-4)


@pytest.mark.parametrize("L,chunk", [(64, 16), (100, 32), (256, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_ssd_kernel_sweep(rng, L, chunk, dtype):
    b, h, p, n = 2, 3, 16, 8
    x = jnp.asarray(rng.normal(size=(b, h, L, p)).astype(np.float32)) * 0.5
    a = -jnp.abs(jnp.asarray(rng.normal(size=(b, h, L)).astype(np.float32))) * 0.3
    B = jnp.asarray(rng.normal(size=(b, h, L, n)).astype(np.float32)) * 0.5
    C = jnp.asarray(rng.normal(size=(b, h, L, n)).astype(np.float32)) * 0.5
    got = ssd_scan(x, a, B, C, chunk=chunk, interpret=True)
    # oracle on (B, L, H, ...) layout
    tr = lambda t: jnp.moveaxis(t, 1, 2)
    want = tr(ref.ssd(tr(x), jnp.moveaxis(a, 1, 2), tr(B), tr(C)))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=5e-4)


def test_ssd_chunk_invariance(rng):
    """Chunk size must not change the math (fig.-1 eq. of SSD)."""
    b, h, L, p, n = 1, 2, 96, 8, 4
    x = jnp.asarray(rng.normal(size=(b, L, h, p)).astype(np.float32)) * 0.3
    a = -jnp.abs(jnp.asarray(rng.normal(size=(b, L, h)).astype(np.float32))) * 0.2
    B = jnp.asarray(rng.normal(size=(b, L, h, n)).astype(np.float32)) * 0.3
    C = jnp.asarray(rng.normal(size=(b, L, h, n)).astype(np.float32)) * 0.3
    outs = [np.asarray(ref.ssd_chunked(x, a, B, C, chunk=c))
            for c in (8, 24, 96)]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], atol=3e-4)


@given(sq=st.integers(1, 80), sk=st.integers(8, 160))
@settings(max_examples=12, deadline=None)
def test_property_blocked_attention_matches_ref(sq, sk):
    rng = np.random.default_rng(sq * 1000 + sk)
    q = jnp.asarray(rng.normal(size=(1, 2, sq, 16)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 2, sk, 16)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 2, sk, 16)).astype(np.float32))
    off = max(sk - sq, 0)
    a = ref.attention(q, k, v, causal=True, q_offset=off)
    b = ref.blocked_attention(q, k, v, causal=True, q_offset=off, block_k=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-5)


def test_ops_dispatch_cpu_paths(rng):
    """ops.* with use_pallas=None on CPU must take the oracle path."""
    a = jnp.asarray(rng.normal(size=(32, 32)).astype(np.float32))
    out = ops.gemm(a, a)
    np.testing.assert_allclose(np.asarray(out), np.asarray(a @ a), atol=1e-4,
                               rtol=1e-4)
