"""Round-trip property tests for the blocked + batched LAPACK layer.

A == L L^T (potrf), P A == L U (getrf), A == Q R + Q orthonormal (geqrf),
for both the blocked single-matrix paths and the vmap-batched drivers, on
well-conditioned, ill-conditioned, and non-square inputs - and the blocked
paths must produce identical factors whether trailing updates run through
``a @ b`` or the Pallas kernel (use_kernel=True, interpret mode).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import lapack
from repro.core.codesign import plan_factorization


def _batch(rng, b, m, n):
    return jnp.asarray(rng.normal(size=(b, m, n)).astype(np.float32))


def _spd_batch(rng, b, n, ridge=None):
    a = rng.normal(size=(b, n, n)).astype(np.float32)
    s = a @ np.swapaxes(a, 1, 2) + (ridge or n) * np.eye(n, dtype=np.float32)
    return jnp.asarray(s)


# --------------------------- blocked round trips ----------------------------

@pytest.mark.parametrize("block", [8, 16, None])
def test_blocked_potrf_roundtrip(rng, block):
    s = _spd_batch(rng, 1, 48)[0]
    l = lapack.potrf(s, block=block)
    np.testing.assert_allclose(np.asarray(l @ l.T), np.asarray(s),
                               rtol=1e-4, atol=5e-3)
    assert float(jnp.max(jnp.abs(jnp.triu(l, 1)))) == 0.0


@pytest.mark.parametrize("m,n", [(48, 48), (56, 40), (40, 56)])
def test_blocked_getrf_roundtrip(rng, m, n):
    a = _batch(rng, 1, m, n)[0]
    packed, piv = lapack.getrf(a, block=16)
    if m == n:
        np.testing.assert_allclose(
            np.asarray(lapack.lu_reconstruct(packed, piv)), np.asarray(a),
            atol=5e-4)
    # partial pivoting keeps multipliers bounded regardless of shape
    assert float(jnp.max(jnp.abs(jnp.tril(packed, -1)))) <= 1.0 + 1e-5


@pytest.mark.parametrize("m,n", [(48, 48), (64, 40), (33, 20)])
def test_blocked_geqrf_roundtrip(rng, m, n):
    a = _batch(rng, 1, m, n)[0]
    q, r = lapack.qr.qr(a, block=16)
    np.testing.assert_allclose(np.asarray(q @ r), np.asarray(a), atol=5e-4)
    np.testing.assert_allclose(np.asarray(q.T @ q), np.eye(min(m, n)),
                               atol=5e-4)


# --------------------- kernel path == reference path ------------------------

def test_potrf_kernel_path_identical(rng):
    s = _spd_batch(rng, 1, 48)[0]
    ref = lapack.potrf(s, block=16, use_kernel=False)
    ker = lapack.potrf(s, block=16, use_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref), atol=1e-5)


def test_getrf_kernel_path_identical(rng):
    a = _batch(rng, 1, 48, 48)[0]
    ref, piv_ref = lapack.getrf(a, block=16, use_kernel=False)
    ker, piv_ker = lapack.getrf(a, block=16, use_kernel=True, interpret=True)
    assert bool(jnp.all(piv_ref == piv_ker))
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref), atol=1e-5)


def test_geqrf_kernel_path_identical(rng):
    a = _batch(rng, 1, 48, 32)[0]
    ref, tau_ref = lapack.geqrf(a, block=16, use_kernel=False)
    ker, tau_ker = lapack.geqrf(a, block=16, use_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(tau_ker), np.asarray(tau_ref),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref), atol=1e-5)


# ------------------------- batched == unbatched -----------------------------

def test_batched_potrf_matches_unbatched(rng):
    s = _spd_batch(rng, 6, 32)
    res = lapack.batched_potrf(s, block=8)
    for i in range(s.shape[0]):
        one = lapack.potrf(s[i], block=8)
        np.testing.assert_allclose(np.asarray(res.factors[i]),
                                   np.asarray(one), atol=1e-5)
    np.testing.assert_allclose(np.asarray(lapack.reconstruct(res)),
                               np.asarray(s), rtol=1e-4, atol=5e-3)


def test_batched_getrf_matches_unbatched(rng):
    a = _batch(rng, 6, 32, 32)
    res = lapack.batched_getrf(a, block=8)
    for i in range(a.shape[0]):
        packed, piv = lapack.getrf(a[i], block=8)
        assert bool(jnp.all(res.pivots[i] == piv))
        np.testing.assert_allclose(np.asarray(res.factors[i]),
                                   np.asarray(packed), atol=1e-5)
    np.testing.assert_allclose(np.asarray(lapack.reconstruct(res)),
                               np.asarray(a), atol=5e-4)


@pytest.mark.parametrize("m,n", [(32, 32), (40, 24)])
def test_batched_geqrf_matches_unbatched(rng, m, n):
    a = _batch(rng, 5, m, n)
    res = lapack.batched_geqrf(a, block=8)
    for i in range(a.shape[0]):
        packed, tau = lapack.geqrf(a[i], block=8)
        np.testing.assert_allclose(np.asarray(res.factors[i]),
                                   np.asarray(packed), atol=1e-5)
        np.testing.assert_allclose(np.asarray(res.tau[i]), np.asarray(tau),
                                   atol=1e-5)
    np.testing.assert_allclose(np.asarray(lapack.reconstruct(res)),
                               np.asarray(a), atol=5e-4)


def test_batched_kernel_path_matches(rng):
    """vmap composes with the Pallas interpret-mode trailing updates."""
    s = _spd_batch(rng, 3, 32)
    ref = lapack.batched_potrf(s, block=16, use_kernel=False)
    ker = lapack.batched_potrf(s, block=16, use_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(ker.factors),
                               np.asarray(ref.factors), atol=1e-5)


# ------------------------------ batched solve -------------------------------

def test_batched_solve_all_kinds(rng):
    B, n = 4, 32
    a = _batch(rng, B, n, n) + 8 * jnp.eye(n)
    s = _spd_batch(rng, B, n)
    b = jnp.asarray(rng.normal(size=(B, n)).astype(np.float32))

    x = lapack.batched_solve(lapack.batched_getrf(a, block=8), b)
    resid = jnp.einsum("bij,bj->bi", a, x) - b
    assert float(jnp.max(jnp.abs(resid))) < 2e-3

    x = lapack.batched_solve(lapack.batched_potrf(s, block=8), b)
    resid = jnp.einsum("bij,bj->bi", s, x) - b
    assert float(jnp.max(jnp.abs(resid))) < 2e-3

    # least squares: tall systems, compare against numpy per item
    at = _batch(rng, B, 48, 20)
    bt = jnp.asarray(rng.normal(size=(B, 48)).astype(np.float32))
    x = lapack.batched_solve(lapack.batched_geqrf(at, block=8), bt)
    for i in range(B):
        ref = np.linalg.lstsq(np.asarray(at[i]), np.asarray(bt[i]),
                              rcond=None)[0]
        np.testing.assert_allclose(np.asarray(x[i]), ref, atol=2e-3)


def test_batched_solve_matrix_rhs(rng):
    B, n, k = 3, 24, 5
    a = _batch(rng, B, n, n) + 8 * jnp.eye(n)
    b = jnp.asarray(rng.normal(size=(B, n, k)).astype(np.float32))
    x = lapack.batched_solve(lapack.batched_getrf(a, block=8), b)
    resid = a @ x - b
    assert float(jnp.max(jnp.abs(resid))) < 2e-3


# --------------------------- edge cases & pytree ----------------------------

def test_potrf_ill_conditioned_stays_finite(rng):
    """Condition number ~1e6: factor must stay finite and reconstruct to a
    relative accuracy ~ cond * eps."""
    n = 24
    q, _ = np.linalg.qr(rng.normal(size=(n, n)))
    d = np.logspace(0, -6, n)
    s = jnp.asarray((q @ np.diag(d) @ q.T).astype(np.float32))
    s = (s + s.T) / 2 + 1e-6 * jnp.eye(n)
    l = lapack.potrf(s, block=8)
    assert bool(jnp.all(jnp.isfinite(l)))
    np.testing.assert_allclose(np.asarray(l @ l.T), np.asarray(s),
                               atol=1e-4)


def test_getrf_singular_column_no_nan(rng):
    """A zero column hits the safe-pivot path, never produces NaN."""
    a = np.asarray(_batch(rng, 1, 16, 16)[0]).copy()
    a[:, 3] = 0.0
    packed, piv = lapack.getrf(jnp.asarray(a), block=8)
    assert bool(jnp.all(jnp.isfinite(packed)))


def test_batched_solve_wide_geqrf_rejected(rng):
    """m < n is underdetermined: clear error, not a shape blowup."""
    a = _batch(rng, 2, 8, 12)
    res = lapack.batched_geqrf(a, block=4)
    with pytest.raises(ValueError, match="m >= n"):
        lapack.batched_solve(res, jnp.asarray(np.ones((2, 8), np.float32)))
    rl = lapack.batched_getrf(_batch(rng, 2, 12, 8), block=4)
    with pytest.raises(ValueError, match="square"):
        lapack.batched_solve(rl, jnp.asarray(np.ones((2, 12), np.float32)))


def test_geqrf_wide_matrix(rng):
    """m < n: kmax = m reflectors, R is m x n trapezoidal."""
    a = _batch(rng, 1, 20, 33)[0]
    packed, tau = lapack.geqrf(a, block=8)
    assert tau.shape == (20,)
    q = lapack.q_from_geqrf(packed, tau)
    r = jnp.triu(packed)
    np.testing.assert_allclose(np.asarray(q @ r), np.asarray(a), atol=5e-4)


def test_factorization_result_is_pytree(rng):
    a = _batch(rng, 2, 16, 16)
    res = lapack.batched_getrf(a, block=8)
    leaves = jax.tree_util.tree_leaves(res)
    assert len(leaves) == 2  # factors + pivots; static kind/block in aux
    rebuilt = jax.tree_util.tree_map(lambda x: x, res)
    assert rebuilt.kind == "getrf" and rebuilt.block == 8
    # jit through the pytree API end to end
    f = jax.jit(lambda m, b: lapack.batched_solve(
        lapack.batched_getrf(m, block=8), b))
    b = jnp.asarray(np.ones((2, 16), np.float32))
    x = f(a, b)
    assert x.shape == (2, 16)


def test_plan_factorization_defaults_are_sane():
    """The codesign model must return usable NB everywhere on the grid the
    benchmarks sweep, and collapse to unblocked for panel-sized problems."""
    for kind in ("potrf", "getrf", "geqrf"):
        for n in (4, 16, 64, 256, 2048):
            p = plan_factorization(n, kind=kind)
            assert 1 <= p.block <= max(n, 8)
            assert p.modeled_time > 0
            assert 0.0 <= p.panel_fraction <= 1.0
        small = plan_factorization(16, kind=kind)
        assert small.block == 16  # single panel -> unblocked path
    with pytest.raises(ValueError):
        plan_factorization(64, kind="svd")


# ------------------ dtype-generic repro.linalg front-end --------------------
# Round-trips of the batched drivers through the new context-scoped API in
# every in-process dtype (float64 runs in tests/test_linalg.py's x64
# subprocess grid); tolerances from the shared dtype_tolerances helper.

from conftest import LINALG_DTYPES  # noqa: F401  (shared dtype grid)

from repro import linalg


@pytest.mark.parametrize("dtype", LINALG_DTYPES)
def test_linalg_batched_cholesky_roundtrip_dtypes(rng, assert_close, dtype):
    s = _spd_batch(rng, 4, 16).astype(dtype)
    res = linalg.batched_cholesky(s, block=8)
    assert res.factors.dtype == jnp.dtype(dtype)
    assert_close(jnp.einsum("bij,bkj->bik", res.factors, res.factors),
                 np.asarray(s.astype(jnp.float32), np.float64), scale=16.0)


@pytest.mark.parametrize("dtype", LINALG_DTYPES)
def test_linalg_batched_lu_roundtrip_dtypes(rng, assert_close, dtype):
    a = _batch(rng, 4, 16, 16).astype(dtype)
    res = linalg.batched_lu(a, block=8)
    assert_close(lapack.reconstruct(res),
                 np.asarray(a.astype(jnp.float32), np.float64), scale=16.0)


@pytest.mark.parametrize("dtype", LINALG_DTYPES)
def test_linalg_batched_qr_roundtrip_dtypes(rng, assert_close, dtype):
    a = _batch(rng, 3, 20, 12).astype(dtype)
    res = linalg.batched_qr(a, block=8)
    assert res.kind == "geqrf" and res.tau.dtype == jnp.dtype(dtype)
    assert_close(lapack.reconstruct(res),
                 np.asarray(a.astype(jnp.float32), np.float64), scale=16.0)


@pytest.mark.parametrize("pol", ["reference", "model", "tuned"])
def test_linalg_batched_solve_policy_grid(rng, pol):
    B, n = 3, 16
    a = _batch(rng, B, n, n) + 8 * jnp.eye(n)
    b = jnp.asarray(rng.normal(size=(B, n)).astype(np.float32))
    with linalg.use(policy=pol):
        x = linalg.batched_solve(linalg.batched_lu(a, block=8), b)
    resid = jnp.einsum("bij,bj->bi", a, x) - b
    assert float(jnp.max(jnp.abs(resid))) < 2e-3
