"""LAPACK substrate: QR / LU / Cholesky / solvers (+ hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import lapack
from repro.blas.level3 import dtrsm


def _rand(rng, m, n):
    return jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))


@pytest.mark.parametrize("block", [8, 999])
@pytest.mark.parametrize("m,n", [(32, 32), (48, 32), (33, 20)])
def test_qr_reconstruction(rng, m, n, block):
    a = _rand(rng, m, n)
    q, r = lapack.qr.qr(a, block=block)
    np.testing.assert_allclose(np.asarray(q @ r), np.asarray(a), atol=5e-4)
    np.testing.assert_allclose(np.asarray(q.T @ q), np.eye(n), atol=5e-4)
    # R upper triangular
    assert float(jnp.max(jnp.abs(jnp.tril(r, -1)))) < 1e-5


def test_qr_matches_numpy_abs(rng):
    a = _rand(rng, 24, 24)
    _, r = lapack.qr.qr(a)
    r_np = np.linalg.qr(np.asarray(a))[1]
    # QR unique up to column signs
    np.testing.assert_allclose(np.abs(np.asarray(r)), np.abs(r_np),
                               atol=5e-4)


@pytest.mark.parametrize("block", [8, 999])
def test_lu_reconstruction(rng, block):
    a = _rand(rng, 40, 40)
    packed, piv = lapack.getrf(a, block=block)
    np.testing.assert_allclose(np.asarray(lapack.lu_reconstruct(packed, piv)),
                               np.asarray(a), atol=5e-4)
    # partial pivoting: |L| <= 1
    l = np.tril(np.asarray(packed), -1)
    assert np.max(np.abs(l)) <= 1.0 + 1e-5


def test_lu_blocked_equals_unblocked(rng):
    a = _rand(rng, 36, 36)
    p1, v1 = lapack.getrf(a, block=8)
    p2, v2 = lapack.getrf_unblocked(a)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2), atol=3e-4)
    assert bool(jnp.all(v1 == v2))


@pytest.mark.parametrize("block", [8, 999])
def test_cholesky(rng, block):
    a = _rand(rng, 32, 32)
    s = a @ a.T + 32 * jnp.eye(32)
    c = lapack.potrf(s, block=block)
    np.testing.assert_allclose(np.asarray(c @ c.T), np.asarray(s), rtol=1e-4,
                               atol=5e-3)
    np.testing.assert_allclose(np.asarray(c), np.linalg.cholesky(np.asarray(s)),
                               rtol=2e-3, atol=5e-3)


def test_gesv(rng):
    a = _rand(rng, 32, 32) + 8 * jnp.eye(32)
    b = _rand(rng, 32, 3)
    x = lapack.gesv(a, b, block=8)
    np.testing.assert_allclose(np.asarray(a @ x), np.asarray(b), atol=2e-3)


def test_lstsq_qr(rng):
    a = _rand(rng, 50, 20)
    b = jnp.asarray(rng.normal(size=50).astype(np.float32))
    x = lapack.lstsq_qr(a, b)
    ref = np.linalg.lstsq(np.asarray(a), np.asarray(b), rcond=None)[0]
    np.testing.assert_allclose(np.asarray(x), ref, atol=2e-3)


def test_jit_compatible(rng):
    a = _rand(rng, 24, 24)
    f = jax.jit(lambda m: lapack.getrf(m, block=8))
    packed, piv = f(a)
    np.testing.assert_allclose(np.asarray(lapack.lu_reconstruct(packed, piv)),
                               np.asarray(a), atol=3e-4)
    g = jax.jit(lambda m: lapack.qr.geqrf(m, block=8))
    pk, tau = g(a)
    q = lapack.q_from_geqrf(pk, tau)
    r = jnp.triu(pk)
    np.testing.assert_allclose(np.asarray(q @ r), np.asarray(a), atol=5e-4)


@given(seed=st.integers(0, 2 ** 31 - 1), n=st.integers(4, 48))
@settings(max_examples=15, deadline=None)
def test_property_lu_solves(seed, n):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(n, n)).astype(np.float32)) \
        + n * jnp.eye(n)
    b = jnp.asarray(rng.normal(size=n).astype(np.float32))
    x = lapack.gesv(a, b, block=16)
    resid = float(jnp.max(jnp.abs(a @ x - b)))
    assert resid < 1e-2 * n


@given(seed=st.integers(0, 2 ** 31 - 1), m=st.integers(6, 40),
       n=st.integers(4, 30))
@settings(max_examples=15, deadline=None)
def test_property_qr_orthogonality(seed, m, n):
    if m < n:
        m, n = n, m
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
    q, r = lapack.qr.qr(a, block=16)
    err = float(jnp.max(jnp.abs(q.T @ q - jnp.eye(n))))
    assert err < 3e-3
