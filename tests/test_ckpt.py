"""Checkpointing: atomicity, keep-N, manifests, elastic restore."""
import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ck
from repro.ckpt.manager import CheckpointManager


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (8, 8)),
            "nested": {"b": jnp.arange(10, dtype=jnp.int32),
                       "c": (jnp.ones((3,)), jnp.zeros((2, 2)))}}


def test_save_restore_roundtrip():
    t = _tree()
    with tempfile.TemporaryDirectory() as d:
        ck.save(d, 5, t)
        like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
        r, step = ck.restore(d, like)
        assert step == 5
        eq = jax.tree.map(lambda a, b: bool(jnp.all(a == b)), t, r)
        assert all(jax.tree.leaves(eq))


def test_keep_n_gc():
    t = _tree()
    with tempfile.TemporaryDirectory() as d:
        for s in range(6):
            ck.save(d, s, t, keep=3)
        assert ck.all_steps(d) == [3, 4, 5]


def test_atomic_no_partial_dirs():
    t = _tree()
    with tempfile.TemporaryDirectory() as d:
        ck.save(d, 1, t)
        names = os.listdir(d)
        assert all(not n.startswith(".tmp") for n in names)
        # manifest contents
        with open(os.path.join(d, "step_0000000001", "manifest.json")) as f:
            man = json.load(f)
        assert man["step"] == 1
        assert "a" in man["keys"]


def test_restore_missing_key_errors():
    t = _tree()
    with tempfile.TemporaryDirectory() as d:
        ck.save(d, 0, t)
        bad_like = {"zzz": jax.ShapeDtypeStruct((1,), jnp.float32)}
        with pytest.raises(KeyError):
            ck.restore(d, bad_like)


def test_latest_step_empty():
    with tempfile.TemporaryDirectory() as d:
        assert ck.latest_step(d) is None
        mgr = CheckpointManager(d)
        state, step = mgr.restore_latest({"x": jax.ShapeDtypeStruct((1,), jnp.float32)})
        assert state is None and step == -1


def test_manager_interval():
    mgr = CheckpointManager("/tmp/unused", save_interval=10)
    assert not mgr.should_save(0)
    assert mgr.should_save(10)
    assert not mgr.should_save(11)
