"""Tests for the SPMD static-analysis layer (rules CC/SH/BY).

Two tiers, mirroring the repo's distributed-test convention: synthetic
jaxpr-like objects exercise every rule's detector in-process (the main
pytest process keeps 1 device - see conftest), and one subprocess with
8 forced host devices runs the real distributed sweep plus real-mesh
seeded violations. Every new rule ID is proven *live* - a seeded
violation fires it - and the clean sweep is proven silent.
"""
import json
import os
import subprocess
import sys
import textwrap
import warnings
from types import SimpleNamespace

import jax.numpy as jnp
import numpy as np
import pytest

from repro import analysis
from repro.analysis import bypass_lint, spmd_lint
from repro.distributed.collectives import (CollectiveRecord,
                                           record_collectives)


# --------------------------- synthetic jaxpr kit ----------------------------

def _var(shape, dtype="float32"):
    return SimpleNamespace(aval=SimpleNamespace(shape=tuple(shape),
                                                dtype=jnp.dtype(dtype)))


def _eqn(prim, params=None, invars=(), outvars=()):
    return SimpleNamespace(primitive=SimpleNamespace(name=prim),
                           params=dict(params or {}),
                           invars=list(invars), outvars=list(outvars))


def _jaxpr(*eqns):
    return SimpleNamespace(eqns=list(eqns))


def _mesh(**axes):
    return SimpleNamespace(shape=dict(axes))


def _shard_map_eqn(body, mesh, in_names=(), out_names=(), invars=(),
                   outvars=()):
    return _eqn("shard_map",
                params={"mesh": mesh, "in_names": tuple(in_names),
                        "out_names": tuple(out_names),
                        "jaxpr": SimpleNamespace(jaxpr=body)},
                invars=invars, outvars=outvars)


def _ppermute(perm, axis="y", shape=(4, 4)):
    return _eqn("ppermute", params={"axis_name": (axis,),
                                    "perm": tuple(perm)},
                invars=[_var(shape)], outvars=[_var(shape)])


def _ring_perm(size):
    return [((d - 1) % size, d) for d in range(size)]


def _rules_of(findings):
    return {f.rule for f in findings}


# ------------------------------- CC001 --------------------------------------

def test_cc001_clean_ring_is_silent():
    f = spmd_lint.lint_ppermute_eqn(_ppermute(_ring_perm(4)), {"y": 4})
    assert f == []


def test_cc001_self_send_fires():
    f = spmd_lint.lint_ppermute_eqn(
        _ppermute([(0, 0), (1, 1)]), {"y": 2})
    assert _rules_of(f) == {"CC001"} and "self-send" in f[0].message


def test_cc001_duplicate_endpoint_fires():
    f = spmd_lint.lint_ppermute_eqn(
        _ppermute([(0, 1), (0, 2), (1, 0)]), {"y": 3})
    assert _rules_of(f) == {"CC001"} and "bijection" in f[0].message


def test_cc001_partial_coverage_fires():
    f = spmd_lint.lint_ppermute_eqn(
        _ppermute([(0, 1), (1, 0)]), {"y": 4})
    assert _rules_of(f) == {"CC001"} and "2 of 4" in f[0].message


def test_cc001_multi_cycle_fires():
    # bijective and covering, but two disjoint 2-cycles - half the ring
    # never sees the src panel
    f = spmd_lint.lint_ppermute_eqn(
        _ppermute([(0, 1), (1, 0), (2, 3), (3, 2)]), {"y": 4})
    assert _rules_of(f) == {"CC001"} and "cycles" in f[0].message


def test_cc001_fires_through_the_walker_with_shard_map_axis_env():
    # the axis size comes from the *enclosing* shard_map's mesh
    body = _jaxpr(_ppermute([(0, 1), (1, 0)], axis="y"))
    top = _jaxpr(_shard_map_eqn(body, _mesh(x=2, y=4)))
    f = spmd_lint.lint_collective_jaxpr(top)
    assert "CC001" in _rules_of(f)


# ------------------------------- SH001 --------------------------------------

def test_sh001_clean_spec_is_silent():
    eq = _shard_map_eqn(_jaxpr(), _mesh(x=2, y=2),
                        in_names=({0: ("x",), 1: ("y",)},),
                        invars=[_var((4, 6))])
    assert spmd_lint.lint_shard_map_eqn(eq) == []


def test_sh001_non_divisible_dim_fires():
    eq = _shard_map_eqn(_jaxpr(), _mesh(x=2, y=2),
                        in_names=({0: ("x",)},), invars=[_var((3, 4))])
    f = spmd_lint.lint_shard_map_eqn(eq)
    assert _rules_of(f) == {"SH001"} and "not divisible" in f[0].message


def test_sh001_spec_beyond_rank_fires():
    eq = _shard_map_eqn(_jaxpr(), _mesh(x=2, y=2),
                        in_names=({2: ("x",)},), invars=[_var((4, 4))])
    f = spmd_lint.lint_shard_map_eqn(eq)
    assert _rules_of(f) == {"SH001"} and "rank-2" in f[0].message


def test_sh001_unknown_mesh_axis_fires_on_out_spec():
    eq = _shard_map_eqn(_jaxpr(), _mesh(x=2, y=2),
                        out_names=({0: ("z",)},), outvars=[_var((4, 4))])
    f = spmd_lint.lint_shard_map_eqn(eq)
    assert _rules_of(f) == {"SH001"} and "absent from the mesh" in \
        f[0].message


# ------------------------------- SH003 --------------------------------------

def test_sh003_all_gather_inside_shard_map_warns():
    gather = _eqn("all_gather", params={"axis_name": ("y",)},
                  invars=[_var((4, 4))], outvars=[_var((8, 4))])
    top = _jaxpr(_shard_map_eqn(_jaxpr(gather), _mesh(x=2, y=2)))
    f = spmd_lint.lint_collective_jaxpr(top)
    assert _rules_of(f) == {"SH003"} and f[0].severity == "warn"


def test_sh003_all_gather_outside_shard_map_is_silent():
    gather = _eqn("all_gather", params={"axis_name": ("y",)},
                  invars=[_var((4, 4))], outvars=[_var((8, 4))])
    assert spmd_lint.lint_collective_jaxpr(_jaxpr(gather)) == []


# ------------------------------- CC002 --------------------------------------

def _ring_record(size=4, hops=None, axis="y", per_hop=64):
    hops = size - 1 if hops is None else hops
    return CollectiveRecord(kind="ring_bcast", axis=axis, size=size,
                            src=0, hops=hops, per_hop_bytes=per_hop,
                            wire_bytes=per_hop * hops)


def test_cc002_recorded_hops_must_be_size_minus_one():
    f = spmd_lint.lint_collective_records(_jaxpr(), [_ring_record(hops=2)])
    assert "CC002" in _rules_of(f)
    assert any("size - 1 = 3" in x.message for x in f)


def test_cc002_jaxpr_census_must_match_records():
    # schedule declares 3 hops on "y"; the trace only contains 2
    jx = _jaxpr(_ppermute(_ring_perm(4)), _ppermute(_ring_perm(4)))
    f = spmd_lint.lint_collective_records(jx, [_ring_record(size=4)])
    assert any(x.rule == "CC002" and "traced 2" in x.message for x in f)


def test_cc002_counter_delta_must_match_records():
    jx = _jaxpr(*[_ppermute(_ring_perm(4)) for _ in range(3)])
    f = spmd_lint.lint_collective_records(
        jx, [_ring_record(size=4)], counter_delta={"collective.hops": 5})
    assert any(x.rule == "CC002" and "counter" in x.message for x in f)


def test_cc002_consistent_schedule_is_silent():
    jx = _jaxpr(*[_ppermute(_ring_perm(4), shape=(4, 4))
                  for _ in range(3)])
    f = spmd_lint.lint_collective_records(
        jx, [_ring_record(size=4, per_hop=64)],
        counter_delta={"collective.hops": 3, "collective.bytes": 192})
    assert f == []


# ------------------------------- CC003 --------------------------------------

def test_cc003_counter_byte_drift_fires():
    jx = _jaxpr(_ppermute(_ring_perm(2), shape=(4, 4)))   # 64 B on wire
    f = spmd_lint.lint_collective_records(
        jx, [_ring_record(size=2, hops=1, per_hop=64)],
        counter_delta={"collective.hops": 1, "collective.bytes": 128})
    assert any(x.rule == "CC003" and "counter" in x.message for x in f)


def test_cc003_plan_pdgemm_drift_fires():
    # a declared pdgemm schedule with an empty trace: the plan's
    # collective term (7168 B for this geometry) has nothing to match
    sched = CollectiveRecord(kind="pdgemm", size=4,
                             info={"m": 48, "n": 64, "k": 32, "px": 2,
                                   "py": 2, "kf": 8, "itemsize": 4,
                                   "dtype": "float32"})
    f = spmd_lint.lint_collective_records(_jaxpr(), [sched])
    assert any(x.rule == "CC003" and "plan_pdgemm" in x.message for x in f)


# ------------------------------- SH002 --------------------------------------

def _pad_record(batch, pad, ndev, identity=True):
    return CollectiveRecord(kind="pad_batch", size=ndev,
                            info={"batch": batch, "pad": pad,
                                  "identity": identity})


def test_sh002_clean_pad_is_silent():
    f = spmd_lint.lint_collective_records(
        _jaxpr(), [_pad_record(2, 6, 8), _pad_record(8, 0, 8)])
    assert f == []


def test_sh002_non_multiple_pad_fires():
    f = spmd_lint.lint_collective_records(_jaxpr(), [_pad_record(3, 2, 4)])
    assert _rules_of(f) == {"SH002"} and "not a" in f[0].message


def test_sh002_non_minimal_pad_fires():
    f = spmd_lint.lint_collective_records(_jaxpr(), [_pad_record(3, 5, 4)])
    assert _rules_of(f) == {"SH002"} and "not minimal" in f[0].message


def test_sh002_non_identity_filler_fires():
    f = spmd_lint.lint_collective_records(
        _jaxpr(), [_pad_record(3, 1, 4, identity=False)])
    assert _rules_of(f) == {"SH002"} and "identity" in f[0].message


# --------------------- real traces on the 1-device host ---------------------

def test_record_collectives_captures_pdgemm_schedule_on_1x1():
    # a (1,1) mesh works on the single-device pytest host: zero hops, but
    # the pdgemm schedule record and the degenerate ring records appear
    import jax
    from repro.blas import distributed as dblas
    a = jnp.ones((8, 8), jnp.float32)
    mesh = dblas.make_blas_mesh(1, 1)
    with record_collectives() as rec:
        jax.make_jaxpr(lambda x, y: dblas.pdgemm(x, y, mesh))(a, a)
    kinds = [r.kind for r in rec]
    assert "pdgemm" in kinds and "ring_bcast" in kinds
    assert all(r.hops == 0 for r in rec if r.kind == "ring_bcast")


def test_check_runs_spmd_rules_on_1x1_mesh_leg():
    from repro import linalg
    a = np.random.default_rng(0).standard_normal((8, 8)).astype(np.float32)
    with linalg.use(mesh=(1, 1)):
        rep = analysis.check(linalg.gemm, a, a, retrace=False, drift=False)
    assert rep.ok, rep.summary()


# ----------------------- 8-device subprocess sweep --------------------------

_ENV = dict(os.environ,
            XLA_FLAGS="--xla_force_host_platform_device_count=8",
            PYTHONPATH="src")

_PRELUDE = """
import jax, jax.numpy as jnp, numpy as np
from functools import partial
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro import analysis
from repro.analysis import report, spmd_lint
from repro.blas import distributed as dblas
"""


def _run(body: str):
    code = _PRELUDE + textwrap.dedent(body)
    r = subprocess.run([sys.executable, "-c", code], env=_ENV,
                       capture_output=True, text=True, timeout=900,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_distributed_sweep_is_clean_and_seeded_violations_fire():
    out = _run("""
    # 1) the clean sweep: direct pdgemm/pdtrsm over every acceptance mesh
    rep = report.check_distributed(dtypes=("float32",))
    assert rep.ok, rep.summary()
    cases = [c for c in rep.cases if "skipped" not in c]
    meshes = {tuple(c["mesh"]) for c in cases}
    names = {c["routine"] for c in cases}
    assert meshes == {(1, 1), (2, 2), (4, 2)}, meshes
    assert names == {"pdgemm", "pdtrsm"}, names
    assert len(cases) == 18, len(cases)     # 3 meshes x 2 routines x 3 pol
    print("clean sweep OK:", len(cases), "cases")

    # ... and the via-context mesh legs of the linalg surface
    rep = analysis.check_surface(routines=["gemm", "batched_cholesky"],
                                 dtypes=("float32",),
                                 meshes=report.SURFACE_MESHES)
    assert rep.ok, rep.summary()
    print("surface mesh legs OK:", len(rep.cases), "cases")

    # 2) seeded CC001 on a real mesh: a two-2-cycle ppermute perm
    mesh = dblas.make_blas_mesh(4, 2)
    def two_cycles(x):
        perm = [(0, 1), (1, 0), (2, 3), (3, 2)]
        return jax.lax.ppermute(x, "x", perm)
    bad = shard_map(two_cycles, mesh=mesh, in_specs=P("x", None),
                    out_specs=P("x", None), check_rep=False)
    closed = jax.make_jaxpr(bad)(jnp.ones((8, 4), jnp.float32))
    f = spmd_lint.lint_collective_jaxpr(closed)
    assert {x.rule for x in f} == {"CC001"}, f
    print("seeded CC001 OK")

    # 3) seeded CC002/CC003 on a real trace: records from a real pdgemm
    # paired against a doctored jaxpr (fewer hops / fewer bytes)
    from repro.distributed.collectives import record_collectives
    a = jnp.ones((16, 16), jnp.float32)
    mesh = dblas.make_blas_mesh(2, 2)
    with record_collectives() as rec:
        closed = jax.make_jaxpr(lambda p, q: dblas.pdgemm(p, q, mesh))(a, a)
    f = spmd_lint.lint_collective_records(closed, rec)
    assert not f, f                          # truthful pairing is silent
    empty = jax.make_jaxpr(lambda x: x + 1)(a)
    f = spmd_lint.lint_collective_records(empty, rec)
    got = {x.rule for x in f}
    assert "CC002" in got and "CC003" in got, f
    print("seeded CC002/CC003 OK")
    """)
    assert "clean sweep OK" in out
    assert "seeded CC001 OK" in out
    assert "seeded CC002/CC003 OK" in out


# ------------------------------- BY001 --------------------------------------

def _raw_entry(name="raw"):
    def build():
        a = jnp.ones((4, 4), jnp.float32)

        def fn(x):
            return x @ x
        return fn, (a,), {}
    return [(name, build)]


def test_by001_fires_on_raw_contraction():
    rep = bypass_lint.lint_bypass(entries=_raw_entry(), allowlist=None)
    assert not rep.ok
    assert [f.rule for f in rep.findings] == ["BY001"]
    assert "dot_general" in rep.findings[0].message


def test_by001_dispatched_path_is_silent():
    # the same contraction through the dispatcher's executor is exempt
    def build():
        from repro.tune import dispatch
        a = jnp.ones((8, 8), jnp.float32)
        res = dispatch.resolve("gemm", (8, 8, 8), a.dtype,
                               policy="reference")

        def fn(x):
            return dispatch._gemm_exec(x, x, res, True)
        return fn, (a,), {}
    rep = bypass_lint.lint_bypass(entries=[("via-dispatch", build)],
                                  allowlist=None)
    assert rep.ok and not rep.findings and not rep.suppressed


def test_by001_allowlist_round_trip(tmp_path):
    rep = bypass_lint.lint_bypass(entries=_raw_entry(), allowlist=None)
    site = rep.findings[0].location
    path = tmp_path / "by.json"
    path.write_text(json.dumps({
        "schema_version": 1, "rule": "BY001",
        "sites": [{"site": site, "reason": "test exemption"}]}))
    rep2 = bypass_lint.lint_bypass(entries=_raw_entry(),
                                   allowlist=str(path))
    assert rep2.ok and not rep2.findings
    assert len(rep2.suppressed) == 1
    assert rep2.suppressed[0].suppressed_by == f"allowlist:{path}"


def test_by001_corrupt_allowlist_warns_once_and_refires(tmp_path):
    path = tmp_path / "corrupt.json"
    path.write_text("{not json")
    with pytest.warns(RuntimeWarning, match="corrupt"):
        rep = bypass_lint.lint_bypass(entries=_raw_entry(),
                                      allowlist=str(path))
    assert not rep.ok and rep.findings          # re-fires, never hides
    with warnings.catch_warnings():
        warnings.simplefilter("error")          # second load: no warning
        assert bypass_lint.load_bypass_allowlist(str(path)) == {}


def test_by001_missing_allowlist_is_silently_empty(tmp_path):
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        got = bypass_lint.load_bypass_allowlist(str(tmp_path / "no.json"))
    assert got == {}


def test_by001_wrong_rule_allowlist_warns(tmp_path):
    path = tmp_path / "wrong.json"
    path.write_text(json.dumps({"schema_version": 1, "rule": "CM001",
                                "sites": []}))
    with pytest.warns(RuntimeWarning, match="corrupt"):
        assert bypass_lint.load_bypass_allowlist(str(path)) == {}


def test_by001_committed_allowlist_covers_every_current_bypass():
    # the acceptance contract: the committed burn-down file enumerates
    # every bypass reachable from models/kernels/serving today (count >
    # 0), so CI fails exactly on *new* sites. Slow-ish (traces every
    # entry point) but pure tracing - no execution.
    assert os.path.exists(bypass_lint.DEFAULT_ALLOWLIST_PATH)
    committed = bypass_lint.load_bypass_allowlist()
    assert len(committed) > 0
    rep = bypass_lint.lint_bypass()
    broken = [c for c in rep.cases if "error" in c]
    assert not broken, broken                    # every entry must trace
    assert rep.ok, "new bypass site(s):\n" + rep.summary()
    assert len(rep.suppressed) == len(committed)


# --------------------------- vocabulary plumbing ----------------------------

def test_spmd_rules_reachable_from_check_surface_defaults():
    # SURFACE_MESHES is the frozen acceptance set; the default surface
    # sweep must expand the legacy mesh knob onto it
    from repro.analysis import report
    assert report.SURFACE_MESHES == ((1, 1), (2, 2), (4, 2))
    assert tuple(report.DISTRIBUTED_ROUTINES) == ("pdgemm", "pdtrsm")


def test_allow_scope_suppresses_spmd_rule():
    with analysis.allow("SH002"):
        rep_findings, = [spmd_lint.lint_collective_records(
            _jaxpr(), [_pad_record(3, 2, 4)])]
        from repro.analysis.rules import apply_suppression
        active, suppressed = apply_suppression(rep_findings)
    assert not active and len(suppressed) == 1
    assert suppressed[0].suppressed_by == "allow()"
