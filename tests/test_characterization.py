"""Paper section 4: symbolic censuses vs the enumerated ISA streams."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import characterization as ch
from repro.core import isa


def test_ddot_counts_match_stream():
    for n in (16, 100, 257):
        prof = ch.characterize_ddot(n)
        stream = isa.compile_ddot(n, schedule="tree")
        census = stream.census()
        assert census["mul"] == prof.pipes["mul"].n_i == n
        assert census["add"] == prof.pipes["add"].n_i == n - 1
        assert stream.hazard_census()["mul"] == 0  # fig. 5: parallel muls


def test_ddot_sequential_maximal_hazards():
    n = 64
    stream = isa.compile_ddot(n, schedule="sequential")
    hz = stream.hazard_census()
    # every accumulate (after the first) depends on the previous instruction
    assert hz["add"] == n - 2
    prof = ch.characterize_ddot(n, schedule="sequential")
    assert prof.pipes["add"].n_h == n - 2


def test_strided_schedule_reduces_hazards():
    n = 512
    seq = isa.compile_ddot(n, schedule="sequential").hazard_census()["add"]
    s8 = isa.compile_ddot(n, schedule="strided",
                          accumulators=8).hazard_census()["add"]
    assert s8 < seq / 4  # U accumulators break the back-to-back chain


def test_dgemv_scales_ddot():
    prof = ch.characterize_dgemv(10, 50)
    one = ch.characterize_ddot(50)
    assert prof.pipes["mul"].n_i == 10 * one.pipes["mul"].n_i
    assert prof.pipes["add"].n_i == 10 * one.pipes["add"].n_i


def test_dgemm_counts():
    m, n, k = 8, 9, 10
    prof = ch.characterize_dgemm(m, n, k)
    stream = isa.compile_dgemm(m, n, k)
    census = stream.census()
    assert census["mul"] == m * n * k == prof.pipes["mul"].n_i
    assert census["add"] == m * n * (k - 1) == prof.pipes["add"].n_i
    assert prof.flops == 2 * m * n * k


def test_dgemm_unroll_reduces_hazards():
    h1 = isa.compile_dgemm(4, 4, 64, unroll=1).hazard_census()["add"]
    h8 = isa.compile_dgemm(4, 4, 64, unroll=8).hazard_census()["add"]
    assert h8 < h1 / 4  # the paper's compiler-optimization effect [23]


def test_qr_stream_op_mix():
    n = 12
    stream = isa.compile_dgeqrf(n)
    census = stream.census()
    # sqrt: one per factored column; div: ~n^2/2 (scaling) + tau
    assert census["sqrt"] == n - 1
    assert n * (n - 1) / 2 * 0.5 < census["div"] < n * n
    # O(n^3) muls dominate O(n^2) divs (the paper's fig. 9 point)
    assert census["mul"] > 10 * census["div"]
    prof = ch.characterize_dgeqrf(n)
    assert prof.pipes["sqrt"].n_h >= prof.pipes["sqrt"].n_i - 1  # serial


def test_lu_stream_op_mix():
    n = 12
    census = isa.compile_dgetrf(n).census()
    assert census["sqrt"] == 0                      # no sqrt in LU
    assert census["div"] == n * (n - 1) / 2         # column scalings
    prof = ch.characterize_dgetrf(n)
    assert prof.pipes["div"].n_i == n * (n - 1) / 2


def test_cholesky_stream():
    n = 10
    census = isa.compile_dpotrf(n).census()
    assert census["sqrt"] == n
    assert census["div"] == n * (n - 1) / 2


def test_optimal_depths_ordering():
    """The paper's bottom line: hazard-free mul pipe wants deep pipelines,
    serial sqrt/div pipes want shallow ones."""
    prof = ch.characterize_dgeqrf(100)
    d = prof.optimal_depths(p_max=64)
    assert d["mul"] == 64                        # monotone: deepest allowed
    assert d["sqrt"] < d["mul"]
    assert d["div"] < d["mul"]


@given(n=st.integers(4, 2048))
@settings(max_examples=30, deadline=None)
def test_property_ddot_census_invariants(n):
    prof = ch.characterize_ddot(n)
    assert prof.pipes["mul"].n_h == 0
    assert prof.pipes["add"].n_i == n - 1
    assert 0 <= prof.pipes["add"].n_h <= prof.pipes["add"].n_i
    assert prof.flops == 2 * n - 1


@given(m=st.integers(2, 12), n=st.integers(2, 12), k=st.integers(2, 24),
       u=st.integers(1, 8))
@settings(max_examples=25, deadline=None)
def test_property_gemm_stream_matches_census(m, n, k, u):
    stream = isa.compile_dgemm(m, n, k, unroll=u)
    census = stream.census()
    assert census["mul"] == m * n * k
    assert census["add"] == m * n * (k - 1)
    assert stream.flops == 2 * m * n * k - m * n
