"""End-to-end training: loss goes down, decode matches forward, resume is
trajectory-consistent, fault injection recovers."""
import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.manager import CheckpointManager
from repro.data.pipeline import DataConfig, SyntheticDataset, make_batch
from repro.launch.mesh import make_debug_mesh
from repro.launch.train import train_loop
from repro.models.config import ModelConfig
from repro.runtime.fault_tolerance import SimulatedFailure, run_with_restarts
from repro.train import train_state as ts
from repro.train.optimizer import AdamWConfig

CFG = ModelConfig("ittest", "dense", n_layers=2, d_model=64, n_heads=4,
                  n_kv=2, d_ff=128, vocab=97, dtype="float32")
OPT = AdamWConfig(lr=5e-3, warmup_steps=5, decay_steps=200)
DATA = DataConfig(vocab=97, global_batch=8, seq_len=32)


def test_loss_decreases():
    state = ts.init_state(jax.random.PRNGKey(0), CFG, OPT)
    step = jax.jit(ts.make_train_step(CFG, OPT))
    losses = []
    for i in range(40):
        state, m = step(state, make_batch(CFG, DATA, i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5
    assert all(np.isfinite(losses))


def test_data_pipeline_deterministic_and_shardable():
    ds = SyntheticDataset(DATA)
    full = ds.local_batch(7)
    # any slice equals the corresponding rows/cols of the full batch
    np.testing.assert_array_equal(ds.tokens_slice(7, 2, 5), full[2:5])
    np.testing.assert_array_equal(ds.tokens_slice(7, 0, 8, 10, 20),
                                  full[:, 10:20])
    # steps differ
    assert not np.array_equal(full, ds.local_batch(8))


def test_resume_trajectory_consistent():
    """Stop at step 10, restore, continue: losses equal the uninterrupted
    run (same counter-based data, same state)."""
    with tempfile.TemporaryDirectory() as d:
        # uninterrupted
        _, ref_hist = train_loop(CFG, OPT, DATA, make_debug_mesh(1, 1),
                                 steps=16, ckpt_dir=os.path.join(d, "a"),
                                 save_interval=1000)
        # interrupted at 10 + resumed
        ckpt = os.path.join(d, "b")
        try:
            train_loop(CFG, OPT, DATA, make_debug_mesh(1, 1), steps=16,
                       ckpt_dir=ckpt, save_interval=5, fail_at_step=10)
        except SimulatedFailure:
            pass
        _, hist2 = train_loop(CFG, OPT, DATA, make_debug_mesh(1, 1),
                              steps=16, ckpt_dir=ckpt, save_interval=5)
        # resumed portion starts right after the last checkpoint (step 9)
        # wait: save at 5-multiples -> last saved step < 10 is 5... resume at 6
        resumed_from = 16 - len(hist2)
        np.testing.assert_allclose(hist2, ref_hist[resumed_from:], rtol=1e-4)


def test_run_with_restarts_recovers():
    with tempfile.TemporaryDirectory() as d:
        calls = {"n": 0}

        def loop(_resume):
            calls["n"] += 1
            fail_at = 7 if calls["n"] == 1 else -1
            train_loop(CFG, OPT, DATA, make_debug_mesh(1, 1), steps=12,
                       ckpt_dir=d, save_interval=3, fail_at_step=fail_at)
            return 12

        report = run_with_restarts(loop, max_restarts=2)
        assert report.completed
        assert report.restarts == 1
        mgr = CheckpointManager(d)
        assert mgr.latest_step() == 11


def test_eval_step():
    state = ts.init_state(jax.random.PRNGKey(0), CFG, OPT)
    ev = jax.jit(ts.make_eval_step(CFG))
    out = ev(state, make_batch(CFG, DATA, 0))
    assert np.isfinite(float(out["loss"]))


def test_grad_accum_equivalence():
    """accum=4 equals accum=1 on the same global batch (fp32, mean loss)."""
    cfg1 = dataclasses.replace(CFG, accum_steps=1)
    cfg4 = dataclasses.replace(CFG, accum_steps=4)
    s1 = ts.init_state(jax.random.PRNGKey(1), cfg1, OPT)
    s4 = jax.tree.map(lambda x: x, s1)
    f1 = jax.jit(ts.make_train_step(cfg1, OPT))
    f4 = jax.jit(ts.make_train_step(cfg4, OPT))
    b1 = make_batch(cfg1, DATA, 0, accum=1)
    b4 = make_batch(cfg4, DATA, 0, accum=4)
    s1n, m1 = f1(s1, b1)
    s4n, m4 = f4(s4, b4)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-5)
    diff = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                        s1n["params"], s4n["params"])
    # fp32 reassociation of the microbatch sum is amplified by AdamW's
    # m/(sqrt(v)+eps) normalization where grads are near zero; ~1e-4 of the
    # 5e-3 first-step update is pure accumulation-order noise
    assert max(jax.tree.leaves(diff)) < 2e-4
