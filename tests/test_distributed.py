"""Distributed machinery on a small fake-device mesh.

XLA's host device count locks at first jax init, so these tests run their
bodies in a subprocess with XLA_FLAGS set (the main pytest process keeps 1
device for everything else).
"""
import os
import subprocess
import sys
import textwrap

import pytest

_ENV = dict(os.environ,
            XLA_FLAGS="--xla_force_host_platform_device_count=8",
            PYTHONPATH="src")


def _run(body: str):
    code = textwrap.dedent(body)
    r = subprocess.run([sys.executable, "-c", code], env=_ENV,
                       capture_output=True, text=True, timeout=900,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_sharded_train_step_matches_single_device():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.models.config import ModelConfig
    from repro.train import train_state as ts
    from repro.train.optimizer import AdamWConfig
    from repro.data.pipeline import DataConfig, make_batch
    from repro.distributed import sharding as sh
    from repro.launch.mesh import make_debug_mesh

    cfg = ModelConfig("t","dense",n_layers=2,d_model=64,n_heads=4,n_kv=2,
                      d_ff=128,vocab=97,dtype="float32")
    opt = AdamWConfig(lr=1e-2, warmup_steps=2, decay_steps=50)
    data = DataConfig(vocab=97, global_batch=8, seq_len=32)
    state = ts.init_state(jax.random.PRNGKey(0), cfg, opt)
    batch = make_batch(cfg, data, 0)

    # single device reference
    f0 = jax.jit(ts.make_train_step(cfg, opt))
    s0, m0 = f0(state, batch)

    # 2x4 mesh, fsdp+tp
    mesh = make_debug_mesh(data=2, model=4)
    st_specs = sh.state_specs(state, mesh, fsdp=True)
    st_sh = sh.to_shardings(st_specs, mesh)
    state_sharded = jax.tree.map(jax.device_put, state, st_sh)
    shard_fn = sh.make_shard_fn(mesh)
    f1 = jax.jit(ts.make_train_step(cfg, opt, shard_fn),
                 in_shardings=(st_sh, None), out_shardings=(st_sh, None))
    with mesh:
        s1, m1 = f1(state_sharded, batch)
    assert abs(float(m0["loss"]) - float(m1["loss"])) < 1e-4, (m0, m1)
    d = jax.tree.map(lambda a,b: float(jnp.max(jnp.abs(a-b))),
                     s0["params"], jax.device_get(s1["params"]))
    assert max(jax.tree.leaves(d)) < 1e-3, max(jax.tree.leaves(d))
    print("sharded == single device OK")
    """)


def test_sharded_decode_and_cache_specs():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.models.config import ModelConfig
    from repro.models import model_zoo as zoo
    from repro.distributed import sharding as sh
    from repro.launch.mesh import make_debug_mesh

    cfg = ModelConfig("t","dense",n_layers=2,d_model=64,n_heads=4,n_kv=4,
                      d_ff=128,vocab=97,dtype="float32")
    params = zoo.init(jax.random.PRNGKey(0), cfg)
    B, S = 4, 64
    caches = zoo.init_caches(params, cfg, B, S, dtype=jnp.float32)
    tok = jnp.zeros((B,1), jnp.int32)
    l0, c0 = zoo.decode_step(params, tok, cfg, caches, jnp.int32(0))

    mesh = make_debug_mesh(data=2, model=4)
    p_sh = sh.to_shardings(sh.params_specs(params, mesh), mesh)
    c_sh = sh.to_shardings(sh.cache_specs(caches, mesh), mesh)
    params_s = jax.tree.map(jax.device_put, params, p_sh)
    caches_s = jax.tree.map(jax.device_put, caches, c_sh)
    f = jax.jit(lambda p,t,c,i: zoo.decode_step(p,t,cfg,c,i),
                in_shardings=(p_sh, None, c_sh, None),
                out_shardings=(None, c_sh))
    with mesh:
        l1, c1 = f(params_s, tok, caches_s, jnp.int32(0))
    np.testing.assert_allclose(np.asarray(l0), np.asarray(jax.device_get(l1)),
                               atol=2e-3)
    print("sharded decode OK")
    """)


def test_compressed_grad_sync_error_feedback():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.launch.mesh import make_debug_mesh
    from repro.distributed.collectives import compressed_grad_sync

    mesh = jax.make_mesh((8,), ("pod",))
    sync = jax.jit(compressed_grad_sync(mesh, "pod"))
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))}
    e = {"w": jnp.zeros((64, 64), jnp.float32)}
    out, err = sync(g, e)
    # identical grads on every shard -> mean == value, small quant error
    rel = float(jnp.max(jnp.abs(out["w"] - g["w"])) / jnp.max(jnp.abs(g["w"])))
    assert rel < 0.02, rel
    # error feedback: residual equals what quantization dropped
    assert float(jnp.max(jnp.abs(err["w"]))) > 0
    # feeding the error back recovers the lost mass over steps
    total = jnp.zeros_like(g["w"]); e2 = jax.tree.map(jnp.zeros_like, e)
    for _ in range(8):
        o, e2 = sync(g, e2)
        total = total + o["w"]
    rel2 = float(jnp.max(jnp.abs(total/8 - g["w"])) / jnp.max(jnp.abs(g["w"])))
    assert rel2 < rel, (rel2, rel)
    print("compressed psum + error feedback OK")
    """)


def test_flash_decoding_sequence_sharded():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.launch.mesh import make_debug_mesh
    from repro.distributed.collectives import sharded_decode_attention
    from repro.kernels import ref

    mesh = make_debug_mesh(data=2, model=4)
    rng = np.random.default_rng(0)
    B,Hq,Hkv,S,D = 2,8,4,256,32
    q = jnp.asarray(rng.normal(size=(B,Hq,D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B,S,Hkv,D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B,S,Hkv,D)).astype(np.float32))
    kv_len = jnp.int32(200)
    attn = jax.jit(sharded_decode_attention(mesh, ("data",)))
    with mesh:
        out = attn(q, k, v, kv_len)
    want = ref.attention(q[:,:,None], jnp.moveaxis(k[:, :200], 2, 1),
                         jnp.moveaxis(v[:, :200], 2, 1), causal=False)[:,:,0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-4)
    print("flash decoding over sharded KV OK")
    """)


def test_pipeline_parallel_forward():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.distributed.pipeline_parallel import pipeline_forward, stack_stage_params

    mesh = jax.make_mesh((4,), ("stage",))
    rng = np.random.default_rng(0)
    # 4 stages, each an affine map
    per_stage = [{"w": jnp.asarray(rng.normal(size=(16,16)).astype(np.float32))/4,
                  "b": jnp.asarray(rng.normal(size=(16,)).astype(np.float32))}
                 for _ in range(4)]
    params = stack_stage_params(per_stage)
    def stage_fn(p, x):
        return jnp.tanh(x @ p["w"] + p["b"])
    run = pipeline_forward(stage_fn, mesh)
    x = jnp.asarray(rng.normal(size=(6, 8, 16)).astype(np.float32))  # 6 micro
    with mesh:
        y = jax.jit(run)(params, x)
    # sequential reference
    ref = x
    for p in per_stage:
        ref = jnp.tanh(ref @ p["w"] + p["b"])
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-4)
    print("1F1B pipeline forward OK")
    """)


def test_elastic_reshard():
    _run("""
    import jax, jax.numpy as jnp, tempfile, numpy as np
    from repro.models.config import ModelConfig
    from repro.train import train_state as ts
    from repro.train.optimizer import AdamWConfig
    from repro.distributed import elastic, sharding as sh
    from repro.ckpt import checkpoint as ck
    from repro.launch.mesh import make_debug_mesh

    cfg = ModelConfig("t","dense",n_layers=2,d_model=64,n_heads=4,n_kv=2,
                      d_ff=128,vocab=97,dtype="float32")
    opt = AdamWConfig()
    state = ts.init_state(jax.random.PRNGKey(0), cfg, opt)
    with tempfile.TemporaryDirectory() as d:
        ck.save(d, 3, state)
        like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                            state)
        # restore onto a *different* mesh shape (elastic rescale 1x1 -> 4x2)
        mesh2 = make_debug_mesh(data=4, model=2)
        restored, step = elastic.elastic_restore(d, like, mesh2)
        assert step == 3
        eq = jax.tree.map(lambda a,b: bool(jnp.all(a==jax.device_get(b))),
                          state, restored)
        assert all(jax.tree.leaves(eq))
        # and the shardings really live on mesh2
        leaf = restored["params"]["blocks"]["attn"]["wq"]
        assert leaf.sharding.mesh.shape == mesh2.shape
    print("elastic reshard OK")
    """)
