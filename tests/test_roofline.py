"""Roofline: HLO collective parser + term arithmetic + device-count probe."""
import json
import os
import subprocess
import sys
import tempfile
import textwrap

import pytest

from repro.core import roofline as rl


def test_shape_bytes():
    assert rl._shape_bytes("f32[64,256]{1,0}") == 64 * 256 * 4
    assert rl._shape_bytes("bf16[8]") == 16
    assert rl._shape_bytes("(f32[2,2], s8[4])") == 16 + 4
    assert rl._shape_bytes("pred[]") == 1


def test_collective_parser_synthetic():
    hlo = """
HloModule m

ENTRY %main {
  %p0 = f32[128,64]{1,0} parameter(0)
  %ag = f32[512,64]{1,0} all-gather(%p0), dimensions={0}
  %ar = f32[512,64]{1,0} all-reduce(%ag), to_apply=%add
  %rs = f32[64,64]{1,0} reduce-scatter(%ar), dimensions={0}
  %cp = f32[64,64]{1,0} collective-permute(%rs), source_target_pairs={{0,1}}
  ROOT %out = f32[64,64]{1,0} add(%cp, %rs)
}
"""
    out = rl.collective_bytes(hlo)
    assert out["all-gather"] == 128 * 64 * 4          # operand p0
    assert out["all-reduce"] == 512 * 64 * 4          # operand ag
    assert out["reduce-scatter"] == 512 * 64 * 4      # operand ar
    assert out["collective-permute"] == 64 * 64 * 4   # operand rs


def test_collective_parser_async_start_done():
    hlo = """
ENTRY %main {
  %p0 = f32[100]{0} parameter(0)
  %s = (f32[100]{0}, f32[100]{0}) all-reduce-start(%p0), to_apply=%add
  %d = f32[100]{0} all-reduce-done(%s)
  ROOT %r = f32[100]{0} add(%d, %d)
}
"""
    out = rl.collective_bytes(hlo)
    assert out["all-reduce"] == 400                   # start counted once


def test_metadata_shapes_not_counted():
    hlo = """
ENTRY %main {
  %p0 = f32[16]{0} parameter(0)
  %ar = f32[16]{0} all-reduce(%p0), metadata={op_name="f32[9999,9999]"}
}
"""
    assert rl.collective_bytes(hlo)["all-reduce"] == 64


def test_roofline_terms():
    r = rl.Roofline(arch="a", shape="s", mesh="m", chips=256,
                    hlo_flops=197e12 * 0.010,       # 10 ms of compute
                    hlo_bytes=819e9 * 0.005,        # 5 ms of HBM
                    coll_bytes=50e9 * 0.002,        # 2 ms of ICI
                    coll_breakdown={}, model_flops=256 * 197e12 * 0.008,
                    bytes_per_device=1e9)
    assert r.compute_s == pytest.approx(0.010)
    assert r.memory_s == pytest.approx(0.005)
    assert r.collective_s == pytest.approx(0.002)
    assert r.dominant == "compute"
    assert r.roofline_fraction == pytest.approx(0.8)


def test_save_load_roundtrip():
    r = rl.Roofline("a", "s", "m", 4, 1e12, 1e9, 1e6, {"all-reduce": 7},
                    5e11, 2e9, extra={"kind": "train"})
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "r.json")
        rl.save_json(p, [r])
        back = rl.load_json(p)[0]
        assert back.arch == "a" and back.coll_breakdown["all-reduce"] == 7
        assert back.dominant == r.dominant


def test_cost_analysis_is_per_device():
    """The device-count semantics probe DESIGN.md section 7 relies on:
    the same per-shard program on 1 vs 4 devices reports ~the same flops
    when the work is fully data-parallel (i.e. cost_analysis is
    per-partition, not global)."""
    code = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, json
    from jax.sharding import NamedSharding, PartitionSpec as P
    def f(x):
        return jnp.sum(x @ x.swapaxes(-1, -2))
    x = jax.ShapeDtypeStruct((4, 128, 128), jnp.float32)
    c1 = jax.jit(f).lower(x).compile().cost_analysis()
    mesh = jax.make_mesh((4,), ("d",))
    c4 = jax.jit(f, in_shardings=NamedSharding(mesh, P("d"))).lower(x)\\
        .compile().cost_analysis()
    c1 = c1[0] if isinstance(c1, list) else c1
    c4 = c4[0] if isinstance(c4, list) else c4
    print(json.dumps({"f1": c1.get("flops", 0), "f4": c4.get("flops", 0)}))
    """)
    r = subprocess.run([sys.executable, "-c", code],
                       env=dict(os.environ, PYTHONPATH="src"),
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stderr
    out = json.loads(r.stdout.strip().splitlines()[-1])
    # per-device: 4-way sharded batch does ~1/4 the flops per partition
    assert out["f4"] == pytest.approx(out["f1"] / 4, rel=0.2), out
