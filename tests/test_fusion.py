"""Fused-vs-unfused differential harness for the streaming Pallas chains.

The fused kernels (``repro.kernels.fused``: GEMM+epilogue, TRSM->GEMM)
must agree with the staged reference chain across the full
shape x dtype x epilogue x policy grid; the float64 leg needs
``JAX_ENABLE_X64`` (a process-level switch) and runs in one subprocess,
pattern of ``tests/test_linalg.py``. The chain planner properties
(VMEM-budget respect, fused bytes never exceeding the unfused chain) run
across every registered machine. See ``docs/fusion.md``.
"""
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import LINALG_DTYPES as DTYPES
from conftest import dtype_tolerances
from repro import arch, linalg, obs, tune
from repro.core import codesign as cd
from repro.kernels import fused as fk
from repro.tune import dispatch as td

POLICIES = ("reference", "model", "tuned")
MACHINES = ("tpu-like", "paper-pe", "cpu-host")
# (m, n, k): aligned, ragged-every-axis, and k spanning multiple blocks
CHAIN_SHAPES = [(16, 16, 16), (48, 56, 24), (130, 64, 40)]


def _mk(rng, shape, dtype):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32)).astype(dtype)


def _close(got, want, scale=1.0, msg=""):
    rtol, atol = dtype_tolerances(np.asarray(got).dtype, scale)
    np.testing.assert_allclose(np.asarray(got).astype(np.float64),
                               np.asarray(want).astype(np.float64),
                               rtol=rtol, atol=atol, err_msg=msg)


# ------------------------- GEMM+epilogue kernel -----------------------------

@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("epilogue", fk.EPILOGUES)
def test_gemm_bias_act_grid(rng, dtype, epilogue):
    """Fused kernel == staged reference chain over shapes x bias x policy.

    The reference policy *is* the unfused chain (plain jnp then
    apply_epilogue), so comparing policies against it is the fused-vs-
    unfused differential, with the shared epilogue definition ruling out
    two-copies-of-the-same-bug.
    """
    for m, n, k in CHAIN_SHAPES:
        a, b = _mk(rng, (m, k), dtype), _mk(rng, (k, n), dtype)
        for bias in (None, _mk(rng, (n,), dtype)):
            want = fk.apply_epilogue(
                jnp.asarray(np.asarray(a, np.float64)
                            @ np.asarray(b, np.float64), jnp.float32),
                epilogue, None if bias is None else bias.astype(jnp.float32))
            for pol in POLICIES:
                with linalg.use(policy=pol):
                    got = linalg.gemm_bias_act(a, b, bias=bias,
                                               epilogue=epilogue)
                assert got.dtype == jnp.dtype(dtype)
                _close(got, want, scale=8.0,
                       msg=f"{m}x{n}x{k} {epilogue} bias={bias is not None} "
                           f"policy={pol}")


def test_gemm_bias_act_direct_kernel(rng):
    """The kernel entry point itself (no dispatch) on a ragged shape."""
    a, b = _mk(rng, (70, 33), np.float32), _mk(rng, (33, 129), np.float32)
    bias = _mk(rng, (129,), np.float32)
    got = fk.gemm_bias_act(a, b, bias=bias, epilogue="gelu")
    want = fk.apply_epilogue(a @ b, "gelu", bias)
    _close(got, want, scale=4.0)


@settings(max_examples=10, deadline=None)
@given(m=st.integers(4, 40), n=st.integers(4, 40), k=st.integers(4, 40),
       epilogue=st.sampled_from(fk.EPILOGUES), has_bias=st.booleans(),
       seed=st.integers(0, 2 ** 16))
def test_epilogue_composition_commutes(m, n, k, epilogue, has_bias, seed):
    """Property: fusing the epilogue into the GEMM commutes with applying
    it to the unfused product, within dtype tolerance."""
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    bias = (jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
            if has_bias else None)
    fused = fk.gemm_bias_act(a, b, bias=bias, epilogue=epilogue)
    staged = fk.apply_epilogue(a @ b, epilogue, bias)
    _close(fused, staged, scale=4.0)


# --------------------------- TRSM->GEMM kernel ------------------------------

@pytest.mark.parametrize("form", ("lu", "syrk"))
@pytest.mark.parametrize("dtype", DTYPES)
def test_trsm_gemm_vs_staged_oracle(rng, form, dtype):
    """Fused panel chain == float64 staged oracle, both forms.

    Diagonally dominant L keeps the solve well-conditioned so the dtype
    tolerances (scaled for the blocked accumulation depth) apply.
    """
    nb, n, m = 24, 72, 40
    l_np = np.tril(rng.normal(size=(nb, nb))).astype(np.float32) \
        + 4.0 * np.eye(nb, dtype=np.float32)
    ap_np = rng.normal(size=(nb, n)).astype(np.float32)
    c_rows = nb if form == "syrk" else m
    c_np = rng.normal(size=(c_rows if form == "lu" else n, n)).astype(np.float32)
    l11 = jnp.asarray(l_np).astype(dtype)
    ap = jnp.asarray(ap_np).astype(dtype)
    unit = form == "lu"
    lf = np.asarray(l_np, np.float64)
    if unit:
        lf = np.tril(lf, -1) + np.eye(nb)
    import scipy.linalg
    x64 = scipy.linalg.solve_triangular(lf, np.asarray(ap_np, np.float64),
                                        lower=True, unit_diagonal=False)
    if form == "lu":
        bl_np = rng.normal(size=(m, nb)).astype(np.float32)
        c_np = rng.normal(size=(m, n)).astype(np.float32)
        bl = jnp.asarray(bl_np).astype(dtype)
        c = jnp.asarray(c_np).astype(dtype)
        x, c_out = fk.trsm_gemm(l11, ap, bl, c, form="lu", unit_diag=True)
        # recompute the oracle with the unit diagonal the kernel uses
        x64 = scipy.linalg.solve_triangular(
            np.tril(np.asarray(l_np, np.float64), -1) + np.eye(nb),
            np.asarray(ap_np, np.float64), lower=True)
        want_c = np.asarray(c_np, np.float64) \
            - np.asarray(bl_np, np.float64) @ x64
    else:
        c_np = rng.normal(size=(n, n)).astype(np.float32)
        c = jnp.asarray(c_np).astype(dtype)
        x, c_out = fk.trsm_gemm(l11, ap, None, c, form="syrk")
        want_c = np.asarray(c_np, np.float64) - x64.T @ x64
    # scale tolerances by the solve magnitude (relative, not absolute)
    xmag = max(float(np.max(np.abs(x64))), 1.0)
    _close(x, x64, scale=4.0 * xmag, msg=f"X {form}")
    _close(c_out, want_c, scale=8.0 * xmag, msg=f"C {form}")


# ----------------------- blocked drivers: fuse on/off -----------------------

@pytest.mark.parametrize("policy", POLICIES)
def test_cholesky_fuse_grid(rng, policy):
    n = 64
    g = rng.normal(size=(n, n)).astype(np.float32)
    s = jnp.asarray(g @ g.T + n * np.eye(n, dtype=np.float32))
    want = np.linalg.cholesky(np.asarray(s, np.float64))
    outs = {}
    for fuse in (False, True, None):
        with linalg.use(policy=policy):
            outs[fuse] = linalg.cholesky(s, block=16, fuse=fuse)
        _close(outs[fuse], want, scale=16.0,
               msg=f"cholesky policy={policy} fuse={fuse}")
    _close(outs[True], outs[False], scale=16.0,
           msg=f"cholesky fused-vs-staged policy={policy}")
    if policy == "reference":
        # reference never fuses: fuse=True must be the staged path, bitwise
        assert np.array_equal(np.asarray(outs[True]),
                              np.asarray(outs[False]))


@pytest.mark.parametrize("policy", POLICIES)
def test_lu_fuse_grid(rng, policy):
    for m, n in ((64, 64), (48, 72), (72, 48)):
        a_np = rng.normal(size=(m, n)).astype(np.float32) \
            + min(m, n) * np.eye(m, n, dtype=np.float32)
        a = jnp.asarray(a_np)
        res = {}
        for fuse in (False, True):
            with linalg.use(policy=policy):
                res[fuse] = linalg.lu(a, block=16, fuse=fuse)
        assert np.array_equal(np.asarray(res[True][1]),
                              np.asarray(res[False][1])), \
            f"pivots drifted {m}x{n} policy={policy}"
        _close(res[True][0], np.asarray(res[False][0], np.float64),
               scale=16.0, msg=f"lu fused-vs-staged {m}x{n} policy={policy}")
        # reconstruction oracle: P A = L U in float64
        packed, piv = res[True]
        k = min(m, n)
        pk = np.asarray(packed, np.float64)
        l = np.tril(pk[:, :k], -1) + np.eye(m, k)
        u = np.triu(pk[:k, :])
        perm = np.arange(m)
        for i, p in enumerate(np.asarray(piv)):
            perm[[i, p]] = perm[[p, i]]
        _close(jnp.asarray((l @ u).astype(np.float32)),
               np.asarray(a_np, np.float64)[perm], scale=64.0,
               msg=f"lu reconstruction {m}x{n} policy={policy}")


def test_cold_start_tuned_is_model_bitwise(rng, tmp_path):
    """The tuning contract extends to the fused ops: an empty registry
    resolves tuned to exactly the model plan, so results are bitwise."""
    reg = tune.Registry(str(tmp_path / "empty.json"))
    a, b = _mk(rng, (48, 24), np.float32), _mk(rng, (24, 56), np.float32)
    bias = _mk(rng, (56,), np.float32)
    g = rng.normal(size=(64, 64)).astype(np.float32)
    s = jnp.asarray(g @ g.T + 64 * np.eye(64, dtype=np.float32))
    with linalg.use(policy="tuned", registry=reg):
        got_t = linalg.gemm_bias_act(a, b, bias=bias, epilogue="relu")
        chol_t = linalg.cholesky(s, block=16, fuse=True)
    with linalg.use(policy="model"):
        got_m = linalg.gemm_bias_act(a, b, bias=bias, epilogue="relu")
        chol_m = linalg.cholesky(s, block=16, fuse=True)
    assert np.array_equal(np.asarray(got_t), np.asarray(got_m))
    assert np.array_equal(np.asarray(chol_t), np.asarray(chol_m))


# ------------------------- chain planner properties -------------------------

@pytest.mark.parametrize("machine", MACHINES)
def test_planner_outputs_respect_vmem_budget(machine):
    """Every planner's working set fits (or truthfully reports not
    fitting) the ambient machine's VMEM budget."""
    mach = arch.get(machine)
    budget = mach.memory.vmem_bytes
    for m, n, k in [(64, 64, 64), (512, 512, 128), (2048, 2048, 2048),
                    (8, 8192, 64)]:
        for db in (2, 4, 8):
            p = cd.plan_gemm(m, n, k, dtype_bytes=db, machine=mach)
            assert p.vmem_bytes <= budget, (machine, m, n, k, db)
            for kind in cd.FUSED_CHAIN_KINDS:
                ch = cd.plan_fused_chain(kind, m, n, k, dtype_bytes=db,
                                         epilogue="gelu", machine=mach)
                # the *verdict* must match the budget arithmetic, and the
                # constituent GEMM plan must itself be feasible
                assert ch.fits_vmem == (ch.vmem_bytes <= budget), \
                    (machine, kind, m, n, k, db)
                assert ch.gemm.vmem_bytes <= budget
    att = cd.plan_attention(2048, 2048, 128, machine=mach)
    assert att.vmem_bytes <= budget
    ssd = cd.plan_ssd(4096, 8, 64, 64, machine=mach)
    assert ssd.vmem_bytes <= budget


@settings(max_examples=25, deadline=None)
@given(m=st.integers(8, 4096), n=st.integers(8, 4096), k=st.integers(8, 512),
       db=st.sampled_from([2, 4, 8]),
       kind=st.sampled_from(cd.FUSED_CHAIN_KINDS),
       epilogue=st.sampled_from(fk.EPILOGUES),
       form=st.sampled_from(["lu", "syrk"]),
       machine=st.sampled_from(MACHINES))
def test_fused_never_models_more_hbm_bytes(m, n, k, db, kind, epilogue,
                                           form, machine):
    """Property: streaming can only *remove* HBM traffic - the fused plan
    never prices more bytes than the unfused chain, on any machine."""
    ch = cd.plan_fused_chain(kind, m, n, k, dtype_bytes=db,
                             epilogue=epilogue, form=form,
                             machine=arch.get(machine))
    assert ch.fused_hbm_bytes <= ch.unfused_hbm_bytes
    assert ch.hbm_bytes_saved == ch.unfused_hbm_bytes - ch.fused_hbm_bytes
    if ch.fused_wins:
        assert ch.fits_vmem


def test_chain_model_prices_win_and_loss():
    """The acceptance shapes: the default machine fuses a 256-square
    trailing update; cpu-host's 2 MiB VMEM rejects the 2048 chain."""
    win = cd.plan_fused_chain("trsm+gemm", 256, 256, 32, dtype_bytes=4,
                              form="syrk")
    assert win.fused_wins and win.hbm_bytes_saved > 0
    lose = cd.plan_fused_chain("trsm+gemm", 2048, 2048, 64, dtype_bytes=4,
                               form="syrk", machine=arch.get("cpu-host"))
    assert not lose.fits_vmem and not lose.fused_wins


# --------------------------- observability + tuner --------------------------

def test_fused_span_records_saved_bytes(rng):
    g = rng.normal(size=(96, 96)).astype(np.float32)
    s = jnp.asarray(g @ g.T + 96 * np.eye(96, dtype=np.float32))
    with obs.trace("fusion-test") as tr:
        with linalg.use(policy="model"):
            linalg.cholesky(s, block=32, fuse=True)
    spans = tr.spans(cat="fused")
    assert spans, "fused cholesky emitted no fused spans"
    for sp in spans:
        assert sp.attrs["hbm_bytes_saved"] >= 0
        assert sp.attrs["fused_hbm_bytes"] + sp.attrs["hbm_bytes_saved"] \
            == sp.attrs["unfused_hbm_bytes"]
    assert any(sp.attrs["hbm_bytes_saved"] > 0 for sp in spans)
    # the staged run must not emit fused spans
    with obs.trace("staged") as tr2:
        with linalg.use(policy="model"):
            linalg.cholesky(s, block=32, fuse=False)
    assert not tr2.spans(cat="fused")


def test_resolve_describe_carries_fusion_fields():
    res = tune.resolve("gemm+epilogue", (256, 256, 64), jnp.float32,
                       policy="model", epilogue="relu")
    d = res.describe()
    assert d["fused"] is True and d["hbm_bytes_saved"] > 0
    assert set(td.FUSED_OPS) <= set(td.OPS)
    # reference policy never fuses
    ref = tune.resolve("trsm+gemm", (64, 64, 16), jnp.float32,
                       policy="reference", form="syrk")
    assert not ref.fused and not ref.use_pallas


def test_tune_fused_gemm_registry_roundtrip(tmp_path):
    reg = tune.Registry(str(tmp_path / "reg.json"))
    sw = tune.tune_fused_gemm(32, 32, 32, epilogue="relu", registry=reg,
                              reps=1)
    assert {r["variant"] for r in sw.measured} == {"staged", "fused"}
    path = reg.save()
    reloaded = tune.Registry(path)
    res = tune.resolve("gemm+epilogue", (32, 32, 32), jnp.float32,
                       policy="tuned", registry=reloaded, epilogue="relu")
    assert res.source == "registry"
    assert res.fused == (bool(sw.best.params["fused"]) and
                         res.chain.fits_vmem)


# ---------------------------- float64 leg (x64) -----------------------------

_ENV = dict(os.environ, JAX_ENABLE_X64="1", PYTHONPATH="src")

_PRELUDE = """
import sys
sys.path.insert(0, "tests")
from conftest import dtype_tolerances
import numpy as np
import jax, jax.numpy as jnp
from repro import linalg
from repro.kernels import fused as fk

def close(got, want, scale=1.0, msg=""):
    rtol, atol = dtype_tolerances(np.asarray(got).dtype, scale)
    np.testing.assert_allclose(np.asarray(got).astype(np.float64),
                               np.asarray(want).astype(np.float64),
                               rtol=rtol, atol=atol, err_msg=msg)
"""


def test_fusion_grid_float64():
    """The float64 differential leg: fused chains at 1e-12-level
    tolerances, all policies, in one x64 subprocess."""
    code = _PRELUDE + textwrap.dedent("""
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(48, 24)))
    b = jnp.asarray(rng.normal(size=(24, 56)))
    bias = jnp.asarray(rng.normal(size=(56,)))
    assert a.dtype == jnp.float64
    g = rng.normal(size=(64, 64))
    s = jnp.asarray(g @ g.T + 64 * np.eye(64))
    want_l = np.linalg.cholesky(np.asarray(s))
    for pol in ("reference", "model", "tuned"):
        for epi in fk.EPILOGUES:
            with linalg.use(policy=pol):
                got = linalg.gemm_bias_act(a, b, bias=bias, epilogue=epi)
            assert got.dtype == jnp.float64
            want = fk.apply_epilogue(a @ b, epi, bias)
            close(got, want, scale=8.0, msg=f"{epi} policy={pol}")
        outs = {}
        for fuse in (False, True):
            with linalg.use(policy=pol):
                outs[fuse] = linalg.cholesky(s, block=16, fuse=fuse)
            close(outs[fuse], want_l, scale=64.0,
                  msg=f"cholesky f64 policy={pol} fuse={fuse}")
        close(outs[True], outs[False], scale=64.0,
              msg=f"cholesky f64 fused-vs-staged policy={pol}")
    print("fusion float64 grid OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], env=_ENV,
                       capture_output=True, text=True, timeout=900,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "fusion float64 grid OK" in r.stdout
