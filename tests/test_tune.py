"""Differential + persistence tests for the repro.tune subsystem.

Per-policy differential tests (ROADMAP convention): every dispatcher op
must agree across ``reference`` / ``model`` / ``tuned`` within the shared
``dtype_tolerances`` (Pallas in interpret mode on CPU). Registry coverage:
round-trip (write -> reload -> same config), corrupt/missing-file
fallback, LRU eviction, and the deprecated ``use_kernel`` alias mapping.
"""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest
import scipy.linalg

from repro import blas, lapack
from repro.tune import dispatch, policy, search
from repro.tune.registry import KernelConfig, Registry, make_key, shape_bucket

POLICIES = ["reference", "model", "tuned"]


def _mk(rng, shape, dtype=np.float32):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32)).astype(dtype)


def _f64(x):
    return np.asarray(x.astype(jnp.float32)).astype(np.float64)


@pytest.fixture
def tmp_registry(tmp_path):
    return Registry(path=str(tmp_path / "registry.json"))


# --------------------- per-policy differential tests ------------------------

@pytest.mark.parametrize("pol", POLICIES)
@pytest.mark.parametrize("m,n,k", [(8, 8, 8), (24, 36, 12), (17, 5, 29)])
def test_dgemm_policies_vs_numpy(rng, assert_close, m, n, k, pol):
    a, b = _mk(rng, (m, k)), _mk(rng, (k, n))
    got = blas.dgemm(a, b, policy=pol, interpret=True)
    assert_close(got, _f64(a) @ _f64(b), scale=max(1.0, k / 16))


@pytest.mark.parametrize("pol", POLICIES)
@pytest.mark.parametrize("ta,tb", [(False, False), (True, False),
                                   (False, True), (True, True)])
def test_dgemm_transpose_flags(rng, assert_close, ta, tb, pol):
    a = _mk(rng, (12, 24) if ta else (24, 12))
    b = _mk(rng, (18, 12) if tb else (12, 18))
    got = blas.dgemm(a, b, transa=ta, transb=tb, policy=pol, interpret=True)
    ref = (_f64(a).T if ta else _f64(a)) @ (_f64(b).T if tb else _f64(b))
    assert_close(got, ref)


@pytest.mark.parametrize("pol", POLICIES)
@pytest.mark.parametrize("trans", [False, True])
def test_dsyrk_policies_reach_gemm_path(rng, assert_close, trans, pol):
    a = _mk(rng, (12, 20))
    op_a = _f64(a).T if trans else _f64(a)
    got = blas.dsyrk(a, trans=trans, policy=pol, interpret=True)
    assert_close(got, op_a @ op_a.T)


@pytest.mark.parametrize("pol", POLICIES)
@pytest.mark.parametrize("trans", [False, True])
def test_dgemv_policies_vs_numpy(rng, assert_close, trans, pol):
    a, x = _mk(rng, (17, 9)), _mk(rng, 17 if trans else 9)
    got = blas.dgemv(a, x, trans=trans, policy=pol, interpret=True)
    assert_close(got, (_f64(a).T if trans else _f64(a)) @ _f64(x))


@pytest.mark.parametrize("pol", POLICIES)
@pytest.mark.parametrize("lower", [True, False])
def test_dtrsm_policies_vs_scipy(rng, assert_close, lower, pol):
    n = 40
    a = _mk(rng, (n, n))
    t = (jnp.tril(a) if lower else jnp.triu(a)) + 4 * jnp.eye(n)
    b = _mk(rng, (n, 3))
    got = blas.dtrsm(t, b, lower=lower, policy=pol, interpret=True)
    ref = scipy.linalg.solve_triangular(_f64(t), _f64(b), lower=lower)
    assert_close(got, ref, scale=4.0)


@pytest.mark.parametrize("pol", POLICIES)
def test_potrf_policies_agree(rng, assert_close, pol):
    a = _mk(rng, (48, 48))
    s = a @ a.T + 48 * jnp.eye(48)
    got = lapack.potrf(s, block=16, policy=pol, interpret=True)
    want = np.linalg.cholesky(_f64(s))
    assert_close(got, want, scale=8.0)


@pytest.mark.parametrize("pol", POLICIES)
def test_gesv_policies_agree(rng, assert_close, pol):
    a = _mk(rng, (32, 32)) + 8 * jnp.eye(32)
    b = _mk(rng, (32, 2))
    got = lapack.gesv(a, b, block=8, policy=pol, interpret=True)
    assert_close(got, np.linalg.solve(_f64(a), _f64(b)), scale=8.0)


def test_cold_start_tuned_identical_to_use_kernel_path(rng, tmp_path):
    """Acceptance: with no registry file, the tuned policy must produce
    bitwise the numerics of the PR-1 use_kernel=True path."""
    empty = Registry(path=str(tmp_path / "never-written.json"))
    a, b = _mk(rng, (24, 12)), _mk(rng, (12, 18))
    old = blas.dgemm(a, b, use_kernel=True, interpret=True)
    new = blas.dgemm(a, b, policy="tuned", registry=empty, interpret=True)
    assert np.array_equal(np.asarray(old), np.asarray(new))
    s = a @ a.T + 24 * jnp.eye(24)
    old_l = lapack.potrf(s, block=8, use_kernel=True, interpret=True)
    import repro.tune.registry as reg_mod
    reg_mod.set_default_registry(empty)
    try:
        new_l = lapack.potrf(s, block=8, policy="tuned", interpret=True)
    finally:
        reg_mod.set_default_registry(None)
    assert np.array_equal(np.asarray(old_l), np.asarray(new_l))


# ----------------------------- policy resolution ----------------------------

def test_use_kernel_alias_mapping():
    assert policy.resolve_policy("tuned", use_kernel=False) == "tuned"
    assert policy.resolve_policy(None, use_kernel=True) == "model"
    assert policy.resolve_policy(None, use_kernel=False) == "reference"
    assert policy.resolve_policy(None, None) == "reference"
    with pytest.raises(ValueError, match="unknown policy"):
        policy.resolve_policy("fastest")


def test_default_policy_env(monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_POLICY", "model")
    assert policy.default_policy() == "model"
    monkeypatch.setenv("REPRO_TUNE_POLICY", "warp-speed")
    with pytest.raises(ValueError):
        policy.default_policy()


def test_resolve_sources(tmp_registry):
    r = dispatch.resolve("gemm", (32, 32, 32), jnp.float32,
                         policy="reference", registry=tmp_registry)
    assert (r.source, r.use_pallas) == ("reference", False)
    r = dispatch.resolve("gemm", (32, 32, 32), jnp.float32, policy="model",
                         registry=tmp_registry)
    assert r.source == "model" and r.gemm_plan is not None
    r = dispatch.resolve("gemm", (32, 32, 32), jnp.float32, policy="tuned",
                         registry=tmp_registry)
    assert r.source == "fallback-model"       # cold start
    tmp_registry.record("gemm", (32, 32, 32), jnp.float32, "cpu",
                        {"bm": 256, "bn": 128, "bk": 128})
    r = dispatch.resolve("gemm", (32, 32, 32), jnp.float32, policy="tuned",
                         registry=tmp_registry, backend="cpu")
    assert r.source == "registry" and r.gemm_plan.bm == 256
    assert r.describe()["config"] == {"bm": 256, "bn": 128, "bk": 128}
    with pytest.raises(ValueError, match="unknown op"):
        dispatch.resolve("axpy", (8,), jnp.float32)


def test_gemv_tuned_shares_gemm_registry_entries(rng, assert_close,
                                                 tmp_registry):
    """gemv executes as an (m, 1, n) GEMM, so its tuned lookups must hit
    gemm entries recorded under that execution shape."""
    tmp_registry.record("gemm", (24, 1, 12), jnp.float32, "cpu",
                        {"bm": 256, "bn": 128, "bk": 128})
    r = dispatch.resolve("gemv", (24, 12), jnp.float32, policy="tuned",
                         registry=tmp_registry, backend="cpu")
    assert r.source == "registry" and r.gemm_plan.bm == 256
    a, x = _mk(rng, (24, 12)), _mk(rng, 12)
    got = blas.dgemv(a, x, policy="tuned", registry=tmp_registry,
                     interpret=True)
    assert_close(got, _f64(a) @ _f64(x))


def test_registry_lru_order_survives_save_load(tmp_path):
    """Recency, not key order, must round-trip through the file."""
    reg = Registry(path=str(tmp_path / "r.json"))
    reg.record("gemm", (8, 8, 8), jnp.float32, "cpu", {"bm": 1, "bn": 1, "bk": 1})
    reg.record("gemm", (16, 16, 16), jnp.float32, "cpu", {"bm": 2, "bn": 2, "bk": 2})
    # touch the alphabetically-later key so it is most recently used
    reg.lookup("gemm", (8, 8, 8), jnp.float32, "cpu")
    path = reg.save()
    reloaded = Registry(path=path, capacity=2)
    reloaded.record("gemm", (32, 32, 32), jnp.float32, "cpu",
                    {"bm": 3, "bn": 3, "bk": 3})
    # (16,16,16) was LRU at save time -> it is the one evicted
    assert reloaded.lookup("gemm", (16, 16, 16), jnp.float32, "cpu") is None
    assert reloaded.lookup("gemm", (8, 8, 8), jnp.float32, "cpu") is not None


def test_trsm_reference_keeps_historical_block():
    r = dispatch.resolve("trsm", (256, 8), jnp.float32, policy="reference")
    assert r.block == 64


# ------------------------------ registry ------------------------------------

def test_registry_round_trip(tmp_registry):
    cfg = tmp_registry.record("gemm", (100, 60, 30), jnp.float32, "cpu",
                              {"bm": 128, "bn": 256, "bk": 128},
                              measured_s=1e-3)
    path = tmp_registry.save()
    reloaded = Registry(path=path)
    got = reloaded.lookup("gemm", (100, 60, 30), jnp.float32, "cpu")
    assert got == cfg
    # bucket neighbors share the entry; different buckets miss
    assert reloaded.lookup("gemm", (65, 36, 20), jnp.float32, "cpu") == cfg
    assert reloaded.lookup("gemm", (300, 60, 30), jnp.float32, "cpu") is None
    assert reloaded.lookup("gemm", (100, 60, 30), jnp.bfloat16, "cpu") is None


def test_registry_missing_file_is_cold_start(tmp_path):
    reg = Registry(path=str(tmp_path / "nope" / "registry.json"))
    assert reg.lookup("gemm", (8, 8, 8), jnp.float32, "cpu") is None
    assert "cold start" in reg.load_error


@pytest.mark.parametrize("blob", ["{not json", '{"version": 99, "entries": {}}',
                                  '[1, 2, 3]',
                                  '{"version": 1, "entries": {"k": {"op": "gemm"}}}'])
def test_registry_corrupt_file_falls_back(tmp_path, blob):
    p = tmp_path / "registry.json"
    p.write_text(blob)
    reg = Registry(path=str(p))
    assert reg.lookup("gemm", (8, 8, 8), jnp.float32, "cpu") is None
    assert reg.load_error is not None
    # and dispatch still resolves (fallback to the model plan)
    r = dispatch.resolve("gemm", (8, 8, 8), jnp.float32, policy="tuned",
                         registry=reg)
    assert r.source == "fallback-model" and r.gemm_plan is not None


def test_registry_lru_eviction(tmp_path):
    reg = Registry(path=str(tmp_path / "r.json"), capacity=2)
    reg.record("gemm", (8, 8, 8), jnp.float32, "cpu", {"bm": 1, "bn": 1, "bk": 1})
    reg.record("gemm", (16, 16, 16), jnp.float32, "cpu", {"bm": 2, "bn": 2, "bk": 2})
    # touch the first so the second becomes least recently used
    assert reg.lookup("gemm", (8, 8, 8), jnp.float32, "cpu") is not None
    reg.record("gemm", (32, 32, 32), jnp.float32, "cpu", {"bm": 3, "bn": 3, "bk": 3})
    assert len(reg) == 2
    assert reg.lookup("gemm", (16, 16, 16), jnp.float32, "cpu") is None
    assert reg.lookup("gemm", (8, 8, 8), jnp.float32, "cpu") is not None


def test_shape_bucket_and_key():
    assert shape_bucket((100, 60, 30)) == (128, 64, 32)
    assert shape_bucket((1, 128)) == (1, 128)
    key = make_key("gemm", (100, 60, 30), jnp.float32, "cpu")
    assert key == "gemm|128x64x32|float32|cpu"


def test_registry_file_format_is_documented_schema(tmp_registry):
    tmp_registry.record("trsm", (64, 8), jnp.float32, "cpu", {"block": 32})
    path = tmp_registry.save()
    blob = json.load(open(path))
    assert blob["version"] == 1
    entry = blob["entries"]["trsm|64x8|float32|cpu"]
    assert entry["op"] == "trsm" and entry["params"] == {"block": 32}
    assert KernelConfig.from_json(entry).params["block"] == 32


# ------------------------------- search -------------------------------------

def test_gemm_candidates_seeded_by_model():
    from repro.core.codesign import plan_gemm
    cands = search.gemm_candidates(256, 256, 256, dtype_bytes=4,
                                   max_candidates=4)
    assert 1 <= len(cands) <= 4
    seed = plan_gemm(256, 256, 256, dtype_bytes=4)
    assert any((c.bm, c.bn, c.bk) == (seed.bm, seed.bn, seed.bk)
               for c in cands)
    for c in cands:
        assert search.model_score(c, 256, 256, 256, 4) > 0


def test_tune_gemm_writes_registry_and_dispatch_uses_it(rng, assert_close,
                                                        tmp_registry):
    res = search.tune_gemm(16, 16, 16, registry=tmp_registry, top_k=2, reps=1)
    assert res.best.op == "gemm" and res.best.measured_s > 0
    assert len(res.measured) >= 1
    import jax
    hit = tmp_registry.lookup("gemm", (16, 16, 16), jnp.float32,
                              jax.default_backend())
    assert hit == res.best
    r = dispatch.resolve("gemm", (16, 16, 16), jnp.float32, policy="tuned",
                         registry=tmp_registry)
    assert r.source == "registry"
    # numerics through the tuned config still match the oracle
    a, b = _mk(rng, (16, 16)), _mk(rng, (16, 16))
    got = blas.dgemm(a, b, policy="tuned", registry=tmp_registry,
                     interpret=True)
    assert_close(got, _f64(a) @ _f64(b))


def test_tune_trsm_writes_registry(tmp_registry):
    res = search.tune_trsm(32, 4, registry=tmp_registry, reps=1,
                           blocks=(16, 32))
    assert res.best.op == "trsm" and "block" in res.best.params
    import jax
    hit = tmp_registry.lookup("trsm", (32, 4), jnp.float32,
                              jax.default_backend())
    assert hit == res.best


def test_seed_registry_from_model_per_dtype(tmp_registry):
    """Model-seeded entries give non-swept dtypes real registry hits."""
    n = search.seed_registry_from_model(
        tmp_registry, gemm_shapes=[(64, 64, 64)], trsm_shapes=[(64, 8)],
        dtypes=(jnp.float32, jnp.float64, jnp.bfloat16), backend="cpu")
    assert n == 6 and len(tmp_registry) == 6
    for dt in (jnp.float32, jnp.float64, jnp.bfloat16):
        hit = tmp_registry.lookup("gemm", (64, 64, 64), dt, "cpu")
        assert hit is not None and hit.source == "model"
        r = dispatch.resolve("gemm", (64, 64, 64), dt, policy="tuned",
                             registry=tmp_registry, backend="cpu")
        assert r.source == "registry"
        rt = dispatch.resolve("trsm", (64, 8), dt, policy="tuned",
                              registry=tmp_registry, backend="cpu")
        assert rt.source == "registry" and rt.block >= 1
    # a later measured sweep overwrites the seeded entry in place
    import jax
    if jax.default_backend() == "cpu":
        res = search.tune_gemm(64, 64, 64, registry=tmp_registry, top_k=1,
                               reps=1)
        hit = tmp_registry.lookup("gemm", (64, 64, 64), jnp.float32, "cpu")
        assert hit.source == "sweep" and hit == res.best


def test_planners_accept_dtype_directly():
    from repro.core import codesign
    p32 = codesign.plan_gemm(256, 256, 256, dtype=jnp.float32)
    pb = codesign.plan_gemm(256, 256, 256, dtype_bytes=4)
    assert (p32.bm, p32.bn, p32.bk) == (pb.bm, pb.bn, pb.bk)
    p64 = codesign.plan_gemm(2048, 2048, 2048, dtype=jnp.float64)
    assert p64.vmem_bytes <= codesign.VMEM_BYTES
    t = codesign.plan_trsm(128, 8, dtype=jnp.float64)
    assert t.block >= 1
    f = codesign.plan_factorization(256, kind="potrf", dtype=jnp.float64)
    assert f.block >= 1
