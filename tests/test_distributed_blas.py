"""Differential tests for the sharded BLAS/LAPACK layer.

ROADMAP convention: every distributed routine is oracle-tested against its
single-device counterpart under the shared ``dtype_tolerances``, over mesh
shapes {(1,1), (2,2), (4,2)} x policy {reference, model, tuned}. Mesh
bodies run in a subprocess with ``--xla_force_host_platform_device_count=8``
(the main pytest process must keep 1 device - see conftest); the
registry/persistence tests are pure CPU and run in-process.
"""
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import pytest

from repro.tune import dispatch
from repro.tune.registry import Registry, make_key

_ENV = dict(os.environ,
            XLA_FLAGS="--xla_force_host_platform_device_count=8",
            PYTHONPATH="src")

_PRELUDE = """
import sys, os
sys.path.insert(0, "tests")
from conftest import dtype_tolerances
import jax, jax.numpy as jnp, numpy as np
from repro.blas import distributed as dblas, level3
MESHES = [(1, 1), (2, 2), (4, 2)]
POLICIES = ["reference", "model", "tuned"]

def close(got, want, scale=1.0, msg=""):
    rtol, atol = dtype_tolerances(np.asarray(got).dtype, scale)
    np.testing.assert_allclose(np.asarray(got).astype(np.float64),
                               np.asarray(want).astype(np.float64),
                               rtol=rtol, atol=atol, err_msg=msg)
"""


def _run(body: str):
    code = _PRELUDE + textwrap.dedent(body)
    r = subprocess.run([sys.executable, "-c", code], env=_ENV,
                       capture_output=True, text=True, timeout=900,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_pdgemm_matches_dgemm_over_meshes_and_policies():
    _run("""
    rng = np.random.default_rng(0)
    # divisible and ragged (padding-path) shapes
    for (m, n, k) in [(32, 32, 32), (24, 20, 36)]:
        a = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
        b = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
        want = np.asarray(level3.dgemm(a, b, policy="reference"))
        for px, py in MESHES:
            mesh = dblas.make_blas_mesh(px, py)
            for pol in POLICIES:
                got = dblas.pdgemm(a, b, mesh, policy=pol)
                assert got.shape == (m, n)
                close(got, want, scale=4.0,
                      msg=f"mesh=({px},{py}) policy={pol} mnk={m},{n},{k}")
    print("pdgemm differential OK")
    """)


def test_pdgemm_epilogue_and_dispatch_route():
    _run("""
    from repro.tune import dispatch as td
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.normal(size=(16, 24)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(24, 16)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(16, 16)).astype(np.float32))
    mesh = dblas.make_blas_mesh(2, 2)
    want = np.asarray(level3.dgemm(a, b, c=c, alpha=0.5, beta=-2.0,
                                   policy="reference"))
    got = dblas.pdgemm(a, b, mesh, c=c, alpha=0.5, beta=-2.0,
                       policy="reference")
    close(got, want, scale=4.0)
    # the unified dispatcher routes op="pdgemm" here too
    got2 = td.dispatch("pdgemm", a, b, mesh=mesh, policy="reference")
    close(got2, np.asarray(a @ b), scale=4.0)
    print("pdgemm epilogue OK")
    """)


def test_pdtrsm_matches_dtrsm():
    _run("""
    rng = np.random.default_rng(2)
    n, nrhs = 48, 10                       # nrhs ragged vs every mesh
    t = np.tril(rng.normal(size=(n, n))).astype(np.float32) \\
        + 4.0 * np.eye(n, dtype=np.float32)
    t = jnp.asarray(t)
    b = jnp.asarray(rng.normal(size=(n, nrhs)).astype(np.float32))
    for lower in (True, False):
        tt = t if lower else t.T
        want = np.asarray(level3.dtrsm(tt, b, lower=lower,
                                       policy="reference"))
        for px, py in MESHES:
            mesh = dblas.make_blas_mesh(px, py)
            for pol in POLICIES:
                got = dblas.pdtrsm(tt, b, mesh, lower=lower, policy=pol)
                close(got, want, scale=8.0,
                      msg=f"mesh=({px},{py}) lower={lower} policy={pol}")
    # right-side solve and 1-D rhs
    mesh = dblas.make_blas_mesh(4, 2)
    want = np.asarray(level3.dtrsm(t, b.T, lower=True, left=False,
                                   policy="reference"))
    close(dblas.pdtrsm(t, b.T, mesh, lower=True, left=False,
                       policy="reference"), want, scale=8.0)
    v = b[:, 0]
    close(dblas.pdtrsm(t, v, mesh, policy="reference"),
          np.asarray(level3.dtrsm(t, v[:, None], policy="reference"))[:, 0],
          scale=8.0)
    print("pdtrsm differential OK")
    """)


def test_mesh_batched_factorizations_match_single_device():
    _run("""
    from repro.lapack import batched, distributed as dlap
    rng = np.random.default_rng(3)
    B, n = 6, 24                           # B=6 ragged vs 4 and 8 devices
    g = rng.normal(size=(B, n, n)).astype(np.float32)
    spd = g @ np.swapaxes(g, 1, 2) + n * np.eye(n, dtype=np.float32)
    spd, g = jnp.asarray(spd), jnp.asarray(g)
    rhs = jnp.asarray(rng.normal(size=(B, n)).astype(np.float32))
    for px, py in MESHES:
        mesh = dblas.make_blas_mesh(px, py)
        for pol in POLICIES:
            r0 = batched.batched_potrf(spd, policy=pol)
            r1 = dlap.batched_potrf(spd, mesh, policy=pol)
            assert (r1.kind, r1.block) == (r0.kind, r0.block)
            close(r1.factors, np.asarray(r0.factors), scale=4.0,
                  msg=f"potrf mesh=({px},{py}) policy={pol}")
            r0g = batched.batched_getrf(g, policy=pol)
            r1g = dlap.batched_getrf(g, mesh, policy=pol)
            close(r1g.factors, np.asarray(r0g.factors), scale=4.0,
                  msg=f"getrf mesh=({px},{py}) policy={pol}")
            assert np.array_equal(np.asarray(r0g.pivots),
                                  np.asarray(r1g.pivots))
            x0 = batched.batched_solve(r0g, rhs, policy=pol)
            x1 = dlap.batched_solve(r1g, rhs, mesh, policy=pol)
            close(x1, np.asarray(x0), scale=16.0,
                  msg=f"solve mesh=({px},{py}) policy={pol}")
    # geqrf + SPD solve round-trip on the largest mesh, reference policy
    mesh = dblas.make_blas_mesh(4, 2)
    rq0 = batched.batched_geqrf(g, policy="reference")
    rq1 = dlap.batched_geqrf(g, mesh, policy="reference")
    close(rq1.factors, np.asarray(rq0.factors), scale=8.0)
    close(rq1.tau, np.asarray(rq0.tau), scale=8.0)
    rp = dlap.batched_potrf(spd, mesh, policy="reference")
    xs = dlap.batched_solve(rp, rhs, mesh, policy="reference")
    close(jnp.einsum("bij,bj->bi", spd, xs), np.asarray(rhs), scale=64.0)
    print("mesh batched LAPACK differential OK")
    """)


# ------------------------- in-process (1 device) ---------------------------

def test_registry_mesh_key_roundtrip(tmp_path):
    reg = Registry(path=str(tmp_path / "registry.json"))
    reg.record("pdgemm", (128, 128, 64), jnp.float32, "cpu",
               {"bm": 128, "bn": 128, "bk": 128}, source="sweep",
               measured_s=1e-3, mesh="x2y4")
    # same op/shape, no mesh component: a distinct single-device entry
    reg.record("gemm", (128, 128, 64), jnp.float32, "cpu",
               {"bm": 256, "bn": 128, "bk": 128})
    path = reg.save()
    reloaded = Registry(path=path)
    hit = reloaded.lookup("pdgemm", (128, 128, 64), jnp.float32, "cpu",
                          mesh="x2y4")
    assert hit is not None and hit.params["bm"] == 128
    assert reloaded.lookup("pdgemm", (128, 128, 64), jnp.float32, "cpu",
                           mesh="x4y2") is None, "mesh shapes must not alias"
    assert reloaded.lookup("pdgemm", (128, 128, 64), jnp.float32,
                           "cpu") is None, "mesh entry must not leak meshless"
    single = reloaded.lookup("gemm", (128, 128, 64), jnp.float32, "cpu")
    assert single is not None and single.params["bm"] == 256
    assert make_key("pdgemm", (128, 128, 64), jnp.float32, "cpu",
                    "x2y4") == "pdgemm|128x128x64|float32|cpu|x2y4"


def test_pdgemm_resolution_sources(tmp_path):
    reg = Registry(path=str(tmp_path / "registry.json"))
    # cold start: tuned falls back to the model plan
    res = dispatch.resolve("pdgemm", (64, 64, 64), jnp.float32,
                           policy="tuned", registry=reg, backend="cpu",
                           mesh=(2, 2))
    assert res.source == "fallback-model" and res.use_pallas
    assert res.mesh == "x2y2" and res.describe()["mesh"] == "x2y2"
    model = dispatch.resolve("pdgemm", (64, 64, 64), jnp.float32,
                             policy="model", backend="cpu", mesh=(2, 2))
    assert res.gemm_plan == model.gemm_plan, "cold-start tuned != model plan"
    # a recorded mesh entry takes over
    reg.record("pdgemm", (64, 64, 64), jnp.float32, "cpu",
               {"bm": 128, "bn": 128, "bk": 128}, mesh="x2y2")
    res2 = dispatch.resolve("pdgemm", (64, 64, 64), jnp.float32,
                            policy="tuned", registry=reg, backend="cpu",
                            mesh=(2, 2))
    assert res2.source == "registry"
    # reference never touches the kernel; mesh is required for pdgemm
    ref = dispatch.resolve("pdgemm", (64, 64, 64), jnp.float32,
                           policy="reference", mesh=(2, 2))
    assert not ref.use_pallas
    with pytest.raises(ValueError):
        dispatch.resolve("pdgemm", (64, 64, 64), jnp.float32,
                         policy="model")


def test_plan_pdgemm_collective_term():
    from repro.core.codesign import plan_pdgemm
    n = 4096                                    # large enough to amortize
    p11 = plan_pdgemm(n, n, n, 1, 1)            # per-step pipeline fill
    p22 = plan_pdgemm(n, n, n, 2, 2)
    p42 = plan_pdgemm(n, n, n, 4, 2)
    assert p11.collective_bytes == 0 and p11.collective_s == 0.0
    assert p22.collective_bytes > 0
    # more devices -> smaller local compute term, more on-wire traffic
    assert p42.compute_s < p11.compute_s
    assert p42.collective_bytes > p22.collective_bytes
    assert p22.steps == 4 and p42.steps == 8
    assert p22.modeled_time == max(p22.compute_s, p22.collective_s)
    # tiny problems never amortize the per-step fill (fig.-2 saturation):
    # the model must expose that, not hide it
    small = plan_pdgemm(128, 128, 128, 4, 2)
    assert small.compute_s > plan_pdgemm(128, 128, 128, 1, 1).compute_s
