"""Fault-tolerance runtime primitives."""
import os
import tempfile
import time

from repro.runtime.fault_tolerance import (Heartbeat, SimulatedFailure,
                                           StragglerDetector,
                                           run_with_restarts)


def test_heartbeat():
    with tempfile.TemporaryDirectory() as d:
        hb = Heartbeat(os.path.join(d, "hb.json"))
        assert hb.is_stale(0.1)           # no file yet
        hb.beat(3)
        assert not hb.is_stale(5.0)
        assert hb.age() < 5.0


def test_straggler_detection():
    det = StragglerDetector(window=20, threshold=2.0)
    for i in range(20):
        det.observe(i, 0.10)
    assert det.observe(20, 0.50)          # 5x median -> flagged
    assert not det.observe(21, 0.12)
    rep = det.report()
    assert rep["flagged"] == [20]
    assert abs(rep["median_s"] - 0.10) < 0.02


def test_run_with_restarts_gives_up():
    def always_fails(_):
        raise SimulatedFailure("boom")
    rep = run_with_restarts(always_fails, max_restarts=2)
    assert not rep.completed
    assert rep.restarts == 2


def test_run_with_restarts_immediate_success():
    rep = run_with_restarts(lambda _: 1, max_restarts=2)
    assert rep.completed and rep.restarts == 0
