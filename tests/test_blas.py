"""BLAS substrate numerics (+ hypothesis properties)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from hypothesis.extra import numpy as hnp

from repro import blas

F32 = st.floats(-10, 10, width=32)


def _vec(n=st.integers(2, 200)):
    return n.flatmap(lambda k: hnp.arrays(np.float32, (k,), elements=F32))


@given(_vec())
@settings(max_examples=40, deadline=None)
def test_property_ddot_schedules_agree(x):
    y = np.roll(x, 1)
    ref = float(np.dot(x.astype(np.float64), y.astype(np.float64)))
    scale = max(float(np.sum(np.abs(x * y))), 1.0)
    for s in ("tree", "sequential", "strided"):
        got = float(blas.ddot(jnp.asarray(x), jnp.asarray(y), schedule=s))
        assert abs(got - ref) / scale < 1e-4, s


@given(_vec())
@settings(max_examples=30, deadline=None)
def test_property_nrm2_overflow_safe(x):
    got = float(blas.dnrm2(jnp.asarray(x)))
    ref = float(np.linalg.norm(x.astype(np.float64)))
    assert got == pytest.approx(ref, rel=1e-4, abs=1e-5)
    # the scaled form survives values near fp32 max
    big = jnp.asarray(x) * 1e30
    assert np.isfinite(float(blas.dnrm2(big))) or float(jnp.max(jnp.abs(big))) == np.inf


def test_gemv_gemm(rng):
    a = jnp.asarray(rng.normal(size=(24, 36)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=36).astype(np.float32))
    np.testing.assert_allclose(np.asarray(blas.dgemv(a, x)),
                               np.asarray(a) @ np.asarray(x), rtol=2e-4,
                               atol=1e-4)
    b = jnp.asarray(rng.normal(size=(36, 12)).astype(np.float32))
    np.testing.assert_allclose(np.asarray(blas.dgemm(a, b)),
                               np.asarray(a) @ np.asarray(b), rtol=2e-4,
                               atol=1e-4)


def test_gemm_alpha_beta(rng):
    a = jnp.asarray(rng.normal(size=(8, 8)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(8, 8)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(8, 8)).astype(np.float32))
    out = blas.dgemm(a, b, c=c, alpha=2.0, beta=-1.0)
    ref = 2.0 * np.asarray(a) @ np.asarray(b) - np.asarray(c)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-4, atol=1e-4)


def test_trsv_trsm(rng):
    n = 40
    a = jnp.asarray(rng.normal(size=(n, n)).astype(np.float32))
    t = jnp.tril(a) + 4 * jnp.eye(n)
    b = jnp.asarray(rng.normal(size=n).astype(np.float32))
    x = blas.dtrsv(t, b, lower=True)
    np.testing.assert_allclose(np.asarray(t @ x), np.asarray(b), atol=1e-4)
    bm = jnp.asarray(rng.normal(size=(n, 7)).astype(np.float32))
    for lower in (True, False):
        tt = t if lower else t.T
        xm = blas.dtrsm(tt, bm, lower=lower, block=16)
        np.testing.assert_allclose(np.asarray(tt @ xm), np.asarray(bm),
                                   atol=2e-4)


def test_trsm_right_side(rng):
    n, m = 24, 10
    t = jnp.tril(jnp.asarray(rng.normal(size=(n, n)).astype(np.float32))) \
        + 4 * jnp.eye(n)
    b = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
    x = blas.dtrsm(t, b, lower=True, left=False, block=8)
    np.testing.assert_allclose(np.asarray(x @ t), np.asarray(b), atol=2e-4)


def test_syrk(rng):
    a = jnp.asarray(rng.normal(size=(12, 20)).astype(np.float32))
    c = blas.dsyrk(a)
    np.testing.assert_allclose(np.asarray(c), np.asarray(a @ a.T), rtol=2e-4,
                               atol=1e-4)


def test_ddot_kernel_dispatch(rng):
    x = jnp.asarray(rng.normal(size=2000).astype(np.float32))
    y = jnp.asarray(rng.normal(size=2000).astype(np.float32))
    from repro.kernels import ops
    got = float(ops.dotp(x, y, use_pallas=True, interpret=True))
    assert got == pytest.approx(float(np.dot(np.asarray(x), np.asarray(y))),
                                rel=1e-4)
