"""Paper section 3: the analytical TPI model and optimal pipeline depth."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import pipeline_model as pm


def test_tpi_three_terms_shape():
    # eq. 2: fixed + t_p/p + gamma*h*t_o*p - check against a hand expansion
    val = pm.tpi(4, n_i=1000, n_h=10, gamma=0.5, t_p=1.0, t_o=0.05)
    h = 10 / 1000
    expect = (0.05 + 0.5 * h * 1.0) + 1.0 / 4 + 0.5 * h * 0.05 * 4
    assert np.isclose(float(val), expect, rtol=1e-6)


def test_popt_closed_form_matches_argmin():
    # eq. 3 optimum == numerical argmin of eq. 2 over a fine grid
    for ratio in (0.01, 0.1, 0.5):
        n_i, gamma = 1e6, 0.5
        n_h = ratio * n_i
        popt = float(pm.p_opt(n_i=n_i, n_h=n_h, gamma=gamma))
        grid = jnp.linspace(1.0, 200.0, 20000)
        vals = pm.tpi(grid, n_i=n_i, n_h=n_h, gamma=gamma)
        num = float(grid[int(jnp.argmin(vals))])
        assert abs(popt - num) / num < 0.02, (ratio, popt, num)


def test_popt_autodiff_crosscheck():
    # derivative of eq. 2 vanishes at the closed-form optimum
    f = lambda p: pm.tpi(p, n_i=1e5, n_h=1e3, gamma=0.6)
    popt = float(pm.p_opt(n_i=1e5, n_h=1e3, gamma=0.6))
    g = float(jax.grad(f)(jnp.float32(popt)))
    assert abs(g) < 1e-4


def test_hazard_free_pipe_unbounded():
    # the paper's ddot multiplier pipe: no hazards -> p_opt = inf and TPI
    # monotonically decreasing ("flat horizontal line")
    assert np.isinf(float(pm.p_opt(n_i=1000, n_h=0, gamma=0.5)))
    vals = pm.tpi(jnp.arange(1, 50), n_i=1000, n_h=0, gamma=0.5)
    assert bool(jnp.all(jnp.diff(vals) <= 0))


def test_remark2_shallower_with_more_hazards():
    # Remark 2: higher N_H/N_I -> shallower optimum
    p_low = float(pm.p_opt(n_i=1e6, n_h=1e3, gamma=0.5))
    p_high = float(pm.p_opt(n_i=1e6, n_h=1e5, gamma=0.5))
    assert p_high < p_low


def test_remark3_gamma_sensitivity():
    # Remark 3 / fig. 4: larger gamma -> shallower optimum
    p1 = float(pm.p_opt(n_i=1e6, n_h=1e4, gamma=0.1))
    p2 = float(pm.p_opt(n_i=1e6, n_h=1e4, gamma=0.8))
    assert p2 < p1


def test_figure2_saturation():
    curves = pm.figure2_curves()
    for (p, r), (grid, vals) in curves.items():
        # TPI decreases toward an asymptote as workload grows (fp32 noise)
        assert bool(jnp.all(jnp.diff(vals) <= 1e-6))
        # deeper pipes saturate lower for the low-hazard regime
    lo = curves[(8, 0.001)][1][-1]
    hi = curves[(2, 0.001)][1][-1]
    assert float(lo) < float(hi)


def test_figure3_minimum_exists():
    curves = pm.figure3_curves()
    for r, (grid, vals) in curves.items():
        i = int(jnp.argmin(vals))
        popt = float(pm.p_opt(n_i=1e6, n_h=r * 1e6, gamma=0.5))
        if popt < float(grid[-1]):
            assert 0 < i < len(grid) - 1, (r, i)  # interior optimum
        else:
            assert i == len(grid) - 1             # optimum beyond the grid


@given(n_i=st.floats(1e3, 1e8), ratio=st.floats(1e-4, 0.9),
       gamma=st.floats(0.05, 0.95))
@settings(max_examples=50, deadline=None)
def test_property_popt_formula(n_i, ratio, gamma):
    """eq. 3 invariance: p_opt^2 * gamma * N_H * t_o == N_I * t_p."""
    n_h = ratio * n_i
    p = float(pm.p_opt(n_i=n_i, n_h=n_h, gamma=gamma, t_p=1.0, t_o=0.05))
    lhs = p * p * gamma * n_h * 0.05
    assert lhs == pytest.approx(n_i * 1.0, rel=1e-3)


@given(p=st.integers(1, 64), n_i=st.floats(1e3, 1e7),
       ratio=st.floats(1e-4, 0.9))
@settings(max_examples=50, deadline=None)
def test_property_tpi_positive_and_bounded_below(p, n_i, ratio):
    v = float(pm.tpi(p, n_i=n_i, n_h=ratio * n_i, gamma=0.5))
    assert v > 0
    assert v >= 0.05  # never beats the latch overhead floor
