"""repro.obs: tracer semantics, routine threading, exporters, counters.

Four contracts under test:

1. **Numerics invariance**: tracing never changes results - traced /
   untraced / ``obs=False``-suppressed runs of the same routine are
   bitwise identical.
2. **Threading**: routines traced under ``linalg.use`` produce nested
   spans (routine -> panel/trailing) whose resolved provenance agrees
   with a direct :func:`repro.tune.dispatch.resolve` call; the mesh leg
   runs in a subprocess (8 forced host devices, pattern of
   ``tests/test_distributed_blas.py``) and validates per-hop collective
   bytes plus the Chrome artifact end-to-end.
3. **Export round-trip**: the Chrome trace survives ``json.loads`` with
   monotonic timestamps; the JSON-lines form round-trips the frozen
   :data:`repro.obs.EVENT_FIELDS` schema.
4. **Graceful is not silent**: a corrupt registry file warns exactly
   once per path and fires ``registry.corrupt_fallback`` (satellite of
   ISSUE 7).
"""
import json
import os
import subprocess
import sys
import textwrap
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro import linalg, obs
from repro import tune


def _mk(rng, shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


# ------------------------------ tracer core ---------------------------------

def test_span_nesting_and_ids():
    with obs.trace("t") as tr:
        with obs.span("outer", cat="a") as so:
            with obs.span("inner", cat="b", k=1):
                pass
            obs.event("tick", cat="c")
        assert so.annotate(extra=2) is so
    assert not obs.enabled()
    # children (and instants) land before their parent closes
    assert [e.name for e in tr.events] == ["inner", "tick", "outer"]
    inner, tick, outer = tr.events
    assert inner.parent == outer.id
    assert tick.parent == outer.id
    assert tick.t_end is None                       # instant
    assert outer.attrs["extra"] == 2
    assert inner.t_start >= outer.t_start
    assert inner.t_end <= outer.t_end


def test_disabled_path_is_noop():
    assert not obs.enabled()
    assert obs.current_trace() is None
    assert obs.span("x") is obs.NOOP_SPAN
    assert obs.event("x") is None
    assert obs.annotate(a=1) is False
    with obs.span("x") as sp:                       # usable as a with-block
        assert sp is obs.NOOP_SPAN


def test_roofline_annotation_prices_flops():
    from repro import arch
    with obs.trace("t") as tr:
        with obs.span("work", cat="k", flops=10 ** 9, bytes=10 ** 6):
            pass
    (sp,) = tr.events
    mach = arch.current_machine()
    assert sp.attrs["machine"] == mach.name
    want = max(10 ** 9 / mach.pe.peak_flops,
               10 ** 6 / mach.memory.hbm_bw)
    assert sp.attrs["modeled_s"] == pytest.approx(want)
    assert sp.attrs["fraction_of_modeled_peak"] > 0
    wall = sp.attrs["wall_s"]
    assert sp.attrs["model_residual"] == pytest.approx(
        tune.model_residual(want, wall))


def test_counters_delta():
    before = obs.counters_snapshot()
    obs.inc("kernel.launch")
    obs.inc("collective.bytes", 128)
    d = obs.counters_delta(before)
    assert d["kernel.launch"] == 1
    assert d["collective.bytes"] == 128
    assert obs.counter("kernel.launch") >= 1
    for name in ("kernel.launch", "collective.bytes"):
        assert name in obs.KNOWN_COUNTERS


# --------------------- numerics invariance (bitwise) ------------------------

def test_tracing_is_bitwise_invisible(rng):
    a = _mk(rng, (96, 64))
    b = _mk(rng, (64, 48))
    with linalg.use(policy="model"):
        q0, r0 = linalg.qr(a, block=16)
        c0 = linalg.gemm(a, b)
    with obs.trace("t") as tr:
        with linalg.use(policy="model"):
            q1, r1 = linalg.qr(a, block=16)
            c1 = linalg.gemm(a, b)
        with linalg.use(policy="model", obs=False):   # suppressed capture
            q2, r2 = linalg.qr(a, block=16)
            c2 = linalg.gemm(a, b)
    for x0, x1, x2 in ((q0, q1, q2), (r0, r1, r2), (c0, c1, c2)):
        assert np.asarray(x0).tobytes() == np.asarray(x1).tobytes()
        assert np.asarray(x0).tobytes() == np.asarray(x2).tobytes()
    # the obs=False block contributed nothing to the trace
    assert len(tr.spans(name="linalg.qr")) == 1
    assert len(tr.spans(name="linalg.gemm")) == 1


# ------------------- routine threading (no-mesh leg) ------------------------

def test_traced_qr_has_nested_panel_spans(rng):
    a = _mk(rng, (96, 64))
    with obs.trace("qr") as tr:
        with linalg.use(policy="model"):
            linalg.qr(a, block=16)
    (qr_span,) = tr.spans(name="linalg.qr")
    assert qr_span.cat == "routine"
    assert qr_span.attrs["shape"] == [96, 64]
    assert qr_span.attrs["dtype"] == "float32"
    assert qr_span.attrs["flops"] > 0
    panels = tr.spans(cat="panel")
    trailing = tr.spans(cat="trailing")
    assert len(panels) == 4 and len(trailing) == 3   # kmax=64, nb=16
    for sp in panels + trailing:
        assert sp.parent == qr_span.id
        assert sp.attrs["flops"] > 0
    # resolve provenance events nest under the trailing spans
    resolves = tr.spans(name="tune.resolve")
    assert resolves and all(e.cat == "resolve" for e in resolves)
    trailing_ids = {sp.id for sp in trailing}
    assert all(e.parent in trailing_ids for e in resolves)


def test_resolve_provenance_agrees_with_dispatcher(rng):
    a = _mk(rng, (64, 32))
    b = _mk(rng, (32, 48))
    with obs.trace("gemm") as tr:
        with linalg.use(policy="model"):
            linalg.gemm(a, b)
    (ev,) = tr.spans(name="tune.resolve")
    direct = tune.resolve("gemm", (64, 48, 32), jnp.float32,
                          policy="model").describe()
    for key in ("op", "policy", "source", "use_pallas", "machine", "config"):
        assert ev.attrs[key] == direct[key], key
    assert ev.attrs["source"] == "model"


def test_context_obs_field_routes_capture(rng):
    a = _mk(rng, (32, 24))
    tr = obs.Trace("explicit")
    with linalg.use(policy="model", obs=tr):
        linalg.gemm(a.T, a)
    tr.finish()
    assert tr.spans(name="linalg.gemm")
    assert tr.counters.get("dispatch.resolve", 0) >= 1
    ctx = linalg.ExecutionContext(obs=tr)
    assert ctx.describe()["obs"] == "explicit"
    assert linalg.ExecutionContext(obs=False).describe()["obs"] is False
    with pytest.raises(ValueError):
        linalg.ExecutionContext(obs="not-a-trace")


def test_measure_annotates_enclosing_span():
    f = jnp.sin
    x = jnp.ones((128,), jnp.float32)
    with obs.trace("m") as tr:
        with obs.span("timed", cat="bench"):
            m = tune.measure_op(f, x, reps=2)
    (sp,) = tr.spans(name="timed")
    assert sp.attrs["measure_reps"] == m.reps == 2
    assert sp.attrs["measure_seconds_median"] == pytest.approx(
        m.seconds_median)
    # with no open span the summary lands as an instant event instead
    with obs.trace("m2") as tr2:
        tune.measure_op(f, x, reps=1)
    assert tr2.spans(name="tune.measure")


# ------------------------------ exporters -----------------------------------

def _small_trace(rng):
    a = _mk(rng, (96, 64))
    with obs.trace("export") as tr:
        with linalg.use(policy="model"):
            linalg.qr(a, block=16)
            linalg.gemm(a.T, a)
    return tr


def test_chrome_export_round_trips(rng, tmp_path):
    tr = _small_trace(rng)
    path = str(tmp_path / "trace.json")
    obs.save_chrome_trace(tr, path)
    with open(path) as f:
        blob = json.loads(f.read())
    assert blob["otherData"]["schema_version"] == obs.SCHEMA_VERSION
    assert blob["otherData"]["trace_name"] == "export"
    assert blob["otherData"]["counters"]["dispatch.resolve"] >= 1
    evs = blob["traceEvents"]
    assert len(evs) == len(tr.events)
    ts = [e["ts"] for e in evs]
    assert ts == sorted(ts)                          # monotonic start times
    for e in evs:
        assert e["ph"] in ("X", "i")
        if e["ph"] == "X":
            assert e["dur"] >= 0
    # provenance survives the export
    assert any(e["name"] == "tune.resolve" and "source" in e["args"]
               for e in evs)


def test_jsonl_export_round_trips(rng, tmp_path):
    tr = _small_trace(rng)
    path = str(tmp_path / "trace.jsonl")
    obs.save_jsonl(tr, path)
    lines = [json.loads(l) for l in open(path)]
    assert lines[0]["kind"] == "header"
    assert lines[0]["schema_version"] == obs.SCHEMA_VERSION
    assert lines[-1]["kind"] == "counters"
    events = [l for l in lines if l["kind"] == "event"]
    assert len(events) == len(tr.events)
    for e in events:
        assert set(e) == set(obs.EVENT_FIELDS) | {"kind"}
    starts = [e["t_start"] for e in events]
    assert starts == sorted(starts)


def test_trace_report_validates_both_formats(rng, tmp_path):
    tr = _small_trace(rng)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    script = os.path.join(root, "scripts", "trace_report.py")
    chrome = str(tmp_path / "t.json")
    jsonl = str(tmp_path / "t.jsonl")
    obs.save_chrome_trace(tr, chrome)
    obs.save_jsonl(tr, jsonl)
    for p in (chrome, jsonl):
        r = subprocess.run([sys.executable, script, "--validate", p],
                           capture_output=True, text=True)
        assert r.returncode == 0, f"{r.stdout}\n{r.stderr}"
        assert "trace OK" in r.stdout
    # a tampered schema version must fail validation
    blob = json.loads(open(chrome).read())
    blob["otherData"]["schema_version"] = 999
    bad = str(tmp_path / "bad.json")
    with open(bad, "w") as f:
        json.dump(blob, f)
    r = subprocess.run([sys.executable, script, "--validate", bad],
                       capture_output=True, text=True)
    assert r.returncode == 1


def test_trace_report_rejects_malformed_roofline_attrs(rng, tmp_path):
    """PR 9 satellite: --validate cross-checks span flops/bytes against
    the schema types and rejects non-finite / negative
    fraction_of_modeled_peak in either exporter format."""
    tr = _small_trace(rng)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    script = os.path.join(root, "scripts", "trace_report.py")
    chrome = str(tmp_path / "t.json")
    obs.save_chrome_trace(tr, chrome)
    for poison, needle in (({"flops": -5}, "flops"),
                           ({"bytes": "many"}, "bytes"),
                           ({"fraction_of_modeled_peak": float("nan")},
                            "fraction_of_modeled_peak"),
                           ({"fraction_of_modeled_peak": -0.25},
                            "fraction_of_modeled_peak")):
        blob = json.loads(open(chrome).read())
        spans = [e for e in blob["traceEvents"] if e.get("ph") == "X"]
        spans[0]["args"].update(poison)
        # python json writes/reads NaN/Infinity literals (allow_nan)
        bad = str(tmp_path / "bad_attr.json")
        with open(bad, "w") as f:
            json.dump(blob, f)
        r = subprocess.run([sys.executable, script, "--validate", bad],
                           capture_output=True, text=True)
        assert r.returncode == 1, f"{poison} passed validation"
        assert needle in r.stdout
    # jsonl leg: same rejection through the attrs dict
    jsonl = str(tmp_path / "t.jsonl")
    obs.save_jsonl(tr, jsonl)
    lines = open(jsonl).read().splitlines()
    recs = [json.loads(l) for l in lines]
    ev = next(r for r in recs if r["kind"] == "event")
    ev["attrs"]["flops"] = float("inf")
    bad = str(tmp_path / "bad.jsonl")
    with open(bad, "w") as f:
        f.write("\n".join(json.dumps(r) for r in recs))
    r = subprocess.run([sys.executable, script, "--validate", bad],
                       capture_output=True, text=True)
    assert r.returncode == 1 and "flops" in r.stdout


def test_summary_mentions_routines(rng):
    tr = _small_trace(rng)
    text = obs.summary(tr)
    assert "linalg.qr" in text
    assert "dispatch.resolve" in text


# -------------------- corrupt-registry fallback (satellite) -----------------

def test_corrupt_registry_warns_once_and_counts(tmp_path):
    from repro.tune.registry import Registry
    path = str(tmp_path / "corrupt.json")
    with open(path, "w") as f:
        f.write("{ not json")
    before = obs.counters_snapshot()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        reg = Registry(path=path)
        assert reg.load() == 0
        assert reg.load_error is not None
        reg2 = Registry(path=path)                  # second load, same path
        assert reg2.load() == 0
    ours = [x for x in w if issubclass(x.category, RuntimeWarning)
            and "falling back to model-planned" in str(x.message)]
    assert len(ours) == 1, "corrupt-registry warning must fire exactly once"
    d = obs.counters_delta(before)
    assert d["registry.corrupt_fallback"] == 2      # counted every load
    assert d["registry.load"] == 2
    # numerics still resolve (model fallback), provenance says so
    res = tune.resolve("gemm", (32, 32, 32), jnp.float32, policy="tuned",
                       registry=reg)
    assert res.source == "fallback-model"


def test_missing_registry_counts_cold_start(tmp_path):
    from repro.tune.registry import Registry
    before = obs.counters_snapshot()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        reg = Registry(path=str(tmp_path / "never-written.json"))
        assert reg.load() == 0
    assert not [x for x in w if issubclass(x.category, RuntimeWarning)]
    d = obs.counters_delta(before)
    assert d["registry.missing_fallback"] == 1
    assert d.get("registry.corrupt_fallback", 0) == 0


# ------------------------- serve smoke (satellite) --------------------------

def test_serve_batch_traces_requests():
    from repro.launch.serve import Request, serve_batch
    from repro.models import model_zoo as zoo
    from repro.models.config import ModelConfig
    cfg = ModelConfig("t", "dense", n_layers=1, d_model=32, n_heads=2,
                      n_kv=1, d_ff=64, vocab=64, dtype="float32")
    import jax
    params = zoo.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    reqs = [Request(rng.integers(0, 64, size=4).astype(np.int32), 2)
            for _ in range(2)]
    tr = obs.Trace("serve")
    outs, stats = serve_batch(params, cfg, reqs, max_len=16,
                              context=linalg.ExecutionContext(obs=tr))
    tr.finish()
    assert len(outs) == 2 and all(len(o) == 6 for o in outs)
    (batch,) = tr.spans(name="serve.batch")
    assert batch.attrs["requests"] == 2
    assert tr.spans(name="serve.prefill")
    (dec,) = tr.spans(name="serve.decode")
    assert dec.attrs["steps"] == stats["steps"]
    assert len(tr.spans(name="serve.request")) == 2


# ---------------------- mesh acceptance leg (subprocess) --------------------

_ENV = dict(os.environ,
            XLA_FLAGS="--xla_force_host_platform_device_count=8",
            PYTHONPATH="src")


def test_traced_mesh_trace_has_collectives_and_provenance(tmp_path):
    """The ISSUE-7 acceptance criterion: traced qr + gemm under a (2, 2)
    mesh yields a Chrome trace with resolved-config provenance, per-hop
    collective bytes, and fraction-of-modeled-peak - and the artifact
    passes ``trace_report.py --validate``."""
    out = str(tmp_path / "mesh_trace.json")
    code = textwrap.dedent(f"""
    import json
    import numpy as np
    import jax.numpy as jnp
    from repro import linalg, obs

    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((96, 64)).astype(np.float32))
    before = obs.counters_snapshot()
    with obs.trace("mesh") as tr:
        with linalg.use(policy="model", mesh=(2, 2)):
            linalg.qr(a, block=16)
            linalg.gemm(a, a.T)
    obs.save_chrome_trace(tr, {out!r})

    assert tr.spans(name="linalg.qr") and tr.spans(name="linalg.gemm")
    # distributed gemm rode pdgemm -> ring_bcast: per-hop bytes recorded
    colls = tr.spans(name="collective.ring_bcast")
    assert colls, "no ring_bcast events under the (2, 2) mesh"
    for ev in colls:
        assert ev.attrs["hops"] >= 1
        assert ev.attrs["per_hop_bytes"] > 0
        assert ev.attrs["wire_bytes"] == \\
            ev.attrs["per_hop_bytes"] * ev.attrs["hops"]
    assert tr.counters.get("collective.hops", 0) >= 1
    assert tr.counters.get("collective.bytes", 0) > 0
    # provenance + roofline on the routine spans
    assert any(e.attrs.get("source") for e in tr.spans(name="tune.resolve"))
    assert any("fraction_of_modeled_peak" in e.attrs
               for e in tr.spans(cat="routine"))
    print("mesh trace OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], env=_ENV,
                       capture_output=True, text=True, timeout=900,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "mesh trace OK" in r.stdout
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    rv = subprocess.run([sys.executable,
                         os.path.join(root, "scripts", "trace_report.py"),
                         "--validate", out],
                        capture_output=True, text=True)
    assert rv.returncode == 0, f"{rv.stdout}\n{rv.stderr}"
    blob = json.loads(open(out).read())
    names = {e["name"] for e in blob["traceEvents"]}
    assert "collective.ring_bcast" in names
    assert "tune.resolve" in names
