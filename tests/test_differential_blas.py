"""Differential tests: every BLAS routine vs a NumPy/SciPy oracle.

Levels 1-3 over a parametrized shape x dtype x transpose grid; all
comparisons go through the shared dtype-keyed tolerance helper in
conftest.py (oracle computed in float64). This is the testing convention
ROADMAP.md prescribes for every new numeric routine.
"""
import jax.numpy as jnp
import numpy as np
import pytest
import scipy.linalg

from repro import blas

DTYPES = [np.float32, jnp.bfloat16]
SHAPES_MM = [(8, 8, 8), (24, 36, 12), (17, 5, 29), (1, 64, 1)]
VEC_NS = [1, 7, 64, 1000]


def _mk(rng, shape, dtype):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32)).astype(dtype)


def _f64(x):
    return np.asarray(x.astype(jnp.float32)).astype(np.float64)


# ------------------------------- level 1 ------------------------------------

@pytest.mark.parametrize("n", VEC_NS)
@pytest.mark.parametrize("schedule", ["tree", "sequential", "strided"])
def test_ddot_vs_numpy(rng, assert_close, n, schedule):
    x = _mk(rng, n, np.float32)
    y = _mk(rng, n, np.float32)
    got = blas.ddot(x, y, schedule=schedule, accumulators=8)
    assert_close(got, np.dot(_f64(x), _f64(y)), scale=max(1.0, n / 64))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("n", VEC_NS)
def test_daxpy_dscal_vs_numpy(rng, assert_close, n, dtype):
    x, y = _mk(rng, n, dtype), _mk(rng, n, dtype)
    assert_close(blas.daxpy(2.5, x, y), 2.5 * _f64(x) + _f64(y))
    assert_close(blas.dscal(-0.5, x), -0.5 * _f64(x))


@pytest.mark.parametrize("n", VEC_NS)
def test_dnrm2_dasum_idamax_vs_numpy(rng, assert_close, n):
    x = _mk(rng, n, np.float32)
    assert_close(blas.dnrm2(x), np.linalg.norm(_f64(x)))
    assert_close(blas.level1.dasum(x), np.abs(_f64(x)).sum(),
                 scale=max(1.0, n / 64))
    assert int(blas.idamax(x)) == int(np.argmax(np.abs(_f64(x))))


def test_drot_vs_oracle(rng, assert_close):
    x, y = _mk(rng, 33, np.float32), _mk(rng, 33, np.float32)
    c, s = np.cos(0.3), np.sin(0.3)
    gx, gy = blas.level1.drot(x, y, c, s)
    assert_close(gx, c * _f64(x) + s * _f64(y))
    assert_close(gy, c * _f64(y) - s * _f64(x))


# ------------------------------- level 2 ------------------------------------

@pytest.mark.parametrize("trans", [False, True])
@pytest.mark.parametrize("m,n", [(8, 8), (24, 36), (17, 5), (1, 64)])
def test_dgemv_vs_numpy(rng, assert_close, m, n, trans):
    a = _mk(rng, (m, n), np.float32)
    x = _mk(rng, m if trans else n, np.float32)
    y = _mk(rng, n if trans else m, np.float32)
    ref = (_f64(a).T if trans else _f64(a)) @ _f64(x)
    assert_close(blas.dgemv(a, x, trans=trans), ref)
    got = blas.dgemv(a, x, trans=trans, alpha=1.5, beta=-2.0, y=y)
    assert_close(got, 1.5 * ref - 2.0 * _f64(y))


def test_dger_vs_numpy(rng, assert_close):
    x, y = _mk(rng, 13, np.float32), _mk(rng, 21, np.float32)
    a = _mk(rng, (13, 21), np.float32)
    assert_close(blas.dger(0.75, x, y, a),
                 _f64(a) + 0.75 * np.outer(_f64(x), _f64(y)))


@pytest.mark.parametrize("unit_diag", [False, True])
@pytest.mark.parametrize("lower", [False, True])
@pytest.mark.parametrize("n", [5, 32, 65])
def test_dtrsv_vs_scipy(rng, assert_close, n, lower, unit_diag):
    a = _mk(rng, (n, n), np.float32)
    t = (jnp.tril(a) if lower else jnp.triu(a)) + 4 * jnp.eye(n)
    b = _mk(rng, n, np.float32)
    got = blas.dtrsv(t, b, lower=lower, unit_diag=unit_diag)
    ref = scipy.linalg.solve_triangular(
        _f64(t), _f64(b), lower=lower, unit_diagonal=unit_diag)
    assert_close(got, ref, scale=4.0)


# ------------------------------- level 3 ------------------------------------

@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("ta,tb", [(False, False), (True, False),
                                   (False, True), (True, True)])
@pytest.mark.parametrize("m,n,k", SHAPES_MM)
def test_dgemm_transpose_grid_vs_numpy(rng, assert_close, m, n, k, ta, tb,
                                       dtype):
    a = _mk(rng, (k, m) if ta else (m, k), dtype)
    b = _mk(rng, (n, k) if tb else (k, n), dtype)
    opa, opb = (a.T if ta else a), (b.T if tb else b)
    ref = (_f64(a).T if ta else _f64(a)) @ (_f64(b).T if tb else _f64(b))
    assert_close(blas.dgemm(opa, opb), ref, scale=max(1.0, k / 16))


@pytest.mark.parametrize("m,n,k", [(24, 36, 12), (17, 5, 29)])
def test_dgemm_alpha_beta_vs_numpy(rng, assert_close, m, n, k):
    a, b = _mk(rng, (m, k), np.float32), _mk(rng, (k, n), np.float32)
    c = _mk(rng, (m, n), np.float32)
    got = blas.dgemm(a, b, c=c, alpha=-1.5, beta=0.5)
    assert_close(got, -1.5 * _f64(a) @ _f64(b) + 0.5 * _f64(c))


@pytest.mark.parametrize("m,n,k", SHAPES_MM)
def test_dgemm_kernel_path_vs_numpy(rng, assert_close, m, n, k):
    """use_kernel=True (Pallas, interpret on CPU) against the same oracle."""
    a, b = _mk(rng, (m, k), np.float32), _mk(rng, (k, n), np.float32)
    got = blas.dgemm(a, b, use_kernel=True, interpret=True)
    assert_close(got, _f64(a) @ _f64(b), scale=max(1.0, k / 16))


@pytest.mark.parametrize("lower", [False, True])
def test_dsyrk_vs_numpy(rng, assert_close, lower):
    a = _mk(rng, (12, 20), np.float32)
    ref = _f64(a) @ _f64(a).T
    assert_close(blas.dsyrk(a, lower=lower), ref)
    c = _mk(rng, (12, 12), np.float32)
    got = blas.dsyrk(a, c=c, alpha=2.0, beta=-1.0, lower=lower,
                     use_kernel=True)
    # BLAS semantics: only the selected triangle of C is referenced
    tri = np.tril if lower else np.triu
    assert_close(tri(np.asarray(got)), tri(2.0 * ref - _f64(c)))


@pytest.mark.parametrize("use_kernel", [False, True])
@pytest.mark.parametrize("left", [True, False])
@pytest.mark.parametrize("lower", [True, False])
@pytest.mark.parametrize("n,nrhs,block", [(24, 7, 8), (40, 3, 999)])
def test_dtrsm_grid_vs_scipy(rng, assert_close, n, nrhs, block, lower, left,
                             use_kernel):
    a = _mk(rng, (n, n), np.float32)
    t = (jnp.tril(a) if lower else jnp.triu(a)) + 4 * jnp.eye(n)
    b = _mk(rng, (n, nrhs) if left else (nrhs, n), np.float32)
    got = blas.dtrsm(t, b, lower=lower, left=left, block=block,
                     use_kernel=use_kernel)
    if left:
        ref = scipy.linalg.solve_triangular(_f64(t), _f64(b), lower=lower)
    else:  # X T = B
        ref = scipy.linalg.solve_triangular(_f64(t).T, _f64(b).T,
                                            lower=not lower).T
    assert_close(got, ref, scale=4.0)


# ------------------ dtype-generic repro.linalg front-end --------------------
# Float64 legs need JAX_ENABLE_X64 and run in tests/test_linalg.py's
# subprocess grid; the in-process grid covers every dtype the default
# config supports.

from conftest import LINALG_DTYPES

from repro import linalg


@pytest.mark.parametrize("dtype", LINALG_DTYPES)
@pytest.mark.parametrize("ta,tb", [(False, False), (True, True)])
@pytest.mark.parametrize("m,n,k", SHAPES_MM)
def test_linalg_gemm_dtype_grid(rng, assert_close, m, n, k, ta, tb, dtype):
    a = _mk(rng, (k, m) if ta else (m, k), dtype)
    b = _mk(rng, (n, k) if tb else (k, n), dtype)
    got = linalg.gemm(a, b, transa=ta, transb=tb)
    assert got.dtype == jnp.dtype(dtype)
    ref = (_f64(a).T if ta else _f64(a)) @ (_f64(b).T if tb else _f64(b))
    assert_close(got, ref, scale=max(1.0, k / 16))


@pytest.mark.parametrize("dtype", LINALG_DTYPES)
@pytest.mark.parametrize("trans", [False, True])
def test_linalg_gemv_dtype_grid(rng, assert_close, trans, dtype):
    a = _mk(rng, (24, 36), dtype)
    x = _mk(rng, 24 if trans else 36, dtype)
    got = linalg.gemv(a, x, trans=trans)
    assert_close(got, (_f64(a).T if trans else _f64(a)) @ _f64(x),
                 scale=2.0)


@pytest.mark.parametrize("dtype", LINALG_DTYPES)
@pytest.mark.parametrize("lower", [False, True])
def test_linalg_trsm_dtype_grid(rng, assert_close, lower, dtype):
    n = 24
    a = _mk(rng, (n, n), dtype)
    t = (jnp.tril(a) if lower else jnp.triu(a)) + 4 * jnp.eye(n, dtype=dtype)
    b = _mk(rng, (n, 5), dtype)
    got = linalg.trsm(t, b, lower=lower, block=8)
    ref = scipy.linalg.solve_triangular(_f64(t), _f64(b), lower=lower)
    assert_close(got, ref, scale=8.0)


@pytest.mark.parametrize("dtype", LINALG_DTYPES)
def test_linalg_level1_dtype_grid(rng, assert_close, dtype):
    x, y = _mk(rng, 129, dtype), _mk(rng, 129, dtype)
    assert_close(linalg.dot(x, y), np.dot(_f64(x), _f64(y)), scale=4.0)
    assert_close(linalg.axpy(0.5, x, y), 0.5 * _f64(x) + _f64(y))
    s = _mk(rng, (8, 12), dtype)
    assert_close(linalg.syrk(s), _f64(s) @ _f64(s).T, scale=2.0)


@pytest.mark.parametrize("pol", ["reference", "model", "tuned"])
def test_linalg_policy_context_equals_kwarg_path(rng, pol):
    """linalg under use(policy=...) must be bitwise the old per-call
    policy= threading (the shims' path)."""
    import warnings as _w
    a, b = _mk(rng, (24, 12), np.float32), _mk(rng, (12, 18), np.float32)
    with linalg.use(policy=pol):
        new = linalg.gemm(a, b)
    with _w.catch_warnings():
        _w.simplefilter("ignore", DeprecationWarning)
        old = blas.dgemm(a, b, policy=pol)
    assert np.array_equal(np.asarray(new), np.asarray(old))
