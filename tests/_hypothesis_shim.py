"""Minimal deterministic stand-in for the slice of `hypothesis` this suite
uses, installed by conftest.py only when the real package is unavailable
(the container image cannot pip install).

Property tests degrade gracefully to sampled-example tests: each ``@given``
test runs ``max_examples`` deterministic draws per strategy — boundary
values first (example 0 draws every strategy's minimum, example 1 every
maximum), then seeded-random interiors — so edge cases are always probed
and failures are reproducible. No shrinking; the failing example is
attached to the raised AssertionError instead.

Covered API (everything the test modules import):
    hypothesis.given / settings / strategies.{integers,floats,booleans,
    sampled_from,just} / strategy.{map,flatmap,filter} /
    hypothesis.extra.numpy.arrays
"""
from __future__ import annotations

import random
import sys
import types

import numpy as np

DEFAULT_MAX_EXAMPLES = 25
_SETTINGS_ATTR = "_shim_settings"


class SearchStrategy:
    """A strategy is a draw function (rng, example_index) -> value.

    ``index`` 0/1 request the strategy's min/max boundary; anything else
    (including None) requests a random interior value.
    """

    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random, index=None):
        return self._draw(rng, index)

    def map(self, f):
        return SearchStrategy(lambda rng, i: f(self._draw(rng, i)))

    def flatmap(self, f):
        return SearchStrategy(lambda rng, i: f(self._draw(rng, i))._draw(rng, i))

    def filter(self, pred):
        def draw(rng, i):
            v = self._draw(rng, i)
            if pred(v):
                return v
            for _ in range(1000):  # boundary rejected: fall back to random
                v = self._draw(rng, None)
                if pred(v):
                    return v
            raise ValueError("filter predicate rejected 1000 draws")
        return SearchStrategy(draw)


def integers(min_value: int, max_value: int) -> SearchStrategy:
    def draw(rng, i):
        if i == 0:
            return int(min_value)
        if i == 1:
            return int(max_value)
        return rng.randint(int(min_value), int(max_value))
    return SearchStrategy(draw)


def floats(min_value=None, max_value=None, width: int = 64,
           allow_nan: bool = False, allow_infinity: bool = False,
           **_ignored) -> SearchStrategy:
    lo = -1e6 if min_value is None else float(min_value)
    hi = 1e6 if max_value is None else float(max_value)

    def draw(rng, i):
        if i == 0:
            v = lo
        elif i == 1:
            v = hi
        else:
            v = rng.uniform(lo, hi)
        return float(np.float32(v)) if width == 32 else v
    return SearchStrategy(draw)


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng, i: bool(i % 2) if i in (0, 1)
                          else rng.random() < 0.5)


def sampled_from(elements) -> SearchStrategy:
    seq = list(elements)
    return SearchStrategy(lambda rng, i: seq[0] if i == 0 else
                          seq[-1] if i == 1 else rng.choice(seq))


def just(value) -> SearchStrategy:
    return SearchStrategy(lambda rng, i: value)


def _np_arrays(dtype, shape, elements: SearchStrategy | None = None,
               **_ignored) -> SearchStrategy:
    """hypothesis.extra.numpy.arrays — shape may be an int, a tuple, or a
    strategy; elements defaults to small floats."""
    elements = elements or floats(-10, 10, width=32)

    def draw(rng, i):
        shp = shape.example(rng, i) if isinstance(shape, SearchStrategy) else shape
        if isinstance(shp, int):
            shp = (shp,)
        n = int(np.prod(shp)) if shp else 1
        # example 0/1 probe all-min / all-max arrays; others are random
        flat = [elements.example(rng, i if i in (0, 1) else None)
                for _ in range(n)]
        return np.asarray(flat, dtype=dtype).reshape(shp)
    return SearchStrategy(draw)


def settings(**kw):
    """Records max_examples (everything else — deadline, suppress_* — is a
    no-op here). Works above or below @given."""
    def deco(fn):
        setattr(fn, _SETTINGS_ATTR, kw)
        return fn
    return deco


def given(*arg_strategies, **kw_strategies):
    def deco(fn):
        def wrapper(*fixture_args, **fixture_kwargs):
            conf = (getattr(wrapper, _SETTINGS_ATTR, None)
                    or getattr(fn, _SETTINGS_ATTR, None) or {})
            n = int(conf.get("max_examples", DEFAULT_MAX_EXAMPLES))
            rng = random.Random(0)
            for i in range(n):
                args = [s.example(rng, i) for s in arg_strategies]
                kwargs = {k: s.example(rng, i)
                          for k, s in kw_strategies.items()}
                try:
                    fn(*fixture_args, *args, **fixture_kwargs, **kwargs)
                except _Rejected:
                    continue  # assume() failed: discard this example
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example #{i}: args={args!r} "
                        f"kwargs={kwargs!r}") from e

        # plain attribute copies — functools.wraps would set __wrapped__ and
        # pytest would then collect the inner signature as fixture requests
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = getattr(fn, "__qualname__", fn.__name__)
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        if hasattr(fn, _SETTINGS_ATTR):
            setattr(wrapper, _SETTINGS_ATTR, getattr(fn, _SETTINGS_ATTR))
        return wrapper
    return deco


def assume(condition) -> bool:
    """Real hypothesis aborts the example; we just skip the rest of it by
    raising the same control-flow exception pytest treats as a pass."""
    if not condition:
        raise _Rejected()
    return True


class _Rejected(Exception):
    pass


class HealthCheck:
    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"

    @classmethod
    def all(cls):
        return [cls.too_slow, cls.data_too_large, cls.filter_too_much]


def install() -> None:
    """Register shim modules under the hypothesis import names."""
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.assume = assume
    hyp.HealthCheck = HealthCheck
    hyp.__version__ = "0.0-shim"

    strat = types.ModuleType("hypothesis.strategies")
    strat.integers = integers
    strat.floats = floats
    strat.booleans = booleans
    strat.sampled_from = sampled_from
    strat.just = just
    strat.SearchStrategy = SearchStrategy
    hyp.strategies = strat

    extra = types.ModuleType("hypothesis.extra")
    extra_np = types.ModuleType("hypothesis.extra.numpy")
    extra_np.arrays = _np_arrays
    extra.numpy = extra_np
    hyp.extra = extra

    sys.modules.setdefault("hypothesis", hyp)
    sys.modules.setdefault("hypothesis.strategies", strat)
    sys.modules.setdefault("hypothesis.extra", extra)
    sys.modules.setdefault("hypothesis.extra.numpy", extra_np)
