"""repro.arch: machine specs, registry, serialization, and the end-to-end
machine -> planner -> tuner-key -> context flow.

Conventions covered (ROADMAP): persistence gets round-trip + corrupt-file +
missing-file tests; the default machine must keep every planner output
bit-identical to the pre-arch module constants (also guarded by
scripts/check_golden_plans.py in CI); a non-default machine must change
planner/tuner decisions end-to-end through ``linalg.use(machine=...)``.
"""
import dataclasses
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro import arch, linalg, tune
from repro.arch import (FPUSpec, MachineSpec, MemorySpec, PEGeometry,
                        PowerAreaSpec)
from repro.core import codesign as cd
from repro.tune.registry import Registry, make_key


@pytest.fixture(autouse=True)
def _clean_machine_state():
    yield
    arch.set_default_machine(None)
    linalg.reset_context()


def _toy_spec(name="toy", **over):
    kw = dict(
        name=name,
        fpu=FPUSpec(depths={"mul": 3, "add": 2, "div": 9, "sqrt": 11},
                    t_p={"mul": 50.0, "add": 30.0, "div": 150.0,
                         "sqrt": 180.0},
                    t_o=0.8,
                    gamma={"mul": 0.4, "add": 0.4, "div": 0.7, "sqrt": 0.9}),
        memory=MemorySpec(hbm_bw=1e11, vmem_bytes=1 << 20, ici_bw=1e10),
        pe=PEGeometry(mxu=16, sublane=2, lane=16, vreg_budget=16,
                      peak_flops=1e12),
        power_area=PowerAreaSpec(
            pj_per_flop={"mul": 1.0, "add": 0.5, "div": 5.0, "sqrt": 6.0},
            pj_per_byte_hbm=20.0, static_w=2.0, area_mm2=10.0),
    )
    kw.update(over)
    return MachineSpec(**kw)


# ------------------------------ spec basics ---------------------------------

def test_tpu_like_matches_legacy_constants():
    """The default machine IS the historical constant set, field by field -
    the bit-identity contract of the refactor."""
    m = arch.get("tpu-like")
    assert m.pe.peak_flops == 197e12 == cd.PEAK_BF16_FLOPS
    assert m.memory.hbm_bw == 819e9 == cd.HBM_BW
    assert m.memory.ici_bw == 50e9 == cd.ICI_BW
    assert m.memory.vmem_bytes == 96 * 2 ** 20 == cd.VMEM_BYTES
    assert m.pe.mxu == 128 == cd.MXU
    assert m.pe.sublane == 8 == cd.SUBLANE
    assert m.pe.lane == 128 == cd.LANE
    assert m.fpu.add_latency == 6 == cd.VPU_ADD_LATENCY
    assert m.pe.vreg_budget == 64 == cd.VREG_BUDGET
    assert m.fpu.acc_overhead == 0.75 == cd.ACC_OVERHEAD
    assert m.memory.pipeline_fill_s == 2e-6 == cd.PIPELINE_FILL_S
    assert m.pe.mxu_clock == cd.MXU_CLOCK
    assert m.pe.vpu_flops == cd.VPU_FLOPS
    assert m.dtype_bytes() == 2          # native bfloat16


def test_spec_is_frozen_and_validated():
    m = _toy_spec()
    with pytest.raises(dataclasses.FrozenInstanceError):
        m.name = "other"
    with pytest.raises(ValueError):
        FPUSpec(depths={"mul": 1}, t_p={"mul": 1.0}, t_o=1.0,
                gamma={"mul": 0.5})              # missing op classes
    with pytest.raises(ValueError):
        _toy_spec(memory=MemorySpec(hbm_bw=-1.0, vmem_bytes=1, ici_bw=1.0))
    with pytest.raises(ValueError):
        _toy_spec(native_dtype="notadtype")
    with pytest.raises(ValueError):
        FPUSpec(depths={"mul": 0, "add": 2, "div": 9, "sqrt": 11},
                t_p={"mul": 1.0, "add": 1.0, "div": 1.0, "sqrt": 1.0},
                t_o=1.0,
                gamma={"mul": .5, "add": .5, "div": .5, "sqrt": .5})


def test_fpu_feeds_pipeline_model():
    """FPUSpec.tpi / p_opt are eq. 2 / eq. 3 at the spec's constants."""
    from repro.core import pipeline_model as pm
    fpu = arch.get("paper-pe").fpu
    got = float(fpu.tpi("div", 8, n_i=1e5, n_h=1e4))
    want = float(pm.tpi(8, n_i=1e5, n_h=1e4, gamma=fpu.gamma["div"],
                        t_p=fpu.t_p["div"], t_o=fpu.t_o))
    assert got == want
    popt = fpu.p_opt("div", n_i=1e5, n_h=1e4)
    assert popt == pytest.approx(
        float(np.sqrt(1e5 * fpu.t_p["div"] / (fpu.gamma["div"] * 1e4
                                              * fpu.t_o))), rel=1e-5)
    # hazard-free pipes: unbounded optimum (the multiplier's flat curve)
    assert np.isinf(fpu.p_opt("mul", n_i=1e5, n_h=0))
    pp = fpu.pipe_params("sqrt", 100, 99)
    assert pp.t_p == fpu.t_p["sqrt"] and pp.gamma == fpu.gamma["sqrt"]


def test_power_area_reproduces_paper_ratio_bands():
    """paper-pe vs tpu-like lands in the paper's comparison bands:
    1.1-1.5x in Gflops/W, 1.9-2.1x in Gflops/mm^2."""
    pe_ = arch.get("paper-pe")
    tpu = arch.get("tpu-like")
    gw = pe_.peak_gflops_per_w() / tpu.peak_gflops_per_w()
    mm = pe_.peak_gflops_per_mm2() / tpu.peak_gflops_per_mm2()
    assert 1.1 <= gw <= 1.5
    assert 1.9 <= mm <= 2.1


def test_watts_model_terms():
    m = _toy_spec()
    base = m.watts(0.0)
    assert base == m.power_area.static_w
    # FMA mix: (1.0 + 0.5)/2 pJ/flop -> 100 Gflops = 0.075 W dynamic
    assert m.watts(100.0) == pytest.approx(2.0 + 100.0 * 0.75e-3)
    assert m.watts(100.0, hbm_bytes_per_s=1e9) == pytest.approx(
        2.0 + 100.0 * 0.75e-3 + 1e9 * 20.0 * 1e-12)
    assert m.gflops_per_mm2(50.0) == pytest.approx(5.0)


def test_bench_metrics_fields():
    row = arch.bench_metrics(123.0)
    assert row["machine"] == "tpu-like"
    assert row["gflops"] == 123.0
    assert row["gflops_per_w"] > 0 and row["gflops_per_mm2"] > 0
    row2 = arch.bench_metrics(123.0, machine="paper-pe")
    assert row2["machine"] == "paper-pe"
    assert row2["gflops_per_w"] != row["gflops_per_w"]


# --------------------- JSON round-trip / corrupt / unknown ------------------

def test_json_roundtrip_in_memory():
    for name in arch.names():
        m = arch.get(name)
        blob = json.loads(json.dumps(m.to_json()))
        assert MachineSpec.from_json(blob) == m


def test_json_roundtrip_file(tmp_path):
    p = os.path.join(tmp_path, "machine.json")
    m = _toy_spec()
    m.save(p)
    assert MachineSpec.load(p) == m


def test_corrupt_file_raises_value_error(tmp_path):
    p = os.path.join(tmp_path, "bad.json")
    with open(p, "w") as f:
        f.write("{not json")
    with pytest.raises(ValueError):
        MachineSpec.load(p)
    # parseable JSON, wrong schema
    with open(p, "w") as f:
        json.dump({"schema": 999, "name": "x"}, f)
    with pytest.raises(ValueError):
        MachineSpec.load(p)
    # right schema, missing section
    blob = _toy_spec().to_json()
    del blob["fpu"]
    with open(p, "w") as f:
        json.dump(blob, f)
    with pytest.raises(ValueError):
        MachineSpec.load(p)
    # right schema, malformed field inside a section
    blob = _toy_spec().to_json()
    blob["memory"]["hbm_bw"] = -5.0
    with pytest.raises(ValueError):
        MachineSpec.from_json(blob)


def test_missing_file_raises_oserror(tmp_path):
    with pytest.raises(OSError):
        MachineSpec.load(os.path.join(tmp_path, "nope.json"))


def test_unknown_name_lists_registered():
    with pytest.raises(ValueError) as e:
        arch.get("not-a-machine")
    msg = str(e.value)
    assert "not-a-machine" in msg and "tpu-like" in msg


def test_register_and_overwrite():
    m = _toy_spec(name="test-register-machine")
    try:
        arch.register(m)
        assert arch.get("test-register-machine") == m
        with pytest.raises(ValueError):
            arch.register(_toy_spec(name="test-register-machine"))
        m2 = _toy_spec(name="test-register-machine",
                       native_dtype="float64")
        arch.register(m2, overwrite=True)
        assert arch.get("test-register-machine") == m2
        with pytest.raises(TypeError):
            arch.register("not-a-spec")
    finally:
        arch.registry._REGISTRY.pop("test-register-machine", None)


# --------------------------- ambient machine scope --------------------------

def test_machine_scope_nesting_and_default():
    assert arch.current_machine().name == "tpu-like"
    with arch.machine_scope("paper-pe"):
        assert arch.current_machine().name == "paper-pe"
        with arch.machine_scope("cpu-host"):
            assert arch.current_machine().name == "cpu-host"
        assert arch.current_machine().name == "paper-pe"
    assert arch.current_machine().name == "tpu-like"
    arch.set_default_machine("cpu-host")
    assert arch.current_machine().name == "cpu-host"
    with arch.machine_scope("paper-pe"):
        assert arch.current_machine().name == "paper-pe"
        with arch.machine_scope(None):      # None = back to process default
            assert arch.current_machine().name == "cpu-host"
    arch.set_default_machine(None)
    assert arch.current_machine().name == "tpu-like"


# ------------------- planners are machine-parameterized ---------------------

def test_shared_dtype_default_unified():
    """Satellite: one shared dtype-width default for every planner, derived
    from the machine's native dtype (no more 2-vs-4 split)."""
    tpu = arch.get("tpu-like")
    assert cd.resolve_dtype_bytes(machine=tpu) == 2          # bfloat16
    assert cd.resolve_dtype_bytes(machine=arch.get("paper-pe")) == 8
    assert cd.resolve_dtype_bytes(machine=arch.get("cpu-host")) == 4
    assert cd.resolve_dtype_bytes(dtype=jnp.float64, machine=tpu) == 8
    assert cd.resolve_dtype_bytes(dtype_bytes=4, machine=tpu) == 4
    # bare planner calls all agree with the explicit native width now
    g = cd.plan_gemm(512, 512, 512)
    assert (g.bm, g.bn, g.bk) == \
        (lambda p: (p.bm, p.bn, p.bk))(cd.plan_gemm(512, 512, 512,
                                                    dtype_bytes=2))
    t = cd.plan_trsm(512, 8)
    assert t.block == cd.plan_trsm(512, 8, dtype_bytes=2).block
    f = cd.plan_factorization(512)
    assert f.block == cd.plan_factorization(512, dtype_bytes=2).block


def test_planners_change_with_machine():
    big = (2048, 2048, 2048)
    p_tpu = cd.plan_gemm(*big, dtype_bytes=4)
    p_pe = cd.plan_gemm(*big, dtype_bytes=4, machine=arch.get("paper-pe"))
    # paper-pe: 32-wide systolic edge, 4 MiB scratch -> smaller tiles
    assert (p_pe.bm, p_pe.bn, p_pe.bk) != (p_tpu.bm, p_tpu.bn, p_tpu.bk)
    assert p_pe.bm % 32 == 0 and p_pe.vmem_bytes <= 4 * 2 ** 20
    assert p_tpu.ridge != p_pe.ridge
    # factorization panel widths respond to the machine's chain depths
    f_tpu = cd.plan_factorization(2048, kind="potrf", dtype_bytes=8)
    f_pe = cd.plan_factorization(2048, kind="potrf", dtype_bytes=8,
                                 machine=arch.get("paper-pe"))
    assert f_pe.modeled_time != f_tpu.modeled_time
    # ambient scoping reaches planners with no kwargs at all
    with arch.machine_scope("paper-pe"):
        assert cd.plan_gemm(*big, dtype_bytes=4) == p_pe


def test_pdgemm_plan_uses_machine_ici():
    p_tpu = cd.plan_pdgemm(4096, 4096, 4096, 2, 2, dtype_bytes=4)
    p_pe = cd.plan_pdgemm(4096, 4096, 4096, 2, 2, dtype_bytes=4,
                          machine=arch.get("paper-pe"))
    assert p_pe.collective_bytes == p_tpu.collective_bytes   # same wire bytes
    assert p_pe.collective_s > p_tpu.collective_s            # slower links


# ------------------- tuner keys / resolve / end-to-end ----------------------

def test_registry_machine_key_component(tmp_path):
    reg = Registry(path=os.path.join(tmp_path, "r.json"))
    reg.record("gemm", (64, 64, 64), jnp.float32, "cpu",
               {"bm": 128, "bn": 128, "bk": 128})
    reg.record("gemm", (64, 64, 64), jnp.float32, "cpu",
               {"bm": 32, "bn": 32, "bk": 32}, machine="paper-pe")
    # namespaces are disjoint
    assert reg.lookup("gemm", (64, 64, 64), jnp.float32,
                      "cpu").params["bm"] == 128
    assert reg.lookup("gemm", (64, 64, 64), jnp.float32, "cpu",
                      machine="paper-pe").params["bm"] == 32
    # key format: default omits the component (old files resolve unchanged)
    assert make_key("gemm", (64, 64, 64), jnp.float32, "cpu") == \
        "gemm|64x64x64|float32|cpu"
    assert make_key("gemm", (64, 64, 64), jnp.float32, "cpu",
                    machine="paper-pe") == \
        "gemm|64x64x64|float32|cpu|m:paper-pe"
    assert make_key("pdgemm", (64, 64, 64), jnp.float32, "cpu",
                    mesh="x2y2", machine="paper-pe") == \
        "pdgemm|64x64x64|float32|cpu|x2y2|m:paper-pe"
    # round-trips through the file with the machine component intact
    path = reg.save()
    reloaded = Registry(path=path)
    assert reloaded.lookup("gemm", (64, 64, 64), jnp.float32, "cpu",
                           machine="paper-pe").params["bm"] == 32


def test_resolve_scopes_registry_by_machine(tmp_path):
    reg = Registry(path=os.path.join(tmp_path, "r.json"))
    import jax
    backend = jax.default_backend()
    reg.record("gemm", (64, 64, 64), jnp.float32, backend,
               {"bm": 256, "bn": 256, "bk": 256})
    # default machine: hit
    r = tune.resolve("gemm", (64, 64, 64), jnp.float32, policy="tuned",
                     registry=reg)
    assert r.source == "registry" and r.machine == "tpu-like"
    # non-default machine: its namespace is empty -> model fallback
    r_pe = tune.resolve("gemm", (64, 64, 64), jnp.float32, policy="tuned",
                        registry=reg, machine=arch.get("paper-pe"))
    assert r_pe.source == "fallback-model" and r_pe.machine == "paper-pe"
    # tune a machine-scoped entry and hit it
    from repro.tune import search
    search.seed_registry_from_model(reg, gemm_shapes=[(64, 64, 64)],
                                    backend=backend,
                                    machine=arch.get("paper-pe"))
    r_pe2 = tune.resolve("gemm", (64, 64, 64), jnp.float32, policy="tuned",
                         registry=reg, machine=arch.get("paper-pe"))
    assert r_pe2.source == "registry"


def test_linalg_use_machine_end_to_end():
    """Acceptance: linalg.use(machine=...) changes planner/tuner decisions
    end-to-end, while the default context resolves exactly as before."""
    shape = (2048, 2048, 2048)
    r_default = tune.resolve("gemm", shape, jnp.float32, policy="model")
    with linalg.use(machine=arch.get("paper-pe")):
        # context machine only binds inside routine bodies; emulate the
        # routine's scope entry the way _machine_scoped does
        ctx = linalg.get_context()
        from repro.linalg.context import resolved_machine
        with arch.machine_scope(resolved_machine(ctx)):
            r_pe = tune.resolve("gemm", shape, jnp.float32, policy="model")
    assert r_default.machine == "tpu-like" and r_pe.machine == "paper-pe"
    cfg_d = (r_default.gemm_plan.bm, r_default.gemm_plan.bn,
             r_default.gemm_plan.bk)
    cfg_p = (r_pe.gemm_plan.bm, r_pe.gemm_plan.bn, r_pe.gemm_plan.bk)
    assert cfg_d != cfg_p
    # and the default context is untouched afterwards
    r_after = tune.resolve("gemm", shape, jnp.float32, policy="model")
    assert r_after == r_default


def test_linalg_machine_context_numerics_and_describe(rng=None):
    """Execution under a non-default machine keeps numerics (same kernel,
    different tiling) and the context describes its machine."""
    rng = np.random.default_rng(7)
    a = jnp.asarray(rng.normal(size=(96, 96)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(96, 96)).astype(np.float32))
    want = np.asarray(a, dtype=np.float64) @ np.asarray(b, dtype=np.float64)
    got_default = linalg.gemm(a, b, context=dict(policy="model"))
    with linalg.use(policy="model", machine="paper-pe") as ctx:
        assert ctx.describe()["machine"] == "paper-pe"
        got_pe = linalg.gemm(a, b)
    np.testing.assert_allclose(np.asarray(got_pe), want, rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(got_default), want, rtol=2e-4,
                               atol=2e-4)
    assert linalg.get_context().describe()["machine"] == "tpu-like"


def test_machine_name_string_in_context_validated():
    with pytest.raises(ValueError):
        linalg.ExecutionContext(machine="definitely-not-registered")
    with pytest.raises(ValueError):
        linalg.ExecutionContext(machine=123)


def test_compat_context_pins_default_machine():
    """Deprecation shims stay machine-agnostic: their pinned context maps
    to the process-default machine even inside use(machine=...)."""
    from repro.linalg.context import compat_context, resolved_machine
    with linalg.use(machine="paper-pe"):
        ctx = compat_context(policy="reference").over(linalg.get_context())
        assert ctx.machine is None
        assert resolved_machine(ctx) is None


def test_cholesky_trailing_updates_see_context_machine(tmp_path):
    """The machine scope wraps the whole routine body: the trailing-update
    GEMMs inside a blocked factorization resolve under ctx.machine (probed
    via the machine-scoped registry namespace)."""
    import jax
    reg = Registry(path=os.path.join(tmp_path, "r.json"))
    rng = np.random.default_rng(3)
    m = rng.normal(size=(48, 48)).astype(np.float32)
    spd = jnp.asarray(m @ m.T + 48 * np.eye(48, dtype=np.float32))
    with linalg.use(policy="tuned", registry=reg, machine="paper-pe"):
        l = linalg.cholesky(spd, block=16)
    np.testing.assert_allclose(np.asarray(l @ l.T), np.asarray(spd),
                               rtol=2e-4, atol=2e-4)
    # the trailing updates resolved under paper-pe: verify by resolving the
    # same trailing shape in both namespaces against a seeded registry
    backend = jax.default_backend()
    from repro.tune import search
    search.seed_registry_from_model(reg, gemm_shapes=[(32, 16, 16)],
                                    backend=backend,
                                    machine=arch.get("paper-pe"))
    r = tune.resolve("gemm", (32, 16, 16), jnp.float32, policy="tuned",
                     registry=reg, machine=arch.get("paper-pe"))
    assert r.source == "registry"
    r_def = tune.resolve("gemm", (32, 16, 16), jnp.float32, policy="tuned",
                         registry=reg)
    assert r_def.source == "fallback-model"
