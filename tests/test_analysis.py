"""repro.analysis: seeded violations, clean sweep, suppression fallbacks.

Four contracts under test:

1. **Every frozen rule ID is live**: one deliberately-bad kernel or
   routine per rule (KL001-KL004, DF001-DF004, CM001-CM003) that *must*
   fire - a rule that cannot fire is dead weight the allowlist would
   happily "suppress" forever.
2. **The real surface is clean**: a no-mesh ``check_surface`` sweep of
   ``linalg.__all__`` produces zero findings (errors *and* warnings);
   the full policy x dtype x mesh grid is CI's job
   (``scripts/check_static_analysis.py``).
3. **Suppression records, never deletes**: ``allow()`` and allowlist
   hits land in ``report.suppressed`` with their suppressor tagged;
   a corrupt allowlist warns once per path and re-fires its findings
   (the registry convention); a missing one is silently empty.
4. **The PR 9 kernel fixes hold**: zero-dim operands route
   ``flash_attention.attention`` / ``ssd_scan.ssd_scan`` to the jnp
   fallback - no Pallas launch in the trace, exact zeros at runtime.
"""
import json
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax
from jax.experimental import pallas as pl

from repro import analysis, linalg
from repro.analysis import rules as _rules


def _f32(*shape, seed=0):
    return np.random.default_rng(seed).standard_normal(shape).astype(
        np.float32)


def _rule_ids(report):
    return sorted({f.rule for f in report.findings})


# --------------------------- frozen vocabulary ------------------------------

def test_rule_vocabulary_frozen():
    expect = {"KL001": "error", "KL002": "error", "KL003": "error",
              "KL004": "error", "DF001": "error", "DF002": "error",
              "DF003": "warn", "DF004": "error", "CM001": "error",
              "CM002": "warn", "CM003": "warn",
              "CC001": "error", "CC002": "error", "CC003": "error",
              "SH001": "error", "SH002": "error", "SH003": "warn",
              "BY001": "error"}
    assert {r.id: r.severity for r in analysis.RULES.values()} == expect
    # IDs are the dict keys, in family order
    assert list(analysis.RULES) == list(expect)


# ------------------------ seeded kernel-launch bugs -------------------------

def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2


def test_kl001_block_does_not_divide():
    def bad_block(x):
        return pl.pallas_call(
            _copy_kernel, grid=(3,),
            in_specs=[pl.BlockSpec((16, 128), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((16, 128), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            interpret=True)(x)

    rep = analysis.check(bad_block, _f32(40, 128))   # 16 does not divide 40
    assert "KL001" in _rule_ids(rep) and not rep.ok


def test_kl002_vmem_budget_exceeded():
    def vmem_hog(x):                       # full-array blocks: 2 operands
        n = x.shape[0]                     # x 2 x 64 MB = 256 MB > 96 MB
        return pl.pallas_call(
            _copy_kernel, grid=(1,),
            in_specs=[pl.BlockSpec((n, n), lambda i: (0, 0))],
            out_specs=pl.BlockSpec((n, n), lambda i: (0, 0)),
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            interpret=True)(x)

    rep = analysis.check(vmem_hog,
                         jax.ShapeDtypeStruct((4096, 4096), jnp.float32))
    assert "KL002" in _rule_ids(rep) and not rep.ok


def test_kl003_int64_index_inside_kernel():
    def i64_kernel(x_ref, o_ref):
        idx = lax.broadcasted_iota(jnp.int64, x_ref.shape, 0)
        o_ref[...] = x_ref[...] + idx.astype(x_ref.dtype)

    def launch(x):
        return pl.pallas_call(
            i64_kernel,
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            interpret=True)(x)

    rep = analysis.check(launch, _f32(8, 128))
    assert "KL003" in _rule_ids(rep) and not rep.ok


def test_kl004_zero_dim_reaches_kernel():
    def no_fallback(x):                    # the PR 8 _gemm_exec bug class
        return pl.pallas_call(
            _copy_kernel,
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            interpret=True)(x)

    rep = analysis.check(no_fallback, np.zeros((0, 8), np.float32))
    assert "KL004" in _rule_ids(rep) and not rep.ok


# --------------------- seeded plan-view (registry) bugs ---------------------

def _poisoned_registry(tmp_path, params):
    from repro.tune.registry import Registry
    reg = Registry(path=str(tmp_path / "reg.json"))
    # surface gemm: a (48, 32) @ b (32, 64) -> lookup shape (m, n, k)
    reg.record("gemm", (48, 64, 32), jnp.float32, jax.default_backend(),
               params, source="test")
    return reg


def test_kl001_plan_tile_misaligned(tmp_path):
    reg = _poisoned_registry(tmp_path, {"bm": 100, "bn": 128, "bk": 128})
    with linalg.use(policy="tuned", registry=reg):
        rep = analysis.check(linalg.gemm, _f32(48, 32), _f32(32, 64),
                             drift=False, retrace=False)
    assert "KL001" in _rule_ids(rep)       # 100 % sublane(8) != 0


def test_kl002_plan_vmem_exceeded(tmp_path):
    reg = _poisoned_registry(tmp_path,
                             {"bm": 4096, "bn": 4096, "bk": 4096})
    with linalg.use(policy="tuned", registry=reg):
        rep = analysis.check(linalg.gemm, _f32(48, 32), _f32(32, 64),
                             drift=False, retrace=False)
    assert "KL002" in _rule_ids(rep)       # ~335 MB plan vs 96 MB budget


# -------------------------- seeded dtype-flow bugs --------------------------

def test_df001_silent_f64_promotion():
    def silent_f64(x):                     # jnp.zeros defaults to f64
        return x + jnp.zeros(x.shape)      # under the x64 lint mode

    rep = analysis.check(silent_f64, _f32(8, 8))
    assert "DF001" in _rule_ids(rep) and not rep.ok


def test_df002_narrow_accumulator_for_f64():
    def narrow_accum(a, b):
        return lax.dot(a, b, preferred_element_type=jnp.float32)

    a = np.zeros((8, 8), np.float64)
    rep = analysis.check(narrow_accum, a, a)
    assert "DF002" in _rule_ids(rep) and not rep.ok


def test_df003_convert_roundtrip():
    def roundtrip(x):
        return x.astype(jnp.bfloat16).astype(jnp.float32) * 2.0

    rep = analysis.check(roundtrip, _f32(8, 8))
    assert "DF003" in _rule_ids(rep)
    assert rep.ok                          # warn severity: gate still green


def test_df004_host_callback():
    def host_call(x):
        return jax.pure_callback(
            lambda v: v, jax.ShapeDtypeStruct(x.shape, x.dtype), x)

    rep = analysis.check(host_call, _f32(4, 4))
    assert "DF004" in _rule_ids(rep) and not rep.ok


# ------------------------- seeded cost-model drift --------------------------

def test_cm001_cm002_annotation_drift():
    rep = analysis.check(lambda a, b: a @ b, _f32(32, 32), _f32(32, 32),
                         info=lambda a, b: {"flops": 1, "bytes": 1},
                         retrace=False)
    ids = _rule_ids(rep)
    assert "CM001" in ids and "CM002" in ids
    assert not rep.ok                      # CM001 is an error


def test_cm003_retrace_instability():
    state = {"n": 0}

    def unstable(x):                       # new constant baked per trace
        state["n"] += 1
        return x * float(state["n"])

    rep = analysis.check(unstable, _f32(8,), drift=False)
    assert "CM003" in _rule_ids(rep)
    assert rep.ok                          # warn severity


# ------------------------------- clean sweep --------------------------------

def test_surface_sweep_is_silent():
    rep = analysis.check_surface(dtypes=("float32",), mesh=None)
    assert rep.findings == [], rep.summary()
    assert rep.suppressed == []
    # every public routine with synthesizable args was actually traced
    assert {c["routine"] for c in rep.cases} == set(
        analysis.surface_routines())
    assert len(rep.cases) == 3 * len(analysis.surface_routines())


def test_surface_mesh_leg_records_skip():
    rep = analysis.check_surface(routines=["gemm"],
                                 policies=("reference",),
                                 dtypes=("float32",), mesh=(64, 64))
    skips = [c for c in rep.cases if "skipped" in c]
    assert skips and "4096 devices" in skips[0]["skipped"]
    assert rep.ok


# ----------------------- suppression and allowlists -------------------------

def _host_call(x):
    return jax.pure_callback(
        lambda v: v, jax.ShapeDtypeStruct(x.shape, x.dtype), x)


def test_allow_roundtrip_records_suppression():
    with analysis.allow("DF004"):
        rep = analysis.check(_host_call, _f32(4, 4))
    assert rep.ok and rep.findings == []
    assert [f.rule for f in rep.suppressed] == ["DF004"]
    assert rep.suppressed[0].suppressed
    assert rep.suppressed[0].suppressed_by == "allow()"
    # serialized form carries the suppression provenance
    blob = rep.to_json()
    assert blob["suppressed"][0]["suppressed_by"] == "allow()"


def test_allow_is_routine_scoped():
    with analysis.allow("DF004", routine="some_other_routine"):
        rep = analysis.check(_host_call, _f32(4, 4))
    assert not rep.ok and _rule_ids(rep) == ["DF004"]


def test_allow_rejects_unknown_rule_id():
    with pytest.raises(KeyError, match="XX999"):
        with analysis.allow("XX999"):
            pass


def test_allowlist_file_roundtrip(tmp_path):
    path = tmp_path / "allow.json"
    path.write_text(json.dumps({
        "schema_version": 1,
        "allow": [{"rule": "DF004", "reason": "seeded fixture"}]}))
    al = analysis.load_allowlist(str(path))
    rep = analysis.check(_host_call, _f32(4, 4), allowlist=al)
    assert rep.ok and rep.findings == []
    assert rep.suppressed[0].suppressed_by == f"allowlist:{path}"


def test_allowlist_missing_file_is_silently_empty(tmp_path):
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        al = analysis.load_allowlist(str(tmp_path / "absent.json"))
    assert al.entries == ()


def test_allowlist_corrupt_warns_once_and_refires(tmp_path):
    path = tmp_path / "corrupt.json"
    path.write_text("{not json")
    with pytest.warns(RuntimeWarning, match="corrupt"):
        al = analysis.load_allowlist(str(path))
    assert al.entries == ()
    with warnings.catch_warnings():        # once per path, registry-style
        warnings.simplefilter("error")
        analysis.load_allowlist(str(path))
    # a broken allowlist must re-fire, never hide, its findings
    rep = analysis.check(_host_call, _f32(4, 4), allowlist=al)
    assert not rep.ok and _rule_ids(rep) == ["DF004"]


def test_allowlist_unknown_rule_is_corrupt(tmp_path):
    path = tmp_path / "unknown_rule.json"
    path.write_text(json.dumps({
        "schema_version": 1, "allow": [{"rule": "ZZ123"}]}))
    with pytest.warns(RuntimeWarning, match="ZZ123"):
        al = analysis.load_allowlist(str(path))
    assert al.entries == ()


# --------------------------- report serialization ---------------------------

def test_report_json_schema(tmp_path):
    rep = analysis.check(_host_call, _f32(4, 4))
    blob = rep.to_json()
    assert set(blob) == {"schema_version", "target", "cases", "findings",
                         "suppressed"}
    assert blob["schema_version"] == _rules.SCHEMA_VERSION
    f = blob["findings"][0]
    assert f["rule"] == "DF004" and f["severity"] == "error"
    assert not f["suppressed"]
    out = tmp_path / "report.json"
    rep.save(str(out))
    assert json.loads(out.read_text())["target"] == rep.target


# ---------------------- PR 9 kernel zero-dim guards -------------------------

def test_attention_zero_dim_routes_to_fallback():
    from repro.kernels.flash_attention import attention
    q = jnp.zeros((2, 2, 0, 16), jnp.float32)
    kv = jnp.zeros((2, 2, 0, 16), jnp.float32)
    rep = analysis.check(attention, q, kv, kv)
    assert rep.ok and rep.findings == [], rep.summary()
    out = attention(q, kv, kv)
    assert out.shape == q.shape


def test_attention_zero_kv_axis_is_exact_zeros():
    from repro.kernels.flash_attention import attention
    q = jnp.asarray(_f32(1, 1, 8, 16))
    kv = jnp.zeros((1, 1, 0, 16), jnp.float32)
    rep = analysis.check(attention, q, kv, kv)
    assert rep.ok and rep.findings == [], rep.summary()
    out = attention(q, kv, kv)             # empty KV: safe-divide zeros
    assert out.shape == q.shape
    np.testing.assert_array_equal(np.asarray(out), 0.0)


def test_ssd_scan_zero_dim_routes_to_fallback():
    from repro.kernels.ssd_scan import ssd_scan
    x = jnp.zeros((2, 2, 0, 4), jnp.float32)
    a_log = jnp.zeros((2, 2, 0), jnp.float32)
    bc = jnp.zeros((2, 2, 0, 4), jnp.float32)
    rep = analysis.check(ssd_scan, x, a_log, bc, bc)
    assert rep.ok and rep.findings == [], rep.summary()
    out = ssd_scan(x, a_log, bc, bc)
    assert out.shape == x.shape
