"""Regression tests for the beyond-paper optimizations (EXPERIMENTS §Perf)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.models import moe as moe_mod
from repro.models import model_zoo as zoo
from repro.models.config import ModelConfig


@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2)])
@pytest.mark.parametrize("s,w", [(256, 32), (192, 64), (130, 16)])
def test_banded_attention_exact(rng, hq, hkv, s, w):
    q = jnp.asarray(rng.normal(size=(2, hq, s, 16)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, hkv, s, 16)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, hkv, s, 16)).astype(np.float32))
    want = ref.attention(q, k, v, causal=True, window=w)
    got = ref.banded_attention(q, k, v, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=5e-5)


def test_banded_attention_grad_finite(rng):
    q = jnp.asarray(rng.normal(size=(1, 2, 64, 8)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 2, 64, 8)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 2, 64, 8)).astype(np.float32))
    g = jax.grad(lambda q: jnp.sum(ref.banded_attention(q, k, v, 16) ** 2))(q)
    assert bool(jnp.all(jnp.isfinite(g)))


@given(seed=st.integers(0, 10_000), s=st.integers(33, 200),
       wexp=st.integers(3, 6))
@settings(max_examples=10, deadline=None)
def test_property_banded_matches_masked(seed, s, wexp):
    w = 1 << wexp
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(1, 2, s, 8)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 2, s, 8)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 2, s, 8)).astype(np.float32))
    a = ref.attention(q, k, v, causal=True, window=w)
    b = ref.banded_attention(q, k, v, w)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_grouped_moe_matches_flat_no_drop(rng):
    cfg = ModelConfig("m", "moe", n_layers=1, d_model=32, n_heads=2, n_kv=1,
                      d_ff=64, vocab=64, n_experts=8, top_k=2, d_expert=64,
                      capacity_factor=8.0, dtype="float32")
    params = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.normal(size=(4, 16, 32)).astype(np.float32))
    y1, _ = moe_mod.apply_moe(params, x, cfg)
    y2, _ = moe_mod.apply_moe(params, x,
                              dataclasses.replace(cfg, moe_grouped=True))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-5)


def test_moe_dropping_bounded(rng):
    """With a tight capacity factor, outputs stay finite and bounded (drops
    zero out, never corrupt)."""
    cfg = ModelConfig("m", "moe", n_layers=1, d_model=32, n_heads=2, n_kv=1,
                      d_ff=64, vocab=64, n_experts=4, top_k=2, d_expert=64,
                      capacity_factor=0.5, dtype="float32", moe_grouped=True)
    params = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.normal(size=(2, 32, 32)).astype(np.float32))
    y, aux = moe_mod.apply_moe(params, x, cfg)
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(jnp.max(jnp.abs(y))) < 1e3
    assert np.isfinite(float(aux))


def test_hybrid_cond_decode_consistency():
    """The lax.cond routing must keep decode == forward (hymba cell)."""
    cfg = ModelConfig("hyb", "hybrid", n_layers=2, d_model=64, n_heads=4,
                      n_kv=2, d_ff=128, vocab=128, ssm_state=16,
                      ssm_head_dim=16, window=8, global_layers=(0,),
                      dtype="float32")
    params = zoo.init(jax.random.PRNGKey(1), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0, 128)
    full, _ = zoo.forward(params, {"tokens": toks}, cfg)
    caches = zoo.init_caches(params, cfg, 2, 16, dtype=jnp.float32)
    dec = []
    for t in range(12):
        lg, caches = zoo.decode_step(params, toks[:, t:t + 1], cfg, caches,
                                     jnp.int32(t))
        dec.append(lg[:, 0])
    err = float(jnp.max(jnp.abs(jnp.stack(dec, 1) - full)))
    assert err < 1e-4


def test_remat_policies_same_loss():
    """remat full/dots/none change memory, never the math."""
    base = ModelConfig("t", "dense", n_layers=2, d_model=64, n_heads=4,
                       n_kv=2, d_ff=128, vocab=97, dtype="float32")
    toks = jax.random.randint(jax.random.PRNGKey(0), (2, 16), 0, 97)
    params = zoo.init(jax.random.PRNGKey(1), base)
    outs = []
    for pol in ("full", "dots", "none"):
        cfg = dataclasses.replace(base, remat_policy=pol)
        loss = jax.grad(lambda p: jnp.sum(
            zoo.forward(p, {"tokens": toks}, cfg)[0].astype(jnp.float32) ** 2
        ).astype(jnp.float32))(params)
        outs.append(loss["embed"]["table"])
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(outs[1]),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(outs[2]),
                               rtol=1e-4, atol=1e-4)
