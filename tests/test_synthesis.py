"""Paper Tables 1-2 and the abstract's efficiency claims."""
import numpy as np
import pytest

from repro.core import synthesis as syn


def test_table2_derivation():
    r = syn.check_table2()
    assert max(r["checked"].values()) < 0.06
    # the paper-internal inconsistency: LAP-PE GFlops/W below 0.95 GHz does
    # not follow from the paper's own Table 1 (recorded, not hidden)
    assert set(r["discrepant"]) <= {"lap_w@0.95", "lap_w@0.33", "lap_w@0.2"}


def test_area_efficiency_claim():
    """Abstract: 1.9x-2.1x GFlops/mm^2. Derived ratios: 2.10-2.17."""
    ratios = syn.efficiency_ratios()["gflops_per_mm2"]
    for speed, r in ratios.items():
        assert 1.9 <= r <= 2.2, (speed, r)


def test_power_efficiency_claim_range():
    """Abstract claims 1.1-1.5x GFlops/W; Table 2 itself spans 0.95-1.66x.
    We assert the *published-table* ratios (what is reproducible)."""
    pub = syn.TABLE2_PUBLISHED
    ratios = {s: v[3] / v[1] for s, v in pub.items()}
    assert min(ratios.values()) == pytest.approx(0.951, abs=0.01)
    assert max(ratios.values()) == pytest.approx(1.660, abs=0.01)
    # and the paper's conclusion holds: PE wins at low frequency
    assert ratios[0.20] > 1.5 and ratios[0.33] > 1.4


def test_gflops_model():
    lap = [p for p in syn.TABLE1 if p.design == "lap-pe"][0]
    assert lap.gflops == pytest.approx(2 * 1.81)
    pe_ = [p for p in syn.TABLE1 if p.design == "pe"][0]
    assert pe_.gflops == pytest.approx(7 * 1.81)


def test_power_model_fit():
    for design in ("lap-pe", "pe"):
        m = syn.fit_power_model(design)
        pts = [p for p in syn.TABLE1 if p.design == design]
        for p in pts:
            pred = m.power_mw(p.speed_ghz)
            assert pred == pytest.approx(p.total_mw, rel=0.35), (design, p)
        # monotone increasing in frequency
        fs = np.linspace(0.1, 2.0, 20)
        ps = [m.power_mw(f) for f in fs]
        assert all(b >= a for a, b in zip(ps, ps[1:]))


def test_energy_per_flop_sane():
    e = syn.energy_per_flop_pj("pe", 0.2)
    # double-precision flops at 28nm-ish: O(1-20) pJ
    assert 0.5 < e < 50
