"""Every d-prefixed shim must warn (once, at the caller) and stay bitwise
identical to its repro.linalg equivalent under the default context.

This module runs with DeprecationWarnings escalated to errors (the
``filterwarnings`` mark below - `scripts/ci_check.sh` runs it as a
dedicated step), so a shim that warns *twice*, or any stray deprecation
path in the library, fails loudly. ``pytest.warns`` captures the expected
first warning of each routine.
"""
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro import blas, linalg
from repro.blas import _deprecated
from repro.tune import policy as tune_policy

pytestmark = pytest.mark.filterwarnings("error::DeprecationWarning")


@pytest.fixture(autouse=True)
def _fresh_warning_state():
    """Each test sees shims that have not warned yet (context reset is
    the shared conftest autouse fixture)."""
    _deprecated.reset_warned()
    yield
    _deprecated.reset_warned()


def _mk(rng, shape, dtype=np.float32):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32)).astype(dtype)


def _pairs(rng):
    """(shim name, shim call thunk, linalg call thunk) for every shim."""
    x, y = _mk(rng, 33), _mk(rng, 33)
    a, b = _mk(rng, (12, 8)), _mk(rng, (8, 10))
    c = _mk(rng, (12, 10))
    sq = _mk(rng, (12, 12))
    t = jnp.tril(sq) + 4 * jnp.eye(12)
    rhs = _mk(rng, (12, 3))
    u7 = _mk(rng, 8)
    g1, g2 = _mk(rng, 12), _mk(rng, 10)
    return [
        ("ddot", lambda: blas.ddot(x, y, schedule="strided"),
         lambda: linalg.dot(x, y, schedule="strided")),
        ("daxpy", lambda: blas.daxpy(1.5, x, y),
         lambda: linalg.axpy(1.5, x, y)),
        ("dscal", lambda: blas.dscal(-2.0, x),
         lambda: linalg.scal(-2.0, x)),
        ("dnrm2", lambda: blas.dnrm2(x), lambda: linalg.nrm2(x)),
        ("dasum", lambda: blas.level1.dasum(x), lambda: linalg.asum(x)),
        ("idamax", lambda: blas.idamax(x), lambda: linalg.iamax(x)),
        ("drot", lambda: blas.level1.drot(x, y, 0.6, 0.8)[0],
         lambda: linalg.rot(x, y, 0.6, 0.8)[0]),
        ("dgemv", lambda: blas.dgemv(a, u7, alpha=1.5),
         lambda: linalg.gemv(a, u7, alpha=1.5)),
        ("dger", lambda: blas.dger(0.5, g1, g2, c),
         lambda: linalg.ger(0.5, g1, g2, c)),
        ("dtrsv", lambda: blas.dtrsv(t, x[:12]),
         lambda: linalg.trsv(t, x[:12])),
        ("dgemm", lambda: blas.dgemm(a, b, c=c, alpha=2.0, beta=-1.0),
         lambda: linalg.gemm(a, b, c=c, alpha=2.0, beta=-1.0)),
        ("dsyrk", lambda: blas.dsyrk(a, lower=False),
         lambda: linalg.syrk(a, lower=False)),
        ("dtrsm", lambda: blas.dtrsm(t, rhs, block=4),
         lambda: linalg.trsm(t, rhs, block=4)),
    ]


def test_every_shim_warns_once_and_is_bitwise_identical(rng):
    for name, old, new in _pairs(rng):
        _deprecated.reset_warned()
        with pytest.warns(DeprecationWarning,
                          match=rf"repro\.blas\.{name} is deprecated"):
            got = old()
        want = new()
        assert np.array_equal(np.asarray(got), np.asarray(want)), name
        # second call: silent (once-per-routine). filterwarnings=error
        # would raise here if the shim warned again.
        got2 = old()
        assert np.array_equal(np.asarray(got2), np.asarray(want)), name


def test_warning_points_at_caller(rng):
    a, b = _mk(rng, (6, 4)), _mk(rng, (4, 5))
    with pytest.warns(DeprecationWarning) as rec:
        blas.dgemm(a, b)
    ours = [w for w in rec.list if "repro.blas.dgemm" in str(w.message)]
    assert ours and ours[0].filename == __file__, \
        "stacklevel must point at the shim's caller"


def test_shims_follow_policy_kwargs_bitwise(rng):
    """Old policy/use_kernel kwargs keep their exact semantics through
    the shim -> linalg bridge (kernel path included)."""
    a, b = _mk(rng, (24, 12)), _mk(rng, (12, 18))
    with pytest.warns(DeprecationWarning):
        old_model = blas.dgemm(a, b, policy="model")
    new_model = linalg.gemm(a, b, context=dict(policy="model"))
    assert np.array_equal(np.asarray(old_model), np.asarray(new_model))
    # use_kernel alias: its own DeprecationWarning + model-path numerics
    tune_policy._warned_use_kernel = False
    with pytest.warns(DeprecationWarning, match="use_kernel is deprecated"):
        old_uk = blas.dgemm(a, b, use_kernel=True)
    assert np.array_equal(np.asarray(old_uk), np.asarray(new_model))
    tune_policy._warned_use_kernel = True  # leave the once-flag quiet


def test_use_pallas_alias_warns_and_maps(rng):
    """The older use_pallas spelling is a warned alias too, with exactly
    use_kernel's semantics (True == policy='model')."""
    a, b = _mk(rng, (24, 12)), _mk(rng, (12, 18))
    want = linalg.gemm(a, b, context=dict(policy="model"))
    tune_policy._warned_use_pallas = False
    with pytest.warns(DeprecationWarning) as rec:   # dgemm shim warns too
        got = blas.dgemm(a, b, use_pallas=True)
    assert any("use_pallas is deprecated" in str(w.message) for w in rec)
    assert np.array_equal(np.asarray(got), np.asarray(want))
    assert tune_policy.resolve_policy(None, None, False) == "reference"
    assert tune_policy.resolve_policy("tuned", None, True) == "tuned"
    tune_policy._warned_use_pallas = True


def test_shims_ignore_active_accum_dtype(rng):
    """Level-1/2 shims pin accum_dtype=None: an active accumulation
    context must not change a deprecated call's numerics."""
    x = _mk(rng, 2048, jnp.bfloat16)
    y = _mk(rng, 2048, jnp.bfloat16)
    from repro.blas import level1
    want = level1.dot(x, y, schedule="sequential")   # operand-dtype core
    with linalg.use(accum_dtype=jnp.float32):
        with pytest.warns(DeprecationWarning):
            got = blas.ddot(x, y, schedule="sequential")
    assert np.array_equal(np.asarray(got), np.asarray(want))
    m = _mk(rng, (6, 4), jnp.bfloat16)
    v = _mk(rng, 4, jnp.bfloat16)
    want_v = level1.axpy(0.5, v, v)
    with linalg.use(accum_dtype=jnp.float32):
        with pytest.warns(DeprecationWarning):
            got_v = blas.daxpy(0.5, v, v)
        with pytest.warns(DeprecationWarning):
            got_g = blas.dger(1.0, m[:, 0], v, m)
    assert np.array_equal(np.asarray(got_v), np.asarray(want_v))
    assert got_g.dtype == jnp.bfloat16


def test_shims_ignore_active_mesh_context(rng):
    """Deprecated routines stay local (mesh pinned to None) even under a
    mesh-bearing context - their pre-linalg contract."""
    a, b = _mk(rng, (8, 6)), _mk(rng, (6, 7))
    want = linalg.gemm(a, b)
    with linalg.use(mesh=(2, 2)):   # no devices needed: shim must not route
        with pytest.warns(DeprecationWarning):
            got = blas.dgemm(a, b)
    assert np.array_equal(np.asarray(got), np.asarray(want))
