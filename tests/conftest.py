"""Shared fixtures. NOTE: no XLA_FLAGS here - smoke tests and benches must
see 1 device; only launch/dryrun forces 512 placeholder devices (and tests
that need a few devices spawn a subprocess - see test_distributed.py)."""
import os
import sys

try:  # the image may lack hypothesis: fall back to the deterministic shim
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_shim
    _hypothesis_shim.install()

import jax
import numpy as np
import pytest


# dtypes the dtype-generic repro.linalg grids run in-process (float64
# needs JAX_ENABLE_X64 and runs in tests/test_linalg.py's subprocess)
LINALG_DTYPES = [np.float32, jax.numpy.bfloat16]


@pytest.fixture(autouse=True)
def _default_linalg_context():
    """Every test starts and ends on the library-default ExecutionContext
    (a leaked use()/set_context scope would silently change numerics)."""
    from repro import linalg
    linalg.reset_context()
    yield
    linalg.reset_context()


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)


# --------------------------- tolerance helper -------------------------------
# One shared oracle-comparison policy for every differential test: tolerances
# keyed by dtype (fp32 kernels accumulate in fp32; bf16 storage loses ~8
# mantissa bits), scaled by an optional problem-size factor so blocked
# algorithms with O(n) accumulation depth get proportional slack.

_DTYPE_TOL = {
    np.dtype(np.float64): dict(rtol=1e-12, atol=1e-12),
    np.dtype(np.float32): dict(rtol=2e-4, atol=1e-4),
}
try:  # bfloat16 loses ~16 mantissa bits vs f32: ~3 decimal digits of slack
    import jax.numpy as _jnp
    _DTYPE_TOL[np.dtype(_jnp.bfloat16)] = dict(rtol=5e-2, atol=5e-2)
except (ImportError, TypeError):  # pragma: no cover - bf16 always available
    pass


def dtype_tolerances(dtype, scale: float = 1.0):
    """(rtol, atol) for comparing a result of ``dtype`` against an oracle."""
    base = _DTYPE_TOL.get(np.dtype(dtype))
    if base is None:  # anything else low-precision
        base = dict(rtol=5e-2, atol=5e-2)
    return base["rtol"] * scale, base["atol"] * scale


@pytest.fixture
def assert_close():
    """np.testing.assert_allclose with dtype-derived tolerances.

    Usage: assert_close(got, want) or assert_close(got, want, scale=4.0).
    Arrays are compared in float64 against the oracle ``want``.
    """
    def check(got, want, scale: float = 1.0, err_msg: str = ""):
        got = np.asarray(got)
        rtol, atol = dtype_tolerances(got.dtype, scale)
        np.testing.assert_allclose(got.astype(np.float64),
                                   np.asarray(want).astype(np.float64),
                                   rtol=rtol, atol=atol, err_msg=err_msg)
    return check
