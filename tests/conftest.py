"""Shared fixtures. NOTE: no XLA_FLAGS here - smoke tests and benches must
see 1 device; only launch/dryrun forces 512 placeholder devices (and tests
that need a few devices spawn a subprocess - see test_distributed.py)."""
import jax
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)
