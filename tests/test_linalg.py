"""Differential + context-semantics tests for the repro.linalg front-end.

Acceptance grid (ISSUE 4): the *same* ``repro.linalg`` call under
{reference, model, tuned} x {no mesh, (2, 2) mesh} x {float32, float64}
must agree with the NumPy/SciPy oracle within the shared
``dtype_tolerances``. The mesh legs need 8 forced host devices and the
float64 legs need ``JAX_ENABLE_X64`` - both are process-level switches -
so that grid runs in one subprocess (pattern of
``tests/test_distributed_blas.py``); everything else (policy x
{float32, bfloat16} grids, batched delegation, ExecutionContext
semantics, registry-path contexts, accumulation dtype) runs in-process.
"""
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest
import scipy.linalg

from repro import linalg
from repro.linalg.context import UNSET

from conftest import LINALG_DTYPES as DTYPES  # shared in-process dtype grid

POLICIES = ["reference", "model", "tuned"]


def _mk(rng, shape, dtype=np.float32):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32)).astype(dtype)


def _f64(x):
    return np.asarray(jnp.asarray(x).astype(jnp.float32)).astype(np.float64)


# --------------------- policy x dtype differential grid ---------------------

@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("pol", POLICIES)
@pytest.mark.parametrize("m,n,k", [(24, 36, 12), (17, 5, 29)])
def test_gemm_policy_dtype_grid(rng, assert_close, m, n, k, pol, dtype):
    a, b = _mk(rng, (m, k), dtype), _mk(rng, (k, n), dtype)
    with linalg.use(policy=pol):
        got = linalg.gemm(a, b)
    assert got.dtype == jnp.dtype(dtype)
    assert_close(got, _f64(a) @ _f64(b), scale=max(1.0, k / 16))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("pol", POLICIES)
def test_gemv_syrk_policy_dtype_grid(rng, assert_close, pol, dtype):
    a, x = _mk(rng, (17, 9), dtype), _mk(rng, 9, dtype)
    with linalg.use(policy=pol):
        got_v = linalg.gemv(a, x)
        got_s = linalg.syrk(a)
    assert_close(got_v, _f64(a) @ _f64(x))
    assert_close(got_s, _f64(a) @ _f64(a).T, scale=2.0)


@pytest.mark.parametrize("pol", POLICIES)
@pytest.mark.parametrize("lower", [True, False])
def test_trsm_policy_grid(rng, assert_close, pol, lower):
    n = 40
    a = _mk(rng, (n, n))
    t = (jnp.tril(a) if lower else jnp.triu(a)) + 4 * jnp.eye(n)
    b = _mk(rng, (n, 3))
    with linalg.use(policy=pol):
        got = linalg.trsm(t, b, lower=lower)
    ref = scipy.linalg.solve_triangular(_f64(t), _f64(b), lower=lower)
    assert_close(got, ref, scale=4.0)


@pytest.mark.parametrize("dtype", DTYPES)
def test_level1_vs_numpy(rng, assert_close, dtype):
    x, y = _mk(rng, 65, dtype), _mk(rng, 65, dtype)
    assert_close(linalg.dot(x, y, schedule="strided"),
                 np.dot(_f64(x), _f64(y)), scale=4.0)
    assert_close(linalg.axpy(2.5, x, y), 2.5 * _f64(x) + _f64(y))
    assert_close(linalg.scal(-0.5, x), -0.5 * _f64(x))
    assert_close(linalg.nrm2(x), np.linalg.norm(_f64(x)), scale=2.0)
    assert_close(linalg.asum(x), np.abs(_f64(x)).sum(), scale=2.0)
    assert int(linalg.iamax(x)) == int(np.argmax(np.abs(_f64(x))))
    gx, gy = linalg.rot(x, y, np.cos(0.3), np.sin(0.3))
    assert_close(gx, np.cos(0.3) * _f64(x) + np.sin(0.3) * _f64(y))
    u, v, a = _mk(rng, 9, dtype), _mk(rng, 7, dtype), _mk(rng, (9, 7), dtype)
    assert_close(linalg.ger(0.75, u, v, a),
                 _f64(a) + 0.75 * np.outer(_f64(u), _f64(v)), scale=2.0)
    b2 = _mk(rng, 9, dtype)
    t = jnp.tril(_mk(rng, (9, 9), dtype)) + 4 * jnp.eye(9, dtype=dtype)
    assert_close(linalg.trsv(t, b2),
                 scipy.linalg.solve_triangular(_f64(t), _f64(b2), lower=True),
                 scale=4.0)


@pytest.mark.parametrize("pol", POLICIES)
def test_lapack_routines_policy_grid(rng, assert_close, pol):
    n = 32
    a = _mk(rng, (n, n)) + 8 * jnp.eye(n)
    s = a @ a.T + n * jnp.eye(n)
    b = _mk(rng, (n, 2))
    with linalg.use(policy=pol):
        l = linalg.cholesky(s, block=8)
        assert_close(l @ l.T, _f64(s), scale=16.0)
        packed, piv = linalg.lu(a, block=8)
        from repro.lapack.lu import lu_reconstruct
        assert_close(lu_reconstruct(packed, piv), _f64(a), scale=16.0)
        q, r = linalg.qr(a, block=8)
        assert_close(q @ r, _f64(a), scale=16.0)
        assert_close(q.T @ q, np.eye(n), scale=16.0)
        x = linalg.solve(a, b, block=8)
        assert_close(x, np.linalg.solve(_f64(a), _f64(b)), scale=16.0)
    tall = _mk(rng, (48, 20))
    bt = _mk(rng, 48)
    with linalg.use(policy=pol):
        xl = linalg.lstsq(tall, bt, block=8)
    ref = np.linalg.lstsq(_f64(tall), _f64(bt), rcond=None)[0]
    assert_close(xl, ref, scale=32.0)


# --------------------------- batched delegation -----------------------------

def test_gemm_3d_batches_via_vmap(rng, assert_close):
    a = _mk(rng, (4, 12, 8))
    b = _mk(rng, (4, 8, 10))
    with linalg.use(policy="model"):
        got = linalg.gemm(a, b)
    assert_close(got, np.einsum("bij,bjk->bik", _f64(a), _f64(b)))


def test_lapack_3d_delegates_to_batched(rng, assert_close):
    g = _mk(rng, (5, 16, 16))
    spd = g @ jnp.swapaxes(g, 1, 2) + 16 * jnp.eye(16)
    l3 = linalg.cholesky(spd, block=8)
    res = linalg.batched_cholesky(spd, block=8)
    assert res.kind == "potrf"
    np.testing.assert_array_equal(np.asarray(l3), np.asarray(res.factors))
    b = _mk(rng, (5, 16))
    x = linalg.batched_solve(res, b)
    resid = jnp.einsum("bij,bj->bi", spd, x) - b
    assert float(jnp.max(jnp.abs(resid))) < 2e-3
    x2 = linalg.solve(g + 8 * jnp.eye(16), b, block=8)
    for i in range(5):
        assert_close(x2[i], np.linalg.solve(_f64(g[i]) + 8 * np.eye(16),
                                            _f64(b[i])), scale=16.0)
    packed, piv = linalg.lu(g, block=8)
    assert packed.shape == (5, 16, 16) and piv.shape == (5, 16)
    q, r = linalg.qr(g, block=8)
    assert_close(jnp.einsum("bij,bjk->bik", q, r), _f64(g), scale=16.0)
    tall = _mk(rng, (4, 24, 10))
    bt = _mk(rng, (4, 24))
    xb = linalg.lstsq(tall, bt, block=8)
    assert xb.shape == (4, 10)
    for i in range(4):
        ref = np.linalg.lstsq(_f64(tall[i]), _f64(bt[i]), rcond=None)[0]
        assert_close(xb[i], ref, scale=32.0)


# ------------------------- ExecutionContext semantics -----------------------

def test_context_layering_and_overrides():
    assert linalg.get_context().policy is None          # library default
    linalg.set_context(policy="model")
    assert linalg.get_context().policy == "model"
    with linalg.use(policy="tuned"):
        assert linalg.get_context().policy == "tuned"
        with linalg.use(interpret=True):                # inherits policy
            assert linalg.get_context().policy == "tuned"
        ctx = linalg.ExecutionContext(policy="reference")
        from repro.linalg.context import current
        assert current(ctx).policy == "reference"       # per-call override
        assert current(dict(policy="model")).policy == "model"
    assert linalg.get_context().policy == "model"       # use() popped
    linalg.reset_context()
    assert linalg.get_context().policy is None


def test_context_validation():
    with pytest.raises(ValueError, match="unknown policy"):
        linalg.ExecutionContext(policy="warp-speed")
    with pytest.raises(ValueError, match="px, py"):
        linalg.ExecutionContext(mesh=(2, 2, 2))
    with pytest.raises(TypeError):
        linalg.use(linalg.ExecutionContext(), policy="model").__enter__()


def test_context_describe_is_jsonable():
    import json
    ctx = linalg.ExecutionContext(policy="tuned", mesh=(2, 2),
                                  accum_dtype=jnp.float32,
                                  registry="/tmp/reg.json")
    d = ctx.describe()
    assert d == {"policy": "tuned", "mesh": [2, 2],
                 "registry": "/tmp/reg.json", "accum_dtype": "float32",
                 "interpret": True, "machine": "tpu-like", "obs": None}
    json.dumps(d)
    # defaults resolve to the process default policy
    assert linalg.get_context().describe()["policy"] == "reference"


def test_context_registry_path_reaches_dispatch(rng, assert_close, tmp_path):
    """A path-string registry in the context must feed tuned resolution -
    for BLAS and for LAPACK trailing updates (the threaded registry)."""
    from repro.tune.registry import Registry
    path = str(tmp_path / "ctx_registry.json")
    reg = Registry(path=path)
    reg.record("gemm", (24, 18, 12), jnp.float32, "cpu",
               {"bm": 256, "bn": 128, "bk": 128})
    reg.save()
    a, b = _mk(rng, (24, 12)), _mk(rng, (12, 18))
    with linalg.use(policy="tuned", registry=path):
        got = linalg.gemm(a, b)
        from repro.linalg.context import resolved_registry
        r = resolved_registry(linalg.get_context())
        assert r is resolved_registry(linalg.get_context())  # cached
        from repro.tune import dispatch
        res = dispatch.resolve("gemm", (24, 18, 12), jnp.float32,
                               policy="tuned", registry=r, backend="cpu")
        assert res.source == "registry" and res.gemm_plan.bm == 256
    assert_close(got, _f64(a) @ _f64(b))


def test_accum_dtype_upcasts_computation(rng):
    """bf16 storage + f32 accumulation must beat pure-bf16 accumulation
    on a long sequential reduction, while keeping bf16 storage."""
    n = 4096
    x = _mk(rng, n, jnp.bfloat16)
    y = _mk(rng, n, jnp.bfloat16)
    want = np.dot(_f64(x), _f64(y))
    plain = linalg.dot(x, y, schedule="sequential")
    with linalg.use(accum_dtype=jnp.float32):
        mixed = linalg.dot(x, y, schedule="sequential")
    assert plain.dtype == jnp.bfloat16 and mixed.dtype == jnp.bfloat16
    err_plain = abs(float(plain) - want)
    err_mixed = abs(float(mixed) - want)
    assert err_mixed <= err_plain + 1e-6


def test_mixed_dtype_accumuland_promotes(rng, assert_close):
    """A wider c/y accumuland must survive the epilogue (no silent
    downcast): default-context results stay bitwise the core path, which
    promotes like plain jnp."""
    a = _mk(rng, (8, 6), jnp.bfloat16)
    b = _mk(rng, (6, 5), jnp.bfloat16)
    c = _mk(rng, (8, 5), np.float32)
    got = linalg.gemm(a, b, c=c, beta=1.0)
    assert got.dtype == jnp.float32
    from repro.blas import level3
    want = level3.gemm(a, b, c=c, beta=1.0)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    x = _mk(rng, 6, jnp.bfloat16)
    y = _mk(rng, 8, np.float32)
    got_v = linalg.gemv(a, x, y=y, beta=1.0)
    assert got_v.dtype == jnp.float32


def test_dtype_arg_casts_storage(rng, assert_close):
    a, b = _mk(rng, (12, 8)), _mk(rng, (8, 10))
    got = linalg.gemm(a, b, dtype=jnp.bfloat16)
    assert got.dtype == jnp.bfloat16
    assert_close(got, _f64(a) @ _f64(b))
    l = linalg.cholesky(jnp.eye(8) * 4.0, dtype=jnp.bfloat16)
    assert l.dtype == jnp.bfloat16


# ------------------ the full acceptance grid (subprocess) -------------------

_ENV = dict(os.environ,
            XLA_FLAGS="--xla_force_host_platform_device_count=8",
            JAX_ENABLE_X64="1",
            PYTHONPATH="src")

_PRELUDE = """
import sys
sys.path.insert(0, "tests")
from conftest import dtype_tolerances
import numpy as np
import jax, jax.numpy as jnp
import scipy.linalg
from repro import linalg

def close(got, want, scale=1.0, msg=""):
    rtol, atol = dtype_tolerances(np.asarray(got).dtype, scale)
    np.testing.assert_allclose(np.asarray(got).astype(np.float64),
                               np.asarray(want).astype(np.float64),
                               rtol=rtol, atol=atol, err_msg=msg)
"""


def test_linalg_grid_policy_mesh_dtype():
    """The same repro.linalg calls over the full acceptance grid:
    {reference, model, tuned} x {no mesh, (2, 2)} x {float32, float64}."""
    code = _PRELUDE + textwrap.dedent("""
    rng = np.random.default_rng(0)
    for dtype in (np.float32, np.float64):
        a = jnp.asarray(rng.normal(size=(24, 20)).astype(dtype))
        b = jnp.asarray(rng.normal(size=(20, 16)).astype(dtype))
        want_mm = np.asarray(a, np.float64) @ np.asarray(b, np.float64)
        t = np.tril(rng.normal(size=(24, 24))).astype(dtype) \\
            + 4.0 * np.eye(24, dtype=dtype)
        rhs = rng.normal(size=(24, 6)).astype(dtype)
        want_tr = scipy.linalg.solve_triangular(
            np.asarray(t, np.float64), np.asarray(rhs, np.float64),
            lower=True)
        g = rng.normal(size=(5, 12, 12)).astype(dtype)
        spd = g @ np.swapaxes(g, 1, 2) + 12 * np.eye(12, dtype=dtype)
        want_l = np.stack([np.linalg.cholesky(np.asarray(m, np.float64))
                           for m in spd])
        brhs = rng.normal(size=(5, 12)).astype(dtype)
        t, rhs, spd, brhs = map(jnp.asarray, (t, rhs, spd, brhs))
        for mesh in (None, (2, 2)):
            for pol in ("reference", "model", "tuned"):
                tag = f"dtype={np.dtype(dtype).name} mesh={mesh} policy={pol}"
                with linalg.use(policy=pol, mesh=mesh):
                    got = linalg.gemm(a, b)
                    assert got.dtype == jnp.dtype(dtype), (tag, got.dtype)
                    close(got, want_mm, scale=8.0, msg="gemm " + tag)
                    close(linalg.trsm(t, rhs, lower=True), want_tr,
                          scale=16.0, msg="trsm " + tag)
                    res = linalg.batched_cholesky(spd, block=8)
                    close(res.factors, want_l, scale=64.0,
                          msg="cholesky " + tag)
                    x = linalg.batched_solve(res, brhs)
                    close(jnp.einsum("bij,bj->bi", jnp.asarray(spd), x),
                          brhs, scale=256.0, msg="solve " + tag)
        # d-prefixed shim == repro.linalg, bitwise, per dtype
        import warnings
        from repro import blas
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            old = blas.dgemm(a, b)
        assert np.array_equal(np.asarray(old), np.asarray(linalg.gemm(a, b)))
    print("linalg acceptance grid OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], env=_ENV,
                       capture_output=True, text=True, timeout=900,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "linalg acceptance grid OK" in r.stdout
