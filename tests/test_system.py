"""End-to-end behaviour of the paper's system: the full codesign loop.

Workload -> characterization -> optimal depths (eq. 7) -> PE simulation
corroboration -> TPU knobs -> codesigned kernels matching oracles. One test
walks the whole pipeline the way examples/quickstart.py does.
"""
import jax.numpy as jnp
import numpy as np

from repro.core import characterization as ch
from repro.core import codesign, isa, pe
from repro.kernels import ops


def test_full_codesign_loop():
    n = 1024
    # 1) characterize (section 4)
    prof = ch.characterize_ddot(n, schedule="sequential")
    assert prof.hazard_ratios()["add"] > 0.9
    # 2) closed-form optimum (eq. 7): serial adds -> shallow-ish pipe
    depths = prof.optimal_depths()
    assert 2 <= depths["add"] <= 16
    # 3) PE simulation corroborates (section 5). Sweep add+mul jointly as
    # the paper's fig. 12 does (otherwise the fixed mul pipe holds the clock
    # and the adder optimum is artificially shallow).
    stream = isa.compile_ddot(n, schedule="sequential")
    sweep = pe.sweep_joint(stream, ["add", "mul"], [1, 2, 4, 8, 16, 32])
    best = pe.best_depth(sweep, "add")
    assert abs(np.log2(max(best, 1)) - np.log2(max(depths["add"], 1))) <= 2
    # 4) TPU adaptation: the same trade-off picks the accumulator count
    u = codesign.optimal_accumulators(n)
    assert u >= 4
    # 5) the codesigned kernel agrees with the oracle
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=n).astype(np.float32))
    y = jnp.asarray(rng.normal(size=n).astype(np.float32))
    got = float(ops.dotp(x, y, accumulators=u, use_pallas=True,
                         interpret=True))
    want = float(np.dot(np.asarray(x, np.float64), np.asarray(y, np.float64)))
    assert abs(got - want) < 1e-3 * max(abs(want), 1.0)


def test_strided_schedule_beats_sequential_on_pe():
    """The codesign claim end-to-end: the U-accumulator schedule chosen by
    eq. 3 runs faster on the simulated PE than the naive serial one."""
    n = 2048
    u = codesign.optimal_accumulators(n)
    seq = pe.simulate(isa.compile_ddot(n, schedule="sequential"))
    par = pe.simulate(isa.compile_ddot(n, schedule="strided",
                                       accumulators=u))
    assert par.cycles < seq.cycles / 2
