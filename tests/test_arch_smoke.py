"""Per-assigned-architecture smoke tests (deliverable f).

Each test instantiates a REDUCED config of the same family (small
layers/width, few experts, tiny vocab) and runs one forward + one train step
on CPU, asserting output shapes and no NaNs. The FULL configs are exercised
via the dry-run (ShapeDtypeStructs, no allocation) - see launch/dryrun.py.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.data.pipeline import DataConfig, make_batch
from repro.launch.train import reduce_config
from repro.models import model_zoo as zoo
from repro.train import train_state as ts
from repro.train.optimizer import AdamWConfig


def _reduced(arch):
    cfg = reduce_config(registry.get_config(arch), layers=2, d_model=64,
                        vocab=128, heads=4)
    return dataclasses.replace(cfg, accum_steps=1, dtype="float32")


@pytest.mark.parametrize("arch", registry.ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = _reduced(arch)
    assert cfg.family == registry.get_config(arch).family
    key = jax.random.PRNGKey(0)
    opt = AdamWConfig(lr=1e-3, eight_bit=cfg.opt_8bit, warmup_steps=2,
                      decay_steps=10)
    state = ts.init_state(key, cfg, opt)
    data = DataConfig(vocab=cfg.vocab, global_batch=4, seq_len=16)
    batch = make_batch(cfg, data, 0)
    # forward: shape + finite
    logits, aux = zoo.forward(state["params"], batch, cfg)
    extra = cfg.num_prefix_tokens if cfg.family == "vlm" else 0
    assert logits.shape == (4, 16 + extra, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    # one train step: loss finite, params move
    step = jax.jit(ts.make_train_step(cfg, opt))
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    moved = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         state["params"], new_state["params"])
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ["minitron-8b", "mamba2-130m",
                                  "hymba-1.5b", "whisper-small",
                                  "qwen3-moe-235b-a22b"])
def test_smoke_decode_step(arch):
    cfg = _reduced(arch)
    key = jax.random.PRNGKey(0)
    params = zoo.init(key, cfg)
    b = 2
    if cfg.family == "encdec":
        mem = jax.random.normal(key, (b, 8, cfg.d_model), jnp.float32)
        caches = zoo.init_caches(params, cfg, b, 24, memory=mem,
                                 dtype=jnp.float32)
    else:
        caches = zoo.init_caches(params, cfg, b, 24, dtype=jnp.float32)
    tok = jnp.zeros((b, 1), jnp.int32)
    logits, new_caches = zoo.decode_step(params, tok, cfg, caches,
                                         jnp.int32(0))
    assert logits.shape == (b, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


def test_full_configs_match_assignment():
    """The FULL configs carry the exact assigned hyperparameters."""
    spec = {
        "minitron-8b": (32, 4096, 32, 8, 16384, 256000),
        "granite-3-8b": (40, 4096, 32, 8, 12800, 49155),
        "gemma-7b": (28, 3072, 16, 16, 24576, 256000),
        "mistral-large-123b": (88, 12288, 96, 8, 28672, 32768),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
        "mamba2-130m": (24, 768, 24, 24, 0, 50280),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
    }
    for arch, (L, d, h, kv, ff, v) in spec.items():
        cfg = registry.get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv,
                cfg.d_ff, cfg.vocab) == (L, d, h, kv, ff, v), arch
    assert registry.get_config("gemma-7b").head_dim == 256
    assert registry.get_config("qwen3-moe-235b-a22b").n_experts == 128
    assert registry.get_config("qwen3-moe-235b-a22b").top_k == 8
    assert registry.get_config("kimi-k2-1t-a32b").n_experts == 384
    assert registry.get_config("mamba2-130m").ssm_state == 128
    assert registry.get_config("hymba-1.5b").ssm_state == 16


def test_param_counts_in_family_range():
    """Sanity: each arch's parameter count is in its advertised class."""
    expect = {"minitron-8b": (8e9, 11e9), "granite-3-8b": (7e9, 9e9),
              "gemma-7b": (7.5e9, 9.5e9),
              "mistral-large-123b": (118e9, 128e9),
              "whisper-small": (0.2e9, 0.35e9),
              "mamba2-130m": (0.11e9, 0.15e9),
              "hymba-1.5b": (1.3e9, 1.9e9), "internvl2-1b": (0.4e9, 0.6e9),
              "qwen3-moe-235b-a22b": (225e9, 245e9),
              "kimi-k2-1t-a32b": (0.95e12, 1.1e12)}
    for arch, (lo, hi) in expect.items():
        n = zoo.param_count(registry.get_config(arch))
        assert lo <= n <= hi, (arch, n)
    # active params for the MoEs: the a22b / a32b designations
    a = zoo.active_param_count(registry.get_config("qwen3-moe-235b-a22b"))
    assert 20e9 <= a <= 24e9
    a = zoo.active_param_count(registry.get_config("kimi-k2-1t-a32b"))
    assert 30e9 <= a <= 34e9


def test_cell_skips_documented():
    defined, skipped = registry.all_cells()
    assert len(defined) == 32
    assert len(skipped) == 8
    assert all(s[1] == "long_500k" for s in skipped)
    # only the sub-quadratic archs run long_500k
    long_archs = {a for a, s in defined if s == "long_500k"}
    assert long_archs == {"mamba2-130m", "hymba-1.5b"}
