"""Trip-count-aware HLO cost analysis vs XLA's cost_analysis."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import hlo_cost

W = jax.ShapeDtypeStruct((128, 128), jnp.float32)
EXPECT = 2 * 128 ** 3


def _hlo(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_dot_exact():
    c = hlo_cost.analyze(_hlo(lambda w, x: x @ w, W, W))
    assert c.flops == pytest.approx(EXPECT, rel=1e-6)


def test_xla_undercounts_scan_we_do_not():
    """The probe DESIGN.md section 7 + the roofline correction rest on."""
    def scanned(w, x):
        return jax.lax.scan(lambda c, _: (c @ w, None), x, None, length=8)[0]
    compiled = jax.jit(scanned).lower(W, W).compile()
    xla = compiled.cost_analysis()
    xla = xla[0] if isinstance(xla, list) else xla
    ours = hlo_cost.analyze(compiled.as_text())
    assert xla.get("flops", 0) == pytest.approx(EXPECT, rel=0.01)   # 1x body!
    assert ours.flops == pytest.approx(8 * EXPECT, rel=0.01)        # 8x body


def test_nested_scan():
    def nested(w, x):
        def outer(c, _):
            return jax.lax.scan(lambda d, _: (d @ w, None), c, None,
                                length=4)[0], None
        return jax.lax.scan(outer, x, None, length=3)[0]
    c = hlo_cost.analyze(_hlo(nested, W, W))
    assert c.flops == pytest.approx(12 * EXPECT, rel=0.01)


def test_fusion_flops_counted_bytes_boundary_only():
    def f(x):
        return jnp.sum(jnp.exp(x) * x + 1.0)
    c = hlo_cost.analyze(_hlo(f, W))
    n = 128 * 128
    # ~3n elementwise + n-ish reduce; generous bounds
    assert n <= c.flops <= 10 * n
    # bytes: input once + small outputs, NOT per-elementwise-op
    assert c.bytes <= 6 * n * 4


def test_remat_recompute_visible():
    """checkpointed grad recomputes the forward: flops ~3x fwd dot count."""
    def f(w, x):
        y = jax.checkpoint(lambda a: jnp.tanh(a @ w))(x)
        return jnp.sum(y @ w)
    fwd = hlo_cost.analyze(_hlo(lambda w, x: jnp.sum(jnp.tanh(x @ w) @ w),
                                W, W)).flops
    g = hlo_cost.analyze(_hlo(lambda w, x: jax.grad(
        lambda xx: f(w, xx))(x), W, W)).flops
    assert g > 1.5 * fwd


def test_trip_count_parse_robust():
    # hand-built module with tuple-typed while
    hlo = """
HloModule m

%body (p: (s32[], f32[64,64])) -> (s32[], f32[64,64]) {
  %p = (s32[], f32[64,64]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[64,64] get-tuple-element(%p), index=1
  %d = f32[64,64] dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[64,64]) tuple(%ip, %d)
}

%cond (p2: (s32[], f32[64,64])) -> pred[] {
  %p2 = (s32[], f32[64,64]) parameter(0)
  %i2 = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(17)
  ROOT %lt = pred[] compare(%i2, %n), direction=LT
}

ENTRY %main (a: f32[64,64]) -> f32[64,64] {
  %a = f32[64,64] parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[64,64]) tuple(%z, %a)
  %w = (s32[], f32[64,64]) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[64,64] get-tuple-element(%w), index=1
}
"""
    c = hlo_cost.analyze(hlo)
    assert c.flops == pytest.approx(17 * 2 * 64 ** 3 + 17, rel=0.01)


def test_collectives_scaled_by_trips():
    hlo = """
HloModule m

%body (p: (s32[], f32[256])) -> (s32[], f32[256]) {
  %p = (s32[], f32[256]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[256] get-tuple-element(%p), index=1
  %ar = f32[256] all-reduce(%x), to_apply=%sum
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[256]) tuple(%ip, %ar)
}

%cond (p2: (s32[], f32[256])) -> pred[] {
  %p2 = (s32[], f32[256]) parameter(0)
  %i2 = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i2, %n), direction=LT
}

ENTRY %main (a: f32[256]) -> f32[256] {
  %a = f32[256] parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[256]) tuple(%z, %a)
  %w = (s32[], f32[256]) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[256] get-tuple-element(%w), index=1
}
"""
    c = hlo_cost.analyze(hlo)
    assert c.coll["all-reduce"] == pytest.approx(5 * 256 * 4)
