"""Paper section 5: PE simulator corroborates the theoretical curves."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import characterization as ch
from repro.core import isa, pe
from repro.core.pipeline_model import tpi


def scoreboard_reference(opcode, src1, src2, lat):
    """Brute-force Python model of the in-order stall-on-use scoreboard:

        issue[i] = max(issue[i-1] + 1, ready[src1[i]], ready[src2[i]])
        ready[i] = issue[i] + lat[opcode[i]]

    Deliberately dumb (dict + loop) so it can only be right; the lax.scan
    simulator in repro.core.pe must agree instruction for instruction.
    """
    ready = {}
    prev_issue, stalls, last_fin = -1, 0, 0
    for i, (op, s1, s2) in enumerate(zip(opcode, src1, src2)):
        earliest = 0
        if s1 >= 0:
            earliest = max(earliest, ready[s1])
        if s2 >= 0:
            earliest = max(earliest, ready[s2])
        issue = max(prev_issue + 1, earliest)
        fin = issue + int(lat[op])
        ready[i] = fin
        stalls += issue - prev_issue - 1
        prev_issue = issue
        last_fin = max(last_fin, fin)
    return last_fin, stalls


def _random_stream(rng, n):
    """Random SSA instruction stream: any opcode, operands drawn from
    earlier ids or RF-resident (-1)."""
    opcode = rng.integers(0, isa.N_OPCODES, size=n).astype(np.int32)
    src1 = np.empty(n, np.int32)
    src2 = np.empty(n, np.int32)
    for i in range(n):
        src1[i] = rng.integers(-1, i) if i else -1
        src2[i] = rng.integers(-1, i) if i else -1
    return opcode, src1, src2


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("n", [1, 2, 37, 400])
def test_scan_scoreboard_matches_bruteforce_random(seed, n):
    rng = np.random.default_rng(seed)
    opcode, src1, src2 = _random_stream(rng, n)
    depths = {"mul": int(rng.integers(1, 20)), "add": int(rng.integers(1, 20)),
              "div": int(rng.integers(1, 40)), "sqrt": int(rng.integers(1, 40))}
    lat = pe._latency_vector(depths)
    want_cycles, want_stalls = scoreboard_reference(opcode, src1, src2, lat)
    got_cycles, got_stalls = pe._scoreboard(
        jnp.asarray(opcode), jnp.asarray(src1), jnp.asarray(src2),
        jnp.asarray(lat))
    assert int(got_cycles) == want_cycles
    assert int(got_stalls) == want_stalls


def test_scan_scoreboard_matches_bruteforce_compiled_streams():
    """Same agreement on real compiled BLAS/LAPACK streams (every compiler,
    every dependence pattern the paper studies)."""
    streams = [isa.compile_ddot(64, schedule="sequential"),
               isa.compile_ddot(64, dot4=True),
               isa.compile_dgemm(3, 3, 8),
               isa.compile_dgeqrf(6),
               isa.compile_dgetrf(6),
               isa.compile_dpotrf(6)]
    lat = pe._latency_vector(pe.DEFAULT_DEPTHS)
    for s in streams:
        want_cycles, want_stalls = scoreboard_reference(
            s.opcode, s.src1, s.src2, lat)
        got_cycles, got_stalls = pe._scoreboard(
            jnp.asarray(s.opcode), jnp.asarray(s.src1), jnp.asarray(s.src2),
            jnp.asarray(lat))
        assert int(got_cycles) == want_cycles, s.name
        assert int(got_stalls) == want_stalls, s.name


def test_scoreboard_exact_small_case():
    """Hand-checked: mul(lat 3) -> add depending on it (lat 2) -> add."""
    b = isa._Builder("hand")
    i0 = b.emit(isa.MUL)               # issue 0, fin 3
    i1 = b.emit(isa.ADD, i0)           # waits: issue 3, fin 5
    i2 = b.emit(isa.ADD, i1)           # waits: issue 5, fin 7
    r = pe.simulate(b.build(), {"mul": 3, "add": 2})
    assert r.cycles == 7
    assert r.stalls == (3 - 1) + (5 - 4)


def test_hazard_free_stream_cpi_one():
    """Independent muls: CPI -> 1 regardless of depth (full pipelining)."""
    b = isa._Builder("nohaz")
    b.emit_block(np.full(500, isa.MUL), -1, -1)
    s = b.build()
    for d in (1, 4, 16):
        r = pe.simulate(s, {"mul": d})
        assert r.cpi == pytest.approx(1.0, rel=0.1)


def test_sequential_chain_cpi_equals_latency():
    """Fully dependent adds: CPI -> add latency (every op stalls)."""
    b = isa._Builder("chain")
    acc = b.emit(isa.ADD)
    for _ in range(299):
        acc = b.emit(isa.ADD, acc)
    r = pe.simulate(b.build(), {"add": 6})
    assert r.cpi == pytest.approx(6.0, rel=0.05)


def test_tpi_minimum_exists_and_matches_theory():
    """Fig. 12 behaviour: TPI vs depth has an interior optimum for hazardous
    streams, and the simulated optimum is near the eq.-7 prediction."""
    stream = isa.compile_ddot(4096, schedule="sequential")
    depths = [1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48]
    results = pe.sweep(stream, "add", depths)
    tpis = [r.tpi for r in results]
    i = int(np.argmin(tpis))
    assert 0 < i < len(depths) - 1, "interior minimum expected"
    # theory: adder pipe of the sequential ddot (gamma~1, NH/NI~1)
    prof = ch.characterize_ddot(4096, schedule="sequential")
    pp = prof.pipes["add"]
    theory = [float(tpi(d, n_i=pp.n_i, n_h=pp.n_h, gamma=1.0,
                        t_p=pp.t_p, t_o=pp.t_o)) for d in depths]
    j = int(np.argmin(theory))
    # the paper: 'fairly flat around optimum' - allow one grid notch
    assert abs(i - j) <= 2, (depths[i], depths[j])


def test_cpi_monotone_in_depth_for_serial_stream():
    """In cycles, deeper pipes only add stalls on a serial stream; the
    optimum exists only in *time* (faster clock) - the eq.-2 trade-off.
    Pure add chain so the adder alone sets the clock."""
    b = isa._Builder("chain")
    acc = b.emit(isa.ADD)
    for _ in range(255):
        acc = b.emit(isa.ADD, acc)
    stream = b.build()
    res = pe.sweep(stream, "add", [1, 4, 16])
    cpis = [r.cpi for r in res]
    assert cpis[0] < cpis[1] < cpis[2]
    freqs = [r.frequency for r in res]
    assert freqs[0] < freqs[1] < freqs[2]


def test_gemm_unroll_improves_cpi():
    s1 = isa.compile_dgemm(4, 4, 32, unroll=1)
    s8 = isa.compile_dgemm(4, 4, 32, unroll=8)
    d = {"mul": 5, "add": 4}
    assert pe.simulate(s8, d).cpi < pe.simulate(s1, d).cpi


def test_qr_sqrt_depth_sweep_shallow_optimum():
    """Fig. 13: QR's serial sqrt chain prefers shallow sqrt pipes."""
    stream = isa.compile_dgeqrf(16)
    res = pe.sweep_joint(stream, ["sqrt", "div"], [2, 4, 8, 16, 32, 48])
    best = min(res, key=lambda r: r.tpi)
    deep = res[-1]
    assert best.depths["sqrt"] <= 16
    assert deep.tpi >= best.tpi


def test_dot4_beats_fma_on_ddot():
    """The enhanced PE's DOT4 (4 mul + 3 add per instruction) retires ddot
    in fewer cycles than the LAP-PE FMAC chain - the section-5 comparison."""
    n = 256
    dot4 = isa.compile_ddot(n, dot4=True)
    fmac = isa.compile_ddot(n, fma=True)
    d = {"mul": 5, "add": 4}
    r4, rf = pe.simulate(dot4, d), pe.simulate(fmac, d)
    assert r4.cycles < rf.cycles / 2
    assert r4.flops == pytest.approx(rf.flops, rel=0.05)


def test_sweep_matches_individual_sims():
    stream = isa.compile_dgemm(3, 3, 16)
    res = pe.sweep(stream, "add", [2, 8])
    for r in res:
        single = pe.simulate(stream, r.depths)
        assert single.cycles == r.cycles


# ---------------------------------------------------------------------------
# sweep / sweep_joint vs the analytic optimum (paper eq. 3) per op class
# ---------------------------------------------------------------------------
# The eq.-2/3 model is exact for a stream of W interleaved dependence
# chains: below depth W the pipe issues every cycle (deeper = faster
# clock); above it every instruction exposes latency (deeper = more
# stalls), so the measured optimum is W. Calibrating gamma to
# t_p / (t_o * W^2) makes eq. 3 predict exactly that point, so simulator
# and closed form must agree within +/-1 stage - the paper's 'theoretical
# curves corroborate simulations', made sharp.

from hypothesis import given, settings, strategies as st

from repro.arch import FPUSpec, MachineSpec, MemorySpec, PEGeometry, \
    PowerAreaSpec

_CHAIN_OPCODES = {"mul": isa.MUL, "add": isa.ADD, "div": isa.DIV,
                  "sqrt": isa.SQRT}
_CHAIN_T_P, _CHAIN_T_O = 55.0, 0.5      # Hartstein-Puzak FO4 ratios


def _chain_stream(op_class: str, n: int, width: int) -> isa.InstrStream:
    """n ops of one class in ``width`` interleaved dependence chains
    (instruction i depends on i - width)."""
    opcode = np.full(n, _CHAIN_OPCODES[op_class], np.int32)
    src1 = np.arange(n, dtype=np.int32) - width
    src1[src1 < 0] = -1
    src2 = np.full(n, -1, np.int32)
    return isa.InstrStream(f"chain-{op_class}-w{width}", opcode, src1, src2)


def _chain_machine(width: int) -> MachineSpec:
    gamma = _CHAIN_T_P / (_CHAIN_T_O * width * width)
    cls = ("mul", "add", "div", "sqrt")
    return MachineSpec(
        name=f"chain-w{width}",
        fpu=FPUSpec(depths={"mul": 5, "add": 4, "div": 12, "sqrt": 14},
                    t_p={k: _CHAIN_T_P for k in cls}, t_o=_CHAIN_T_O,
                    gamma={k: gamma for k in cls}),
        memory=MemorySpec(hbm_bw=1e9, vmem_bytes=1 << 20, ici_bw=1e9),
        pe=PEGeometry(mxu=8, sublane=1, lane=8, vreg_budget=8,
                      peak_flops=1e9),
        power_area=PowerAreaSpec(
            pj_per_flop={k: 1.0 for k in cls}, pj_per_byte_hbm=1.0,
            static_w=1.0, area_mm2=1.0))


@given(op_class=st.sampled_from(["mul", "add", "div", "sqrt"]),
       width=st.sampled_from([4, 6, 10, 16]))
@settings(max_examples=16, deadline=None)
def test_sweep_best_depth_matches_eq3_popt(op_class, width):
    """Per op class: the measured sweep optimum equals the eq.-3 closed
    form within one stage (FPUSpec.p_opt is the analytic side)."""
    n = 20 * width
    mach = _chain_machine(width)
    res = pe.sweep(_chain_stream(op_class, n, width), op_class,
                   list(range(1, 33)), machine=mach)
    best = pe.best_depth(res, op_class)
    popt = mach.fpu.p_opt(op_class, n_i=n, n_h=n - width)
    assert abs(best - popt) <= 1.0, \
        f"{op_class} w={width}: sweep best {best} vs eq.-3 {popt:.2f}"


@given(width=st.sampled_from([4, 8, 12]))
@settings(max_examples=6, deadline=None)
def test_sweep_joint_matches_eq3_popt(width):
    """sweep_joint over the serial pair (sqrt, div) - the fig.-13 pairing -
    agrees with eq. 3 within one stage when both pipes share the chain
    structure."""
    n = 20 * width
    mach = _chain_machine(width)
    # interleave sqrt and div chains: even slots sqrt, odd slots div, each
    # depending on the same-class op `width` same-class slots earlier
    opcode = np.empty(2 * n, np.int32)
    opcode[0::2] = isa.SQRT
    opcode[1::2] = isa.DIV
    src1 = np.arange(2 * n, dtype=np.int32) - 2 * width
    src1[src1 < 0] = -1
    stream = isa.InstrStream(f"chain-joint-w{width}", opcode, src1,
                             np.full(2 * n, -1, np.int32))
    res = pe.sweep_joint(stream, ["sqrt", "div"], list(range(1, 33)),
                         machine=mach)
    best = pe.best_depth(res, "sqrt")
    # per-class: n ops in `width` chains (distance 2*width in the merged
    # stream = width same-class slots)
    gamma = _CHAIN_T_P / (_CHAIN_T_O * (2 * width) ** 2)
    from repro.core.pipeline_model import p_opt as _p_opt
    popt = float(_p_opt(n_i=2 * n, n_h=2 * (n - width), gamma=gamma,
                        t_p=_CHAIN_T_P, t_o=_CHAIN_T_O))
    assert abs(best - popt) <= 1.0, \
        f"joint w={width}: sweep best {best} vs eq.-3 {popt:.2f}"


def test_sweep_joint_hazard_routines_match_shared_clock_analytic():
    """For the paper's hazard-bound LAPACK streams (fig. 13), the joint
    sweep optimum matches the eq.-1/2 analytic evaluated at the shared
    clock, exactly - theory corroborates simulation."""
    n = 24
    cases = [
        ("dgetrf", isa.compile_dgetrf(n), ch.characterize_dgetrf(n),
         ["div"]),
        ("dpotrf", isa.compile_dpotrf(n), ch.characterize_dpotrf(n),
         ["sqrt", "div"]),
        ("dgeqrf", isa.compile_dgeqrf(n), ch.characterize_dgeqrf(n),
         ["sqrt", "div"]),
    ]
    depths = list(range(2, 41))
    for name, stream, prof, units in cases:
        res = pe.sweep_joint(stream, units, depths)
        sim = pe.best_depth(res, units[0])
        used = [k for k, v in stream.census().items() if v > 0]
        n_i_total = sum(p.n_i for p in prof.pipes.values())
        best_t, ana = None, None
        for d in depths:
            cfg = dict(pe.DEFAULT_DEPTHS)
            for u in units:
                cfg[u] = d
            # eq. 1/2 at the shared clock: cycles = N_I + sum_u
            # gamma_u * N_H_u * p_u (each hazard exposes gamma*p cycles)
            cycles = n_i_total + sum(p.gamma * p.n_h * cfg[k]
                                     for k, p in prof.pipes.items()
                                     if p.n_i > 0)
            t = pe.cycle_time(cfg, used=used) * cycles
            if best_t is None or t < best_t:
                best_t, ana = t, d
        assert abs(sim - ana) <= 1, f"{name}: sim {sim} vs analytic {ana}"
