"""Paper section 4 (figs 6-8, 10): BLAS/LAPACK characterization table."""
from __future__ import annotations

from repro.core import characterization as ch
from repro.core.pipeline_model import OP_CLASSES


def run(emit):
    table = ch.characterization_table(n=100)
    for routine, row in table.items():
        for k in OP_CLASSES:
            r = row[f"NH/NI_{k}"]
            p = row[f"popt_{k}"]
            if r or p == p:  # emit present pipes
                emit(f"char,{routine},{k}", r, "hazard_ratio")
                emit(f"char,{routine},{k}", p, "p_opt")
    # fig 6/7: 1000-element inner product, adder pipe optimum per gamma
    for gamma in (0.2, 0.4, 0.6, 0.8):
        prof = ch.characterize_ddot(1000, schedule="tree")
        pp = prof.pipes["add"].replace(gamma=gamma)
        from repro.core.pipeline_model import p_opt_int
        emit(f"fig6,gamma={gamma}", p_opt_int(pp), "adder_p_opt")
    # fig 10: QR sqrt pipe optimum vs hazard ratio
    for ratio in (0.01, 0.1, 0.2, 0.4, 0.6, 0.8):
        prof = ch.characterize_dgeqrf(100)
        pp = prof.pipes["sqrt"]
        pp = pp.replace(n_h=ratio * pp.n_i)
        from repro.core.pipeline_model import p_opt_int
        emit(f"fig10,ratio={ratio}", p_opt_int(pp), "sqrt_p_opt")
