"""Benchmark driver: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--only name] [--fast] [--list]``
Prints ``name,value,derived`` CSV rows (``--list`` prints the registered
benches without running anything). ``--trace [DIR]`` additionally runs
every selected bench under a :mod:`repro.obs` trace and writes one
Chrome-format artifact per bench to ``DIR/trace_<name>.json`` (default
``benchmarks/out``) - load it in ``chrome://tracing`` / Perfetto, or
summarize with ``python scripts/trace_report.py <file>``.
"""
from __future__ import annotations

import argparse
import contextlib
import os
import sys
import time
import traceback

MODULES = [
    ("theory", "benchmarks.bench_theory"),                # figs 2-4
    ("characterization", "benchmarks.bench_characterization"),  # figs 6-10
    ("pe_cpi", "benchmarks.bench_pe_cpi"),                # figs 12-13
    ("synthesis", "benchmarks.bench_synthesis"),          # tables 1-2
    ("blas", "benchmarks.bench_blas"),                    # substrate perf
    ("lapack_batched", "benchmarks.bench_lapack_batched"),  # batched sweep
    ("tune", "benchmarks.bench_tune"),                    # tuner sweep -> registry
    ("distributed_blas", "benchmarks.bench_distributed_blas"),  # mesh sweep
    ("census", "benchmarks.bench_census"),                # section 4 on zoo
    ("roofline", "benchmarks.bench_roofline"),            # dry-run reader
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--fast", action="store_true",
                    help="skip the slow PE stream sweeps")
    ap.add_argument("--list", action="store_true",
                    help="print registered benches (name, module) and exit")
    ap.add_argument("--trace", nargs="?", const="benchmarks/out",
                    default=None, metavar="DIR",
                    help="trace each bench via repro.obs and write "
                         "DIR/trace_<name>.json (Chrome trace_event format; "
                         "default DIR: benchmarks/out)")
    args = ap.parse_args()

    if args.list:
        for name, modpath in MODULES:
            print(f"{name:18s} {modpath}")
        return

    def emit(name, value, unit):
        print(f"{name},{value},{unit}", flush=True)

    failures = []
    for name, modpath in MODULES:
        if args.only and name != args.only:
            continue
        if args.fast and name in ("pe_cpi", "census"):
            continue
        mod = __import__(modpath, fromlist=["run"])
        # perf_counter, not time.time(): the wall clock can step under NTP
        # mid-benchmark and corrupt the recorded duration
        t0 = time.perf_counter()
        print(f"# === {name} ===", flush=True)
        try:
            with contextlib.ExitStack() as st:
                tr = None
                if args.trace is not None:
                    from repro import obs
                    tr = st.enter_context(obs.trace(f"bench.{name}"))
                if name == "pe_cpi":
                    mod.run(emit, n=32 if args.fast else 48)
                else:
                    mod.run(emit)
            if tr is not None:
                from repro.obs import save_chrome_trace
                os.makedirs(args.trace, exist_ok=True)
                path = os.path.join(args.trace, f"trace_{name}.json")
                save_chrome_trace(tr, path)
                print(f"# trace: {path} ({len(tr.events)} events)",
                      flush=True)
        except Exception:
            failures.append(name)
            traceback.print_exc()
        print(f"# {name} done in {time.perf_counter() - t0:.1f}s", flush=True)
    if failures:
        print(f"# FAILURES: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
