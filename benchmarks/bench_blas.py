"""BLAS/LAPACK substrate micro-benchmarks (CPU wall time + derived Gflop/s)
and the codesign schedule comparison the paper's section 4 predicts."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import blas, lapack
from repro.core.codesign import optimal_accumulators


def _timeit(f, *args, reps=5):
    f(*args)                                    # compile
    jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def run(emit):
    rng = np.random.default_rng(0)
    n = 512
    a = jnp.asarray(rng.normal(size=(n, n)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(n, n)).astype(np.float32))
    t = _timeit(jax.jit(blas.dgemm), a, b)
    emit(f"blas,dgemm,{n}", t * 1e6, "us_per_call")
    emit(f"blas,dgemm,{n}", 2 * n ** 3 / t / 1e9, "gflops")

    x = jnp.asarray(rng.normal(size=1 << 20).astype(np.float32))
    y = jnp.asarray(rng.normal(size=1 << 20).astype(np.float32))
    for sched in ("tree", "sequential", "strided"):
        f = jax.jit(lambda u, v, s=sched: blas.ddot(u, v, schedule=s,
                                                    accumulators=optimal_accumulators(1 << 20)))
        t = _timeit(f, x, y, reps=3)
        emit(f"blas,ddot_{sched},1M", t * 1e6, "us_per_call")

    m = jnp.asarray(rng.normal(size=(192, 192)).astype(np.float32))
    for name, f in (("geqrf", jax.jit(lambda z: lapack.geqrf(z, block=32))),
                    ("getrf", jax.jit(lambda z: lapack.getrf(z, block=32)))):
        t = _timeit(f, m, reps=3)
        emit(f"lapack,{name},192", t * 1e3, "ms_per_call")
    s = m @ m.T + 192 * jnp.eye(192)
    t = _timeit(jax.jit(lambda z: lapack.potrf(z, block=32)), s, reps=3)
    emit("lapack,potrf,192", t * 1e3, "ms_per_call")
