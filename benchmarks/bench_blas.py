"""BLAS/LAPACK substrate micro-benchmarks (CPU wall time + derived Gflop/s)
and the codesign schedule comparison the paper's section 4 predicts.

Calls go through the :mod:`repro.linalg` front-end under one scoped
ExecutionContext; every JSON row records the dtype, the resolved context,
and a *per-op* kernel-config resolution, plus the shared timing fields of
``docs/benchmarking.md``: ``seconds_median`` / ``seconds_spread`` /
``reps`` from the :mod:`repro.tune.measure` repetition controller and a
``model_residual`` (modeled vs measured seconds under the row's machine) -
so trajectories stay comparable as the dispatch surface evolves and the
perf-regression gate (``scripts/check_perf_regression.py``) can defend
them.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro import arch, lapack, linalg, tune
from repro.core.codesign import (FACTOR_FLOP_COEFF, modeled_factorization_time,
                                 optimal_accumulators, plan_gemm)
from repro.tune.measure import measure, model_residual
from repro.tune.search import model_score

_OUT_DEFAULT = os.path.join(os.path.dirname(__file__), "out", "blas.json")
# maps the bench's row names onto the factorization-kind table the flop
# coefficients and the panel/trailing time model are keyed by
_FACTOR_KIND = {"geqrf": "geqrf", "lu": "getrf", "cholesky": "potrf"}


def _measured(f, *args, reps):
    """Adaptive measurement, bounded at 2x the historical rep count."""
    return measure(f, *args, min_reps=reps, max_reps=2 * reps)


def run(emit, policy: str = "reference", dtype=jnp.float32,
        fast: bool = False, out: str = _OUT_DEFAULT):
    rng = np.random.default_rng(0)
    rows = []
    dtype = jnp.dtype(dtype)
    n = 128 if fast else 512            # GEMM size
    nf = 96 if fast else 192            # factorization size
    block = 32
    gemm_reps, fact_reps = (2, 2) if fast else (5, 3)
    with linalg.use(policy=policy) as ctx:
        ctx_desc = ctx.describe()
        a = jnp.asarray(rng.normal(size=(n, n)).astype(np.float32)).astype(dtype)
        b = jnp.asarray(rng.normal(size=(n, n)).astype(np.float32)).astype(dtype)
        ms = _measured(jax.jit(lambda x, y: linalg.gemm(x, y)), a, b,
                       reps=gemm_reps)
        t = ms.seconds_median
        emit(f"blas,gemm,{n}", t * 1e6, "us_per_call")
        emit(f"blas,gemm,{n}", 2 * n ** 3 / t / 1e9, "gflops")
        gemm_model_s = model_score(plan_gemm(n, n, n, dtype=dtype),
                                   n, n, n, dtype.itemsize)
        rows.append({"op": "gemm", "n": n, "dtype": dtype.name,
                     "context": ctx_desc, "seconds_per_call": t,
                     **ms.row_fields(),
                     "model_residual": model_residual(gemm_model_s, t),
                     **arch.bench_metrics(2 * n ** 3 / t / 1e9),
                     "resolution": tune.resolve("gemm", (n, n, n), dtype,
                                                policy=policy).describe()})

        nd = 1 << (16 if fast else 20)
        x = jnp.asarray(rng.normal(size=nd).astype(np.float32))
        y = jnp.asarray(rng.normal(size=nd).astype(np.float32))
        for sched in ("tree", "sequential", "strided"):
            f = jax.jit(lambda u, v, s=sched: linalg.dot(
                u, v, schedule=s,
                accumulators=optimal_accumulators(nd)))
            ms = _measured(f, x, y, reps=3)
            emit(f"blas,dot_{sched},{nd >> 10}K", ms.seconds_median * 1e6,
                 "us_per_call")

        m = jnp.asarray(rng.normal(size=(nf, nf)).astype(np.float32))
        # geqrf times the packed factorization core (linalg.qr would add
        # the full Q accumulation); lu goes through the front-end
        s = m @ m.T + nf * jnp.eye(nf)
        for name, f, arg in (("geqrf", jax.jit(lambda z: lapack.geqrf(
                                  z, block=block, policy=policy)), m),
                             ("lu", jax.jit(lambda z: linalg.lu(
                                  z, block=block)), m),
                             ("cholesky", jax.jit(lambda z: linalg.cholesky(
                                  z, block=block)), s)):
            ms = _measured(f, arg, reps=fact_reps)
            t = ms.seconds_median
            emit(f"lapack,{name},{nf}", t * 1e3, "ms_per_call")
            kind = _FACTOR_KIND[name]
            # per-op resolution: the kernel config *this* op's widest
            # trailing update resolves to (one shared gemm resolution used
            # to be recorded for all three rows, misattributing configs)
            res = tune.resolve("gemm", (nf - block, nf - block, block),
                               jnp.float32, policy=policy).describe()
            fact_model_s = modeled_factorization_time(
                nf, kind=kind, block=block, dtype=jnp.float32)
            rows.append({"op": name, "n": nf, "block": block,
                         "dtype": "float32", "context": ctx_desc,
                         "seconds_per_call": t, **ms.row_fields(),
                         "model_residual": model_residual(fact_model_s, t),
                         "resolution": {"for_op": name, **res},
                         **arch.bench_metrics(
                             FACTOR_FLOP_COEFF[kind] * nf ** 3 / t / 1e9)})

    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump({"benchmark": "blas", "backend": jax.default_backend(),
                   "policy": policy, "fast": fast, "context": ctx_desc,
                   "rows": rows}, f, indent=2)
    emit("blas,json", out, "path")
