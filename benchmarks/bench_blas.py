"""BLAS/LAPACK substrate micro-benchmarks (CPU wall time + derived Gflop/s)
and the codesign schedule comparison the paper's section 4 predicts.

Calls go through the :mod:`repro.linalg` front-end under one scoped
ExecutionContext; every JSON row records the dtype, the resolved context,
and a *per-op* kernel-config resolution, plus the shared timing fields of
``docs/benchmarking.md``: ``seconds_median`` / ``seconds_spread`` /
``reps`` from the :mod:`repro.tune.measure` repetition controller and a
``model_residual`` (modeled vs measured seconds under the row's machine) -
so trajectories stay comparable as the dispatch surface evolves and the
perf-regression gate (``scripts/check_perf_regression.py``) can defend
them.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro import arch, lapack, linalg, tune
from repro.core.codesign import (FACTOR_FLOP_COEFF, modeled_factorization_time,
                                 optimal_accumulators, plan_fused_chain,
                                 plan_gemm)
from repro.tune.measure import measure, model_residual
from repro.tune.search import model_score

_OUT_DEFAULT = os.path.join(os.path.dirname(__file__), "out", "blas.json")
# maps the bench's row names onto the factorization-kind table the flop
# coefficients and the panel/trailing time model are keyed by
_FACTOR_KIND = {"geqrf": "geqrf", "lu": "getrf", "cholesky": "potrf"}


def _measured(f, *args, reps):
    """Adaptive measurement, bounded at 2x the historical rep count."""
    return measure(f, *args, min_reps=reps, max_reps=2 * reps)


def run(emit, policy: str = "reference", dtype=jnp.float32,
        fast: bool = False, out: str = _OUT_DEFAULT):
    rng = np.random.default_rng(0)
    rows = []
    dtype = jnp.dtype(dtype)
    n = 128 if fast else 512            # GEMM size
    nf = 96 if fast else 192            # factorization size
    block = 32
    gemm_reps, fact_reps = (2, 2) if fast else (5, 3)
    with linalg.use(policy=policy) as ctx:
        ctx_desc = ctx.describe()
        a = jnp.asarray(rng.normal(size=(n, n)).astype(np.float32)).astype(dtype)
        b = jnp.asarray(rng.normal(size=(n, n)).astype(np.float32)).astype(dtype)
        ms = _measured(jax.jit(lambda x, y: linalg.gemm(x, y)), a, b,
                       reps=gemm_reps)
        t = ms.seconds_median
        emit(f"blas,gemm,{n}", t * 1e6, "us_per_call")
        emit(f"blas,gemm,{n}", 2 * n ** 3 / t / 1e9, "gflops")
        gemm_model_s = model_score(plan_gemm(n, n, n, dtype=dtype),
                                   n, n, n, dtype.itemsize)
        rows.append({"op": "gemm", "n": n, "dtype": dtype.name,
                     "context": ctx_desc, "seconds_per_call": t,
                     **ms.row_fields(),
                     "model_residual": model_residual(gemm_model_s, t),
                     **arch.bench_metrics(2 * n ** 3 / t / 1e9),
                     "resolution": tune.resolve("gemm", (n, n, n), dtype,
                                                policy=policy).describe()})

        # fused GEMM+epilogue: time the front-end call and record the
        # chain model's modeled HBM bytes next to the resolved fuse
        # decision, so the trajectory tracks whether streaming the
        # epilogue through VMEM pays on this machine/policy
        ke = 64
        af = jnp.asarray(rng.normal(size=(n, ke)).astype(np.float32)).astype(dtype)
        bf = jnp.asarray(rng.normal(size=(ke, n)).astype(np.float32)).astype(dtype)
        bias = jnp.asarray(rng.normal(size=(n,)).astype(np.float32)).astype(dtype)
        chain = plan_fused_chain("gemm+epilogue", n, n, ke,
                                 dtype_bytes=dtype.itemsize, epilogue="relu")
        res_f = tune.resolve("gemm+epilogue", (n, n, ke), dtype,
                             policy=policy, epilogue="relu")
        ms = _measured(jax.jit(lambda x, y, bb: linalg.gemm_bias_act(
            x, y, bias=bb, epilogue="relu")), af, bf, bias, reps=gemm_reps)
        t = ms.seconds_median
        emit(f"blas,gemm_bias_act,{n}", t * 1e6, "us_per_call")
        rows.append({"op": "gemm_bias_act", "n": n, "k": ke,
                     "dtype": dtype.name, "context": ctx_desc,
                     "seconds_per_call": t, **ms.row_fields(),
                     "model_residual": model_residual(
                         chain.fused_time if res_f.fused
                         else chain.unfused_time, t),
                     "fused": bool(res_f.fused),
                     "modeled_hbm_bytes": (chain.fused_hbm_bytes if res_f.fused
                                           else chain.unfused_hbm_bytes),
                     "modeled_hbm_bytes_unfused": chain.unfused_hbm_bytes,
                     "hbm_bytes_saved": chain.hbm_bytes_saved,
                     "resolution": {"for_op": "gemm+epilogue",
                                    **res_f.describe()},
                     **arch.bench_metrics(2 * n * n * ke / t / 1e9)})

        # fused-chain pricing rows (modeled, never timed - the regression
        # gate skips them): one shape the default machine's chain model
        # fuses, one where cpu-host's small VMEM forces the chain apart
        for expect, mach_, (cm, cn, ck) in (
                ("win", None, (256, 256, 32)),
                ("lose", arch.get("cpu-host"), (2048, 2048, 64))):
            ch = plan_fused_chain("trsm+gemm", cm, cn, ck,
                                  dtype_bytes=dtype.itemsize, form="syrk",
                                  machine=mach_)
            assert ch.fused_wins == (expect == "win"), \
                f"chain model stopped pricing a fusion {expect} at " \
                f"{cm}x{cn}x{ck}"
            rows.append({"op": "fused_chain", "modeled_only": True,
                         "kind": "trsm+gemm",
                         "m": cm, "n": cn, "k": ck, "dtype": dtype.name,
                         "machine": arch.resolve_machine(mach_).name,
                         "expect": expect, "fused_wins": ch.fused_wins,
                         "fits_vmem": ch.fits_vmem,
                         "modeled_hbm_bytes": ch.fused_hbm_bytes,
                         "modeled_hbm_bytes_unfused": ch.unfused_hbm_bytes,
                         "hbm_bytes_saved": ch.hbm_bytes_saved,
                         "modeled_time_fused": ch.fused_time,
                         "modeled_time_unfused": ch.unfused_time})

        nd = 1 << (16 if fast else 20)
        x = jnp.asarray(rng.normal(size=nd).astype(np.float32))
        y = jnp.asarray(rng.normal(size=nd).astype(np.float32))
        for sched in ("tree", "sequential", "strided"):
            f = jax.jit(lambda u, v, s=sched: linalg.dot(
                u, v, schedule=s,
                accumulators=optimal_accumulators(nd)))
            ms = _measured(f, x, y, reps=3)
            emit(f"blas,dot_{sched},{nd >> 10}K", ms.seconds_median * 1e6,
                 "us_per_call")

        m = jnp.asarray(rng.normal(size=(nf, nf)).astype(np.float32))
        # geqrf times the packed factorization core (linalg.qr would add
        # the full Q accumulation); lu goes through the front-end
        s = m @ m.T + nf * jnp.eye(nf)
        for name, f, arg in (("geqrf", jax.jit(lambda z: lapack.geqrf(
                                  z, block=block, policy=policy)), m),
                             ("lu", jax.jit(lambda z: linalg.lu(
                                  z, block=block)), m),
                             ("cholesky", jax.jit(lambda z: linalg.cholesky(
                                  z, block=block)), s)):
            ms = _measured(f, arg, reps=fact_reps)
            t = ms.seconds_median
            emit(f"lapack,{name},{nf}", t * 1e3, "ms_per_call")
            kind = _FACTOR_KIND[name]
            # per-op resolution: the kernel config *this* op's widest
            # trailing update resolves to (one shared gemm resolution used
            # to be recorded for all three rows, misattributing configs)
            res = tune.resolve("gemm", (nf - block, nf - block, block),
                               jnp.float32, policy=policy).describe()
            fact_model_s = modeled_factorization_time(
                nf, kind=kind, block=block, dtype=jnp.float32)
            row = {"op": name, "n": nf, "block": block,
                   "dtype": "float32", "context": ctx_desc,
                   "seconds_per_call": t, **ms.row_fields(),
                   "model_residual": model_residual(fact_model_s, t),
                   "resolution": {"for_op": name, **res},
                   **arch.bench_metrics(
                       FACTOR_FLOP_COEFF[kind] * nf ** 3 / t / 1e9)}
            if name in ("lu", "cholesky"):
                # the trailing updates route through the trsm+gemm chain;
                # record the resolved fuse decision for the widest step
                form = "lu" if name == "lu" else "syrk"
                res_c = tune.resolve("trsm+gemm",
                                     (nf - block, nf - block, block),
                                     jnp.float32, policy=policy, form=form)
                row["fused"] = bool(res_c.fused)
                if res_c.chain is not None:
                    row["modeled_hbm_bytes"] = res_c.chain.fused_hbm_bytes \
                        if res_c.fused else res_c.chain.unfused_hbm_bytes
                    row["hbm_bytes_saved"] = res_c.chain.hbm_bytes_saved
            rows.append(row)

    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump({"benchmark": "blas", "backend": jax.default_backend(),
                   "policy": policy, "fast": fast, "context": ctx_desc,
                   "rows": rows}, f, indent=2)
    emit("blas,json", out, "path")
