"""BLAS/LAPACK substrate micro-benchmarks (CPU wall time + derived Gflop/s)
and the codesign schedule comparison the paper's section 4 predicts.

Calls go through the :mod:`repro.linalg` front-end under one scoped
ExecutionContext; every JSON row records the dtype and the resolved
context alongside the kernel-config resolution, so trajectories stay
comparable as the dispatch surface evolves.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro import arch, lapack, linalg, tune
from repro.core.codesign import FACTOR_FLOP_COEFF, optimal_accumulators
from repro.tune.search import measure_wall_time


def _timeit(f, *args, reps=5):
    return measure_wall_time(f, *args, reps=reps)


def run(emit, policy: str = "reference", dtype=jnp.float32):
    rng = np.random.default_rng(0)
    rows = []
    dtype = jnp.dtype(dtype)
    with linalg.use(policy=policy) as ctx:
        ctx_desc = ctx.describe()
        n = 512
        a = jnp.asarray(rng.normal(size=(n, n)).astype(np.float32)).astype(dtype)
        b = jnp.asarray(rng.normal(size=(n, n)).astype(np.float32)).astype(dtype)
        t = _timeit(jax.jit(lambda x, y: linalg.gemm(x, y)), a, b)
        emit(f"blas,gemm,{n}", t * 1e6, "us_per_call")
        emit(f"blas,gemm,{n}", 2 * n ** 3 / t / 1e9, "gflops")
        rows.append({"op": "gemm", "n": n, "dtype": dtype.name,
                     "context": ctx_desc, "seconds_per_call": t,
                     **arch.bench_metrics(2 * n ** 3 / t / 1e9),
                     "resolution": tune.resolve("gemm", (n, n, n), dtype,
                                                policy=policy).describe()})

        x = jnp.asarray(rng.normal(size=1 << 20).astype(np.float32))
        y = jnp.asarray(rng.normal(size=1 << 20).astype(np.float32))
        for sched in ("tree", "sequential", "strided"):
            f = jax.jit(lambda u, v, s=sched: linalg.dot(
                u, v, schedule=s,
                accumulators=optimal_accumulators(1 << 20)))
            t = _timeit(f, x, y, reps=3)
            emit(f"blas,dot_{sched},1M", t * 1e6, "us_per_call")

        m = jnp.asarray(rng.normal(size=(192, 192)).astype(np.float32))
        fact_res = tune.resolve("gemm", (192, 192, 32), jnp.float32,
                                policy=policy).describe()
        # geqrf times the packed factorization core (linalg.qr would add
        # the full Q accumulation); lu goes through the front-end
        for name, f in (("geqrf", jax.jit(lambda z: lapack.geqrf(
                            z, block=32, policy=policy))),
                        ("lu", jax.jit(lambda z: linalg.lu(z, block=32)))):
            t = _timeit(f, m, reps=3)
            emit(f"lapack,{name},192", t * 1e3, "ms_per_call")
            coeff = FACTOR_FLOP_COEFF[{"geqrf": "geqrf",
                                       "lu": "getrf"}[name]]
            rows.append({"op": name, "n": 192, "block": 32,
                         "dtype": "float32", "context": ctx_desc,
                         "seconds_per_call": t, "resolution": fact_res,
                         **arch.bench_metrics(
                             coeff * 192 ** 3 / t / 1e9)})
        s = m @ m.T + 192 * jnp.eye(192)
        t = _timeit(jax.jit(lambda z: linalg.cholesky(z, block=32)), s,
                    reps=3)
        emit("lapack,cholesky,192", t * 1e3, "ms_per_call")
        rows.append({"op": "cholesky", "n": 192, "block": 32,
                     "dtype": "float32", "context": ctx_desc,
                     "seconds_per_call": t, "resolution": fact_res,
                     **arch.bench_metrics(
                         FACTOR_FLOP_COEFF["potrf"] * 192 ** 3 / t / 1e9)})

    out = os.path.join(os.path.dirname(__file__), "out", "blas.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump({"benchmark": "blas", "backend": jax.default_backend(),
                   "policy": policy, "context": ctx_desc, "rows": rows}, f,
                  indent=2)
    emit("blas,json", out, "path")
