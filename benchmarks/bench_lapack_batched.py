"""Batched blocked LAPACK sweep: batch x size x block wall time + Gflop/s.

Records the trajectory the ISSUE-1 tentpole opens: how the batched
factorizations scale as the trailing updates ride the GEMM hot path, and
how the measured best block compares with the codesign model's
``plan_factorization`` choice.

Standalone:  PYTHONPATH=src python -m benchmarks.bench_lapack_batched \
                 [--fast] [--out benchmarks/out/lapack_batched.json]
Driver:      registered in benchmarks.run as "lapack_batched".
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro import arch, lapack, linalg, tune
from repro.core.codesign import FACTOR_FLOP_COEFF as FLOP_COEFF
from repro.core.codesign import (modeled_factorization_time,
                                 plan_factorization)
from repro.tune.measure import measure, model_residual

FACTOR_FN = {"potrf": lapack.batched_potrf, "getrf": lapack.batched_getrf,
             "geqrf": lapack.batched_geqrf}


def sweep(batches=(1, 8, 32), sizes=(32, 64, 128), blocks=(8, 16, 32, None),
          kinds=("potrf", "getrf", "geqrf"), reps=3, policy="reference",
          dtype=jnp.float32):
    """Returns a list of row dicts, one per (kind, batch, n, block); every
    row carries the dtype, the resolved ExecutionContext, and the policy
    its trailing updates resolved through the repro.tune dispatcher."""
    rng = np.random.default_rng(0)
    rows = []
    dtype = jnp.dtype(dtype)
    ctx_desc = linalg.ExecutionContext(policy=policy).describe()
    for kind in kinds:
        fn = FACTOR_FN[kind]
        for n in sizes:
            a = rng.normal(size=(max(batches), n, n)).astype(np.float32)
            if kind == "potrf":
                a = a @ np.swapaxes(a, 1, 2) + n * np.eye(n, dtype=np.float32)
            a = a.astype(dtype)
            gemm_cfg = tune.resolve(
                "gemm", (n, n, n), dtype, policy=policy).describe()
            for b in batches:
                x = jnp.asarray(a[:b])
                for block in blocks:
                    f = jax.jit(lambda m, k=kind, nb=block: FACTOR_FN[k](
                        m, block=nb, policy=policy).factors)
                    ms = measure(f, x, min_reps=reps, max_reps=2 * reps)
                    t = ms.seconds_median
                    flops = b * FLOP_COEFF[kind] * n ** 3
                    nb_eff = (block if block is not None else
                              plan_factorization(n, kind=kind).block)
                    model_s = modeled_factorization_time(
                        n, kind=kind, block=nb_eff, batch=b, dtype=dtype)
                    row = {
                        "kind": kind, "batch": b, "n": n,
                        "block": nb_eff,
                        "planned": block is None,
                        "policy": policy,
                        "dtype": dtype.name,
                        "context": ctx_desc,
                        "trailing_resolution": gemm_cfg,
                        "seconds_per_call": t, **ms.row_fields(),
                        "model_residual": model_residual(model_s, t),
                        **arch.bench_metrics(flops / t / 1e9),
                    }
                    if kind in ("potrf", "getrf") and n > nb_eff:
                        # the per-item trailing updates route through the
                        # trsm+gemm chain; record its resolved fuse verdict
                        # and modeled HBM traffic for the widest step
                        form = "syrk" if kind == "potrf" else "lu"
                        res_c = tune.resolve(
                            "trsm+gemm", (n - nb_eff, n - nb_eff, nb_eff),
                            dtype, policy=policy, form=form)
                        row["fused"] = bool(res_c.fused)
                        if res_c.chain is not None:
                            ch = res_c.chain
                            row["modeled_hbm_bytes"] = ch.fused_hbm_bytes \
                                if res_c.fused else ch.unfused_hbm_bytes
                            row["hbm_bytes_saved"] = ch.hbm_bytes_saved
                    rows.append(row)
    return rows


def record(rows) -> dict:
    """JSON record: config + rows + per-(kind, batch, n) best block vs the
    codesign model's pick."""
    best = {}
    for r in rows:
        key = (r["kind"], r["batch"], r["n"])
        if key not in best or r["seconds_per_call"] < best[key]["seconds_per_call"]:
            best[key] = r
    summary = [{
        "kind": k, "batch": b, "n": n,
        "best_block": v["block"],
        "best_gflops": v["gflops"],
        "planned_block": plan_factorization(n, kind=k, batch=b).block,
    } for (k, b, n), v in sorted(best.items())]
    return {
        "benchmark": "lapack_batched",
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "policy": rows[0]["policy"] if rows else None,
        "dtype": rows[0]["dtype"] if rows else None,
        "context": rows[0]["context"] if rows else None,
        "rows": rows,
        "summary": summary,
    }


# CI-sized grid shared by run() and main(--fast)
FAST_GRID = dict(batches=(1, 8), sizes=(32, 64), blocks=(8, 16, None), reps=2)


def run(emit, fast: bool = True):
    """benchmarks.run driver entry: CSV rows + JSON artifact."""
    rows = sweep(**FAST_GRID) if fast else sweep()
    for r in rows:
        name = f"lapack_batched,{r['kind']},b{r['batch']},n{r['n']},nb{r['block']}"
        emit(name, r["seconds_per_call"] * 1e3, "ms_per_call")
        emit(name, r["gflops"], "gflops")
    out = os.path.join(os.path.dirname(__file__), "out", "lapack_batched.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(record(rows), f, indent=2)
    emit("lapack_batched,json", out, "path")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="benchmarks/out/lapack_batched.json")
    ap.add_argument("--fast", action="store_true",
                    help="small grid (CI-sized)")
    args = ap.parse_args()
    rows = sweep(**FAST_GRID) if args.fast else sweep()
    rec = record(rows)
    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=2)
    print(f"wrote {len(rows)} rows -> {args.out}")
    for s in rec["summary"]:
        print(f"{s['kind']:6s} batch={s['batch']:<3d} n={s['n']:<4d} "
              f"best_block={s['best_block']:<4} model={s['planned_block']:<4} "
              f"{s['best_gflops']:.2f} Gflop/s")


if __name__ == "__main__":
    main()
