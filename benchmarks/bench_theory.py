"""Paper figs 2-4: theoretical TPI curves. Emits CSV rows + derived optima."""
from __future__ import annotations

import numpy as np

from repro.core import pipeline_model as pm


def run(emit):
    # Fig 2: TPI vs workload size
    for (p, r), (grid, vals) in pm.figure2_curves().items():
        sat = float(vals[-1])
        emit(f"fig2,p={p},ratio={r}", sat, "saturated_tpi")
    # Fig 3: TPI vs depth, varying hazard ratio
    for r, (grid, vals) in pm.figure3_curves().items():
        i = int(np.argmin(np.asarray(vals)))
        emit(f"fig3,ratio={r}", float(grid[i]), "argmin_depth")
    # Fig 4: TPI vs depth, varying gamma
    for g, (grid, vals) in pm.figure4_curves().items():
        i = int(np.argmin(np.asarray(vals)))
        emit(f"fig4,gamma={g}", float(grid[i]), "argmin_depth")
    # closed-form optima for the paper's remark sweep
    for ratio in (0.001, 0.01, 0.1, 0.8):
        popt = float(pm.p_opt(n_i=1e6, n_h=ratio * 1e6, gamma=0.5))
        emit(f"eq3,ratio={ratio}", popt, "p_opt")
