"""Paper figs 12-13: simulated CPI/TPI vs pipeline depths on the PE.

Fig 12: matrix multiplication, QR, LU with varying adder+multiplier depth.
Fig 13: QR, LU with varying sqrt+divider depth.
Matrix size is reduced from the paper's 100x100 (multi-million-instruction
streams) to 48x48 by default to keep the benchmark minutes-scale on one CPU
core; pass n=100 for the faithful size.
"""
from __future__ import annotations

import numpy as np

from repro import arch
from repro.core import isa, pe


def run(emit, n: int = 48):
    machine = arch.get("paper-pe")        # the PE under test
    emit("machine", machine.name, "name")
    emit("machine,peak", machine.peak_gflops_per_w(), "gflops_per_w")
    emit("machine,peak", machine.peak_gflops_per_mm2(), "gflops_per_mm2")
    depths = [2, 4, 6, 8, 12, 16, 24]
    streams = {
        "dgemm": isa.compile_dgemm(n, n, n, unroll=4),
        "dgeqrf": isa.compile_dgeqrf(n),
        "dgetrf": isa.compile_dgetrf(n),
    }
    for name, stream in streams.items():
        emit(f"fig12,{name}", stream.n_instructions, "instructions")
        res = pe.sweep_joint(stream, ["add", "mul"], depths)
        for r in res:
            emit(f"fig12,{name},p={r.depths['add']}", r.cpi, "cpi")
            emit(f"fig12,{name},p={r.depths['add']}", r.tpi, "tpi")
        best = min(res, key=lambda r: r.tpi)
        emit(f"fig12,{name}", best.depths["add"], "best_depth_tpi")
    for name in ("dgeqrf", "dgetrf"):
        res = pe.sweep_joint(streams[name], ["sqrt", "div"], depths)
        for r in res:
            emit(f"fig13,{name},p={r.depths['sqrt']}", r.cpi, "cpi")
            emit(f"fig13,{name},p={r.depths['sqrt']}", r.tpi, "tpi")
        best = min(res, key=lambda r: r.tpi)
        emit(f"fig13,{name}", best.depths["sqrt"], "best_depth_tpi")
    # enhanced PE (DOT4) vs LAP-PE (FMAC) cycle comparison on GEMM
    d4 = isa.compile_dgemm(n, n, n, unroll=4, dot4=True)
    base = {"mul": 5, "add": 4}
    emit("sec5,dot4_gemm", pe.simulate(d4, base).cycles, "cycles")
    emit("sec5,scalar_gemm", pe.simulate(streams["dgemm"], base).cycles,
         "cycles")
