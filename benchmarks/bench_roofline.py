"""Roofline table reader: aggregates results/dryrun/*.json into the
EXPERIMENTS.md section-Roofline rows (terms, dominant, useful-flop ratio)."""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.environ.get("DRYRUN_DIR", "results/dryrun")


def rows():
    out = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def run(emit):
    rs = rows()
    if not rs:
        emit("roofline,missing", 0, f"no dry-run results in {RESULTS}")
        return
    for r in rs:
        tag = f"roofline,{r['arch']},{r['shape']},{r['mesh']}"
        emit(tag, r["compute_s"] * 1e3, "compute_ms")
        emit(tag, r["memory_s"] * 1e3, "memory_ms")
        emit(tag, r["collective_s"] * 1e3, "collective_ms")
        emit(tag, r["roofline_fraction"], "roofline_fraction")
        emit(tag, r["useful_flop_ratio"], "useful_flop_ratio")
        emit(tag, r["bytes_per_device"] / 2 ** 30, "gib_per_device")
        emit(tag, 1.0 if r["dominant"] == "compute" else
             (2.0 if r["dominant"] == "memory" else 3.0),
             f"dominant={r['dominant']}")
