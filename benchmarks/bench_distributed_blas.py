"""Distributed BLAS sweep: mesh shape x matrix size x policy -> trajectory.

Runs SUMMA GEMM and the mesh-parallel batched factorizations - through
the :mod:`repro.linalg` front-end with a mesh-bearing ExecutionContext,
so the routing layer itself is on the measured path - over every mesh
shape that fits the device count,
recording wall time, the resolved kernel config (including the registry's
mesh key component), and the :func:`repro.core.codesign.plan_pdgemm` model
terms (compute vs per-hop collective bytes) - so the cross-device
co-design claim is a measured trajectory, not prose.

Device note: XLA fixes the host device count at first jax init, so
standalone runs force 8 virtual CPU devices via ``XLA_FLAGS`` *before*
importing jax, and the ``benchmarks.run`` driver entry re-execs this
module in a subprocess (the driver process already initialized jax with 1
device).

Standalone:  PYTHONPATH=src python -m benchmarks.bench_distributed_blas \
                 [--fast] [--out benchmarks/out/BENCH_distributed.json]
Driver:      registered in benchmarks.run as "distributed_blas".
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

_N_DEVICES = 8
_DEV_FLAG = f"--xla_force_host_platform_device_count={_N_DEVICES}"


def _with_device_flag(flags: str) -> str:
    """Append the forced-device-count flag to an XLA_FLAGS value,
    preserving whatever else is already there."""
    if "xla_force_host_platform_device_count" in flags:
        return flags
    return f"{flags} {_DEV_FLAG}".strip()


if __name__ == "__main__":  # force the virtual mesh before jax initializes
    os.environ["XLA_FLAGS"] = _with_device_flag(os.environ.get("XLA_FLAGS", ""))

import jax
import jax.numpy as jnp
import numpy as np

MESHES = [(1, 1), (2, 2), (4, 2)]
GEMM_SHAPES = [(64, 64, 64), (128, 128, 64)]
FAST_GEMM = [(32, 32, 32), (64, 48, 32)]
FACTOR_GRID = [("potrf", 8, 48), ("getrf", 8, 48)]
FAST_FACTOR = [("potrf", 8, 32), ("getrf", 8, 32)]
POLICIES = ("reference", "model", "tuned")
_OUT_DEFAULT = os.path.join(os.path.dirname(__file__), "out",
                            "BENCH_distributed.json")


def sweep(gemm_shapes=None, factor_grid=None, policies=POLICIES, reps=1):
    """Returns trajectory rows over mesh x shape x policy; every row
    records the mesh shape and the resolved config."""
    from repro import arch as _arch
    from repro import linalg
    from repro.blas import distributed as dblas
    from repro.core.codesign import (FACTOR_FLOP_COEFF,
                                     modeled_factorization_time, plan_pdgemm)
    from repro.tune import dispatch
    from repro.tune.measure import measure, model_residual

    rng = np.random.default_rng(0)
    rows = []
    ndev = jax.device_count()
    if ndev < _N_DEVICES:
        print(f"WARNING: only {ndev} device(s) visible (want {_N_DEVICES}; "
              f"XLA_FLAGS must carry {_DEV_FLAG} before jax initializes) - "
              f"multi-device meshes will be skipped", file=sys.stderr)
    meshes = [(px, py) for px, py in MESHES if px * py <= ndev]
    for px, py in meshes:
        mesh = dblas.make_blas_mesh(px, py)
        mkey = dblas.mesh_key(mesh)
        for m, n, k in (gemm_shapes if gemm_shapes is not None
                        else GEMM_SHAPES):
            a = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
            b = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
            plan = plan_pdgemm(m, n, k, px, py, dtype_bytes=4)
            for pol in policies:
                res = dispatch.resolve("pdgemm", (m, n, k), jnp.float32,
                                       policy=pol, mesh=(px, py))
                ctx = dict(policy=pol, mesh=(px, py))
                f = jax.jit(lambda x, y, c=dict(ctx): linalg.gemm(
                    x, y, context=c))
                ms = measure(f, a, b, min_reps=reps, max_reps=2 * reps)
                t = ms.seconds_median
                rows.append({
                    "op": "pdgemm", "mesh": [px, py], "mesh_key": mkey,
                    "shape": [m, n, k], "policy": pol,
                    "dtype": "float32",
                    "context": linalg.ExecutionContext(**ctx).describe(),
                    "resolution": res.describe(),
                    "seconds_per_call": t, **ms.row_fields(),
                    "model_residual": model_residual(plan.modeled_time, t),
                    **_arch.bench_metrics(2.0 * m * n * k / t / 1e9),
                    "model": {"compute_s": plan.compute_s,
                              "collective_s": plan.collective_s,
                              "collective_bytes": plan.collective_bytes,
                              "collective_bound": plan.collective_bound,
                              "steps": plan.steps, "k_fine": plan.k_fine},
                })
        for kind, batch, nsz in (factor_grid if factor_grid is not None
                                 else FACTOR_GRID):
            x = rng.normal(size=(batch, nsz, nsz)).astype(np.float32)
            if kind == "potrf":
                x = x @ np.swapaxes(x, 1, 2) + nsz * np.eye(
                    nsz, dtype=np.float32)
            xj = jnp.asarray(x)
            fn = {"potrf": linalg.batched_cholesky,
                  "getrf": linalg.batched_lu}[kind]
            for pol in policies:
                ctx = dict(policy=pol, mesh=(px, py))
                f = jax.jit(lambda v, c=dict(ctx): fn(
                    v, context=c).factors)
                ms = measure(f, xj, min_reps=reps, max_reps=2 * reps)
                t = ms.seconds_median
                res = dispatch.resolve("gemm", (nsz, nsz, nsz), jnp.float32,
                                       policy=pol)
                flops = batch * FACTOR_FLOP_COEFF[kind] * nsz ** 3
                model_s = modeled_factorization_time(
                    nsz, kind=kind, batch=batch, dtype=jnp.float32)
                rows.append({
                    "op": f"batched_{kind}", "mesh": [px, py],
                    "mesh_key": mkey, "shape": [batch, nsz, nsz],
                    "policy": pol, "dtype": "float32",
                    "context": linalg.ExecutionContext(**ctx).describe(),
                    "resolution": res.describe(),
                    "seconds_per_call": t, **ms.row_fields(),
                    "model_residual": model_residual(model_s, t),
                    **_arch.bench_metrics(flops / t / 1e9),
                })
    return rows


def record(rows) -> dict:
    return {
        "benchmark": "distributed_blas",
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "meshes": sorted({tuple(r["mesh"]) for r in rows}),
        "rows": rows,
    }


def _emit_rows(emit, rec) -> None:
    for r in rec["rows"]:
        mesh = "x".join(str(d) for d in r["mesh"])
        shape = "x".join(str(d) for d in r["shape"])
        name = f"distributed_blas,{r['op']},mesh{mesh},{shape},{r['policy']}"
        emit(name, r["seconds_per_call"] * 1e3, "ms_per_call")
        if "gflops" in r:
            emit(name, r["gflops"], "gflops")


def run(emit, fast: bool = True):
    """benchmarks.run driver entry. The driver process has 1 device, so
    re-exec this module standalone (subprocess) with the forced-device
    XLA flag, then emit from its JSON artifact."""
    out = _OUT_DEFAULT
    os.makedirs(os.path.dirname(out), exist_ok=True)
    env = dict(os.environ,
               XLA_FLAGS=_with_device_flag(os.environ.get("XLA_FLAGS", "")),
               PYTHONPATH="src" + (os.pathsep + os.environ["PYTHONPATH"]
                                   if os.environ.get("PYTHONPATH") else ""))
    cmd = [sys.executable, "-m", "benchmarks.bench_distributed_blas",
           "--out", out] + (["--fast"] if fast else [])
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    r = subprocess.run(cmd, env=env, cwd=root, capture_output=True,
                       text=True, timeout=3600)
    if r.returncode != 0:
        raise RuntimeError(
            f"distributed sweep subprocess failed:\n{r.stdout}\n{r.stderr}")
    with open(out) as f:
        rec = json.load(f)
    _emit_rows(emit, rec)
    emit("distributed_blas,device_count", rec["device_count"], "devices")
    emit("distributed_blas,json", out, "path")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=_OUT_DEFAULT)
    ap.add_argument("--fast", action="store_true", help="CI-sized grid")
    args = ap.parse_args()
    rows = sweep(gemm_shapes=FAST_GEMM if args.fast else None,
                 factor_grid=FAST_FACTOR if args.fast else None,
                 reps=1 if args.fast else 2)
    rec = record(rows)
    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=2)
    print(f"wrote {len(rows)} rows -> {args.out} "
          f"({rec['device_count']} devices)")
    for r in rows:
        mesh = "x".join(str(d) for d in r["mesh"])
        shape = "x".join(str(d) for d in r["shape"])
        extra = f" {r['gflops']:8.3f} Gflop/s" if "gflops" in r else ""
        print(f"{r['op']:14s} mesh={mesh:4s} {shape:>10s} "
              f"{r['policy']:9s} {r['seconds_per_call']*1e3:9.2f} ms{extra}")


if __name__ == "__main__":
    main()
