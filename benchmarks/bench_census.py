"""Per-architecture op-class census (the paper's section 4 applied to the
model zoo): hazard ratios + optimal pipe depths per assigned arch, derived
mechanically from reduced-config train-step jaxprs."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.core import jaxpr_census as jc
from repro.launch.train import reduce_config
from repro.models import model_zoo as zoo


def run(emit):
    for arch in registry.ARCHS:
        cfg = reduce_config(registry.get_config(arch), layers=2, d_model=64,
                            vocab=128, heads=4)
        cfg = dataclasses.replace(cfg, dtype="float32")
        params = jax.eval_shape(lambda k: zoo.init(k, cfg),
                                jax.ShapeDtypeStruct((2,), jnp.uint32))
        batch = {"tokens": jax.ShapeDtypeStruct((2, 32), jnp.int32)}
        if cfg.family == "encdec":
            batch["frames"] = jax.ShapeDtypeStruct((2, 16, cfg.d_model),
                                                   jnp.float32)
        if cfg.family == "vlm":
            batch["patches"] = jax.ShapeDtypeStruct(
                (2, cfg.num_prefix_tokens, cfg.d_model), jnp.float32)

        def loss(p, bt):
            logits, aux = zoo.forward(p, bt, cfg)
            return jnp.sum(logits.astype(jnp.float32)) + aux

        c = jc.census_of(lambda p, bt: jax.grad(
            lambda pp: loss(pp, bt))(p), params, batch, name=arch)
        prof = c.to_profile()
        depths = prof.optimal_depths()
        for k in ("mul", "add", "div", "sqrt"):
            if prof.pipes[k].n_i > 0:
                emit(f"census,{arch},{k}",
                     prof.pipes[k].n_h / prof.pipes[k].n_i, "hazard_ratio")
                emit(f"census,{arch},{k}", depths[k], "p_opt")
        emit(f"census,{arch}", c.flops, "train_flops")
