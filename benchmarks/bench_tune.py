"""Tuner sweep: measured GEMM/TRSM configs -> persistent registry + trajectory.

Runs the :mod:`repro.tune.search` sweeps over a standard shape grid, writes
the winning configs to ``tune_registry.json`` (the cache
``REPRO_TUNE_REGISTRY`` should point at), and records the full trajectory -
every measured candidate, the model's own pick, and the post-sweep
``dispatch.resolve`` outcome per shape - to ``BENCH_tune.json`` so tuning
quality is comparable across PRs.

Standalone:  PYTHONPATH=src python -m benchmarks.bench_tune \
                 [--fast] [--out-dir benchmarks/out]
Driver:      registered in benchmarks.run as "tune".
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp

from repro import arch, linalg
from repro.tune import dispatch, search
from repro.tune.registry import Registry

GEMM_SHAPES = [(64, 64, 64), (128, 128, 64), (128, 64, 128)]
TRSM_SHAPES = [(64, 8), (128, 8)]
FAST_GEMM = [(32, 32, 32), (64, 64, 64)]
FAST_TRSM = [(48, 4)]
# measured sweeps per dtype the kernel path executes on this backend;
# float64 additionally gets model-seeded entries (no measurement - the
# default jax config would silently downcast the operands)
SWEEP_DTYPES = (jnp.float32, jnp.bfloat16)
SEED_DTYPES = (jnp.float64,)


def sweep(registry: Registry, gemm_shapes=None, trsm_shapes=None,
          top_k: int = 3, reps: int = 2, dtypes=SWEEP_DTYPES):
    """Run every sweep into ``registry`` per dtype; returns trajectory
    rows. Non-measurable dtypes (float64 without X64) get model-seeded
    registry entries via :func:`repro.tune.search.seed_registry_from_model`
    so their tuned lookups hit real configs instead of falling back."""
    rows = []
    gshapes = gemm_shapes if gemm_shapes is not None else GEMM_SHAPES
    tshapes = trsm_shapes if trsm_shapes is not None else TRSM_SHAPES

    def _with_winner_stats(r):
        """Lift the winning candidate's controller stats (median / spread /
        reps / model_residual) to the row's top level, the shared bench-row
        convention the perf-regression gate reads."""
        win = min(r["measured"], key=lambda c: c["seconds"])
        r.update({k: win[k] for k in ("seconds_median", "seconds_spread",
                                      "reps", "model_residual")})
        return r

    for dtype in dtypes:
        for m, n, k in gshapes:
            r = _with_winner_stats(search.tune_gemm(
                m, n, k, dtype=dtype, registry=registry,
                top_k=top_k, reps=reps).to_json())
            r.update(arch.bench_metrics(
                2.0 * m * n * k / max(r["best"]["measured_s"], 1e-12) / 1e9))
            rows.append(r)
        for n, nrhs in tshapes:
            r = _with_winner_stats(search.tune_trsm(
                n, nrhs, dtype=dtype, registry=registry,
                reps=reps).to_json())
            r.update(arch.bench_metrics(
                n * n * nrhs / max(r["best"]["measured_s"], 1e-12) / 1e9))
            rows.append(r)
    search.seed_registry_from_model(registry, gemm_shapes=gshapes,
                                    trsm_shapes=tshapes, dtypes=SEED_DTYPES)
    return rows


def record(registry: Registry, rows) -> dict:
    """JSON record: trajectory + the resolution every row now gets from the
    freshly written registry (must be source="registry" - a lookup miss
    here means the schema broke)."""
    resolutions = []
    for r in rows:
        res = dispatch.resolve(r["op"], tuple(r["shape"]), jnp.dtype(r["dtype"]),
                               policy="tuned", registry=registry)
        resolutions.append(res.describe())
    return {
        "benchmark": "tune",
        "backend": jax.default_backend(),
        "policy": "tuned",
        "dtypes": sorted({r["dtype"] for r in rows}),
        "context": linalg.ExecutionContext(
            policy="tuned", registry=registry.path).describe(),
        "registry_path": registry.path,
        "registry_entries": len(registry),
        "rows": rows,
        "resolutions": resolutions,
        "all_hits": all(r["source"] == "registry" for r in resolutions),
    }


def run(emit, fast: bool = True):
    """benchmarks.run driver entry: CSV rows + registry + JSON artifact."""
    out_dir = os.path.join(os.path.dirname(__file__), "out")
    os.makedirs(out_dir, exist_ok=True)
    reg = Registry(path=os.path.join(out_dir, "tune_registry.json"))
    rows = sweep(reg, gemm_shapes=FAST_GEMM if fast else None,
                 trsm_shapes=FAST_TRSM if fast else None,
                 top_k=2 if fast else 3, reps=1 if fast else 2)
    reg.save()
    rec = record(reg, rows)
    for r in rows:
        shape = "x".join(str(d) for d in r["shape"])
        cfg = "/".join(f"{k}={v}" for k, v in sorted(r["best"]["params"].items()))
        emit(f"tune,{r['op']},{shape},{cfg}", r["best"]["measured_s"] * 1e3,
             "ms_per_call")
    emit("tune,registry", reg.path, "path")
    out = os.path.join(out_dir, "BENCH_tune.json")
    with open(out, "w") as f:
        json.dump(rec, f, indent=2)
    emit("tune,all_hits", int(rec["all_hits"]), "bool")
    emit("tune,json", out, "path")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="benchmarks/out")
    ap.add_argument("--fast", action="store_true", help="CI-sized grid")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    reg = Registry(path=os.path.join(args.out_dir, "tune_registry.json"))
    rows = sweep(reg, gemm_shapes=FAST_GEMM if args.fast else None,
                 trsm_shapes=FAST_TRSM if args.fast else None,
                 top_k=2 if args.fast else 3, reps=1 if args.fast else 2)
    reg.save()
    rec = record(reg, rows)
    out = os.path.join(args.out_dir, "BENCH_tune.json")
    with open(out, "w") as f:
        json.dump(rec, f, indent=2)
    print(f"wrote {len(rows)} sweeps -> {out}; registry -> {reg.path} "
          f"({len(reg)} entries, all_hits={rec['all_hits']})")
    for r in rows:
        print(f"{r['op']:5s} {'x'.join(str(d) for d in r['shape']):>12s} "
              f"best={r['best']['params']} model={r['model_params']}")


if __name__ == "__main__":
    main()
