"""Paper Tables 1-2: synthesis model, efficiency ratios, abstract claims."""
from __future__ import annotations

from repro.core import synthesis as syn


def run(emit):
    derived = syn.derive_table2()
    for speed, row in sorted(derived.items(), reverse=True):
        for k, v in row.items():
            emit(f"table2,{speed}GHz,{k}", v, "derived")
    pub = syn.TABLE2_PUBLISHED
    for speed, (lm, lw, pm, pw) in pub.items():
        emit(f"table2pub,{speed}GHz,pe_gflops_w", pw, "published")
    ratios = syn.efficiency_ratios()
    for metric, per_speed in ratios.items():
        for speed, r in sorted(per_speed.items(), reverse=True):
            emit(f"ratio,{metric},{speed}GHz", r, "pe_over_lappe")
    checks = syn.check_table2()
    emit("table2,check", max(checks["checked"].values()), "max_rel_err")
    for k, v in checks["discrepant"].items():
        emit(f"table2,paper_inconsistency,{k}", v, "rel_err_vs_table1")
    for design in ("lap-pe", "pe"):
        for f in (0.2, 0.95, 1.81):
            emit(f"energy,{design},{f}GHz",
                 syn.energy_per_flop_pj(design, f), "pJ_per_flop")
