#!/usr/bin/env bash
# CI gate: fast import-error guard first (a broken import chain once hid 9
# test modules from the suite - see ISSUE 1), then the tier-1 suite.
#
# Usage: scripts/ci_check.sh [extra pytest args...]
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

echo "== collection guard (zero import errors required) =="
python -m pytest --collect-only -q

echo "== tuner smoke (tiny sweep -> tmpdir registry -> lookup must hit) =="
python - <<'PY'
import tempfile, os, sys
import jax, jax.numpy as jnp
from repro.tune import dispatch, search
from repro.tune.registry import Registry

with tempfile.TemporaryDirectory() as d:
    reg = Registry(path=os.path.join(d, "registry.json"))
    search.tune_gemm(16, 16, 16, registry=reg, top_k=1, reps=1)
    search.tune_trsm(32, 4, registry=reg, reps=1, blocks=(16,))
    path = reg.save()
    reloaded = Registry(path=path)
    backend = jax.default_backend()
    for op, shape in (("gemm", (16, 16, 16)), ("trsm", (32, 4))):
        assert reloaded.lookup(op, shape, jnp.float32, backend) is not None, \
            f"registry round-trip lost the {op} entry"
        res = dispatch.resolve(op, shape, jnp.float32, policy="tuned",
                               registry=reloaded)
        assert res.source == "registry", \
            f"{op} resolution missed the registry: {res.source}"
print("tuner smoke OK: sweep -> save -> reload -> registry hit")
PY

echo "== tier-1 suite =="
python -m pytest -x -q "$@"
