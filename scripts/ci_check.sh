#!/usr/bin/env bash
# CI gate: fast import-error guard first (a broken import chain once hid 9
# test modules from the suite - see ISSUE 1), then the tier-1 suite.
#
# Usage: scripts/ci_check.sh [extra pytest args...]
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

echo "== collection guard (zero import errors required) =="
python -m pytest --collect-only -q

echo "== tier-1 suite =="
python -m pytest -x -q "$@"
