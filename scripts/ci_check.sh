#!/usr/bin/env bash
# CI gate: fast import-error guard first (a broken import chain once hid 9
# test modules from the suite - see ISSUE 1), then the tier-1 suite.
#
# Usage: scripts/ci_check.sh [extra pytest args...]
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

echo "== collection guard (zero import errors required) =="
python -m pytest --collect-only -q

echo "== static analysis gate (trace-time lint of the linalg surface) =="
# trace-only: no kernel executes; fails on any unsuppressed error-severity
# finding (rule vocabulary in docs/static_analysis.md). Split in two so a
# base-grid failure is distinguishable from a distributed/SPMD one.
python scripts/check_static_analysis.py --no-mesh --no-bypass

echo "== SPMD static analysis (meshes x direct pdgemm/pdtrsm + BY001) =="
# sharded legs over SURFACE_MESHES (1x1, 2x2, 4x2) plus the direct
# distributed entry points - the script forces 8 host devices itself so
# no mesh case ever skips - then the dispatcher-bypass burn-down lint
# (a raw contraction off the committed allowlist fails here)
python scripts/check_static_analysis.py --spmd-only
python - <<'PY'
import os, subprocess, sys
# BY001 gate: committed burn-down allowlist must cover every current
# bypass site and stay non-empty (the debt is tracked, not hidden)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, "src")
from repro.analysis import bypass_lint
rep = bypass_lint.lint_bypass()
print(rep.summary().splitlines()[0])
assert rep.ok, "new dispatcher-bypass site(s):\n" + rep.summary()
assert rep.suppressed, "bypass allowlist is empty - BY001 checked nothing"
print(f"bypass burn-down OK: {len(rep.suppressed)} allowlisted site(s), "
      "no new bypasses")
PY

echo "== tuner smoke (tiny sweep -> tmpdir registry -> lookup must hit) =="
python - <<'PY'
import tempfile, os, sys
import jax, jax.numpy as jnp
from repro.tune import dispatch, search
from repro.tune.registry import Registry

with tempfile.TemporaryDirectory() as d:
    reg = Registry(path=os.path.join(d, "registry.json"))
    search.tune_gemm(16, 16, 16, registry=reg, top_k=1, reps=1)
    search.tune_trsm(32, 4, registry=reg, reps=1, blocks=(16,))
    path = reg.save()
    reloaded = Registry(path=path)
    backend = jax.default_backend()
    for op, shape in (("gemm", (16, 16, 16)), ("trsm", (32, 4))):
        assert reloaded.lookup(op, shape, jnp.float32, backend) is not None, \
            f"registry round-trip lost the {op} entry"
        res = dispatch.resolve(op, shape, jnp.float32, policy="tuned",
                               registry=reloaded)
        assert res.source == "registry", \
            f"{op} resolution missed the registry: {res.source}"
print("tuner smoke OK: sweep -> save -> reload -> registry hit")
PY

echo "== repro.linalg + repro.arch API surface guard =="
python scripts/check_api_surface.py

echo "== golden default-machine planner outputs (bitwise vs pre-arch) =="
python scripts/check_golden_plans.py

echo "== machine smoke (spec round-trip + non-default machine resolves) =="
python - <<'PY'
import json, tempfile, os
import jax.numpy as jnp
from repro import arch, tune

# JSON round-trip through a real file
with tempfile.TemporaryDirectory() as d:
    p = os.path.join(d, "m.json")
    arch.get("paper-pe").save(p)
    assert arch.MachineSpec.load(p) == arch.get("paper-pe")
# a non-default machine must actually change planner decisions somewhere
r_def = tune.resolve("gemm", (2048, 2048, 2048), jnp.float32, policy="model")
r_pe = tune.resolve("gemm", (2048, 2048, 2048), jnp.float32, policy="model",
                    machine=arch.get("paper-pe"))
assert r_def.machine == "tpu-like" and r_pe.machine == "paper-pe"
assert (r_def.gemm_plan.bm, r_def.gemm_plan.bn, r_def.gemm_plan.bk) != \
    (r_pe.gemm_plan.bm, r_pe.gemm_plan.bn, r_pe.gemm_plan.bk), \
    "machine swap did not change the GEMM tiling"
print("machine smoke OK: round-trip + machine-dependent resolution")
PY

echo "== perf-regression gate (self-test, then fresh fast bench vs committed) =="
# self-test first: the gate must pass the committed trajectory against
# itself and fail a synthetically degraded copy - a silent-pass bug in the
# gate itself may not land
python scripts/check_perf_regression.py --self-test benchmarks/out/blas.json
PERF_TMP="$(mktemp -d)"
trap 'rm -rf "$PERF_TMP"' EXIT
python - "$PERF_TMP" <<'PY'
import os, sys
from benchmarks import bench_blas
out = os.path.join(sys.argv[1], "blas_fast.json")
bench_blas.run(lambda *a: None, fast=True, out=out)
print(f"fresh fast bench -> {out}")
PY
# generous CI tolerance (container timing is noisy); catastrophic
# regressions - an interpret-mode fallback, an accidental O(n^4) - are
# orders of magnitude, not tens of percent
python scripts/check_perf_regression.py \
    --baseline benchmarks/out/blas_fast.json \
    --fresh "$PERF_TMP/blas_fast.json" \
    --tol "${REPRO_PERF_TOL:-2.0}"

echo "== traced bench smoke (tiny traced run -> chrome trace -> validate) =="
python - "$PERF_TMP" <<'PY'
import os, sys
import numpy as np
import jax.numpy as jnp
from repro import linalg, obs

rng = np.random.default_rng(0)
a = jnp.asarray(rng.standard_normal((48, 32)), jnp.float32)
before = obs.counters_snapshot()
with obs.trace("ci-smoke") as tr:
    with linalg.use(policy="model"):
        linalg.qr(a)
        linalg.gemm(a.T, a)
path = os.path.join(sys.argv[1], "trace_ci.json")
obs.save_chrome_trace(tr, path)
assert tr.spans(cat="routine"), "no routine spans captured"
assert tr.spans(name="tune.resolve"), \
    "no dispatch provenance events in the trace"
assert obs.counters_delta(before).get("dispatch.resolve", 0) > 0, \
    "dispatch.resolve counter did not move"
print(f"traced bench smoke OK -> {path} ({len(tr.events)} events)")
PY
python scripts/trace_report.py --validate "$PERF_TMP/trace_ci.json"

echo "== fused smoke (fused cholesky trace + sweep vs tuner choice) =="
python - "$PERF_TMP" <<'PY'
import os, sys, tempfile
import numpy as np
import jax.numpy as jnp
from repro import linalg, obs, tune

# a tiny blocked cholesky with fusion forced on must agree with the staged
# chain and leave a fused span carrying positive modeled HBM savings
rng = np.random.default_rng(0)
g = rng.standard_normal((96, 96)).astype(np.float32)
s = jnp.asarray(g @ g.T + 96 * np.eye(96, dtype=np.float32))
with obs.trace("fused-smoke") as tr:
    with linalg.use(policy="model"):
        l_fused = linalg.cholesky(s, block=32, fuse=True)
with linalg.use(policy="model"):
    l_staged = linalg.cholesky(s, block=32, fuse=False)
err = float(jnp.max(jnp.abs(l_fused - l_staged)))
assert err < 1e-4, f"fused vs staged cholesky drifted: {err}"
spans = tr.spans(cat="fused")
assert spans, "no fused spans in the fused cholesky trace"
assert any(sp.attrs.get("hbm_bytes_saved", 0) > 0 for sp in spans), \
    "fused spans carry no positive hbm_bytes_saved"
path = os.path.join(sys.argv[1], "trace_fused.json")
obs.save_chrome_trace(tr, path)

# the measured sweep must land in the registry, and the tuner's resolved
# fuse/no-fuse choice must match the measured winner
with tempfile.TemporaryDirectory() as d:
    reg = tune.Registry(os.path.join(d, "reg.json"))
    sw = tune.tune_fused_gemm(64, 64, 64, epilogue="relu", registry=reg,
                              reps=1)
    res = tune.resolve("gemm+epilogue", (64, 64, 64), jnp.float32,
                       policy="tuned", registry=reg, epilogue="relu")
    assert res.source == "registry", f"fused sweep missed: {res.source}"
    want = bool(sw.best.params["fused"]) and res.chain.fits_vmem
    assert res.fused == want, \
        f"tuned fuse choice {res.fused} != measured winner {want}"
print(f"fused smoke OK: {len(spans)} fused spans, sweep winner "
      f"fused={bool(sw.best.params['fused'])} -> {path}")
PY
python scripts/trace_report.py "$PERF_TMP/trace_fused.json" \
    --require-span fused --require-attr hbm_bytes_saved
python scripts/trace_report.py --validate "$PERF_TMP/trace_fused.json"

echo "== calibration smoke (fit -> register -> round-trip) =="
python - <<'PY'
import os, tempfile
from repro import arch

with tempfile.TemporaryDirectory() as d:
    p = os.path.join(d, "calibrated.json")
    res = arch.calibrate_full(path=p, gemm_sizes=(16, 32),
                              stream_elems=1 << 16, chain_iters=32, reps=1)
    assert arch.get("calibrated-cpu") == res.machine
    assert arch.MachineSpec.load(p) == res.machine
    assert res.best_residual("gemm") <= arch.CALIBRATION_TOLERANCE
    assert res.best_residual("stream") <= arch.CALIBRATION_TOLERANCE
print("calibration smoke OK: fit + register + JSON round-trip + residuals")
PY

echo "== deprecation shims (DeprecationWarning -> error, our module only) =="
# the module's pytestmark escalates DeprecationWarning to error for every
# test in it (the shim warnings attribute to the caller, i.e. that module,
# via stacklevel), so an unexpected deprecation path in repro.* fails;
# the -W flag additionally escalates warnings attributed to the module at
# collection/import time (note: no "tests." prefix - tests/ is not a
# package, so the module __name__ is bare)
python -m pytest -q tests/test_linalg_deprecation.py \
    -W "error::DeprecationWarning:test_linalg_deprecation"

echo "== docs reference check (stale paths must fail) =="
python - <<'PY'
import os, re, sys

docs = ["README.md"] + sorted(
    os.path.join("docs", f) for f in os.listdir("docs") if f.endswith(".md"))
# every source-tree path or benchmark module a doc names must exist
pat = re.compile(r"(?:src/repro/[\w/.-]+\.py|benchmarks/(?:bench_[\w]+|run)\.py"
                 r"|tests/[\w]+\.py|scripts/[\w]+\.(?:sh|py)|examples/[\w]+\.py"
                 r"|docs/[\w]+\.md)")
stale = []
for doc in docs:
    with open(doc) as f:
        text = f.read()
    for ref in sorted(set(pat.findall(text))):
        if not os.path.exists(ref):
            stale.append(f"{doc}: {ref}")
if stale:
    print("stale documentation references:\n  " + "\n  ".join(stale))
    sys.exit(1)
print(f"docs reference check OK ({len(docs)} docs scanned)")
PY

echo "== distributed BLAS/LAPACK tests (8 forced host devices) =="
XLA_FLAGS="--xla_force_host_platform_device_count=8" \
    python -m pytest -q tests/test_distributed_blas.py

echo "== tier-1 suite =="
# the distributed module just ran above; skip it here so CI does not pay
# its 8-device subprocess bodies twice
python -m pytest -x -q --ignore=tests/test_distributed_blas.py "$@"
