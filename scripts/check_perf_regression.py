#!/usr/bin/env python
"""Spread-aware perf-regression gate over committed bench trajectories.

The ``benchmarks/out/*.json`` artifacts are not decoration: they are the
repo's perf trajectory, and this gate is what makes the trajectory
*defended*. It matches rows of a fresh bench run against the committed
baseline by their identity fields (op/kind, sizes, block, batch, dtype,
policy, mesh) and fails when a fresh median exceeds the spread-aware
allowance

    allowed = base_median * (1 + tol + spread_k * rel_spread)

where ``rel_spread`` is the larger of the two rows' recorded
``seconds_spread`` (the relative IQR the repetition controller of
``repro.tune.measure`` records - see ``docs/benchmarking.md``). Rows
without ``seconds_median`` (pre-controller artifacts) are skipped, rows
only present on one side are reported but not fatal (benchmarks grow),
and an empty intersection is an error (the gate must be comparing
something).

Usage:
    check_perf_regression.py --baseline FILE --fresh FILE [--tol X]
        [--spread-k K]
    check_perf_regression.py --self-test FILE

``--self-test`` proves the gate has teeth without a timing run: the file
compared against itself must pass, and the same file with one row's
median synthetically degraded beyond the allowance must fail.
``REPRO_PERF_TOL`` overrides the default tolerance.
"""
from __future__ import annotations

import argparse
import copy
import json
import os
import sys

# identity fields: everything that names *what* a row measured (never how
# fast it was). A row's key is the subset of these it carries.
ID_FIELDS = ("op", "kind", "n", "m", "k", "shape", "block", "batch",
             "dtype", "policy", "mesh", "planned", "backend")
DEFAULT_TOL = float(os.environ.get("REPRO_PERF_TOL", 0.5))
DEFAULT_SPREAD_K = 3.0


def row_key(row):
    return tuple((f, json.dumps(row[f], sort_keys=True))
                 for f in ID_FIELDS if f in row)


def index_rows(doc):
    """rows keyed by identity; later duplicates get a counter suffix so
    nothing is silently dropped."""
    out = {}
    for row in doc.get("rows", []):
        key = row_key(row)
        i = 0
        while (key, i) in out:
            i += 1
        out[(key, i)] = row
    return out


def compare(baseline, fresh, tol=DEFAULT_TOL, spread_k=DEFAULT_SPREAD_K):
    """Returns (failures, checked, skipped): failures are human-readable
    strings, checked the number of compared rows, skipped the rows present
    on both sides but lacking controller fields."""
    base_idx = index_rows(baseline)
    fresh_idx = index_rows(fresh)
    common = sorted(set(base_idx) & set(fresh_idx), key=str)
    failures, checked, skipped = [], 0, 0
    for key in common:
        b, f = base_idx[key], fresh_idx[key]
        bt, ft = b.get("seconds_median"), f.get("seconds_median")
        if bt is None or ft is None or not bt > 0:
            skipped += 1
            continue
        spread = max(float(b.get("seconds_spread", 0.0)),
                     float(f.get("seconds_spread", 0.0)), 0.0)
        allowed = bt * (1.0 + tol + spread_k * spread)
        checked += 1
        if ft > allowed:
            name = ", ".join(f"{k}={v}" for k, v in key[0])
            failures.append(
                f"{name}: fresh median {ft:.3e}s exceeds allowance "
                f"{allowed:.3e}s (baseline {bt:.3e}s, rel spread "
                f"{spread:.2f}, tol {tol})")
    return failures, checked, skipped


def gate(baseline_path, fresh_path, tol, spread_k):
    with open(baseline_path) as fh:
        baseline = json.load(fh)
    with open(fresh_path) as fh:
        fresh = json.load(fh)
    failures, checked, skipped = compare(baseline, fresh, tol, spread_k)
    only_base = len(set(index_rows(baseline)) - set(index_rows(fresh)))
    only_fresh = len(set(index_rows(fresh)) - set(index_rows(baseline)))
    if only_base or only_fresh:
        print(f"note: {only_base} baseline-only / {only_fresh} fresh-only "
              f"rows not compared")
    if checked == 0:
        print(f"perf gate ERROR: no comparable rows between "
              f"{baseline_path} and {fresh_path} "
              f"({skipped} skipped without controller fields)")
        return 1
    if failures:
        print(f"perf gate FAILED ({len(failures)}/{checked} rows):")
        for f in failures:
            print(f"  - {f}")
        return 1
    print(f"perf gate OK: {checked} rows within tolerance "
          f"(tol={tol}, spread_k={spread_k}, {skipped} skipped)")
    return 0


def self_test(path, tol, spread_k):
    """The gate must pass a file against itself and fail a synthetically
    degraded copy - run on every CI invocation so a silent-pass bug in the
    gate itself cannot land."""
    with open(path) as fh:
        doc = json.load(fh)
    failures, checked, _ = compare(doc, doc, tol, spread_k)
    if checked == 0:
        print(f"perf gate self-test ERROR: {path} has no rows with "
              f"controller fields (seconds_median)")
        return 1
    if failures:
        print(f"perf gate self-test FAILED: identical trajectories "
              f"reported {len(failures)} regressions")
        return 1
    degraded = copy.deepcopy(doc)
    victim = None
    for row in degraded["rows"]:
        if row.get("seconds_median"):
            spread = max(float(row.get("seconds_spread", 0.0)), 0.0)
            row["seconds_median"] *= 2.0 * (1.0 + tol + spread_k * spread)
            victim = row_key(row)
            break
    failures, _, _ = compare(doc, degraded, tol, spread_k)
    if not failures:
        print(f"perf gate self-test FAILED: synthetic degradation of "
              f"{victim} slipped through")
        return 1
    print(f"perf gate self-test OK: identity passes, degraded row fails "
          f"({checked} rows, tol={tol})")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", help="committed trajectory JSON")
    ap.add_argument("--fresh", help="freshly measured trajectory JSON")
    ap.add_argument("--self-test", dest="self_test", metavar="FILE",
                    help="verify the gate fails a synthetically degraded "
                         "copy of FILE and passes FILE vs itself")
    ap.add_argument("--tol", type=float, default=DEFAULT_TOL,
                    help="fractional slowdown allowed before spread "
                         "widening (default from REPRO_PERF_TOL or 0.5)")
    ap.add_argument("--spread-k", type=float, default=DEFAULT_SPREAD_K,
                    help="tolerance widening per unit of relative IQR")
    args = ap.parse_args()
    if args.self_test:
        return self_test(args.self_test, args.tol, args.spread_k)
    if not (args.baseline and args.fresh):
        ap.error("need --baseline and --fresh (or --self-test FILE)")
    return gate(args.baseline, args.fresh, args.tol, args.spread_k)


if __name__ == "__main__":
    sys.exit(main())
