import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf hillclimb driver: lower one cell under variant configurations and
print the three roofline terms per variant (EXPERIMENTS.md §Perf).

PYTHONPATH=src python scripts/hillclimb.py <arch> <shape> <variant.json>...
where variant.json is e.g. '{"name":"dots","overrides":{"remat_policy":"dots"}}'
Results append to results/hillclimb/<arch>__<shape>__<name>.json.
"""
import json
import sys

from repro.launch import dryrun
from repro.launch.mesh import make_production_mesh
from repro.core import roofline as rl

def main():
    arch, shape = sys.argv[1], sys.argv[2]
    variants = [json.loads(v) for v in sys.argv[3:]] or [
        {"name": "baseline"}]
    mesh = make_production_mesh(multi_pod=False)
    os.makedirs("results/hillclimb", exist_ok=True)
    for v in variants:
        name = v.get("name", "variant")
        path = f"results/hillclimb/{arch}__{shape}__{name}.json"
        if os.path.exists(path):
            print(f"CACHED {name}")
            with open(path) as f:
                d = json.load(f)
        else:
            print(f"LOWER {arch} x {shape} [{name}] ...", flush=True)
            kw = dict(v)
            kw.pop("name", None)
            compiled, row = dryrun.lower_cell(arch, shape, mesh, **kw)
            d = row.to_dict()
            with open(path, "w") as f:
                json.dump(d, f, indent=1)
            import gzip
            with gzip.open(path.replace(".json", ".hlo.gz"), "wt") as f:
                f.write(compiled.as_text())
        print(f"  [{name:24s}] compute={d['compute_s']*1e3:9.2f}ms "
              f"memory={d['memory_s']*1e3:9.2f}ms "
              f"coll={d['collective_s']*1e3:9.2f}ms dom={d['dominant']:10s} "
              f"frac={d['roofline_fraction']:.4f} "
              f"useful={d['useful_flop_ratio']:.3f} "
              f"GiB/dev={d['bytes_per_device']/2**30:.2f}", flush=True)

if __name__ == "__main__":
    main()
