#!/usr/bin/env python
"""Summarize or validate an on-disk repro.obs trace artifact.

Usage:
    python scripts/trace_report.py TRACE.json            # text summary
    python scripts/trace_report.py TRACE.json --validate # schema gate
    python scripts/trace_report.py TRACE.json \
        --require-span fused --require-attr hbm_bytes_saved
        # gate: >= 1 span whose name or cat contains "fused" AND whose
        # attrs carry hbm_bytes_saved > 0 (the fused-smoke CI step)

Reads both exporter formats (auto-detected): the Chrome ``trace_event``
object written by ``obs.save_chrome_trace`` (also what
``benchmarks/run.py --trace`` emits) and the JSON-lines form from
``obs.save_jsonl``. ``--validate`` is the CI schema gate
(``scripts/ci_check.sh``): it fails (exit 1) on a schema-version
mismatch, missing required fields, non-monotonic ``ts`` ordering, or
malformed events - so exporter drift cannot land silently. See
``docs/observability.md`` for the schemas.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys
from typing import Dict, List, Tuple

# importable from any cwd: the schema constants live in src/repro/obs
sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.obs import EVENT_FIELDS, SCHEMA_VERSION  # noqa: E402

CHROME_REQUIRED = {"name", "cat", "ph", "ts", "pid", "tid"}


def load(path: str) -> Tuple[str, Dict, List[Dict]]:
    """-> (format, metadata, events); format in {"chrome", "jsonl"}."""
    with open(path) as f:
        text = f.read()
    try:
        blob = json.loads(text)
    except json.JSONDecodeError:
        blob = None
    if isinstance(blob, dict) and "traceEvents" in blob:
        return "chrome", blob.get("otherData", {}), blob["traceEvents"]
    # JSON-lines: one object per line
    meta: Dict = {}
    events: List[Dict] = []
    for i, line in enumerate(filter(None, map(str.strip, text.splitlines()))):
        rec = json.loads(line)
        kind = rec.get("kind")
        if kind == "header":
            meta.update(rec)
        elif kind == "counters":
            meta["counters"] = rec.get("counters", {})
        elif kind == "event":
            events.append(rec)
        else:
            raise ValueError(f"line {i + 1}: unknown record kind {kind!r}")
    if not meta:
        raise ValueError("jsonl trace has no header line")
    return "jsonl", meta, events


def _attr_problems(i: int, e: Dict) -> List[str]:
    """Cross-check the roofline attrs a span may carry, either format.

    ``flops``/``bytes`` feed the roofline annotation (span.close), so when
    present they must be finite non-negative numbers, and a derived
    ``fraction_of_modeled_peak`` must be a finite ratio >= 0 - a NaN/inf
    (zero modeled peak) or negative value means the annotation math broke
    upstream and would silently poison trace summaries and CI gates.
    """
    problems = []
    attrs = _event_attrs(e)
    for key in ("flops", "bytes"):
        v = attrs.get(key)
        if v is None:
            continue
        if isinstance(v, bool) or not isinstance(v, (int, float)):
            problems.append(f"event {i}: attr {key}={v!r} is not numeric")
        elif not math.isfinite(v) or v < 0:
            problems.append(f"event {i}: attr {key}={v!r} must be a "
                            "finite value >= 0")
    frac = attrs.get("fraction_of_modeled_peak")
    if frac is not None:
        if isinstance(frac, bool) or not isinstance(frac, (int, float)) \
                or not math.isfinite(frac) or frac < 0:
            problems.append(f"event {i}: fraction_of_modeled_peak="
                            f"{frac!r} must be a finite value >= 0")
    return problems


def validate(fmt: str, meta: Dict, events: List[Dict]) -> List[str]:
    """Schema check; returns a list of human-readable problems."""
    problems = []
    got_ver = meta.get("schema_version")
    if got_ver != SCHEMA_VERSION:
        problems.append(f"schema_version {got_ver!r} != expected "
                        f"{SCHEMA_VERSION}")
    if "counters" not in meta:
        problems.append("missing counters block")
    if fmt == "chrome":
        last_ts = None
        for i, e in enumerate(events):
            missing = CHROME_REQUIRED - set(e)
            if missing:
                problems.append(f"event {i}: missing {sorted(missing)}")
                continue
            if e["ph"] not in ("X", "i"):
                problems.append(f"event {i}: unexpected ph {e['ph']!r}")
            if e["ph"] == "X" and not (isinstance(e.get("dur"), (int, float))
                                       and e["dur"] >= 0):
                problems.append(f"event {i}: ph=X needs dur >= 0")
            if not isinstance(e["ts"], (int, float)):
                problems.append(f"event {i}: non-numeric ts")
            elif last_ts is not None and e["ts"] < last_ts:
                problems.append(f"event {i}: ts {e['ts']} < previous "
                                f"{last_ts} (not monotonically ordered)")
            else:
                last_ts = e["ts"]
            if "id" not in e.get("args", {}):
                problems.append(f"event {i}: args missing event id")
            problems += _attr_problems(i, e)
    else:
        want = set(EVENT_FIELDS)
        last_ts = None
        for i, e in enumerate(events):
            fields = set(e) - {"kind"}
            if fields != want:
                problems.append(f"event {i}: fields {sorted(fields)} != "
                                f"{sorted(want)}")
                continue
            ts = e["t_start"]
            if last_ts is not None and ts < last_ts:
                problems.append(f"event {i}: t_start not monotonic")
            last_ts = ts
            problems += _attr_problems(i, e)
    return problems


def _event_attrs(e: Dict) -> Dict:
    """Attr dict regardless of format (chrome ``args`` vs jsonl ``attrs``)."""
    return e.get("args") or e.get("attrs") or {}


def require_span(events: List[Dict], substr: str,
                 attr: str = None) -> List[str]:
    """Gate: at least one event whose name or cat contains ``substr``;
    with ``attr``, at least one such event must also carry ``attrs[attr]``
    as a number > 0. Returns problems (empty = pass)."""
    matched = [e for e in events
               if substr in str(e.get("name", ""))
               or substr in str(e.get("cat", ""))]
    if not matched:
        return [f"no span matching {substr!r} "
                f"(trace has {len(events)} events)"]
    if attr is None:
        return []
    for e in matched:
        v = _event_attrs(e).get(attr)
        if isinstance(v, (int, float)) and v > 0:
            return []
    return [f"{len(matched)} span(s) match {substr!r} but none carry "
            f"attr {attr!r} > 0"]


def summarize(meta: Dict, events: List[Dict]) -> str:
    groups: Dict = {}
    for e in events:
        cat = e.get("cat", "?")
        name = e.get("name", "?")
        if "dur" in e:                                  # chrome: micros
            dur_s = e["dur"] / 1e6
            args = e.get("args", {})
        elif e.get("t_end") is not None:                # jsonl: seconds
            dur_s = e["t_end"] - e["t_start"]
            args = e.get("attrs", {})
        else:
            dur_s = 0.0
            args = e.get("args") or e.get("attrs") or {}
        g = groups.setdefault((cat, name),
                              {"count": 0, "total_s": 0.0, "fracs": []})
        g["count"] += 1
        g["total_s"] += dur_s
        frac = args.get("fraction_of_modeled_peak")
        if isinstance(frac, (int, float)):
            g["fracs"].append(frac)
    name = meta.get("trace_name", "?")
    lines = [f"trace {name!r}: {len(events)} events",
             f"{'cat':<12} {'name':<28} {'count':>6} {'total_ms':>10} "
             f"{'frac_peak':>10}"]
    for (cat, nm), g in sorted(groups.items(), key=lambda kv: -kv[1]["total_s"]):
        frac = (sum(g["fracs"]) / len(g["fracs"])) if g["fracs"] else None
        lines.append(f"{cat:<12} {nm:<28} {g['count']:>6} "
                     f"{1e3 * g['total_s']:>10.3f} "
                     f"{(f'{frac:.2e}' if frac is not None else '-'):>10}")
    counters = meta.get("counters") or {}
    if counters:
        lines.append("counters:")
        lines += [f"  {k:<28} {v}" for k, v in sorted(counters.items())]
    return "\n".join(lines)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", help="trace artifact (chrome-trace or jsonl)")
    ap.add_argument("--validate", action="store_true",
                    help="schema-gate the artifact instead of summarizing")
    ap.add_argument("--require-span", metavar="SUBSTR",
                    help="fail unless >= 1 span name/cat contains SUBSTR")
    ap.add_argument("--require-attr", metavar="KEY",
                    help="with --require-span: a matching span must carry "
                         "attr KEY with a numeric value > 0")
    args = ap.parse_args()
    if args.require_attr and not args.require_span:
        ap.error("--require-attr needs --require-span")

    try:
        fmt, meta, events = load(args.trace)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"unreadable trace {args.trace}: {e}", file=sys.stderr)
        return 1
    if args.require_span:
        problems = require_span(events, args.require_span, args.require_attr)
        if problems:
            print(f"trace {args.trace} FAILED span requirement:")
            for p in problems:
                print(f"  - {p}")
            return 1
        print(f"trace OK: {args.trace} has span matching "
              f"{args.require_span!r}"
              + (f" with {args.require_attr} > 0" if args.require_attr
                 else ""))
        if not args.validate:
            return 0
    if args.validate:
        problems = validate(fmt, meta, events)
        if problems:
            print(f"trace {args.trace} FAILED validation ({fmt} format):")
            for p in problems[:20]:
                print(f"  - {p}")
            return 1
        print(f"trace OK: {args.trace} ({fmt} format, {len(events)} events, "
              f"schema v{SCHEMA_VERSION})")
        return 0
    print(summarize(meta, events))
    return 0


if __name__ == "__main__":
    sys.exit(main())
