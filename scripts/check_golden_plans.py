#!/usr/bin/env python
"""CI guard: default-machine planner outputs are frozen.

The ``repro.arch`` refactor replaced the codesign layer's module constants
with a swappable :class:`repro.arch.MachineSpec`; the contract is that the
default machine (``"tpu-like"``) reproduces the pre-refactor constants
*bit-for-bit*. This script evaluates every planner over a fixed
shape x dtype-bytes grid and compares the full plan tuples against the
committed golden file - any numerical drift in the default path fails CI.

Usage:
    python scripts/check_golden_plans.py           # check (CI mode)
    python scripts/check_golden_plans.py --write   # regenerate the golden
                                                   # (intentional changes
                                                   # only, same PR)
"""
import json
import os
import sys

GOLDEN = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                      "golden_default_plans.json")

GEMM_SHAPES = [(128, 128, 128), (300, 300, 300), (512, 512, 512),
               (1024, 1024, 1024), (4096, 4096, 4096), (8, 8192, 8192)]
TRSM_SHAPES = [(64, 1), (512, 8), (2048, 32)]
# (kind, form-or-epilogue, m, n, k); covers both fused chains, a clear
# fusion win (256-square panel) and a deliberate VMEM-pressure case
FUSED_CHAINS = [("gemm+epilogue", "none", 256, 256, 64),
                ("gemm+epilogue", "relu", 256, 256, 64),
                ("gemm+epilogue", "gelu", 512, 512, 128),
                ("trsm+gemm", "syrk", 256, 256, 32),
                ("trsm+gemm", "lu", 256, 256, 32),
                ("trsm+gemm", "syrk", 2048, 2048, 64)]
FACTOR_NS = [64, 256, 2048]
PDGEMM_MESHES = [(1, 1), (2, 2), (4, 2)]
DTYPE_BYTES = [2, 4, 8]


def compute():
    from repro.core import codesign as cd

    out = {"constants": {
        "PEAK_BF16_FLOPS": cd.PEAK_BF16_FLOPS, "HBM_BW": cd.HBM_BW,
        "ICI_BW": cd.ICI_BW, "VMEM_BYTES": cd.VMEM_BYTES, "MXU": cd.MXU,
        "SUBLANE": cd.SUBLANE, "LANE": cd.LANE,
        "VPU_ADD_LATENCY": cd.VPU_ADD_LATENCY,
        "VREG_BUDGET": cd.VREG_BUDGET, "ACC_OVERHEAD": cd.ACC_OVERHEAD,
        "PIPELINE_FILL_S": cd.PIPELINE_FILL_S, "MXU_CLOCK": cd.MXU_CLOCK,
        "VPU_FLOPS": cd.VPU_FLOPS,
    }, "gemm": {}, "trsm": {}, "factorization": {}, "pdgemm": {},
        "fused": {}}
    for m, n, k in GEMM_SHAPES:
        for db in DTYPE_BYTES:
            p = cd.plan_gemm(m, n, k, dtype_bytes=db)
            out["gemm"][f"{m}x{n}x{k}|{db}"] = {
                "bm": p.bm, "bn": p.bn, "bk": p.bk,
                "accumulators": p.accumulators, "grid": list(p.grid),
                "vmem_bytes": p.vmem_bytes,
                "arithmetic_intensity": p.arithmetic_intensity,
                "compute_bound": p.compute_bound}
    for n, nrhs in TRSM_SHAPES:
        for db in DTYPE_BYTES:
            t = cd.plan_trsm(n, nrhs, dtype_bytes=db)
            out["trsm"][f"{n}x{nrhs}|{db}"] = {
                "block": t.block, "panel_time": t.panel_time,
                "trailing_time": t.trailing_time}
    for kind in ("potrf", "getrf", "geqrf"):
        for n in FACTOR_NS:
            for db in DTYPE_BYTES:
                f = cd.plan_factorization(n, kind=kind, dtype_bytes=db)
                out["factorization"][f"{kind}|{n}|{db}"] = {
                    "block": f.block, "panel_time": f.panel_time,
                    "trailing_time": f.trailing_time,
                    "gemm": [f.gemm.bm, f.gemm.bn, f.gemm.bk]}
    for kind, variant, m, n, k in FUSED_CHAINS:
        for db in DTYPE_BYTES:
            if kind == "gemm+epilogue":
                c = cd.plan_fused_chain(kind, m, n, k, dtype_bytes=db,
                                        epilogue=variant)
            else:
                c = cd.plan_fused_chain(kind, m, n, k, dtype_bytes=db,
                                        form=variant)
            out["fused"][f"{kind}|{variant}|{m}x{n}x{k}|{db}"] = {
                "block": c.block, "vmem_bytes": c.vmem_bytes,
                "fits_vmem": c.fits_vmem,
                "unfused_hbm_bytes": c.unfused_hbm_bytes,
                "fused_hbm_bytes": c.fused_hbm_bytes,
                "hbm_bytes_saved": c.hbm_bytes_saved,
                "unfused_time": c.unfused_time,
                "fused_time": c.fused_time,
                "fused_wins": c.fused_wins,
                "gemm": [c.gemm.bm, c.gemm.bn, c.gemm.bk]}
    for px, py in PDGEMM_MESHES:
        for db in DTYPE_BYTES:
            p = cd.plan_pdgemm(4096, 4096, 4096, px, py, dtype_bytes=db)
            out["pdgemm"][f"x{px}y{py}|{db}"] = {
                "steps": p.steps, "k_fine": p.k_fine,
                "local": [p.local.bm, p.local.bn, p.local.bk],
                "compute_s": p.compute_s, "collective_s": p.collective_s,
                "collective_bytes": p.collective_bytes}
    return out


def main() -> int:
    got = compute()
    if "--write" in sys.argv:
        with open(GOLDEN, "w") as f:
            json.dump(got, f, indent=1, sort_keys=True)
        n = sum(len(v) for v in got.values())
        print(f"wrote {n} golden entries to {GOLDEN}")
        return 0
    try:
        with open(GOLDEN) as f:
            want = json.load(f)
    except OSError as e:
        print(f"golden plan file missing ({e}); regenerate with --write")
        return 1
    errors = []
    for section, entries in want.items():
        for key, w in entries.items():
            g = got.get(section, {}).get(key)
            if g != w:
                errors.append(f"{section}[{key}]: {g!r} != golden {w!r}")
    if errors:
        print("default-machine planner outputs drifted from the golden "
              "(the tpu-like spec must stay bit-identical to the "
              "pre-arch constants):")
        for e in errors[:20]:
            print(f"  - {e}")
        if len(errors) > 20:
            print(f"  ... and {len(errors) - 20} more")
        return 1
    n = sum(len(v) for v in want.values())
    print(f"golden default-machine plans OK ({n} entries bitwise-identical)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
