#!/usr/bin/env python
"""CI gate: trace-time static analysis of the repro.linalg surface.

Sweeps every public (arg-synthesizable) ``repro.linalg`` routine over the
acceptance grid - policies x dtypes x {no mesh, mesh} - with
``repro.analysis.check_surface`` and fails (exit 1) on any unsuppressed
``error``-severity finding. Warnings print but do not fail. Nothing is
executed: every case is a ``jax.make_jaxpr`` trace, so the sweep runs in
seconds on the CI host with no accelerator.

The mesh leg needs ``SURFACE_MESH`` (2x2 = 4) devices; this script forces
8 host devices via XLA_FLAGS *before* importing jax (same idiom as
``scripts/hillclimb.py`` / the distributed test step in
``scripts/ci_check.sh``) so CI never records a skipped mesh case.

Usage:
    python scripts/check_static_analysis.py
    python scripts/check_static_analysis.py --routines gemm,qr
    python scripts/check_static_analysis.py --allowlist allow.json \
        --out analysis_report.json

See ``docs/static_analysis.md`` for the rule vocabulary and the
allowlist format.
"""
import argparse
import os
import sys
import time

# force enough host devices for the mesh leg before jax is imported
# anywhere in-process (XLA reads the flag at backend init)
_FLAG = "--xla_force_host_platform_device_count=8"
if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " " + _FLAG).strip()
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# importable from any cwd
sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--routines", metavar="A,B,...",
                    help="comma-separated subset (default: every "
                         "checkable linalg.__all__ routine)")
    ap.add_argument("--allowlist", metavar="PATH",
                    help="JSON allowlist of suppressed findings "
                         "(missing file = empty; corrupt warns + empty)")
    ap.add_argument("--out", metavar="PATH",
                    help="also save the merged AnalysisReport as JSON")
    ap.add_argument("--no-mesh", action="store_true",
                    help="skip the sharded (mesh) leg of the grid")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="print every case as it is checked")
    args = ap.parse_args()

    from repro import analysis

    routines = (args.routines.split(",") if args.routines
                else analysis.surface_routines())
    allowlist = analysis.load_allowlist(args.allowlist)
    mesh = None if args.no_mesh else analysis.report.SURFACE_MESH

    checked = [0]

    def progress(case):
        checked[0] += 1
        if args.verbose:
            print(f"  [{checked[0]:4d}] {case['routine']:>18s} "
                  f"policy={case['policy']} dtype={case['dtype']} "
                  f"mesh={case['mesh']}")

    t0 = time.time()
    rep = analysis.check_surface(routines=routines, mesh=mesh,
                                 allowlist=allowlist, progress=progress)
    dt = time.time() - t0
    if args.out:
        rep.save(args.out)
        print(f"report -> {args.out}")

    skipped = [c for c in rep.cases if "skipped" in c]
    print(rep.summary())
    print(f"static analysis: {len(rep.cases)} cases "
          f"({len(skipped)} skipped) over {len(routines)} routines "
          f"in {dt:.1f}s")
    if skipped:
        # the forced-device preamble should make this impossible in CI
        print(f"  note: {len(skipped)} mesh case(s) skipped: "
              f"{skipped[0].get('skipped')}")
    if not rep.ok:
        print(f"FAILED: {len(rep.errors)} unsuppressed error-severity "
              "finding(s) (suppress via docs/static_analysis.md "
              "allowlist workflow only with a reason)")
        return 1
    if rep.warnings:
        print(f"passed with {len(rep.warnings)} warning(s)")
    else:
        print("static analysis OK: no findings")
    return 0


if __name__ == "__main__":
    sys.exit(main())
