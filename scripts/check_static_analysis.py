#!/usr/bin/env python
"""CI gate: trace-time static analysis of the repro.linalg surface.

Sweeps every public (arg-synthesizable) ``repro.linalg`` routine over the
acceptance grid - policies x dtypes x {no mesh, SURFACE_MESHES} plus the
direct ``pdgemm``/``pdtrsm`` distributed entry points and the BY001
dispatcher-bypass lint - with ``repro.analysis.check_surface`` /
``lint_bypass`` and fails (exit 1) on any unsuppressed
``error``-severity finding. Warnings print but do not fail. Nothing is
executed: every case is a ``jax.make_jaxpr`` trace, so the sweep runs in
seconds on the CI host with no accelerator.

The mesh legs need up to 8 (4x2) devices; this script forces 8 host
devices via XLA_FLAGS *before* importing jax (same idiom as
``scripts/hillclimb.py`` / the distributed test step in
``scripts/ci_check.sh``) so CI never records a skipped mesh case.

Usage:
    python scripts/check_static_analysis.py
    python scripts/check_static_analysis.py --routines gemm,qr
    python scripts/check_static_analysis.py --no-mesh --no-bypass
    python scripts/check_static_analysis.py --spmd-only
    python scripts/check_static_analysis.py --write-bypass-allowlist \
        src/repro/analysis/bypass_allowlist.json

See ``docs/static_analysis.md`` for the rule vocabulary, the allowlist
format, and the BY001 burn-down workflow.
"""
import argparse
import os
import sys
import time

# force enough host devices for the mesh legs before jax is imported
# anywhere in-process (XLA reads the flag at backend init)
_FLAG = "--xla_force_host_platform_device_count=8"
if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " " + _FLAG).strip()
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# importable from any cwd
sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--routines", metavar="A,B,...",
                    help="comma-separated subset (default: every "
                         "checkable linalg.__all__ routine)")
    ap.add_argument("--allowlist", metavar="PATH",
                    help="JSON allowlist of suppressed findings "
                         "(missing file = empty; corrupt warns + empty)")
    ap.add_argument("--out", metavar="PATH",
                    help="also save the merged AnalysisReport as JSON")
    ap.add_argument("--no-mesh", action="store_true",
                    help="skip the sharded (mesh + direct distributed) "
                         "legs of the grid")
    ap.add_argument("--spmd-only", action="store_true",
                    help="run only the sharded legs: mesh sweeps over "
                         "SURFACE_MESHES plus the direct pdgemm/pdtrsm "
                         "entry points (no base legs, no bypass lint)")
    ap.add_argument("--no-bypass", action="store_true",
                    help="skip the BY001 dispatcher-bypass lint")
    ap.add_argument("--write-bypass-allowlist", metavar="PATH",
                    help="regenerate the BY001 burn-down allowlist from "
                         "the current bypass set and exit")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="print every case as it is checked")
    args = ap.parse_args()

    from repro import analysis
    from repro.analysis import bypass_lint

    if args.write_bypass_allowlist:
        sites, cases = bypass_lint.collect_bypass_sites(
            progress=(print if args.verbose else None))
        path = bypass_lint.save_bypass_allowlist(
            sites, args.write_bypass_allowlist)
        broken = [c for c in cases if "error" in c]
        for c in broken:
            print(f"  entry {c['entry']} failed: {c['error']}")
        print(f"bypass allowlist -> {path} ({len(sites)} site(s) from "
              f"{len(cases) - len(broken)} entry point(s))")
        return 1 if broken else 0

    routines = (args.routines.split(",") if args.routines
                else analysis.surface_routines())
    allowlist = analysis.load_allowlist(args.allowlist)

    checked = [0]

    def progress(case):
        checked[0] += 1
        if args.verbose:
            print(f"  [{checked[0]:4d}] {case['routine']:>18s} "
                  f"policy={case['policy']} dtype={case['dtype']} "
                  f"mesh={case['mesh']}"
                  + (" direct" if case.get("entry") == "direct" else ""))

    t0 = time.time()
    meshes = () if args.no_mesh else analysis.report.SURFACE_MESHES
    rep = analysis.check_surface(
        routines=None if args.routines is None else routines,
        meshes=meshes, base_leg=not args.spmd_only,
        distributed=bool(meshes) and (args.routines is None),
        allowlist=allowlist, progress=progress)
    reports = [rep]
    if not (args.no_bypass or args.spmd_only):
        reports.append(bypass_lint.lint_bypass())
    rep = analysis.merge_reports(reports, target="static-analysis")
    dt = time.time() - t0
    if args.out:
        rep.save(args.out)
        print(f"report -> {args.out}")

    skipped = [c for c in rep.cases if "skipped" in c]
    direct = [c for c in rep.cases if c.get("entry") == "direct"]
    mesh_cases = [c for c in rep.cases if c.get("mesh")]
    bypass_cases = [c for c in rep.cases if "bypasses" in c]
    print(rep.summary())
    print(f"static analysis: {len(rep.cases)} cases "
          f"({len(skipped)} skipped) over {len(routines)} routines "
          f"in {dt:.1f}s")
    if mesh_cases:
        n_meshes = len({tuple(c["mesh"]) for c in mesh_cases})
        print(f"  distributed: {len(mesh_cases)} sharded case(s) over "
              f"{n_meshes} mesh shape(s), {len(direct)} direct "
              f"pdgemm/pdtrsm case(s)")
    if bypass_cases:
        n_by = sum(c.get("bypasses", 0) for c in bypass_cases)
        print(f"  bypass lint: {len(bypass_cases)} entry point(s), "
              f"{n_by} raw contraction(s) at "
              f"{len(rep.suppressed)} allowlisted site(s)")
    if skipped:
        # the forced-device preamble should make this impossible in CI
        print(f"  note: {len(skipped)} mesh case(s) skipped: "
              f"{skipped[0].get('skipped')}")
    if not rep.ok:
        print(f"FAILED: {len(rep.errors)} unsuppressed error-severity "
              "finding(s) (suppress via docs/static_analysis.md "
              "allowlist workflow only with a reason)")
        return 1
    if rep.warnings:
        print(f"passed with {len(rep.warnings)} warning(s)")
    else:
        print("static analysis OK: no findings")
    return 0


if __name__ == "__main__":
    sys.exit(main())
