"""Re-derive roofline rows from the saved .hlo.gz artifacts (no recompile).

PYTHONPATH=src python scripts/reanalyze.py results/dryrun
"""
import glob
import gzip
import json
import os
import sys

from repro.core import hlo_cost


def main():
    d = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    for jpath in sorted(glob.glob(os.path.join(d, "*.json"))):
        hpath = jpath.replace(".json", ".hlo.gz")
        if not os.path.exists(hpath):
            continue
        with open(jpath) as f:
            row = json.load(f)
        with gzip.open(hpath, "rt") as f:
            hlo = f.read()
        c = hlo_cost.analyze(hlo)
        row["hlo_flops"] = c.flops
        row["hlo_bytes"] = c.bytes_fused       # TPU-fusion traffic model
        row.setdefault("extra", {})["bytes_unfused"] = c.bytes
        row["coll_breakdown"] = {k: int(v) for k, v in c.coll.items()}
        row["coll_bytes"] = float(c.collective_bytes)
        # recompute derived fields
        from repro.core.roofline import Roofline
        r = Roofline(**{k: row[k] for k in
                        ("arch", "shape", "mesh", "chips", "hlo_flops",
                         "hlo_bytes", "coll_bytes", "coll_breakdown",
                         "model_flops", "bytes_per_device", "extra")})
        row.update(compute_s=r.compute_s, memory_s=r.memory_s,
                   collective_s=r.collective_s, dominant=r.dominant,
                   useful_flop_ratio=r.useful_flop_ratio,
                   roofline_fraction=r.roofline_fraction,
                   step_time_s=r.step_time_s)
        with open(jpath, "w") as f:
            json.dump(row, f, indent=1)
        print(f"reanalyzed {os.path.basename(jpath)}")


if __name__ == "__main__":
    main()
