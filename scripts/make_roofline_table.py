"""Render the EXPERIMENTS.md roofline table from results/dryrun/*.json.

PYTHONPATH=src python scripts/make_roofline_table.py [results/dryrun]
"""
import glob
import json
import os
import sys

from repro.core.roofline import Roofline, advice, load_json  # noqa: E402

V5E_HBM = 16 * 2 ** 30


def rows(d):
    out = []
    for p in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(p) as f:
            out.append(json.load(f))
    return out


def fmt(rs, mesh):
    sel = [r for r in rs if r["mesh"] == mesh]
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "MODEL_FLOPS | useful ratio | roofline frac | GiB/dev | fits v5e |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(sel, key=lambda r: (r["arch"], r["shape"])):
        gib = r["bytes_per_device"] / 2 ** 30
        fits = "yes" if r["bytes_per_device"] <= V5E_HBM else "**NO**"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"{r['dominant']} | {r['model_flops']:.3e} | "
            f"{r['useful_flop_ratio']:.3f} | {r['roofline_fraction']:.3f} | "
            f"{gib:.2f} | {fits} |")
    return "\n".join(lines)


def advice_lines(rs, mesh):
    sel = [r for r in rs if r["mesh"] == mesh]
    out = []
    for r in sorted(sel, key=lambda x: (x["arch"], x["shape"])):
        ro = Roofline(**{k: r[k] for k in
                         ("arch", "shape", "mesh", "chips", "hlo_flops",
                          "hlo_bytes", "coll_bytes", "coll_breakdown",
                          "model_flops", "bytes_per_device", "extra")})
        out.append(f"* **{r['arch']} × {r['shape']}** — {advice(ro)}")
    return "\n".join(out)


if __name__ == "__main__":
    d = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    rs = rows(d)
    print("### Single-pod (16×16 = 256 chips) — baseline, every defined cell\n")
    print(fmt(rs, "data16xmodel16"))
    print("\n### Multi-pod (2×16×16 = 512 chips)\n")
    print(fmt(rs, "pod2xdata16xmodel16"))
    print("\n### Per-cell bottleneck advice (single-pod)\n")
    print(advice_lines(rs, "data16xmodel16"))
