#!/usr/bin/env python
"""CI guard for the repro.linalg public surface.

Asserts (1) ``repro.linalg.__all__`` is exactly the frozen list below,
(2) every routine keeps its dtype-generic, context-scoped signature
(``dtype`` and ``context`` keyword parameters), and (3) the
ExecutionContext field set is stable - so an accidental surface break
(renamed routine, dropped kwarg, new required positional) fails CI
instead of landing silently. Update the frozen lists *in the same PR* as
an intentional surface change.
"""
import inspect
import sys

EXPECTED_ALL = [
    # context machinery
    "ExecutionContext", "use", "get_context", "set_context", "reset_context",
    # BLAS level 1
    "axpy", "dot", "scal", "nrm2", "asum", "iamax", "rot",
    # BLAS level 2
    "gemv", "ger", "trsv",
    # BLAS level 3
    "gemm", "syrk", "trsm",
    # LAPACK
    "cholesky", "lu", "qr", "solve", "lstsq",
    # batched LAPACK
    "batched_cholesky", "batched_lu", "batched_qr", "batched_solve",
    "FactorizationResult",
]

# routine -> parameters that must exist (beyond the operands)
EXPECTED_PARAMS = {
    "gemm": {"a", "b", "c", "alpha", "beta", "transa", "transb", "dtype",
             "context"},
    "gemv": {"a", "x", "y", "alpha", "beta", "trans", "dtype", "context"},
    "syrk": {"a", "c", "alpha", "beta", "lower", "trans", "dtype", "context"},
    "trsm": {"a", "b", "lower", "unit_diag", "left", "block", "dtype",
             "context"},
    "axpy": {"alpha", "x", "y", "dtype", "context"},
    "dot": {"x", "y", "schedule", "accumulators", "dtype", "context"},
    "scal": {"alpha", "x", "dtype", "context"},
    "nrm2": {"x", "dtype", "context"},
    "asum": {"x", "dtype", "context"},
    "iamax": {"x", "context"},
    "rot": {"x", "y", "c", "s", "dtype", "context"},
    "ger": {"alpha", "x", "y", "a", "dtype", "context"},
    "trsv": {"a", "b", "lower", "unit_diag", "dtype", "context"},
    "cholesky": {"a", "block", "dtype", "context"},
    "lu": {"a", "block", "dtype", "context"},
    "qr": {"a", "block", "dtype", "context"},
    "solve": {"a", "b", "block", "dtype", "context"},
    "lstsq": {"a", "b", "block", "dtype", "context"},
    "batched_cholesky": {"a", "block", "dtype", "context"},
    "batched_lu": {"a", "block", "dtype", "context"},
    "batched_qr": {"a", "block", "dtype", "context"},
    "batched_solve": {"res", "b", "dtype", "context"},
}

EXPECTED_CONTEXT_FIELDS = {"policy", "mesh", "registry", "accum_dtype",
                           "interpret"}


def main() -> int:
    from repro import linalg

    errors = []
    got_all = list(linalg.__all__)
    if got_all != EXPECTED_ALL:
        missing = set(EXPECTED_ALL) - set(got_all)
        extra = set(got_all) - set(EXPECTED_ALL)
        errors.append(f"__all__ drifted: missing={sorted(missing)} "
                      f"extra={sorted(extra)} (order matters too)")

    for name, want in EXPECTED_PARAMS.items():
        fn = getattr(linalg, name, None)
        if fn is None:
            errors.append(f"routine {name} missing from repro.linalg")
            continue
        params = set(inspect.signature(fn).parameters)
        lost = want - params
        if lost:
            errors.append(f"{name}: lost parameters {sorted(lost)} "
                          f"(has {sorted(params)})")
        if name != "iamax" and "dtype" not in params:
            errors.append(f"{name}: must stay dtype-generic (dtype kwarg)")
        if "context" not in params:
            errors.append(f"{name}: must accept a per-call context override")

    import dataclasses
    fields = {f.name for f in dataclasses.fields(linalg.ExecutionContext)}
    if fields != EXPECTED_CONTEXT_FIELDS:
        errors.append(f"ExecutionContext fields drifted: {sorted(fields)} "
                      f"!= {sorted(EXPECTED_CONTEXT_FIELDS)}")

    if errors:
        print("repro.linalg API surface check FAILED:")
        for e in errors:
            print(f"  - {e}")
        return 1
    print(f"repro.linalg API surface OK ({len(EXPECTED_PARAMS)} routines, "
          f"{len(EXPECTED_ALL)} exported names)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
