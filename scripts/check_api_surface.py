#!/usr/bin/env python
"""CI guard for the repro.linalg public surface.

Asserts (1) ``repro.linalg.__all__`` is exactly the frozen list below,
(2) every routine keeps its dtype-generic, context-scoped signature
(``dtype`` and ``context`` keyword parameters), and (3) the
ExecutionContext field set is stable - so an accidental surface break
(renamed routine, dropped kwarg, new required positional) fails CI
instead of landing silently. Update the frozen lists *in the same PR* as
an intentional surface change.
"""
import inspect
import sys

EXPECTED_ALL = [
    # context machinery
    "ExecutionContext", "use", "get_context", "set_context", "reset_context",
    # BLAS level 1
    "axpy", "dot", "scal", "nrm2", "asum", "iamax", "rot",
    # BLAS level 2
    "gemv", "ger", "trsv",
    # BLAS level 3
    "gemm", "gemm_bias_act", "syrk", "trsm",
    # LAPACK
    "cholesky", "lu", "qr", "solve", "lstsq",
    # batched LAPACK
    "batched_cholesky", "batched_lu", "batched_qr", "batched_solve",
    "FactorizationResult",
]

# routine -> parameters that must exist (beyond the operands)
EXPECTED_PARAMS = {
    "gemm": {"a", "b", "c", "alpha", "beta", "transa", "transb", "dtype",
             "context"},
    "gemv": {"a", "x", "y", "alpha", "beta", "trans", "dtype", "context"},
    "syrk": {"a", "c", "alpha", "beta", "lower", "trans", "dtype", "context"},
    "trsm": {"a", "b", "lower", "unit_diag", "left", "block", "dtype",
             "context"},
    "axpy": {"alpha", "x", "y", "dtype", "context"},
    "dot": {"x", "y", "schedule", "accumulators", "dtype", "context"},
    "scal": {"alpha", "x", "dtype", "context"},
    "nrm2": {"x", "dtype", "context"},
    "asum": {"x", "dtype", "context"},
    "iamax": {"x", "context"},
    "rot": {"x", "y", "c", "s", "dtype", "context"},
    "ger": {"alpha", "x", "y", "a", "dtype", "context"},
    "trsv": {"a", "b", "lower", "unit_diag", "dtype", "context"},
    "gemm_bias_act": {"a", "b", "bias", "epilogue", "dtype", "context"},
    "cholesky": {"a", "block", "dtype", "context", "fuse"},
    "lu": {"a", "block", "dtype", "context", "fuse"},
    "qr": {"a", "block", "dtype", "context"},
    "solve": {"a", "b", "block", "dtype", "context"},
    "lstsq": {"a", "b", "block", "dtype", "context"},
    "batched_cholesky": {"a", "block", "dtype", "context"},
    "batched_lu": {"a", "block", "dtype", "context"},
    "batched_qr": {"a", "block", "dtype", "context"},
    "batched_solve": {"res", "b", "dtype", "context"},
}

EXPECTED_CONTEXT_FIELDS = {"policy", "mesh", "registry", "accum_dtype",
                           "interpret", "machine", "obs"}

EXPECTED_ARCH_ALL = [
    # spec types
    "MachineSpec", "FPUSpec", "MemorySpec", "PEGeometry", "PowerAreaSpec",
    "OP_CLASSES",
    # registry
    "get", "register", "names", "DEFAULT_MACHINE",
    # ambient machine scoping
    "current_machine", "machine_scope", "set_default_machine",
    "resolve_machine", "machine_key_component",
    # built-in specs
    "TPU_LIKE", "PAPER_PE", "CPU_HOST",
    # measured-machine calibration
    "calibrate", "calibrate_full", "load_or_calibrate",
    "CalibrationResult", "CALIBRATION_TOLERANCE",
    # benchmark helper
    "bench_metrics",
]

# arch.calibrate* keyword surface (benchmark kwargs ride **bench_kwargs and
# are guarded on run_microbenchmarks instead)
EXPECTED_CALIBRATE_PARAMS = {"backend", "base", "name", "register",
                             "overwrite", "path"}
EXPECTED_MICROBENCH_PARAMS = {"gemm_sizes", "stream_elems", "chain_iters",
                              "reps", "min_reps", "max_reps", "rel_spread"}

# the measurement surface every sweep/bench/calibration times through
EXPECTED_TUNE_MEASURE = ["Measurement", "measure", "measure_wall_time",
                         "model_residual", "repetition_controller"]
EXPECTED_MEASUREMENT_FIELDS = {"samples", "seconds_median", "seconds_spread",
                               "reps", "converged", "target_spread"}
# the row fields every bench JSON row carries (docs/benchmarking.md;
# the perf-regression gate reads seconds_median/seconds_spread)
EXPECTED_ROW_FIELDS = {"seconds_median", "seconds_spread", "reps"}

# spec dataclass -> frozen field set (registry keys and serialized files
# depend on these names; change them only with a schema bump)
EXPECTED_ARCH_FIELDS = {
    "MachineSpec": {"name", "fpu", "memory", "pe", "power_area",
                    "native_dtype"},
    "FPUSpec": {"depths", "t_p", "t_o", "gamma", "acc_overhead"},
    "MemorySpec": {"hbm_bw", "vmem_bytes", "ici_bw", "hbm_bytes",
                   "pipeline_fill_s"},
    "PEGeometry": {"mxu", "sublane", "lane", "vreg_budget", "peak_flops"},
    "PowerAreaSpec": {"pj_per_flop", "pj_per_byte_hbm", "static_w",
                      "area_mm2"},
}

EXPECTED_MACHINE_NAMES = {"tpu-like", "paper-pe", "cpu-host"}

# the repro.obs tracing surface (docs/observability.md): exported names,
# the frozen per-event schema (exporters and scripts/trace_report.py
# parse these exact fields), and the counter vocabulary
EXPECTED_OBS_ALL = [
    # schema
    "SCHEMA_VERSION", "EVENT_FIELDS",
    # tracer
    "Trace", "Span", "trace", "capture", "span", "event", "annotate",
    "enabled", "current_trace", "NOOP_SPAN",
    # counters
    "KNOWN_COUNTERS", "inc", "counter", "counters_snapshot",
    "counters_delta", "reset_counters",
    # exporters
    "to_chrome_trace", "save_chrome_trace", "to_jsonl", "save_jsonl",
    "summary",
]
EXPECTED_EVENT_FIELDS = ("name", "cat", "id", "parent", "t_start", "t_end",
                         "attrs")
EXPECTED_COUNTERS = {
    "dispatch.resolve", "dispatch.registry_hit", "dispatch.registry_miss",
    "registry.load", "registry.missing_fallback", "registry.corrupt_fallback",
    "kernel.launch", "collective.hops", "collective.bytes",
}

# the repro.analysis static-verification surface (docs/static_analysis.md):
# exported names, the frozen rule-ID vocabulary (allowlists, docs, and
# seeded-violation tests key on IDs and severities), and the report /
# finding record layouts that CI artifacts serialize
EXPECTED_ANALYSIS_ALL = [
    "RULES", "Finding", "AnalysisReport",
    "check", "check_routine", "check_surface", "check_distributed",
    "surface_routines", "merge_reports", "allow", "Allowlist",
    "load_allowlist",
    "lint_bypass", "collect_bypass_sites", "load_bypass_allowlist",
]
EXPECTED_ANALYSIS_RULES = {
    "KL001": "error", "KL002": "error", "KL003": "error", "KL004": "error",
    "DF001": "error", "DF002": "error", "DF003": "warn", "DF004": "error",
    "CM001": "error", "CM002": "warn", "CM003": "warn",
    "CC001": "error", "CC002": "error", "CC003": "error",
    "SH001": "error", "SH002": "error", "SH003": "warn",
    "BY001": "error",
}
# trace-time collective metadata record (spmd_lint's record view): the
# analyzer, obs counters, and plan_pdgemm all key on these field names
EXPECTED_COLLECTIVE_RECORD_FIELDS = {"kind", "axis", "size", "src", "hops",
                                     "per_hop_bytes", "wire_bytes", "info"}
# the distributed acceptance meshes CI sweeps (degenerate/square/rect)
EXPECTED_SURFACE_MESHES = ((1, 1), (2, 2), (4, 2))
EXPECTED_REPORT_FIELDS = {"target", "cases", "findings", "suppressed",
                          "schema_version"}
EXPECTED_FINDING_FIELDS = {"rule", "severity", "routine", "message",
                           "location", "case", "suppressed", "suppressed_by"}


# the streaming-fusion surface (docs/fusion.md): kernel exports, the
# registry op strings dispatch resolves, the chain planner signature, and
# the FusedChainPlan record the benches/tests consume
EXPECTED_FUSED_KERNELS = ["EPILOGUES", "apply_epilogue", "fused_span",
                          "gemm_bias_act", "trsm_gemm"]
EXPECTED_EPILOGUES = ("none", "relu", "gelu")
EXPECTED_FUSED_OPS = ("gemm+epilogue", "trsm+gemm")
EXPECTED_FUSED_CHAIN_PARAMS = {"kind", "m", "n", "k", "dtype_bytes", "dtype",
                               "epilogue", "has_bias", "form", "machine"}
EXPECTED_FUSED_CHAIN_FIELDS = {"kind", "form", "gemm", "block", "vmem_bytes",
                               "fits_vmem", "unfused_hbm_bytes",
                               "fused_hbm_bytes", "unfused_time",
                               "fused_time"}


def check_fusion(errors) -> None:
    import dataclasses

    from repro import tune
    from repro.core import codesign as cd
    from repro.kernels import fused as fk
    from repro.tune import dispatch as td

    for name in EXPECTED_FUSED_KERNELS:
        if not hasattr(fk, name):
            errors.append(f"repro.kernels.fused lost {name}")
    if tuple(getattr(fk, "EPILOGUES", ())) != EXPECTED_EPILOGUES:
        errors.append(f"kernels.fused.EPILOGUES drifted: "
                      f"{getattr(fk, 'EPILOGUES', None)} "
                      f"!= {EXPECTED_EPILOGUES}")
    if tuple(getattr(td, "FUSED_OPS", ())) != EXPECTED_FUSED_OPS:
        errors.append(f"dispatch.FUSED_OPS drifted: "
                      f"{getattr(td, 'FUSED_OPS', None)} "
                      f"!= {EXPECTED_FUSED_OPS}")
    if not set(EXPECTED_FUSED_OPS) <= set(td.OPS):
        errors.append("fused registry ops missing from dispatch.OPS: "
                      f"{sorted(set(EXPECTED_FUSED_OPS) - set(td.OPS))}")
    if tuple(getattr(cd, "FUSED_CHAIN_KINDS", ())) != EXPECTED_FUSED_OPS:
        errors.append("codesign.FUSED_CHAIN_KINDS must match the dispatch "
                      "registry op strings")
    params = set(inspect.signature(cd.plan_fused_chain).parameters)
    lost = EXPECTED_FUSED_CHAIN_PARAMS - params
    if lost:
        errors.append(f"plan_fused_chain: lost parameters {sorted(lost)}")
    fields = {f.name for f in dataclasses.fields(cd.FusedChainPlan)}
    if fields != EXPECTED_FUSED_CHAIN_FIELDS:
        errors.append(f"FusedChainPlan fields drifted: {sorted(fields)} "
                      f"!= {sorted(EXPECTED_FUSED_CHAIN_FIELDS)}")
    if "tune_fused_gemm" not in tune.__all__:
        errors.append("repro.tune.__all__ lost tune_fused_gemm")


def check_arch(errors) -> None:
    import dataclasses

    from repro import arch

    got_all = list(arch.__all__)
    if got_all != EXPECTED_ARCH_ALL:
        missing = set(EXPECTED_ARCH_ALL) - set(got_all)
        extra = set(got_all) - set(EXPECTED_ARCH_ALL)
        errors.append(f"arch.__all__ drifted: missing={sorted(missing)} "
                      f"extra={sorted(extra)} (order matters too)")
    for cls_name, want in EXPECTED_ARCH_FIELDS.items():
        cls = getattr(arch, cls_name, None)
        if cls is None:
            errors.append(f"repro.arch lost {cls_name}")
            continue
        fields = {f.name for f in dataclasses.fields(cls)}
        if fields != want:
            errors.append(f"arch.{cls_name} fields drifted: "
                          f"{sorted(fields)} != {sorted(want)}")
    if not EXPECTED_MACHINE_NAMES <= set(arch.names()):
        errors.append(f"built-in machines missing: "
                      f"{sorted(EXPECTED_MACHINE_NAMES - set(arch.names()))}")

    for fn_name, want in (("calibrate", EXPECTED_CALIBRATE_PARAMS),
                          ("calibrate_full", EXPECTED_CALIBRATE_PARAMS)):
        fn = getattr(arch, fn_name, None)
        if fn is None:
            errors.append(f"repro.arch lost {fn_name}")
            continue
        params = set(inspect.signature(fn).parameters)
        lost = want - params
        if lost:
            errors.append(f"arch.{fn_name}: lost parameters {sorted(lost)}")
    import importlib
    # arch.calibrate the function shadows the submodule attribute
    _cal = importlib.import_module("repro.arch.calibrate")
    params = set(inspect.signature(_cal.run_microbenchmarks).parameters)
    lost = EXPECTED_MICROBENCH_PARAMS - params
    if lost:
        errors.append(f"arch.calibrate.run_microbenchmarks: lost "
                      f"parameters {sorted(lost)}")


def check_measure(errors) -> None:
    import dataclasses

    from repro import tune
    from repro.tune import measure as m

    for name in EXPECTED_TUNE_MEASURE:
        if not hasattr(m, name):
            errors.append(f"repro.tune.measure lost {name}")
        if name not in tune.__all__ and name != "measure":
            errors.append(f"repro.tune.__all__ lost {name}")
    if "measure" not in tune.__all__ or "measure_op" not in tune.__all__:
        errors.append("repro.tune.__all__ lost the measure submodule / "
                      "measure_op alias")
    fields = {f.name for f in dataclasses.fields(m.Measurement)}
    if fields != EXPECTED_MEASUREMENT_FIELDS:
        errors.append(f"Measurement fields drifted: {sorted(fields)} "
                      f"!= {sorted(EXPECTED_MEASUREMENT_FIELDS)}")
    try:
        row = m.Measurement.from_samples([1.0, 2.0, 3.0]).row_fields()
        if set(row) != EXPECTED_ROW_FIELDS:
            errors.append(f"Measurement.row_fields drifted: {sorted(row)} "
                          f"!= {sorted(EXPECTED_ROW_FIELDS)}")
    except Exception as e:  # pragma: no cover - surface break
        errors.append(f"Measurement.row_fields broken: {e!r}")
    # the sweeps' historical import path must keep working
    from repro.tune import search
    if getattr(search, "measure_wall_time", None) is not m.measure_wall_time:
        errors.append("repro.tune.search.measure_wall_time is no longer the "
                      "shared measure helper")
    if getattr(search, "_timeit", None) is not m.measure_wall_time:
        errors.append("repro.tune.search._timeit alias broken")


def check_obs(errors) -> None:
    from repro import obs

    got_all = list(obs.__all__)
    if got_all != EXPECTED_OBS_ALL:
        missing = set(EXPECTED_OBS_ALL) - set(got_all)
        extra = set(got_all) - set(EXPECTED_OBS_ALL)
        errors.append(f"obs.__all__ drifted: missing={sorted(missing)} "
                      f"extra={sorted(extra)} (order matters too)")
    if tuple(obs.EVENT_FIELDS) != EXPECTED_EVENT_FIELDS:
        errors.append(f"obs.EVENT_FIELDS drifted: {tuple(obs.EVENT_FIELDS)} "
                      f"!= {EXPECTED_EVENT_FIELDS} (schema bump needed)")
    if set(obs.KNOWN_COUNTERS) != EXPECTED_COUNTERS:
        errors.append(f"obs.KNOWN_COUNTERS drifted: "
                      f"{sorted(set(obs.KNOWN_COUNTERS) ^ EXPECTED_COUNTERS)}")
    if obs.SCHEMA_VERSION != 1:
        errors.append(f"obs.SCHEMA_VERSION bumped to {obs.SCHEMA_VERSION}: "
                      "update trace_report.py + this guard together")
    # the disabled-path contract: no ambient trace -> the shared no-op span
    if obs.enabled():
        errors.append("obs.enabled() is True at import with no trace active")
    if obs.span("surface-check") is not obs.NOOP_SPAN:
        errors.append("obs.span() off-trace must return the NOOP_SPAN "
                      "singleton (dict-free disabled path)")


def check_analysis(errors) -> None:
    import dataclasses

    from repro import analysis

    got_all = list(analysis.__all__)
    if got_all != EXPECTED_ANALYSIS_ALL:
        missing = set(EXPECTED_ANALYSIS_ALL) - set(got_all)
        extra = set(got_all) - set(EXPECTED_ANALYSIS_ALL)
        errors.append(f"analysis.__all__ drifted: missing={sorted(missing)} "
                      f"extra={sorted(extra)} (order matters too)")
    got_rules = {r.id: r.severity for r in analysis.RULES.values()}
    if got_rules != EXPECTED_ANALYSIS_RULES:
        drifted = {rid for rid in set(got_rules) | set(EXPECTED_ANALYSIS_RULES)
                   if got_rules.get(rid) != EXPECTED_ANALYSIS_RULES.get(rid)}
        errors.append(f"analysis rule vocabulary drifted on {sorted(drifted)}"
                      ": IDs are frozen - an ID may gain wording but never "
                      "disappear or change severity silently")
    for cls_name, want in (("AnalysisReport", EXPECTED_REPORT_FIELDS),
                           ("Finding", EXPECTED_FINDING_FIELDS)):
        cls = getattr(analysis, cls_name, None)
        if cls is None:
            errors.append(f"repro.analysis lost {cls_name}")
            continue
        fields = {f.name for f in dataclasses.fields(cls)}
        if fields != want:
            errors.append(f"analysis.{cls_name} fields drifted: "
                          f"{sorted(fields)} != {sorted(want)} "
                          "(CI artifacts serialize these)")
    if analysis.check_surface.__defaults__ is None:
        errors.append("analysis.check_surface lost its defaulted grid")
    from repro.analysis import report as _report
    if tuple(getattr(_report, "SURFACE_MESHES", ())) != \
            EXPECTED_SURFACE_MESHES:
        errors.append(f"analysis SURFACE_MESHES drifted: "
                      f"{getattr(_report, 'SURFACE_MESHES', None)} "
                      f"!= {EXPECTED_SURFACE_MESHES}")
    from repro.distributed import collectives as _coll
    rec = getattr(_coll, "CollectiveRecord", None)
    if rec is None or not hasattr(_coll, "record_collectives"):
        errors.append("repro.distributed.collectives lost the "
                      "CollectiveRecord / record_collectives surface")
    else:
        fields = {f.name for f in dataclasses.fields(rec)}
        if fields != EXPECTED_COLLECTIVE_RECORD_FIELDS:
            errors.append(f"CollectiveRecord fields drifted: "
                          f"{sorted(fields)} != "
                          f"{sorted(EXPECTED_COLLECTIVE_RECORD_FIELDS)}")
    from repro.tune import dispatch as _td
    dm = getattr(_td, "DISPATCHED_MODULES", ())
    if not (isinstance(dm, tuple) and dm):
        errors.append("tune.dispatch.DISPATCHED_MODULES must stay a "
                      "non-empty tuple (BY001 provenance)")


def main() -> int:
    from repro import linalg

    errors = []
    check_arch(errors)
    check_measure(errors)
    check_obs(errors)
    check_fusion(errors)
    check_analysis(errors)
    got_all = list(linalg.__all__)
    if got_all != EXPECTED_ALL:
        missing = set(EXPECTED_ALL) - set(got_all)
        extra = set(got_all) - set(EXPECTED_ALL)
        errors.append(f"__all__ drifted: missing={sorted(missing)} "
                      f"extra={sorted(extra)} (order matters too)")

    for name, want in EXPECTED_PARAMS.items():
        fn = getattr(linalg, name, None)
        if fn is None:
            errors.append(f"routine {name} missing from repro.linalg")
            continue
        params = set(inspect.signature(fn).parameters)
        lost = want - params
        if lost:
            errors.append(f"{name}: lost parameters {sorted(lost)} "
                          f"(has {sorted(params)})")
        if name != "iamax" and "dtype" not in params:
            errors.append(f"{name}: must stay dtype-generic (dtype kwarg)")
        if "context" not in params:
            errors.append(f"{name}: must accept a per-call context override")

    import dataclasses
    fields = {f.name for f in dataclasses.fields(linalg.ExecutionContext)}
    if fields != EXPECTED_CONTEXT_FIELDS:
        errors.append(f"ExecutionContext fields drifted: {sorted(fields)} "
                      f"!= {sorted(EXPECTED_CONTEXT_FIELDS)}")

    if errors:
        print("repro.linalg API surface check FAILED:")
        for e in errors:
            print(f"  - {e}")
        return 1
    print(f"repro.linalg + repro.arch + repro.tune.measure + repro.obs + "
          f"fusion + analysis API surface OK ({len(EXPECTED_PARAMS)} "
          f"routines, "
          f"{len(EXPECTED_ALL)} linalg + {len(EXPECTED_ARCH_ALL)} arch + "
          f"{len(EXPECTED_OBS_ALL)} obs + {len(EXPECTED_ANALYSIS_ALL)} "
          f"analysis exported names, "
          f"{len(EXPECTED_ANALYSIS_RULES)} frozen rule IDs, "
          f"{len(EXPECTED_TUNE_MEASURE)} measurement names, "
          f"{len(EXPECTED_FUSED_KERNELS)} fused-kernel names)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
