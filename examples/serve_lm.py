"""Batched serving demo: prefill + decode with KV caches over a request
queue, on a reduced config of an assigned architecture.

  PYTHONPATH=src python examples/serve_lm.py --arch hymba-1.5b
  PYTHONPATH=src python examples/serve_lm.py --arch mamba2-130m --requests 8
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    serve_main()
