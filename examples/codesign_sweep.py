"""Reproduce the paper's experimental arc end-to-end (figs 12-13 + eq. 7).

For GEMM, QR and LU instruction streams, sweep the relevant FP-unit pipeline
depths on the cycle-exact PE, print the TPI curves, and compare the simulated
optimum with the closed-form eq.-7 prediction from the symbolic
characterization - the paper's 'theoretical curves corroborate simulations'
claim, regenerated from scratch.

Run:  PYTHONPATH=src python examples/codesign_sweep.py [n]
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import characterization as ch
from repro.core import isa, pe

n = int(sys.argv[1]) if len(sys.argv) > 1 else 32
depths = [2, 3, 4, 6, 8, 12, 16, 24, 32]

cases = [
    ("dgemm", isa.compile_dgemm(n, n, n, unroll=4),
     ch.characterize_dgemm(n, n, n), ["add", "mul"]),
    ("dgeqrf", isa.compile_dgeqrf(n), ch.characterize_dgeqrf(n),
     ["sqrt", "div"]),
    ("dgetrf", isa.compile_dgetrf(n), ch.characterize_dgetrf(n), ["div"]),
]

for name, stream, prof, units in cases:
    print(f"\n=== {name} (n={n}, {stream.n_instructions} instructions) ===")
    res = pe.sweep_joint(stream, units, depths)
    print("   depth   CPI       TPI")
    for r in res:
        print(f"   {r.depths[units[0]]:5d}  {r.cpi:7.3f}  {r.tpi:9.3f}")
    best = min(res, key=lambda r: r.tpi)
    theory = prof.optimal_depths()
    print(f"   simulated best {units[0]} depth: {best.depths[units[0]]}")
    print(f"   eq.-7 prediction: { {u: theory.get(u) for u in units} }")
print("\nOK - theory and simulation agree on the depth ordering: "
      "hazard-free pipes deep, serial sqrt/div pipes shallow.")
