"""Reproduce the paper's experimental arc end-to-end (figs 12-13 + eq. 7),
driven by the `repro.arch` machine API.

Part 1 - pipeline-depth sweeps on the cycle-exact PE: for GEMM, QR and LU
instruction streams, sweep the relevant FP-unit depths (priced at the
"paper-pe" machine's technology constants), print the TPI curves, and
compare the simulated optimum with the closed-form eq.-7 prediction from
the symbolic characterization - the paper's 'theoretical curves
corroborate simulations' claim, regenerated from scratch.

Part 2 - machine comparison: sweep the same GEMM through the analytic
planner on two registered machines and score each in modeled Gflops/W and
Gflops/mm^2 - the paper's two comparison axes (its PE wins 1.1-1.5x /
1.9-2.1x over custom realizations; the built-in specs reproduce those
bands at peak).

Run:  PYTHONPATH=src python examples/codesign_sweep.py [n]
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import arch
from repro.core import characterization as ch
from repro.core import codesign, isa, pe

n = int(sys.argv[1]) if len(sys.argv) > 1 else 32
depths = [2, 3, 4, 6, 8, 12, 16, 24, 32]

paper_pe = arch.get("paper-pe")

cases = [
    ("dgemm", isa.compile_dgemm(n, n, n, unroll=4),
     ch.characterize_dgemm(n, n, n, fpu=paper_pe.fpu), ["add", "mul"]),
    ("dgeqrf", isa.compile_dgeqrf(n),
     ch.characterize_dgeqrf(n, fpu=paper_pe.fpu), ["sqrt", "div"]),
    ("dgetrf", isa.compile_dgetrf(n),
     ch.characterize_dgetrf(n, fpu=paper_pe.fpu), ["div"]),
]

for name, stream, prof, units in cases:
    print(f"\n=== {name} (n={n}, {stream.n_instructions} instructions, "
          f"machine={paper_pe.name}) ===")
    res = pe.sweep_joint(stream, units, depths, machine=paper_pe)
    print("   depth   CPI       TPI")
    for r in res:
        print(f"   {r.depths[units[0]]:5d}  {r.cpi:7.3f}  {r.tpi:9.3f}")
    best = min(res, key=lambda r: r.tpi)
    theory = prof.optimal_depths()
    print(f"   simulated best {units[0]} depth: {best.depths[units[0]]}")
    print(f"   eq.-7 prediction: { {u: theory.get(u) for u in units} }")

# --------------------- machine comparison (Gflops/W) ------------------------

MACHINES = ("tpu-like", "paper-pe")
gemm_n = 4096
print(f"\n=== machine sweep: GEMM {gemm_n}^3 at each machine's native "
      f"dtype ===")
header = (f"{'machine':>10} {'native':>9} {'tiling':>14} {'gflops':>10} "
          f"{'gflops/W':>9} {'gflops/mm2':>11}")
print(header)
print("-" * len(header))
for name in MACHINES:
    m = arch.get(name)
    plan = codesign.plan_gemm(gemm_n, gemm_n, gemm_n, machine=m)
    # modeled sustained rate at this tiling: roofline-limited
    rate = min(m.pe.peak_flops,
               plan.arithmetic_intensity * m.memory.hbm_bw)
    gflops = rate / 1e9
    hbm_rate = rate / max(plan.arithmetic_intensity, 1e-12)
    row = arch.bench_metrics(gflops, machine=m, hbm_bytes_per_s=hbm_rate)
    tiling = f"{plan.bm}x{plan.bn}x{plan.bk}"
    print(f"{name:>10} {m.native_dtype:>9} {tiling:>14} "
          f"{row['gflops']:>10.0f} {row['gflops_per_w']:>9.1f} "
          f"{row['gflops_per_mm2']:>11.1f}")

ratio_w = (arch.get('paper-pe').peak_gflops_per_w()
           / arch.get('tpu-like').peak_gflops_per_w())
ratio_a = (arch.get('paper-pe').peak_gflops_per_mm2()
           / arch.get('tpu-like').peak_gflops_per_mm2())
print(f"\npaper-pe vs tpu-like at peak: {ratio_w:.2f}x Gflops/W, "
      f"{ratio_a:.2f}x Gflops/mm2 (paper: 1.1-1.5x / 1.9-2.1x)")
print("\nOK - theory and simulation agree on the depth ordering "
      "(hazard-free pipes deep, serial sqrt/div pipes shallow), and the "
      "machine registry reproduces the paper's efficiency comparison.")
