"""End-to-end training driver: train a GQA LM on the synthetic pipeline.

Defaults to a ~7M-param config that makes visible progress in minutes on
this CPU container; ``--hundred-m`` selects a ~100M-param model (same code
path - run it when you have a real accelerator or patience). Demonstrates
the full production loop: sharded state, checkpoint/resume, heartbeat,
straggler report, LR schedule, gradient clipping.

  PYTHONPATH=src python examples/train_lm.py --steps 300
  PYTHONPATH=src python examples/train_lm.py --hundred-m --steps 300
"""
import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_debug_mesh
from repro.launch.train import train_loop
from repro.models.config import ModelConfig
from repro.models import model_zoo as zoo
from repro.train.optimizer import AdamWConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--hundred-m", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="checkpoints/train_lm")
    args = ap.parse_args()

    if args.hundred_m:
        cfg = ModelConfig("lm-100m", "dense", n_layers=12, d_model=768,
                          n_heads=12, n_kv=4, d_ff=2048, vocab=32768,
                          dtype="float32")
    else:
        cfg = ModelConfig("lm-7m", "dense", n_layers=4, d_model=256,
                          n_heads=8, n_kv=4, d_ff=1024, vocab=4096,
                          dtype="float32")
    print(f"model: {cfg.name}  params={zoo.param_count(cfg) / 1e6:.1f}M")
    opt = AdamWConfig(lr=1e-3, warmup_steps=max(args.steps // 20, 10),
                      decay_steps=args.steps)
    data = DataConfig(vocab=cfg.vocab, global_batch=args.batch,
                      seq_len=args.seq)
    mesh = make_debug_mesh(data=1, model=1)
    _, hist = train_loop(cfg, opt, data, mesh, args.steps, args.ckpt_dir,
                         save_interval=max(args.steps // 4, 10))
    print(f"loss: {hist[0]:.4f} -> {hist[-1]:.4f} over {len(hist)} steps")
    assert hist[-1] < hist[0], "no learning?"
    print("OK")


if __name__ == "__main__":
    main()
