"""LAPACK-on-JAX demo: blocked QR / LU / Cholesky + solver accuracy, with
the panel/trailing split the paper's section 4.2 characterizes, and the
jaxpr census run over the factorizations themselves (closing the loop:
workload -> census -> optimal pipe depths, on the real implementation).

  PYTHONPATH=src python examples/factorization_demo.py [n]
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro import lapack
from repro.core import jaxpr_census as jc

n = int(sys.argv[1]) if len(sys.argv) > 1 else 96
rng = np.random.default_rng(0)
a = jnp.asarray(rng.normal(size=(n, n)).astype(np.float32))

print(f"=== blocked QR ({n}x{n}) ===")
q, r = lapack.qr.qr(a, block=32)
print(f"  ||QR - A||_max = {float(jnp.max(jnp.abs(q @ r - a))):.2e}")
print(f"  ||Q'Q - I||_max = {float(jnp.max(jnp.abs(q.T @ q - jnp.eye(n)))):.2e}")

print(f"=== blocked LU w/ partial pivoting ===")
packed, piv = lapack.getrf(a, block=32)
rec = lapack.lu_reconstruct(packed, piv)
print(f"  ||PtLU - A||_max = {float(jnp.max(jnp.abs(rec - a))):.2e}")

print(f"=== blocked Cholesky ===")
s = a @ a.T + n * jnp.eye(n)
c = lapack.potrf(s, block=32)
print(f"  ||LL' - S||_max = {float(jnp.max(jnp.abs(c @ c.T - s))):.2e}")

print(f"=== solve (LU) + least squares (QR) ===")
b = jnp.asarray(rng.normal(size=n).astype(np.float32))
x = lapack.gesv(a, b)
print(f"  ||Ax - b||_max = {float(jnp.max(jnp.abs(a @ x - b))):.2e}")

print("=== batched blocked LAPACK (vmap over the GEMM hot path) ===")
from repro.core.codesign import plan_factorization

B = 8
batch = jnp.asarray(rng.normal(size=(B, n, n)).astype(np.float32))
spd = batch @ jnp.swapaxes(batch, 1, 2) + n * jnp.eye(n)
plan = plan_factorization(n, kind="potrf", batch=B)
print(f"  plan_factorization(n={n}, potrf): NB={plan.block}, "
      f"panel_fraction={plan.panel_fraction:.2f}")
res = lapack.batched_potrf(spd)          # NB defaults to the plan's choice
err = float(jnp.max(jnp.abs(lapack.reconstruct(res) - spd)))
print(f"  batched_potrf({B}x{n}x{n}): ||LL' - S||_max = {err:.2e}")
rhs = jnp.asarray(rng.normal(size=(B, n)).astype(np.float32))
x = lapack.batched_solve(lapack.batched_getrf(batch), rhs)
resid = float(jnp.max(jnp.abs(jnp.einsum("bij,bj->bi", batch, x) - rhs)))
print(f"  batched_solve (LU, {B} systems): ||Ax - b||_max = {resid:.2e}")

print("=== section-4 census of the real DGEQRF implementation ===")
cen = jc.census_of(lambda m: lapack.qr.geqrf(m, block=32), a, name="dgeqrf")
print(jc.report(cen))
print("-> the sqrt pipe is fully serial (hazard ratio 1.0) while the "
      "GEMM-dominated mul/add volume dwarfs the O(n^2) div stream - the "
      "paper's fig. 9/10 structure, measured on the framework's own "
      "factorization. (The program-order hazard proxy under-detects the "
      "div chain; the ISA-stream census in benchmarks/bench_pe_cpi.py "
      "carries the exact dependences.)")
