"""Quickstart: the paper's codesign loop in five steps.

1. characterize a BLAS workload (section 4),
2. get the optimal pipeline depths (eq. 7),
3. confirm on the cycle-level PE simulator (section 5),
4. map the optimum to TPU knobs (accumulators / block shapes),
5. run the codesigned Pallas kernels against their oracles.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import characterization as ch
from repro.core import codesign, isa, pe
from repro.kernels import ops

print("=" * 70)
print("1) Characterize ddot(4096) - the paper's fig. 5 DAG")
prof = ch.characterize_ddot(4096, schedule="sequential")
print(f"   hazard ratios: { {k: round(v, 3) for k, v in prof.hazard_ratios().items()} }")

print("2) Optimal pipeline depths (eq. 7)")
print(f"   p_opt = {prof.optimal_depths()} (mul unbounded: hazard-free)")

print("3) Cycle-level PE simulation (depth sweep on the adder)")
stream = isa.compile_ddot(4096, schedule="sequential")
results = pe.sweep(stream, "add", [1, 2, 4, 8, 16, 32])
for r in results:
    print(f"   depth {r.depths['add']:3d}: CPI {r.cpi:6.3f}  TPI {r.tpi:8.3f}")
print(f"   best simulated depth: {pe.best_depth(results, 'add')}")

print("4) TPU adaptation: eq. 3 -> accumulator count / GEMM tiling")
u = codesign.optimal_accumulators(4096)
plan = codesign.plan_gemm(2048, 2048, 2048)
print(f"   U* = {u} accumulators (VPU add-latency window)")
print(f"   GEMM blocks ({plan.bm},{plan.bn},{plan.bk}), VMEM "
      f"{plan.vmem_bytes / 2**20:.1f} MiB, AI {plan.arithmetic_intensity:.0f} "
      f"flops/byte, compute_bound={plan.compute_bound}")

print("5) Codesigned Pallas kernels vs oracles (interpret=True on CPU)")
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=4096).astype(np.float32))
y = jnp.asarray(rng.normal(size=4096).astype(np.float32))
got = float(ops.dotp(x, y, accumulators=u, use_pallas=True, interpret=True))
want = float(np.dot(np.asarray(x), np.asarray(y)))
print(f"   dotp kernel: {got:.4f} vs oracle {want:.4f} "
      f"(err {abs(got - want):.2e})")
a = jnp.asarray(rng.normal(size=(256, 384)).astype(np.float32))
b = jnp.asarray(rng.normal(size=(384, 128)).astype(np.float32))
gk = ops.gemm(a, b, use_pallas=True, interpret=True)
err = float(jnp.max(jnp.abs(gk - a @ b)))
print(f"   gemm kernel max err vs oracle: {err:.2e}")
print("=" * 70)
print("OK")
