from repro.lapack import batched, cholesky, lu, qr, solve
from repro.lapack.batched import (FactorizationResult, batched_geqrf,
                                  batched_getrf, batched_potrf,
                                  batched_solve, reconstruct)
from repro.lapack.cholesky import potrf, potrf_unblocked
from repro.lapack.lu import getrf, getrf_unblocked, lu_reconstruct
from repro.lapack.qr import geqrf, geqrf_unblocked, q_from_geqrf
from repro.lapack.solve import gesv, lstsq_qr
from repro.lapack import distributed
