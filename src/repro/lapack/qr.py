"""GEQRF - Householder QR, unblocked and blocked (compact-WY), in JAX.

The paper's section-4.2 workload: the panel path carries the serial
sqrt (column norm) -> div (vector scale) hazard chain; the trailing update is
pure DGEMM. The blocked form makes that split explicit - panel = hazards,
trailing = throughput - which is why the adder/multiplier depths from
section 4.1 carry over and only sqrt/div need their own analysis.

All routines are jittable (static shapes, masked updates inside fori_loop).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro import obs as _obs
from repro.blas.level3 import gemm
from repro.lapack.cholesky import default_block


def _house_column(a: jnp.ndarray, k: int | jnp.ndarray,
                  row0: int | jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Householder vector for column ``k`` of ``a``, rows >= row0.

    Returns (v, tau) with v[row0..] the reflector (v[row0] = 1), zeros above.
    H = I - tau v v^T maps the column to (-sign(x0) ||x||) e_row0.
    """
    m = a.shape[0]
    rows = jnp.arange(m)
    mask = rows >= row0
    x = jnp.where(mask, a[:, k], 0.0)
    normx = jnp.sqrt(jnp.sum(x * x))
    x0 = a[row0, k]
    sign = jnp.where(x0 >= 0, 1.0, -1.0).astype(a.dtype)
    alpha = x0 + sign * normx                       # v0 before normalization
    safe = jnp.abs(alpha) > jnp.finfo(a.dtype).tiny
    alpha = jnp.where(safe, alpha, 1.0)
    v = jnp.where(rows > row0, x / alpha, 0.0)
    v = jnp.where(rows == row0, 1.0, v)
    v = jnp.where(mask, v, 0.0)
    vtv = jnp.sum(v * v)
    tau = jnp.where(safe & (normx > 0), 2.0 / vtv, 0.0).astype(a.dtype)
    return v, tau


def geqrf_unblocked(a: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Unblocked Householder QR in LAPACK packed layout.

    Parameters
    ----------
    a : (m, n) matrix (float32/float64), any aspect ratio.

    Returns
    -------
    (packed, tau)
        ``packed``: R on/above the diagonal, reflector tails below;
        ``tau``: (min(m, n),) reflector scales.

    Notes
    -----
    Oracle: ``tests/test_lapack.py`` (Q/R round-trip vs
    ``np.linalg.qr``).
    """
    m, n = a.shape
    kmax = min(m, n)

    def body(k, carry):
        A, tau = carry
        v, tk = _house_column(A, k, k)
        # apply H = I - tau v v^T to columns >= k only (earlier columns hold
        # stored reflector tails which H must not touch)
        w = tk * (v @ A)                             # (n,)
        w = jnp.where(jnp.arange(n) >= k, w, 0.0)
        A = A - jnp.outer(v, w)
        # store the reflector tail below the diagonal of column k
        col = jnp.where(jnp.arange(m) > k, v, A[:, k])
        A = A.at[:, k].set(col)
        return A, tau.at[k].set(tk)

    A, tau = lax.fori_loop(0, kmax, body, (a, jnp.zeros((kmax,), a.dtype)))
    return A, tau


def _larft(v: jnp.ndarray, tau: jnp.ndarray) -> jnp.ndarray:
    """Forward compact-WY T factor: Q = I - V T V^T (T upper triangular)."""
    nb = tau.shape[0]

    def body(k, t):
        # T[:k, k] = -tau_k * T[:k, :k] @ (V^T v_k);  T[k, k] = tau_k
        col = (v.T @ v[:, k])                        # (nb,)
        col = jnp.where(jnp.arange(nb) < k, col, 0.0)
        tcol = -tau[k] * (t @ col)
        tcol = jnp.where(jnp.arange(nb) < k, tcol, 0.0)
        tcol = tcol.at[k].set(tau[k])
        return t.at[:, k].set(tcol)

    return lax.fori_loop(0, nb, body, jnp.zeros((nb, nb), v.dtype))


def geqrf(a: jnp.ndarray, block: Optional[int] = None,
          policy: Optional[str] = None, use_kernel: Optional[bool] = None,
          interpret: bool = True,
          registry=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Blocked Householder QR, compact WY (LAPACK DGEQRF).

    Python loop over static panel boundaries -> still a single jittable
    computation.

    Parameters
    ----------
    a : (m, n) matrix (float32/float64).
    block : panel width NB; ``None`` takes
        ``plan_factorization(kind="geqrf")``'s model pick at a's dtype.
    registry : tuned-config registry forwarded to every trailing update
        (``None`` = the process default).
    policy : {"reference", "model", "tuned"}, optional
        The trailing compact-WY triple product is three GEMMs dispatched
        through :func:`repro.blas.level3.gemm`, resolved by
        :mod:`repro.tune.dispatch` (``"model"`` - the deprecated
        ``use_kernel=True`` - is the Pallas MXU kernel, ``"tuned"`` the
        registry config).

    Returns
    -------
    (packed, tau)
        Same LAPACK packed contract as :func:`geqrf_unblocked`.

    Notes
    -----
    Oracle: ``tests/test_lapack.py`` and ``tests/test_lapack_batched.py``
    (round-trip incl. tall and ill-conditioned inputs); per-policy
    agreement in ``tests/test_tune.py``.
    """
    from repro.tune.policy import resolve_policy
    pol = resolve_policy(policy, use_kernel)
    m, n = a.shape
    kmax = min(m, n)
    if block is None:
        block = default_block(kmax, "geqrf", a.dtype)
    if kmax <= block:
        return geqrf_unblocked(a)
    taus = []
    for j0 in range(0, kmax, block):
        nb = min(block, kmax - j0)
        # panel factorization (unblocked on the full height, masked rows)
        panel = a[:, j0:j0 + nb]

        def pbody(k, carry):
            P, tau = carry
            v, tk = _house_column(P, k, j0 + k)
            w = tk * (v @ P)
            w = jnp.where(jnp.arange(nb) >= k, w, 0.0)
            P = P - jnp.outer(v, w)
            col = jnp.where(jnp.arange(m) > j0 + k, v, P[:, k])
            P = P.at[:, k].set(col)
            return P, tau.at[k].set(tk)

        with _obs.span("geqrf.panel", cat="panel", j0=j0, nb=nb,
                       flops=2 * (m - j0) * nb * nb):
            panel, tau = lax.fori_loop(0, nb, pbody,
                                       (panel, jnp.zeros((nb,), a.dtype)))
        a = a.at[:, j0:j0 + nb].set(panel)
        taus.append(tau)
        # trailing update: C <- (I - V T V^T)^T C = C - V T^T (V^T C)
        if j0 + nb < n:
            rest = n - j0 - nb              # trailing columns
            with _obs.span("geqrf.trailing", cat="trailing", j0=j0, nb=nb,
                           flops=4 * m * nb * rest + 2 * nb * nb * rest):
                rows = jnp.arange(m)
                V = jnp.where(rows[:, None] > (j0 + jnp.arange(nb))[None, :],
                              panel, 0.0)
                V = jnp.where(rows[:, None] == (j0 + jnp.arange(nb))[None, :],
                              1.0, V)
                T = _larft(V, tau)
                C = a[:, j0 + nb:]
                W = gemm(V, C, transa=True, policy=pol, interpret=interpret,
                         registry=registry)           # (nb, rest)   GEMM
                W = T.T @ W                           # small (nb x nb) GEMM
                a = a.at[:, j0 + nb:].set(
                    C - gemm(V, W, policy=pol, interpret=interpret,
                             registry=registry))      # GEMM
    return a, jnp.concatenate(taus)


def q_from_geqrf(packed: jnp.ndarray, tau: jnp.ndarray) -> jnp.ndarray:
    """Accumulate the full (m, m) orthogonal Q from a packed
    :func:`geqrf` result (LAPACK DORGQR, applied in reverse reflector
    order). Oracle: ``tests/test_lapack.py`` (orthogonality +
    round-trip)."""
    m = packed.shape[0]
    kmax = tau.shape[0]
    rows = jnp.arange(m)

    def body(i, q):
        k = kmax - 1 - i                              # apply in reverse
        v = jnp.where(rows > k, packed[:, k], 0.0)
        v = v.at[k].set(1.0)
        v = jnp.where(rows >= k, v, 0.0)
        w = tau[k] * (v @ q)
        return q - jnp.outer(v, w)

    return lax.fori_loop(0, kmax, body, jnp.eye(m, dtype=packed.dtype))


def qr(a: jnp.ndarray, block: Optional[int] = None,
       policy: Optional[str] = None, use_kernel: Optional[bool] = None,
       interpret: bool = True,
       registry=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Convenience thin-QR: returns (Q (m, min(m,n)), R (min(m,n), n))
    from :func:`geqrf` + :func:`q_from_geqrf`; same
    block/policy/``use_kernel`` contract as :func:`geqrf`."""
    packed, tau = geqrf(a, block=block, policy=policy, use_kernel=use_kernel,
                        interpret=interpret, registry=registry)
    q = q_from_geqrf(packed, tau)
    r = jnp.triu(packed)[: min(a.shape), :]
    return q[:, : min(a.shape)], r
