"""GETRF - LU with partial pivoting, unblocked and blocked, in JAX.

Section-4.2 workload #2: the column-scaling divisions are the serial divider
stream ("the occurrence of division ... is similar to the square root/divider
in the QR factorization"); the trailing update is DGEMM.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp
from jax import lax

from repro import obs as _obs
from repro.lapack.cholesky import default_block


def getrf_unblocked(a: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Unblocked LU with partial pivoting of one matrix.

    Parameters
    ----------
    a : (n, m) matrix (float32/float64); square or rectangular.

    Returns
    -------
    (packed, piv)
        ``packed``: L (unit lower, below diagonal) and U (on/above) in
        one array; ``piv``: (min(n, m),) int32 - ``piv[k]`` is the row
        swapped into k (LAPACK ipiv, 0-based).

    Notes
    -----
    Oracle: ``tests/test_lapack.py`` (vs ``scipy.linalg.lu_factor``).
    """
    n = a.shape[0]
    rows = jnp.arange(n)

    def body(k, carry):
        A, piv = carry
        col = jnp.where(rows >= k, jnp.abs(A[:, k]), -jnp.inf)
        # pin to int32 (LAPACK ipiv width): under JAX_ENABLE_X64 argmax
        # yields int64, and scattering that into the int32 piv buffer is
        # a dtype-mismatch error in future JAX (analysis rule DF family)
        p = jnp.argmax(col).astype(jnp.int32)
        piv = piv.at[k].set(p)
        rk, rp = A[k], A[p]
        A = A.at[k].set(rp).at[p].set(rk)
        pivval = A[k, k]
        safe = jnp.where(jnp.abs(pivval) > 0, pivval, 1.0)
        l = jnp.where(rows > k, A[:, k] / safe, 0.0)
        A = A.at[:, k].set(jnp.where(rows > k, l, A[:, k]))
        urow = jnp.where(jnp.arange(A.shape[1]) > k, A[k], 0.0)
        A = A - jnp.outer(l, urow)
        return A, piv

    A, piv = lax.fori_loop(0, min(a.shape), body,
                           (a, jnp.zeros((min(a.shape),), jnp.int32)))
    return A, piv


def getrf(a: jnp.ndarray, block: Optional[int] = None,
          policy: Optional[str] = None, use_kernel: Optional[bool] = None,
          interpret: bool = True, registry=None,
          fuse: Optional[bool] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Blocked right-looking LU with partial pivoting (LAPACK DGETRF).

    Parameters
    ----------
    a : (m, n) matrix (float32/float64).
    block : panel width NB; ``None`` takes
        ``plan_factorization(kind="getrf")``'s model pick at a's dtype.
    registry : tuned-config registry forwarded to every trailing update
        (``None`` = the process default).
    policy : {"reference", "model", "tuned"}, optional
        Trailing updates (TRSM for U12, GEMM for A22) dispatch through
        :mod:`repro.blas.level3`, resolved by :mod:`repro.tune.dispatch`:
        ``"model"`` (deprecated ``use_kernel=True``) reaches the Pallas
        MXU kernel, ``"tuned"`` the registry config.
    fuse : stream each trailing TRSM->GEMM pair through the fused
        ``trsm+gemm`` kernel? ``None`` defers to
        :func:`repro.core.codesign.plan_fused_chain` under the kernel
        policies; ``False`` forces the staged path (bitwise the
        historical trailing update), ``True`` forces fusion whenever the
        policy reaches the kernel at all.

    Returns
    -------
    (packed, piv)
        Same packed L\\U + 0-based ipiv contract as
        :func:`getrf_unblocked`, piv length min(m, n).

    Notes
    -----
    Oracle: ``tests/test_lapack.py`` and
    ``tests/test_lapack_batched.py`` (reconstruction round-trip,
    non-square and ill-conditioned cases); per-policy agreement in
    ``tests/test_tune.py``; fused-vs-staged agreement in
    ``tests/test_fusion.py``.
    """
    from repro.tune import dispatch as _tune
    from repro.tune.policy import resolve_policy
    pol = resolve_policy(policy, use_kernel)
    n, nc = a.shape
    kmax = min(n, nc)
    if block is None:
        block = default_block(kmax, "getrf", a.dtype)
    if kmax <= block:
        return getrf_unblocked(a)
    pivs = []
    rows = jnp.arange(n)
    for j0 in range(0, kmax, block):
        nb = min(block, kmax - j0)
        # panel factorization over full remaining height, swaps applied to
        # the whole width (LAPACK's laswp).
        def pbody(kk, carry):
            A, piv = carry
            k = j0 + kk
            col = jnp.where(rows >= k, jnp.abs(A[:, k]), -jnp.inf)
            p = jnp.argmax(col).astype(jnp.int32)   # ipiv stays int32 (x64)
            piv = piv.at[kk].set(p)
            rk, rp = A[k], A[p]
            A = A.at[k].set(rp).at[p].set(rk)
            pivval = A[k, k]
            safe = jnp.where(jnp.abs(pivval) > 0, pivval, 1.0)
            l = jnp.where(rows > k, A[:, k] / safe, 0.0)
            A = A.at[:, k].set(jnp.where(rows > k, l, A[:, k]))
            # rank-1 update restricted to the panel's remaining columns
            urow = jnp.where((jnp.arange(nc) > k) & (jnp.arange(nc) < j0 + nb),
                             A[k], 0.0)
            A = A - jnp.outer(l, urow)
            return A, piv

        with _obs.span("getrf.panel", cat="panel", j0=j0, nb=nb,
                       flops=(n - j0) * nb * nb):
            a, piv = lax.fori_loop(0, nb, pbody,
                                   (a, jnp.zeros((nb,), jnp.int32)))
        pivs.append(piv)
        if j0 + nb < nc:
            mr, ncr = n - j0 - nb, nc - j0 - nb     # trailing block dims
            with _obs.span("getrf.trailing", cat="trailing", j0=j0, nb=nb,
                           flops=nb * nb * ncr + 2 * mr * ncr * nb):
                # U12 = L11^{-1} A12 ; A22 -= L21 U12: the trsm+gemm
                # chain streams U12 through VMEM when its plan says
                # fusing wins; otherwise the staged TRSM + GEMM pair runs
                # exactly as before
                l11 = a[j0:j0 + nb, j0:j0 + nb]
                u12, c_out = _tune.dispatch(
                    "trsm+gemm", l11, a[j0:j0 + nb, j0 + nb:],
                    a[j0 + nb:, j0:j0 + nb], a[j0 + nb:, j0 + nb:],
                    form="lu", unit_diag=True, fuse=fuse, policy=pol,
                    interpret=interpret, registry=registry)
                a = a.at[j0:j0 + nb, j0 + nb:].set(u12)
                a = a.at[j0 + nb:, j0 + nb:].set(c_out)
    return a, jnp.concatenate(pivs)


def apply_ipiv(b: jnp.ndarray, piv: jnp.ndarray) -> jnp.ndarray:
    """Apply the pivot sequence (forward) to rows of b: b <- P b.

    b : (n,) or (n, k); piv : int32 ipiv from :func:`getrf`. Returns b
    with its shape. Inverse operation inside :func:`lu_reconstruct`.
    """
    def body(k, x):
        p = piv[k]
        rk, rp = x[k], x[p]
        return x.at[k].set(rp).at[p].set(rk)
    return lax.fori_loop(0, piv.shape[0], body, b)


def lu_reconstruct(packed: jnp.ndarray, piv: jnp.ndarray) -> jnp.ndarray:
    """P^T L U from a packed :func:`getrf` result - the testing oracle:
    the return value should equal the original input matrix (square
    packed layout)."""
    n = packed.shape[0]
    l = jnp.tril(packed, -1) + jnp.eye(n, dtype=packed.dtype)
    u = jnp.triu(packed)
    lu = l @ u
    # invert the pivot sequence (apply swaps in reverse)
    def body(i, x):
        k = piv.shape[0] - 1 - i
        p = piv[k]
        rk, rp = x[k], x[p]
        return x.at[k].set(rp).at[p].set(rk)
    return lax.fori_loop(0, piv.shape[0], body, lu)
