"""Mesh-parallel batched LAPACK: shard the batch axis, reuse the blocked
drivers per shard.

The batched workload (many independent factorizations) has no cross-item
dependence at all, so the mesh mapping is pure data parallelism: the batch
axis is sharded over every mesh axis, and each device runs the *same*
vmapped blocked driver (:mod:`repro.lapack.batched`) on its slab - panel
hazard chains in lockstep locally, trailing updates on the policy-dispatched
Pallas GEMM path, zero collectives. This is the scaling layer between the
single-device batched drivers (PR 1) and the SUMMA kernels of
:mod:`repro.blas.distributed`: factor on the mesh, solve on the mesh, and
the per-shard kernel configs still resolve through ``repro.tune``.

Batches that do not divide the device count are padded with identity
matrices (SPD, invertible - safe for every factorization kind) and the pad
is sliced off the result, so any batch size runs on any mesh.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.distributed.collectives import CollectiveRecord, emit_record
from repro.lapack import batched as _batched
from repro.lapack.batched import FactorizationResult, _resolve_block
from repro.tune.policy import resolve_policy


def _mesh_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


def _ndev(mesh: Mesh) -> int:
    n = 1
    for a in mesh.axis_names:
        n *= mesh.shape[a]
    return n


def _pad_batch(a: jnp.ndarray, ndev: int) -> Tuple[jnp.ndarray, int]:
    """Pad the (B, m, n) batch to a device-count multiple with identities."""
    b = a.shape[0]
    pad = (-b) % ndev
    # declare the pad for spmd_lint's SH002 discipline check: identity
    # filler (factorizable), minimal, and device-count divisible
    emit_record(CollectiveRecord(
        kind="pad_batch", size=ndev,
        info={"batch": b, "pad": pad, "identity": True}))
    if pad == 0:
        return a, b
    eye = jnp.broadcast_to(jnp.eye(a.shape[1], a.shape[2], dtype=a.dtype),
                           (pad, a.shape[1], a.shape[2]))
    return jnp.concatenate([a, eye], axis=0), b


def _shard_batched(mesh: Mesh, fn, a: jnp.ndarray, n_out: int):
    """Run ``fn`` (local batch -> tuple of per-item arrays) on the
    batch-sharded ``a``; returns the tuple with the pad still attached."""
    axes = _mesh_axes(mesh)
    spec = P(axes)                              # batch axis only; rest open
    return shard_map(fn, mesh=mesh, in_specs=(spec,),
                     out_specs=tuple(spec for _ in range(n_out)),
                     check_rep=False)(a)


def batched_potrf(a: jnp.ndarray, mesh: Mesh, block: Optional[int] = None,
                  policy: Optional[str] = None,
                  use_kernel: Optional[bool] = None,
                  interpret: bool = True,
                  registry=None) -> FactorizationResult:
    """Cholesky of a (B, n, n) SPD batch, batch-sharded over ``mesh``.

    Parameters
    ----------
    a : (B, n, n) SPD batch (float32/float64).
    mesh : any jax Mesh; the batch is sharded over all its axes flattened.
    block, policy : forwarded to the per-shard
        :func:`repro.lapack.batched.batched_potrf` - the trailing updates
        of every local factorization resolve their kernel configs through
        ``repro.tune`` exactly as on one device.

    Returns
    -------
    FactorizationResult
        Same pytree as the single-device driver (kind "potrf"); factors
        hold L per batch item.

    Notes
    -----
    Oracle: ``tests/test_distributed_blas.py`` - bitwise-comparable to
    single-device ``batched_potrf`` under ``dtype_tolerances`` on every
    mesh in {(1,1), (2,2), (4,2)}.
    """
    assert a.ndim == 3 and a.shape[1] == a.shape[2], a.shape
    pol = resolve_policy(policy, use_kernel)
    nb = _resolve_block(a.shape[1], block, "potrf", a.dtype)
    a_p, b0 = _pad_batch(a, _ndev(mesh))

    def local(x):
        return (_batched.batched_potrf(x, block=nb, policy=pol,
                                       interpret=interpret,
                                       registry=registry).factors,)

    (factors,) = _shard_batched(mesh, local, a_p, 1)
    return FactorizationResult(factors[:b0], None, None, "potrf", nb)


def batched_getrf(a: jnp.ndarray, mesh: Mesh, block: Optional[int] = None,
                  policy: Optional[str] = None,
                  use_kernel: Optional[bool] = None,
                  interpret: bool = True,
                  registry=None) -> FactorizationResult:
    """LU with partial pivoting of a (B, m, n) batch, batch-sharded.

    Shape/dtype/policy contract matches
    :func:`repro.lapack.batched.batched_getrf`; pivots come back (B, k)
    int32 in LAPACK ipiv convention. Oracle:
    ``tests/test_distributed_blas.py``.
    """
    assert a.ndim == 3, a.shape
    pol = resolve_policy(policy, use_kernel)
    nb = _resolve_block(min(a.shape[1], a.shape[2]), block, "getrf", a.dtype)
    a_p, b0 = _pad_batch(a, _ndev(mesh))

    def local(x):
        r = _batched.batched_getrf(x, block=nb, policy=pol,
                                   interpret=interpret, registry=registry)
        return r.factors, r.pivots

    factors, piv = _shard_batched(mesh, local, a_p, 2)
    return FactorizationResult(factors[:b0], piv[:b0], None, "getrf", nb)


def batched_geqrf(a: jnp.ndarray, mesh: Mesh, block: Optional[int] = None,
                  policy: Optional[str] = None,
                  use_kernel: Optional[bool] = None,
                  interpret: bool = True,
                  registry=None) -> FactorizationResult:
    """Householder QR of a (B, m, n) batch, batch-sharded.

    Contract matches :func:`repro.lapack.batched.batched_geqrf` (packed
    R/V factors + tau). Oracle: ``tests/test_distributed_blas.py``.
    """
    assert a.ndim == 3, a.shape
    pol = resolve_policy(policy, use_kernel)
    nb = _resolve_block(min(a.shape[1], a.shape[2]), block, "geqrf", a.dtype)
    a_p, b0 = _pad_batch(a, _ndev(mesh))

    def local(x):
        r = _batched.batched_geqrf(x, block=nb, policy=pol,
                                   interpret=interpret, registry=registry)
        return r.factors, r.tau

    factors, tau = _shard_batched(mesh, local, a_p, 2)
    return FactorizationResult(factors[:b0], None, tau[:b0], "geqrf", nb)


def batched_solve(res: FactorizationResult, b: jnp.ndarray, mesh: Mesh,
                  policy: Optional[str] = None,
                  use_kernel: Optional[bool] = None,
                  interpret: bool = True, registry=None) -> jnp.ndarray:
    """Solve A_i x_i = b_i for a batch-sharded FactorizationResult.

    ``res`` is a result of any driver in this module (or the single-device
    ones - the pytrees are identical); ``b`` is (B, n) or (B, n, k). The
    factors, pivot/tau metadata, and RHS are sharded on the batch axis and
    every device runs :func:`repro.lapack.batched.batched_solve` on its
    slab, so the triangular solves thread the same policy as the
    factorization did. Identity-padded batch items solve against a zero
    RHS and are sliced off.

    Oracle: ``tests/test_distributed_blas.py`` (factor + solve round-trip
    vs the single-device path under ``dtype_tolerances``).
    """
    pol = resolve_policy(policy, use_kernel)
    ndev = _ndev(mesh)
    axes = _mesh_axes(mesh)
    b0 = res.factors.shape[0]
    pad = (-b0) % ndev
    vec = b.ndim == 2
    rhs = b[:, :, None] if vec else b
    emit_record(CollectiveRecord(
        kind="pad_batch", size=ndev,
        info={"batch": b0, "pad": pad, "identity": True}))
    if pad:
        m_f, n_f = res.factors.shape[1], res.factors.shape[2]
        eye = jnp.broadcast_to(
            jnp.eye(m_f, n_f, dtype=res.factors.dtype), (pad, m_f, n_f))
        factors = jnp.concatenate([res.factors, eye], axis=0)
        rhs = jnp.concatenate(
            [rhs, jnp.zeros((pad,) + rhs.shape[1:], rhs.dtype)], axis=0)
    else:
        factors = res.factors

    def _pad_meta(x, fill):
        if x is None or pad == 0:
            return x
        return jnp.concatenate(
            [x, jnp.broadcast_to(fill, (pad,) + x.shape[1:])], axis=0)

    piv = _pad_meta(res.pivots,
                    jnp.arange(res.pivots.shape[1], dtype=res.pivots.dtype)
                    if res.pivots is not None else None)
    tau = _pad_meta(res.tau, jnp.zeros((), res.factors.dtype)
                    if res.tau is not None else None)

    spec = P(axes)
    operands = [factors, rhs]
    in_specs = [spec, spec]
    if piv is not None:
        operands.append(piv)
        in_specs.append(spec)
    if tau is not None:
        operands.append(tau)
        in_specs.append(spec)

    def local(f, r, *meta):
        lp = meta[0] if piv is not None else None
        lt = meta[0] if (tau is not None and piv is None) else None
        lres = FactorizationResult(f, lp, lt, res.kind, res.block)
        return _batched.batched_solve(lres, r, policy=pol,
                                      interpret=interpret, registry=registry)

    x = shard_map(local, mesh=mesh, in_specs=tuple(in_specs),
                  out_specs=spec, check_rep=False)(*operands)
    x = x[:b0]
    return x[:, :, 0] if vec else x
