"""DPOTRF - Cholesky factorization (lower), unblocked and blocked."""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.blas.level3 import dtrsm


def potrf_unblocked(a: jnp.ndarray) -> jnp.ndarray:
    """Lower-triangular Cholesky; the serial sqrt-then-div chain per column
    is the paper's dpotrf hazard profile."""
    n = a.shape[0]
    rows = jnp.arange(n)

    def body(k, A):
        d = jnp.sqrt(A[k, k])
        col = jnp.where(rows > k, A[:, k] / d, 0.0)
        A = A.at[k, k].set(d)
        A = A.at[:, k].set(jnp.where(rows > k, col, A[:, k]))
        # trailing rank-1 update on the lower triangle
        upd = jnp.outer(col, col)
        mask = (rows[:, None] > k) & (rows[None, :] > k)
        return A - jnp.where(mask, upd, 0.0)

    A = lax.fori_loop(0, n, body, a)
    return jnp.tril(A)


def potrf(a: jnp.ndarray, block: int = 32) -> jnp.ndarray:
    """Blocked: POTRF(diag) + TRSM(panel) + SYRK(trailing)."""
    n = a.shape[0]
    if n <= block:
        return potrf_unblocked(a)
    for j0 in range(0, n, block):
        nb = min(block, n - j0)
        a = a.at[j0:j0 + nb, j0:j0 + nb].set(
            potrf_unblocked(a[j0:j0 + nb, j0:j0 + nb]))
        if j0 + nb < n:
            l11 = a[j0:j0 + nb, j0:j0 + nb]
            # L21 = A21 L11^{-T}
            l21 = dtrsm(l11, a[j0 + nb:, j0:j0 + nb].T, lower=True,
                        unit_diag=False, left=True).T
            a = a.at[j0 + nb:, j0:j0 + nb].set(l21)
            a = a.at[j0 + nb:, j0 + nb:].add(-(l21 @ l21.T))
    return jnp.tril(a)
