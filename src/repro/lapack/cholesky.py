"""POTRF - Cholesky factorization (lower), unblocked and blocked.

Blocked right-looking form: POTRF(diag) + TRSM(panel) + SYRK(trailing).
Every trailing flop dispatches through :mod:`repro.blas.level3`, whose
kernel configs resolve via :mod:`repro.tune.dispatch`: ``policy="model"``
(the deprecated ``use_kernel=True``) lowers the SYRK/GEMM hot path onto
the Pallas MXU kernel (interpret mode on CPU); ``"tuned"`` uses the
registry's measured config. The default panel width comes from
:func:`repro.core.codesign.plan_factorization` - the same roofline +
pipeline-depth model that tiles the GEMM itself. Public front-end:
:func:`repro.linalg.cholesky`.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
from jax import lax

from repro import obs as _obs
from repro.blas.level3 import gemm, trsm


def default_block(n: int, kind: str, dtype=None) -> int:
    """Model-picked panel width NB for a size-n factorization.

    ``dtype`` (optional) makes the plan dtype-aware: the roofline terms
    price operand bytes at that dtype's width (float32 when omitted).
    """
    from repro.core.codesign import plan_factorization
    return plan_factorization(n, kind=kind, dtype=dtype).block


def potrf_unblocked(a: jnp.ndarray) -> jnp.ndarray:
    """Lower-triangular Cholesky of one SPD matrix, unblocked.

    Parameters
    ----------
    a : (n, n) SPD matrix (float32/float64). Non-SPD input produces NaNs,
        LAPACK-style - no error is raised.

    Returns
    -------
    (n, n) lower-triangular L with A = L L^T.

    Notes
    -----
    The serial sqrt-then-div chain per column is the paper's dpotrf
    hazard profile. Oracle: ``tests/test_lapack.py`` (vs
    ``np.linalg.cholesky``).
    """
    n = a.shape[0]
    rows = jnp.arange(n)

    def body(k, A):
        d = jnp.sqrt(A[k, k])
        col = jnp.where(rows > k, A[:, k] / d, 0.0)
        A = A.at[k, k].set(d)
        A = A.at[:, k].set(jnp.where(rows > k, col, A[:, k]))
        # trailing rank-1 update on the lower triangle
        upd = jnp.outer(col, col)
        mask = (rows[:, None] > k) & (rows[None, :] > k)
        return A - jnp.where(mask, upd, 0.0)

    A = lax.fori_loop(0, n, body, a)
    return jnp.tril(A)


def potrf(a: jnp.ndarray, block: Optional[int] = None,
          policy: Optional[str] = None, use_kernel: Optional[bool] = None,
          interpret: bool = True, registry=None) -> jnp.ndarray:
    """Blocked right-looking POTRF: panel = hazards, trailing = GEMM.

    Parameters
    ----------
    a : (n, n) SPD matrix (float32/float64; NaNs on non-SPD input,
        LAPACK-style).
    block : panel width NB; ``None`` takes
        :func:`repro.core.codesign.plan_factorization`'s model pick at
        a's dtype.
    policy : {"reference", "model", "tuned"}, optional
        Every trailing update (panel TRSM + trailing GEMM) dispatches
        through :mod:`repro.blas.level3`, so the kernel policies put all
        trailing flops on the Pallas MXU path; ``use_kernel`` is the
        deprecated alias (True == "model").
    registry : tuned-config registry forwarded to every trailing update
        (``None`` = the process default).

    Returns
    -------
    (n, n) lower-triangular L with A = L L^T.

    Notes
    -----
    Oracle: ``tests/test_lapack.py`` (round-trip vs
    ``np.linalg.cholesky``); kernel-path agreement in
    ``tests/test_lapack_batched.py`` and ``tests/test_tune.py``.
    """
    from repro.tune.policy import resolve_policy
    pol = resolve_policy(policy, use_kernel)
    n = a.shape[0]
    if block is None:
        block = default_block(n, "potrf", a.dtype)
    if n <= block:
        return potrf_unblocked(a)
    for j0 in range(0, n, block):
        nb = min(block, n - j0)
        with _obs.span("potrf.panel", cat="panel", j0=j0, nb=nb,
                       flops=nb ** 3 // 3):
            a = a.at[j0:j0 + nb, j0:j0 + nb].set(
                potrf_unblocked(a[j0:j0 + nb, j0:j0 + nb]))
        if j0 + nb < n:
            r = n - j0 - nb                 # trailing-block side length
            with _obs.span("potrf.trailing", cat="trailing", j0=j0, nb=nb,
                           flops=nb * nb * r + 2 * r * r * nb):
                l11 = a[j0:j0 + nb, j0:j0 + nb]
                # L21 = A21 L11^{-T}
                l21 = trsm(l11, a[j0 + nb:, j0:j0 + nb].T, lower=True,
                           unit_diag=False, left=True, policy=pol,
                           interpret=interpret, registry=registry).T
                a = a.at[j0 + nb:, j0:j0 + nb].set(l21)
                # trailing SYRK: A22 -= L21 L21^T (the GEMM hot path)
                a = a.at[j0 + nb:, j0 + nb:].add(
                    -gemm(l21, l21, transb=True, policy=pol,
                          interpret=interpret, registry=registry))
    return jnp.tril(a)
