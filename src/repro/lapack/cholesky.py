"""POTRF - Cholesky factorization (lower), unblocked and blocked.

Blocked right-looking form: POTRF(diag) + TRSM(panel) + SYRK(trailing).
Every trailing flop dispatches through :mod:`repro.blas.level3`, whose
kernel configs resolve via :mod:`repro.tune.dispatch`: ``policy="model"``
(the deprecated ``use_kernel=True``) lowers the SYRK/GEMM hot path onto
the Pallas MXU kernel (interpret mode on CPU); ``"tuned"`` uses the
registry's measured config. The default panel width comes from
:func:`repro.core.codesign.plan_factorization` - the same roofline +
pipeline-depth model that tiles the GEMM itself. Public front-end:
:func:`repro.linalg.cholesky`.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
from jax import lax

from repro import obs as _obs


def default_block(n: int, kind: str, dtype=None) -> int:
    """Model-picked panel width NB for a size-n factorization.

    ``dtype`` (optional) makes the plan dtype-aware: the roofline terms
    price operand bytes at that dtype's width (float32 when omitted).
    """
    from repro.core.codesign import plan_factorization
    return plan_factorization(n, kind=kind, dtype=dtype).block


def potrf_unblocked(a: jnp.ndarray) -> jnp.ndarray:
    """Lower-triangular Cholesky of one SPD matrix, unblocked.

    Parameters
    ----------
    a : (n, n) SPD matrix (float32/float64). Non-SPD input produces NaNs,
        LAPACK-style - no error is raised.

    Returns
    -------
    (n, n) lower-triangular L with A = L L^T.

    Notes
    -----
    The serial sqrt-then-div chain per column is the paper's dpotrf
    hazard profile. Oracle: ``tests/test_lapack.py`` (vs
    ``np.linalg.cholesky``).
    """
    n = a.shape[0]
    rows = jnp.arange(n)

    def body(k, A):
        d = jnp.sqrt(A[k, k])
        col = jnp.where(rows > k, A[:, k] / d, 0.0)
        A = A.at[k, k].set(d)
        A = A.at[:, k].set(jnp.where(rows > k, col, A[:, k]))
        # trailing rank-1 update on the lower triangle
        upd = jnp.outer(col, col)
        mask = (rows[:, None] > k) & (rows[None, :] > k)
        return A - jnp.where(mask, upd, 0.0)

    A = lax.fori_loop(0, n, body, a)
    return jnp.tril(A)


def potrf(a: jnp.ndarray, block: Optional[int] = None,
          policy: Optional[str] = None, use_kernel: Optional[bool] = None,
          interpret: bool = True, registry=None,
          fuse: Optional[bool] = None) -> jnp.ndarray:
    """Blocked right-looking POTRF: panel = hazards, trailing = GEMM.

    Parameters
    ----------
    a : (n, n) SPD matrix (float32/float64; NaNs on non-SPD input,
        LAPACK-style).
    block : panel width NB; ``None`` takes
        :func:`repro.core.codesign.plan_factorization`'s model pick at
        a's dtype.
    policy : {"reference", "model", "tuned"}, optional
        Every trailing update (panel TRSM + trailing GEMM) dispatches
        through :mod:`repro.blas.level3`, so the kernel policies put all
        trailing flops on the Pallas MXU path; ``use_kernel`` is the
        deprecated alias (True == "model").
    registry : tuned-config registry forwarded to every trailing update
        (``None`` = the process default).
    fuse : stream each trailing TRSM->SYRK pair through the fused
        ``trsm+gemm`` kernel (:mod:`repro.kernels.fused`)? ``None``
        (default) defers to :func:`repro.core.codesign.plan_fused_chain`
        under the kernel policies; ``False`` forces the staged path
        (bitwise the historical trailing update), ``True`` forces fusion
        whenever the policy reaches the kernel at all.

    Returns
    -------
    (n, n) lower-triangular L with A = L L^T.

    Notes
    -----
    Oracle: ``tests/test_lapack.py`` (round-trip vs
    ``np.linalg.cholesky``); kernel-path agreement in
    ``tests/test_lapack_batched.py`` and ``tests/test_tune.py``;
    fused-vs-staged agreement in ``tests/test_fusion.py``.
    """
    from repro.tune import dispatch as _tune
    from repro.tune.policy import resolve_policy
    pol = resolve_policy(policy, use_kernel)
    n = a.shape[0]
    if block is None:
        block = default_block(n, "potrf", a.dtype)
    if n <= block:
        return potrf_unblocked(a)
    for j0 in range(0, n, block):
        nb = min(block, n - j0)
        with _obs.span("potrf.panel", cat="panel", j0=j0, nb=nb,
                       flops=nb ** 3 // 3):
            a = a.at[j0:j0 + nb, j0:j0 + nb].set(
                potrf_unblocked(a[j0:j0 + nb, j0:j0 + nb]))
        if j0 + nb < n:
            r = n - j0 - nb                 # trailing-block side length
            with _obs.span("potrf.trailing", cat="trailing", j0=j0, nb=nb,
                           flops=nb * nb * r + 2 * r * r * nb):
                l11 = a[j0:j0 + nb, j0:j0 + nb]
                # X = L11^{-1} A21^T then A22 -= X^T X (L21 = X^T): the
                # trsm+gemm chain keeps X resident in VMEM when its plan
                # says streaming wins; otherwise it runs the staged
                # TRSM + SYRK-shaped GEMM exactly as before
                x, c_out = _tune.dispatch(
                    "trsm+gemm", l11, a[j0 + nb:, j0:j0 + nb].T, None,
                    a[j0 + nb:, j0 + nb:], form="syrk", unit_diag=False,
                    fuse=fuse, policy=pol, interpret=interpret,
                    registry=registry)
                a = a.at[j0 + nb:, j0:j0 + nb].set(x.T)
                a = a.at[j0 + nb:, j0 + nb:].set(c_out)
    return jnp.tril(a)
