"""DGESV-style dense solvers built on the factorizations."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.blas.level3 import dtrsm
from repro.lapack.lu import apply_ipiv, getrf
from repro.lapack.qr import geqrf, q_from_geqrf


def gesv(a: jnp.ndarray, b: jnp.ndarray, block: Optional[int] = None,
         use_kernel: bool = False, interpret: bool = True) -> jnp.ndarray:
    """Solve A X = B via LU with partial pivoting + two triangular solves."""
    packed, piv = getrf(a, block=block, use_kernel=use_kernel,
                        interpret=interpret)
    rhs = b if b.ndim == 2 else b[:, None]
    rhs = apply_ipiv(rhs, piv)
    y = dtrsm(packed, rhs, lower=True, unit_diag=True, left=True,
              use_kernel=use_kernel, interpret=interpret)
    x = dtrsm(packed, y, lower=False, unit_diag=False, left=True,
              use_kernel=use_kernel, interpret=interpret)
    return x if b.ndim == 2 else x[:, 0]


def lstsq_qr(a: jnp.ndarray, b: jnp.ndarray, block: Optional[int] = None,
             use_kernel: bool = False, interpret: bool = True) -> jnp.ndarray:
    """Least-squares via QR: x = R^{-1} Q^T b (m >= n, full rank)."""
    m, n = a.shape
    packed, tau = geqrf(a, block=block, use_kernel=use_kernel,
                        interpret=interpret)
    q = q_from_geqrf(packed, tau)
    rhs = b if b.ndim == 2 else b[:, None]
    qtb = q.T @ rhs
    r = jnp.triu(packed)[:n, :n]
    x = dtrsm(r, qtb[:n], lower=False, unit_diag=False, left=True,
              use_kernel=use_kernel, interpret=interpret)
    return x if b.ndim == 2 else x[:, 0]
