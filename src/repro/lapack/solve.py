"""GESV-style dense solvers built on the factorizations.

Both drivers thread the tuner policy (``reference`` | ``model`` |
``tuned``; ``use_kernel`` deprecated alias) through every factorization
and triangular solve, so the whole solve resolves its kernel configs via
:mod:`repro.tune.dispatch`.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.blas.level3 import trsm
from repro.lapack.lu import apply_ipiv, getrf
from repro.lapack.qr import geqrf, q_from_geqrf


def gesv(a: jnp.ndarray, b: jnp.ndarray, block: Optional[int] = None,
         policy: Optional[str] = None, use_kernel: Optional[bool] = None,
         interpret: bool = True, registry=None) -> jnp.ndarray:
    """Solve A X = B via LU with partial pivoting (LAPACK DGESV).

    Parameters
    ----------
    a : (n, n) matrix (float32/float64); b : (n,) or (n, k) RHS.
    block : forwarded to :func:`repro.lapack.lu.getrf`.
    policy : {"reference", "model", "tuned"}, optional
        Threaded through the factorization and both triangular solves,
        so the whole solve resolves its kernel configs through
        :mod:`repro.tune.dispatch`; ``use_kernel`` deprecated alias.

    Returns
    -------
    X with b's shape.

    Notes
    -----
    Oracle: ``tests/test_lapack.py`` (vs ``np.linalg.solve``).
    """
    from repro.tune.policy import resolve_policy
    pol = resolve_policy(policy, use_kernel)
    packed, piv = getrf(a, block=block, policy=pol, interpret=interpret,
                        registry=registry)
    rhs = b if b.ndim == 2 else b[:, None]
    rhs = apply_ipiv(rhs, piv)
    y = trsm(packed, rhs, lower=True, unit_diag=True, left=True,
             policy=pol, interpret=interpret, registry=registry)
    x = trsm(packed, y, lower=False, unit_diag=False, left=True,
             policy=pol, interpret=interpret, registry=registry)
    return x if b.ndim == 2 else x[:, 0]


def lstsq_qr(a: jnp.ndarray, b: jnp.ndarray, block: Optional[int] = None,
             policy: Optional[str] = None, use_kernel: Optional[bool] = None,
             interpret: bool = True, registry=None) -> jnp.ndarray:
    """Least-squares min ||A x - b|| via QR: x = R^{-1} Q^T b.

    Parameters
    ----------
    a : (m, n) matrix with m >= n, full column rank (float32/float64);
        b : (m,) or (m, k) RHS.
    block, policy : forwarded to :func:`repro.lapack.qr.geqrf` and the
        final TRSM - same policy semantics as :func:`gesv`.

    Returns
    -------
    x, shape (n,) or (n, k).

    Notes
    -----
    Oracle: ``tests/test_lapack.py`` (vs ``np.linalg.lstsq`` on
    overdetermined systems).
    """
    from repro.tune.policy import resolve_policy
    pol = resolve_policy(policy, use_kernel)
    m, n = a.shape
    packed, tau = geqrf(a, block=block, policy=pol, interpret=interpret,
                        registry=registry)
    q = q_from_geqrf(packed, tau)
    rhs = b if b.ndim == 2 else b[:, None]
    qtb = q.T @ rhs
    r = jnp.triu(packed)[:n, :n]
    x = trsm(r, qtb[:n], lower=False, unit_diag=False, left=True,
             policy=pol, interpret=interpret, registry=registry)
    return x if b.ndim == 2 else x[:, 0]
