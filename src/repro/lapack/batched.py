"""Batched blocked LAPACK drivers (vmap over the leading axis).

The ROADMAP's batched-workload scenario: many independent small/medium
factorizations (mixture-of-experts solves, per-head whitening, ensemble
Kalman updates) executed as ONE blocked computation. ``vmap`` lifts the
blocked right-looking routines of :mod:`repro.lapack` - whose trailing
updates all dispatch through :func:`repro.blas.level3.gemm` - so a batch
of trailing updates lowers onto batched GEMM on the Pallas hot path, and
the panel hazard chains of the whole batch run in lockstep instead of
serially.

All entry points share one result type, :class:`FactorizationResult`, a
registered pytree (jit/vmap/scan-transparent) tagged with the static
factorization kind, so downstream code (``batched_solve``,
``reconstruct``) dispatches without re-inspecting shapes.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.blas.level3 import trsm as _trsm
from repro.lapack import cholesky, lu, qr
from repro.lapack.cholesky import default_block
from repro.tune.policy import resolve_policy


@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=["factors", "pivots", "tau"],
                   meta_fields=["kind", "block"])
@dataclasses.dataclass(frozen=True)
class FactorizationResult:
    """One batched factorization in LAPACK packed layout.

    factors: (B, m, n) packed factor(s) - L (potrf), L\\U (getrf), or the
             Householder-packed R/V (geqrf).
    pivots:  (B, k) int32 ipiv (getrf only, else None).
    tau:     (B, k) reflector scales (geqrf only, else None).
    kind:    static tag: "potrf" | "getrf" | "geqrf".
    block:   panel width the factorization actually used.
    """

    factors: jnp.ndarray
    pivots: Optional[jnp.ndarray]
    tau: Optional[jnp.ndarray]
    kind: str
    block: int

    @property
    def batch(self) -> int:
        return self.factors.shape[0]


def _resolve_block(kmax: int, block: Optional[int], kind: str,
                   dtype=None) -> int:
    return default_block(kmax, kind, dtype) if block is None else int(block)


def batched_potrf(a: jnp.ndarray, block: Optional[int] = None,
                  policy: Optional[str] = None,
                  use_kernel: Optional[bool] = None,
                  interpret: bool = True,
                  registry=None) -> FactorizationResult:
    """Cholesky of a (B, n, n) SPD batch; factors holds L (lower).

    float32/float64 (NaNs per non-SPD item, LAPACK-style). ``policy``
    threads to every trailing update via :mod:`repro.tune.dispatch`
    (``use_kernel`` deprecated alias); ``block=None`` takes the
    ``plan_factorization`` model pick. Oracle:
    ``tests/test_lapack_batched.py`` (round-trip + kernel-path-identical);
    mesh-parallel form: :func:`repro.lapack.distributed.batched_potrf`.
    """
    assert a.ndim == 3 and a.shape[1] == a.shape[2], a.shape
    pol = resolve_policy(policy, use_kernel)
    nb = _resolve_block(a.shape[1], block, "potrf", a.dtype)
    f = jax.vmap(lambda x: cholesky.potrf(x, block=nb, policy=pol,
                                          interpret=interpret,
                                          registry=registry))
    return FactorizationResult(f(a), None, None, "potrf", nb)


def batched_getrf(a: jnp.ndarray, block: Optional[int] = None,
                  policy: Optional[str] = None,
                  use_kernel: Optional[bool] = None,
                  interpret: bool = True,
                  registry=None) -> FactorizationResult:
    """LU with partial pivoting of a (B, m, n) batch.

    Returns packed L\\U factors + (B, min(m, n)) int32 ipiv. Same
    policy/block contract as :func:`batched_potrf`. Oracle:
    ``tests/test_lapack_batched.py`` (incl. non-square and
    ill-conditioned); mesh-parallel form:
    :func:`repro.lapack.distributed.batched_getrf`.
    """
    assert a.ndim == 3, a.shape
    pol = resolve_policy(policy, use_kernel)
    nb = _resolve_block(min(a.shape[1], a.shape[2]), block, "getrf", a.dtype)
    f = jax.vmap(lambda x: lu.getrf(x, block=nb, policy=pol,
                                    interpret=interpret, registry=registry))
    packed, piv = f(a)
    return FactorizationResult(packed, piv, None, "getrf", nb)


def batched_geqrf(a: jnp.ndarray, block: Optional[int] = None,
                  policy: Optional[str] = None,
                  use_kernel: Optional[bool] = None,
                  interpret: bool = True,
                  registry=None) -> FactorizationResult:
    """Householder QR of a (B, m, n) batch (packed R/V + tau per item).

    Same policy/block contract as :func:`batched_potrf`. Oracle:
    ``tests/test_lapack_batched.py``; mesh-parallel form:
    :func:`repro.lapack.distributed.batched_geqrf`.
    """
    assert a.ndim == 3, a.shape
    pol = resolve_policy(policy, use_kernel)
    nb = _resolve_block(min(a.shape[1], a.shape[2]), block, "geqrf", a.dtype)
    f = jax.vmap(lambda x: qr.geqrf(x, block=nb, policy=pol,
                                    interpret=interpret, registry=registry))
    packed, tau = f(a)
    return FactorizationResult(packed, None, tau, "geqrf", nb)


def batched_solve(res: FactorizationResult, b: jnp.ndarray,
                  policy: Optional[str] = None,
                  use_kernel: Optional[bool] = None,
                  interpret: bool = True, registry=None) -> jnp.ndarray:
    """Solve A_i x_i = b_i for every batch item from a FactorizationResult.

    b: (B, n) or (B, n, k). potrf solves the SPD system L L^T x = b; getrf
    the pivoted L U x = P b; geqrf the least-squares system via
    R^{-1} Q^T b (m >= n). ``policy`` threads to every triangular solve
    (``use_kernel`` deprecated alias). Oracle:
    ``tests/test_lapack_batched.py`` (solve residuals per kind);
    mesh-parallel form: :func:`repro.lapack.distributed.batched_solve`.
    """
    vec = b.ndim == 2
    rhs = b[:, :, None] if vec else b
    pol = resolve_policy(policy, use_kernel)

    def trsm(t, r, **kw):
        return _trsm(t, r, left=True, policy=pol, interpret=interpret,
                     registry=registry, **kw)

    if res.kind == "potrf":
        def solve1(l, r):
            y = trsm(l, r, lower=True, unit_diag=False)
            return trsm(l.T, y, lower=False, unit_diag=False)
        x = jax.vmap(solve1)(res.factors, rhs)
    elif res.kind == "getrf":
        m, n = res.factors.shape[1:]
        if m != n:
            raise ValueError(
                f"batched_solve(getrf) needs square factors; got "
                f"{res.factors.shape} (use geqrf for least squares)")

        def solve1(packed, piv, r):
            r = lu.apply_ipiv(r, piv)
            y = trsm(packed, r, lower=True, unit_diag=True)
            return trsm(packed, y, lower=False, unit_diag=False)
        x = jax.vmap(solve1)(res.factors, res.pivots, rhs)
    elif res.kind == "geqrf":
        m, n = res.factors.shape[1:]
        if m < n:
            raise ValueError(
                f"batched_solve(geqrf) is a least-squares solve and needs "
                f"m >= n; got factors of shape {res.factors.shape}")

        def solve1(packed, tau, r):
            q = qr.q_from_geqrf(packed, tau)
            qtb = q.T @ r
            rr = jnp.triu(packed)[:n, :n]
            return trsm(rr, qtb[:n], lower=False, unit_diag=False)
        x = jax.vmap(solve1)(res.factors, res.tau, rhs)
    else:
        raise ValueError(f"unknown factorization kind: {res.kind!r}")
    return x[:, :, 0] if vec else x


def reconstruct(res: FactorizationResult) -> jnp.ndarray:
    """Rebuild the (B, m, n) input batch from its factors (testing oracle)."""
    if res.kind == "potrf":
        return jax.vmap(lambda l: l @ l.T)(res.factors)
    if res.kind == "getrf":
        return jax.vmap(lu.lu_reconstruct)(res.factors, res.pivots)
    if res.kind == "geqrf":
        def rec1(packed, tau):
            q = qr.q_from_geqrf(packed, tau)
            return q @ jnp.triu(packed)
        return jax.vmap(rec1)(res.factors, res.tau)
    raise ValueError(f"unknown factorization kind: {res.kind!r}")
