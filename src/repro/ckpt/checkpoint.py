"""Atomic, sharded, keep-N checkpointing with resume and elastic restore.

Layout:  <dir>/step_<N>/ {manifest.json, arrays.npz}  written to a tmp dir
and renamed into place (rename is atomic on POSIX), so a crash mid-save can
never corrupt the latest checkpoint - the fault-tolerance substrate the
multi-pod runtime builds on. Each process writes only its addressable shards
(single-process here: the full arrays); restore re-places leaves onto any
mesh via an optional sharding tree (elastic re-scaling).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "/"


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save(directory: str, step: int, tree, keep: Optional[int] = None) -> str:
    """Atomically write ``tree`` as step ``step``. Returns the final path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:010d}")
    flat = {k: np.asarray(jax.device_get(v)) for k, v in _flatten(tree).items()}
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_save_")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        manifest = {
            "step": step,
            "keys": sorted(flat.keys()),
            "dtypes": {k: str(v.dtype) for k, v in flat.items()},
            "shapes": {k: list(v.shape) for k, v in flat.items()},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                      # atomic commit
    except Exception:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    if keep is not None:
        for old in all_steps(directory)[:-keep]:
            shutil.rmtree(os.path.join(directory, f"step_{old:010d}"),
                          ignore_errors=True)
    return final


def all_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_"):
            try:
                steps.append(int(name.split("_")[1]))
            except (IndexError, ValueError):
                continue
    return sorted(steps)


def latest_step(directory: str) -> Optional[int]:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, like, step: Optional[int] = None,
            shardings=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings``: optional matching pytree of
    jax.sharding.Sharding for elastic re-placement on a (possibly different)
    mesh."""
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    keys = [_SEP.join(_path_str(p) for p in path_) for path_, _ in flat_like]
    missing = [k for k in keys if k not in manifest["keys"]]
    if missing:
        raise KeyError(f"checkpoint at step {step} missing keys: {missing[:5]}")
    leaves = [data[k] for k in keys]
    if shardings is not None:
        flat_sh = jax.tree_util.tree_flatten(shardings)[0]
        leaves = [jax.device_put(l, s) for l, s in zip(leaves, flat_sh)]
    else:
        leaves = [jnp.asarray(l) for l in leaves]
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves)
    return tree, step
