"""Checkpoint manager: interval policy, keep-N GC, restore-latest."""
from __future__ import annotations

from typing import Optional

from repro.ckpt import checkpoint


class CheckpointManager:
    def __init__(self, directory: str, save_interval: int = 100,
                 keep: int = 3):
        self.directory = directory
        self.save_interval = max(1, save_interval)
        self.keep = keep

    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.save_interval == 0

    def save(self, step: int, state) -> str:
        return checkpoint.save(self.directory, step, state, keep=self.keep)

    def latest_step(self) -> Optional[int]:
        return checkpoint.latest_step(self.directory)

    def restore_latest(self, like, shardings=None):
        """Returns (state, step) or (None, -1) if no checkpoint exists."""
        if self.latest_step() is None:
            return None, -1
        return checkpoint.restore(self.directory, like, shardings=shardings)
