"""Execution contexts: one scoped object replaces per-call kwarg threading.

Every :mod:`repro.linalg` routine resolves an :class:`ExecutionContext`
instead of taking ``policy=`` / ``use_kernel=`` / ``registry=`` kwargs.
The context carries the *deployment shape* of a call:

``policy``
    ``"reference" | "model" | "tuned"`` (``None`` = the process default,
    i.e. ``REPRO_TUNE_POLICY`` or ``"reference"``).
``mesh``
    ``None`` for single-device execution, or a ``jax.sharding.Mesh`` /
    ``(px, py)`` tuple. With a mesh set, routines that have a distributed
    backend (``gemm`` -> SUMMA ``pdgemm``, ``trsm`` -> ``pdtrsm``, the
    batched factorizations -> the batch-sharded drivers) route there
    automatically; everything else stays local.
``registry``
    A :class:`repro.tune.registry.Registry`, a path string, or ``None``
    (the process-default registry). Path strings are normalized to one
    cached ``Registry`` per path so the file is read once.
``accum_dtype``
    Optional accumulation dtype: operands are upcast to it for the
    computation and the result is cast back to the storage dtype. ``None``
    (the default) leaves numerics exactly as the operand dtype dictates.
``interpret``
    Run Pallas kernels in interpret mode (required on CPU; default True).
``machine``
    A :class:`repro.arch.MachineSpec` (or registered machine name) the
    call's planners and tuner lookups resolve against; ``None`` (the
    default) inherits the ambient :func:`repro.arch.current_machine` -
    the process default (``"tpu-like"`` unless
    :func:`repro.arch.set_default_machine` changed it) or an enclosing
    explicit ``arch.machine_scope``. Routines with a machine set enter an
    :func:`repro.arch.machine_scope` for their whole body, so nested
    resolutions - e.g. the trailing updates inside a blocked
    factorization - see the same machine.
``obs``
    Observability capture (:mod:`repro.obs`). ``None`` (the default)
    inherits the ambient :func:`repro.obs.trace` scope, if any;
    ``False`` suppresses capture inside the scope; an explicit
    :class:`repro.obs.Trace` routes the routines' spans into that trace
    regardless of the ambient scope. Like ``machine``, the routed
    capture covers the whole routine body (nested panel/trailing spans
    included).

Contexts layer: the module default, then :func:`set_context`, then nested
:func:`use` blocks, then a per-call ``context=`` override - inner layers
override only the fields they set (everything else is inherited through
the :data:`UNSET` sentinel). ``use`` scopes live in a
:class:`contextvars.ContextVar`, so concurrent threads (and asyncio
tasks) each see only their own scopes; :func:`set_context` replaces the
process-global base underneath every scope.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Any, Dict, Iterator, Mapping, Optional, Tuple


class _UnsetType:
    """Sentinel for 'inherit this field from the enclosing context'."""

    _instance: Optional["_UnsetType"] = None

    def __new__(cls):
        if cls._instance is None:
            cls._instance = super().__new__(cls)
        return cls._instance

    def __repr__(self) -> str:
        return "UNSET"

    def __bool__(self) -> bool:
        return False


UNSET = _UnsetType()

_FIELDS = ("policy", "mesh", "registry", "accum_dtype", "interpret",
           "machine", "obs")


@dataclasses.dataclass(frozen=True)
class ExecutionContext:
    """One call's execution recipe; fields left :data:`UNSET` inherit."""

    policy: Any = UNSET
    mesh: Any = UNSET
    registry: Any = UNSET
    accum_dtype: Any = UNSET
    interpret: Any = UNSET
    machine: Any = UNSET
    obs: Any = UNSET

    def __post_init__(self):
        if self.policy is not UNSET and self.policy is not None:
            from repro.tune.policy import POLICIES
            if self.policy not in POLICIES:
                raise ValueError(
                    f"unknown policy {self.policy!r}; expected one of "
                    f"{POLICIES} (or None for the process default)")
        if self.mesh is not UNSET and self.mesh is not None:
            if isinstance(self.mesh, tuple):
                if len(self.mesh) != 2:
                    raise ValueError(
                        f"tuple mesh must be (px, py); got {self.mesh!r}")
        if self.machine is not UNSET and self.machine is not None:
            from repro.arch import MachineSpec, get as _arch_get
            if isinstance(self.machine, str):
                _arch_get(self.machine)     # unknown names fail eagerly
            elif not isinstance(self.machine, MachineSpec):
                raise ValueError(
                    f"machine must be a MachineSpec, a registered machine "
                    f"name, or None; got {type(self.machine).__name__}")
        if self.obs is not UNSET and self.obs is not None \
                and self.obs is not False:
            from repro.obs import Trace
            if not isinstance(self.obs, Trace):
                raise ValueError(
                    f"obs must be a repro.obs.Trace, False (suppress), or "
                    f"None (inherit); got {type(self.obs).__name__}")

    def over(self, base: "ExecutionContext") -> "ExecutionContext":
        """This context layered over ``base``: set fields win."""
        merged = {f: (getattr(self, f) if getattr(self, f) is not UNSET
                      else getattr(base, f)) for f in _FIELDS}
        return ExecutionContext(**merged)

    def describe(self) -> Dict[str, Any]:
        """JSON-able summary (benchmarks attach this to every row)."""
        import numpy as np
        from repro.tune.policy import default_policy
        pol = self.policy if self.policy not in (UNSET, None) \
            else default_policy()
        mesh = None if self.mesh in (UNSET, None) else (
            list(self.mesh) if isinstance(self.mesh, tuple)
            else [int(self.mesh.shape[a]) for a in self.mesh.axis_names])
        reg = self.registry
        if reg is UNSET or reg is None:
            reg_path = None
        elif isinstance(reg, str):
            reg_path = reg
        else:
            reg_path = getattr(reg, "path", None)
        acc = None if self.accum_dtype in (UNSET, None) \
            else np.dtype(self.accum_dtype).name
        interp = True if self.interpret is UNSET else bool(self.interpret)
        from repro import arch as _arch
        if self.machine in (UNSET, None):
            mach = _arch.current_machine().name
        elif isinstance(self.machine, str):
            mach = self.machine
        else:
            mach = self.machine.name
        if self.obs in (UNSET, None):
            obs_desc = None                         # inherit ambient trace
        elif self.obs is False:
            obs_desc = False
        else:
            obs_desc = getattr(self.obs, "name", "trace")
        return {"policy": pol, "mesh": mesh, "registry": reg_path,
                "accum_dtype": acc, "interpret": interp, "machine": mach,
                "obs": obs_desc}


# fully-resolved root: what a call sees with no context set anywhere
_DEFAULT = ExecutionContext(policy=None, mesh=None, registry=None,
                            accum_dtype=None, interpret=True, machine=None,
                            obs=None)
# process-global base (set_context) + per-thread/task overlay scopes (use)
_base = _DEFAULT
_scopes: "contextvars.ContextVar[Tuple[ExecutionContext, ...]]" = \
    contextvars.ContextVar("repro_linalg_scopes", default=())


def _as_overlay(context, fields: Mapping[str, Any]) -> ExecutionContext:
    if context is not None and fields:
        raise TypeError("pass either a context object or field kwargs, "
                        "not both")
    if context is None:
        return ExecutionContext(**dict(fields))
    if isinstance(context, ExecutionContext):
        return context
    if isinstance(context, Mapping):
        return ExecutionContext(**dict(context))
    raise TypeError(f"context must be an ExecutionContext or mapping; "
                    f"got {type(context).__name__}")


def _active() -> ExecutionContext:
    ctx = _base
    for overlay in _scopes.get():
        ctx = overlay.over(ctx)
    return ctx


def current(call_override=None) -> ExecutionContext:
    """The active context, with an optional per-call overlay on top."""
    ctx = _active()
    if call_override is not None:
        ctx = _as_overlay(call_override, {}).over(ctx)
    return ctx


@contextlib.contextmanager
def use(context=None, **fields) -> Iterator[ExecutionContext]:
    """Scope a context: ``with repro.linalg.use(policy="tuned", mesh=(2, 2)):``.

    Accepts an :class:`ExecutionContext` (or mapping) positionally, or the
    fields as kwargs. Unset fields inherit from the enclosing scope.
    Scopes are per-thread/per-task (contextvars); exit restores exactly
    the scopes that were active at entry, so a stray
    :func:`reset_context` inside the block cannot unbalance anything.
    """
    overlay = _as_overlay(context, fields)
    token = _scopes.set(_scopes.get() + (overlay,))
    try:
        yield _active()
    finally:
        _scopes.reset(token)


def set_context(context=None, **fields) -> ExecutionContext:
    """Replace the process-global base context (under any active ``use``)."""
    global _base
    _base = _as_overlay(context, fields).over(_DEFAULT)
    return _base


def get_context() -> ExecutionContext:
    """The currently active (fully layered) context."""
    return _active()


def reset_context() -> None:
    """Reset the global base and this thread's scopes to the library
    default (tests)."""
    global _base
    _base = _DEFAULT
    _scopes.set(())


def compat_context(policy=None, use_kernel=None, interpret: bool = True,
                   registry=None, use_pallas=None) -> ExecutionContext:
    """Old kwarg triple -> per-call context (the d-prefixed shims' bridge).

    Pins ``mesh=None``, ``accum_dtype=None``, and ``machine=None`` so a
    deprecated call behaves exactly like the pre-:mod:`repro.linalg`
    routine it shims - local execution, operand-dtype accumulation, and
    no machine opinion of its own (``machine=None`` overrides any
    enclosing context machine; planning falls back to the ambient
    :func:`repro.arch.current_machine`, i.e. the process default unless
    an explicit ``arch.machine_scope`` is active) - whatever context is
    active. ``use_kernel`` / ``use_pallas`` go through
    :func:`repro.tune.policy.resolve_policy`, which owns their own
    deprecation warnings.
    """
    if policy is not None or use_kernel is not None or use_pallas is not None:
        from repro.tune.policy import resolve_policy
        pol = resolve_policy(policy, use_kernel, use_pallas)
    else:
        pol = UNSET
    return ExecutionContext(
        policy=pol, mesh=None, accum_dtype=None, interpret=interpret,
        registry=registry if registry is not None else UNSET, machine=None)


# ------------------------- lazy field normalizers ---------------------------

_registry_cache: Dict[str, Any] = {}
_mesh_cache: Dict[tuple, Any] = {}


def resolved_registry(ctx: ExecutionContext):
    """ctx.registry as a Registry-or-None (path strings cached per path)."""
    reg = ctx.registry
    if reg is UNSET or reg is None:
        return None
    if isinstance(reg, str):
        if reg not in _registry_cache:
            from repro.tune.registry import Registry
            _registry_cache[reg] = Registry(path=reg)
        return _registry_cache[reg]
    return reg


def resolved_mesh(ctx: ExecutionContext):
    """ctx.mesh as a jax Mesh-or-None ((px, py) tuples built lazily)."""
    mesh = ctx.mesh
    if mesh is UNSET or mesh is None:
        return None
    if isinstance(mesh, tuple):
        if mesh not in _mesh_cache:
            from repro.blas.distributed import make_blas_mesh
            _mesh_cache[mesh] = make_blas_mesh(*mesh)
        return _mesh_cache[mesh]
    return mesh


def resolved_policy(ctx: ExecutionContext):
    """ctx.policy as a policy-string-or-None (None = process default)."""
    return None if ctx.policy is UNSET else ctx.policy


def resolved_interpret(ctx: ExecutionContext) -> bool:
    return True if ctx.interpret is UNSET else bool(ctx.interpret)


def resolved_accum_dtype(ctx: ExecutionContext):
    return None if ctx.accum_dtype in (UNSET, None) else ctx.accum_dtype


def resolved_machine(ctx: ExecutionContext):
    """ctx.machine as a MachineSpec-or-None (names resolved through the
    arch registry; None = the process-default machine)."""
    mach = ctx.machine
    if mach is UNSET or mach is None:
        return None
    if isinstance(mach, str):
        from repro import arch as _arch
        return _arch.get(mach)
    return mach


def resolved_obs(ctx: ExecutionContext):
    """ctx.obs as a Trace-or-None. ``UNSET``/``None`` inherit the ambient
    :func:`repro.obs.current_trace`; ``False`` resolves to ``None`` even
    under an ambient trace (the routine wrappers additionally mask the
    ambient scope in that case, so nested spans stay suppressed too)."""
    o = ctx.obs
    if o is False:
        return None
    if o is UNSET or o is None:
        from repro.obs import current_trace
        return current_trace()
    return o
