"""repro.linalg - the dtype-generic, context-scoped BLAS/LAPACK front-end.

This package is the single public API of the repo's linear algebra stack:
one set of routine names (``gemm``, ``gemv``, ``syrk``, ``trsm``,
``axpy``, ``dot``, ..., ``cholesky``, ``lu``, ``qr``, ``solve`` and their
batched forms) over every supported dtype (float32/float64; bfloat16
storage on the kernel paths), with the deployment shape - policy, device
mesh, registry, accumulation dtype - carried by a scoped
:class:`ExecutionContext` instead of per-call kwarg threading::

    from repro import linalg

    c = linalg.gemm(a, b)                          # process-default context

    with linalg.use(policy="tuned"):               # scoped policy
        l = linalg.cholesky(spd)

    with linalg.use(policy="model", mesh=(2, 2)):  # SUMMA + sharded batch
        c = linalg.gemm(a, b)                      # routes to pdgemm
        r = linalg.batched_cholesky(spd_batch)     # batch-sharded driver

    with linalg.use(machine=arch.get("paper-pe")): # swap the machine model:
        c = linalg.gemm(a, b)                      # planners + tuner keys
                                                   # follow the MachineSpec

    linalg.set_context(policy="tuned",             # process-global default
                       registry="/path/to/registry.json")
    x = linalg.solve(a, b, context=dict(policy="reference"))  # per call

Callers never pick a namespace by deployment shape: the same ``gemm``
call runs plain jnp, the Pallas MXU kernel, a tuned registry config, or
the SUMMA mesh schedule depending only on the active context. The old
d-prefixed routines (``repro.blas.dgemm``, ...) survive as thin
deprecation shims that forward here (see ``docs/migration.md``).
"""
from repro.lapack.batched import FactorizationResult
from repro.linalg.context import (UNSET, ExecutionContext, get_context,
                                  reset_context, set_context, use)
from repro.linalg.blas import (asum, axpy, dot, gemm, gemm_bias_act, gemv,
                               ger, iamax, nrm2, rot, scal, syrk, trsm,
                               trsv)
from repro.linalg.lapack import (batched_cholesky, batched_lu, batched_qr,
                                 batched_solve, cholesky, lstsq, lu, qr,
                                 solve)

__all__ = [
    # context machinery
    "ExecutionContext", "use", "get_context", "set_context", "reset_context",
    # BLAS level 1
    "axpy", "dot", "scal", "nrm2", "asum", "iamax", "rot",
    # BLAS level 2
    "gemv", "ger", "trsv",
    # BLAS level 3
    "gemm", "gemm_bias_act", "syrk", "trsm",
    # LAPACK
    "cholesky", "lu", "qr", "solve", "lstsq",
    # batched LAPACK
    "batched_cholesky", "batched_lu", "batched_qr", "batched_solve",
    "FactorizationResult",
]
