"""dtype-generic LAPACK front-end, routed by the active ExecutionContext.

``cholesky`` / ``lu`` / ``qr`` / ``solve`` (+ ``lstsq``) accept one matrix
(2-D) or a leading batch axis (3-D, delegated to the batched drivers);
the explicit ``batched_*`` forms return the shared
:class:`repro.lapack.batched.FactorizationResult` pytree. When the active
context carries a mesh, the batched forms route to the batch-sharded
drivers in :mod:`repro.lapack.distributed`; single-matrix factorizations
run locally under any context (there is no distributed single-matrix
path), with their trailing updates still policy-dispatched through
:mod:`repro.tune`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.lapack import batched as _batched
from repro.lapack import cholesky as _chol
from repro.lapack import lu as _lu
from repro.lapack import qr as _qr
from repro.lapack import solve as _solve
from repro.lapack.batched import FactorizationResult
from repro.linalg.blas import (_cast, _dtype_name, _dtypes, _kw, _nbytes,
                               _routine, _shape)
from repro.linalg.context import current, resolved_mesh


def _batched_route(ctx, local_fn, dist_fn, a, **kw):
    mesh = resolved_mesh(ctx)
    if mesh is not None:
        return dist_fn(a, mesh, **kw)
    return local_fn(a, **kw)


# --------------------- span annotation (traced calls only) ------------------
# Leading-order LAPACK flop counts (the paper's accounting coefficients -
# see FACTOR_FLOP_COEFF in repro.core.codesign for the square-case forms);
# exact lower-order terms are not tracked, these price roofline spans.

def _potrf_flops(n):
    return n ** 3 // 3


def _getrf_flops(m, n):
    k = min(m, n)
    return m * n * k - (m + n) * k * k // 2 + k ** 3 // 3


def _geqrf_flops(m, n):
    k = min(m, n)
    return 2 * m * n * k - k * k * (m + n) + 2 * k ** 3 // 3


def _factor_info(flops_fn):
    """Factorization info factory; ``flops_fn(m, n)`` prices one item."""
    def info(a, *args, **kw):
        s = _shape(a)
        batch = s[0] if len(s) == 3 else 1
        return {"shape": list(s), "dtype": _dtype_name(a),
                "flops": batch * flops_fn(s[-2], s[-1]),
                "bytes": _nbytes(a)}
    return info


def _solve_info(a, b, *args, **kw):
    sa, sb = _shape(a), _shape(b)
    batch = sa[0] if len(sa) == 3 else 1
    n = sa[-1]
    nrhs = sb[-1] if len(sb) - (len(sa) - 2) >= 2 else 1
    flops = _getrf_flops(sa[-2], n) + 2 * n * n * nrhs
    return {"shape": list(sa), "dtype": _dtype_name(a, b),
            "flops": batch * flops, "bytes": _nbytes(a, b)}


def _lstsq_info(a, b, *args, **kw):
    sa, sb = _shape(a), _shape(b)
    batch = sa[0] if len(sa) == 3 else 1
    m, n = sa[-2], sa[-1]
    nrhs = sb[-1] if len(sb) - (len(sa) - 2) >= 2 else 1
    flops = _geqrf_flops(m, n) + 2 * n * n * nrhs
    return {"shape": list(sa), "dtype": _dtype_name(a, b),
            "flops": batch * flops, "bytes": _nbytes(a, b)}


def _batched_solve_info(res, b, *args, **kw):
    sf, sb = _shape(res.factors), _shape(b)
    batch = sf[0] if len(sf) == 3 else 1
    n = sf[-1]
    nrhs = sb[-1] if len(sb) >= 3 else 1
    return {"shape": list(sf), "dtype": _dtype_name(res.factors, b),
            "flops": batch * 2 * n * n * nrhs,
            "bytes": _nbytes(res.factors, b)}


def _cast_result(res: FactorizationResult, store) -> FactorizationResult:
    factors = _cast(res.factors, store)
    tau = None if res.tau is None else _cast(res.tau, store)
    return dataclasses.replace(res, factors=factors, tau=tau)


# ------------------------------ factorizations ------------------------------

@_routine("cholesky", _factor_info(lambda m, n: _potrf_flops(n)))
def cholesky(a, block: Optional[int] = None, dtype=None,
             context=None, fuse: Optional[bool] = None) -> jnp.ndarray:
    """Lower-triangular Cholesky factor of an SPD matrix (or batch).

    2-D input returns L with A = L L^T; 3-D input returns the (B, n, n)
    factor batch (via :func:`batched_cholesky`, mesh-routed; ``fuse``
    applies to the 2-D driver only). ``fuse`` controls the fused
    trsm+gemm trailing chain: ``None`` defers to the chain plan under the
    kernel policies, ``False`` forces the staged path, ``True`` forces
    fusion. Non-SPD input produces NaNs, LAPACK-style. Oracle:
    ``tests/test_linalg.py``; fused-vs-staged: ``tests/test_fusion.py``.
    """
    ctx = current(context)
    store, comp = _dtypes(ctx, dtype, a)
    a_ = _cast(a, comp)
    if a_.ndim == 3:
        return _cast(batched_cholesky(a_, block=block, context=ctx).factors,
                     store)
    out = _chol.potrf(a_, block=block, fuse=fuse, **_kw(ctx))
    return _cast(out, store)


@_routine("lu", _factor_info(_getrf_flops))
def lu(a, block: Optional[int] = None, dtype=None,
       context=None,
       fuse: Optional[bool] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """LU with partial pivoting: (packed L\\U, int32 ipiv).

    3-D input factorizes the batch (mesh-routed) and returns
    ((B, m, n) packed, (B, k) ipiv); ``fuse`` applies to the 2-D driver
    only and controls the fused trsm+gemm trailing chain (``None`` =
    defer to the chain plan, ``False`` = staged, ``True`` = force).
    """
    ctx = current(context)
    store, comp = _dtypes(ctx, dtype, a)
    a_ = _cast(a, comp)
    if a_.ndim == 3:
        res = batched_lu(a_, block=block, context=ctx)
        return _cast(res.factors, store), res.pivots
    packed, piv = _lu.getrf(a_, block=block, fuse=fuse, **_kw(ctx))
    return _cast(packed, store), piv


@_routine("qr", _factor_info(lambda m, n: _geqrf_flops(m, n) + 2 * m * m * min(m, n)))
def qr(a, block: Optional[int] = None, dtype=None,
       context=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Thin QR: (Q (m, min(m, n)), R (min(m, n), n)).

    3-D input returns batched (Q, R) via :func:`batched_qr` (mesh-routed)
    plus a local per-item Q accumulation.
    """
    ctx = current(context)
    store, comp = _dtypes(ctx, dtype, a)
    a_ = _cast(a, comp)
    if a_.ndim == 3:
        res = batched_qr(a_, block=block, context=ctx)
        kmin = min(a_.shape[1], a_.shape[2])

        def one(packed, tau):
            q = _qr.q_from_geqrf(packed, tau)
            return q[:, :kmin], jnp.triu(packed)[:kmin, :]

        q, r = jax.vmap(one)(res.factors, res.tau)
        return _cast(q, store), _cast(r, store)
    q, r = _qr.qr(a_, block=block, **_kw(ctx))
    return _cast(q, store), _cast(r, store)


@_routine("solve", _solve_info)
def solve(a, b, block: Optional[int] = None, dtype=None,
          context=None) -> jnp.ndarray:
    """Solve A X = B via pivoted LU (LAPACK GESV).

    2-D ``a`` solves one system; 3-D ``a`` factorizes and solves the batch
    (``b`` (B, n) or (B, n, k)), routed to the batch-sharded drivers under
    a mesh context.
    """
    ctx = current(context)
    store, comp = _dtypes(ctx, dtype, a, b)
    a_, b_ = _cast(a, comp), _cast(b, comp)
    if a_.ndim == 3:
        res = batched_lu(a_, block=block, context=ctx)
        return _cast(batched_solve(res, b_, context=ctx), store)
    out = _solve.gesv(a_, b_, block=block, **_kw(ctx))
    return _cast(out, store)


@_routine("lstsq", _lstsq_info)
def lstsq(a, b, block: Optional[int] = None, dtype=None,
          context=None) -> jnp.ndarray:
    """Least-squares min ||A x - b|| via QR (m >= n, full column rank).

    3-D ``a`` solves the batch through :func:`batched_qr` +
    :func:`batched_solve` (mesh-routed under a mesh context).
    """
    ctx = current(context)
    store, comp = _dtypes(ctx, dtype, a, b)
    a_, b_ = _cast(a, comp), _cast(b, comp)
    if a_.ndim == 3:
        res = batched_qr(a_, block=block, context=ctx)
        return _cast(batched_solve(res, b_, context=ctx), store)
    out = _solve.lstsq_qr(a_, b_, block=block, **_kw(ctx))
    return _cast(out, store)


# ------------------------------ batched drivers -----------------------------

@_routine("batched_cholesky", _factor_info(lambda m, n: _potrf_flops(n)))
def batched_cholesky(a, block: Optional[int] = None, dtype=None,
                     context=None) -> FactorizationResult:
    """Cholesky of a (B, n, n) SPD batch -> FactorizationResult("potrf").

    Routes to :func:`repro.lapack.distributed.batched_potrf` when the
    context carries a mesh (batch axis sharded, zero collectives).
    """
    ctx = current(context)
    store, comp = _dtypes(ctx, dtype, a)
    from repro.lapack import distributed as _dist
    res = _batched_route(ctx, _batched.batched_potrf, _dist.batched_potrf,
                         _cast(a, comp), block=block, **_kw(ctx))
    return _cast_result(res, store)


@_routine("batched_lu", _factor_info(_getrf_flops))
def batched_lu(a, block: Optional[int] = None, dtype=None,
               context=None) -> FactorizationResult:
    """Pivoted LU of a (B, m, n) batch -> FactorizationResult("getrf")."""
    ctx = current(context)
    store, comp = _dtypes(ctx, dtype, a)
    from repro.lapack import distributed as _dist
    res = _batched_route(ctx, _batched.batched_getrf, _dist.batched_getrf,
                         _cast(a, comp), block=block, **_kw(ctx))
    return _cast_result(res, store)


@_routine("batched_qr", _factor_info(_geqrf_flops))
def batched_qr(a, block: Optional[int] = None, dtype=None,
               context=None) -> FactorizationResult:
    """Householder QR of a (B, m, n) batch -> FactorizationResult("geqrf")."""
    ctx = current(context)
    store, comp = _dtypes(ctx, dtype, a)
    from repro.lapack import distributed as _dist
    res = _batched_route(ctx, _batched.batched_geqrf, _dist.batched_geqrf,
                         _cast(a, comp), block=block, **_kw(ctx))
    return _cast_result(res, store)


@_routine("batched_solve", _batched_solve_info)
def batched_solve(res: FactorizationResult, b, dtype=None,
                  context=None) -> jnp.ndarray:
    """Solve A_i x_i = b_i from any FactorizationResult (mesh-routed)."""
    ctx = current(context)
    store, comp = _dtypes(ctx, dtype, res.factors, b)
    res_ = _cast_result(res, comp)
    b_ = _cast(b, comp)
    mesh = resolved_mesh(ctx)
    if mesh is not None:
        from repro.lapack import distributed as _dist
        out = _dist.batched_solve(res_, b_, mesh, **_kw(ctx))
    else:
        out = _batched.batched_solve(res_, b_, **_kw(ctx))
    return _cast(out, store)
