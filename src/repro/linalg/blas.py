"""dtype-generic BLAS front-end, routed by the active ExecutionContext.

Every routine here:

* accepts float32/float64 operands (bfloat16 storage where the kernel
  path supports it) and an explicit ``dtype=`` cast,
* resolves policy / registry / interpret / accumulation dtype from the
  active :class:`repro.linalg.ExecutionContext` (``context=`` overrides
  per call),
* routes to the distributed backend when the context carries a mesh
  (``gemm`` -> SUMMA :func:`repro.blas.distributed.pdgemm`, ``trsm`` ->
  :func:`repro.blas.distributed.pdtrsm`, ``syrk`` through ``pdgemm``);
  routines without a mesh backend (vector ops, ``gemv``, batched GEMM)
  run locally under any context,
* supports a leading batch axis on the matrix routines (3-D operands are
  vmapped over the local path).

The numeric cores live in :mod:`repro.blas.level1`/``level2``/``level3``;
this layer only resolves the context and casts dtypes, so a call under the
default context is bit-identical to the deprecated d-prefixed routine it
replaces.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro import arch as _arch
from repro.blas import level1 as _l1
from repro.blas import level2 as _l2
from repro.blas import level3 as _l3
from repro.linalg.context import (current, resolved_accum_dtype,
                                  resolved_interpret, resolved_machine,
                                  resolved_mesh, resolved_policy,
                                  resolved_registry)


def _machine_scoped(fn):
    """Run the routine body under the context's machine.

    The resolved ``ctx.machine`` becomes the ambient
    :func:`repro.arch.machine_scope` for the whole call, so every nested
    planner/tuner resolution - the trailing updates inside a blocked
    factorization included - sees it without kwarg threading. A ``None``
    machine inherits whatever scope (or the process default) is already
    active.
    """
    @functools.wraps(fn)
    def wrapper(*args, context=None, **kw):
        ctx = current(context)
        mach = resolved_machine(ctx)
        if mach is None:
            return fn(*args, context=ctx, **kw)
        with _arch.machine_scope(mach):
            return fn(*args, context=ctx, **kw)
    return wrapper


def _dtypes(ctx, dtype, *arrays):
    """(storage dtype, compute dtype) for this call, or (None, None).

    (None, None) - the passthrough fast path - means no explicit ``dtype``
    and no context accumulation dtype: operands reach the numeric core
    untouched, so results are bitwise what the core produces (the
    deprecation shims rely on this). Otherwise: storage = the explicit
    ``dtype`` or the result type of *all* operands (accumulands like
    ``c``/``y`` participate in the promotion, as they would in plain jnp);
    compute = the context's accumulation dtype (upcast) or the storage
    dtype.
    """
    acc = resolved_accum_dtype(ctx)
    if dtype is None and acc is None:
        return None, None
    arrs = [a for a in arrays if a is not None]
    store = jnp.dtype(dtype) if dtype is not None else jnp.result_type(*arrs)
    comp = jnp.dtype(acc) if acc is not None else store
    return store, comp


def _cast(x, to):
    if x is None:
        return None
    x = jnp.asarray(x)
    if to is None or x.dtype == to:
        return x
    return x.astype(to)


def _kw(ctx):
    """Context fields -> the kwargs every numeric core takes."""
    return dict(policy=resolved_policy(ctx), interpret=resolved_interpret(ctx),
                registry=resolved_registry(ctx))


# -------------------------------- level 3 -----------------------------------

@_machine_scoped
def gemm(a, b, c=None, alpha=1.0, beta=0.0, transa: bool = False,
         transb: bool = False, dtype=None, context=None) -> jnp.ndarray:
    """C <- alpha * op(A) op(B) + beta * C, any supported dtype.

    2-D operands run the policy-dispatched local kernel path; with a mesh
    in the active context they run SUMMA ``pdgemm`` instead. 3-D operands
    (leading batch axis) vmap the local path. Oracle:
    ``tests/test_linalg.py`` / ``tests/test_differential_blas.py``.
    """
    ctx = current(context)
    store, comp = _dtypes(ctx, dtype, a, b, c)
    a_, b_, c_ = _cast(a, comp), _cast(b, comp), _cast(c, comp)
    if a_.ndim == 3:
        kw = _kw(ctx)
        f = lambda x, y: _l3.gemm(x, y, transa=transa, transb=transb, **kw)
        out = jax.vmap(f)(a_, b_)
        out = alpha * out
        if c_ is not None:
            out = out + beta * c_
        return _cast(out, store)
    mesh = resolved_mesh(ctx)
    if mesh is not None:
        from repro.blas import distributed as _dist
        op_a = a_.T if transa else a_
        op_b = b_.T if transb else b_
        out = _dist.pdgemm(op_a, op_b, mesh, c=c_, alpha=alpha, beta=beta,
                           **_kw(ctx))
        return _cast(out, store)
    out = _l3.gemm(a_, b_, c=c_, alpha=alpha, beta=beta, transa=transa,
                   transb=transb, **_kw(ctx))
    return _cast(out, store)


@_machine_scoped
def syrk(a, c=None, alpha=1.0, beta=0.0, lower: bool = True,
         trans: bool = False, dtype=None, context=None) -> jnp.ndarray:
    """C <- alpha op(A) op(A)^T + beta C, symmetric output.

    Under a mesh the product runs through SUMMA ``pdgemm`` before the
    triangle mirror; locally it shares the GEMM kernel path (and its
    registry entries).
    """
    ctx = current(context)
    store, comp = _dtypes(ctx, dtype, a, c)
    a_, c_ = _cast(a, comp), _cast(c, comp)
    mesh = resolved_mesh(ctx)
    if mesh is not None and a_.ndim == 2:
        from repro.blas import distributed as _dist
        op_a = a_.T if trans else a_
        full = alpha * _dist.pdgemm(op_a, op_a.T, mesh, **_kw(ctx))
        if c_ is not None:
            full = full + beta * c_
        return _cast(_l3.mirror_triangle(full, lower), store)
    kw = _kw(ctx)
    if a_.ndim == 3:
        f = lambda x, y: _l3.syrk(x, c=y, alpha=alpha, beta=beta,
                                  lower=lower, trans=trans, **kw)
        out = jax.vmap(f)(a_, c_) if c_ is not None else jax.vmap(
            lambda x: _l3.syrk(x, alpha=alpha, lower=lower, trans=trans,
                               **kw))(a_)
        return _cast(out, store)
    out = _l3.syrk(a_, c=c_, alpha=alpha, beta=beta, lower=lower,
                   trans=trans, **kw)
    return _cast(out, store)


@_machine_scoped
def trsm(a, b, lower: bool = True, unit_diag: bool = False,
         left: bool = True, block: Optional[int] = None, dtype=None,
         context=None) -> jnp.ndarray:
    """Solve op(T) X = B (or X op(T) = B), blocked, any supported dtype.

    Under a mesh the right-hand-side columns are sharded via ``pdtrsm``;
    locally the off-diagonal GEMM updates follow the context policy onto
    the kernel path. 3-D operands vmap the local path.
    """
    ctx = current(context)
    store, comp = _dtypes(ctx, dtype, a, b)
    a_, b_ = _cast(a, comp), _cast(b, comp)
    kw = _kw(ctx)
    if a_.ndim == 3:
        f = lambda t, r: _l3.trsm(t, r, lower=lower, unit_diag=unit_diag,
                                  left=left, block=block, **kw)
        return _cast(jax.vmap(f)(a_, b_), store)
    mesh = resolved_mesh(ctx)
    if mesh is not None:
        from repro.blas import distributed as _dist
        out = _dist.pdtrsm(a_, b_, mesh, lower=lower, unit_diag=unit_diag,
                           left=left, block=block, **kw)
        return _cast(out, store)
    out = _l3.trsm(a_, b_, lower=lower, unit_diag=unit_diag, left=left,
                   block=block, **kw)
    return _cast(out, store)


# -------------------------------- level 2 -----------------------------------

@_machine_scoped
def gemv(a, x, y=None, alpha=1.0, beta=0.0, trans: bool = False,
         dtype=None, context=None) -> jnp.ndarray:
    """y <- alpha*op(A) x + beta*y. Kernel policies run op(A) x through
    the Pallas GEMM path (shared registry entries); no mesh backend -
    always local. 3-D a / 2-D x vmap over the batch axis."""
    ctx = current(context)
    store, comp = _dtypes(ctx, dtype, a, x, y)
    a_, x_, y_ = _cast(a, comp), _cast(x, comp), _cast(y, comp)
    kw = _kw(ctx)
    if a_.ndim == 3:
        f = lambda m, v: _l2.gemv(m, v, trans=trans, **kw)
        out = alpha * jax.vmap(f)(a_, x_)
        if y_ is not None:
            out = out + beta * y_
        return _cast(out, store)
    out = _l2.gemv(a_, x_, y=y_, alpha=alpha, beta=beta, trans=trans, **kw)
    return _cast(out, store)


@_machine_scoped
def ger(alpha, x, y, a, dtype=None, context=None) -> jnp.ndarray:
    """A <- alpha * x y^T + A (rank-1 update, pure jnp)."""
    ctx = current(context)
    store, comp = _dtypes(ctx, dtype, x, y, a)
    out = _l2.ger(alpha, _cast(x, comp), _cast(y, comp), _cast(a, comp))
    return _cast(out, store)


@_machine_scoped
def trsv(a, b, lower: bool = True, unit_diag: bool = False, dtype=None,
         context=None) -> jnp.ndarray:
    """Solve op(T) x = b via the row-sequential scan (the divider-hazard
    chain); the blocked, policy-dispatched form is :func:`trsm`."""
    ctx = current(context)
    store, comp = _dtypes(ctx, dtype, a, b)
    out = _l2.trsv(_cast(a, comp), _cast(b, comp), lower=lower,
                   unit_diag=unit_diag)
    return _cast(out, store)


# -------------------------------- level 1 -----------------------------------

@_machine_scoped
def dot(x, y, schedule: str = "tree", accumulators: int = 8, dtype=None,
        context=None) -> jnp.ndarray:
    """Inner product with an explicit reduction schedule
    (tree/sequential/strided) - see :func:`repro.blas.level1.dot`.
    ``accum_dtype`` in the context upcasts the whole reduction."""
    ctx = current(context)
    store, comp = _dtypes(ctx, dtype, x, y)
    out = _l1.dot(_cast(x, comp), _cast(y, comp), schedule=schedule,
                  accumulators=accumulators)
    return _cast(out, store)


@_machine_scoped
def axpy(alpha, x, y, dtype=None, context=None) -> jnp.ndarray:
    """y <- alpha*x + y."""
    ctx = current(context)
    store, comp = _dtypes(ctx, dtype, x, y)
    return _cast(_l1.axpy(alpha, _cast(x, comp), _cast(y, comp)), store)


@_machine_scoped
def scal(alpha, x, dtype=None, context=None) -> jnp.ndarray:
    """x <- alpha*x."""
    ctx = current(context)
    store, comp = _dtypes(ctx, dtype, x)
    return _cast(_l1.scal(alpha, _cast(x, comp)), store)


@_machine_scoped
def nrm2(x, dtype=None, context=None) -> jnp.ndarray:
    """Overflow-safe Euclidean norm."""
    ctx = current(context)
    store, comp = _dtypes(ctx, dtype, x)
    return _cast(_l1.nrm2(_cast(x, comp)), store)


@_machine_scoped
def asum(x, dtype=None, context=None) -> jnp.ndarray:
    """Sum of absolute values."""
    ctx = current(context)
    store, comp = _dtypes(ctx, dtype, x)
    return _cast(_l1.asum(_cast(x, comp)), store)


@_machine_scoped
def iamax(x, context=None) -> jnp.ndarray:
    """Index of the first max-|x| element (0-based int; no dtype cast)."""
    return _l1.iamax(jnp.asarray(x))


@_machine_scoped
def rot(x, y, c, s, dtype=None, context=None):
    """Apply a Givens rotation: (c*x + s*y, c*y - s*x)."""
    ctx = current(context)
    store, comp = _dtypes(ctx, dtype, x, y)
    gx, gy = _l1.rot(_cast(x, comp), _cast(y, comp), c, s)
    return _cast(gx, store), _cast(gy, store)
