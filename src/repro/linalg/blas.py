"""dtype-generic BLAS front-end, routed by the active ExecutionContext.

Every routine here:

* accepts float32/float64 operands (bfloat16 storage where the kernel
  path supports it) and an explicit ``dtype=`` cast,
* resolves policy / registry / interpret / accumulation dtype from the
  active :class:`repro.linalg.ExecutionContext` (``context=`` overrides
  per call),
* routes to the distributed backend when the context carries a mesh
  (``gemm`` -> SUMMA :func:`repro.blas.distributed.pdgemm`, ``trsm`` ->
  :func:`repro.blas.distributed.pdtrsm`, ``syrk`` through ``pdgemm``);
  routines without a mesh backend (vector ops, ``gemv``, batched GEMM)
  run locally under any context,
* supports a leading batch axis on the matrix routines (3-D operands are
  vmapped over the local path).

The numeric cores live in :mod:`repro.blas.level1`/``level2``/``level3``;
this layer only resolves the context and casts dtypes, so a call under the
default context is bit-identical to the deprecated d-prefixed routine it
replaces.
"""
from __future__ import annotations

import contextlib
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro import arch as _arch
from repro import obs as _obs
from repro.blas import level1 as _l1
from repro.blas import level2 as _l2
from repro.blas import level3 as _l3
from repro.linalg.context import (current, resolved_accum_dtype,
                                  resolved_interpret, resolved_machine,
                                  resolved_mesh, resolved_obs,
                                  resolved_policy, resolved_registry)


def _routine(op, info=None):
    """Routine wrapper: machine scoping + one obs span per public call.

    The resolved ``ctx.machine`` becomes the ambient
    :func:`repro.arch.machine_scope` for the whole call, so every nested
    planner/tuner resolution - the trailing updates inside a blocked
    factorization included - sees it without kwarg threading. A ``None``
    machine inherits whatever scope (or the process default) is already
    active.

    When a trace is capturing (the ambient :func:`repro.obs.trace` scope,
    or an explicit ``ctx.obs``), the body runs under a
    ``linalg.<op>`` span annotated by ``info(*args, **kw)`` - shapes,
    dtype, flop/byte counts - which the span prices against the ambient
    machine at close (``docs/observability.md``). With no capture active
    the wrapper takes a dict-free early return into the numeric body:
    untraced calls execute byte-for-byte the pre-obs path. An annotation
    failure never breaks the call (``info`` runs under ``except``).
    """
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, context=None, **kw):
            ctx = current(context)
            mach = resolved_machine(ctx)
            tr = resolved_obs(ctx)
            if tr is None and not _obs.enabled():
                # fast path: no capture anywhere - identical to pre-obs
                if mach is None:
                    return fn(*args, context=ctx, **kw)
                with _arch.machine_scope(mach):
                    return fn(*args, context=ctx, **kw)
            with contextlib.ExitStack() as st:
                if mach is not None:
                    st.enter_context(_arch.machine_scope(mach))
                if tr is None:
                    # ctx.obs=False under an ambient trace: mask capture
                    # for the whole body (nested spans included)
                    st.enter_context(_obs.capture(None))
                    return fn(*args, context=ctx, **kw)
                if tr is not _obs.current_trace():
                    st.enter_context(_obs.capture(tr))
                sp = st.enter_context(_obs.span("linalg." + op,
                                                cat="routine"))
                if info is not None:
                    try:
                        sp.annotate(**info(*args, **kw))
                    except Exception:
                        pass
                return fn(*args, context=ctx, **kw)
        # the static analyzer's drift oracle: repro.analysis.check reads
        # the routine name and its flops/bytes annotation fn off the
        # wrapper to compare against jaxpr_census-derived counts (CM001/2)
        wrapper._analysis_op = op
        wrapper._analysis_info = info
        return wrapper
    return deco


# --------------------- span annotation (traced calls only) ------------------

def _shape(x):
    return tuple(int(d) for d in getattr(x, "shape", ()))


def _nbytes(*arrays) -> int:
    """Total operand bytes (arrays without shape/dtype - e.g. python
    scalars - count 0); works on jit tracers (shape/dtype are static)."""
    total = 0
    for x in arrays:
        shp = getattr(x, "shape", None)
        dt = getattr(x, "dtype", None)
        if shp is None or dt is None:
            continue
        n = 1
        for d in shp:
            n *= int(d)
        total += n * jnp.dtype(dt).itemsize
    return total


def _dtype_name(*arrays) -> str:
    return jnp.result_type(*[a for a in arrays if a is not None]).name


def _gemm_info(a, b, c=None, alpha=1.0, beta=0.0, transa=False, transb=False,
               **kw):
    sa, sb = _shape(a), _shape(b)
    batch = sa[0] if len(sa) == 3 else 1
    m = sa[-1] if transa else sa[-2]
    k = sa[-2] if transa else sa[-1]
    n = sb[-2] if transb else sb[-1]
    out_itemsize = jnp.dtype(jnp.result_type(
        *[v for v in (a, b, c) if v is not None])).itemsize
    return {"shape": ([m, n, k] if batch == 1 else [batch, m, n, k]),
            "dtype": _dtype_name(a, b, c),
            "flops": 2 * batch * m * n * k,
            "bytes": _nbytes(a, b, c) + batch * m * n * out_itemsize}


def _gemm_bias_act_info(a, b, bias=None, epilogue="none", **kw):
    sa, sb = _shape(a), _shape(b)
    batch = sa[0] if len(sa) == 3 else 1
    m, k, n = sa[-2], sa[-1], sb[-1]
    out_itemsize = jnp.dtype(jnp.result_type(a, b)).itemsize
    return {"shape": ([m, n, k] if batch == 1 else [batch, m, n, k]),
            "dtype": _dtype_name(a, b), "epilogue": epilogue,
            "flops": 2 * batch * m * n * k + batch * m * n,
            "bytes": _nbytes(a, b, bias) + batch * m * n * out_itemsize}


def _syrk_info(a, c=None, alpha=1.0, beta=0.0, lower=True, trans=False, **kw):
    sa = _shape(a)
    batch = sa[0] if len(sa) == 3 else 1
    n = sa[-1] if trans else sa[-2]
    k = sa[-2] if trans else sa[-1]
    return {"shape": ([n, k] if batch == 1 else [batch, n, k]),
            "dtype": _dtype_name(a, c), "flops": 2 * batch * n * n * k,
            "bytes": _nbytes(a, c)}


def _trsm_info(a, b, lower=True, unit_diag=False, left=True, block=None,
               **kw):
    sa, sb = _shape(a), _shape(b)
    batch = sa[0] if len(sa) == 3 else 1
    n = sa[-1]
    nrhs = sb[-1] if len(sb) >= 2 else 1
    return {"shape": ([n, nrhs] if batch == 1 else [batch, n, nrhs]),
            "dtype": _dtype_name(a, b), "flops": batch * n * n * nrhs,
            "bytes": _nbytes(a, b)}


def _gemv_info(a, x, y=None, alpha=1.0, beta=0.0, trans=False, **kw):
    sa = _shape(a)
    batch = sa[0] if len(sa) == 3 else 1
    m, n = sa[-2], sa[-1]
    return {"shape": ([m, n] if batch == 1 else [batch, m, n]),
            "dtype": _dtype_name(a, x, y), "flops": 2 * batch * m * n,
            "bytes": _nbytes(a, x, y)}


def _ger_info(alpha, x, y, a, **kw):
    m, n = _shape(a)[-2:]
    return {"shape": [m, n], "dtype": _dtype_name(x, y, a),
            "flops": 2 * m * n, "bytes": _nbytes(x, y, a)}


def _trsv_info(a, b, **kw):
    n = _shape(a)[-1]
    return {"shape": [n], "dtype": _dtype_name(a, b), "flops": n * n,
            "bytes": _nbytes(a, b)}


def _vec_info(flop_per_elem):
    def info(*args, **kw):
        arrs = [a for a in args if getattr(a, "shape", None) is not None
                or isinstance(a, (list, tuple))]
        x = arrs[0] if arrs else args[0]
        x = jnp.asarray(x) if getattr(x, "shape", None) is None else x
        n = 1
        for d in _shape(x):
            n *= d
        return {"shape": list(_shape(x)), "dtype": _dtype_name(x),
                "flops": flop_per_elem * n, "bytes": _nbytes(*args)}
    return info


def _dtypes(ctx, dtype, *arrays):
    """(storage dtype, compute dtype) for this call, or (None, None).

    (None, None) - the passthrough fast path - means no explicit ``dtype``
    and no context accumulation dtype: operands reach the numeric core
    untouched, so results are bitwise what the core produces (the
    deprecation shims rely on this). Otherwise: storage = the explicit
    ``dtype`` or the result type of *all* operands (accumulands like
    ``c``/``y`` participate in the promotion, as they would in plain jnp);
    compute = the context's accumulation dtype (upcast) or the storage
    dtype.
    """
    acc = resolved_accum_dtype(ctx)
    if dtype is None and acc is None:
        return None, None
    arrs = [a for a in arrays if a is not None]
    store = jnp.dtype(dtype) if dtype is not None else jnp.result_type(*arrs)
    comp = jnp.dtype(acc) if acc is not None else store
    return store, comp


def _cast(x, to):
    if x is None:
        return None
    x = jnp.asarray(x)
    if to is None or x.dtype == to:
        return x
    return x.astype(to)


def _kw(ctx):
    """Context fields -> the kwargs every numeric core takes."""
    return dict(policy=resolved_policy(ctx), interpret=resolved_interpret(ctx),
                registry=resolved_registry(ctx))


# -------------------------------- level 3 -----------------------------------

@_routine("gemm", _gemm_info)
def gemm(a, b, c=None, alpha=1.0, beta=0.0, transa: bool = False,
         transb: bool = False, dtype=None, context=None) -> jnp.ndarray:
    """C <- alpha * op(A) op(B) + beta * C, any supported dtype.

    2-D operands run the policy-dispatched local kernel path; with a mesh
    in the active context they run SUMMA ``pdgemm`` instead. 3-D operands
    (leading batch axis) vmap the local path. Oracle:
    ``tests/test_linalg.py`` / ``tests/test_differential_blas.py``.
    """
    ctx = current(context)
    store, comp = _dtypes(ctx, dtype, a, b, c)
    a_, b_, c_ = _cast(a, comp), _cast(b, comp), _cast(c, comp)
    if a_.ndim == 3:
        kw = _kw(ctx)
        f = lambda x, y: _l3.gemm(x, y, transa=transa, transb=transb, **kw)
        out = jax.vmap(f)(a_, b_)
        out = alpha * out
        if c_ is not None:
            out = out + beta * c_
        return _cast(out, store)
    mesh = resolved_mesh(ctx)
    if mesh is not None:
        from repro.blas import distributed as _dist
        op_a = a_.T if transa else a_
        op_b = b_.T if transb else b_
        out = _dist.pdgemm(op_a, op_b, mesh, c=c_, alpha=alpha, beta=beta,
                           **_kw(ctx))
        return _cast(out, store)
    out = _l3.gemm(a_, b_, c=c_, alpha=alpha, beta=beta, transa=transa,
                   transb=transb, **_kw(ctx))
    return _cast(out, store)


@_routine("gemm_bias_act", _gemm_bias_act_info)
def gemm_bias_act(a, b, bias=None, epilogue: str = "none", dtype=None,
                  context=None) -> jnp.ndarray:
    """C = act(A B + bias): GEMM with a streamed bias/activation epilogue.

    Under the kernel policies the whole chain resolves as the
    ``"gemm+epilogue"`` op: one fused Pallas launch when
    :func:`repro.core.codesign.plan_fused_chain` says streaming wins,
    else the staged kernel + epilogue pass. Always local (no mesh
    backend); 3-D operands vmap the local path with a shared ``bias``.
    Oracle: ``tests/test_fusion.py``.
    """
    ctx = current(context)
    store, comp = _dtypes(ctx, dtype, a, b, bias)
    a_, b_, bias_ = _cast(a, comp), _cast(b, comp), _cast(bias, comp)
    kw = _kw(ctx)
    if a_.ndim == 3:
        f = lambda x, y: _l3.gemm_bias_act(x, y, bias=bias_,
                                           epilogue=epilogue, **kw)
        return _cast(jax.vmap(f)(a_, b_), store)
    out = _l3.gemm_bias_act(a_, b_, bias=bias_, epilogue=epilogue, **kw)
    return _cast(out, store)


@_routine("syrk", _syrk_info)
def syrk(a, c=None, alpha=1.0, beta=0.0, lower: bool = True,
         trans: bool = False, dtype=None, context=None) -> jnp.ndarray:
    """C <- alpha op(A) op(A)^T + beta C, symmetric output.

    Under a mesh the product runs through SUMMA ``pdgemm`` before the
    triangle mirror; locally it shares the GEMM kernel path (and its
    registry entries).
    """
    ctx = current(context)
    store, comp = _dtypes(ctx, dtype, a, c)
    a_, c_ = _cast(a, comp), _cast(c, comp)
    mesh = resolved_mesh(ctx)
    if mesh is not None and a_.ndim == 2:
        from repro.blas import distributed as _dist
        op_a = a_.T if trans else a_
        full = alpha * _dist.pdgemm(op_a, op_a.T, mesh, **_kw(ctx))
        if c_ is not None:
            full = full + beta * c_
        return _cast(_l3.mirror_triangle(full, lower), store)
    kw = _kw(ctx)
    if a_.ndim == 3:
        f = lambda x, y: _l3.syrk(x, c=y, alpha=alpha, beta=beta,
                                  lower=lower, trans=trans, **kw)
        out = jax.vmap(f)(a_, c_) if c_ is not None else jax.vmap(
            lambda x: _l3.syrk(x, alpha=alpha, lower=lower, trans=trans,
                               **kw))(a_)
        return _cast(out, store)
    out = _l3.syrk(a_, c=c_, alpha=alpha, beta=beta, lower=lower,
                   trans=trans, **kw)
    return _cast(out, store)


@_routine("trsm", _trsm_info)
def trsm(a, b, lower: bool = True, unit_diag: bool = False,
         left: bool = True, block: Optional[int] = None, dtype=None,
         context=None) -> jnp.ndarray:
    """Solve op(T) X = B (or X op(T) = B), blocked, any supported dtype.

    Under a mesh the right-hand-side columns are sharded via ``pdtrsm``;
    locally the off-diagonal GEMM updates follow the context policy onto
    the kernel path. 3-D operands vmap the local path.
    """
    ctx = current(context)
    store, comp = _dtypes(ctx, dtype, a, b)
    a_, b_ = _cast(a, comp), _cast(b, comp)
    kw = _kw(ctx)
    if a_.ndim == 3:
        f = lambda t, r: _l3.trsm(t, r, lower=lower, unit_diag=unit_diag,
                                  left=left, block=block, **kw)
        return _cast(jax.vmap(f)(a_, b_), store)
    mesh = resolved_mesh(ctx)
    if mesh is not None:
        from repro.blas import distributed as _dist
        out = _dist.pdtrsm(a_, b_, mesh, lower=lower, unit_diag=unit_diag,
                           left=left, block=block, **kw)
        return _cast(out, store)
    out = _l3.trsm(a_, b_, lower=lower, unit_diag=unit_diag, left=left,
                   block=block, **kw)
    return _cast(out, store)


# -------------------------------- level 2 -----------------------------------

@_routine("gemv", _gemv_info)
def gemv(a, x, y=None, alpha=1.0, beta=0.0, trans: bool = False,
         dtype=None, context=None) -> jnp.ndarray:
    """y <- alpha*op(A) x + beta*y. Kernel policies run op(A) x through
    the Pallas GEMM path (shared registry entries); no mesh backend -
    always local. 3-D a / 2-D x vmap over the batch axis."""
    ctx = current(context)
    store, comp = _dtypes(ctx, dtype, a, x, y)
    a_, x_, y_ = _cast(a, comp), _cast(x, comp), _cast(y, comp)
    kw = _kw(ctx)
    if a_.ndim == 3:
        f = lambda m, v: _l2.gemv(m, v, trans=trans, **kw)
        out = alpha * jax.vmap(f)(a_, x_)
        if y_ is not None:
            out = out + beta * y_
        return _cast(out, store)
    out = _l2.gemv(a_, x_, y=y_, alpha=alpha, beta=beta, trans=trans, **kw)
    return _cast(out, store)


@_routine("ger", _ger_info)
def ger(alpha, x, y, a, dtype=None, context=None) -> jnp.ndarray:
    """A <- alpha * x y^T + A (rank-1 update, pure jnp)."""
    ctx = current(context)
    store, comp = _dtypes(ctx, dtype, x, y, a)
    out = _l2.ger(alpha, _cast(x, comp), _cast(y, comp), _cast(a, comp))
    return _cast(out, store)


@_routine("trsv", _trsv_info)
def trsv(a, b, lower: bool = True, unit_diag: bool = False, dtype=None,
         context=None) -> jnp.ndarray:
    """Solve op(T) x = b via the row-sequential scan (the divider-hazard
    chain); the blocked, policy-dispatched form is :func:`trsm`."""
    ctx = current(context)
    store, comp = _dtypes(ctx, dtype, a, b)
    out = _l2.trsv(_cast(a, comp), _cast(b, comp), lower=lower,
                   unit_diag=unit_diag)
    return _cast(out, store)


# -------------------------------- level 1 -----------------------------------

@_routine("dot", _vec_info(2))
def dot(x, y, schedule: str = "tree", accumulators: int = 8, dtype=None,
        context=None) -> jnp.ndarray:
    """Inner product with an explicit reduction schedule
    (tree/sequential/strided) - see :func:`repro.blas.level1.dot`.
    ``accum_dtype`` in the context upcasts the whole reduction."""
    ctx = current(context)
    store, comp = _dtypes(ctx, dtype, x, y)
    out = _l1.dot(_cast(x, comp), _cast(y, comp), schedule=schedule,
                  accumulators=accumulators)
    return _cast(out, store)


@_routine("axpy", _vec_info(2))
def axpy(alpha, x, y, dtype=None, context=None) -> jnp.ndarray:
    """y <- alpha*x + y."""
    ctx = current(context)
    store, comp = _dtypes(ctx, dtype, x, y)
    return _cast(_l1.axpy(alpha, _cast(x, comp), _cast(y, comp)), store)


@_routine("scal", _vec_info(1))
def scal(alpha, x, dtype=None, context=None) -> jnp.ndarray:
    """x <- alpha*x."""
    ctx = current(context)
    store, comp = _dtypes(ctx, dtype, x)
    return _cast(_l1.scal(alpha, _cast(x, comp)), store)


@_routine("nrm2", _vec_info(2))
def nrm2(x, dtype=None, context=None) -> jnp.ndarray:
    """Overflow-safe Euclidean norm."""
    ctx = current(context)
    store, comp = _dtypes(ctx, dtype, x)
    return _cast(_l1.nrm2(_cast(x, comp)), store)


@_routine("asum", _vec_info(1))
def asum(x, dtype=None, context=None) -> jnp.ndarray:
    """Sum of absolute values."""
    ctx = current(context)
    store, comp = _dtypes(ctx, dtype, x)
    return _cast(_l1.asum(_cast(x, comp)), store)


@_routine("iamax", _vec_info(1))
def iamax(x, context=None) -> jnp.ndarray:
    """Index of the first max-|x| element (0-based int; no dtype cast)."""
    return _l1.iamax(jnp.asarray(x))


@_routine("rot", _vec_info(6))
def rot(x, y, c, s, dtype=None, context=None):
    """Apply a Givens rotation: (c*x + s*y, c*y - s*x)."""
    ctx = current(context)
    store, comp = _dtypes(ctx, dtype, x, y)
    gx, gy = _l1.rot(_cast(x, comp), _cast(y, comp), c, s)
    return _cast(gx, store), _cast(gy, store)
