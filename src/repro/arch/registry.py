"""Named machine registry + the ambient "current machine" scope.

Built-in machines:

``"tpu-like"``
    The default. Numerically identical to the historical module constants
    in :mod:`repro.core.codesign` (TPU v5e assumptions), so every planner
    output under the default machine is bit-identical to the
    pre-``repro.arch`` behavior. Native dtype bfloat16 - the width the
    peak is quoted at and the planners' dtype default.
``"paper-pe"``
    The paper's PE/APE-based accelerator: the section-5 pipeline depths
    (mul 5 / add 4 / div 12 / sqrt 14), the Hartstein-Puzak technology
    constants of :mod:`repro.core.characterization`, a small local
    memory, double-precision native, and the power/area point at which
    the paper reports its 1.1-1.5x Gflops/W and 1.9-2.1x Gflops/mm^2
    advantage over custom BLAS/LAPACK realizations.
``"cpu-host"``
    A host-CPU-shaped machine (SIMD lanes instead of a systolic array,
    DDR-class bandwidth) - the container this repo actually runs on.

The *current* machine is dynamically scoped (contextvars, so threads and
asyncio tasks are isolated): :func:`machine_scope` nests, and
:func:`set_default_machine` replaces the process default under every
scope. ``repro.linalg`` routines enter a scope from their resolved
ExecutionContext, so every nested planner/tuner resolution - trailing
updates inside a blocked factorization included - sees the context's
machine without any kwarg threading.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Dict, Iterator, Optional, Tuple, Union

from repro.arch.spec import (FPUSpec, MachineSpec, MemorySpec, PEGeometry,
                             PowerAreaSpec)

DEFAULT_MACHINE = "tpu-like"

# --------------------------- built-in machines ------------------------------

TPU_LIKE = MachineSpec(
    name="tpu-like",
    native_dtype="bfloat16",
    fpu=FPUSpec(
        # fixed-silicon effective latencies; add=6 is the dependent
        # FP-add chain latency the accumulator planner fills (eq. 3)
        depths={"mul": 5, "add": 6, "div": 12, "sqrt": 14},
        t_p={"mul": 60.0, "add": 40.0, "div": 160.0, "sqrt": 200.0},
        t_o=1.0,
        gamma={"mul": 0.5, "add": 0.5, "div": 0.8, "sqrt": 0.9},
        acc_overhead=0.75,
    ),
    memory=MemorySpec(hbm_bw=819e9, vmem_bytes=96 * 2 ** 20, ici_bw=50e9,
                      hbm_bytes=16 * 2 ** 30, pipeline_fill_s=2e-6),
    pe=PEGeometry(mxu=128, sublane=8, lane=128, vreg_budget=64,
                  peak_flops=197e12),
    power_area=PowerAreaSpec(
        pj_per_flop={"mul": 0.55, "add": 0.25, "div": 4.0, "sqrt": 5.0},
        pj_per_byte_hbm=30.0, static_w=60.0, area_mm2=300.0),
)

PAPER_PE = MachineSpec(
    name="paper-pe",
    native_dtype="float64",
    fpu=FPUSpec(
        # section-5 experimental optimum: deep hazard-free mul/add pipes,
        # shallow serial div/sqrt pipes
        depths={"mul": 5, "add": 4, "div": 12, "sqrt": 14},
        t_p={"mul": 60.0, "add": 40.0, "div": 160.0, "sqrt": 200.0},
        t_o=1.0,
        gamma={"mul": 0.5, "add": 0.5, "div": 0.8, "sqrt": 0.9},
        acc_overhead=0.75,
    ),
    memory=MemorySpec(hbm_bw=256e9, vmem_bytes=4 * 2 ** 20, ici_bw=25e9,
                      hbm_bytes=8 * 2 ** 30, pipeline_fill_s=1e-6),
    pe=PEGeometry(mxu=32, sublane=4, lane=32, vreg_budget=32,
                  peak_flops=8e12),
    power_area=PowerAreaSpec(
        pj_per_flop={"mul": 0.5, "add": 0.3, "div": 3.0, "sqrt": 3.5},
        pj_per_byte_hbm=25.0, static_w=1.1, area_mm2=6.1),
)

CPU_HOST = MachineSpec(
    name="cpu-host",
    native_dtype="float32",
    fpu=FPUSpec(
        depths={"mul": 4, "add": 4, "div": 14, "sqrt": 18},
        t_p={"mul": 60.0, "add": 40.0, "div": 160.0, "sqrt": 200.0},
        t_o=1.0,
        gamma={"mul": 0.5, "add": 0.5, "div": 0.8, "sqrt": 0.9},
        acc_overhead=0.5,
    ),
    memory=MemorySpec(hbm_bw=80e9, vmem_bytes=2 * 2 ** 20, ici_bw=10e9,
                      hbm_bytes=64 * 2 ** 30, pipeline_fill_s=5e-6),
    pe=PEGeometry(mxu=16, sublane=1, lane=16, vreg_budget=32,
                  peak_flops=2e12),
    power_area=PowerAreaSpec(
        pj_per_flop={"mul": 8.0, "add": 6.0, "div": 30.0, "sqrt": 40.0},
        pj_per_byte_hbm=60.0, static_w=30.0, area_mm2=200.0),
)

_REGISTRY: Dict[str, MachineSpec] = {
    m.name: m for m in (TPU_LIKE, PAPER_PE, CPU_HOST)
}


def register(spec: MachineSpec, overwrite: bool = False) -> MachineSpec:
    """Add a machine to the named registry (``overwrite=True`` to replace)."""
    if not isinstance(spec, MachineSpec):
        raise TypeError(f"register() takes a MachineSpec, "
                        f"got {type(spec).__name__}")
    if spec.name in _REGISTRY and not overwrite:
        raise ValueError(f"machine {spec.name!r} already registered "
                         f"(pass overwrite=True to replace)")
    _REGISTRY[spec.name] = spec
    return spec


def get(name: str) -> MachineSpec:
    """Look up a registered machine by name; ``ValueError`` (listing the
    known names) on an unknown one."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown machine {name!r}; registered machines: "
                         f"{names()}") from None


def names() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# ------------------------- ambient current machine --------------------------

_process_default: Optional[MachineSpec] = None
_scope: "contextvars.ContextVar[Optional[MachineSpec]]" = \
    contextvars.ContextVar("repro_arch_machine", default=None)


def _as_spec(machine: Union[MachineSpec, str, None]) -> Optional[MachineSpec]:
    if machine is None or isinstance(machine, MachineSpec):
        return machine
    if isinstance(machine, str):
        return get(machine)
    raise TypeError(f"machine must be a MachineSpec, a registered name, or "
                    f"None; got {type(machine).__name__}")


def current_machine() -> MachineSpec:
    """The active machine: innermost :func:`machine_scope`, else the
    :func:`set_default_machine` process default, else ``"tpu-like"``."""
    scoped = _scope.get()
    if scoped is not None:
        return scoped
    if _process_default is not None:
        return _process_default
    return _REGISTRY[DEFAULT_MACHINE]


@contextlib.contextmanager
def machine_scope(machine: Union[MachineSpec, str, None]) -> Iterator[MachineSpec]:
    """Scope the current machine: ``with arch.machine_scope("paper-pe"):``.

    ``None`` pins the scope back to the process default (an explicit
    reset for code that must ignore enclosing scopes). Note that
    ``repro.linalg`` routines only enter a scope when their context sets
    a machine - a default-context call *inherits* whatever scope is
    active, so wrapping linalg calls in ``machine_scope`` works the way
    an ambient scope should.
    """
    token = _scope.set(_as_spec(machine))
    try:
        yield current_machine()
    finally:
        _scope.reset(token)


def set_default_machine(machine: Union[MachineSpec, str, None]) -> MachineSpec:
    """Replace the process-default machine (``None`` resets to
    ``"tpu-like"``); scopes layer on top."""
    global _process_default
    _process_default = _as_spec(machine)
    return current_machine()


def resolve_machine(machine: Union[MachineSpec, str, None] = None) -> MachineSpec:
    """A ``machine=`` argument as a MachineSpec: names looked up, ``None``
    resolved to the ambient :func:`current_machine`. The one helper every
    planner/tuner entry point shares."""
    if machine is None:
        return current_machine()
    spec = _as_spec(machine)
    return spec if spec is not None else current_machine()


def machine_key_component(machine: Union[MachineSpec, str, None]) -> Optional[str]:
    """The tune-registry key component for a machine: ``None`` for the
    default machine (so pre-arch registry files keep resolving unchanged),
    the machine name otherwise. Recording and lookup must share this rule,
    or tuned entries land in a different namespace than dispatch reads."""
    mach = resolve_machine(machine)
    return None if mach.name == DEFAULT_MACHINE else mach.name
