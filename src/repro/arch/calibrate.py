"""Measured-machine calibration: fit a MachineSpec from micro-benchmarks.

Every machine in :mod:`repro.arch.registry` is hand-declared; this module
closes the ELAPS-style loop (arXiv:1504.08035, 1209.2364) for the backend
the process is actually running on. A small micro-benchmark suite runs
under the adaptive repetition controller of :mod:`repro.tune.measure`:

* **GEMM ladder** - square f32 matmuls of increasing size; the best
  sustained flop rate fits ``PEGeometry.peak_flops`` (the MXU/SIMD
  throughput term every roofline in the repo prices against).
* **Streaming copy + reduction** - large-array traversals; the best
  sustained byte rate fits ``MemorySpec.hbm_bw`` (the HBM-class bandwidth
  term of the roofline).
* **Dependent chains per op class** - a loop-carried mul / add / div /
  sqrt chain exposes each class's effective dependent-op latency exactly
  like an under-filled pipeline (the paper's eq.-2 hazard term); the
  measured latency ratios, anchored at the base spec's multiplier depth,
  fit ``FPUSpec.depths``.

The fitted sections replace their counterparts in a *base* spec (default:
``cpu-host`` for the CPU backend) - power/area stays the base's, since
wall-clock micro-benchmarks cannot observe pJ/flop or die area - and the
result is a frozen, JSON-serializable :class:`~repro.arch.spec.MachineSpec`
named ``calibrated-<backend>`` that is registered into the machine
registry (``arch.get("calibrated-cpu")``) and can round-trip through
``save``/``load`` like any other spec.

By construction the fitted machine's modeled time for the *best* rung of
the GEMM ladder and of the stream suite equals the measured median (the
fit is that rung's rate); :data:`CALIBRATION_TOLERANCE` is the documented
band within which those modeled-vs-measured residuals must stay for a
calibration to be considered sane (see ``docs/benchmarking.md``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple

from repro.arch import registry as _registry
from repro.arch.spec import MachineSpec, OP_CLASSES

# |model_residual| band the best-rung micro-bench rows must satisfy for a
# calibration to be accepted as self-consistent (documented tolerance of
# the acceptance loop; the best rungs are exact fits up to rep noise, so
# this bounds measurement spread, not model error).
CALIBRATION_TOLERANCE = 0.35

# fitted pipeline depths are clamped into this range: >= 1 by FPUSpec's
# validation, <= 64 so one noisy chain sample cannot declare an absurd pipe
_DEPTH_RANGE = (1, 64)


@dataclasses.dataclass(frozen=True)
class CalibrationResult:
    """A fitted machine plus the micro-bench evidence behind it.

    ``report`` rows carry ``{"bench", "params", "seconds_median",
    "seconds_spread", "reps", "modeled_s", "model_residual"}`` - the same
    timing-field convention as every benchmark JSON row, with ``modeled_s``
    computed *under the fitted machine* so the residuals say how well the
    calibrated spec explains its own evidence.
    """

    machine: MachineSpec
    report: Tuple[Dict[str, Any], ...]
    backend: str

    def best_residual(self, bench: str) -> float:
        """Smallest |model_residual| over the rows of one bench family."""
        rs = [abs(r["model_residual"]) for r in self.report
              if r["bench"] == bench]
        if not rs:
            raise ValueError(f"no report rows for bench {bench!r}")
        return min(rs)

    def to_json(self) -> Dict[str, Any]:
        return {"backend": self.backend, "machine": self.machine.to_json(),
                "report": [dict(r) for r in self.report]}


def _measure_mod():
    # lazy: repro.tune imports repro.arch at package-import time, so the
    # arch package cannot import repro.tune back at module level
    from repro.tune import measure
    return measure


def run_microbenchmarks(gemm_sizes: Sequence[int] = (64, 128, 256),
                        stream_elems: int = 1 << 22,
                        chain_iters: int = 256,
                        reps: Optional[int] = None,
                        min_reps: int = 3, max_reps: int = 10,
                        rel_spread: float = 0.2) -> Dict[str, Any]:
    """Run the calibration suite on the running backend.

    Returns raw evidence: per-rung GEMM measurements (+ flops), the two
    stream measurements (+ bytes), and the per-op-class dependent-chain
    latencies. All timing goes through the adaptive controller
    (``reps=N`` pins exact rep counts for deterministic duration).
    """
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax import lax

    meas = _measure_mod()
    kw = dict(reps=reps, min_reps=min_reps, max_reps=max_reps,
              rel_spread=rel_spread)

    rng = np.random.default_rng(0)
    gemm = []
    for n in gemm_sizes:
        a = jnp.asarray(rng.normal(size=(n, n)).astype(np.float32))
        b = jnp.asarray(rng.normal(size=(n, n)).astype(np.float32))
        m = meas.measure(jax.jit(lambda x, y: x @ y), a, b, **kw)
        gemm.append({"n": int(n), "flops": 2.0 * n ** 3, "measurement": m})

    itemsize = 4
    x = jnp.asarray(rng.normal(size=int(stream_elems)).astype(np.float32))
    stream = []
    # copy: one read + one write stream; reduction: one read stream
    m = meas.measure(jax.jit(lambda v: v + jnp.float32(0.0)), x, **kw)
    stream.append({"kind": "copy", "bytes": 2 * int(stream_elems) * itemsize,
                   "measurement": m})
    m = meas.measure(jax.jit(jnp.sum), x, **kw)
    stream.append({"kind": "reduction", "bytes": int(stream_elems) * itemsize,
                   "measurement": m})

    # loop-carried dependent chains: per iteration exactly one op of the
    # class on an 8-lane value, latency-bound by construction
    c = jnp.float32(1.0000001)
    chain_body = {
        "mul": lambda i, v: v * c,
        "add": lambda i, v: v + c,
        "div": lambda i, v: v / c,
        "sqrt": lambda i, v: jnp.sqrt(v) + jnp.float32(0.5),
    }
    v0 = jnp.full((8,), 2.0, dtype=jnp.float32)
    chains = {}
    for cls in OP_CLASSES:
        f = jax.jit(lambda v, body=chain_body[cls]: lax.fori_loop(
            0, int(chain_iters), body, v))
        m = meas.measure(f, v0, **kw)
        chains[cls] = {"iters": int(chain_iters), "measurement": m,
                       "latency_s": m.seconds_median / int(chain_iters)}

    return {"backend": jax.default_backend(), "gemm": gemm,
            "stream": stream, "chains": chains}


def _fit_depths(chains: Mapping[str, Mapping[str, Any]],
                base: MachineSpec) -> Dict[str, int]:
    """Effective pipeline depth per op class from dependent-chain latency
    ratios, anchored at the base spec's multiplier depth (wall-clock alone
    fixes ratios, not the cycle time)."""
    lat = {k: float(chains[k]["latency_s"]) for k in OP_CLASSES}
    anchor = base.fpu.depths["mul"] / max(lat["mul"], 1e-12)
    lo, hi = _DEPTH_RANGE
    return {k: min(max(int(round(lat[k] * anchor)), lo), hi)
            for k in OP_CLASSES}


def fit_machine(results: Mapping[str, Any],
                base: Optional[MachineSpec] = None,
                name: Optional[str] = None) -> MachineSpec:
    """Fit FPU/Memory/PE parameters from :func:`run_microbenchmarks`
    evidence into a copy of ``base`` (default: ``cpu-host`` on the CPU
    backend, ``tpu-like`` otherwise)."""
    backend = results["backend"]
    if base is None:
        base = _registry.get("cpu-host" if backend == "cpu" else "tpu-like")
    name = name or f"calibrated-{backend}"

    peak = max(r["flops"] / r["measurement"].seconds_median
               for r in results["gemm"])
    bw = max(r["bytes"] / r["measurement"].seconds_median
             for r in results["stream"])
    depths = _fit_depths(results["chains"], base)

    return MachineSpec(
        name=name,
        native_dtype="float32",          # the dtype the suite measured at
        fpu=dataclasses.replace(base.fpu, depths=depths),
        memory=dataclasses.replace(base.memory, hbm_bw=float(bw)),
        pe=dataclasses.replace(base.pe, peak_flops=float(peak)),
        power_area=base.power_area,      # not observable from wall clock
    )


def _report(results: Mapping[str, Any],
            machine: MachineSpec) -> Tuple[Dict[str, Any], ...]:
    """Modeled-vs-measured rows for the fitted machine, in the shared
    bench-row field convention."""
    meas = _measure_mod()
    peak = machine.pe.peak_flops
    bw = machine.memory.hbm_bw
    rows = []

    def row(bench, params, m, modeled_s):
        rows.append({"bench": bench, "params": params, **m.row_fields(),
                     "converged": m.converged, "modeled_s": modeled_s,
                     "model_residual": meas.model_residual(
                         modeled_s, m.seconds_median)})

    for r in results["gemm"]:
        n = r["n"]
        ai = r["flops"] / (3.0 * n * n * 4)         # A, B in; C out (f32)
        row("gemm", {"n": n}, r["measurement"],
            r["flops"] / min(peak, ai * bw))
    for r in results["stream"]:
        row("stream", {"kind": r["kind"]}, r["measurement"],
            r["bytes"] / bw)
    anchor_lat = results["chains"]["mul"]["latency_s"] \
        / machine.fpu.depths["mul"]
    for cls in OP_CLASSES:
        c = results["chains"][cls]
        row("chain", {"op_class": cls, "iters": c["iters"]},
            c["measurement"],
            machine.fpu.depths[cls] * anchor_lat * c["iters"])
    return tuple(rows)


def calibrate_full(backend: Optional[str] = None,
                   base: Optional[MachineSpec] = None,
                   name: Optional[str] = None, *,
                   register: bool = True, overwrite: bool = True,
                   path: Optional[str] = None,
                   **bench_kwargs) -> CalibrationResult:
    """Run the suite, fit a machine, register it, and return machine +
    evidence report. ``bench_kwargs`` forward to
    :func:`run_microbenchmarks` (sizes / rep budgets - tests shrink them).
    ``path`` additionally writes the fitted spec's JSON there.
    """
    import jax
    got = jax.default_backend()
    if backend is not None and backend != got:
        raise ValueError(f"cannot calibrate backend {backend!r} from a "
                         f"process running on {got!r}")
    results = run_microbenchmarks(**bench_kwargs)
    machine = fit_machine(results, base=base, name=name)
    if register:
        _registry.register(machine, overwrite=overwrite)
    if path is not None:
        machine.save(path)
    return CalibrationResult(machine=machine, report=_report(results, machine),
                             backend=results["backend"])


def calibrate(backend: Optional[str] = None,
              base: Optional[MachineSpec] = None,
              name: Optional[str] = None, *,
              register: bool = True, overwrite: bool = True,
              path: Optional[str] = None,
              **bench_kwargs) -> MachineSpec:
    """Measure the running backend and return the fitted, registered
    ``calibrated-<backend>`` :class:`MachineSpec` (the ``arch.calibrate()``
    entry point; :func:`calibrate_full` keeps the evidence report)."""
    return calibrate_full(backend, base, name, register=register,
                          overwrite=overwrite, path=path,
                          **bench_kwargs).machine


def load_or_calibrate(path: str, **calibrate_kwargs) -> MachineSpec:
    """The persistence convention for calibrated machines: load ``path``
    and register the spec if the file is a valid MachineSpec JSON;
    on a missing *or corrupt* file fall back to a fresh
    :func:`calibrate` run and write its result to ``path``."""
    try:
        spec = MachineSpec.load(path)
    except (OSError, ValueError):
        return calibrate(path=path, **calibrate_kwargs)
    register = calibrate_kwargs.get("register", True)
    if register:
        _registry.register(spec, overwrite=True)
    return spec
