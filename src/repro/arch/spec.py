"""Machine/FPU architecture specs - the paper's design space as data.

The paper's contribution is that FPU micro-architecture parameters - the
per-op-class pipeline depths (multiplier / adder / square root / divider),
the PE compute geometry, and the memory hierarchy - determine BLAS/LAPACK
performance, and it scores candidate designs in Gflops/W and Gflops/mm^2.
This module makes that parameter space a first-class, frozen, serializable
value:

``FPUSpec``
    Per-op-class pipeline depths plus the eq.-2 technology constants
    (``t_p`` latch-free logic delay, ``t_o`` latch overhead, ``gamma``
    exposed-hazard fraction). Feeds :func:`repro.core.pipeline_model.tpi`
    and the eq.-3 closed-form ``p_opt`` directly.
``MemorySpec``
    HBM / VMEM / inter-chip bandwidths and capacities, plus the per
    grid-step software-pipeline fill cost the planners price.
``PEGeometry``
    Systolic-array edge, VPU sublanes/lanes, vector-register budget, and
    peak FLOP rate (clock and vector peak are derived).
``PowerAreaSpec``
    Per-op-class dynamic energy (pJ/flop), HBM access energy, static
    power, and die area - so any plan or benchmark row reports *modeled*
    Gflops/W and Gflops/mm^2, the paper's two scoring axes.
``MachineSpec``
    The frozen composition of the four, with a name, a native compute
    dtype (the planners' dtype default), and JSON (de)serialization.

Everything here is standalone (no imports from the rest of ``repro``), so
every planner, tuner, and benchmark can depend on it without cycles. Named
instances live in :mod:`repro.arch.registry`.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Mapping, Optional

import numpy as np

# The paper's four floating-point instruction classes, K = {M, A, S, D}.
OP_CLASSES = ("mul", "add", "div", "sqrt")

SCHEMA_VERSION = 1


def _np_dtype(name) -> "np.dtype":
    """np.dtype with the extended (ml_dtypes) names jax uses - plain numpy
    does not know ``bfloat16``."""
    try:
        return np.dtype(name)
    except TypeError:
        import jax.numpy as jnp
        return jnp.dtype(name)


def _class_map(value, name: str, cast) -> Dict[str, Any]:
    """Validate/normalize a per-op-class mapping (exactly OP_CLASSES keys)."""
    if not isinstance(value, Mapping):
        raise ValueError(f"{name} must be a mapping over {OP_CLASSES}, "
                         f"got {type(value).__name__}")
    got = set(value)
    if got != set(OP_CLASSES):
        raise ValueError(f"{name} must have exactly the op classes "
                         f"{OP_CLASSES}; got {sorted(got)}")
    return {k: cast(value[k]) for k in OP_CLASSES}


@dataclasses.dataclass(frozen=True)
class FPUSpec:
    """Floating-point unit micro-architecture (paper sections 3-4).

    Attributes
    ----------
    depths : per-op-class pipeline depth ``p`` (the experimental knob the
        paper sweeps in figs. 12-13; on fixed hardware, the effective
        dependent-op latency of each class).
    t_p : per-op-class latch-free logic delay (FO4-relative units, the
        Hartstein-Puzak convention the paper adopts).
    t_o : per-stage latch overhead for the technology node.
    gamma : per-op-class mean exposed fraction of the pipe delay per
        hazard (paper: gamma = (1/N_H) * sum beta_h).
    acc_overhead : issue slots of bookkeeping per extra software
        accumulator (the TPU adaptation's c_o term).
    """

    depths: Mapping[str, int]
    t_p: Mapping[str, float]
    t_o: float
    gamma: Mapping[str, float]
    acc_overhead: float = 0.75

    def __post_init__(self):
        object.__setattr__(self, "depths",
                           _class_map(self.depths, "depths", int))
        object.__setattr__(self, "t_p", _class_map(self.t_p, "t_p", float))
        object.__setattr__(self, "gamma",
                           _class_map(self.gamma, "gamma", float))
        if not float(self.t_o) > 0:
            raise ValueError(f"t_o must be positive, got {self.t_o!r}")
        for k, d in self.depths.items():
            if d < 1:
                raise ValueError(f"depths[{k!r}] must be >= 1, got {d}")

    @property
    def add_latency(self) -> int:
        """Dependent-add chain latency in cycles - the reduction-schedule
        knob (accumulator count U ~ this latency, paper eq. 3)."""
        return self.depths["add"]

    def pipe_params(self, op_class: str, n_i: float, n_h: float):
        """A :class:`repro.core.pipeline_model.PipeParams` for one op
        class of this FPU at a given workload census."""
        from repro.core.pipeline_model import PipeParams
        return PipeParams(n_i=float(n_i), n_h=float(n_h),
                          gamma=self.gamma[op_class],
                          t_p=self.t_p[op_class], t_o=self.t_o)

    def tpi(self, op_class: str, p, n_i: float, n_h: float):
        """Paper eq.-2 time-per-instruction of one pipe at depth ``p``."""
        from repro.core import pipeline_model
        return pipeline_model.tpi(p, n_i=float(n_i), n_h=float(n_h),
                                  gamma=self.gamma[op_class],
                                  t_p=self.t_p[op_class], t_o=self.t_o)

    def p_opt(self, op_class: str, n_i: float, n_h: float) -> float:
        """Paper eq.-3 closed-form optimal depth for one op class (+inf
        for hazard-free streams, the multiplier's flat curve)."""
        from repro.core import pipeline_model
        return float(pipeline_model.p_opt(
            n_i=float(n_i), n_h=float(n_h), gamma=self.gamma[op_class],
            t_p=self.t_p[op_class], t_o=self.t_o))

    def cycle_time(self, depths: Optional[Mapping[str, int]] = None,
                   used=OP_CLASSES) -> float:
        """Clock period = slowest pipe stage + latch overhead (the paper's
        equal-stage-time assumption across pipes)."""
        p = dict(self.depths)
        if depths:
            p.update({k: int(v) for k, v in depths.items()})
        stage = max(self.t_p[u] / p[u] for u in used) if used else 1.0
        return stage + self.t_o


@dataclasses.dataclass(frozen=True)
class MemorySpec:
    """Memory-hierarchy bandwidths and capacities the planners price.

    ``pipeline_fill_s`` is the per grid-step DMA/launch overhead of the
    software pipeline (fig. 2's unamortized-fill region, in seconds).
    """

    hbm_bw: float                 # bytes/s per chip
    vmem_bytes: int               # usable on-chip scratch budget
    ici_bw: float                 # bytes/s per inter-chip link
    hbm_bytes: Optional[int] = None   # HBM capacity (None = unmodeled)
    pipeline_fill_s: float = 2e-6

    def __post_init__(self):
        for f in ("hbm_bw", "vmem_bytes", "ici_bw"):
            if not float(getattr(self, f)) > 0:
                raise ValueError(f"{f} must be positive, "
                                 f"got {getattr(self, f)!r}")


@dataclasses.dataclass(frozen=True)
class PEGeometry:
    """Compute-resource structure of the processing element array.

    ``mxu`` is the systolic-array edge (matrix-unit tile = mxu x mxu);
    ``sublane``/``lane`` the vector-unit shape; ``peak_flops`` the chip's
    peak FLOP rate at the native dtype, from which the implied clock and
    the vector (non-matrix) peak are derived.
    """

    mxu: int
    sublane: int
    lane: int
    vreg_budget: int              # architectural vector registers
    peak_flops: float             # per chip, at the native dtype

    def __post_init__(self):
        for f in ("mxu", "sublane", "lane", "vreg_budget", "peak_flops"):
            if not float(getattr(self, f)) > 0:
                raise ValueError(f"{f} must be positive, "
                                 f"got {getattr(self, f)!r}")

    @property
    def mxu_clock(self) -> float:
        """Cycles/s implied by the peak rate (2*mxu^2 flops per cycle)."""
        return self.peak_flops / (2 * self.mxu * self.mxu)

    @property
    def vpu_flops(self) -> float:
        """Vector (non-matrix) peak: one lane-grid op per cycle."""
        return self.mxu_clock * self.sublane * self.lane


@dataclasses.dataclass(frozen=True)
class PowerAreaSpec:
    """Energy/area model: the paper's Gflops/W and Gflops/mm^2 axes.

    ``pj_per_flop`` is the per-op-class dynamic energy; the default FLOP
    mix is FMA-balanced (half multiplies, half adds), which is exact for
    GEMM-dominated BLAS-3/LAPACK workloads.
    """

    pj_per_flop: Mapping[str, float]
    pj_per_byte_hbm: float        # HBM access energy per byte
    static_w: float               # leakage + always-on power
    area_mm2: float               # die area

    def __post_init__(self):
        object.__setattr__(self, "pj_per_flop",
                           _class_map(self.pj_per_flop, "pj_per_flop", float))
        for f in ("pj_per_byte_hbm", "static_w", "area_mm2"):
            if float(getattr(self, f)) < 0:
                raise ValueError(f"{f} must be >= 0, "
                                 f"got {getattr(self, f)!r}")
        if not float(self.area_mm2) > 0:
            raise ValueError(f"area_mm2 must be positive, "
                             f"got {self.area_mm2!r}")

    def flop_energy_pj(self, mix: Optional[Mapping[str, float]] = None) -> float:
        """Weighted pJ/flop for a FLOP mix (fractions per op class);
        default is the FMA mix {mul: 0.5, add: 0.5}."""
        mix = dict(mix) if mix else {"mul": 0.5, "add": 0.5}
        total = sum(mix.values())
        if not total > 0:
            raise ValueError("flop mix must have positive total weight")
        return sum(self.pj_per_flop[k] * w for k, w in mix.items()) / total


@dataclasses.dataclass(frozen=True)
class MachineSpec:
    """A complete machine: FPU + memory + PE geometry + power/area.

    ``native_dtype`` is the dtype the machine's peak is quoted at and the
    planners' dtype default (the one shared place a bare planner call gets
    its operand width from - see
    :func:`repro.core.codesign.resolve_dtype_bytes`).
    """

    name: str
    fpu: FPUSpec
    memory: MemorySpec
    pe: PEGeometry
    power_area: PowerAreaSpec
    native_dtype: str = "float32"

    def __post_init__(self):
        if not self.name or not isinstance(self.name, str):
            raise ValueError(f"machine name must be a non-empty string, "
                             f"got {self.name!r}")
        try:
            _np_dtype(self.native_dtype)
        except TypeError as e:
            raise ValueError(f"unknown native_dtype "
                             f"{self.native_dtype!r}") from e

    # ------------------------------ dtypes ----------------------------------

    def dtype_bytes(self, dtype=None) -> int:
        """Itemsize of ``dtype``, defaulting to the native compute dtype."""
        return int(_np_dtype(dtype if dtype is not None
                              else self.native_dtype).itemsize)

    # --------------------------- modeled metrics ----------------------------

    @property
    def peak_gflops(self) -> float:
        return self.pe.peak_flops / 1e9

    def watts(self, gflops: float, hbm_bytes_per_s: float = 0.0,
              mix: Optional[Mapping[str, float]] = None) -> float:
        """Modeled power at a sustained FLOP rate + HBM traffic rate."""
        dynamic = gflops * self.power_area.flop_energy_pj(mix) * 1e-3
        hbm = hbm_bytes_per_s * self.power_area.pj_per_byte_hbm * 1e-12
        return dynamic + hbm + self.power_area.static_w

    def gflops_per_w(self, gflops: float, hbm_bytes_per_s: float = 0.0,
                     mix: Optional[Mapping[str, float]] = None) -> float:
        """The paper's energy-efficiency score at an achieved rate."""
        w = self.watts(gflops, hbm_bytes_per_s, mix)
        return gflops / w if w > 0 else float("inf")

    def gflops_per_mm2(self, gflops: float) -> float:
        """The paper's area-efficiency score at an achieved rate."""
        return gflops / self.power_area.area_mm2

    def peak_gflops_per_w(self) -> float:
        return self.gflops_per_w(self.peak_gflops)

    def peak_gflops_per_mm2(self) -> float:
        return self.gflops_per_mm2(self.peak_gflops)

    # ------------------------- JSON (de)serialization -----------------------

    def to_json(self) -> Dict[str, Any]:
        return {
            "schema": SCHEMA_VERSION,
            "name": self.name,
            "native_dtype": self.native_dtype,
            "fpu": {"depths": dict(self.fpu.depths),
                    "t_p": dict(self.fpu.t_p), "t_o": self.fpu.t_o,
                    "gamma": dict(self.fpu.gamma),
                    "acc_overhead": self.fpu.acc_overhead},
            "memory": dataclasses.asdict(self.memory),
            "pe": dataclasses.asdict(self.pe),
            "power_area": {"pj_per_flop": dict(self.power_area.pj_per_flop),
                           "pj_per_byte_hbm": self.power_area.pj_per_byte_hbm,
                           "static_w": self.power_area.static_w,
                           "area_mm2": self.power_area.area_mm2},
        }

    @classmethod
    def from_json(cls, blob: Mapping[str, Any]) -> "MachineSpec":
        """Rebuild a spec from :meth:`to_json` output.

        Raises ``ValueError`` on any malformed input (wrong schema,
        missing section, bad field) - callers reading files should treat
        that as a corrupt file.
        """
        if not isinstance(blob, Mapping):
            raise ValueError(f"machine spec must be a JSON object, "
                             f"got {type(blob).__name__}")
        if blob.get("schema") != SCHEMA_VERSION:
            raise ValueError(f"machine spec schema mismatch: want "
                             f"{SCHEMA_VERSION}, got {blob.get('schema')!r}")
        try:
            return cls(
                name=blob["name"],
                native_dtype=blob.get("native_dtype", "float32"),
                fpu=FPUSpec(**dict(blob["fpu"])),
                memory=MemorySpec(**dict(blob["memory"])),
                pe=PEGeometry(**dict(blob["pe"])),
                power_area=PowerAreaSpec(**dict(blob["power_area"])),
            )
        except (KeyError, TypeError) as e:
            raise ValueError(f"malformed machine spec: {e!r}") from e

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1)
        return path

    @classmethod
    def load(cls, path: str) -> "MachineSpec":
        """Load a spec from a JSON file; ``ValueError`` on a corrupt file
        (unparseable JSON or a malformed spec), ``OSError`` on a missing
        or unreadable one."""
        with open(path) as f:
            try:
                blob = json.load(f)
            except json.JSONDecodeError as e:
                raise ValueError(f"corrupt machine spec at {path}: {e}") from e
        return cls.from_json(blob)
