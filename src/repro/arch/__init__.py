"""repro.arch - first-class machine/FPU architecture specs.

The paper scores FPU micro-architectures (pipeline depths, PE structure,
memory hierarchy) in Gflops/W and Gflops/mm^2; this package is that design
space as a value type. A frozen :class:`MachineSpec` composes
:class:`FPUSpec` + :class:`MemorySpec` + :class:`PEGeometry` +
:class:`PowerAreaSpec`, serializes to JSON, and lives in a named registry::

    from repro import arch, linalg

    m = arch.get("paper-pe")               # or "tpu-like" (default), "cpu-host"
    plan = codesign.plan_gemm(4096, 4096, 4096, machine=m)

    with linalg.use(machine=m):            # machine flows context ->
        l = linalg.cholesky(spd)           #   planner -> tuner key -> kernel

    arch.register(my_spec)                 # custom designs join the registry
    m.gflops_per_w(achieved_gflops)        # the paper's scoring axes
    m.save("my_machine.json"); arch.MachineSpec.load("my_machine.json")

Every planner in :mod:`repro.core.codesign`, the tuner in
:mod:`repro.tune`, and every benchmark takes (or records) a machine; the
default machine ``"tpu-like"`` reproduces the historical module-constant
behavior bit-for-bit. See ``docs/machines.md``.
"""
from repro.arch.calibrate import (CALIBRATION_TOLERANCE, CalibrationResult,
                                  calibrate, calibrate_full,
                                  load_or_calibrate)
from repro.arch.registry import (CPU_HOST, DEFAULT_MACHINE, PAPER_PE,
                                 TPU_LIKE, current_machine, get,
                                 machine_key_component, machine_scope,
                                 names, register, resolve_machine,
                                 set_default_machine)
from repro.arch.spec import (OP_CLASSES, FPUSpec, MachineSpec, MemorySpec,
                             PEGeometry, PowerAreaSpec)

__all__ = [
    # spec types
    "MachineSpec", "FPUSpec", "MemorySpec", "PEGeometry", "PowerAreaSpec",
    "OP_CLASSES",
    # registry
    "get", "register", "names", "DEFAULT_MACHINE",
    # ambient machine scoping
    "current_machine", "machine_scope", "set_default_machine",
    "resolve_machine", "machine_key_component",
    # built-in specs
    "TPU_LIKE", "PAPER_PE", "CPU_HOST",
    # measured-machine calibration
    "calibrate", "calibrate_full", "load_or_calibrate",
    "CalibrationResult", "CALIBRATION_TOLERANCE",
    # benchmark helper
    "bench_metrics",
]


def bench_metrics(gflops: float, machine=None,
                  hbm_bytes_per_s: float = 0.0) -> dict:
    """The per-row machine fields every benchmark records.

    Returns ``{"machine", "gflops", "gflops_per_w", "gflops_per_mm2"}``
    for an achieved FLOP rate under ``machine`` (default: the ambient
    current machine) - modeled scores, the paper's two comparison axes.
    """
    m = resolve_machine(machine)
    g = float(gflops)
    return {"machine": m.name, "gflops": g,
            "gflops_per_w": m.gflops_per_w(g, hbm_bytes_per_s),
            "gflops_per_mm2": m.gflops_per_mm2(g)}
