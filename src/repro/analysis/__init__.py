"""repro.analysis - trace-time static verification of the linalg stack.

The paper's core claim is that performance (and correctness hazards) are
readable off static structure; this package enforces the repo's own half
of that bargain. ``check`` traces any routine from the ``repro.linalg``
surface with ``jax.make_jaxpr`` - no execution, no devices needed - and
verifies a frozen, ID'd rule vocabulary over the result:

======  =====================  ========================================
family  rules                  contract
======  =====================  ========================================
KL      KL001 KL002 KL003      Pallas launch geometry: block
        KL004                  divisibility, VMEM budget (the
                               FusedChainPlan veto), int32 index
                               dtypes under x64, zero-dim -> jnp
                               fallback routing
DF      DF001 DF002 DF003      dtype flow: no silent f64, f64
        DF004                  accumulators for f64 operands, no
                               narrowing convert round-trips, no host
                               transfers
CM      CM001 CM002 CM003      cost-model drift: span flops/bytes
                               annotations vs jaxpr_census counts
                               within declared tolerance; retrace
                               (jit cache key) stability
CC      CC001 CC002 CC003      collective schedules: ppermute perms are
                               bijective single-cycle rings; ring
                               hop counts are size - 1 and match the
                               jaxpr census and obs counters; on-wire
                               bytes agree with the counters and
                               plan_pdgemm's collective term
SH      SH001 SH002 SH003      sharding discipline: shard_map specs
                               consistent with shapes and mesh; ragged
                               batches identity-padded to device-count
                               multiples; no replication collectives
                               inside shard_map bodies
BY      BY001                  dispatcher bypass: raw dot_general/conv
                               contractions reachable from models,
                               kernels, or serving that never pass
                               tune.dispatch.resolve - burn-down
                               allowlisted, new sites fail CI
======  =====================  ========================================

Typical use::

    from repro import analysis, linalg

    rep = analysis.check(linalg.gemm, a, b)     # one routine
    assert rep.ok, rep.summary()

    rep = analysis.check_surface()              # full acceptance grid
    rep.save("analysis_report.json")

    with analysis.allow("CM002", routine="qr"):  # scoped suppression
        rep = analysis.check(linalg.qr, a)

CI runs ``scripts/check_static_analysis.py`` (wired into
``scripts/ci_check.sh``), which sweeps ``linalg.__all__`` and fails on
any unsuppressed ``error``. Rule IDs, ``AnalysisReport`` fields, and
this module's ``__all__`` are frozen by ``scripts/check_api_surface.py``.
See ``docs/static_analysis.md`` for the full vocabulary and suppression
workflow.
"""
from repro.analysis.bypass_lint import (collect_bypass_sites, lint_bypass,
                                        load_bypass_allowlist)
from repro.analysis.report import (AnalysisReport, check, check_distributed,
                                   check_routine, check_surface,
                                   merge_reports, surface_routines)
from repro.analysis.rules import (RULES, Allowlist, Finding, allow,
                                  load_allowlist)

__all__ = [
    "RULES", "Finding", "AnalysisReport",
    "check", "check_routine", "check_surface", "check_distributed",
    "surface_routines", "merge_reports", "allow", "Allowlist",
    "load_allowlist",
    "lint_bypass", "collect_bypass_sites", "load_bypass_allowlist",
]
