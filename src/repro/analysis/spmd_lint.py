"""SPMD lint over traced jaxprs and collective records (CC/SH rules).

PR 9's rule families stop at the device boundary; this pass looks inside
``shard_map``. Two complementary views, same pattern as
:mod:`repro.analysis.kernel_lint`:

* **jaxpr view** - walk the trace tracking the enclosing shard_map's
  mesh axis sizes, and check every ``ppermute`` permutation is a
  bijective single-cycle ring on its axis (CC001), every ``shard_map``
  eqn's in/out names are consistent with operand shapes and the mesh
  (SH001), and no collective inside a shard_map body re-replicates a
  sharded operand (SH003 - ``all_gather``/``all_to_all``).
* **record view** - the :class:`~repro.distributed.collectives
  .CollectiveRecord` stream ``ring_bcast``/``pdgemm``/``_pad_batch``
  emit at trace time, cross-checked against the jaxpr: hop census vs
  recorded hops and the ``collective.hops`` counter (CC002), on-wire
  bytes vs the ``collective.bytes`` counter *and* ``plan_pdgemm``'s
  collective term (CC003 - comm-cost drift, the distributed sibling of
  CM001), and ragged-batch identity-pad discipline (SH002).

Everything is trace-only: the records are emitted while shard_map traces
and the jaxpr census never executes, so the whole distributed leg of
``check_surface`` runs on a CPU host with forced devices.
"""
from __future__ import annotations

from typing import Dict, Iterator, List, Mapping, Optional, Sequence, Tuple

import jax.numpy as jnp

from repro.analysis import rules
from repro.analysis.jaxpr_lint import _source_location, _subjaxprs
from repro.analysis.rules import Finding, make_finding

# collectives that materialize a sharded operand on every participant
REPLICATING_PRIMITIVES = ("all_gather", "all_to_all")


def _aval_bytes(aval) -> int:
    shape = getattr(aval, "shape", None)
    dtype = getattr(aval, "dtype", None)
    if shape is None or dtype is None:
        return 0
    n = 1
    for d in shape:
        n *= int(d)
    try:
        return n * jnp.dtype(dtype).itemsize
    except TypeError:
        return 0


def _mesh_axis_sizes(mesh) -> Dict[str, int]:
    """{axis: size} from a Mesh (or any object with a .shape mapping)."""
    try:
        return {str(a): int(s) for a, s in dict(mesh.shape).items()}
    except Exception:
        return {}


def iter_spmd_eqns(jaxpr, axis_env: Optional[Mapping[str, int]] = None,
                   in_shard_map: bool = False
                   ) -> Iterator[Tuple[object, Dict[str, int], bool]]:
    """Yield (eqn, mesh-axis env, inside-shard_map) over all sub-jaxprs.

    The axis env accumulates the ``mesh`` params of enclosing shard_map
    eqns, so a ``ppermute`` deep inside pjit/scan bodies still knows the
    size of the axis it permutes over."""
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)           # accept ClosedJaxpr
    env = dict(axis_env or {})
    for eqn in jaxpr.eqns:
        yield eqn, env, in_shard_map
        inner_env = env
        inner_sm = in_shard_map
        if eqn.primitive.name == "shard_map":
            inner_sm = True
            mesh = eqn.params.get("mesh")
            if mesh is not None:
                inner_env = dict(env)
                inner_env.update(_mesh_axis_sizes(mesh))
        for value in eqn.params.values():
            for sub in _subjaxprs(value):
                yield from iter_spmd_eqns(sub, inner_env, inner_sm)


def _axis_names(eqn) -> Tuple[str, ...]:
    axes = eqn.params.get("axis_name")
    if axes is None:
        return ()
    if isinstance(axes, (tuple, list)):
        return tuple(str(a) for a in axes)
    return (str(axes),)


def _axis_key(eqn) -> str:
    return ",".join(_axis_names(eqn))


# ------------------------------- CC001 --------------------------------------

def lint_ppermute_eqn(eqn, axis_env: Mapping[str, int],
                      routine: Optional[str] = None) -> List[Finding]:
    """CC001: the permutation must be a bijective single-cycle ring."""
    findings: List[Finding] = []
    loc = _source_location(eqn)
    axes = _axis_names(eqn)
    size = 1
    size_known = bool(axes)
    for a in axes:
        if a in axis_env:
            size *= axis_env[a]
        else:
            size_known = False
    try:
        perm = [(int(s), int(d)) for s, d in eqn.params.get("perm", ())]
    except Exception:
        return findings                      # unknown param layout: skip

    def hit(msg):
        findings.append(make_finding(
            "CC001", f"ppermute over axis {_axis_key(eqn)!r}: {msg} "
            f"(perm={perm})", routine=routine, location=loc))

    self_sends = [p for p in perm if p[0] == p[1]]
    srcs = [s for s, _ in perm]
    dsts = [d for _, d in perm]
    if self_sends:
        hit(f"self-send pair(s) {self_sends} - a device sending to "
            "itself deadlocks the ring")
        return findings
    if len(set(srcs)) != len(srcs) or len(set(dsts)) != len(dsts):
        hit("duplicate source or destination - not a bijection")
        return findings
    if size_known and size > 1 and (set(srcs) != set(range(size))
                                    or set(dsts) != set(range(size))):
        hit(f"covers {len(perm)} of {size} ring members - a device "
            "outside the perm waits forever")
        return findings
    if perm:
        # bijective and covering: must be ONE cycle, not several
        nxt = dict(perm)
        seen = {perm[0][0]}
        cur = nxt[perm[0][0]]
        while cur not in seen and cur in nxt:
            seen.add(cur)
            cur = nxt[cur]
        if len(seen) != len(perm):
            hit(f"decomposes into multiple cycles ({len(seen)} of "
                f"{len(perm)} members reachable from {perm[0][0]})")
    return findings


# ------------------------------- SH001 --------------------------------------

def _spec_entries(names) -> List[Tuple[int, Tuple[str, ...]]]:
    """Normalize one shard_map in/out names entry ({dim: axes}) to a
    [(dim, axes tuple)] list; unknown layouts come back empty."""
    out: List[Tuple[int, Tuple[str, ...]]] = []
    try:
        for dim, axes in dict(names).items():
            if isinstance(axes, (tuple, list)):
                out.append((int(dim), tuple(str(a) for a in axes)))
            else:
                out.append((int(dim), (str(axes),)))
    except Exception:
        return []
    return out


def lint_shard_map_eqn(eqn, routine: Optional[str] = None) -> List[Finding]:
    """SH001: in/out names consistent with operand shapes and the mesh."""
    findings: List[Finding] = []
    loc = _source_location(eqn)
    mesh_sizes = _mesh_axis_sizes(eqn.params.get("mesh"))

    def check_side(side: str, names_seq, vars_seq):
        avals = [getattr(v, "aval", None) for v in vars_seq]
        for i, names in enumerate(names_seq or ()):
            aval = avals[i] if i < len(avals) else None
            shape = getattr(aval, "shape", None)
            for dim, axes in _spec_entries(names):
                missing = [a for a in axes if a not in mesh_sizes]
                if missing:
                    findings.append(make_finding(
                        "SH001", f"{side} spec of operand {i} names mesh "
                        f"axes {missing} absent from the mesh "
                        f"(axes={sorted(mesh_sizes)})",
                        routine=routine, location=loc))
                    continue
                extent = 1
                for a in axes:
                    extent *= mesh_sizes[a]
                if shape is None:
                    continue
                if dim >= len(shape):
                    findings.append(make_finding(
                        "SH001", f"{side} spec of operand {i} shards dim "
                        f"{dim} of a rank-{len(shape)} operand "
                        f"{tuple(shape)}", routine=routine, location=loc))
                elif extent > 0 and int(shape[dim]) % extent != 0:
                    findings.append(make_finding(
                        "SH001", f"{side} spec of operand {i}: dim {dim} "
                        f"({int(shape[dim])}) not divisible by mesh axes "
                        f"{list(axes)} extent {extent} (shape "
                        f"{tuple(shape)})", routine=routine, location=loc))

    check_side("in", eqn.params.get("in_names"), eqn.invars)
    check_side("out", eqn.params.get("out_names"), eqn.outvars)
    return findings


# --------------------------- jaxpr-view driver ------------------------------

def lint_collective_jaxpr(closed_jaxpr, routine: Optional[str] = None
                          ) -> List[Finding]:
    """CC001 + SH001 + SH003 over one trace (and all nested jaxprs)."""
    findings: List[Finding] = []
    for eqn, env, in_sm in iter_spmd_eqns(closed_jaxpr):
        name = eqn.primitive.name
        if name == "shard_map":
            findings.extend(lint_shard_map_eqn(eqn, routine=routine))
        elif name == "ppermute":
            findings.extend(lint_ppermute_eqn(eqn, env, routine=routine))
        elif name in REPLICATING_PRIMITIVES and in_sm:
            op_bytes = sum(_aval_bytes(getattr(v, "aval", None))
                           for v in eqn.invars)
            findings.append(make_finding(
                "SH003", f"{name!r} over axis {_axis_key(eqn)!r} inside a "
                f"shard_map body replicates a sharded operand "
                f"({op_bytes} B per shard) onto every device",
                routine=routine, location=_source_location(eqn)))
    return findings


# --------------------------- record-view driver -----------------------------

def derived_comm(closed_jaxpr) -> Tuple[int, int, Dict[str, int]]:
    """Jaxpr-side comm census: (total ppermute hops, total on-wire bytes,
    per-axis hop counts). Each ppermute eqn is one hop carrying its input
    aval bytes per link - exactly :func:`ring_bcast_bytes`' accounting."""
    hops = 0
    wire_bytes = 0
    per_axis: Dict[str, int] = {}
    for eqn, _, _ in iter_spmd_eqns(closed_jaxpr):
        if eqn.primitive.name != "ppermute":
            continue
        hops += 1
        wire_bytes += sum(_aval_bytes(getattr(v, "aval", None))
                          for v in eqn.invars)
        key = _axis_key(eqn)
        per_axis[key] = per_axis.get(key, 0) + 1
    return hops, wire_bytes, per_axis


def _planned_bytes(record) -> Optional[int]:
    """plan_pdgemm's collective term for one "pdgemm" schedule record."""
    info = record.info or {}
    try:
        from repro.core.codesign import plan_pdgemm
        plan = plan_pdgemm(info["m"], info["n"], info["k"],
                           info["px"], info["py"],
                           dtype_bytes=info["itemsize"])
        return int(plan.collective_bytes)
    except Exception:
        return None


def lint_collective_records(closed_jaxpr, records: Sequence,
                            counter_delta: Optional[Mapping[str, int]] = None,
                            routine: Optional[str] = None) -> List[Finding]:
    """CC002/CC003/SH002 - recorded schedule vs traced jaxpr vs counters.

    ``records`` is the :func:`repro.distributed.collectives
    .record_collectives` capture of the same trace that produced
    ``closed_jaxpr``; ``counter_delta`` the ``obs`` counter movement
    across it (``collective.hops`` / ``collective.bytes``)."""
    findings: List[Finding] = []
    rings = [r for r in records if getattr(r, "kind", None) == "ring_bcast"]
    scheds = [r for r in records if getattr(r, "kind", None) == "pdgemm"]
    pads = [r for r in records if getattr(r, "kind", None) == "pad_batch"]

    # SH002: every declared ragged-batch pad keeps the discipline
    for p in pads:
        info = p.info or {}
        batch = int(info.get("batch", 0))
        pad = int(info.get("pad", 0))
        ndev = int(p.size)
        if ndev > 0 and (batch + pad) % ndev != 0:
            findings.append(make_finding(
                "SH002", f"batch {batch} padded by {pad} is not a "
                f"multiple of the {ndev}-device mesh", routine=routine))
        elif pad >= ndev > 0:
            findings.append(make_finding(
                "SH002", f"pad {pad} is not minimal for batch {batch} "
                f"over {ndev} devices", routine=routine))
        if pad > 0 and not info.get("identity", False):
            findings.append(make_finding(
                "SH002", f"batch pad of {pad} items is not identity "
                "filler - padded items are not safely factorizable",
                routine=routine))

    d_hops, d_bytes, per_axis = derived_comm(closed_jaxpr)

    # CC002: per-record hop law, then per-axis and total census agreement
    rec_hops = 0
    rec_by_axis: Dict[str, int] = {}
    for r in rings:
        want = max(int(r.size) - 1, 0)
        if int(r.hops) != want:
            findings.append(make_finding(
                "CC002", f"ring_bcast over axis {r.axis!r} (size "
                f"{r.size}) recorded {r.hops} hops; a SUMMA ring step "
                f"must take exactly size - 1 = {want}", routine=routine))
        rec_hops += int(r.hops)
        key = str(r.axis) if r.axis is not None else ""
        rec_by_axis[key] = rec_by_axis.get(key, 0) + int(r.hops)
    if rings or d_hops:
        for axis in sorted(set(rec_by_axis) | set(per_axis)):
            got, want = per_axis.get(axis, 0), rec_by_axis.get(axis, 0)
            if got != want:
                findings.append(make_finding(
                    "CC002", f"axis {axis!r}: traced {got} ppermute "
                    f"hop(s) but the recorded schedule declares {want}",
                    routine=routine))
    if counter_delta is not None and rec_hops != int(
            counter_delta.get("collective.hops", 0)):
        findings.append(make_finding(
            "CC002", f"collective.hops counter moved "
            f"{counter_delta.get('collective.hops', 0)} but the recorded "
            f"schedule declares {rec_hops} hop(s)", routine=routine))

    # CC003: three-way byte agreement (jaxpr vs counters vs plan_pdgemm)
    tol = rules.drift_tolerance(rules.DRIFT_COMM_TOL, routine)

    def _drift(a: float, b: float) -> float:
        if a == b:
            return 0.0
        return abs(a - b) / max(abs(a), abs(b), 1.0)

    if counter_delta is not None and (rings or d_bytes):
        c_bytes = int(counter_delta.get("collective.bytes", 0))
        if _drift(d_bytes, c_bytes) > tol:
            findings.append(make_finding(
                "CC003", f"traced on-wire bytes {d_bytes} vs "
                f"collective.bytes counter {c_bytes}: drift "
                f"{_drift(d_bytes, c_bytes):.2f} > declared tolerance "
                f"{tol:.2f}", routine=routine))
    if scheds:
        planned = [_planned_bytes(r) for r in scheds]
        if None not in planned:
            total = sum(planned)
            if _drift(d_bytes, total) > tol:
                findings.append(make_finding(
                    "CC003", f"traced on-wire bytes {d_bytes} vs "
                    f"plan_pdgemm collective term {total}: drift "
                    f"{_drift(d_bytes, total):.2f} > declared tolerance "
                    f"{tol:.2f}", routine=routine))
    return findings


def lint_spmd(closed_jaxpr, records: Sequence = (),
              counter_delta: Optional[Mapping[str, int]] = None,
              routine: Optional[str] = None) -> List[Finding]:
    """All CC/SH rules for one trace + its collective-record capture."""
    findings = lint_collective_jaxpr(closed_jaxpr, routine=routine)
    findings.extend(lint_collective_records(
        closed_jaxpr, records, counter_delta=counter_delta,
        routine=routine))
    return findings
