"""Kernel-launch lint (rules KL001/KL002/KL004) over traced Pallas calls
and recorded dispatch resolutions.

Two complementary views of the same launch contract:

* **jaxpr view** - every ``pallas_call`` eqn found in the trace exposes
  its ``grid_mapping`` (grid + per-operand block shapes) and kernel body;
  block divisibility, the modeled VMEM working set, and zero-dim grids
  are checked against the *actual* launch geometry the tracer saw.
* **plan view** - :func:`repro.tune.dispatch.record_resolutions` captures
  every :class:`Resolution` the dispatcher produced while tracing; the
  resolved :class:`GemmPlan` tiles and fused-chain verdicts are checked
  against the ambient machine budget *before* any kernel exists, which
  catches a poisoned registry entry (e.g. hand-edited ``bm``) that the
  kernels would happily pad around.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax.numpy as jnp

from repro.analysis.jaxpr_lint import _source_location, iter_eqns
from repro.analysis.rules import Finding, make_finding


def _block_dims(block_shape) -> List[Optional[int]]:
    """Block shape entries as ints (None for squeezed/element dims)."""
    dims: List[Optional[int]] = []
    for d in block_shape:
        if isinstance(d, int):
            dims.append(d)
        else:
            # pl.Squeezed / Blocked wrappers on newer Pallas versions
            inner = getattr(d, "block_size", None)
            dims.append(int(inner) if isinstance(inner, int) else None)
    return dims


def _aval_bytes(aval) -> int:
    shape = getattr(aval, "shape", None)
    dtype = getattr(aval, "dtype", None)
    if shape is None or dtype is None:
        return 0
    n = 1
    for d in shape:
        n *= int(d)
    return n * jnp.dtype(dtype).itemsize


def _pallas_calls(closed_jaxpr):
    for eqn, in_pallas in iter_eqns(closed_jaxpr.jaxpr):
        if eqn.primitive.name == "pallas_call" and not in_pallas:
            yield eqn


def lint_pallas_eqn(eqn, machine, routine: Optional[str] = None
                    ) -> List[Finding]:
    """KL001/KL002/KL004 for one traced ``pallas_call`` equation."""
    findings: List[Finding] = []
    loc = _source_location(eqn)
    gm = eqn.params.get("grid_mapping")
    if gm is None:                       # unknown Pallas internals: skip
        return findings
    grid = tuple(int(g) for g in getattr(gm, "grid", ())
                 if isinstance(g, int))
    if any(g == 0 for g in grid):
        findings.append(make_finding(
            "KL004", f"Pallas launch with a zero-length grid {grid} "
            "(empty operand reached the kernel path)",
            routine=routine, location=loc))
    operands = [v.aval for v in list(eqn.invars) + list(eqn.outvars)
                if hasattr(v, "aval")]
    mappings = list(getattr(gm, "block_mappings", ()))
    vmem = 0
    for i, bm in enumerate(mappings):
        block = _block_dims(getattr(bm, "block_shape", ()))
        aval = operands[i] if i < len(operands) else None
        if aval is None or not hasattr(aval, "shape"):
            continue
        # trailing-aligned: block ndim can be < operand ndim (squeezed
        # leading grid axes); compare the dims the block actually tiles
        shape = list(aval.shape)[-len(block):] if block else []
        for bd, ad in zip(block, shape):
            if bd is None:
                continue
            if bd == 0 or ad == 0:
                findings.append(make_finding(
                    "KL004", f"zero-dim block/operand pair (block {bd}, "
                    f"dim {ad}) in Pallas operand {i} of {aval.shape}",
                    routine=routine, location=loc))
            elif ad % bd != 0:
                findings.append(make_finding(
                    "KL001", f"block dim {bd} does not divide padded "
                    f"operand dim {ad} (operand {i}, shape "
                    f"{tuple(aval.shape)}, block {tuple(block)})",
                    routine=routine, location=loc))
        blk_elems = 1
        for bd, ad in zip(block, shape):
            blk_elems *= bd if bd is not None else 1
        dtype = getattr(aval, "dtype", None)
        if dtype is not None:
            # double-buffered streaming blocks, the plan_gemm accounting
            vmem += 2 * blk_elems * jnp.dtype(dtype).itemsize
    # scratch refs: kernel jaxpr invars beyond the mapped operands
    kernel_jaxpr = eqn.params.get("jaxpr")
    if kernel_jaxpr is not None and len(kernel_jaxpr.invars) > len(mappings):
        for v in kernel_jaxpr.invars[len(mappings):]:
            aval = getattr(v, "aval", None)
            vmem += _aval_bytes(getattr(aval, "inner_aval", aval))
    budget = machine.memory.vmem_bytes
    if vmem > budget:
        findings.append(make_finding(
            "KL002", f"modeled VMEM working set {vmem} B exceeds "
            f"machine budget {budget} B ({machine.name})",
            routine=routine, location=loc))
    return findings


def lint_kernel_launches(closed_jaxpr, machine,
                         routine: Optional[str] = None,
                         zero_dim_inputs: bool = False) -> List[Finding]:
    """All pallas_call eqns in a trace; with ``zero_dim_inputs`` any
    launch at all is a KL004 (the routine must have taken the jnp
    fallback)."""
    findings: List[Finding] = []
    for eqn in _pallas_calls(closed_jaxpr):
        if zero_dim_inputs:
            findings.append(make_finding(
                "KL004", "Pallas launch reached with a zero-dim operand "
                "(must route to the jnp fallback)", routine=routine,
                location=_source_location(eqn)))
        findings.extend(lint_pallas_eqn(eqn, machine, routine=routine))
    return findings


def lint_resolutions(resolutions: Sequence, machine,
                     routine: Optional[str] = None) -> List[Finding]:
    """KL001/KL002 over recorded dispatch Resolutions (the plan view)."""
    findings: List[Finding] = []
    sublane = machine.pe.sublane
    budget = machine.memory.vmem_bytes
    for res in resolutions:
        plan = getattr(res, "gemm_plan", None)
        if plan is not None:
            bad = [b for b in (plan.bm, plan.bn, plan.bk)
                   if b % sublane != 0]
            if bad:
                findings.append(make_finding(
                    "KL001", f"resolved {res.op} plan tile "
                    f"(bm={plan.bm}, bn={plan.bn}, bk={plan.bk}) not "
                    f"aligned to sublane {sublane} (source={res.source})",
                    routine=routine))
            if plan.vmem_bytes > budget:
                findings.append(make_finding(
                    "KL002", f"resolved {res.op} plan VMEM "
                    f"{plan.vmem_bytes} B exceeds budget {budget} B "
                    f"(source={res.source})", routine=routine))
        chain = getattr(res, "chain", None)
        if getattr(res, "fused", False) and chain is not None \
                and not chain.fits_vmem:
            findings.append(make_finding(
                "KL002", f"fused {res.op} chosen although the chain does "
                f"not fit VMEM ({chain.vmem_bytes} B)", routine=routine))
    return findings
