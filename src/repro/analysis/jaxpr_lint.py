"""Dtype-flow lint over traced jaxprs (rules DF001-DF004, KL003).

Everything here works on the output of ``jax.make_jaxpr`` - tracing only,
no execution - which is what lets ``analysis.check`` sweep the whole
``repro.linalg`` surface in CI without paying a single kernel launch.
The walker recurses through every higher-order primitive (pjit, scan,
while, cond, shard_map, pallas_call, ...) by structurally discovering
sub-jaxprs in eqn params, tracking whether it is *inside a Pallas kernel
body* - several rules only apply there (KL003) or need the distinction
for messages.
"""
from __future__ import annotations

from typing import Iterator, List, Optional, Sequence, Tuple

import jax.numpy as jnp

from repro.analysis.rules import Finding, make_finding

# primitives that move data or control to the host; none belong in a
# traced BLAS/LAPACK routine body (DF004)
HOST_PRIMITIVES = ("pure_callback", "io_callback", "debug_callback",
                   "callback", "device_put")


def _source_location(eqn) -> Optional[str]:
    """Best-effort user frame of one eqn ("file:line"); None when the
    tracer did not keep source info (private API - degrade, never fail)."""
    try:
        from jax._src import source_info_util
        frame = source_info_util.user_frame(eqn.source_info)
        if frame is None:
            return None
        return f"{frame.file_name}:{frame.start_line}"
    except Exception:
        return None


def _subjaxprs(value):
    """Yield every (Closed)Jaxpr reachable from one eqn param value."""
    if hasattr(value, "eqns"):                       # a raw Jaxpr
        yield value
    elif hasattr(value, "jaxpr"):                    # a ClosedJaxpr
        yield value.jaxpr
    elif isinstance(value, (tuple, list)):
        for v in value:
            yield from _subjaxprs(v)


def iter_eqns(jaxpr, in_pallas: bool = False) -> Iterator[Tuple[object, bool]]:
    """Yield (eqn, in_pallas) over a jaxpr and all nested sub-jaxprs."""
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)           # accept ClosedJaxpr
    for eqn in jaxpr.eqns:
        yield eqn, in_pallas
        inner_pallas = in_pallas or eqn.primitive.name == "pallas_call"
        for name, value in eqn.params.items():
            for sub in _subjaxprs(value):
                yield from iter_eqns(sub, in_pallas=inner_pallas)


def _out_avals(eqn):
    return [v.aval for v in eqn.outvars if hasattr(v, "aval")]


def _in_avals(eqn):
    return [v.aval for v in eqn.invars if hasattr(v, "aval")]


def _is_f64(dtype) -> bool:
    try:
        return jnp.dtype(dtype) == jnp.dtype("float64")
    except TypeError:
        return False


def _is_64bit_int(dtype) -> bool:
    try:
        dt = jnp.dtype(dtype)
    except TypeError:
        return False
    return dt.kind in ("i", "u") and dt.itemsize == 8


def _width(dtype) -> int:
    return jnp.dtype(dtype).itemsize


def lint_dtype_flow(closed_jaxpr, routine: Optional[str] = None,
                    accum_dtype=None) -> List[Finding]:
    """DF001/DF002/DF003/DF004 + KL003 over one traced jaxpr.

    ``closed_jaxpr`` is a ``jax.make_jaxpr`` result; operand dtypes come
    from its ``in_avals``. ``accum_dtype`` is the active context's
    accumulation dtype - an explicit f64 accumulator legitimizes f64
    intermediates over f32 operands (DF001 stands down).
    """
    findings: List[Finding] = []
    in_dtypes = [a.dtype for a in closed_jaxpr.in_avals
                 if hasattr(a, "dtype")]
    f64_inputs = any(_is_f64(d) for d in in_dtypes)
    f64_expected = f64_inputs or (accum_dtype is not None
                                  and _is_f64(accum_dtype))
    # var id -> (source dtype, via dtype) for convert_element_type chains
    convert_origin = {}
    df1 = df3 = kl3 = 0                  # first-hit reporting, total counts
    for eqn, in_pallas in iter_eqns(closed_jaxpr.jaxpr):
        name = eqn.primitive.name
        loc = None
        if name in HOST_PRIMITIVES:
            findings.append(make_finding(
                "DF004", f"host primitive {name!r} in traced body",
                routine=routine, location=_source_location(eqn)))
            continue
        outs = _out_avals(eqn)
        # weak-typed f64 scalars are python-literal artifacts (e.g.
        # jnp.where(c, 1.0, -1.0) under x64); JAX's weak-type promotion
        # cannot let them widen an array result, so only committed
        # (non-weak) float64 intermediates count as silent promotion
        if not f64_expected and any(
                _is_f64(getattr(a, "dtype", None))
                and not getattr(a, "weak_type", False) for a in outs):
            df1 += 1
            if df1 == 1:
                findings.append(make_finding(
                    "DF001",
                    f"float64 intermediate from {name!r} under a non-f64 "
                    "context (operands "
                    f"{[str(d) for d in in_dtypes]})",
                    routine=routine, location=_source_location(eqn)))
        if in_pallas and any(
                _is_64bit_int(getattr(a, "dtype", None)) for a in outs):
            kl3 += 1
            if kl3 == 1:
                findings.append(make_finding(
                    "KL003",
                    f"64-bit integer index dtype from {name!r} inside a "
                    "Pallas kernel body (must stay int32 under x64)",
                    routine=routine, location=_source_location(eqn)))
        if name == "dot_general":
            ins = [getattr(a, "dtype", None) for a in _in_avals(eqn)]
            out = getattr(outs[0], "dtype", None) if outs else None
            if (len(ins) >= 2 and all(_is_f64(d) for d in ins[:2])
                    and out is not None and not _is_f64(out)):
                findings.append(make_finding(
                    "DF002",
                    f"f64 operands accumulate into {out} dot_general "
                    "output (accumulator narrower than operands)",
                    routine=routine, location=_source_location(eqn)))
        if name == "convert_element_type":
            src = _in_avals(eqn)
            dst = outs[0] if outs else None
            if src and dst is not None and hasattr(src[0], "dtype"):
                src_dt, dst_dt = src[0].dtype, dst.dtype
                prior = convert_origin.get(id(eqn.invars[0]))
                if (prior is not None and prior == jnp.dtype(dst_dt)
                        and _width(src_dt) < _width(dst_dt)
                        and jnp.dtype(dst_dt).kind == "f"):
                    df3 += 1
                    if df3 == 1:
                        findings.append(make_finding(
                            "DF003",
                            f"convert round-trip {dst_dt} -> {src_dt} -> "
                            f"{dst_dt} through a narrower dtype",
                            routine=routine, location=_source_location(eqn)))
                for ov in eqn.outvars:
                    convert_origin[id(ov)] = jnp.dtype(src_dt)
    return findings
