"""analysis.check / check_surface: trace, lint, and report.

``check(fn, *args)`` is the one entry point: it traces ``fn`` with
``jax.make_jaxpr`` (no execution) under ``jax.experimental.enable_x64``
- the canonical lint mode, where silent f64 promotion (DF001) and 64-bit
index dtypes (KL003) are *representable* instead of being clamped away -
records every dispatcher :class:`~repro.tune.dispatch.Resolution` the
trace produced, and runs the three rule families over the result:

* kernel-launch lint over the traced ``pallas_call`` eqns and recorded
  plans (:mod:`repro.analysis.kernel_lint`),
* dtype-flow lint over the jaxpr (:mod:`repro.analysis.jaxpr_lint`),
* cost-model drift: the routine's ``_routine`` span annotation
  (``flops``/``bytes``) against jaxpr-derived counts, plus a double-trace
  retrace-stability probe (CM003),
* SPMD lint over the same trace (:mod:`repro.analysis.spmd_lint`):
  ppermute ring discipline, shard_map spec/shape consistency, and the
  recorded :class:`~repro.distributed.collectives.CollectiveRecord`
  schedule cross-checked against the jaxpr hop/byte census, the ``obs``
  collective counters, and ``plan_pdgemm``'s collective term.

``check_surface()`` sweeps every public ``repro.linalg`` routine over the
acceptance grid (policies x dtypes x {no mesh, meshes}) with canonical
small operands and merges the per-case reports - the distributed leg now
covers ``SURFACE_MESHES`` plus direct ``pdgemm``/``pdtrsm`` entry points;
it is the engine behind ``scripts/check_static_analysis.py``. See
``docs/static_analysis.md``.
"""
from __future__ import annotations

import dataclasses
import json
import math
import re
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import arch as _arch
from repro.analysis import kernel_lint, rules, spmd_lint
from repro.analysis.jaxpr_lint import iter_eqns, lint_dtype_flow
from repro.analysis.rules import (Allowlist, Finding, apply_suppression,
                                  drift_tolerance, load_allowlist,
                                  make_finding)
from repro.core import jaxpr_census

SCHEMA_VERSION = rules.SCHEMA_VERSION


@dataclasses.dataclass
class AnalysisReport:
    """Lint results for one target (routine or surface sweep).

    ``cases`` records what was actually checked - one dict per traced
    (policy, dtype, mesh) leg, including skips - so a report that found
    nothing is distinguishable from a report that checked nothing.
    """

    target: str
    cases: List[Dict]
    findings: List[Finding]
    suppressed: List[Finding]
    schema_version: int = SCHEMA_VERSION

    @property
    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == rules.ERROR]

    @property
    def warnings(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == rules.WARN]

    @property
    def ok(self) -> bool:
        """No unsuppressed errors (warnings do not fail the gate)."""
        return not self.errors

    def to_json(self) -> Dict:
        return {"schema_version": self.schema_version, "target": self.target,
                "cases": self.cases,
                "findings": [f.to_json() for f in self.findings],
                "suppressed": [f.to_json() for f in self.suppressed]}

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)
        return path

    def summary(self) -> str:
        n_e, n_w = len(self.errors), len(self.warnings)
        head = (f"analysis[{self.target}]: {len(self.cases)} case(s), "
                f"{n_e} error(s), {n_w} warning(s), "
                f"{len(self.suppressed)} suppressed")
        lines = [head]
        for f in self.findings:
            lines.append(f"  {f.severity.upper():5s} {f.rule} "
                         f"[{f.routine or '-'}] {f.message}")
        for f in self.suppressed:
            lines.append(f"  allow {f.rule} [{f.routine or '-'}] "
                         f"(via {f.suppressed_by})")
        return "\n".join(lines)


def merge_reports(reports: Sequence[AnalysisReport],
                  target: str) -> AnalysisReport:
    cases: List[Dict] = []
    findings: List[Finding] = []
    suppressed: List[Finding] = []
    for r in reports:
        cases.extend(r.cases)
        findings.extend(r.findings)
        suppressed.extend(r.suppressed)
    return AnalysisReport(target, cases, findings, suppressed)


# ------------------------------ tracing helpers -----------------------------

def _x64():
    from jax.experimental import enable_x64
    return enable_x64()


def _leaves(args, kw):
    return jax.tree_util.tree_leaves((args, kw))


def _has_zero_dim(args, kw) -> bool:
    return any(0 in tuple(getattr(a, "shape", ()))
               for a in _leaves(args, kw))


_ADDR = re.compile(r"0x[0-9a-fA-F]+")


def _normalize_jaxpr_str(closed) -> str:
    """Jaxpr text with memory addresses scrubbed: two traces of a stable
    function compare equal even where params repr closure objects."""
    return _ADDR.sub("0x", str(closed.jaxpr))


def _trace(fn: Callable, args, kw):
    """(closed_jaxpr, resolutions, collective_records, counter_delta)
    under the canonical lint mode. The collective records and the
    ``collective.*`` counter movement come from the *same* trace as the
    jaxpr, so spmd_lint can diff declared schedule against traced
    reality."""
    from repro.distributed.collectives import record_collectives
    from repro.obs import counters as _counters
    from repro.tune import dispatch
    before = _counters.snapshot()
    with _x64():
        with dispatch.record_resolutions() as rec, \
                record_collectives() as coll:
            closed = jax.make_jaxpr(lambda *a: fn(*a, **kw))(*args)
    return closed, list(rec), list(coll), _counters.delta(before)


# --------------------------- cost-model drift (CM) --------------------------

def _getrf_flops(m, n):
    k = min(m, n)
    return m * n * k - (m + n) * k * k // 2 + k ** 3 // 3


def _geqrf_flops(m, n):
    k = min(m, n)
    return 2 * m * n * k - k * k * (m + n) + 2 * k ** 3 // 3


def _opaque_lapack_flops(closed) -> float:
    """Analytic flops of LAPACK primitives jaxpr_census treats as opaque
    (it counts elementwise/dot volumes; `cholesky`, `triangular_solve`,
    ... are single eqns to it). Leading-order coefficients, the same
    accounting the span annotations use."""
    total = 0.0
    for eqn, _ in iter_eqns(closed.jaxpr):
        name = eqn.primitive.name
        avals = [v.aval for v in eqn.invars if hasattr(v, "aval")]
        if not avals or not hasattr(avals[0], "shape"):
            continue
        s = avals[0].shape
        batch = float(np.prod(s[:-2])) if len(s) > 2 else 1.0
        if name == "cholesky" and len(s) >= 2:
            total += batch * s[-1] ** 3 / 3
        elif name == "lu" and len(s) >= 2:
            total += batch * _getrf_flops(s[-2], s[-1])
        elif name == "geqrf" and len(s) >= 2:
            total += batch * _geqrf_flops(s[-2], s[-1])
        elif name == "householder_product" and len(s) >= 2:
            k = min(s[-2], s[-1])
            total += batch * (4 * s[-2] * s[-1] * k - 2 * (s[-2] + s[-1])
                              * k * k + 4 * k ** 3 / 3) / 2
        elif name == "triangular_solve" and len(avals) >= 2:
            b = avals[1].shape
            nrhs = b[-1] if len(b) >= 2 else 1
            total += batch * s[-1] ** 2 * nrhs
    return total


def _boundary_bytes(closed) -> int:
    total = 0
    for aval in list(closed.in_avals) + list(closed.out_avals):
        shape = getattr(aval, "shape", None)
        dtype = getattr(aval, "dtype", None)
        if shape is None or dtype is None:
            continue
        n = 1
        for d in shape:
            n *= int(d)
        total += n * jnp.dtype(dtype).itemsize
    return total


def _rel_drift(annotated: float, derived: float) -> float:
    if annotated == derived:
        return 0.0
    return abs(annotated - derived) / max(abs(annotated), abs(derived), 1.0)


def _drift_findings(fn: Callable, args, kw, info: Callable,
                    closed, routine: Optional[str],
                    case: Optional[Mapping]) -> List[Finding]:
    """CM001/CM002: span annotation vs jaxpr-derived counts.

    The census runs on the *reference-policy* trace (plain jnp: the
    census cannot see inside pallas_call bodies), which is fair game -
    the annotation claims to price the mathematical routine, not one
    kernelization of it."""
    findings: List[Finding] = []
    try:
        ann = info(*args, **kw)
        ann_flops = float(ann["flops"])
        ann_bytes = float(ann["bytes"])
    except Exception as exc:
        findings.append(make_finding(
            "CM001", f"span annotation info fn failed: {exc!r}",
            routine=routine, case=case))
        return findings
    from repro import linalg
    with linalg.use(policy="reference"), _x64():
        cen = jaxpr_census.census_of(lambda *a: fn(*a, **kw), *args,
                                     name=routine or "fn")
        ref_closed = jax.make_jaxpr(lambda *a: fn(*a, **kw))(*args)
    derived_flops = cen.flops + _opaque_lapack_flops(ref_closed)
    tol_f = drift_tolerance(rules.DRIFT_FLOPS_TOL, routine)
    drift_f = _rel_drift(ann_flops, derived_flops)
    if drift_f > tol_f:
        findings.append(make_finding(
            "CM001", f"flops annotation {ann_flops:.4g} vs census "
            f"{derived_flops:.4g}: drift {drift_f:.2f} > declared "
            f"tolerance {tol_f:.2f}", routine=routine, case=case))
    derived_bytes = _boundary_bytes(closed)
    tol_b = drift_tolerance(rules.DRIFT_BYTES_TOL, routine)
    drift_b = _rel_drift(ann_bytes, derived_bytes)
    if drift_b > tol_b:
        findings.append(make_finding(
            "CM002", f"bytes annotation {ann_bytes:.4g} vs traced "
            f"boundary {derived_bytes:.4g}: drift {drift_b:.2f} > "
            f"declared tolerance {tol_b:.2f}", routine=routine, case=case))
    return findings


# --------------------------------- check ------------------------------------

def check(fn: Callable, *args, routine: Optional[str] = None,
          info: Optional[Callable] = None, machine=None,
          allowlist: Optional[Allowlist] = None, accum_dtype=None,
          drift: bool = True, retrace: bool = True,
          case: Optional[Mapping] = None, **kw) -> AnalysisReport:
    """Statically verify one callable against the full rule vocabulary.

    ``fn`` is traced, never executed. ``routine``/``info`` default to the
    ``_analysis_op``/``_analysis_info`` attributes the ``_routine``
    decorator attaches to every public linalg routine (so
    ``check(linalg.gemm, a, b)`` just works); ``info=None`` skips the
    drift rules. ``machine`` defaults to the ambient
    :func:`repro.arch.current_machine`. ``allowlist`` (see
    :func:`repro.analysis.rules.load_allowlist`) and any active
    :func:`repro.analysis.allow` scopes move matching findings into
    ``report.suppressed`` instead of deleting them.
    """
    routine = routine or getattr(fn, "_analysis_op", None) \
        or getattr(fn, "__name__", None)
    info = info if info is not None else getattr(fn, "_analysis_info", None)
    mach = _arch.resolve_machine(machine)
    zero_dim = _has_zero_dim(args, kw)
    findings: List[Finding] = []
    cases: List[Dict] = [dict(case or {}, routine=routine,
                              zero_dim=zero_dim)]
    try:
        closed, resolutions, coll_records, counter_delta = \
            _trace(fn, args, kw)
    except Exception as exc:
        if zero_dim:
            # the PR 8 bug class: an empty operand crashed the kernel
            # path at trace time instead of routing to the jnp fallback
            findings.append(make_finding(
                "KL004", f"trace crashed on zero-dim operands: "
                f"{type(exc).__name__}: {exc}", routine=routine, case=case))
            active, suppressed = apply_suppression(findings, allowlist)
            return AnalysisReport(routine or "fn", cases, active, suppressed)
        raise
    findings.extend(kernel_lint.lint_kernel_launches(
        closed, mach, routine=routine, zero_dim_inputs=zero_dim))
    findings.extend(kernel_lint.lint_resolutions(
        resolutions, mach, routine=routine))
    findings.extend(lint_dtype_flow(closed, routine=routine,
                                    accum_dtype=accum_dtype))
    findings.extend(spmd_lint.lint_spmd(closed, coll_records,
                                        counter_delta=counter_delta,
                                        routine=routine))
    if retrace:
        closed2, _, _, _ = _trace(fn, args, kw)
        if _normalize_jaxpr_str(closed) != _normalize_jaxpr_str(closed2):
            findings.append(make_finding(
                "CM003", "two same-shape traces produced different "
                "jaxprs (unstable jit cache key - every call retraces)",
                routine=routine, case=case))
    if drift and info is not None and not zero_dim:
        findings.extend(_drift_findings(fn, args, kw, info, closed,
                                        routine, case))
    if case is not None:
        findings = [dataclasses.replace(f, case=dict(case))
                    if f.case is None else f for f in findings]
    active, suppressed = apply_suppression(findings, allowlist)
    return AnalysisReport(routine or "fn", cases, active, suppressed)


def check_routine(name: str, *args, **kw) -> AnalysisReport:
    """``check`` a public routine by its ``repro.linalg`` name."""
    from repro import linalg
    return check(getattr(linalg, name), *args, **kw)


# ----------------------------- surface sweep --------------------------------

# canonical operand sizes: big enough that blocked drivers take their
# real panel/trailing structure and leading-order flop terms dominate,
# small enough that a full sweep stays trace-only cheap
_N, _M, _K, _VEC, _BATCH = 64, 48, 32, 4096, 2


def _rng():
    return np.random.default_rng(0)


def _mat(r, *shape):
    return r.standard_normal(shape).astype(np.float32)


def _spd(r, n):
    g = _mat(r, n, n)
    return (g @ g.T + n * np.eye(n, dtype=np.float32)).astype(np.float32)


def _surface_args(name: str) -> Optional[Tuple[tuple, dict]]:
    """Canonical (args, kwargs) for one linalg routine, float32 base."""
    r = _rng()
    n, m, k, v, bt = _N, _M, _K, _VEC, _BATCH
    if name == "gemm":
        return (_mat(r, m, k), _mat(r, k, n)), {}
    if name == "gemm_bias_act":
        return (_mat(r, m, k), _mat(r, k, n)), {"bias": _mat(r, n),
                                                "epilogue": "relu"}
    if name == "syrk":
        return (_mat(r, m, k),), {}
    if name == "trsm":
        t = np.tril(_mat(r, n, n)) + n * np.eye(n, dtype=np.float32)
        return (t.astype(np.float32), _mat(r, n, k)), {}
    if name == "gemv":
        return (_mat(r, m, k), _mat(r, k)), {}
    if name == "ger":
        return (1.5, _mat(r, m), _mat(r, k), _mat(r, m, k)), {}
    if name == "trsv":
        t = np.tril(_mat(r, n, n)) + n * np.eye(n, dtype=np.float32)
        return (t.astype(np.float32), _mat(r, n)), {}
    if name in ("axpy", "scal"):
        return ((1.5, _mat(r, v), _mat(r, v)) if name == "axpy"
                else (1.5, _mat(r, v))), {}
    if name in ("dot", "nrm2", "asum", "iamax"):
        return ((_mat(r, v), _mat(r, v)) if name == "dot"
                else (_mat(r, v),)), {}
    if name == "rot":
        return (_mat(r, v), _mat(r, v), 0.8, 0.6), {}
    if name == "cholesky":
        return (_spd(r, n),), {}
    if name in ("lu", "qr"):
        return (_mat(r, n, n),), {}
    if name == "solve":
        return (_spd(r, n), _mat(r, n, 4)), {}
    if name == "lstsq":
        return (_mat(r, n, k), _mat(r, n)), {}
    if name == "batched_cholesky":
        return (np.stack([_spd(r, k) for _ in range(bt)]),), {}
    if name in ("batched_lu", "batched_qr"):
        return (np.stack([_mat(r, k, k) for _ in range(bt)]),), {}
    if name == "batched_solve":
        from repro.lapack.batched import FactorizationResult
        factors = np.stack([_spd(r, k) for _ in range(bt)])
        res = FactorizationResult(factors=jnp.asarray(factors), pivots=None,
                                  tau=None, kind="potrf", block=16)
        return (res, _mat(r, bt, k)), {}
    return None                         # context machinery etc: not callable


def _cast_args(args, kw, dtype):
    def cast(x):
        if hasattr(x, "dtype") and jnp.dtype(x.dtype).kind == "f":
            return jnp.asarray(x).astype(dtype)
        return x
    return jax.tree_util.tree_map(cast, args), \
        jax.tree_util.tree_map(cast, kw)


SURFACE_POLICIES = ("reference", "model", "tuned")
SURFACE_DTYPES = ("float32", "bfloat16", "float64")
SURFACE_MESH = (2, 2)
# the acceptance meshes of tests/test_distributed_blas.py: degenerate,
# square, and rectangular - the shapes that exercise distinct SUMMA
# schedules (0, 8, and 32 hops per pdgemm)
SURFACE_MESHES = ((1, 1), (2, 2), (4, 2))
# distributed entry points checked directly (not via the linalg context):
# name -> callable(mesh, policy) applied to canonical operands
DISTRIBUTED_ROUTINES = ("pdgemm", "pdtrsm")


def _distributed_args(name: str) -> Tuple[tuple, dict]:
    """Canonical float32 operands for one direct distributed entry."""
    r = _rng()
    if name == "pdgemm":
        return (_mat(r, _M, _K), _mat(r, _K, _N)), {}
    if name == "pdtrsm":
        t = np.tril(_mat(r, _N, _N)) + _N * np.eye(_N, dtype=np.float32)
        return (t.astype(np.float32), _mat(r, _N, _K)), {}
    raise KeyError(name)


def check_distributed(meshes: Sequence[Tuple[int, int]] = SURFACE_MESHES,
                      policies: Sequence[str] = SURFACE_POLICIES,
                      dtypes: Sequence[str] = SURFACE_DTYPES,
                      allowlist: Optional[Allowlist] = None,
                      machine=None, progress: Optional[Callable] = None
                      ) -> AnalysisReport:
    """Sweep the direct ``pdgemm``/``pdtrsm`` entry points.

    Unlike the ``linalg.use(mesh=...)`` legs of :func:`check_surface`
    (which route the *single-device* surface through the context), this
    calls :mod:`repro.blas.distributed` directly with a real Mesh, so the
    SUMMA schedule - ppermute rings, recorded hops/bytes, plan_pdgemm's
    collective term - is on the traced path for the CC/SH rules. Meshes
    needing more devices than the backend has record skipped cases.
    """
    import functools
    from repro.blas import distributed as _dist
    reports: List[AnalysisReport] = []
    for mesh in meshes:
        px, py = int(mesh[0]), int(mesh[1])
        ndev = px * py
        mesh_ok = len(jax.devices()) >= ndev
        mesh_obj = _dist.make_blas_mesh(px, py) if mesh_ok else None
        for name in DISTRIBUTED_ROUTINES:
            base = _distributed_args(name)
            fn = getattr(_dist, name)
            for dtype in dtypes:
                with _x64():
                    args, kw = _cast_args(*base, jnp.dtype(dtype))
                for policy in policies:
                    case = {"routine": name, "policy": policy,
                            "dtype": dtype, "mesh": [px, py],
                            "entry": "direct"}
                    if not mesh_ok:
                        reports.append(AnalysisReport(
                            name, [dict(case,
                                        skipped=f"needs {ndev} devices")],
                            [], []))
                        continue
                    if progress is not None:
                        progress(case)
                    call = functools.partial(fn, mesh=mesh_obj,
                                             policy=policy)
                    reports.append(check(
                        call, *args, routine=name, machine=machine,
                        allowlist=allowlist, drift=False, retrace=False,
                        case=case, **kw))
    return merge_reports(reports, target="distributed-surface")


def surface_routines() -> List[str]:
    """The checkable (callable, arg-synthesizable) slice of linalg.__all__."""
    from repro import linalg
    return [n for n in linalg.__all__ if _surface_args(n) is not None]


def check_surface(routines: Optional[Sequence[str]] = None,
                  policies: Sequence[str] = SURFACE_POLICIES,
                  dtypes: Sequence[str] = SURFACE_DTYPES,
                  mesh: Optional[Tuple[int, int]] = SURFACE_MESH,
                  allowlist: Optional[Allowlist] = None,
                  machine=None, progress: Optional[Callable] = None,
                  meshes: Optional[Sequence[Tuple[int, int]]] = None,
                  base_leg: bool = True,
                  distributed: Optional[bool] = None) -> AnalysisReport:
    """Sweep the public surface over the acceptance grid and merge.

    Grid: routines x policies x dtypes x {no mesh, meshes}, plus (for a
    full default sweep) the direct distributed entry points of
    :func:`check_distributed`. ``mesh`` is the legacy single-mesh knob:
    left at its default it expands to ``SURFACE_MESHES``; set explicitly
    it pins exactly that mesh (``None`` = no mesh legs). ``meshes``
    overrides both. A mesh leg needs ``px * py`` devices and records a
    skipped case when the backend has fewer
    (``scripts/check_static_analysis.py`` re-execs itself with forced
    host devices so CI never skips it). ``base_leg=False`` drops the
    no-mesh legs (the SPMD-only sweep); ``distributed`` defaults to True
    exactly for unrestricted default-grid sweeps. Drift and retrace
    probes run on the no-mesh legs only: annotations are
    mesh-independent, and the census does not descend into shard_map.
    """
    from repro import linalg
    names = list(routines) if routines is not None else surface_routines()
    if meshes is None:
        if mesh is None:
            meshes = ()
        elif tuple(mesh) == SURFACE_MESH:
            meshes = SURFACE_MESHES
        else:
            meshes = (tuple(mesh),)
    meshes = tuple(tuple(m) for m in meshes)
    if distributed is None:
        distributed = routines is None and bool(meshes)
    reports: List[AnalysisReport] = []
    for name in names:
        base = _surface_args(name)
        if base is None:
            raise KeyError(f"no canonical surface args for {name!r}")
        fn = getattr(linalg, name)
        for dtype in dtypes:
            with _x64():
                args, kw = _cast_args(*base, jnp.dtype(dtype))
            for policy in policies:
                legs = ([None] if base_leg else []) + list(meshes)
                for leg in legs:
                    case = {"routine": name, "policy": policy,
                            "dtype": dtype,
                            "mesh": None if leg is None else list(leg)}
                    if leg is not None and \
                            len(jax.devices()) < int(np.prod(leg)):
                        reports.append(AnalysisReport(
                            name, [dict(case, skipped="needs "
                                        f"{int(np.prod(leg))} devices")],
                            [], []))
                        continue
                    if progress is not None:
                        progress(case)
                    with linalg.use(policy=policy, mesh=leg):
                        reports.append(check(
                            fn, *args, machine=machine, allowlist=allowlist,
                            drift=(leg is None and policy == "reference"
                                   ), retrace=leg is None, case=case, **kw))
    if distributed and meshes:
        reports.append(check_distributed(
            meshes=meshes, policies=policies, dtypes=dtypes,
            allowlist=allowlist, machine=machine, progress=progress))
    return merge_reports(reports, target="linalg-surface")
