"""Frozen rule vocabulary, findings, and suppression for repro.analysis.

The rule IDs below are a *frozen public contract* (mirrored by
``scripts/check_api_surface.py``): CI allowlists, doc references, and
seeded-violation tests all key on them, so an ID may gain wording but
never disappear or change severity silently. Three families mirror the
paper's static-structure claim (performance is predictable from the DAG):

* ``KL...`` kernel-launch rules - the Pallas launch geometry contract
  (block divisibility, VMEM budget, index dtypes, zero-dim routing),
* ``DF...`` dtype-flow rules - precision discipline in the traced jaxpr
  (no silent f64, accumulator widths, convert round-trips, host calls),
* ``CM...`` cost-model-drift rules - the hand-written ``flops``/``bytes``
  span annotations must keep agreeing with jaxpr-derived counts.

Suppression is structured, never a bare boolean: the ``allow()`` context
scopes rule IDs (optionally to one routine) for a ``with`` block, and an
allowlist JSON file pins per-call-site exemptions with a reason. Both
paths *record* the suppression on the report instead of dropping the
finding. Allowlist loading follows the registry convention
(``repro.tune.registry``): a missing file is silently empty, a corrupt
file warns once per path and is treated as empty - a broken allowlist can
re-fire findings, never hide new ones.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import warnings
from collections import OrderedDict
from contextvars import ContextVar
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

SCHEMA_VERSION = 1

ERROR = "error"
WARN = "warn"
INFO = "info"
SEVERITIES = (ERROR, WARN, INFO)


@dataclasses.dataclass(frozen=True)
class Rule:
    """One frozen rule: stable ID, severity, and the invariant it checks."""

    id: str
    title: str
    severity: str
    description: str


RULES: "OrderedDict[str, Rule]" = OrderedDict((r.id, r) for r in (
    Rule("KL001", "kernel-block-geometry", ERROR,
         "Pallas block shapes must divide the padded operand dims they "
         "tile, and resolved GEMM-plan tiles must stay aligned to the "
         "machine's sublane - a non-dividing or misaligned block launches "
         "partial tiles the kernels were never written to mask."),
    Rule("KL002", "kernel-vmem-budget", ERROR,
         "The modeled VMEM working set of every Pallas launch "
         "(double-buffered operand blocks + scratch) and every resolved "
         "plan must fit MachineSpec.memory.vmem_bytes - the same veto "
         "FusedChainPlan.fits_vmem applies to fusion."),
    Rule("KL003", "kernel-index-dtype", ERROR,
         "Index/iota/grid arithmetic inside a Pallas kernel body must be "
         "int32 even under JAX_ENABLE_X64; a 64-bit index dtype is the "
         "exact class of the PR 8 trsm_gemm crash."),
    Rule("KL004", "kernel-zero-dim-routing", ERROR,
         "Zero-dim operands must route to the plain-jnp fallback: a "
         "Pallas launch (or a trace-time crash) on an empty operand is "
         "the PR 8 _gemm_exec bug class."),
    Rule("DF001", "dtype-silent-f64", ERROR,
         "Under an f32/bf16 ExecutionContext no traced intermediate may "
         "silently promote to float64 (checked with x64 enabled, where "
         "promotion is representable)."),
    Rule("DF002", "dtype-accum-width", ERROR,
         "float64 operands must keep float64 accumulators: a dot_general "
         "over f64 inputs may not emit a narrower output."),
    Rule("DF003", "dtype-convert-roundtrip", WARN,
         "A convert_element_type round-trip through a narrower dtype "
         "(A -> B -> A with B narrower) destroys precision invisibly."),
    Rule("DF004", "dtype-host-transfer", ERROR,
         "Traced routine bodies must stay on device: host callbacks "
         "(pure/io/debug callback) and device_put transfers do not belong "
         "in the jaxpr of a BLAS/LAPACK routine."),
    Rule("CM001", "cost-flops-drift", ERROR,
         "The flops a routine's span annotation declares must agree with "
         "the jaxpr_census-derived count within the routine's declared "
         "tolerance (per shape and dtype)."),
    Rule("CM002", "cost-bytes-drift", WARN,
         "The bytes a routine's span annotation declares must agree with "
         "the traced operand/result bytes within the routine's declared "
         "tolerance."),
    Rule("CM003", "cost-retrace-instability", WARN,
         "Tracing the same routine twice with identical shapes/dtypes "
         "must produce the same jaxpr - a drifting trace means an "
         "unstable jit cache key (retrace per call)."),
    Rule("CC001", "collective-ring-permutation", ERROR,
         "Every ppermute permutation must be a bijective single-cycle "
         "ring over its mesh axis: a self-send, duplicate endpoint, "
         "partial coverage, or multi-cycle perm deadlocks or drops "
         "panels at runtime instead of failing a test."),
    Rule("CC002", "collective-hop-count", ERROR,
         "Ring-broadcast hop accounting must match the traced schedule: "
         "every recorded ring_bcast performs exactly size - 1 ppermute "
         "hops on its axis, and the jaxpr hop census must equal the "
         "recorded and counter totals."),
    Rule("CC003", "collective-bytes-drift", ERROR,
         "Jaxpr-derived on-wire collective bytes must agree with the obs "
         "collective counters and with plan_pdgemm's collective term "
         "within the declared comm tolerance - the distributed sibling "
         "of CM001."),
    Rule("SH001", "shardmap-spec-shape", ERROR,
         "shard_map in/out specs must be consistent with operand shapes "
         "and the mesh: every named dim divisible by its mesh-axes "
         "extent, every referenced axis present on the mesh, no spec "
         "entry beyond the operand rank."),
    Rule("SH002", "shardmap-pad-discipline", ERROR,
         "Ragged batches sharded over a mesh must be identity-padded to "
         "a device-count multiple (minimal pad, invertible filler) - the "
         "lapack.distributed discipline that keeps every padded item "
         "factorizable."),
    Rule("SH003", "shardmap-replication", WARN,
         "No unintended replication of sharded operands: an all_gather / "
         "all_to_all inside a shard_map body materializes a sharded "
         "operand on every device, defeating the sharding its specs "
         "declared."),
    Rule("BY001", "dispatcher-bypass", ERROR,
         "Raw dot_general/conv contractions reachable from the model "
         "zoo, the hand-rolled attention/SSD kernels, or the serving "
         "path that never pass through tune.dispatch.resolve bypass the "
         "dispatcher; every such site must be on the committed burn-down "
         "allowlist (new sites fail CI)."),
))


# Cost-model drift tolerances, as a symmetric relative error
# |annotated - derived| / max(annotated, derived). The annotations are
# *leading-order paper coefficients* (see repro.linalg.blas /
# repro.linalg.lapack), while the census counts every traced op, so each
# routine declares how much lower-order structure its annotation ignores.
# These are declared bounds, not aspirations: the drift rules exist to
# catch *changes* that push a routine outside its band (an accidental
# O(n^4) update, a dropped term), exactly like tune.measure's
# model_residual bands the measured side.
DRIFT_FLOPS_TOL: Dict[str, float] = {
    # the GEMM-shaped ops trace within ~2% of their 2mnk annotations;
    # default covers them plus the level-1 ops whose bookkeeping the
    # 2n-style annotations ignore (measured <= 0.33 at lint shapes)
    "default": 0.45,
    # overflow-safe nrm2 does an extra abs/max/scale pass (measured 0.50)
    "nrm2": 0.65,
    # row-sequential triangular solves: the traced scan masks the full
    # vector per row, n^2-ish overhead on the n^2 annotation (0.76/0.52)
    "trsv": 0.85, "trsm": 0.70,
    # blocked factorizations: the masked right-looking implementations
    # trace full-matrix updates per step (~2n^3 traced volume against the
    # leading-order n^3/3-style coefficients; measured 0.67-0.93). The
    # band is tight in ratio terms: a complexity-class regression (an
    # accidental O(n^4) update) lands at drift > 0.98 and still fires.
    "cholesky": 0.90, "lu": 0.90, "qr": 0.80, "solve": 0.88, "lstsq": 0.96,
    "batched_cholesky": 0.90, "batched_lu": 0.90, "batched_qr": 0.90,
    "batched_solve": 0.82,
}
DRIFT_BYTES_TOL: Dict[str, float] = {
    # annotations price *operand* bytes; the traced boundary adds the
    # results, up to ~2x for the write-heavy ops (measured <= 0.51)
    "default": 0.60,
    # syrk annotates A only, the boundary carries the n x n product
    # (0.60); qr's boundary carries Q and R (0.67)
    "syrk": 0.72, "qr": 0.78, "batched_qr": 0.72,
}


DRIFT_COMM_TOL: Dict[str, float] = {
    # the three sides of CC003 (traced ppermute bytes, obs counters,
    # plan_pdgemm's collective term) agree *exactly* on the direct pdgemm
    # path today - measured drift 0.0 across meshes {(1,1),(2,2),(4,2)} x
    # {f32,bf16,f64}. The band is slack for rounding in future
    # overlap/2.5D schedules, not for today's code.
    "default": 0.02,
}


def drift_tolerance(table: Mapping[str, float], routine: Optional[str]) -> float:
    return table.get(routine or "", table["default"])


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule hit: what fired, where, and whether it was suppressed."""

    rule: str
    severity: str
    routine: Optional[str]
    message: str
    location: Optional[str] = None
    case: Optional[Mapping] = None      # {"policy","dtype","mesh",...}
    suppressed: bool = False
    suppressed_by: Optional[str] = None  # "allow()" | "allowlist:<path>"

    def to_json(self) -> Dict:
        d = {"rule": self.rule, "severity": self.severity,
             "routine": self.routine, "message": self.message,
             "location": self.location, "suppressed": self.suppressed}
        if self.case is not None:
            d["case"] = dict(self.case)
        if self.suppressed_by is not None:
            d["suppressed_by"] = self.suppressed_by
        return d


def make_finding(rule_id: str, message: str, routine: Optional[str] = None,
                 location: Optional[str] = None,
                 case: Optional[Mapping] = None) -> Finding:
    rule = RULES[rule_id]
    return Finding(rule=rule.id, severity=rule.severity, routine=routine,
                   message=message, location=location, case=case)


# ------------------------------- suppression --------------------------------

_ALLOW: "ContextVar[Tuple[Tuple[str, Optional[str]], ...]]" = ContextVar(
    "analysis_allow", default=())


@contextlib.contextmanager
def allow(*rule_ids: str, routine: Optional[str] = None):
    """Scope-suppress rule IDs (optionally for one routine only).

    Findings that match inside the block are still *recorded* - they land
    in ``AnalysisReport.suppressed`` with ``suppressed_by="allow()"`` -
    they just stop counting as failures. Unknown IDs raise immediately so
    a typo cannot silently allow nothing.
    """
    for rid in rule_ids:
        if rid not in RULES:
            raise KeyError(f"unknown rule id {rid!r}; known: "
                           f"{', '.join(RULES)}")
    frames = _ALLOW.get() + tuple((rid, routine) for rid in rule_ids)
    token = _ALLOW.set(frames)
    try:
        yield
    finally:
        _ALLOW.reset(token)


def _context_allows(finding: Finding) -> bool:
    for rid, routine in _ALLOW.get():
        if rid == finding.rule and (routine is None
                                    or routine == finding.routine):
            return True
    return False


_warned_paths: set = set()


@dataclasses.dataclass(frozen=True)
class Allowlist:
    """Parsed allowlist file: (rule, routine-or-None, reason) entries."""

    path: Optional[str] = None
    entries: Tuple[Tuple[str, Optional[str]], ...] = ()

    def matches(self, finding: Finding) -> bool:
        for rid, routine in self.entries:
            if rid == finding.rule and (routine is None
                                        or routine == finding.routine):
                return True
        return False


def load_allowlist(path: Optional[str]) -> Allowlist:
    """Load a JSON allowlist; registry-convention fallbacks.

    Format: ``{"schema_version": 1, "allow": [{"rule": "CM002",
    "routine": "qr", "reason": "..."}]}`` (``routine`` optional = any).
    Missing file -> silently empty (cold start). Corrupt / wrong-schema
    file -> ``RuntimeWarning`` once per path, treated as empty, so a bad
    allowlist re-fires its findings instead of hiding new ones.
    """
    if path is None or not os.path.exists(path):
        return Allowlist(path=path)
    try:
        with open(path) as f:
            raw = json.load(f)
        if int(raw.get("schema_version", -1)) != SCHEMA_VERSION:
            raise ValueError(f"schema_version {raw.get('schema_version')!r}"
                             f" != {SCHEMA_VERSION}")
        entries = []
        for e in raw["allow"]:
            rid = str(e["rule"])
            if rid not in RULES:
                raise ValueError(f"unknown rule id {rid!r}")
            entries.append((rid, e.get("routine")))
        return Allowlist(path=path, entries=tuple(entries))
    except Exception as exc:  # corrupt: warn once, never hide findings
        if path not in _warned_paths:
            _warned_paths.add(path)
            warnings.warn(f"analysis allowlist {path!r} is corrupt "
                          f"({exc}); treating as empty", RuntimeWarning,
                          stacklevel=2)
        return Allowlist(path=path)


def apply_suppression(findings: Sequence[Finding],
                      allowlist: Optional[Allowlist] = None
                      ) -> Tuple[List[Finding], List[Finding]]:
    """Split findings into (active, suppressed), tagging the suppressor."""
    active: List[Finding] = []
    suppressed: List[Finding] = []
    for f in findings:
        if _context_allows(f):
            suppressed.append(dataclasses.replace(
                f, suppressed=True, suppressed_by="allow()"))
        elif allowlist is not None and allowlist.matches(f):
            suppressed.append(dataclasses.replace(
                f, suppressed=True,
                suppressed_by=f"allowlist:{allowlist.path}"))
        else:
            active.append(f)
    return active, suppressed
