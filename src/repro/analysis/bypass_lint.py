"""BY001: interprocedural dispatcher-bypass lint over the model/serving layer.

Every GEMM-shaped contraction in this repo is supposed to flow through
:func:`repro.tune.dispatch.resolve` so the policy/registry machinery can
route it onto the tuned Pallas path. The model zoo and the hand-rolled
attention/SSD kernels predate that discipline: their ``dot_general``s are
raw. This lint makes the debt *visible and monotone* instead of silent -
it traces the real entry points (``zoo.forward`` / ``zoo.decode_step``
per architecture family, the serving prefill path, and the two standalone
kernels), walks every jaxpr including Pallas kernel bodies, and
attributes each raw contraction to its source site. Sites living under
:data:`repro.tune.dispatch.DISPATCHED_MODULES` are dispatched by
construction; everything else is a bypass and must appear on the
committed burn-down allowlist (``bypass_allowlist.json``) with a reason.
A *new* bypass site fails CI; deleting an entry as code migrates onto the
dispatcher is the burn-down.

Traces run *without* x64 (models use int32 tokens and run in their
declared dtype), unlike the BLAS lint mode in ``report._trace``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import warnings
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import rules
from repro.analysis.jaxpr_lint import iter_eqns
from repro.analysis.rules import Finding, make_finding

# the contraction primitives the dispatcher exists to route
CONTRACTION_PRIMITIVES = ("dot_general", "conv_general_dilated")

# one representative architecture per model family
BYPASS_ARCHS = ("gemma-7b", "whisper-small", "mamba2-130m", "hymba-1.5b",
                "internvl2-1b", "qwen3-moe-235b-a22b")

DEFAULT_ALLOWLIST_PATH = os.path.join(os.path.dirname(__file__),
                                      "bypass_allowlist.json")


# ------------------------------ entry points --------------------------------

def _reduced(arch: str):
    import dataclasses as _dc
    from repro.configs import registry
    from repro.launch.train import reduce_config
    cfg = reduce_config(registry.get_config(arch), layers=2, d_model=64,
                        vocab=128, heads=4)
    return _dc.replace(cfg, accum_steps=1, dtype="float32")


def _init(cfg):
    from repro.models import model_zoo as zoo
    return zoo.init(jax.random.PRNGKey(0), cfg)


def _batch(cfg, batch: int = 4, seq: int = 16):
    from repro.data.pipeline import DataConfig, make_batch
    return make_batch(cfg, DataConfig(vocab=cfg.vocab, global_batch=batch,
                                      seq_len=seq), 0)


def _forward_builder(arch: str):
    def build():
        from repro.models import model_zoo as zoo
        cfg = _reduced(arch)
        params, batch = _init(cfg), _batch(cfg)

        def fn(p, b):
            return zoo.forward(p, b, cfg, use_pallas=False)
        return fn, (params, batch), {}
    return build


def _decode_builder(arch: str):
    def build():
        from repro.models import model_zoo as zoo
        cfg = _reduced(arch)
        params = _init(cfg)
        b = 2
        memory = None
        if cfg.family == "encdec":
            memory = jax.random.normal(jax.random.PRNGKey(1),
                                       (b, 8, cfg.d_model), jnp.float32)
        caches = zoo.init_caches(params, cfg, b, 24, memory=memory,
                                 dtype=jnp.float32)
        tok = jnp.zeros((b, 1), jnp.int32)

        def fn(p, t, c):
            return zoo.decode_step(p, t, cfg, c, jnp.int32(0))
        return fn, (params, tok, caches), {}
    return build


def _serve_builder():
    def build():
        # mirrors launch/serve.py's compute path exactly
        from repro.models import model_zoo as zoo
        cfg = _reduced("mamba2-130m")
        params, batch = _init(cfg), _batch(cfg)

        def fn(p, b):
            return zoo.prefill(p, b, cfg, use_pallas=False)
        return fn, (params, batch), {}
    return build


def _attention_builder():
    def build():
        from repro.kernels.flash_attention import attention
        r = np.random.default_rng(0)
        q, k, v = (jnp.asarray(r.standard_normal((2, 2, 32, 16)),
                               jnp.float32) for _ in range(3))

        def fn(q_, k_, v_):
            return attention(q_, k_, v_, interpret=True)
        return fn, (q, k, v), {}
    return build


def _ssd_builder():
    def build():
        from repro.kernels.ssd_scan import ssd_scan
        r = np.random.default_rng(0)
        x = jnp.asarray(r.standard_normal((2, 2, 32, 4)), jnp.float32)
        a_log = jnp.asarray(-np.abs(r.standard_normal((2, 2, 32))),
                            jnp.float32)
        B = jnp.asarray(r.standard_normal((2, 2, 32, 4)), jnp.float32)
        C = jnp.asarray(r.standard_normal((2, 2, 32, 4)), jnp.float32)

        def fn(x_, a_, b_, c_):
            return ssd_scan(x_, a_, b_, c_, interpret=True)
        return fn, (x, a_log, B, C), {}
    return build


def default_entries() -> List[Tuple[str, Callable]]:
    """(name, builder) per lintable entry point; builders are lazy so one
    broken family cannot stop the others from being collected."""
    entries: List[Tuple[str, Callable]] = []
    for arch in BYPASS_ARCHS:
        entries.append((f"zoo.forward[{arch}]", _forward_builder(arch)))
        entries.append((f"zoo.decode_step[{arch}]", _decode_builder(arch)))
    entries.append(("serve.prefill", _serve_builder()))
    entries.append(("kernels.flash_attention", _attention_builder()))
    entries.append(("kernels.ssd_scan", _ssd_builder()))
    return entries


# --------------------------- site classification ----------------------------

def _site_of(eqn) -> Optional[str]:
    """``repro/<path>.py:<function>`` for one eqn, or None if unknown."""
    try:
        from jax._src import source_info_util
        frame = source_info_util.user_frame(eqn.source_info)
    except Exception:
        frame = None
    if frame is None:
        return None
    path = str(frame.file_name).replace("\\", "/")
    idx = path.rfind("/repro/")
    if idx >= 0:
        path = path[idx + 1:]
    return f"{path}:{frame.function_name}"


def _is_dispatched(site: str) -> bool:
    from repro.tune.dispatch import DISPATCHED_MODULES
    path = site.split(":", 1)[0]
    return any(path.startswith(p) for p in DISPATCHED_MODULES)


def collect_bypass_sites(entries: Optional[Sequence[Tuple[str, Callable]]]
                         = None, progress: Optional[Callable] = None
                         ) -> "Tuple[OrderedDict, List[Dict]]":
    """Trace every entry and attribute its raw contractions.

    Returns ``(sites, cases)``: ``sites`` maps each *bypass* site key
    (``repro/<file>:<function>``) to ``{"primitives", "count",
    "entries"}``; ``cases`` records per-entry totals (including entries
    that failed to build, so a broken family is visible, not silent).
    """
    entries = default_entries() if entries is None else list(entries)
    sites: "OrderedDict[str, Dict]" = OrderedDict()
    cases: List[Dict] = []
    for name, build in entries:
        if progress is not None:
            progress(name)
        try:
            fn, args, kw = build()
            closed = jax.make_jaxpr(lambda *a: fn(*a, **kw))(*args)
        except Exception as exc:
            cases.append({"entry": name, "error":
                          f"{type(exc).__name__}: {exc}"})
            continue
        contractions = bypasses = 0
        for eqn, _ in iter_eqns(closed.jaxpr):
            if eqn.primitive.name not in CONTRACTION_PRIMITIVES:
                continue
            contractions += 1
            site = _site_of(eqn) or f"<unknown>:{eqn.primitive.name}"
            if _is_dispatched(site):
                continue
            bypasses += 1
            rec = sites.setdefault(site, {"primitives": set(), "count": 0,
                                          "entries": set()})
            rec["primitives"].add(eqn.primitive.name)
            rec["count"] += 1
            rec["entries"].add(name)
        cases.append({"entry": name, "contractions": contractions,
                      "bypasses": bypasses})
    for rec in sites.values():
        rec["primitives"] = sorted(rec["primitives"])
        rec["entries"] = sorted(rec["entries"])
    return sites, cases


# ------------------------------- allowlist ----------------------------------

def load_bypass_allowlist(path: Optional[str] = DEFAULT_ALLOWLIST_PATH
                          ) -> Dict[str, str]:
    """``{site: reason}`` from the burn-down file; registry convention.

    Missing file -> silently empty (cold start: every bypass fires).
    Corrupt / wrong-schema file -> ``RuntimeWarning`` once per path and
    treated as empty, so breakage re-fires findings, never hides one.
    """
    if path is None or not os.path.exists(path):
        return {}
    try:
        with open(path) as f:
            raw = json.load(f)
        if int(raw.get("schema_version", -1)) != rules.SCHEMA_VERSION:
            raise ValueError(f"schema_version {raw.get('schema_version')!r}"
                             f" != {rules.SCHEMA_VERSION}")
        if raw.get("rule") != "BY001":
            raise ValueError(f"rule {raw.get('rule')!r} != 'BY001'")
        return {str(e["site"]): str(e.get("reason", ""))
                for e in raw["sites"]}
    except Exception as exc:
        if path not in rules._warned_paths:
            rules._warned_paths.add(path)
            warnings.warn(f"bypass allowlist {path!r} is corrupt ({exc}); "
                          "treating as empty", RuntimeWarning, stacklevel=2)
        return {}


def save_bypass_allowlist(sites: Dict[str, Dict], path: str,
                          reason: str = "pre-dispatcher site; burn down"
                          ) -> str:
    """Write the burn-down file for the current bypass set."""
    payload = {"schema_version": rules.SCHEMA_VERSION, "rule": "BY001",
               "sites": [{"site": s, "reason": reason,
                          "primitives": info["primitives"],
                          "entries": info["entries"]}
                         for s, info in sorted(sites.items())]}
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


# --------------------------------- driver -----------------------------------

def lint_bypass(entries: Optional[Sequence[Tuple[str, Callable]]] = None,
                allowlist: Optional[str] = DEFAULT_ALLOWLIST_PATH,
                progress: Optional[Callable] = None):
    """BY001 over the model/serving/kernel entry points -> AnalysisReport.

    One finding per unique bypass site; sites on the committed allowlist
    land in ``report.suppressed`` (tagged ``allowlist:<path>``), so
    ``report.ok`` fails exactly when a *new* bypass appears.
    """
    from repro.analysis.report import AnalysisReport
    sites, cases = collect_bypass_sites(entries, progress=progress)
    allowed = load_bypass_allowlist(allowlist)
    active: List[Finding] = []
    suppressed: List[Finding] = []
    for site, info in sites.items():
        f = make_finding(
            "BY001", f"raw {'/'.join(info['primitives'])} at {site} "
            f"({info['count']} eqn(s), reachable from "
            f"{', '.join(info['entries'])}) never passes "
            "tune.dispatch.resolve",
            routine=info["entries"][0], location=site,
            case={"entries": info["entries"]})
        if site in allowed:
            suppressed.append(dataclasses.replace(
                f, suppressed=True, suppressed_by=f"allowlist:{allowlist}"))
        else:
            active.append(f)
    return AnalysisReport("dispatcher-bypass", cases, active, suppressed)
