"""Measured config sweeps, seeded by the analytical model - not brute force.

Candidate generation asks :mod:`repro.core.codesign` for the model's own
pick plus its VMEM-feasible neighbors, then *ranks* them with the same two
models the rest of the repo is built on:

* :mod:`repro.core.roofline` terms - a candidate's achievable FLOP rate is
  ``min(PEAK, arithmetic_intensity * HBM_BW)`` at its tiling;
* :mod:`repro.core.pipeline_model` eq. 2 - the HBM->VMEM grid is a software
  pipeline whose "instructions" are grid steps and whose hazards are the
  K-carried accumulator dependencies, so ``tpi(p, n_i, n_h, ...)`` prices
  the per-step overhead (fill never amortized on short grids, fig. 2).

Only the ``top_k`` model-ranked candidates are actually measured (wall
time of the jitted kernel, interpret mode on CPU), and the measured winner
is recorded in the registry. This is the ELAPS loop: model proposes,
measurement disposes.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import arch as _arch
from repro.arch import MachineSpec
from repro.core import pipeline_model
from repro.core.codesign import (GemmPlan, plan_from_blocks, plan_fused_chain,
                                 plan_gemm, plan_trsm)
from repro.tune import measure as _measure
from repro.tune.registry import KernelConfig, Registry, default_registry


# machine resolution + registry-key component: the shared arch helpers
# (recording here and lookup in dispatch must agree on the namespace rule)
_mach = _arch.resolve_machine
_mach_key = _arch.machine_key_component


def _block_grid(mach: MachineSpec) -> Tuple[int, ...]:
    """Sweep neighborhood: 1x / 2x / 4x the machine's systolic edge."""
    return (mach.pe.mxu, 2 * mach.pe.mxu, 4 * mach.pe.mxu)


def model_score(plan: GemmPlan, m: int, n: int, k: int,
                dtype_bytes: int,
                machine: Optional[MachineSpec] = None) -> float:
    """Modeled seconds for one GEMM at this tiling (lower is better)."""
    mach = _mach(machine)
    flops = 2.0 * m * n * k
    roofline_rate = min(mach.pe.peak_flops,
                        plan.arithmetic_intensity * mach.memory.hbm_bw)
    compute_s = flops / roofline_rate
    # grid pipeline through eq. 2: steps are instructions, the K-carried
    # accumulator dependence is the hazard, DMA time is the logic delay,
    # per-step launch overhead is the latch overhead. Depth 2 = the kernel's
    # double buffering.
    g0, g1, g2 = plan.grid
    steps = max(g0 * g1 * g2, 1)
    hazards = g0 * g1 * max(g2 - 1, 0)
    t_dma = (plan.bm * plan.bk + plan.bk * plan.bn) * dtype_bytes         / mach.memory.hbm_bw
    per_step = float(pipeline_model.tpi(
        2.0, n_i=float(steps), n_h=float(hazards), gamma=0.5, t_p=t_dma,
        t_o=mach.memory.pipeline_fill_s))
    return max(compute_s, per_step * steps)


def gemm_candidates(m: int, n: int, k: int, dtype_bytes: int = 4,
                    max_candidates: int = 8,
                    vmem_budget: Optional[int] = None,
                    machine: Optional[MachineSpec] = None) -> List[GemmPlan]:
    """Model pick first, then its VMEM-feasible neighbors, ranked by
    :func:`model_score`. Never empty."""
    mach = _mach(machine)
    vmem_budget = mach.memory.vmem_bytes if vmem_budget is None         else vmem_budget
    seed = plan_gemm(m, n, k, dtype_bytes=dtype_bytes, machine=mach)
    seen = {(seed.bm, seed.bn, seed.bk)}
    cands = [seed]
    grid = _block_grid(mach)
    for bm in grid:
        for bn in grid:
            for bk in grid:
                p = plan_from_blocks(m, n, k, bm, bn, bk,
                                     dtype_bytes=dtype_bytes, machine=mach)
                key = (p.bm, p.bn, p.bk)
                if key in seen or p.vmem_bytes > vmem_budget:
                    continue
                seen.add(key)
                cands.append(p)
    ranked = sorted(cands, key=lambda p: model_score(p, m, n, k, dtype_bytes,
                                                     machine=mach))
    # the model seed always survives the cut (it is the fallback config)
    top = ranked[:max_candidates]
    if seed not in top:
        top[-1] = seed
    return top


@dataclasses.dataclass(frozen=True)
class SweepResult:
    """Trajectory record of one tuned op: every measured candidate plus the
    winner that went into the registry."""

    op: str
    shape: Tuple[int, ...]
    dtype: str
    backend: str
    measured: Tuple[dict, ...]          # [{params..., seconds}] model order
    best: KernelConfig
    model_params: dict                  # what the model alone would pick

    def to_json(self) -> dict:
        return {"op": self.op, "shape": list(self.shape), "dtype": self.dtype,
                "backend": self.backend, "measured": list(self.measured),
                "best": self.best.to_json(), "model_params": self.model_params}


# The one wall-clock path shared by the sweeps and the benchmark drivers
# now lives in repro.tune.measure (ELAPS-style repetition controller:
# per-rep samples, median + spread, every rep individually synchronized).
# measure_wall_time/_timeit stay importable from here for callers; the
# historical one-shot average (which left `out` unbound at reps=0 and only
# synchronized the final async dispatch) is gone.
measure_wall_time = _measure.measure_wall_time
_timeit = measure_wall_time


def tune_gemm(m: int, n: int, k: int, dtype=jnp.float32,
              registry: Optional[Registry] = None, top_k: int = 3,
              reps: int = 2, interpret: Optional[bool] = None,
              seed: int = 0,
              machine: Optional[MachineSpec] = None) -> SweepResult:
    """Sweep Pallas GEMM block shapes for one (m, n, k, dtype); record the
    measured winner in the registry keyed by the shape bucket (plus the
    machine component for a non-default ``machine``)."""
    from repro.kernels import ops                   # lazy: kernels optional
    mach = _mach(machine)
    reg = registry if registry is not None else default_registry()
    backend = jax.default_backend()
    interp = (backend != "tpu") if interpret is None else interpret
    dtype = jnp.dtype(dtype)
    model_pick = plan_gemm(m, n, k, dtype_bytes=dtype.itemsize, machine=mach)
    cands = gemm_candidates(m, n, k, dtype_bytes=dtype.itemsize,
                            max_candidates=max(top_k, 1), machine=mach)
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32)).astype(dtype)
    b = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32)).astype(dtype)
    measured = []
    best_i, best_t = 0, None
    for i, plan in enumerate(cands):
        f = jax.jit(lambda x, y, p=plan: ops.gemm(
            x, y, plan=p, use_pallas=True, interpret=interp))
        ms = _measure.measure(f, a, b, min_reps=reps, max_reps=2 * reps)
        t = ms.seconds_median
        model_s = model_score(plan, m, n, k, dtype.itemsize, machine=mach)
        measured.append({"bm": plan.bm, "bn": plan.bn, "bk": plan.bk,
                         "seconds": t, **ms.row_fields(),
                         "model_s": model_s,
                         "model_residual": _measure.model_residual(model_s, t)})
        if best_t is None or t < best_t:
            best_i, best_t = i, t
    win = cands[best_i]
    cfg = reg.record("gemm", (m, n, k), dtype, backend,
                     {"bm": win.bm, "bn": win.bn, "bk": win.bk},
                     source="sweep", measured_s=best_t,
                     machine=_mach_key(mach))
    return SweepResult("gemm", (m, n, k), dtype.name, backend,
                       tuple(measured), cfg,
                       {"bm": model_pick.bm, "bn": model_pick.bn,
                        "bk": model_pick.bk})


def tune_fused_gemm(m: int, n: int, k: int, epilogue: str = "relu",
                    dtype=jnp.float32, has_bias: bool = True,
                    registry: Optional[Registry] = None, reps: int = 2,
                    interpret: Optional[bool] = None, seed: int = 0,
                    machine: Optional[MachineSpec] = None) -> SweepResult:
    """Measure the fused GEMM+epilogue kernel against the staged chain
    (Pallas GEMM, then the epilogue as a separate jnp pass) at the chain
    plan's tiling, and record the measured winner under ``gemm+epilogue``.

    The registry entry carries the tiling plus a ``fused`` flag (0/1):
    dispatch honors the flag when resolving ``policy="tuned"``, so the
    sweep decides *whether* to fuse on this machine, not just how to
    tile. The chain model's ``fused_wins`` verdict is reported alongside
    as ``model_params`` for the trajectory record.
    """
    from repro.kernels import fused as _fk          # lazy: kernels optional
    from repro.kernels import ops                   # lazy: kernels optional
    mach = _mach(machine)
    reg = registry if registry is not None else default_registry()
    backend = jax.default_backend()
    interp = (backend != "tpu") if interpret is None else interpret
    dtype = jnp.dtype(dtype)
    chain = plan_fused_chain("gemm+epilogue", m, n, k,
                             dtype_bytes=dtype.itemsize, epilogue=epilogue,
                             has_bias=has_bias, machine=mach)
    plan = chain.gemm
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32)).astype(dtype)
    b = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32)).astype(dtype)
    bias = (jnp.asarray(rng.normal(size=(n,)).astype(np.float32)).astype(dtype)
            if has_bias else None)

    def staged(x, y, bb):
        c = ops.gemm(x, y, plan=plan, use_pallas=True, interpret=interp)
        return _fk.apply_epilogue(c, epilogue, bb)

    def fused_fn(x, y, bb):
        return _fk.gemm_bias_act(x, y, bias=bb, epilogue=epilogue,
                                 plan=plan, interpret=interp)

    measured = []
    best_i, best_t = 0, None
    for i, (name, fn) in enumerate((("staged", staged), ("fused", fused_fn))):
        f = jax.jit(fn)
        ms = _measure.measure(f, a, b, bias, min_reps=reps, max_reps=2 * reps)
        t = ms.seconds_median
        measured.append({"variant": name, "fused": int(name == "fused"),
                         "bm": plan.bm, "bn": plan.bn, "bk": plan.bk,
                         "seconds": t, **ms.row_fields(),
                         "model_s": (chain.fused_time if name == "fused"
                                     else chain.unfused_time)})
        if best_t is None or t < best_t:
            best_i, best_t = i, t
    fused_won = int(measured[best_i]["fused"])
    cfg = reg.record("gemm+epilogue", (m, n, k), dtype, backend,
                     {"bm": plan.bm, "bn": plan.bn, "bk": plan.bk,
                      "fused": fused_won},
                     source="sweep", measured_s=best_t,
                     machine=_mach_key(mach))
    return SweepResult("gemm+epilogue", (m, n, k), dtype.name, backend,
                       tuple(measured), cfg,
                       {"bm": plan.bm, "bn": plan.bn, "bk": plan.bk,
                        "fused": int(chain.fused_wins)})


def seed_registry_from_model(registry: Optional[Registry] = None,
                             gemm_shapes: Sequence[Tuple[int, int, int]] = (),
                             trsm_shapes: Sequence[Tuple[int, int]] = (),
                             dtypes: Sequence = (jnp.float32,),
                             backend: Optional[str] = None,
                             machine: Optional[MachineSpec] = None) -> int:
    """Record the *model's* pick for every (op, shape, dtype) as a real
    registry entry (``source="model"``, unmeasured).

    This is how non-swept dtypes (float64, bfloat16) get first-class
    registry entries instead of silently falling back at lookup time:
    the analytic planners are dtype-aware (operand bytes change the VMEM
    and roofline terms), so each dtype gets its own seeded config, and a
    later measured sweep simply overwrites the entry in place. Returns
    the number of entries recorded.
    """
    mach = _mach(machine)
    mkey = _mach_key(mach)
    reg = registry if registry is not None else default_registry()
    backend = backend or jax.default_backend()
    count = 0
    for dtype in dtypes:
        dt = jnp.dtype(dtype)
        for m, n, k in gemm_shapes:
            p = plan_gemm(m, n, k, dtype_bytes=dt.itemsize, machine=mach)
            reg.record("gemm", (m, n, k), dt, backend,
                       {"bm": p.bm, "bn": p.bn, "bk": p.bk}, source="model",
                       machine=mkey)
            count += 1
        for n, nrhs in trsm_shapes:
            p = plan_trsm(n, nrhs, dtype_bytes=dt.itemsize, machine=mach)
            reg.record("trsm", (n, nrhs), dt, backend,
                       {"block": p.block}, source="model", machine=mkey)
            count += 1
    return count


def trsm_candidates(n: int, nrhs: int, dtype_bytes: int = 4,
                    blocks: Sequence[int] = (16, 32, 64, 128),
                    machine: Optional[MachineSpec] = None) -> List[int]:
    """Model pick first, then the remaining distinct feasible widths."""
    seedb = plan_trsm(n, nrhs, dtype_bytes=dtype_bytes,
                      machine=machine).block
    out = [seedb]
    for b in blocks:
        b_ = min(int(b), max(int(n), 1))
        if b_ not in out:
            out.append(b_)
    return out


def tune_trsm(n: int, nrhs: int = 8, dtype=jnp.float32,
              registry: Optional[Registry] = None, reps: int = 2,
              blocks: Sequence[int] = (16, 32, 64, 128),
              seed: int = 0,
              machine: Optional[MachineSpec] = None) -> SweepResult:
    """Sweep the blocked-TRSM diagonal width; record the measured winner.

    Measured on the reference inner-GEMM path (the block trade-off - serial
    substitution vs trailing update - is the same on both paths, and the
    interpret-mode kernel would drown it in emulation overhead on CPU).
    """
    from repro.blas import level3                   # lazy: avoid import cycle
    mach = _mach(machine)
    reg = registry if registry is not None else default_registry()
    backend = jax.default_backend()
    dtype = jnp.dtype(dtype)
    rng = np.random.default_rng(seed)
    t_np = np.tril(rng.normal(size=(n, n))).astype(np.float32) \
        + 4.0 * np.eye(n, dtype=np.float32)
    t = jnp.asarray(t_np).astype(dtype)
    b = jnp.asarray(rng.normal(size=(n, nrhs)).astype(np.float32)).astype(dtype)
    cands = trsm_candidates(n, nrhs, dtype_bytes=dtype.itemsize, blocks=blocks,
                            machine=mach)
    measured = []
    best_i, best_t = 0, None
    for i, blk in enumerate(cands):
        f = jax.jit(lambda tt, bb, nb=blk: level3.trsm(
            tt, bb, lower=True, block=nb, policy="reference"))
        ms = _measure.measure(f, t, b, min_reps=reps, max_reps=2 * reps)
        sec = ms.seconds_median
        model_s = plan_trsm(n, nrhs, dtype_bytes=dtype.itemsize,
                            candidates=(blk,), machine=mach).modeled_time
        measured.append({"block": blk, "seconds": sec, **ms.row_fields(),
                         "model_s": model_s,
                         "model_residual": _measure.model_residual(model_s,
                                                                   sec)})
        if best_t is None or sec < best_t:
            best_i, best_t = i, sec
    cfg = reg.record("trsm", (n, nrhs), dtype, backend,
                     {"block": cands[best_i]}, source="sweep",
                     measured_s=best_t, machine=_mach_key(mach))
    return SweepResult("trsm", (n, nrhs), dtype.name, backend,
                       tuple(measured), cfg, {"block": cands[0]})
