"""Unified BLAS/LAPACK kernel-config resolution and execution.

``resolve`` is the single place a (op, shape, dtype, backend, policy)
tuple becomes an executable config; ``dispatch`` executes it. Every BLAS-3
and blocked-LAPACK call in the repo funnels through here - the old
``use_kernel`` booleans survive only as deprecated aliases that
:func:`repro.tune.policy.resolve_policy` folds into a policy.

Resolution table (``source`` records which row fired):

    policy      registry hit        registry miss / no file / corrupt
    ---------   -----------------   ---------------------------------
    reference   (never consulted)   plain jnp
    model       (never consulted)   plan_gemm / plan_trsm config
    tuned       stored config       model config  (source="fallback-model")

The miss column is why a cold-start ``tuned`` run is numerically identical
to ``model``: both execute the same kernel with the same plan.
"""
from __future__ import annotations

import contextlib
import dataclasses
from contextvars import ContextVar
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import arch as _arch
from repro import obs as _obs
from repro.arch import MachineSpec
from repro.core.codesign import (FusedChainPlan, GemmPlan, plan_from_blocks,
                                 plan_fused_chain, plan_gemm, plan_pdgemm,
                                 plan_trsm)
from repro.obs import counters as _counters
from repro.tune.policy import resolve_policy, uses_kernel
from repro.tune.registry import Registry, default_registry


OPS = ("gemm", "gemv", "trsm", "syrk", "pdgemm", "gemm+epilogue",
       "trsm+gemm")
FUSED_OPS = ("gemm+epilogue", "trsm+gemm")

# Resolution provenance for the dispatcher-bypass lint (BY001,
# repro.analysis.bypass_lint): every contraction traced from a source
# file under one of these prefixes reached ``resolve()``/``dispatch()``
# by construction - the BLAS/LAPACK drivers and the kernels this module
# launches are the *governed* set. A raw dot_general/conv whose source
# frame lies anywhere else (models/, launch/, the hand-rolled attention
# and SSD kernels) bypassed the dispatcher and must be on the committed
# burn-down allowlist. Frozen by scripts/check_api_surface.py.
DISPATCHED_MODULES = (
    "repro/blas/", "repro/lapack/", "repro/linalg/", "repro/tune/",
    "repro/core/",
    "repro/kernels/ops.py", "repro/kernels/ref.py", "repro/kernels/gemm.py",
    "repro/kernels/fused.py", "repro/kernels/dotp.py",
    "repro/kernels/compat.py",
)


@dataclasses.dataclass(frozen=True)
class Resolution:
    """The resolved execution recipe for one call."""

    op: str
    policy: str                   # "reference" | "model" | "tuned"
    source: str                   # "reference" | "model" | "registry" |
                                  # "fallback-model"
    use_pallas: bool
    gemm_plan: Optional[GemmPlan] = None
    block: Optional[int] = None   # trsm diagonal width
    mesh: Optional[str] = None    # registry mesh component (pdgemm)
    machine: Optional[str] = None   # machine the call resolved under
    fused: bool = False           # run the streaming fused kernel?
    chain: Optional[FusedChainPlan] = None   # fused-vs-staged pricing

    def describe(self) -> dict:
        """JSON-able summary - benchmarks attach this to every record so
        trajectories are comparable across PRs."""
        d = {"op": self.op, "policy": self.policy, "source": self.source,
             "use_pallas": self.use_pallas, "machine": self.machine}
        if self.gemm_plan is not None:
            d["config"] = {"bm": self.gemm_plan.bm, "bn": self.gemm_plan.bn,
                           "bk": self.gemm_plan.bk}
        if self.block is not None:
            d.setdefault("config", {})["block"] = self.block
        if self.mesh is not None:
            d["mesh"] = self.mesh
        if self.op in FUSED_OPS:
            d["fused"] = self.fused
            if self.chain is not None:
                d["hbm_bytes_saved"] = self.chain.hbm_bytes_saved
        return d


# scoped Resolution capture for the static analyzer: resolve() runs in
# Python at trace time, so every plan a jax.make_jaxpr trace produces can
# be recorded without executing anything (repro.analysis.kernel_lint
# checks the recorded plans against the ambient machine budget)
_RECORD: "ContextVar[Optional[List[Resolution]]]" = ContextVar(
    "dispatch_resolution_record", default=None)


@contextlib.contextmanager
def record_resolutions():
    """Collect every Resolution produced inside the scope (trace-safe)."""
    rec: List[Resolution] = []
    token = _RECORD.set(rec)
    try:
        yield rec
    finally:
        _RECORD.reset(token)


def _observed(res: "Resolution") -> "Resolution":
    """Resolution accounting: counters always, a provenance event when a
    trace is capturing (``obs.event("tune.resolve", ...)`` carrying
    :meth:`Resolution.describe` - the registry-hit / model-seeded /
    reference provenance every traced call records)."""
    rec = _RECORD.get()
    if rec is not None:
        rec.append(res)
    _counters.inc("dispatch.resolve")
    if res.policy == "tuned":
        _counters.inc("dispatch.registry_hit" if res.source == "registry"
                      else "dispatch.registry_miss")
    if _obs.enabled():
        _obs.event("tune.resolve", cat="resolve", **res.describe())
    return res


def resolve(op: str, shape: Tuple[int, ...], dtype,
            policy: Optional[str] = None, use_kernel: Optional[bool] = None,
            registry: Optional[Registry] = None,
            backend: Optional[str] = None,
            mesh: Optional[Tuple[int, int]] = None,
            machine: Optional[MachineSpec] = None,
            epilogue: str = "none", form: str = "lu",
            has_bias: bool = True) -> Resolution:
    """Resolve one call's config. shape is (m, n, k) for gemm/syrk/pdgemm
    (pdgemm: the *global* problem), (m, n) for gemv, (n, nrhs) for trsm.
    ``mesh`` is the (px, py) device mesh for pdgemm; its registry entries
    live under the mesh-suffixed key ``pdgemm|bucket|dtype|backend|pxXpyY``.
    ``machine`` parameterizes every planner and (for non-default machines)
    suffixes the registry key; ``None`` resolves the ambient
    :func:`repro.arch.current_machine` - which is what
    ``repro.linalg.use(machine=...)`` scopes for its routines.

    The fused chain ops take shape (m, n, k): ``"gemm+epilogue"`` is the
    GEMM problem (``epilogue``/``has_bias`` price the second stage),
    ``"trsm+gemm"`` the trailing update C[m, n] fed by a width-k panel
    solve (``form`` = "lu" | "syrk"). Their ``fused`` flag comes from
    :func:`repro.core.codesign.plan_fused_chain` under the kernel
    policies (a tuned registry hit stores the measured winner, still
    vetoed when the streamed kernel no longer fits the ambient machine's
    VMEM).
    """
    if op not in OPS:
        raise ValueError(f"unknown op {op!r}; expected one of {OPS}")
    if op == "pdgemm" and mesh is None:
        raise ValueError("pdgemm resolution needs mesh=(px, py)")
    mach = _arch.resolve_machine(machine)
    mach_str = _arch.machine_key_component(mach)
    mesh_str = f"x{mesh[0]}y{mesh[1]}" if (op == "pdgemm" and mesh) else None
    pol = resolve_policy(policy, use_kernel)
    if not uses_kernel(pol):
        if op == "trsm":
            # the reference path still needs a diagonal width; 64 is the
            # historical (pre-tuner) default
            return _observed(Resolution(op, pol, "reference", False, block=64,
                              machine=mach.name))
        return _observed(Resolution(op, pol, "reference", False, mesh=mesh_str,
                          machine=mach.name))
    dtype = jnp.dtype(dtype)
    backend = backend or jax.default_backend()
    cfg = None
    source = "model"
    if pol == "tuned":
        reg = registry if registry is not None else default_registry()
        # syrk and gemv execute as GEMMs, so they share the gemm registry
        # entries (gemv under its execution shape (m, 1, n))
        lookup_op, lookup_shape = op, shape
        if op == "syrk":
            lookup_op = "gemm"
        elif op == "gemv":
            lookup_op, lookup_shape = "gemm", (shape[0], 1, shape[1])
        cfg = reg.lookup(lookup_op, lookup_shape, dtype, backend,
                         mesh=mesh_str, machine=mach_str)
        source = "registry" if cfg is not None else "fallback-model"
    if op == "pdgemm":
        # the stored/planned config tiles the per-step *local* update
        # (m/px, k_fine) @ (k_fine, n/py) - see codesign.plan_pdgemm
        m, n, k = shape
        px, py = mesh
        pplan = plan_pdgemm(m, n, k, px, py, dtype_bytes=dtype.itemsize,
                            machine=mach)
        if cfg is not None:
            local = plan_from_blocks(
                -(-max(m, 1) // px), -(-max(n, 1) // py), pplan.k_fine,
                cfg.params["bm"], cfg.params["bn"], cfg.params["bk"],
                dtype_bytes=dtype.itemsize, machine=mach)
        else:
            local = pplan.local
        return _observed(Resolution(op, pol, source, True, gemm_plan=local,
                          mesh=mesh_str, machine=mach.name))
    if op in FUSED_OPS:
        m, n, k = shape
        chain = plan_fused_chain(op, m, n, k, dtype_bytes=dtype.itemsize,
                                 epilogue=epilogue, form=form,
                                 has_bias=has_bias, machine=mach)
        if cfg is not None:
            plan = plan_from_blocks(m, n, k, cfg.params["bm"],
                                    cfg.params["bn"], cfg.params["bk"],
                                    dtype_bytes=dtype.itemsize, machine=mach)
            # the registry stores the *measured* winner; the ambient
            # machine's VMEM budget still vetoes it
            fused = bool(cfg.params.get("fused", 1)) and chain.fits_vmem
        else:
            plan = chain.gemm
            fused = chain.fused_wins
        return _observed(Resolution(op, pol, source, True, gemm_plan=plan,
                          block=chain.block, machine=mach.name, fused=fused,
                          chain=chain))
    if op in ("gemm", "syrk"):
        m, n, k = shape
        if cfg is not None:
            plan = plan_from_blocks(m, n, k, cfg.params["bm"],
                                    cfg.params["bn"], cfg.params["bk"],
                                    dtype_bytes=dtype.itemsize, machine=mach)
        else:
            plan = plan_gemm(m, n, k, dtype_bytes=dtype.itemsize,
                             machine=mach)
        return _observed(Resolution(op, pol, source, True, gemm_plan=plan,
                          machine=mach.name))
    if op == "gemv":
        m, n = shape
        if cfg is not None:
            plan = plan_from_blocks(m, 1, n, cfg.params["bm"],
                                    cfg.params["bn"], cfg.params["bk"],
                                    dtype_bytes=dtype.itemsize, machine=mach)
        else:
            plan = plan_gemm(m, 1, n, dtype_bytes=dtype.itemsize,
                             machine=mach)
        return _observed(Resolution(op, pol, source, True, gemm_plan=plan,
                          machine=mach.name))
    # trsm
    n, nrhs = shape
    block = cfg.params["block"] if cfg is not None \
        else plan_trsm(n, nrhs, dtype_bytes=dtype.itemsize,
                       machine=mach).block
    return _observed(Resolution(op, pol, source, True, block=block, machine=mach.name))


def _gemm_exec(a, b, res: Resolution, interpret: bool):
    if not res.use_pallas or 0 in a.shape or 0 in b.shape:
        # degenerate operands (e.g. a wide-LU trailing block with no rows
        # left) cannot tile a Pallas grid; plain jnp handles empties
        return a @ b
    _counters.inc("kernel.launch")
    from repro.kernels import ops                   # lazy: kernels optional
    if b.ndim == 1:                                 # matvec through the MXU
        return ops.gemm(a, b[:, None], plan=res.gemm_plan, use_pallas=True,
                        interpret=interpret)[:, 0]
    return ops.gemm(a, b, plan=res.gemm_plan, use_pallas=True,
                    interpret=interpret)


def dispatch(op: str, *args, policy: Optional[str] = None,
             use_kernel: Optional[bool] = None, interpret: bool = True,
             registry: Optional[Registry] = None,
             machine: Optional[MachineSpec] = None, **kw):
    """One entry point for every BLAS-3 / blocked-LAPACK kernel call.

    dispatch("gemm", a, b)             -> a @ b (by policy)
    dispatch("syrk", a, trans=False)   -> a a^T / a^T a (by policy)
    dispatch("gemv", a, x, trans=...)  -> op(a) x (by policy)
    dispatch("trsm", a, b, lower=..., unit_diag=..., left=..., block=...)
    dispatch("gemm+epilogue", a, b, bias=..., epilogue=...)
                                       -> act(a @ b + bias); streamed in one
                                          fused kernel when the chain plan
                                          says fusing wins
    dispatch("trsm+gemm", l11, ap, bl, c, form=..., unit_diag=..., fuse=...)
                                       -> (x, c - bl x) / (x, c - x^T x);
                                          fuse=None defers to the chain
                                          plan, True/False forces

    alpha/beta epilogues stay in :mod:`repro.blas`; this layer only
    resolves and runs the kernel-shaped core of each op. An explicit
    ``machine`` scopes the whole call (including the cores it forwards
    to); ``None`` uses the ambient current machine.
    """
    if machine is not None:
        with _arch.machine_scope(machine):
            return dispatch(op, *args, policy=policy, use_kernel=use_kernel,
                            interpret=interpret, registry=registry, **kw)
    if op == "gemm":
        a, b = args
        n_out = b.shape[1] if b.ndim == 2 else 1
        res = resolve("gemm", (a.shape[0], n_out, a.shape[1]), a.dtype,
                      policy, use_kernel, registry)
        return _gemm_exec(a, b, res, interpret)
    if op == "syrk":
        (a,) = args
        trans = kw.pop("trans", False)
        op_a = a.T if trans else a
        res = resolve("syrk", (op_a.shape[0], op_a.shape[0], op_a.shape[1]),
                      a.dtype, policy, use_kernel, registry)
        return _gemm_exec(op_a, op_a.T, res, interpret)
    if op == "gemv":
        a, x = args
        trans = kw.pop("trans", False)
        op_a = a.T if trans else a
        res = resolve("gemv", op_a.shape, a.dtype, policy, use_kernel,
                      registry)
        if not res.use_pallas:
            return op_a @ x
        return _gemm_exec(op_a, x[:, None], res, interpret)[:, 0]
    if op == "trsm":
        a, b = args
        from repro.blas import level3               # lazy: avoid import cycle
        return level3.trsm(a, b, policy=policy, use_kernel=use_kernel,
                           interpret=interpret, registry=registry, **kw)
    if op == "pdgemm":
        a, b = args
        from repro.blas import distributed          # lazy: avoid import cycle
        return distributed.pdgemm(a, b, policy=policy, use_kernel=use_kernel,
                                  interpret=interpret, registry=registry,
                                  **kw)
    if op == "gemm+epilogue":
        a, b = args
        bias = kw.pop("bias", None)
        epilogue = kw.pop("epilogue", "none")
        res = resolve("gemm+epilogue", (a.shape[0], b.shape[1], a.shape[1]),
                      a.dtype, policy, use_kernel, registry,
                      epilogue=epilogue, has_bias=bias is not None)
        from repro.kernels import fused as _fk      # lazy: kernels optional
        if not res.use_pallas:
            return _fk.apply_epilogue(a @ b, epilogue, bias)
        _counters.inc("kernel.launch")
        if res.fused:
            with _fk.fused_span("gemm_bias_act", res.chain,
                                epilogue=epilogue,
                                flops=2 * a.shape[0] * b.shape[1]
                                * a.shape[1],
                                bytes=res.chain.fused_hbm_bytes):
                return _fk.gemm_bias_act(a, b, bias=bias, epilogue=epilogue,
                                         plan=res.gemm_plan,
                                         interpret=interpret)
        # staged: the dispatcher GEMM kernel, then the epilogue as a
        # second pass over the HBM-resident product
        from repro.kernels import ops
        out = ops.gemm(a, b, plan=res.gemm_plan, use_pallas=True,
                       interpret=interpret)
        return _fk.apply_epilogue(out, epilogue, bias)
    if op == "trsm+gemm":
        l11, a_panel, b_left, c = args
        form = kw.pop("form", "lu")
        unit_diag = kw.pop("unit_diag", False)
        fuse = kw.pop("fuse", None)
        res = resolve("trsm+gemm", (c.shape[0], c.shape[1], l11.shape[0]),
                      c.dtype, policy, use_kernel, registry, form=form)
        do_fuse = res.fused if fuse is None \
            else (bool(fuse) and res.use_pallas)
        if c.shape[0] == 0:
            # degenerate wide-LU trailing block (columns remain, rows do
            # not): the staged chain handles the empty GEMM
            do_fuse = False
        if do_fuse:
            from repro.kernels import fused as _fk
            _counters.inc("kernel.launch")
            m, n, nb = c.shape[0], c.shape[1], l11.shape[0]
            with _fk.fused_span("trsm_gemm", res.chain, form=form,
                                flops=nb * nb * n + 2 * m * n * nb,
                                bytes=res.chain.fused_hbm_bytes):
                return _fk.trsm_gemm(l11, a_panel, b_left, c, form=form,
                                     unit_diag=unit_diag,
                                     row_block=res.block,
                                     interpret=interpret)
        # staged dispatcher chain: TRSM then GEMM, X round-tripping HBM -
        # operation-for-operation the blocked drivers' historical trailing
        # update, so fuse=False keeps their numerics bitwise
        from repro.blas import level3               # lazy: avoid import cycle
        x = level3.trsm(l11, a_panel, lower=True, unit_diag=unit_diag,
                        left=True, policy=res.policy, interpret=interpret,
                        registry=registry)
        bl = x.T if form == "syrk" else b_left
        upd = dispatch("gemm", bl, x, policy=res.policy, interpret=interpret,
                       registry=registry)
        return x, c - upd
    raise ValueError(f"unknown op {op!r}; expected one of {OPS}")
