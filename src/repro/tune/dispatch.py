"""Unified BLAS/LAPACK kernel-config resolution and execution.

``resolve`` is the single place a (op, shape, dtype, backend, policy)
tuple becomes an executable config; ``dispatch`` executes it. Every BLAS-3
and blocked-LAPACK call in the repo funnels through here - the old
``use_kernel`` booleans survive only as deprecated aliases that
:func:`repro.tune.policy.resolve_policy` folds into a policy.

Resolution table (``source`` records which row fired):

    policy      registry hit        registry miss / no file / corrupt
    ---------   -----------------   ---------------------------------
    reference   (never consulted)   plain jnp
    model       (never consulted)   plan_gemm / plan_trsm config
    tuned       stored config       model config  (source="fallback-model")

The miss column is why a cold-start ``tuned`` run is numerically identical
to ``model``: both execute the same kernel with the same plan.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro import arch as _arch
from repro import obs as _obs
from repro.arch import MachineSpec
from repro.core.codesign import (GemmPlan, plan_from_blocks, plan_gemm,
                                 plan_pdgemm, plan_trsm)
from repro.obs import counters as _counters
from repro.tune.policy import resolve_policy, uses_kernel
from repro.tune.registry import Registry, default_registry


OPS = ("gemm", "gemv", "trsm", "syrk", "pdgemm")


@dataclasses.dataclass(frozen=True)
class Resolution:
    """The resolved execution recipe for one call."""

    op: str
    policy: str                   # "reference" | "model" | "tuned"
    source: str                   # "reference" | "model" | "registry" |
                                  # "fallback-model"
    use_pallas: bool
    gemm_plan: Optional[GemmPlan] = None
    block: Optional[int] = None   # trsm diagonal width
    mesh: Optional[str] = None    # registry mesh component (pdgemm)
    machine: Optional[str] = None   # machine the call resolved under

    def describe(self) -> dict:
        """JSON-able summary - benchmarks attach this to every record so
        trajectories are comparable across PRs."""
        d = {"op": self.op, "policy": self.policy, "source": self.source,
             "use_pallas": self.use_pallas, "machine": self.machine}
        if self.gemm_plan is not None:
            d["config"] = {"bm": self.gemm_plan.bm, "bn": self.gemm_plan.bn,
                           "bk": self.gemm_plan.bk}
        if self.block is not None:
            d.setdefault("config", {})["block"] = self.block
        if self.mesh is not None:
            d["mesh"] = self.mesh
        return d


def _observed(res: "Resolution") -> "Resolution":
    """Resolution accounting: counters always, a provenance event when a
    trace is capturing (``obs.event("tune.resolve", ...)`` carrying
    :meth:`Resolution.describe` - the registry-hit / model-seeded /
    reference provenance every traced call records)."""
    _counters.inc("dispatch.resolve")
    if res.policy == "tuned":
        _counters.inc("dispatch.registry_hit" if res.source == "registry"
                      else "dispatch.registry_miss")
    if _obs.enabled():
        _obs.event("tune.resolve", cat="resolve", **res.describe())
    return res


def resolve(op: str, shape: Tuple[int, ...], dtype,
            policy: Optional[str] = None, use_kernel: Optional[bool] = None,
            registry: Optional[Registry] = None,
            backend: Optional[str] = None,
            mesh: Optional[Tuple[int, int]] = None,
            machine: Optional[MachineSpec] = None) -> Resolution:
    """Resolve one call's config. shape is (m, n, k) for gemm/syrk/pdgemm
    (pdgemm: the *global* problem), (m, n) for gemv, (n, nrhs) for trsm.
    ``mesh`` is the (px, py) device mesh for pdgemm; its registry entries
    live under the mesh-suffixed key ``pdgemm|bucket|dtype|backend|pxXpyY``.
    ``machine`` parameterizes every planner and (for non-default machines)
    suffixes the registry key; ``None`` resolves the ambient
    :func:`repro.arch.current_machine` - which is what
    ``repro.linalg.use(machine=...)`` scopes for its routines.
    """
    if op not in OPS:
        raise ValueError(f"unknown op {op!r}; expected one of {OPS}")
    if op == "pdgemm" and mesh is None:
        raise ValueError("pdgemm resolution needs mesh=(px, py)")
    mach = _arch.resolve_machine(machine)
    mach_str = _arch.machine_key_component(mach)
    mesh_str = f"x{mesh[0]}y{mesh[1]}" if (op == "pdgemm" and mesh) else None
    pol = resolve_policy(policy, use_kernel)
    if not uses_kernel(pol):
        if op == "trsm":
            # the reference path still needs a diagonal width; 64 is the
            # historical (pre-tuner) default
            return _observed(Resolution(op, pol, "reference", False, block=64,
                              machine=mach.name))
        return _observed(Resolution(op, pol, "reference", False, mesh=mesh_str,
                          machine=mach.name))
    dtype = jnp.dtype(dtype)
    backend = backend or jax.default_backend()
    cfg = None
    source = "model"
    if pol == "tuned":
        reg = registry if registry is not None else default_registry()
        # syrk and gemv execute as GEMMs, so they share the gemm registry
        # entries (gemv under its execution shape (m, 1, n))
        lookup_op, lookup_shape = op, shape
        if op == "syrk":
            lookup_op = "gemm"
        elif op == "gemv":
            lookup_op, lookup_shape = "gemm", (shape[0], 1, shape[1])
        cfg = reg.lookup(lookup_op, lookup_shape, dtype, backend,
                         mesh=mesh_str, machine=mach_str)
        source = "registry" if cfg is not None else "fallback-model"
    if op == "pdgemm":
        # the stored/planned config tiles the per-step *local* update
        # (m/px, k_fine) @ (k_fine, n/py) - see codesign.plan_pdgemm
        m, n, k = shape
        px, py = mesh
        pplan = plan_pdgemm(m, n, k, px, py, dtype_bytes=dtype.itemsize,
                            machine=mach)
        if cfg is not None:
            local = plan_from_blocks(
                -(-max(m, 1) // px), -(-max(n, 1) // py), pplan.k_fine,
                cfg.params["bm"], cfg.params["bn"], cfg.params["bk"],
                dtype_bytes=dtype.itemsize, machine=mach)
        else:
            local = pplan.local
        return _observed(Resolution(op, pol, source, True, gemm_plan=local,
                          mesh=mesh_str, machine=mach.name))
    if op in ("gemm", "syrk"):
        m, n, k = shape
        if cfg is not None:
            plan = plan_from_blocks(m, n, k, cfg.params["bm"],
                                    cfg.params["bn"], cfg.params["bk"],
                                    dtype_bytes=dtype.itemsize, machine=mach)
        else:
            plan = plan_gemm(m, n, k, dtype_bytes=dtype.itemsize,
                             machine=mach)
        return _observed(Resolution(op, pol, source, True, gemm_plan=plan,
                          machine=mach.name))
    if op == "gemv":
        m, n = shape
        if cfg is not None:
            plan = plan_from_blocks(m, 1, n, cfg.params["bm"],
                                    cfg.params["bn"], cfg.params["bk"],
                                    dtype_bytes=dtype.itemsize, machine=mach)
        else:
            plan = plan_gemm(m, 1, n, dtype_bytes=dtype.itemsize,
                             machine=mach)
        return _observed(Resolution(op, pol, source, True, gemm_plan=plan,
                          machine=mach.name))
    # trsm
    n, nrhs = shape
    block = cfg.params["block"] if cfg is not None \
        else plan_trsm(n, nrhs, dtype_bytes=dtype.itemsize,
                       machine=mach).block
    return _observed(Resolution(op, pol, source, True, block=block, machine=mach.name))


def _gemm_exec(a, b, res: Resolution, interpret: bool):
    if not res.use_pallas:
        return a @ b
    _counters.inc("kernel.launch")
    from repro.kernels import ops                   # lazy: kernels optional
    if b.ndim == 1:                                 # matvec through the MXU
        return ops.gemm(a, b[:, None], plan=res.gemm_plan, use_pallas=True,
                        interpret=interpret)[:, 0]
    return ops.gemm(a, b, plan=res.gemm_plan, use_pallas=True,
                    interpret=interpret)


def dispatch(op: str, *args, policy: Optional[str] = None,
             use_kernel: Optional[bool] = None, interpret: bool = True,
             registry: Optional[Registry] = None,
             machine: Optional[MachineSpec] = None, **kw):
    """One entry point for every BLAS-3 / blocked-LAPACK kernel call.

    dispatch("gemm", a, b)             -> a @ b (by policy)
    dispatch("syrk", a, trans=False)   -> a a^T / a^T a (by policy)
    dispatch("gemv", a, x, trans=...)  -> op(a) x (by policy)
    dispatch("trsm", a, b, lower=..., unit_diag=..., left=..., block=...)

    alpha/beta epilogues stay in :mod:`repro.blas`; this layer only
    resolves and runs the kernel-shaped core of each op. An explicit
    ``machine`` scopes the whole call (including the cores it forwards
    to); ``None`` uses the ambient current machine.
    """
    if machine is not None:
        with _arch.machine_scope(machine):
            return dispatch(op, *args, policy=policy, use_kernel=use_kernel,
                            interpret=interpret, registry=registry, **kw)
    if op == "gemm":
        a, b = args
        n_out = b.shape[1] if b.ndim == 2 else 1
        res = resolve("gemm", (a.shape[0], n_out, a.shape[1]), a.dtype,
                      policy, use_kernel, registry)
        return _gemm_exec(a, b, res, interpret)
    if op == "syrk":
        (a,) = args
        trans = kw.pop("trans", False)
        op_a = a.T if trans else a
        res = resolve("syrk", (op_a.shape[0], op_a.shape[0], op_a.shape[1]),
                      a.dtype, policy, use_kernel, registry)
        return _gemm_exec(op_a, op_a.T, res, interpret)
    if op == "gemv":
        a, x = args
        trans = kw.pop("trans", False)
        op_a = a.T if trans else a
        res = resolve("gemv", op_a.shape, a.dtype, policy, use_kernel,
                      registry)
        if not res.use_pallas:
            return op_a @ x
        return _gemm_exec(op_a, x[:, None], res, interpret)[:, 0]
    if op == "trsm":
        a, b = args
        from repro.blas import level3               # lazy: avoid import cycle
        return level3.trsm(a, b, policy=policy, use_kernel=use_kernel,
                           interpret=interpret, registry=registry, **kw)
    if op == "pdgemm":
        a, b = args
        from repro.blas import distributed          # lazy: avoid import cycle
        return distributed.pdgemm(a, b, policy=policy, use_kernel=use_kernel,
                                  interpret=interpret, registry=registry,
                                  **kw)
    raise ValueError(f"unknown op {op!r}; expected one of {OPS}")
