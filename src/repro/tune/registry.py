"""Persistent kernel-config registry with in-memory LRU lookup.

Winning sweep configs are cached as JSON keyed by
``(op, shape-bucket, dtype, backend[, mesh][, machine])`` (see the package
docstring for the exact file format; the optional mesh component scopes
distributed ops to one device-mesh shape, and the optional machine
component scopes entries tuned under a non-default
:class:`repro.arch.MachineSpec` - the default machine omits it, so every
pre-arch registry file keeps resolving unchanged). Loading is lazy and
*graceful*: a missing, unreadable, or schema-incompatible file yields an
empty registry - dispatch then falls back to the model-predicted plan, so
a broken cache can never change numerics, only speed. Graceful is not
silent, though: a *corrupt* file fires a once-per-path
``warnings.warn(RuntimeWarning)`` and the ``registry.corrupt_fallback``
counter (a cold start - no file at all - is normal and only counts
``registry.missing_fallback``), so losing tuned configs to a bad cache
shows up instead of just running slower.
"""
from __future__ import annotations

import dataclasses
import json
import os
import warnings
from collections import OrderedDict
from typing import Dict, Mapping, Optional, Sequence, Set, Tuple

from repro.obs import counters as _counters

SCHEMA_VERSION = 1
_ENV_PATH = "REPRO_TUNE_REGISTRY"
DEFAULT_PATH = os.path.join(os.path.expanduser("~"), ".cache", "repro-tune",
                            "registry.json")


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """One tuned (or model-seeded) kernel configuration.

    params holds op-specific integers: ``{"bm","bn","bk"}`` for gemm,
    ``{"block"}`` for trsm. ``source`` records provenance ("sweep" for a
    measured winner, "model" for an analytically seeded entry).
    """

    op: str
    params: Mapping[str, int]
    source: str = "sweep"
    measured_s: Optional[float] = None

    def to_json(self) -> Dict:
        return {"op": self.op, "params": dict(self.params),
                "source": self.source, "measured_s": self.measured_s}

    @classmethod
    def from_json(cls, d: Mapping) -> "KernelConfig":
        params = {str(k): int(v) for k, v in dict(d["params"]).items()}
        return cls(op=str(d["op"]), params=params,
                   source=str(d.get("source", "sweep")),
                   measured_s=d.get("measured_s"))


def shape_bucket(shape: Sequence[int]) -> Tuple[int, ...]:
    """Round every dim up to the next power of two (>= 1), so one sweep
    covers a neighborhood of problem sizes instead of one exact shape."""
    out = []
    for d in shape:
        d = max(int(d), 1)
        out.append(1 << (d - 1).bit_length())
    return tuple(out)


def make_key(op: str, shape: Sequence[int], dtype, backend: str,
             mesh: Optional[str] = None,
             machine: Optional[str] = None) -> str:
    """Registry key ``op|shape-bucket|dtype|backend[|mesh][|m:machine]``.

    ``mesh`` is the device-mesh component for distributed ops (e.g.
    ``"x2y4"`` for a 2x4 ("x", "y") mesh - see
    :func:`repro.blas.distributed.mesh_key`). ``machine`` is the machine
    name for entries tuned under a non-default
    :class:`repro.arch.MachineSpec` (``m:``-prefixed so it can never
    collide with a mesh component). Single-device, default-machine
    entries omit both, so every pre-mesh/pre-arch registry file keeps
    resolving unchanged.
    """
    bucket = "x".join(str(d) for d in shape_bucket(shape))
    import numpy as np
    key = f"{op}|{bucket}|{np.dtype(dtype).name}|{backend}"
    if mesh is not None:
        key = f"{key}|{mesh}"
    return key if machine is None else f"{key}|m:{machine}"


# corrupt-registry warn-once bookkeeping (per absolute path, process-wide;
# re-loading the same broken file still counts, but warns only once)
_warned_corrupt_paths: Set[str] = set()


class Registry:
    """JSON-backed config store with LRU semantics.

    ``capacity`` bounds the number of in-memory (and persisted) entries;
    the least recently *used* entry is evicted first. All mutations mark
    the registry dirty; call :meth:`save` to persist.
    """

    def __init__(self, path: Optional[str] = None, capacity: int = 256,
                 autoload: bool = True):
        self.path = path if path is not None else os.environ.get(
            _ENV_PATH, DEFAULT_PATH)
        self.capacity = int(capacity)
        self._entries: "OrderedDict[str, KernelConfig]" = OrderedDict()
        self._loaded = not autoload
        self.load_error: Optional[str] = None
        self.dirty = False

    # ------------------------------ persistence -----------------------------

    def load(self, path: Optional[str] = None) -> int:
        """Read entries from disk (replacing in-memory state). Returns the
        number of entries loaded; 0 with ``load_error`` set on any failure
        (missing file, bad JSON, wrong schema) - never raises. A missing
        file is a normal cold start (counted as
        ``registry.missing_fallback``); a *corrupt* file additionally
        warns once per path (``RuntimeWarning``) and increments
        ``registry.corrupt_fallback`` - the fallback to model-planned
        configs changes speed, never numerics, but it should not be
        silent."""
        self._loaded = True
        self._entries.clear()
        self.load_error = None
        p = path or self.path
        _counters.inc("registry.load")
        try:
            with open(p) as f:
                blob = json.load(f)
            if not isinstance(blob, dict) or blob.get("version") != SCHEMA_VERSION:
                raise ValueError(
                    f"registry schema mismatch: want version={SCHEMA_VERSION}, "
                    f"got {blob.get('version') if isinstance(blob, dict) else type(blob)}")
            for key, d in blob.get("entries", {}).items():
                self._entries[str(key)] = KernelConfig.from_json(d)
        except FileNotFoundError:
            self.load_error = f"no registry file at {p} (cold start)"
            _counters.inc("registry.missing_fallback")
        except (OSError, ValueError, KeyError, TypeError) as e:
            self.load_error = f"unreadable registry at {p}: {e}"
            self._entries.clear()
            _counters.inc("registry.corrupt_fallback")
            ap = os.path.abspath(p)
            if ap not in _warned_corrupt_paths:
                _warned_corrupt_paths.add(ap)
                warnings.warn(
                    f"tune registry at {p} is unreadable ({e}); falling "
                    f"back to model-planned configs (numerics unchanged, "
                    f"tuned speed lost)", RuntimeWarning, stacklevel=2)
        return len(self._entries)

    def save(self, path: Optional[str] = None) -> str:
        p = path or self.path
        d = os.path.dirname(os.path.abspath(p))
        os.makedirs(d, exist_ok=True)
        blob = {"version": SCHEMA_VERSION,
                "entries": {k: v.to_json() for k, v in self._entries.items()}}
        tmp = p + ".tmp"
        with open(tmp, "w") as f:
            # entries keep insertion (= recency) order so the LRU order
            # survives a save/load round-trip; don't sort keys
            json.dump(blob, f, indent=1)
        os.replace(tmp, p)
        self.dirty = False
        return p

    def _ensure_loaded(self) -> None:
        if not self._loaded:
            self.load()

    # -------------------------------- access --------------------------------

    def lookup(self, op: str, shape: Sequence[int], dtype, backend: str,
               mesh: Optional[str] = None,
               machine: Optional[str] = None) -> Optional[KernelConfig]:
        """LRU lookup; None on miss (dispatch falls back to the model).

        ``mesh`` scopes the key to one device-mesh shape (distributed ops);
        ``machine`` to one non-default machine spec; ``None`` is the
        single-device / default-machine namespace.
        """
        self._ensure_loaded()
        key = make_key(op, shape, dtype, backend, mesh, machine)
        cfg = self._entries.get(key)
        if cfg is not None:
            self._entries.move_to_end(key)
        return cfg

    def record(self, op: str, shape: Sequence[int], dtype, backend: str,
               params: Mapping[str, int], source: str = "sweep",
               measured_s: Optional[float] = None,
               mesh: Optional[str] = None,
               machine: Optional[str] = None) -> KernelConfig:
        self._ensure_loaded()
        key = make_key(op, shape, dtype, backend, mesh, machine)
        cfg = KernelConfig(op=op, params={k: int(v) for k, v in params.items()},
                           source=source, measured_s=measured_s)
        self._entries[key] = cfg
        self._entries.move_to_end(key)
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)       # evict least recently used
        self.dirty = True
        return cfg

    def clear(self) -> None:
        self._entries.clear()
        self.dirty = True

    def keys(self):
        self._ensure_loaded()
        return list(self._entries.keys())

    def __len__(self) -> int:
        self._ensure_loaded()
        return len(self._entries)


_default: Optional[Registry] = None


def default_registry() -> Registry:
    """Process-wide registry (path from ``REPRO_TUNE_REGISTRY`` or the
    user cache dir); created lazily, loaded lazily."""
    global _default
    if _default is None:
        _default = Registry()
    return _default


def set_default_registry(reg: Optional[Registry]) -> None:
    """Swap the process-wide registry (tests; ``None`` resets to lazy)."""
    global _default
    _default = reg


def set_default_path(path: str) -> Registry:
    """Point the process-wide registry at ``path`` and return it."""
    global _default
    _default = Registry(path=path)
    return _default
