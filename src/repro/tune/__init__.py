"""repro.tune - autotuning + persistent kernel-config registry behind a
unified BLAS/LAPACK dispatcher.

The paper's performance claims are "attained through tuning of several
algorithmic and architectural parameters" (block sizes, pipeline depths,
memory sizes). ELAPS (1504.08035) and the dense-linear-algebra performance
modeling line (1209.2364) both show the real optimum per shape/dtype/backend
comes from *measured sweeps seeded by a model*, not from the model alone.
This package is that loop, persisted:

    model (core.codesign + core.pipeline_model + core.roofline)
        -> candidate configs (search.gemm_candidates / trsm_candidates)
        -> measured sweep (search.tune_gemm / search.tune_trsm)
        -> persistent registry (registry.Registry, JSON on disk)
        -> every BLAS/LAPACK call (dispatch.dispatch)

Policy semantics
================
The public way to pick a policy is the :mod:`repro.linalg`
ExecutionContext (``linalg.use(policy=...)``); underneath, every BLAS-3 /
blocked-LAPACK numeric core takes ``policy``:

``"reference"``
    Plain jnp (``a @ b``, scan substitutions). No Pallas, no registry.
    This is the oracle path and the old ``use_kernel=False``.
``"model"``
    Pallas kernel with the analytically chosen config - ``plan_gemm`` /
    ``plan_trsm`` from :mod:`repro.core.codesign` (the paper's
    pipeline-depth equation transplanted to block shapes). This is the old
    ``use_kernel=True``.
``"tuned"``
    Pallas kernel with the *measured* best config from the registry, keyed
    by ``(op, shape-bucket, dtype, backend)``. A lookup miss (cold start,
    missing or corrupt registry file) falls back to exactly the ``model``
    resolution, so a cold-start ``tuned`` run is numerically identical to
    ``model`` (and hence to the PR-1 ``use_kernel=True`` path).

``use_kernel=True/False`` is kept everywhere as a *deprecated alias* for
``policy="model"`` / ``policy="reference"``; an explicit ``policy`` wins.
The default policy is ``"reference"`` and can be overridden with the
``REPRO_TUNE_POLICY`` environment variable.

Registry file format
====================
One JSON object (schema version 1)::

    {"version": 1,
     "entries": {
       "gemm|256x256x128|float32|cpu": {
          "op": "gemm",
          "params": {"bm": 128, "bn": 128, "bk": 128},
          "source": "sweep",            # "sweep" | "model"
          "measured_s": 1.3e-4},
       "trsm|64x8|float32|cpu": {
          "op": "trsm", "params": {"block": 32}, ...}}}

Keys are ``op|shape-bucket|dtype|backend[|mesh]`` where the shape bucket
rounds every dimension up to the next power of two, so one sweep covers a
neighborhood of problem sizes. The optional trailing mesh component scopes
distributed ops to one device-mesh shape (e.g.
``"pdgemm|128x128x64|float32|cpu|x2y4"`` for a 2x4 ("x", "y") mesh);
single-device entries omit it, so pre-mesh registry files keep resolving
unchanged. Lookups go through an in-memory LRU; the file is read lazily
once and written with :meth:`Registry.save`.

Regenerating the cache
======================
``PYTHONPATH=src python -m benchmarks.bench_tune --out-dir benchmarks/out``
sweeps the standard shape grid, writes ``benchmarks/out/tune_registry.json``
and the ``benchmarks/out/BENCH_tune.json`` trajectory. Point the runtime at
a registry file with ``REPRO_TUNE_REGISTRY=/path/to/registry.json`` (or
``registry.set_default_path``). ``scripts/ci_check.sh`` runs a tiny smoke
sweep into a temp dir on every CI run so schema drift cannot land silently.
"""
from repro.tune import dispatch, measure, policy, registry, search
from repro.tune.dispatch import Resolution, dispatch as dispatch_op, resolve
from repro.tune.measure import (Measurement, measure_wall_time,
                                model_residual, repetition_controller)
from repro.tune.measure import measure as measure_op  # noqa: F401 (alias:
# the submodule itself is exported as `measure`; the callable is
# tune.measure.measure / tune.measure_op)
from repro.tune.policy import POLICIES, default_policy, resolve_policy
from repro.tune.registry import KernelConfig, Registry, default_registry
from repro.tune.search import (seed_registry_from_model, tune_fused_gemm,
                               tune_gemm, tune_trsm)

__all__ = [
    "POLICIES", "KernelConfig", "Measurement", "Registry", "Resolution",
    "default_policy", "default_registry", "dispatch", "dispatch_op",
    "measure", "measure_op", "measure_wall_time", "model_residual",
    "policy", "registry", "repetition_controller", "resolve",
    "resolve_policy", "search", "seed_registry_from_model",
    "tune_fused_gemm", "tune_gemm", "tune_trsm",
]
