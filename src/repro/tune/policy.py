"""Dispatch policies and the deprecated ``use_kernel`` alias.

``"reference"`` - plain jnp, the oracle path (old ``use_kernel=False``).
``"model"``     - Pallas kernel, analytically planned config (old
                  ``use_kernel=True``).
``"tuned"``     - Pallas kernel, measured config from the registry; cold
                  start falls back to the ``model`` resolution.
"""
from __future__ import annotations

import os
import warnings
from typing import Optional

POLICIES = ("reference", "model", "tuned")

# policies whose execution path is the Pallas kernel
KERNEL_POLICIES = ("model", "tuned")

_ENV_POLICY = "REPRO_TUNE_POLICY"
_warned_use_kernel = False
_warned_use_pallas = False


def default_policy() -> str:
    """Process-wide default policy (env ``REPRO_TUNE_POLICY``, else
    ``"reference"`` - the conservative oracle path)."""
    pol = os.environ.get(_ENV_POLICY, "reference")
    if pol not in POLICIES:
        raise ValueError(
            f"{_ENV_POLICY}={pol!r} is not one of {POLICIES}")
    return pol


def resolve_policy(policy: Optional[str] = None,
                   use_kernel: Optional[bool] = None,
                   use_pallas: Optional[bool] = None) -> str:
    """Collapse (policy, deprecated use_kernel/use_pallas) into one policy.

    An explicit ``policy`` always wins. ``use_kernel`` (and its older
    spelling ``use_pallas``) map True -> ``"model"`` and False ->
    ``"reference"`` (their exact pre-tuner semantics); each alias warns
    once per process. With none given, :func:`default_policy` applies.
    """
    global _warned_use_kernel, _warned_use_pallas
    if policy is not None:
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; expected one of "
                             f"{POLICIES}")
        return policy
    if use_kernel is not None:
        if not _warned_use_kernel:
            warnings.warn(
                "use_kernel is deprecated; pass policy='model' (True) or "
                "policy='reference' (False) instead", DeprecationWarning,
                stacklevel=3)
            _warned_use_kernel = True
        return "model" if use_kernel else "reference"
    if use_pallas is not None:
        if not _warned_use_pallas:
            warnings.warn(
                "use_pallas is deprecated; pass policy='model' (True) or "
                "policy='reference' (False) instead", DeprecationWarning,
                stacklevel=3)
            _warned_use_pallas = True
        return "model" if use_pallas else "reference"
    return default_policy()


def uses_kernel(policy: str) -> bool:
    return policy in KERNEL_POLICIES
