"""ELAPS-style wall-clock measurement: per-rep samples + adaptive repetition.

The repo's perf claims (tuned configs, model residuals, the CI perf
trajectory) are only as good as the timing under them, and one-shot
averages are not good enough: wall-clock samples on a shared host are
noisy and skewed, so *The ELAPS Framework* (arXiv:1504.08035) and the
dense-linear-algebra performance-modeling line (arXiv:1209.2364) both
time every experiment as repeated samples summarized by robust statistics
(median + spread), repeating until the spread tightens or a budget is
hit. This module is that discipline as the repo's one timing helper:

:func:`measure`
    Times a callable (compile/warm-up excluded, every rep individually
    synchronized through ``jax.block_until_ready``) and returns a
    :class:`Measurement`: the per-rep samples, their median, a relative
    spread (interquartile range / median), and the rep count the
    controller actually used.
:func:`repetition_controller`
    The pure-Python adaptive loop under :func:`measure` - take samples
    until the relative spread is inside the target band (but at least
    ``min_reps``) or ``max_reps`` is exhausted. Takes any
    ``sample_fn() -> seconds``, so tests drive it with synthetic noisy
    timers.
:func:`measure_wall_time`
    Back-compatible scalar facade (the historical name the sweeps and
    benchmark drivers import): validates ``reps >= 1`` and returns the
    median of exactly ``reps`` samples.
:func:`model_residual`
    The shared modeled-vs-measured residual definition every bench row
    records (see ``docs/benchmarking.md`` for the semantics).

Every JAX call is synchronized *inside* its own timed region: with JAX's
async dispatch, timing ``f(*args)`` without blocking measures dispatch
latency, and blocking only after the loop attributes earlier reps' device
time to the final sample.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Optional, Tuple

import jax

from repro import obs as _obs

# Defaults for adaptive measurement: start at MIN_REPS, stop as soon as
# the relative IQR is inside REL_SPREAD, never exceed MAX_REPS.
DEFAULT_MIN_REPS = 3
DEFAULT_MAX_REPS = 20
DEFAULT_REL_SPREAD = 0.10


def _quantile(sorted_samples: Tuple[float, ...], q: float) -> float:
    """Linear-interpolation quantile of pre-sorted samples (numpy's
    default method, inlined so the controller stays dependency-free)."""
    n = len(sorted_samples)
    if n == 1:
        return sorted_samples[0]
    pos = q * (n - 1)
    lo = int(math.floor(pos))
    hi = min(lo + 1, n - 1)
    frac = pos - lo
    return sorted_samples[lo] * (1.0 - frac) + sorted_samples[hi] * frac


@dataclasses.dataclass(frozen=True)
class Measurement:
    """Per-rep wall-clock samples summarized the ELAPS way.

    ``seconds_spread`` is the *relative* interquartile range,
    ``(q75 - q25) / median`` - the variability number the repetition
    controller converges on and the perf-regression gate widens its
    tolerance by. ``converged`` records whether the spread reached the
    ``target_spread`` band before the rep budget ran out.
    """

    samples: Tuple[float, ...]
    seconds_median: float
    seconds_spread: float
    reps: int
    converged: bool
    target_spread: float

    @classmethod
    def from_samples(cls, samples, target_spread: float = DEFAULT_REL_SPREAD)         -> "Measurement":
        xs = tuple(float(s) for s in samples)
        if not xs:
            raise ValueError("Measurement needs at least one sample")
        s = tuple(sorted(xs))
        med = _quantile(s, 0.5)
        iqr = _quantile(s, 0.75) - _quantile(s, 0.25)
        spread = iqr / med if med > 0 else float("inf")
        return cls(samples=xs, seconds_median=med, seconds_spread=spread,
                   reps=len(xs), converged=spread <= target_spread,
                   target_spread=float(target_spread))

    @property
    def seconds_min(self) -> float:
        return min(self.samples)

    @property
    def seconds_mean(self) -> float:
        return sum(self.samples) / len(self.samples)

    def row_fields(self) -> dict:
        """The canonical per-row timing fields every bench JSON carries."""
        return {"seconds_median": self.seconds_median,
                "seconds_spread": self.seconds_spread,
                "reps": self.reps}

    def to_json(self) -> dict:
        return {**self.row_fields(), "samples": list(self.samples),
                "converged": self.converged,
                "target_spread": self.target_spread}


def repetition_controller(sample_fn: Callable[[], float],
                          min_reps: int = DEFAULT_MIN_REPS,
                          max_reps: int = DEFAULT_MAX_REPS,
                          rel_spread: float = DEFAULT_REL_SPREAD) -> Measurement:
    """Adaptively sample ``sample_fn`` until the relative IQR of the
    samples is ``<= rel_spread`` (checked from ``min_reps`` on) or
    ``max_reps`` samples have been taken. Returns the full
    :class:`Measurement` either way; ``converged`` says which exit fired.
    """
    min_reps = int(min_reps)
    max_reps = int(max_reps)
    if min_reps < 1:
        raise ValueError(f"min_reps must be >= 1, got {min_reps}")
    if max_reps < min_reps:
        raise ValueError(f"max_reps ({max_reps}) must be >= min_reps "
                         f"({min_reps})")
    if not float(rel_spread) >= 0:
        raise ValueError(f"rel_spread must be >= 0, got {rel_spread!r}")
    samples = []
    while len(samples) < max_reps:
        samples.append(float(sample_fn()))
        if len(samples) >= min_reps:
            m = Measurement.from_samples(samples, rel_spread)
            if m.converged:
                return m
    return Measurement.from_samples(samples, rel_spread)


def measure(f, *args, reps: Optional[int] = None,
            min_reps: int = DEFAULT_MIN_REPS,
            max_reps: int = DEFAULT_MAX_REPS,
            rel_spread: float = DEFAULT_REL_SPREAD) -> Measurement:
    """Measure ``f(*args)`` under the repetition controller.

    One untimed warm-up call (compile + first dispatch) runs first; each
    subsequent rep is an individually timed, individually synchronized
    call, so async dispatch can neither hide device time outside the
    timed region nor pile earlier reps onto the last sample.

    ``reps=N`` pins the controller to exactly ``N`` samples (the
    deterministic-duration mode the benchmark drivers use); otherwise the
    ``min_reps``/``max_reps``/``rel_spread`` band drives the rep count.

    Under an active :mod:`repro.obs` capture, the measurement summary
    (reps / median / spread / convergence) is attached to the enclosing
    span (:func:`repro.obs.annotate`) - or recorded as a
    ``tune.measure`` instant event when no span is open - so traces
    carry real per-execution device timing next to the trace-time spans.
    """
    if reps is not None:
        reps = int(reps)
        if reps < 1:
            raise ValueError(f"reps must be >= 1, got {reps}")
        min_reps = max_reps = reps
    jax.block_until_ready(f(*args))                 # compile / warm up

    def sample() -> float:
        t0 = time.perf_counter()
        jax.block_until_ready(f(*args))
        return time.perf_counter() - t0

    m = repetition_controller(sample, min_reps=min_reps,
                              max_reps=max_reps, rel_spread=rel_spread)
    if _obs.enabled():
        fields = {"measure_reps": m.reps,
                  "measure_seconds_median": m.seconds_median,
                  "measure_seconds_spread": m.seconds_spread,
                  "measure_converged": m.converged}
        if not _obs.annotate(**fields):
            _obs.event("tune.measure", cat="measure", **fields)
    return m


def measure_wall_time(f, *args, reps: int = 2) -> float:
    """Median seconds of exactly ``reps`` timed calls (compile/warm-up
    excluded). The historical scalar facade over :func:`measure`;
    ``reps`` must be ``>= 1``.
    """
    return measure(f, *args, reps=reps).seconds_median


def model_residual(modeled_s: float, measured_s: float) -> float:
    """Relative modeled-vs-measured residual of one bench row.

    ``(measured - modeled) / measured``: 0 means the machine model
    explains the measured median exactly, values near 1 mean the model is
    far optimistic (the normal regime for interpret-mode kernels on CPU),
    negative values mean the code beat the model. NaN when the measured
    time is not positive.
    """
    measured_s = float(measured_s)
    if not measured_s > 0 or not math.isfinite(measured_s):
        return float("nan")
    return (measured_s - float(modeled_s)) / measured_s
