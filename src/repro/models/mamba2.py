"""Mamba-2 (SSD) block: projections, causal conv, selective state space.

Prefill/training run the chunked SSD (Pallas kernel on TPU, jnp oracle here);
decode is the O(1) per-token recurrence against a cached (H, P, N) state +
conv tail - the reason ``long_500k`` is feasible for SSM archs at all.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.models.config import ModelConfig
from repro.models.layers import init_rmsnorm, apply_rmsnorm, truncated_normal


def init_mamba(key, cfg: ModelConfig):
    d = cfg.d_model
    di = cfg.d_inner
    g, n = cfg.ssm_groups, cfg.ssm_state
    h = cfg.n_ssm_heads
    conv_dim = di + 2 * g * n
    ks = jax.random.split(key, 5)
    return {
        # order: [z (di), x (di), B (g*n), C (g*n), dt (h)]
        "in_proj": truncated_normal(ks[0], (d, 2 * di + 2 * g * n + h),
                                    d ** -0.5),
        "conv_w": truncated_normal(ks[1], (cfg.ssm_conv, conv_dim), 0.2),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)),       # A = -exp(a_log)
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm": init_rmsnorm(di),
        "out_proj": truncated_normal(ks[4], (di, d), di ** -0.5),
    }


def _split_proj(zxbcdt, cfg: ModelConfig):
    di = cfg.d_inner
    gn = cfg.ssm_groups * cfg.ssm_state
    h = cfg.n_ssm_heads
    z = zxbcdt[..., :di]
    xs = zxbcdt[..., di:2 * di]
    B = zxbcdt[..., 2 * di:2 * di + gn]
    C = zxbcdt[..., 2 * di + gn:2 * di + 2 * gn]
    dt = zxbcdt[..., 2 * di + 2 * gn:2 * di + 2 * gn + h]
    return z, xs, B, C, dt


def _causal_conv(u: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 tail: Optional[jnp.ndarray] = None):
    """Depthwise causal conv. u: (B, S, C); w: (K, C). ``tail``: (B, K-1, C)
    carried state for decode. Returns (y, new_tail)."""
    kk = w.shape[0]
    if tail is None:
        tail = jnp.zeros((u.shape[0], kk - 1, u.shape[2]), u.dtype)
    ext = jnp.concatenate([tail, u], axis=1)                # (B, K-1+S, C)
    y = sum(ext[:, i:i + u.shape[1]] * w[i].astype(u.dtype)
            for i in range(kk))
    y = jax.nn.silu(y + b.astype(u.dtype))
    new_tail = ext[:, -(kk - 1):] if kk > 1 else tail
    return y, new_tail


def _prepare_ssd(xs, B, C, dt, p, cfg: ModelConfig):
    """Shared head-reshape + dt/A handling for prefill and decode."""
    bsz, s, _ = xs.shape
    h, hd = cfg.n_ssm_heads, cfg.ssm_head_dim
    g, n = cfg.ssm_groups, cfg.ssm_state
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))    # (B,S,H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))                # (H,)
    a_log_dt = dt * a[None, None, :]                            # (B,S,H) <= 0
    xh = xs.reshape(bsz, s, h, hd) * dt[..., None].astype(xs.dtype)
    rep = h // g
    Bh = jnp.repeat(B.reshape(bsz, s, g, n), rep, axis=2)
    Ch = jnp.repeat(C.reshape(bsz, s, g, n), rep, axis=2)
    return xh, a_log_dt, Bh, Ch


def apply_mamba(p, x: jnp.ndarray, cfg: ModelConfig,
                use_pallas: Optional[bool] = None) -> jnp.ndarray:
    """Full-sequence path. x: (B, S, d)."""
    dtype = x.dtype
    bsz, s, _ = x.shape
    di = cfg.d_inner
    gn = cfg.ssm_groups * cfg.ssm_state
    zxbcdt = x @ p["in_proj"].astype(dtype)
    z, xs, B, C, dt = _split_proj(zxbcdt, cfg)
    xbc = jnp.concatenate([xs, B, C], axis=-1)
    xbc, _ = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs, B, C = xbc[..., :di], xbc[..., di:di + gn], xbc[..., di + gn:]
    xh, a_log, Bh, Ch = _prepare_ssd(xs, B, C, dt, p, cfg)
    y = ops.ssd(xh, a_log, Bh, Ch, chunk=cfg.ssm_chunk, use_pallas=use_pallas)
    y = y + xh * p["d_skip"].astype(dtype)[None, None, :, None]
    y = y.reshape(bsz, s, di)
    y = apply_rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return y @ p["out_proj"].astype(dtype)


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    h, hd, n = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return {
        "state": jnp.zeros((batch, h, hd, n), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
    }


def apply_mamba_decode(p, x: jnp.ndarray, cfg: ModelConfig, cache
                       ) -> Tuple[jnp.ndarray, dict]:
    """One-token recurrence. x: (B, 1, d)."""
    dtype = x.dtype
    bsz = x.shape[0]
    di = cfg.d_inner
    gn = cfg.ssm_groups * cfg.ssm_state
    zxbcdt = x @ p["in_proj"].astype(dtype)
    z, xs, B, C, dt = _split_proj(zxbcdt, cfg)
    xbc = jnp.concatenate([xs, B, C], axis=-1)
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"],
                                 tail=cache["conv"])
    xs, B, C = xbc[..., :di], xbc[..., di:di + gn], xbc[..., di + gn:]
    xh, a_log, Bh, Ch = _prepare_ssd(xs, B, C, dt, p, cfg)
    # exact one-step recurrence: h' = exp(a) h + x (x) B ; y = h' C
    a = jnp.exp(a_log[:, 0].astype(jnp.float32))[:, :, None, None]
    state = cache["state"]
    upd = jnp.einsum("bhp,bhn->bhpn", xh[:, 0].astype(jnp.float32),
                     Bh[:, 0].astype(jnp.float32))
    state = a * state + upd
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch[:, 0].astype(jnp.float32))
    y = y.astype(dtype)[:, None]                                # (B,1,H,P)
    y = y + xh * p["d_skip"].astype(dtype)[None, None, :, None]
    y = y.reshape(bsz, 1, di)
    y = apply_rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return y @ p["out_proj"].astype(dtype), {"state": state, "conv": new_conv}
