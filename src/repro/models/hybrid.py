"""Hymba-style hybrid block: parallel attention + Mamba heads per layer.

Each layer runs a (windowed or global) attention path and an SSM path on the
same normalized input; the outputs are each RMS-normalized and averaged with
learnable per-path scales (the Hymba fusion). Most layers use sliding-window
attention; ``cfg.global_layers`` use full attention. Hymba's meta tokens are
omitted (DESIGN.md section 4 records this).

The SSM path gives the O(1) global state that makes ``long_500k`` decoding
feasible while the windowed attention keeps local precision.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mamba2
from repro.models.config import ModelConfig
from repro.models.layers import apply_rmsnorm, init_rmsnorm


def init_hybrid(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    return {
        "attn": attn.init_attention(k1, cfg),
        "ssm": mamba2.init_mamba(k2, cfg),
        "attn_norm": init_rmsnorm(cfg.d_model),
        "ssm_norm": init_rmsnorm(cfg.d_model),
        "attn_scale": jnp.ones((), jnp.float32),
        "ssm_scale": jnp.ones((), jnp.float32),
    }


def _fuse(p, ya, ys, cfg: ModelConfig):
    ya = apply_rmsnorm(p["attn_norm"], ya, cfg.norm_eps)
    ys = apply_rmsnorm(p["ssm_norm"], ys, cfg.norm_eps)
    return 0.5 * (p["attn_scale"].astype(ya.dtype) * ya
                  + p["ssm_scale"].astype(ys.dtype) * ys)


def apply_hybrid(p, x, cfg: ModelConfig, positions, is_global,
                 use_pallas: Optional[bool] = None) -> jnp.ndarray:
    """Full-sequence path. ``is_global``: bool (traced ok) - full vs window.

    The two attention flavours go through ``lax.cond`` so only ONE executes
    per layer (the first implementation computed both and selected - 2x the
    attention cost on every windowed layer; EXPERIMENTS.md §Perf hymba)."""
    if cfg.window is not None:
        ya = jax.lax.cond(
            is_global,
            lambda h: attn.apply_attention(p["attn"], h, cfg, positions,
                                           window=None,
                                           use_pallas=use_pallas),
            lambda h: attn.apply_attention(p["attn"], h, cfg, positions,
                                           window=cfg.window,
                                           use_pallas=use_pallas),
            x)
    else:
        ya = attn.apply_attention(p["attn"], x, cfg, positions, window=None,
                                  use_pallas=use_pallas)
    ys = mamba2.apply_mamba(p["ssm"], x, cfg, use_pallas=use_pallas)
    return _fuse(p, ya, ys, cfg)


def init_hybrid_cache(cfg: ModelConfig, batch: int, max_len: int,
                      is_global: bool = False, dtype=jnp.bfloat16):
    """Windowed layers keep a ``window``-sized KV ring (the memory win that
    makes long_500k feasible); ``is_global`` layers get the full horizon.
    Cache shapes therefore differ per layer -> hybrid caches are a per-layer
    list, and decode unrolls the (few) layers instead of scanning."""
    if is_global or cfg.window is None:
        kv_len = max_len
    else:
        kv_len = min(max_len, cfg.window)
    return {
        "attn": attn.init_kv_cache(cfg, batch, kv_len, dtype),
        "ssm": mamba2.init_ssm_cache(cfg, batch, dtype),
    }


def apply_hybrid_decode(p, x, cfg: ModelConfig, cache, cache_index,
                        is_global) -> Tuple[jnp.ndarray, dict]:
    """One-token decode. The attention cache is a ring buffer of the window
    size (RoPE at absolute positions keeps relative offsets exact)."""
    smax = cache["attn"]["k"].shape[1]
    widx = cache_index % smax                    # ring write slot
    kv_len = jnp.minimum(cache_index + 1, smax)  # valid slots
    ya, new_kv = attn.apply_attention_decode(
        p["attn"], x, cfg, cache["attn"], widx, cache_index, kv_len)
    ys, new_ssm = mamba2.apply_mamba_decode(p["ssm"], x, cfg, cache["ssm"])
    y = _fuse(p, ya, ys, cfg)
    return y, {"attn": new_kv, "ssm": new_ssm}
