"""Modality frontend STUBS for [audio] and [vlm] architectures.

Per the assignment, these entries specify the transformer BACKBONE only; the
frontend provides *precomputed* frame/patch embeddings. ``input_specs()``
in the configs returns ShapeDtypeStructs of these shapes; the synthetic data
pipeline draws matching random embeddings for smoke tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

WHISPER_FRAMES = 1500          # 30 s of audio at the encoder's frame rate
INTERNVL_PATCHES = 256         # 448x448 / 14 patch / pixel-shuffle 0.5


def frontend_tokens(cfg: ModelConfig) -> int:
    if cfg.frontend == "audio":
        return cfg.encoder_seq or WHISPER_FRAMES
    if cfg.frontend == "vision":
        return cfg.num_prefix_tokens or INTERNVL_PATCHES
    return 0


def frontend_spec(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    n = frontend_tokens(cfg)
    if n == 0:
        return None
    return jax.ShapeDtypeStruct((batch, n, cfg.d_model), dtype)


def synthetic_frontend(key, cfg: ModelConfig, batch: int,
                       dtype=jnp.bfloat16) -> jnp.ndarray:
    n = frontend_tokens(cfg)
    return (0.02 * jax.random.normal(key, (batch, n, cfg.d_model))
            ).astype(dtype)
