"""Decoder-only LM covering the dense / moe / ssm / hybrid / vlm families.

Layers are *scanned* (params stacked on a leading L axis) so HLO size is
layer-count independent - the 94-layer MoE compiles on one CPU core - and
``jax.checkpoint`` around the scan body gives per-layer remat.

An optional ``shard_fn(x, name)`` hook lets the distributed layer constrain
activation shardings without the model importing mesh machinery.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import hybrid as hybrid_mod
from repro.models import mamba2 as mamba_mod
from repro.models import moe as moe_mod
from repro.models.config import ModelConfig
from repro.models.layers import (apply_embedding, apply_ffn, apply_rmsnorm,
                                 init_embedding, init_ffn, init_rmsnorm,
                                 truncated_normal)

ShardFn = Callable[[jnp.ndarray, str], jnp.ndarray]
_id_shard: ShardFn = lambda x, name: x


def maybe_remat(body, cfg: ModelConfig):
    """Per-layer remat with the configured policy.

    'full' recomputes everything in backward (min memory, ~2x fwd compute in
    bwd); 'dots' saves matmul outputs (recompute only cheap elementwise -
    the compute-term hillclimb lever); 'none' disables remat."""
    if not cfg.remat or cfg.remat_policy == "none":
        return body
    policy = None
    if cfg.remat_policy == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    return jax.checkpoint(body, prevent_cse=False, policy=policy)


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def init_block(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    p = {"ln1": init_rmsnorm(cfg.d_model)}
    if cfg.family == "ssm":
        p["ssm"] = mamba_mod.init_mamba(ks[0], cfg)
        if cfg.d_ff:
            p["ln2"] = init_rmsnorm(cfg.d_model)
            p["ffn"] = init_ffn(ks[1], cfg.d_model, cfg.d_ff, cfg.glu)
        return p
    if cfg.family == "hybrid":
        p["mix"] = hybrid_mod.init_hybrid(ks[0], cfg)
    else:
        p["attn"] = attn_mod.init_attention(ks[0], cfg)
    p["ln2"] = init_rmsnorm(cfg.d_model)
    if cfg.family == "moe":
        assert cfg.moe_every == 1, "scan requires uniform layer structure"
        p["moe"] = moe_mod.init_moe(ks[1], cfg)
    else:
        p["ffn"] = init_ffn(ks[1], cfg.d_model, cfg.d_ff, cfg.glu)
    return p


def apply_block(p, x, cfg: ModelConfig, positions, is_global,
                shard_fn: ShardFn = _id_shard,
                use_pallas: Optional[bool] = None,
                causal: bool = True):
    """Full-sequence block. Returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = apply_rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.family == "ssm":
        x = x + mamba_mod.apply_mamba(p["ssm"], h, cfg, use_pallas=use_pallas)
        if cfg.d_ff:
            h2 = apply_rmsnorm(p["ln2"], x, cfg.norm_eps)
            x = x + apply_ffn(p["ffn"], h2, cfg.act, x.dtype)
        return shard_fn(x, "residual"), aux
    if cfg.family == "hybrid":
        mix = hybrid_mod.apply_hybrid(p["mix"], h, cfg, positions, is_global,
                                      use_pallas=use_pallas)
        x = x + mix
    else:
        window = cfg.window
        x = x + attn_mod.apply_attention(p["attn"], h, cfg, positions,
                                         window=window, causal=causal,
                                         use_pallas=use_pallas)
    x = shard_fn(x, "residual")
    h = apply_rmsnorm(p["ln2"], x, cfg.norm_eps)
    if cfg.family == "moe":
        y, aux = moe_mod.apply_moe(p["moe"], h, cfg)
    else:
        y = apply_ffn(p["ffn"], h, cfg.act, x.dtype)
    return shard_fn(x + y, "residual"), aux


def apply_block_decode(p, x, cfg: ModelConfig, cache, cache_index, is_global
                       ) -> Tuple[jnp.ndarray, jnp.ndarray, dict]:
    """One-token decode block. Returns (x, aux, new_cache)."""
    aux = jnp.zeros((), jnp.float32)
    h = apply_rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.family == "ssm":
        y, nc = mamba_mod.apply_mamba_decode(p["ssm"], h, cfg, cache)
        x = x + y
        if cfg.d_ff:
            h2 = apply_rmsnorm(p["ln2"], x, cfg.norm_eps)
            x = x + apply_ffn(p["ffn"], h2, cfg.act, x.dtype)
        return x, aux, nc
    if cfg.family == "hybrid":
        y, nc = hybrid_mod.apply_hybrid_decode(p["mix"], h, cfg, cache,
                                               cache_index, is_global)
        x = x + y
    else:
        smax = cache["k"].shape[1]
        kv_len = jnp.minimum(cache_index + 1, smax)
        y, nc = attn_mod.apply_attention_decode(
            p["attn"], h, cfg, cache, cache_index % smax, cache_index, kv_len)
        x = x + y
    h = apply_rmsnorm(p["ln2"], x, cfg.norm_eps)
    if cfg.family == "moe":
        y, aux = moe_mod.apply_moe(p["moe"], h, cfg)
    else:
        y = apply_ffn(p["ffn"], h, cfg.act, x.dtype)
    return x + y, aux, nc


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------

def _is_global_arr(cfg: ModelConfig) -> jnp.ndarray:
    g = jnp.zeros((cfg.n_layers,), bool)
    for i in cfg.global_layers:
        g = g.at[i].set(True)
    return g


def init_lm(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    params = {
        "embed": init_embedding(ks[0], cfg.vocab, cfg.d_model),
        "blocks": jax.vmap(lambda k: init_block(k, cfg))(
            jax.random.split(ks[1], cfg.n_layers)),
        "final_norm": init_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["head"] = truncated_normal(ks[2], (cfg.d_model, cfg.vocab),
                                          cfg.d_model ** -0.5)
    if cfg.frontend is not None:
        params["frontend_proj"] = truncated_normal(
            ks[3], (cfg.d_model, cfg.d_model), cfg.d_model ** -0.5)
    return params


def _logits(params, x, cfg: ModelConfig):
    head = (params["embed"]["table"].T if cfg.tie_embeddings
            else params["head"])
    logits = x @ head.astype(x.dtype)
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits.astype(jnp.float32) / c)
    return logits


def forward(params, tokens, cfg: ModelConfig,
            prefix_embeds: Optional[jnp.ndarray] = None,
            shard_fn: ShardFn = _id_shard,
            use_pallas: Optional[bool] = None,
            collect_kv: bool = False):
    """Training / prefill forward.

    tokens: (B, S) int32. prefix_embeds: (B, P, d) stub frontend output
    (vlm/audio), prepended before the token embeddings.
    Returns (logits (B, S_total, d), aux) or (logits, aux, caches) with
    ``collect_kv`` (prefill).
    """
    dtype = jnp.dtype(cfg.dtype)
    x = apply_embedding(params["embed"], tokens, dtype)
    if prefix_embeds is not None:
        pe = prefix_embeds.astype(dtype) @ params["frontend_proj"].astype(dtype)
        x = jnp.concatenate([pe, x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = shard_fn(x, "residual")
    is_global = _is_global_arr(cfg)

    def body(carry, layer):
        xc, aux = carry
        lp, g = layer
        xc, a = apply_block(lp, xc, cfg, positions, g, shard_fn=shard_fn,
                            use_pallas=use_pallas)
        return (xc, aux + a), None

    body = maybe_remat(body, cfg)
    if cfg.scan_layers:
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   (params["blocks"], is_global))
    else:
        aux = jnp.zeros((), jnp.float32)
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda t: t[i], params["blocks"])
            (x, aux), _ = body((x, aux), (lp, is_global[i]))
    x = apply_rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return _logits(params, x, cfg), aux


def init_caches(cfg: ModelConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16):
    """Stacked (L, ...) caches for the scan-over-layers decode path.

    Hybrid models return a per-layer *list* (global layers carry a full
    horizon, windowed layers a ring of ``window`` slots - shapes differ), and
    decode unrolls layers instead of scanning.
    """
    if cfg.family == "hybrid":
        g = set(cfg.global_layers)
        return [hybrid_mod.init_hybrid_cache(cfg, batch, max_len,
                                             is_global=(i in g), dtype=dtype)
                for i in range(cfg.n_layers)]
    def one(_):
        if cfg.family == "ssm":
            return mamba_mod.init_ssm_cache(cfg, batch, dtype)
        return attn_mod.init_kv_cache(cfg, batch, max_len, dtype)
    caches = [one(i) for i in range(cfg.n_layers)]
    return jax.tree.map(lambda *ls: jnp.stack(ls), *caches)


def decode_step(params, token, cfg: ModelConfig, caches, cache_index,
                shard_fn: ShardFn = _id_shard):
    """One serving step: token (B, 1) -> (logits (B, 1, V), new caches)."""
    dtype = jnp.dtype(cfg.dtype)
    x = apply_embedding(params["embed"], token, dtype)
    x = shard_fn(x, "residual")
    is_global = _is_global_arr(cfg)

    def body(carry, layer):
        xc = carry
        lp, cache, g = layer
        xc, _, nc = apply_block_decode(lp, xc, cfg, cache, cache_index, g)
        xc = shard_fn(xc, "residual")
        return xc, nc

    if isinstance(caches, list):            # hybrid: ragged cache shapes
        ncs = []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda t: t[i], params["blocks"])
            x, nc = body(x, (lp, caches[i], is_global[i]))
            ncs.append(nc)
        new_caches = ncs
    elif cfg.scan_layers:
        x, new_caches = jax.lax.scan(body, x,
                                     (params["blocks"], caches, is_global))
    else:
        ncs = []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda t: t[i], params["blocks"])
            cache = jax.tree.map(lambda t: t[i], caches)
            x, nc = body(x, (lp, cache, is_global[i]))
            ncs.append(nc)
        new_caches = jax.tree.map(lambda *ls: jnp.stack(ls), *ncs)
    x = apply_rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return _logits(params, x, cfg), new_caches


def prefill(params, tokens, cfg: ModelConfig,
            prefix_embeds: Optional[jnp.ndarray] = None,
            shard_fn: ShardFn = _id_shard,
            use_pallas: Optional[bool] = None):
    """Prefill: full forward + per-layer KV caches (attention families).

    Implemented as a scan whose ys are the per-layer caches.
    """
    dtype = jnp.dtype(cfg.dtype)
    x = apply_embedding(params["embed"], tokens, dtype)
    if prefix_embeds is not None:
        pe = prefix_embeds.astype(dtype) @ params["frontend_proj"].astype(dtype)
        x = jnp.concatenate([pe, x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = shard_fn(x, "residual")
    is_global = _is_global_arr(cfg)

    def body(carry, layer):
        xc, aux = carry
        lp, g = layer
        h = apply_rmsnorm(lp["ln1"], xc, cfg.norm_eps)
        kv = None
        if cfg.family in ("dense", "moe", "vlm"):
            q, k, v = attn_mod._project_qkv(lp["attn"], h, cfg, positions,
                                            dtype)
            kv = {"k": k, "v": v}
        xc, a = apply_block(lp, xc, cfg, positions, g, shard_fn=shard_fn,
                            use_pallas=use_pallas)
        return (xc, aux + a), kv

    body = maybe_remat(body, cfg)
    (x, aux), caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (params["blocks"], is_global))
    x = apply_rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return _logits(params, x, cfg), aux, caches
