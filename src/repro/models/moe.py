"""Mixture-of-Experts FFN with sort-based token dispatch (dropping impl).

Design notes (MaxText-style, chosen for multi-pod shardability):
  * router -> top-k -> flatten (tokens x k) assignments,
  * stable-sort assignments by expert, compute each assignment's position
    within its expert via a counts/offset subtraction (no giant one-hot
    dispatch tensors - the GShard einsum would materialize O(T*E*C)),
  * scatter into a (E, C, d) padded buffer (assignments past capacity C are
    dropped, standard dropping semantics),
  * batched expert FFN einsum, sharded over the 'model' axis in E,
  * gather back + weighted combine + load-balancing aux loss.

The expert einsum is the paper's dgemm profile batched E ways; EP sharding
adds the all-to-all traffic the roofline's collective term tracks.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import _act, truncated_normal


def init_moe(key, cfg: ModelConfig):
    d = cfg.d_model
    de = cfg.d_expert or cfg.d_ff
    e = cfg.n_experts
    ks = jax.random.split(key, 4)
    p = {
        "router": truncated_normal(ks[0], (d, e), d ** -0.5),
        "w_in": truncated_normal(ks[1], (e, d, de), d ** -0.5),
        "w_out": truncated_normal(ks[2], (e, de, d), de ** -0.5),
    }
    if cfg.glu:
        p["w_gate"] = truncated_normal(ks[3], (e, d, de), d ** -0.5)
    return p


def capacity(n_tokens: int, cfg: ModelConfig) -> int:
    c = int(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(8, -(-c // 8) * 8)


def apply_moe(p, x: jnp.ndarray, cfg: ModelConfig
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (y, aux_loss).

    ``cfg.moe_grouped`` dispatches per batch row (group = one sequence):
    the (E, C, d) buffer grows a leading B dim sharded over the data axes
    while E stays sharded over "model" - dispatch scatter, expert einsum and
    combine gather are all shard-LOCAL. The flat (global-token) dispatch
    forces XLA to reshard T x d activations against the model-sharded buffer
    every layer: the all-to-all/collective-permute storm the qwen3 baseline
    row shows (EXPERIMENTS.md §Perf). Dropping variance rises slightly
    (capacity per row instead of global), standard group-wise semantics.
    """
    if cfg.moe_grouped:
        y, aux = jax.vmap(lambda row: _moe_tokens(p, row, cfg))(x)
        return y, jnp.mean(aux)
    b, s, d = x.shape
    y, aux = _moe_tokens(p, x.reshape(b * s, d), cfg)
    return y.reshape(b, s, d), aux


def _moe_tokens(p, xt: jnp.ndarray, cfg: ModelConfig
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sort-based dispatch over a flat (T, d) token group."""
    dtype = xt.dtype
    t, d = xt.shape
    k = cfg.top_k
    e = cfg.n_experts
    cap = capacity(t, cfg)

    logits = (xt.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # (T, E)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)            # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # ---- load-balancing aux loss (Switch-style) ----
    me = jnp.mean(probs, axis=0)                               # mean prob
    ce = jnp.mean(jax.nn.one_hot(expert_ids[:, 0], e), axis=0) # top-1 fraction
    aux = cfg.router_aux_coef * e * jnp.sum(me * ce)

    # ---- sort-based dispatch ----
    flat_e = expert_ids.reshape(-1)                            # (T*k,)
    flat_t = jnp.repeat(jnp.arange(t), k)                      # token of slot
    order = jnp.argsort(flat_e, stable=True)
    se, st = flat_e[order], flat_t[order]
    counts = jnp.zeros((e,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts                       # exclusive
    pos = jnp.arange(t * k, dtype=jnp.int32) - starts[se]
    dest = jnp.where(pos < cap, se * cap + pos, e * cap)       # drop slot

    buf = jnp.zeros((e * cap + 1, d), dtype).at[dest].set(xt[st].astype(dtype))
    h = buf[: e * cap].reshape(e, cap, d)

    # ---- expert FFN (batched GEMM, sharded over E) ----
    up = jnp.einsum("ecd,edf->ecf", h, p["w_in"].astype(dtype))
    if cfg.glu:
        g = jnp.einsum("ecd,edf->ecf", h, p["w_gate"].astype(dtype))
        up = _act(g, cfg.act) * up
    else:
        up = _act(up, cfg.act)
    out = jnp.einsum("ecf,efd->ecd", up, p["w_out"].astype(dtype))

    # ---- combine ----
    out_flat = jnp.concatenate(
        [out.reshape(e * cap, d), jnp.zeros((1, d), dtype)], axis=0)
    slot_out = out_flat[dest]                                  # sorted order
    slot_gate = gate_vals.reshape(-1)[order].astype(dtype)
    y = jnp.zeros((t, d), dtype).at[st].add(slot_out * slot_gate[:, None])
    return y, aux.astype(jnp.float32)
