"""Shared layer primitives: norms, embeddings, linear, RoPE, FFN.

Parameters are plain pytrees (nested dicts) built by ``init_*`` functions;
``apply_*`` functions are pure. Compute runs in cfg.dtype (bf16 by default)
with fp32 norms/softmax; parameters are stored fp32 and cast at use
(the train state owns the masters).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def truncated_normal(key, shape, scale: float, dtype=jnp.float32):
    return scale * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def init_linear(key, d_in: int, d_out: int, bias: bool = False):
    p = {"w": truncated_normal(key, (d_in, d_out), d_in ** -0.5)}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def apply_linear(p, x, dtype):
    y = x.astype(dtype) @ p["w"].astype(dtype)
    if "b" in p:
        y = y + p["b"].astype(dtype)
    return y


def init_rmsnorm(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def apply_rmsnorm(p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def init_embedding(key, vocab: int, d: int):
    return {"table": truncated_normal(key, (vocab, d), 1.0)}


def apply_embedding(p, ids, dtype):
    return p["table"].astype(dtype)[ids]


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10_000.0):
    """Rotary embedding. x: (..., S, H, D) or (..., S, D); positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs     # (..., S, half)
    if x.ndim == ang.ndim + 1:                                 # head dim present
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                           axis=-1).astype(x.dtype)


def sinusoidal_positions(seq: int, d: int, dtype=jnp.float32):
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    div = jnp.exp(-jnp.log(10_000.0) * jnp.arange(0, d, 2, jnp.float32) / d)
    pe = jnp.zeros((seq, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div[: (d + 1) // 2]))
    return pe.astype(dtype)


def _act(x, kind: str):
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(kind)


def init_ffn(key, d: int, f: int, glu: bool):
    ks = jax.random.split(key, 3)
    p = {"w_in": truncated_normal(ks[0], (d, f), d ** -0.5),
         "w_out": truncated_normal(ks[1], (f, d), f ** -0.5)}
    if glu:
        p["w_gate"] = truncated_normal(ks[2], (d, f), d ** -0.5)
    return p


def apply_ffn(p, x, act: str, dtype):
    h = x @ p["w_in"].astype(dtype)
    if "w_gate" in p:
        h = _act(x @ p["w_gate"].astype(dtype), act) * h
    else:
        h = _act(h, act)
    return h @ p["w_out"].astype(dtype)
