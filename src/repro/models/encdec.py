"""Whisper-style encoder-decoder backbone.

The conv/mel frontend is a STUB per the assignment: ``input_specs()`` feeds
precomputed frame embeddings (B, encoder_seq, d_model). Sinusoidal positions
are used on both sides (the released model's learned decoder positions cap
at 448 tokens; sinusoidal extrapolates, which makes the assigned
``decode_32k`` cell well-defined - recorded in DESIGN.md section 4).

Encoder: bidirectional self-attention blocks. Decoder: causal self-attention
+ cross-attention to the encoder memory + FFN. Decode caches: self-attention
KV ring + cross-attention K/V computed once from the memory.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models.config import ModelConfig
from repro.models.layers import (apply_embedding, apply_ffn, apply_rmsnorm,
                                 init_embedding, init_ffn, init_rmsnorm,
                                 sinusoidal_positions, truncated_normal)


def init_encdec(key, cfg: ModelConfig):
    ks = jax.random.split(key, 6)

    def enc_block(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": init_rmsnorm(cfg.d_model),
                "attn": attn_mod.init_attention(k1, cfg),
                "ln2": init_rmsnorm(cfg.d_model),
                "ffn": init_ffn(k2, cfg.d_model, cfg.d_ff, cfg.glu)}

    def dec_block(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"ln1": init_rmsnorm(cfg.d_model),
                "attn": attn_mod.init_attention(k1, cfg),
                "ln_x": init_rmsnorm(cfg.d_model),
                "xattn": attn_mod.init_attention(k2, cfg),
                "ln2": init_rmsnorm(cfg.d_model),
                "ffn": init_ffn(k3, cfg.d_model, cfg.d_ff, cfg.glu)}

    return {
        "embed": init_embedding(ks[0], cfg.vocab, cfg.d_model),
        "enc_blocks": jax.vmap(enc_block)(
            jax.random.split(ks[1], cfg.encoder_layers)),
        "enc_norm": init_rmsnorm(cfg.d_model),
        "dec_blocks": jax.vmap(dec_block)(
            jax.random.split(ks[2], cfg.n_layers)),
        "dec_norm": init_rmsnorm(cfg.d_model),
        "head": truncated_normal(ks[3], (cfg.d_model, cfg.vocab),
                                 cfg.d_model ** -0.5),
    }


def encode(params, frames, cfg: ModelConfig, shard_fn=lambda x, n: x,
           use_pallas: Optional[bool] = None):
    """frames: (B, S_enc, d) stub frontend embeddings -> memory (B, S_enc, d)."""
    dtype = jnp.dtype(cfg.dtype)
    b, s, _ = frames.shape
    x = frames.astype(dtype) + sinusoidal_positions(s, cfg.d_model, dtype)[None]
    x = shard_fn(x, "residual")
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(xc, lp):
        h = apply_rmsnorm(lp["ln1"], xc, cfg.norm_eps)
        xc = xc + attn_mod.apply_attention(lp["attn"], h, cfg, positions,
                                           causal=False,
                                           use_pallas=use_pallas)
        h = apply_rmsnorm(lp["ln2"], xc, cfg.norm_eps)
        xc = xc + apply_ffn(lp["ffn"], h, cfg.act, xc.dtype)
        return shard_fn(xc, "residual"), None

    from repro.models.transformer import maybe_remat
    body = maybe_remat(body, cfg)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return apply_rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def decode_train(params, tokens, memory, cfg: ModelConfig,
                 shard_fn=lambda x, n: x, use_pallas: Optional[bool] = None):
    """Teacher-forced decoder pass: tokens (B, S) + memory -> logits."""
    dtype = jnp.dtype(cfg.dtype)
    b, s = tokens.shape
    x = apply_embedding(params["embed"], tokens, dtype)
    x = x + sinusoidal_positions(s, cfg.d_model, dtype)[None]
    x = shard_fn(x, "residual")
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(xc, lp):
        h = apply_rmsnorm(lp["ln1"], xc, cfg.norm_eps)
        xc = xc + attn_mod.apply_attention(lp["attn"], h, cfg, positions,
                                           causal=True, use_pallas=use_pallas)
        h = apply_rmsnorm(lp["ln_x"], xc, cfg.norm_eps)
        xc = xc + attn_mod.apply_cross_attention(lp["xattn"], h, cfg, memory,
                                                 use_pallas=use_pallas)
        h = apply_rmsnorm(lp["ln2"], xc, cfg.norm_eps)
        xc = xc + apply_ffn(lp["ffn"], h, cfg.act, xc.dtype)
        return shard_fn(xc, "residual"), None

    from repro.models.transformer import maybe_remat
    body = maybe_remat(body, cfg)
    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    x = apply_rmsnorm(params["dec_norm"], x, cfg.norm_eps)
    return x @ params["head"].astype(dtype)


def forward(params, frames, tokens, cfg: ModelConfig,
            shard_fn=lambda x, n: x, use_pallas: Optional[bool] = None):
    memory = encode(params, frames, cfg, shard_fn, use_pallas)
    logits = decode_train(params, tokens, memory, cfg, shard_fn, use_pallas)
    return logits, jnp.zeros((), jnp.float32)


def init_decode_caches(params, memory, cfg: ModelConfig, batch: int,
                       max_len: int, dtype=jnp.bfloat16):
    """Self-attn KV caches + cross K/V precomputed from memory, per layer."""
    hd, hkv = cfg.hd, cfg.n_kv
    sm = memory.shape[1]

    def per_layer(lp):
        ck = (memory @ lp["xattn"]["wk"].astype(memory.dtype)
              ).reshape(batch, sm, hkv, hd)
        cv = (memory @ lp["xattn"]["wv"].astype(memory.dtype)
              ).reshape(batch, sm, hkv, hd)
        return {
            "self": attn_mod.init_kv_cache(cfg, batch, max_len, dtype),
            "cross_k": ck.astype(dtype), "cross_v": cv.astype(dtype),
        }

    return jax.vmap(per_layer)(params["dec_blocks"])


def decode_step(params, token, cfg: ModelConfig, caches, cache_index,
                shard_fn=lambda x, n: x):
    """One decoder token against cached self/cross KV."""
    dtype = jnp.dtype(cfg.dtype)
    b = token.shape[0]
    x = apply_embedding(params["embed"], token, dtype)
    pos_tab = sinusoidal_positions(caches["self"]["k"].shape[2],
                                   cfg.d_model, dtype)
    x = x + jax.lax.dynamic_slice_in_dim(pos_tab, cache_index, 1)[None]
    x = shard_fn(x, "residual")

    def body(xc, layer):
        lp, cache = layer
        smax = cache["self"]["k"].shape[1]
        kv_len = jnp.minimum(cache_index + 1, smax)
        h = apply_rmsnorm(lp["ln1"], xc, cfg.norm_eps)
        y, nkv = attn_mod.apply_attention_decode(
            lp["attn"], h, cfg, cache["self"], cache_index % smax,
            cache_index, kv_len)
        xc = xc + y
        h = apply_rmsnorm(lp["ln_x"], xc, cfg.norm_eps)
        hd, hq, hkv = cfg.hd, cfg.n_heads, cfg.n_kv
        q = (h @ lp["xattn"]["wq"].astype(dtype)).reshape(b, 1, hq, hd)
        o = attn_mod.masked_decode_attention(
            jnp.moveaxis(q, 2, 1),
            jnp.moveaxis(cache["cross_k"], 2, 1).astype(dtype),
            jnp.moveaxis(cache["cross_v"], 2, 1).astype(dtype),
            cache["cross_k"].shape[1])
        o = jnp.moveaxis(o, 1, 2).reshape(b, 1, hq * hd)
        xc = xc + o @ lp["xattn"]["wo"].astype(dtype)
        h = apply_rmsnorm(lp["ln2"], xc, cfg.norm_eps)
        xc = xc + apply_ffn(lp["ffn"], h, cfg.act, xc.dtype)
        new_cache = {"self": nkv, "cross_k": cache["cross_k"],
                     "cross_v": cache["cross_v"]}
        return xc, new_cache

    x, new_caches = jax.lax.scan(body, x, (params["dec_blocks"], caches))
    x = apply_rmsnorm(params["dec_norm"], x, cfg.norm_eps)
    return x @ params["head"].astype(dtype), new_caches
