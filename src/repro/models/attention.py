"""GQA attention with RoPE, KV cache, sliding windows, and cross-attention.

Training path uses the flash oracle (Pallas kernel on TPU via ops.attention);
decode path writes one token into the cache and attends with a kv-length
mask. The decode attention over a sequence-sharded cache (flash-decoding via
shard_map) lives in repro.distributed.collectives.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models.config import ModelConfig
from repro.models.layers import init_linear, rope, truncated_normal


def init_attention(key, cfg: ModelConfig, d_in: Optional[int] = None):
    d = d_in or cfg.d_model
    hd, hq, hkv = cfg.hd, cfg.n_heads, cfg.n_kv
    ks = jax.random.split(key, 4)
    p = {
        "wq": truncated_normal(ks[0], (d, hq * hd), d ** -0.5),
        "wk": truncated_normal(ks[1], (d, hkv * hd), d ** -0.5),
        "wv": truncated_normal(ks[2], (d, hkv * hd), d ** -0.5),
        "wo": truncated_normal(ks[3], (hq * hd, d), (hq * hd) ** -0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), jnp.float32)
        p["bk"] = jnp.zeros((hkv * hd,), jnp.float32)
        p["bv"] = jnp.zeros((hkv * hd,), jnp.float32)
    return p


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  dtype=jnp.bfloat16):
    hd = cfg.hd
    return {
        "k": jnp.zeros((batch, max_len, cfg.n_kv, hd), dtype),
        "v": jnp.zeros((batch, max_len, cfg.n_kv, hd), dtype),
    }


def _project_qkv(p, x, cfg: ModelConfig, positions, dtype, use_rope=True):
    b, s, _ = x.shape
    hd, hq, hkv = cfg.hd, cfg.n_heads, cfg.n_kv
    q = x @ p["wq"].astype(dtype)
    k = x @ p["wk"].astype(dtype)
    v = x @ p["wv"].astype(dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dtype)
        k = k + p["bk"].astype(dtype)
        v = v + p["bv"].astype(dtype)
    q = q.reshape(b, s, hq, hd)
    k = k.reshape(b, s, hkv, hd)
    v = v.reshape(b, s, hkv, hd)
    if use_rope and cfg.pos == "rope":
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def apply_attention(p, x, cfg: ModelConfig, positions,
                    window: Optional[int] = None, causal: bool = True,
                    use_pallas: Optional[bool] = None) -> jnp.ndarray:
    """Full-sequence (training / prefill) attention. x: (B, S, d)."""
    dtype = x.dtype
    q, k, v = _project_qkv(p, x, cfg, positions, dtype)
    qh = jnp.moveaxis(q, 2, 1)                    # (B, Hq, S, hd)
    kh = jnp.moveaxis(k, 2, 1)
    vh = jnp.moveaxis(v, 2, 1)
    o = ops.attention(qh, kh, vh, causal=causal, window=window,
                      use_pallas=use_pallas)
    b, s = x.shape[:2]
    o = jnp.moveaxis(o, 1, 2).reshape(b, s, cfg.n_heads * cfg.hd)
    return o @ p["wo"].astype(dtype)


def apply_attention_decode(p, x, cfg: ModelConfig, cache, write_idx,
                           position, kv_len,
                           use_pallas: Optional[bool] = None
                           ) -> Tuple[jnp.ndarray, dict]:
    """One-token decode. x: (B, 1, d); cache k/v: (B, Smax, Hkv, hd).

    ``write_idx``: cache slot to write (ring buffers: position % Smax);
    ``position``: absolute token position (RoPE);
    ``kv_len``: number of valid cache slots to attend over.

    RoPE keys are stored rotated at their absolute positions, so ring-buffer
    slot order does not matter - relative offsets survive the dot product.
    """
    dtype = x.dtype
    b = x.shape[0]
    positions = jnp.full((b, 1), position, jnp.int32)
    q, k, v = _project_qkv(p, x, cfg, positions, dtype)
    ck = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, write_idx, 0, 0))
    cv = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, write_idx, 0, 0))
    qh = jnp.moveaxis(q, 2, 1)                    # (B, Hq, 1, hd)
    kh = jnp.moveaxis(ck, 2, 1).astype(dtype)     # (B, Hkv, Smax, hd)
    vh = jnp.moveaxis(cv, 2, 1).astype(dtype)
    o = masked_decode_attention(qh, kh, vh, kv_len)
    o = jnp.moveaxis(o, 1, 2).reshape(b, 1, cfg.n_heads * cfg.hd)
    return o @ p["wo"].astype(dtype), {"k": ck, "v": cv}


def masked_decode_attention(q, k, v, kv_len):
    """Reference decode attention with explicit kv-len mask (fp32 softmax).

    q: (B, Hq, 1, hd); k/v: (B, Hkv, Smax, hd). Replaced per-shard by the
    flash-decoding shard_map in the distributed serve path.
    """
    b, hq, _, hd = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    qf = q.astype(jnp.float32).reshape(b, hkv, group, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bhgd,bhkd->bhgk", qf, kf) / (hd ** 0.5)
    kpos = jnp.arange(k.shape[2])
    mask = kpos[None, :] < kv_len
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhgk,bhkd->bhgd", probs, vf)
    return o.reshape(b, hq, 1, hd).astype(q.dtype)


def apply_cross_attention(p, x, cfg: ModelConfig, memory,
                          use_pallas: Optional[bool] = None) -> jnp.ndarray:
    """Decoder cross-attention: queries from x (B,S,d), keys/values from
    encoder memory (B,Sm,d). No RoPE on cross path (Whisper-style)."""
    dtype = x.dtype
    b, s, _ = x.shape
    sm = memory.shape[1]
    hd, hq, hkv = cfg.hd, cfg.n_heads, cfg.n_kv
    q = (x @ p["wq"].astype(dtype)).reshape(b, s, hq, hd)
    k = (memory @ p["wk"].astype(dtype)).reshape(b, sm, hkv, hd)
    v = (memory @ p["wv"].astype(dtype)).reshape(b, sm, hkv, hd)
    o = ops.attention(jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1),
                      jnp.moveaxis(v, 2, 1), causal=False,
                      use_pallas=use_pallas)
    o = jnp.moveaxis(o, 1, 2).reshape(b, s, hq * hd)
    return o @ p["wo"].astype(dtype)
