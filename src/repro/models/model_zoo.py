"""Unified model API over all families: init / forward / prefill / decode.

Every architecture (dense, moe, ssm, hybrid, vlm, encdec) is driven through
the same four functions; the launcher, trainer, and dry-run never dispatch on
family themselves.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import encdec, transformer
from repro.models.config import ModelConfig


def init(key, cfg: ModelConfig):
    if cfg.family == "encdec":
        return encdec.init_encdec(key, cfg)
    return transformer.init_lm(key, cfg)


def forward(params, batch: dict, cfg: ModelConfig, shard_fn=lambda x, n: x,
            use_pallas: Optional[bool] = None):
    """batch: {'tokens': (B,S)} + optional {'frames'|'patches': (B,P,d)}.
    Returns (logits, aux)."""
    if cfg.family == "encdec":
        return encdec.forward(params, batch["frames"], batch["tokens"], cfg,
                              shard_fn=shard_fn, use_pallas=use_pallas)
    prefix = batch.get("patches")
    return transformer.forward(params, batch["tokens"], cfg,
                               prefix_embeds=prefix, shard_fn=shard_fn,
                               use_pallas=use_pallas)


def init_caches(params, cfg: ModelConfig, batch: int, max_len: int,
                memory: Optional[jnp.ndarray] = None, dtype=jnp.bfloat16):
    if cfg.family == "encdec":
        assert memory is not None, "encdec caches need the encoder memory"
        return encdec.init_decode_caches(params, memory, cfg, batch, max_len,
                                         dtype)
    return transformer.init_caches(cfg, batch, max_len, dtype)


def decode_step(params, token, cfg: ModelConfig, caches, cache_index,
                shard_fn=lambda x, n: x):
    if cfg.family == "encdec":
        return encdec.decode_step(params, token, cfg, caches, cache_index,
                                  shard_fn=shard_fn)
    return transformer.decode_step(params, token, cfg, caches, cache_index,
                                   shard_fn=shard_fn)


def prefill(params, batch: dict, cfg: ModelConfig, shard_fn=lambda x, n: x,
            use_pallas: Optional[bool] = None):
    if cfg.family == "encdec":
        memory = encdec.encode(params, batch["frames"], cfg, shard_fn,
                               use_pallas)
        logits = encdec.decode_train(params, batch["tokens"], memory, cfg,
                                     shard_fn, use_pallas)
        return logits, jnp.zeros((), jnp.float32), memory
    prefix = batch.get("patches")
    return transformer.prefill(params, batch["tokens"], cfg,
                               prefix_embeds=prefix, shard_fn=shard_fn,
                               use_pallas=use_pallas)


def param_count(cfg: ModelConfig) -> int:
    """Exact parameter count via abstract init (no allocation)."""
    shapes = jax.eval_shape(lambda k: init(k, cfg),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    return int(sum(np.prod(l.shape) for l in jax.tree.leaves(shapes)))


def active_param_count(cfg: ModelConfig) -> int:
    """Params touched per token: total minus non-selected experts."""
    total = param_count(cfg)
    if cfg.family != "moe":
        return total
    de = cfg.d_expert or cfg.d_ff
    per_expert = cfg.d_model * de * (3 if cfg.glu else 2)
    return total - cfg.n_layers * (cfg.n_experts - cfg.top_k) * per_expert
