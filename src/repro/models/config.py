"""Model configuration - one dataclass covers the whole assigned pool.

Families: dense (GQA transformer), moe (dense + expert FFNs), ssm (Mamba-2),
hybrid (parallel attn+SSM heads, Hymba-style), encdec (Whisper-style),
vlm/audio (LM backbone + stub modality frontend feeding precomputed
embeddings).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    act: str = "silu"                # silu | gelu
    glu: bool = True                 # gated FFN (SwiGLU / GeGLU)
    qkv_bias: bool = False
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_expert: int = 0                # expert hidden dim (d_ff if 0)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    moe_every: int = 1               # every k-th layer is MoE
    moe_grouped: bool = False        # per-batch-row (EP-local) dispatch

    # SSM (Mamba-2)
    ssm_state: int = 0
    ssm_heads: int = 0               # 0 -> d_inner // ssm_head_dim
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 64

    # hybrid (Hymba)
    window: Optional[int] = None          # sliding window for local layers
    global_layers: Tuple[int, ...] = ()   # full-attention layer indices

    # encoder-decoder (Whisper)
    encoder_layers: int = 0
    encoder_seq: int = 0                  # precomputed frame count (1500)

    # modality frontend stub (vlm/audio)
    frontend: Optional[str] = None        # 'vision' | 'audio'
    num_prefix_tokens: int = 0            # patch embeddings prepended

    # positions / norm
    rope_theta: float = 10_000.0
    pos: str = "rope"                     # rope | sinusoidal
    norm_eps: float = 1e-6
    logit_softcap: Optional[float] = None

    # numerics / compilation
    dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "full"       # full | dots | none (hillclimb lever)
    scan_layers: bool = True

    # distribution/runtime defaults (overridable per run)
    accum_steps: int = 1                  # gradient accumulation microbatches
    opt_8bit: bool = False                # 8-bit AdamW moments
    master_fp32: bool = True              # fp32 master params

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.ssm_heads or self.d_inner // self.ssm_head_dim

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run long_500k? SSM and windowed-hybrid: yes."""
        return self.family == "ssm" or (self.family == "hybrid"
                                        and self.window is not None)

    def param_count(self) -> int:
        """Analytical parameter count (for 6ND roofline math)."""
        d, v = self.d_model, self.vocab
        n = v * d                                           # embedding
        if not self.tie_embeddings:
            n += d * v                                      # lm head
        for i in range(self.n_layers):
            n += self._layer_params(i)
        if self.family == "encdec":
            for _ in range(self.encoder_layers):
                n += self._attn_params() + self._ffn_params() + 2 * d
            n += self.n_layers * (self._attn_params() + d)  # cross-attn
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k experts only)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        de = self.d_expert or self.d_ff
        per_expert = d * de * (3 if self.glu else 2)
        total = self.param_count()
        moe_layers = len([i for i in range(self.n_layers)
                          if i % self.moe_every == 0])
        return (total - moe_layers * self.n_experts * per_expert
                + moe_layers * self.top_k * per_expert)

    def _attn_params(self) -> int:
        d, hq, hkv, hd = self.d_model, self.n_heads, self.n_kv, self.hd
        return d * hq * hd + 2 * d * hkv * hd + hq * hd * d

    def _ffn_params(self) -> int:
        f = self.d_ff
        return self.d_model * f * (3 if self.glu else 2)

    def _moe_params(self) -> int:
        de = self.d_expert or self.d_ff
        per = self.d_model * de * (3 if self.glu else 2)
        return self.n_experts * per + self.d_model * self.n_experts

    def _ssm_params(self) -> int:
        d, di = self.d_model, self.d_inner
        g, nst, h = self.ssm_groups, self.ssm_state, self.n_ssm_heads
        in_proj = d * (2 * di + 2 * g * nst + h)
        conv = (di + 2 * g * nst) * self.ssm_conv
        return in_proj + conv + 2 * h + di + di * d       # A, dt_bias, norm, out

    def _layer_params(self, i: int) -> int:
        d = self.d_model
        n = 2 * d                                          # two rmsnorms
        if self.family == "ssm":
            return n + self._ssm_params() + self._ffn_params() \
                if self.d_ff else n + self._ssm_params()
        if self.family == "hybrid":
            return n + self._attn_params() + self._ssm_params() // 2 \
                + self._ffn_params()
        n += self._attn_params()
        if self.family == "moe" and i % self.moe_every == 0:
            n += self._moe_params()
        else:
            n += self._ffn_params()
        return n
