from repro.models import model_zoo
from repro.models.config import ModelConfig
