"""Elastic re-scaling: move a train state between meshes of different size.

A checkpoint written on one mesh restores onto any other (more pods, fewer
pods, different DP x TP split): checkpoints store full logical arrays
(ckpt.checkpoint), and this module re-derives the sharding rules on the new
mesh and re-places every leaf. The data pipeline is counter-based, so the
token stream is identical across re-shardings - resume is bitwise-consistent
modulo reduction order (tests/test_ckpt.py asserts loss-trajectory
continuity).
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh

from repro.ckpt import checkpoint
from repro.distributed import sharding as sh


def reshard_state(state, new_mesh: Mesh, fsdp: bool = True):
    """Re-place an in-memory train state onto ``new_mesh``."""
    specs = sh.state_specs(state, new_mesh, fsdp=fsdp)
    shardings = sh.to_shardings(specs, new_mesh)
    return jax.tree.map(jax.device_put, state, shardings)


def elastic_restore(directory: str, like, new_mesh: Mesh,
                    step: Optional[int] = None, fsdp: bool = True):
    """Restore the latest (or given) checkpoint onto a new mesh.

    ``like``: abstract state (from jax.eval_shape of init) defining the
    structure; returns (state, step).
    """
    specs = sh.state_specs(like, new_mesh, fsdp=fsdp)
    shardings = sh.to_shardings(specs, new_mesh)
    return checkpoint.restore(directory, like, step=step,
                              shardings=shardings)
