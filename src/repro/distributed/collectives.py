"""Manual collectives: compressed gradient sync and flash-decoding.

Two shard_map-level building blocks the pjit path cannot express on its own:

1. **int8-compressed gradient mean with error feedback.** Gradients are
   blockwise-quantized to int8 before crossing the (slow, cross-pod) link;
   the quantization residual is fed back into the next step's gradient
   (error feedback keeps SGD/Adam convergence - Karimireddy et al.). The
   collective moves 1/4 of the fp32 bytes; the HLO collective-bytes parser
   (core.roofline) sees exactly that reduction.

2. **Flash-decoding over a sequence-sharded KV cache.** Decode attention
   with the cache's S dim sharded over "model": each shard computes a
   partial softmax (m_i, l_i, o_i) over its chunk; the combine is two tiny
   collectives (pmax + psum) of (B, H, d)-sized tensors. This is the paper's
   'more parallel accumulators for the serial reduction' insight applied at
   cluster scale - and what fits mistral-large-123b's 1.5 TB decode cache.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
from contextvars import ContextVar
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro import obs as _obs
from repro.obs import counters as _counters

Q_BLOCK = 256


# ---------------------------------------------------------------------------
# trace-time collective metadata (the spmd_lint "record view")
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CollectiveRecord:
    """One trace-time communication/padding fact, kind-tagged.

    The distributed sibling of :class:`repro.tune.dispatch.Resolution`:
    everything here is computed in Python while shard_map traces, so a
    ``jax.make_jaxpr`` pass captures the full declared schedule without
    executing anything. ``repro.analysis.spmd_lint`` cross-checks these
    declarations against the jaxpr it traced (rules CC002/CC003/SH002).

    kinds: ``"ring_bcast"`` (one SUMMA panel movement - axis/size/src/
    hops/bytes), ``"pdgemm"`` (one whole pdgemm schedule - the global
    problem geometry ``info`` carries lets the analyzer re-price
    ``plan_pdgemm``'s collective term), ``"pad_batch"`` (one ragged-batch
    identity pad - ``info`` carries batch/pad/identity).
    """

    kind: str
    axis: Optional[str] = None
    size: int = 1
    src: int = 0
    hops: int = 0
    per_hop_bytes: int = 0
    wire_bytes: int = 0
    info: Optional[Dict] = None


_RECORD: "ContextVar[Optional[List[CollectiveRecord]]]" = ContextVar(
    "collective_record", default=None)


@contextlib.contextmanager
def record_collectives():
    """Collect every CollectiveRecord produced inside the scope.

    Mirrors :func:`repro.tune.dispatch.record_resolutions`: the records
    are emitted at trace time, so wrapping a ``jax.make_jaxpr`` call
    captures the declared collective schedule with no execution."""
    rec: List[CollectiveRecord] = []
    token = _RECORD.set(rec)
    try:
        yield rec
    finally:
        _RECORD.reset(token)


def emit_record(rec: CollectiveRecord) -> None:
    """Append to the active record_collectives() scope, if any (no-op
    otherwise - the hot path stays a single ContextVar read)."""
    lst = _RECORD.get()
    if lst is not None:
        lst.append(rec)


# ---------------------------------------------------------------------------
# ring broadcast: the SUMMA panel-movement primitive
# ---------------------------------------------------------------------------

def ring_bcast(val: jnp.ndarray, axis_name: str, size: int,
               src: int) -> jnp.ndarray:
    """Broadcast ``val`` from mesh index ``src`` along ``axis_name`` via a
    ring of ``size - 1`` :func:`jax.lax.ppermute` hops. Call inside
    shard_map.

    Each hop forwards the buffer one position around the ring; a device
    adopts the incoming value exactly when it is ``src``'s (step-th)
    successor, so after ``size - 1`` hops every participant holds ``src``'s
    panel. This is the pipelined alternative to a masked psum broadcast:
    hop ``t`` of panel ``s`` can overlap the local GEMM of panel ``s - 1``,
    and each hop moves only ``val.nbytes`` per link (see
    :func:`ring_bcast_bytes` - the accounting that
    :func:`repro.core.codesign.plan_pdgemm` prices).

    Observability: every call increments the ``collective.hops`` /
    ``collective.bytes`` counters and, under an active trace, records a
    ``collective.ring_bcast`` event with the per-hop panel bytes priced
    against the ambient machine's ``MemorySpec.ici_bw``. The accounting
    runs at *trace* time (this function executes inside shard_map
    tracing), so counts cover distinct traced schedules, not cached
    re-executions - see ``docs/observability.md``.
    """
    if size <= 1:
        emit_record(CollectiveRecord(
            kind="ring_bcast", axis=str(axis_name), size=int(size),
            src=int(src)))
        return val
    hops = size - 1
    n_elems = 1
    for d in val.shape:                     # static even on jit tracers
        n_elems *= int(d)
    panel_bytes = n_elems * jnp.dtype(val.dtype).itemsize
    wire_bytes = ring_bcast_bytes(panel_bytes, size)
    _counters.inc("collective.hops", hops)
    _counters.inc("collective.bytes", wire_bytes)
    emit_record(CollectiveRecord(
        kind="ring_bcast", axis=str(axis_name), size=int(size),
        src=int(src), hops=hops, per_hop_bytes=panel_bytes,
        wire_bytes=wire_bytes,
        info={"shape": list(val.shape),
              "dtype": jnp.dtype(val.dtype).name}))
    if _obs.enabled():
        attrs = {"axis": axis_name, "size": size, "src": int(src),
                 "hops": hops, "per_hop_bytes": panel_bytes,
                 "wire_bytes": wire_bytes, "shape": list(val.shape),
                 "dtype": jnp.dtype(val.dtype).name}
        try:
            from repro import arch          # lazy: avoid import cycle
            ici = arch.current_machine().memory.ici_bw
            if ici > 0:
                attrs.update(ici_bw=ici, modeled_hop_s=panel_bytes / ici,
                             modeled_s=wire_bytes / ici)
        except Exception:
            pass
        _obs.event("collective.ring_bcast", cat="collective", **attrs)
    idx = lax.axis_index(axis_name)
    perm = [((d - 1) % size, d) for d in range(size)]
    buf = val
    for step in range(size - 1):
        nxt = lax.ppermute(buf, axis_name, perm)
        buf = jnp.where(idx == (src + step + 1) % size, nxt, buf)
    return buf


def ring_bcast_bytes(panel_bytes: int, size: int) -> int:
    """On-wire bytes per participating link for one ring broadcast: the
    panel crosses ``size - 1`` hops, each carrying the full panel."""
    return int(panel_bytes) * max(int(size) - 1, 0)


def _quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    flat = x.reshape(-1)
    pad = (-flat.size) % Q_BLOCK
    fp = jnp.pad(flat, (0, pad)).reshape(-1, Q_BLOCK)
    scale = jnp.max(jnp.abs(fp), axis=1, keepdims=True) / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(fp / safe), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequantize(q, scale, shape):
    fp = q.astype(jnp.float32) * scale
    n = 1
    for d in shape:
        n *= d
    return fp.reshape(-1)[:n].reshape(shape)


def compressed_mean(x: jnp.ndarray, err: jnp.ndarray, axis_name: str
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Mean of ``x`` over ``axis_name`` with int8 on-wire compression and
    error feedback. Call inside shard_map. Returns (mean, new_err)."""
    n = lax.psum(1, axis_name)
    y = x + err
    q, scale = _quantize(y)
    sent = _dequantize(q, scale, x.shape)
    new_err = y - sent                                  # feedback residual
    # on-wire: int8 codes all-gathered (bytes = n * size/4 vs fp32 ring 2x);
    qs = lax.all_gather(q, axis_name)                   # (n, blocks, Q)
    ss = lax.all_gather(scale, axis_name)
    total = jnp.sum(qs.astype(jnp.float32) * ss, axis=0)
    nel = 1
    for d in x.shape:
        nel *= d
    mean = total.reshape(-1)[:nel].reshape(x.shape) / n
    return mean, new_err


def compressed_grad_sync(mesh: Mesh, axis_name: str = "pod"):
    """jit-able pytree gradient mean over one mesh axis with compression.

    grads enter replicated over ``axis_name`` *per shard* semantics: inside
    shard_map each device holds its local gradient; returns the synced mean
    and the updated error-feedback buffers.
    """
    def sync(grads, errs):
        def one(g, e):
            return compressed_mean(g, e, axis_name)
        flat_g, td = jax.tree.flatten(grads)
        flat_e = td.flatten_up_to(errs)
        out = [one(g, e) for g, e in zip(flat_g, flat_e)]
        return td.unflatten([o[0] for o in out]), td.unflatten([o[1] for o in out])

    spec = P()                                          # replicated leaves
    return shard_map(sync, mesh=mesh,
                     in_specs=(spec, spec), out_specs=(spec, spec),
                     check_rep=False)


# ---------------------------------------------------------------------------
# flash-decoding over a sequence-sharded cache
# ---------------------------------------------------------------------------

def _partial_softmax_attention(q, k, v, valid):
    """q (B,Hq,D); k,v (B,Hkv,Sc,D); valid (B,1,Sc) bool -> (o, m, l)."""
    b, hq, d = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    qf = q.astype(jnp.float32).reshape(b, hkv, g, d)
    s = jnp.einsum("bhgd,bhkd->bhgk", qf, k.astype(jnp.float32)) / (d ** 0.5)
    s = jnp.where(valid[:, :, None, :], s, -1e30)
    m = jnp.max(s, axis=-1)                              # (b,hkv,g)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhgk,bhkd->bhgd", p, v.astype(jnp.float32))
    return (o.reshape(b, hq, d), m.reshape(b, hq), l.reshape(b, hq))


def sharded_decode_attention(mesh: Mesh, dp_axes, kv_len_static: bool = False):
    """Builds decode_attn(q, k_cache, v_cache, kv_len) with the cache S dim
    sharded over "model" and batch over the DP axes.

    q: (B, Hq, D) replicated over "model"; caches (B, S, Hkv, D) sharded
    P(dp, "model", None, None). Output (B, Hq, D), "model"-replicated.
    """
    dp = tuple(dp_axes)
    n_model = mesh.shape["model"]

    def inner(q, k, v, kv_len):
        # per-shard chunk: S_local = S / n_model; global positions:
        idx = lax.axis_index("model")
        s_local = k.shape[1]
        kpos = idx * s_local + jnp.arange(s_local)
        valid = (kpos < kv_len)[None, None, :]
        kh = jnp.moveaxis(k, 2, 1)                      # (B,Hkv,Sc,D)
        vh = jnp.moveaxis(v, 2, 1)
        o, m, l = _partial_softmax_attention(q, kh, vh,
                                             jnp.broadcast_to(valid, (q.shape[0], 1, s_local)))
        m_g = lax.pmax(m, "model")                       # (B,Hq)
        corr = jnp.exp(m - m_g)
        l_g = lax.psum(l * corr, "model")
        o_g = lax.psum(o * corr[..., None], "model")
        safe = jnp.where(l_g > 0, l_g, 1.0)
        return (o_g / safe[..., None]).astype(q.dtype)

    qspec = P(dp if dp else None, None, None)
    kvspec = P(dp if dp else None, "model", None, None)
    return shard_map(inner, mesh=mesh,
                     in_specs=(qspec, kvspec, kvspec, P()),
                     out_specs=qspec, check_rep=False)
