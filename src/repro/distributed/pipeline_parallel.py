"""Pipeline parallelism: collective-permute microbatch schedule over a
"stage" mesh axis via shard_map.

The assigned 40-cell dry-run mesh is DP x TP per the task; PP is provided as
a first-class framework feature with its own tests/example (DESIGN.md
section 5): a GPipe-style fill-drain schedule in which stage s computes
microbatch t - s while activations hop stages through collective-permute
(the 1F1B ordering falls out of the skewed schedule; with forward-only
steady state each stage is busy every tick after fill).

``stage_fn(params_local, x)`` is the per-stage computation; params are
stacked on a leading stage dim and sharded P("stage", ...).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pipeline_forward(stage_fn: Callable, mesh: Mesh,
                     stage_axis: str = "stage"):
    """Builds run(params_stacked, x_micro) -> y_micro.

    params_stacked: pytree with leading dim = n_stages (sharded over
    ``stage_axis``). x_micro: (M, B, ...) microbatches, replicated.
    Returns (M, B, ...) outputs of the last stage, broadcast to all stages.
    """
    n_stages = mesh.shape[stage_axis]
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def inner(params, x):
        params = jax.tree.map(lambda t: t[0], params)    # local stage params
        sid = lax.axis_index(stage_axis)
        m = x.shape[0]
        ticks = m + n_stages - 1
        buf = jnp.zeros_like(x)                          # output collector
        carry = jnp.zeros_like(x[0])                     # inter-stage wire

        def tick(t, acc):
            carry, buf = acc
            # stage 0 injects microbatch t; others take the permuted wire
            inject = x[jnp.minimum(t, m - 1)]
            xin = jnp.where(sid == 0, inject, carry)
            yout = stage_fn(params, xin)
            # last stage records its result for microbatch t - (S-1)
            slot = t - (n_stages - 1)
            ok = (sid == n_stages - 1) & (slot >= 0)
            buf = lax.cond(
                ok,
                lambda b: lax.dynamic_update_index_in_dim(
                    b, yout, jnp.maximum(slot, 0), 0),
                lambda b: b, buf)
            carry = lax.ppermute(yout, stage_axis, perm)
            return (carry, buf)

        _, buf = lax.fori_loop(0, ticks, tick, (carry, buf))
        # broadcast the last stage's collected outputs to every stage
        last = n_stages - 1
        buf = lax.psum(jnp.where(sid == last, buf, jnp.zeros_like(buf)),
                       stage_axis)
        return buf

    # P(stage_axis) is a pytree *prefix*: applies to every params leaf
    return shard_map(inner, mesh=mesh, in_specs=(P(stage_axis), P()),
                     out_specs=P(), check_rep=False)


def stack_stage_params(per_stage_params):
    """[stage0_params, stage1_params, ...] -> stacked pytree (S, ...)."""
    return jax.tree.map(lambda *ls: jnp.stack(ls), *per_stage_params)
