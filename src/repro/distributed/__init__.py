from repro.distributed import collectives, elastic, pipeline_parallel, sharding
