"""Sharding rules: parameter PartitionSpecs, activation constraints, inputs.

Scheme (DP x TP, ZeRO-3 on top - DESIGN.md section 5):
  * batch over ("pod", "data") - DP across pods and the data axis,
  * Megatron TP over "model": column-parallel in-projections
    (wq/wk/wv/w_in/w_gate/in_proj), row-parallel out-projections
    (wo/w_out/out_proj); vocab-sharded embedding + head; MoE experts sharded
    over "model" in E (expert parallelism),
  * FSDP/ZeRO: every remaining unsharded large dim additionally sharded over
    the data axes; XLA all-gathers per layer inside the scan,
  * activations constrained at block boundaries (residual stream),
  * optimizer moments inherit the parameter specs (fp32) or shard their
    quantized block dim (8-bit).

Dims that do not divide the axis stay replicated - the rules degrade, never
fail, so one rule set serves every (arch x shape x mesh) cell.
"""
from __future__ import annotations

import re
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def batch_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axsize(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _fits(dim: int, mesh: Mesh, axes) -> bool:
    s = _axsize(mesh, axes)
    return s > 1 and dim % s == 0


# (path regex, spec builder over trailing dims). Leading stacked-layer dims
# (blocks/...) are handled by the caller. Builders may return None entries.
_COL = ("wq", "wk", "wv", "w_in", "w_gate", "in_proj", "router")
_ROW = ("wo", "w_out", "out_proj")


def _rule_for(path: str, shape, mesh: Mesh):
    """TP spec over the *trailing* dims of a (possibly layer-stacked) leaf."""
    name = path.split("/")[-1]
    nd = len(shape)
    if name == "table":                                   # embedding (V, d)
        return ["model" if _fits(shape[0], mesh, "model") else None, None]
    if name == "head" or path.endswith("head"):           # (d, V)
        return [None, "model" if _fits(shape[1], mesh, "model") else None]
    if name in ("w_in", "w_gate", "w_out") and nd == 3:   # MoE (E, ., .)
        return ["model" if _fits(shape[0], mesh, "model") else None,
                None, None]
    if name in _COL and nd == 2:
        return [None, "model" if _fits(shape[1], mesh, "model") else None]
    if name in _ROW and nd == 2:
        return ["model" if _fits(shape[0], mesh, "model") else None, None]
    if name == "frontend_proj":
        return [None, "model" if _fits(shape[1], mesh, "model") else None]
    return [None] * nd


_STACKED = re.compile(r"^(blocks|enc_blocks|dec_blocks)(/|$)")


def param_spec(path: str, leaf, mesh: Mesh, fsdp: bool = True) -> P:
    shape = tuple(leaf.shape)
    stacked = bool(_STACKED.match(path)) and len(shape) >= 1
    trailing = shape[1:] if stacked else shape
    spec = _rule_for(path, trailing, mesh)
    if fsdp:
        dp = batch_axes(mesh)
        if dp:
            # ZeRO: shard the largest still-replicated trailing dim over DP
            order = sorted(range(len(trailing)),
                           key=lambda i: -trailing[i])
            for i in order:
                if spec[i] is None and _fits(trailing[i], mesh, dp):
                    spec[i] = dp
                    break
    if stacked:
        spec = [None] + spec
    return P(*spec)


def params_specs(params, mesh: Mesh, fsdp: bool = True):
    """Pytree of PartitionSpec matching ``params``."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        pstr = "/".join(_key(p) for p in path)
        specs.append(param_spec(pstr, leaf, mesh, fsdp=fsdp))
    return jax.tree_util.tree_unflatten(treedef, specs)


def _key(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def state_specs(state, mesh: Mesh, fsdp: bool = True):
    """Specs for the full train state: params + AdamW moments + step.

    fp32 moments mirror their parameter's spec; 8-bit moments shard the
    quantized block dim over the data axes when divisible.
    """
    pspecs = params_specs(state["params"], mesh, fsdp=fsdp)

    def moment_spec(m, ps):
        if isinstance(m, tuple) and hasattr(m, "_fields"):   # _Moment(q, scale)
            dp = batch_axes(mesh)
            qdim = m.q.shape[0]
            qs = P(dp if dp and _fits(qdim, mesh, dp) else None, None)
            return type(m)(qs, P(None, None))
        return ps

    mspecs = jax.tree.map(moment_spec, state["opt"]["m"], pspecs,
                          is_leaf=lambda x: isinstance(x, tuple) and hasattr(x, "_fields"))
    vspecs = jax.tree.map(moment_spec, state["opt"]["v"], pspecs,
                          is_leaf=lambda x: isinstance(x, tuple) and hasattr(x, "_fields"))
    return {"params": pspecs,
            "opt": {"step": P(), "m": mspecs, "v": vspecs}}


def to_shardings(specs, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        specs, is_leaf=lambda x: isinstance(x, P))


def make_shard_fn(mesh: Mesh, model_axis_residual: bool = False):
    """Activation-constraint hook for the models' ``shard_fn(x, name)``.

    'residual' (B, S, d): batch over DP axes; optionally d over "model"
    (saves boundary activation memory for the huge-d archs - a hillclimb
    lever measured in EXPERIMENTS.md).
    """
    dp = batch_axes(mesh)

    def shard_fn(x, name):
        if name != "residual" or x.ndim < 2:
            return x
        b = x.shape[0]
        spec_b = dp if dp and b % _axsize(mesh, dp) == 0 else None
        d = x.shape[-1]
        spec_d = ("model" if model_axis_residual
                  and _fits(d, mesh, "model") else None)
        spec = [spec_b] + [None] * (x.ndim - 2) + [spec_d]
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*spec)))

    return shard_fn


def batch_specs(batch_shapes, mesh: Mesh, accum: int = 1):
    """Input specs: tokens (B, S) or (accum, B/accum, S) -> batch over DP."""
    dp = batch_axes(mesh)

    def spec_of(shape):
        nd = len(shape)
        bdim = 1 if accum > 1 else 0
        b = shape[bdim]
        sb = dp if dp and b % _axsize(mesh, dp) == 0 else None
        spec = [None] * nd
        spec[bdim] = sb
        return P(*spec)

    return jax.tree.map(lambda s: spec_of(s.shape), batch_shapes)


def cache_specs(caches, mesh: Mesh, seq_shard: bool = True):
    """KV-cache shardings for decode: batch over DP; the *sequence* dim over
    "model" (flash-decoding / sequence parallelism) when divisible - this is
    what fits a 1.5 TB mistral-large cache on a pod. SSM states shard heads
    over "model" when divisible."""
    dp = batch_axes(mesh)

    # base (unstacked) rank per cache leaf name; a leading layer-stack dim
    # may or may not be present, so offset = nd - base_rank.
    base_rank = {"k": 4, "v": 4, "cross_k": 4, "cross_v": 4,
                 "state": 4, "conv": 3}

    def leaf_spec(path, leaf):
        shape = leaf.shape
        name = _key(path[-1]) if path else ""
        nd = len(shape)
        spec = [None] * nd
        br = base_rank.get(name)
        if br is None or nd < br:
            return P(*spec)
        off = nd - br                                    # 0 or 1 (stacked)
        bdim = off
        if dp and shape[bdim] % _axsize(mesh, dp) == 0:
            spec[bdim] = dp
        if name in ("k", "v", "cross_k", "cross_v"):
            sdim = off + 1                               # (B, S, H, hd)
            if seq_shard and _fits(shape[sdim], mesh, "model"):
                spec[sdim] = "model"
        if name == "state":                              # (B, H, P, N)
            hdim = off + 1
            if _fits(shape[hdim], mesh, "model"):
                spec[hdim] = "model"
        return P(*spec)

    flat, treedef = jax.tree_util.tree_flatten_with_path(caches)
    return jax.tree_util.tree_unflatten(
        treedef, [leaf_spec(p, l) for p, l in flat])
