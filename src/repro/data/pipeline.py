"""Synthetic, deterministic, shardable data pipeline.

Every (step, global position) maps to tokens through a counter-based hash
(SplitMix64), so any host can materialize exactly its shard of the global
batch with no coordination - the property a real multi-pod input pipeline
needs, demonstrated here with ``jax.make_array_from_callback``.

The stream is not uniform noise: tokens follow a periodic Markov-ish pattern
(mixture of a linear-congruential walk and rare resets) so a language model
trained on it has signal to fit - integration tests assert the loss drops.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.frontends import frontend_tokens


def _splitmix64(x: np.ndarray) -> np.ndarray:
    x = (x + np.uint64(0x9E3779B97F4A7C15)).astype(np.uint64)
    z = x
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    global_batch: int
    seq_len: int
    seed: int = 0
    pattern_period: int = 97          # learnable structure scale


class SyntheticDataset:
    """Deterministic token stream: ``tokens(step)[b, t]`` is a pure function
    of (seed, step, b, t)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def tokens_slice(self, step: int, b0: int, b1: int,
                     t0: int = 0, t1: Optional[int] = None) -> np.ndarray:
        """Materialize rows [b0, b1) x cols [t0, t1) of the step's batch."""
        c = self.cfg
        t1 = c.seq_len if t1 is None else t1
        bs = np.arange(b0, b1, dtype=np.uint64)[:, None]
        ts = np.arange(t0, t1, dtype=np.uint64)[None, :]
        base = (np.uint64(c.seed) * np.uint64(0x100000001B3)
                + np.uint64(step) * np.uint64(0x1000193))
        # slowly-varying walk + hash noise: predictable next-token structure
        walk = (bs * np.uint64(31) + ts * np.uint64(7)) % np.uint64(c.pattern_period)
        noise = _splitmix64(base + bs * np.uint64(65537) + ts)
        mix = np.where((noise % np.uint64(13)) == 0, noise >> np.uint64(32), walk)
        return (mix % np.uint64(c.vocab)).astype(np.int32)

    def local_batch(self, step: int) -> np.ndarray:
        return self.tokens_slice(step, 0, self.cfg.global_batch)

    def global_batch(self, step: int, sharding) -> jax.Array:
        """Build the globally-sharded batch array: each device's shard is
        generated independently from the counter hash."""
        c = self.cfg
        shape = (c.global_batch, c.seq_len)

        def cb(index):
            rows, cols = index
            b0 = rows.start or 0
            b1 = rows.stop if rows.stop is not None else c.global_batch
            t0 = cols.start or 0
            t1 = cols.stop if cols.stop is not None else c.seq_len
            return self.tokens_slice(step, b0, b1, t0, t1)

        return jax.make_array_from_callback(shape, sharding, cb)


def make_batch(cfg: ModelConfig, data: DataConfig, step: int,
               sharding=None, accum: int = 1):
    """Assemble the model-facing batch dict (host-local arrays if no
    sharding given). Frontend families get synthetic embeddings."""
    ds = SyntheticDataset(data)
    if sharding is None:
        toks = jnp.asarray(ds.local_batch(step))
    else:
        toks = ds.global_batch(step, sharding)
    batch = {"tokens": toks}
    nf = frontend_tokens(cfg)
    if nf:
        key = jax.random.fold_in(jax.random.PRNGKey(data.seed), step)
        emb = (0.02 * jax.random.normal(
            key, (data.global_batch, nf, cfg.d_model))).astype(jnp.bfloat16)
        batch["frames" if cfg.frontend == "audio" else "patches"] = emb
    if accum > 1:
        b = data.global_batch // accum
        batch = jax.tree.map(
            lambda t: t.reshape(accum, b, *t.shape[1:]), batch)
    return batch
