from repro.data.pipeline import DataConfig, SyntheticDataset, make_batch
