"""Level-1 BLAS in JAX (the paper's section-4.1 workloads).

dtype-generic (the 'd' prefix is kept for LAPACK fidelity). ``ddot`` exposes
the *schedule* knob the paper's analysis is about: tree / sequential /
strided-U reductions produce identical values (up to FP reassociation) with
very different dependence structure; the strided form with U =
``codesign.optimal_accumulators`` is the TPU-codesign schedule.

Level-1 routines are pure jnp (no ``policy`` keyword - there is no
kernel-shaped core to dispatch); the policy mechanism starts at Level 2.
All routines accept float32/float64 (and bfloat16 storage) and are
differential-tested against NumPy oracles in
``tests/test_differential_blas.py`` and ``tests/test_blas.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def ddot(x: jnp.ndarray, y: jnp.ndarray, schedule: str = "tree",
         accumulators: int = 8) -> jnp.ndarray:
    """Inner product x^T y with an explicit reduction schedule.

    Parameters
    ----------
    x, y : (n,) arrays, same shape and dtype (float32/float64/bfloat16).
    schedule : {"tree", "sequential", "strided"}
        * ``"tree"`` - ``jnp.sum`` (XLA's tree reduce).
        * ``"sequential"`` - a single running sum: the fully serial hazard
          chain, one dependent add per element.
        * ``"strided"`` - ``accumulators`` parallel partial sums + a small
          combine tree: the paper's depth-p pipeline realized as software
          ILP (U from :func:`repro.core.codesign.optimal_accumulators`).
    accumulators : int
        U for the strided schedule; ignored otherwise.

    Returns
    -------
    jnp.ndarray
        Scalar of x's dtype. Schedules agree up to FP reassociation.

    Notes
    -----
    Oracle: ``tests/test_differential_blas.py`` (vs ``np.dot`` per
    schedule); schedule-equivalence in ``tests/test_blas.py``.
    """
    prods = x * y
    if schedule == "tree":
        return jnp.sum(prods)
    if schedule == "sequential":
        return lax.scan(lambda c, v: (c + v, None), jnp.zeros((), x.dtype),
                        prods)[0]
    if schedule == "strided":
        u = max(1, int(accumulators))
        n = prods.shape[0]
        pad = (-n) % u
        p = jnp.pad(prods, (0, pad)).reshape(-1, u)
        # each column is one accumulator chain; final tree over U partials
        partials = lax.scan(lambda c, row: (c + row, None),
                            jnp.zeros((u,), x.dtype), p)[0]
        return jnp.sum(partials)
    raise ValueError(schedule)


def daxpy(alpha, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """y <- alpha*x + y.

    Parameters
    ----------
    alpha : scalar; x, y : same-shape float arrays.

    Returns
    -------
    jnp.ndarray with y's shape. Oracle: ``tests/test_differential_blas.py``.
    """
    return alpha * x + y


def dscal(alpha, x: jnp.ndarray) -> jnp.ndarray:
    """x <- alpha*x (any float dtype/shape).

    Oracle: ``tests/test_differential_blas.py``.
    """
    return alpha * x


def dnrm2(x: jnp.ndarray) -> jnp.ndarray:
    """Euclidean norm of a vector, overflow-safe (reference-BLAS style).

    Scales by max|x| before squaring, so ||x|| is finite whenever the
    inputs are - the reference dnrm2 contract. Returns a scalar of x's
    dtype. Oracle: ``tests/test_differential_blas.py`` (vs
    ``np.linalg.norm``, including huge/tiny magnitudes).
    """
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax, 1.0)
    return scale * jnp.sqrt(jnp.sum((x / scale) ** 2))


def dasum(x: jnp.ndarray) -> jnp.ndarray:
    """Sum of absolute values (BLAS dasum). Scalar of x's dtype.

    Oracle: ``tests/test_differential_blas.py``.
    """
    return jnp.sum(jnp.abs(x))


def idamax(x: jnp.ndarray) -> jnp.ndarray:
    """Index of the first max-|x| element (BLAS idamax, 0-based int).

    Oracle: ``tests/test_differential_blas.py`` (vs ``np.argmax(|x|)``).
    """
    return jnp.argmax(jnp.abs(x))


def drot(x, y, c, s):
    """Apply a Givens rotation to a vector pair.

    Parameters
    ----------
    x, y : same-shape float arrays; c, s : rotation cosine/sine scalars.

    Returns
    -------
    (x', y') = (c*x + s*y, c*y - s*x).
    Oracle: ``tests/test_differential_blas.py``.
    """
    return c * x + s * y, c * y - s * x
