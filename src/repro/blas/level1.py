"""Level-1 BLAS in JAX (the paper's section-4.1 workloads).

dtype-generic (the 'd' prefix is kept for LAPACK fidelity). ``ddot`` exposes
the *schedule* knob the paper's analysis is about: tree / sequential /
strided-U reductions produce identical values (up to FP reassociation) with
very different dependence structure; the strided form with U =
``codesign.optimal_accumulators`` is the TPU-codesign schedule.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def ddot(x: jnp.ndarray, y: jnp.ndarray, schedule: str = "tree",
         accumulators: int = 8) -> jnp.ndarray:
    """Inner product with an explicit reduction schedule.

    * 'tree'       - jnp.sum (XLA's tree reduce)
    * 'sequential' - a single running sum (the fully serial hazard chain)
    * 'strided'    - U parallel partial sums + small combine (the paper's
                     depth-p pipeline realized as software ILP)
    """
    prods = x * y
    if schedule == "tree":
        return jnp.sum(prods)
    if schedule == "sequential":
        return lax.scan(lambda c, v: (c + v, None), jnp.zeros((), x.dtype),
                        prods)[0]
    if schedule == "strided":
        u = max(1, int(accumulators))
        n = prods.shape[0]
        pad = (-n) % u
        p = jnp.pad(prods, (0, pad)).reshape(-1, u)
        # each column is one accumulator chain; final tree over U partials
        partials = lax.scan(lambda c, row: (c + row, None),
                            jnp.zeros((u,), x.dtype), p)[0]
        return jnp.sum(partials)
    raise ValueError(schedule)


def daxpy(alpha, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """y <- alpha*x + y."""
    return alpha * x + y


def dscal(alpha, x: jnp.ndarray) -> jnp.ndarray:
    return alpha * x


def dnrm2(x: jnp.ndarray) -> jnp.ndarray:
    """Euclidean norm with overflow-safe scaling (reference-BLAS style)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax, 1.0)
    return scale * jnp.sqrt(jnp.sum((x / scale) ** 2))


def dasum(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum(jnp.abs(x))


def idamax(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.argmax(jnp.abs(x))


def drot(x, y, c, s):
    """Givens rotation applied to a vector pair."""
    return c * x + s * y, c * y - s * x
