"""Level-1 BLAS in JAX (the paper's section-4.1 workloads).

dtype-generic cores under their un-prefixed names (``dot``, ``axpy``, ...);
``ddot``/``daxpy``/... survive as deprecation shims that forward through
:mod:`repro.linalg`. ``dot`` exposes the *schedule* knob the paper's
analysis is about: tree / sequential / strided-U reductions produce
identical values (up to FP reassociation) with very different dependence
structure; the strided form with U = ``codesign.optimal_accumulators`` is
the TPU-codesign schedule.

Level-1 routines are pure jnp (no policy - there is no kernel-shaped core
to dispatch); the policy mechanism starts at Level 2. All routines accept
float32/float64 (and bfloat16 storage) and are differential-tested against
NumPy oracles in ``tests/test_differential_blas.py`` and
``tests/test_blas.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.blas._deprecated import warn_once


def dot(x: jnp.ndarray, y: jnp.ndarray, schedule: str = "tree",
        accumulators: int = 8) -> jnp.ndarray:
    """Inner product x^T y with an explicit reduction schedule.

    Parameters
    ----------
    x, y : (n,) arrays, same shape and dtype (float32/float64/bfloat16).
    schedule : {"tree", "sequential", "strided"}
        * ``"tree"`` - ``jnp.sum`` (XLA's tree reduce).
        * ``"sequential"`` - a single running sum: the fully serial hazard
          chain, one dependent add per element.
        * ``"strided"`` - ``accumulators`` parallel partial sums + a small
          combine tree: the paper's depth-p pipeline realized as software
          ILP (U from :func:`repro.core.codesign.optimal_accumulators`).
    accumulators : int
        U for the strided schedule; ignored otherwise.

    Returns
    -------
    jnp.ndarray
        Scalar of x's dtype. Schedules agree up to FP reassociation.

    Notes
    -----
    Oracle: ``tests/test_differential_blas.py`` (vs ``np.dot`` per
    schedule); schedule-equivalence in ``tests/test_blas.py``.
    """
    prods = x * y
    if schedule == "tree":
        return jnp.sum(prods)
    if schedule == "sequential":
        return lax.scan(lambda c, v: (c + v, None), jnp.zeros((), x.dtype),
                        prods)[0]
    if schedule == "strided":
        u = max(1, int(accumulators))
        n = prods.shape[0]
        pad = (-n) % u
        p = jnp.pad(prods, (0, pad)).reshape(-1, u)
        # each column is one accumulator chain; final tree over U partials
        partials = lax.scan(lambda c, row: (c + row, None),
                            jnp.zeros((u,), x.dtype), p)[0]
        return jnp.sum(partials)
    raise ValueError(schedule)


def axpy(alpha, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """y <- alpha*x + y.

    Parameters
    ----------
    alpha : scalar; x, y : same-shape float arrays.

    Returns
    -------
    jnp.ndarray with y's shape. Oracle: ``tests/test_differential_blas.py``.
    """
    return alpha * x + y


def scal(alpha, x: jnp.ndarray) -> jnp.ndarray:
    """x <- alpha*x (any float dtype/shape).

    Oracle: ``tests/test_differential_blas.py``.
    """
    return alpha * x


def nrm2(x: jnp.ndarray) -> jnp.ndarray:
    """Euclidean norm of a vector, overflow-safe (reference-BLAS style).

    Scales by max|x| before squaring, so ||x|| is finite whenever the
    inputs are - the reference dnrm2 contract. Returns a scalar of x's
    dtype. Oracle: ``tests/test_differential_blas.py`` (vs
    ``np.linalg.norm``, including huge/tiny magnitudes).
    """
    amax = jnp.max(jnp.abs(x))
    scale_ = jnp.where(amax > 0, amax, 1.0)
    return scale_ * jnp.sqrt(jnp.sum((x / scale_) ** 2))


def asum(x: jnp.ndarray) -> jnp.ndarray:
    """Sum of absolute values (BLAS asum). Scalar of x's dtype.

    Oracle: ``tests/test_differential_blas.py``.
    """
    return jnp.sum(jnp.abs(x))


def iamax(x: jnp.ndarray) -> jnp.ndarray:
    """Index of the first max-|x| element (BLAS iamax, 0-based int).

    Oracle: ``tests/test_differential_blas.py`` (vs ``np.argmax(|x|)``).
    """
    return jnp.argmax(jnp.abs(x))


def rot(x, y, c, s):
    """Apply a Givens rotation to a vector pair.

    Parameters
    ----------
    x, y : same-shape float arrays; c, s : rotation cosine/sine scalars.

    Returns
    -------
    (x', y') = (c*x + s*y, c*y - s*x).
    Oracle: ``tests/test_differential_blas.py``.
    """
    return c * x + s * y, c * y - s * x


# -------------------------- deprecated d-prefixed shims ----------------------
# Thin forwards through repro.linalg under a *pinned* compat context
# (mesh=None, accum_dtype=None), so an active context can never change a
# deprecated call's numerics. One DeprecationWarning per routine. Oracle +
# warning behavior: tests/test_linalg_deprecation.py.

def _compat():
    from repro.linalg.context import compat_context
    return compat_context()


def ddot(x, y, schedule: str = "tree", accumulators: int = 8):
    """Deprecated alias of :func:`repro.linalg.dot`."""
    warn_once("ddot", "dot")
    from repro import linalg
    return linalg.dot(x, y, schedule=schedule, accumulators=accumulators,
                      context=_compat())


def daxpy(alpha, x, y):
    """Deprecated alias of :func:`repro.linalg.axpy`."""
    warn_once("daxpy", "axpy")
    from repro import linalg
    return linalg.axpy(alpha, x, y, context=_compat())


def dscal(alpha, x):
    """Deprecated alias of :func:`repro.linalg.scal`."""
    warn_once("dscal", "scal")
    from repro import linalg
    return linalg.scal(alpha, x, context=_compat())


def dnrm2(x):
    """Deprecated alias of :func:`repro.linalg.nrm2`."""
    warn_once("dnrm2", "nrm2")
    from repro import linalg
    return linalg.nrm2(x, context=_compat())


def dasum(x):
    """Deprecated alias of :func:`repro.linalg.asum`."""
    warn_once("dasum", "asum")
    from repro import linalg
    return linalg.asum(x, context=_compat())


def idamax(x):
    """Deprecated alias of :func:`repro.linalg.iamax`."""
    warn_once("idamax", "iamax")
    from repro import linalg
    return linalg.iamax(x, context=_compat())


def drot(x, y, c, s):
    """Deprecated alias of :func:`repro.linalg.rot`."""
    warn_once("drot", "rot")
    from repro import linalg
    return linalg.rot(x, y, c, s, context=_compat())
