"""Level-3 BLAS in JAX, policy-dispatched onto the Pallas GEMM hot spot.

``gemm`` is the routine the whole paper orbits (every LAPACK trailing
update lowers to it). Every kernel-shaped core here resolves through
:mod:`repro.tune.dispatch`: ``policy="reference"`` is plain jnp,
``"model"`` the Pallas MXU kernel at the :func:`repro.core.codesign`
tiling, ``"tuned"`` the measured registry config (cold start == model).
``syrk`` and ``trsm`` thread the same policy through their internal
GEMMs, so a blocked factorization dispatches *every* trailing flop onto
the one hot path.

These are the numeric cores; the public, context-scoped front-end is
:mod:`repro.linalg`. The old d-prefixed names (``dgemm``/``dsyrk``/
``dtrsm``) are deprecation shims forwarding there, and
``use_kernel=True/False`` remains a deprecated alias for
``policy="model"`` / ``"reference"``.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
from jax import lax

from repro.blas._deprecated import warn_once


def gemm(a: jnp.ndarray, b: jnp.ndarray, c: Optional[jnp.ndarray] = None,
         alpha=1.0, beta=0.0, transa: bool = False, transb: bool = False,
         policy: Optional[str] = None, use_kernel: Optional[bool] = None,
         interpret: bool = True, registry=None) -> jnp.ndarray:
    """C <- alpha * op(A) op(B) + beta * C (BLAS GEMM core).

    Parameters
    ----------
    a, b : matrices with op(A) (m, k) and op(B) (k, n); ``transa`` /
        ``transb`` are the BLAS transpose flags. Any float dtype
        (float32/float64; bfloat16 storage, fp32 accumulation in the
        kernel - float64 operands accumulate in float64).
    c : (m, n) accumuland for the ``beta`` epilogue, optional.
    policy : {"reference", "model", "tuned"}, optional
        ``reference`` = plain jnp (the oracle path); ``model`` = Pallas
        MXU kernel at the :func:`repro.core.codesign.plan_gemm` tiling;
        ``tuned`` = the registry's measured config, cold-starting to
        ``model``. ``use_kernel`` is the deprecated alias
        (True == "model", False == "reference").
    interpret : run Pallas in interpret mode (required on CPU).

    Returns
    -------
    jnp.ndarray, shape (m, n).

    Notes
    -----
    This is the hot path the whole stack funnels into - every LAPACK
    trailing update and the distributed SUMMA panels execute here.
    Public front-end: :func:`repro.linalg.gemm` (context-scoped, mesh
    routing). Oracle: ``tests/test_differential_blas.py`` (shape x dtype
    x transpose grid vs NumPy); per-policy agreement in
    ``tests/test_tune.py``.
    """
    from repro.tune import dispatch as _tune
    op_a = a.T if transa else a
    op_b = b.T if transb else b
    ab = _tune.dispatch("gemm", op_a, op_b, policy=policy,
                        use_kernel=use_kernel, interpret=interpret,
                        registry=registry)
    out = alpha * ab
    if c is not None:
        out = out + beta * c
    return out


def gemm_bias_act(a: jnp.ndarray, b: jnp.ndarray,
                  bias: Optional[jnp.ndarray] = None, epilogue: str = "none",
                  policy: Optional[str] = None,
                  use_kernel: Optional[bool] = None, interpret: bool = True,
                  registry=None) -> jnp.ndarray:
    """C = act(A @ B + bias) - the fused-epilogue GEMM core.

    Parameters
    ----------
    a, b : (m, k) and (k, n) matrices (any supported float dtype).
    bias : length-n vector broadcast over rows, optional.
    epilogue : one of :data:`repro.kernels.fused.EPILOGUES`
        (``"none"`` / ``"relu"`` / ``"gelu"``).
    policy : {"reference", "model", "tuned"}, optional
        ``reference`` applies the epilogue to plain ``a @ b``; the kernel
        policies resolve the ``"gemm+epilogue"`` chain through
        :func:`repro.tune.dispatch.resolve`, which streams the epilogue
        inside the Pallas GEMM when
        :func:`repro.core.codesign.plan_fused_chain` says fusing wins
        (else the staged kernel + epilogue pass).

    Notes
    -----
    Public front-end: :func:`repro.linalg.gemm_bias_act`. Differential
    oracle: ``tests/test_fusion.py``.
    """
    from repro.tune import dispatch as _tune
    return _tune.dispatch("gemm+epilogue", a, b, bias=bias,
                          epilogue=epilogue, policy=policy,
                          use_kernel=use_kernel, interpret=interpret,
                          registry=registry)


def syrk(a: jnp.ndarray, c: Optional[jnp.ndarray] = None, alpha=1.0,
         beta=0.0, lower: bool = True, trans: bool = False,
         policy: Optional[str] = None, use_kernel: Optional[bool] = None,
         interpret: bool = True, registry=None) -> jnp.ndarray:
    """C <- alpha op(A) op(A)^T + beta C (BLAS SYRK core), symmetric output.

    Parameters
    ----------
    a : (n, k) matrix ((k, n) when ``trans``); any float dtype.
    trans : BLAS TRANS flag - False computes A A^T, True A^T A.
    lower : which triangle of C is authoritative; the other is mirrored.
    c : (n, n) accumuland, optional.
    policy : {"reference", "model", "tuned"}, optional
        The product runs through the same ``gemm`` kernel path (SYRK
        shares the gemm registry entries), so SYRK reaches Pallas under
        the kernel policies; ``use_kernel`` deprecated alias as in
        :func:`gemm`.

    Returns
    -------
    (n, n) symmetric matrix.

    Notes
    -----
    Public front-end: :func:`repro.linalg.syrk`. Oracle:
    ``tests/test_differential_blas.py``; per-policy agreement in
    ``tests/test_tune.py``.
    """
    from repro.tune import dispatch as _tune
    full = alpha * _tune.dispatch("syrk", a, trans=trans, policy=policy,
                                  use_kernel=use_kernel, interpret=interpret,
                                  registry=registry)
    if c is not None:
        full = full + beta * c
    return mirror_triangle(full, lower)


def mirror_triangle(full: jnp.ndarray, lower: bool) -> jnp.ndarray:
    """SYRK epilogue: keep the authoritative triangle of ``full`` and
    mirror it across the diagonal (shared by the local and SUMMA paths)."""
    n = full.shape[0]
    i, j = jnp.mgrid[0:n, 0:n]
    mask = (i >= j) if lower else (i <= j)
    return jnp.where(mask, full, full.T)


def trsm(a: jnp.ndarray, b: jnp.ndarray, lower: bool = True,
         unit_diag: bool = False, left: bool = True,
         block: Optional[int] = None, policy: Optional[str] = None,
         use_kernel: Optional[bool] = None, interpret: bool = True,
         registry=None) -> jnp.ndarray:
    """Solve op(T) X = B (left=True) or X op(T) = B, T triangular, blocked.

    Diagonal blocks use the sequential substitution scan (the serial
    divider chain); off-diagonal updates are GEMMs - the paper's
    panel/trailing structure in miniature - and follow the policy onto the
    Pallas path.

    Parameters
    ----------
    a : (n, n) triangular matrix; b : (n, k) or (n,) RHS ((m, n) layouts
        transposed internally when ``left=False``). Any float dtype.
    lower, unit_diag : LAPACK UPLO/DIAG flags.
    left : solve op(T) X = B (True) or X op(T) = B (False).
    block : diagonal-block width; ``None`` resolves it through
        :func:`repro.tune.dispatch.resolve` (64 under ``reference`` - the
        historical default - else the ``plan_trsm`` model or the
        registry's measured width).
    policy : {"reference", "model", "tuned"}, optional
        Applies to the off-diagonal GEMM updates (the substitution scan
        itself has no kernel form); ``use_kernel`` deprecated alias.

    Returns
    -------
    X with b's shape.

    Notes
    -----
    Public front-end: :func:`repro.linalg.trsm` (context-scoped, pdtrsm
    under a mesh). Oracle: ``tests/test_differential_blas.py`` (vs
    ``scipy.linalg.solve_triangular`` over lower/upper x unit/non-unit);
    per-policy agreement in ``tests/test_tune.py``.
    """
    if not left:
        # X T = B  <=>  T^T X^T = B^T
        return trsm(a.T, b.T, lower=not lower, unit_diag=unit_diag,
                    left=True, block=block, policy=policy,
                    use_kernel=use_kernel, interpret=interpret,
                    registry=registry).T
    n = a.shape[0]
    if block is None:
        from repro.tune import dispatch as _tune
        nrhs = b.shape[1] if b.ndim == 2 else 1
        res = _tune.resolve("trsm", (n, nrhs), a.dtype, policy=policy,
                            use_kernel=use_kernel, registry=registry)
        pol, block = res.policy, res.block
    else:
        from repro.tune.policy import resolve_policy
        pol = resolve_policy(policy, use_kernel)
    if n <= block:
        return _trsm_unblocked(a, b, lower=lower, unit_diag=unit_diag)
    blocks = list(range(0, n, block))
    x = jnp.zeros_like(b)
    order = blocks if lower else blocks[::-1]
    for i0 in order:
        i1 = min(i0 + block, n)
        rhs = b[i0:i1]
        if lower and i0 > 0:
            rhs = rhs - gemm(a[i0:i1, :i0], x[:i0], policy=pol,
                             interpret=interpret, registry=registry)
        elif not lower and i1 < n:
            rhs = rhs - gemm(a[i0:i1, i1:], x[i1:], policy=pol,
                             interpret=interpret, registry=registry)
        xi = _trsm_unblocked(a[i0:i1, i0:i1], rhs, lower=lower,
                             unit_diag=unit_diag)
        x = x.at[i0:i1].set(xi)
    return x


def _trsm_unblocked(a: jnp.ndarray, b: jnp.ndarray, lower: bool,
                    unit_diag: bool) -> jnp.ndarray:
    n = a.shape[0]
    order = jnp.arange(n) if lower else jnp.arange(n - 1, -1, -1)
    diag = jnp.diagonal(a)
    strict = a - jnp.diag(diag)

    def body(x, i):
        s = b[i] - strict[i] @ x
        xi = s if unit_diag else s / diag[i]
        return x.at[i].set(xi), None

    x, _ = lax.scan(body, jnp.zeros_like(b), order)
    return x


# -------------------------- deprecated d-prefixed shims ----------------------

def dgemm(a, b, c=None, alpha=1.0, beta=0.0, transa: bool = False,
          transb: bool = False, policy: Optional[str] = None,
          use_kernel: Optional[bool] = None, interpret: bool = True,
          registry=None, use_pallas: Optional[bool] = None):
    """Deprecated alias of :func:`repro.linalg.gemm` (old kwargs mapped to
    a local, per-call context). Warning + bitwise-identity oracle:
    ``tests/test_linalg_deprecation.py``."""
    warn_once("dgemm", "gemm")
    from repro import linalg
    from repro.linalg.context import compat_context
    return linalg.gemm(a, b, c=c, alpha=alpha, beta=beta, transa=transa,
                       transb=transb,
                       context=compat_context(policy, use_kernel, interpret,
                                              registry, use_pallas))


def dsyrk(a, c=None, alpha=1.0, beta=0.0, lower: bool = True,
          trans: bool = False, policy: Optional[str] = None,
          use_kernel: Optional[bool] = None, interpret: bool = True,
          registry=None, use_pallas: Optional[bool] = None):
    """Deprecated alias of :func:`repro.linalg.syrk`."""
    warn_once("dsyrk", "syrk")
    from repro import linalg
    from repro.linalg.context import compat_context
    return linalg.syrk(a, c=c, alpha=alpha, beta=beta, lower=lower,
                       trans=trans,
                       context=compat_context(policy, use_kernel, interpret,
                                              registry, use_pallas))


def dtrsm(a, b, lower: bool = True, unit_diag: bool = False,
          left: bool = True, block: Optional[int] = None,
          policy: Optional[str] = None, use_kernel: Optional[bool] = None,
          interpret: bool = True, registry=None,
          use_pallas: Optional[bool] = None):
    """Deprecated alias of :func:`repro.linalg.trsm`."""
    warn_once("dtrsm", "trsm")
    from repro import linalg
    from repro.linalg.context import compat_context
    return linalg.trsm(a, b, lower=lower, unit_diag=unit_diag, left=left,
                       block=block,
                       context=compat_context(policy, use_kernel, interpret,
                                              registry, use_pallas))
