"""Level-3 BLAS in JAX, with Pallas-kernel dispatch for the GEMM hot spot.

``dgemm`` is the routine the whole paper orbits (every LAPACK trailing update
lowers to it); ``use_kernel=True`` routes through the Pallas MXU kernel whose
tiling comes from :func:`repro.core.codesign.plan_gemm`. ``dsyrk`` and
``dtrsm`` thread the same flag through to their internal GEMMs, so a blocked
factorization dispatches *every* trailing flop onto the one hot path.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
from jax import lax


def dgemm(a: jnp.ndarray, b: jnp.ndarray, c: Optional[jnp.ndarray] = None,
          alpha=1.0, beta=0.0, use_kernel: bool = False,
          interpret: bool = True) -> jnp.ndarray:
    """C <- alpha * A B + beta * C."""
    if use_kernel:
        from repro.kernels import ops  # local import: kernels are optional
        ab = ops.gemm(a, b, use_pallas=True, interpret=interpret)
    else:
        ab = a @ b
    out = alpha * ab
    if c is not None:
        out = out + beta * c
    return out


def dsyrk(a: jnp.ndarray, c: Optional[jnp.ndarray] = None, alpha=1.0,
          beta=0.0, lower: bool = True, use_kernel: bool = False,
          interpret: bool = True) -> jnp.ndarray:
    """C <- alpha A A^T + beta C, triangular part referenced."""
    full = alpha * dgemm(a, a.T, use_kernel=use_kernel, interpret=interpret)
    if c is not None:
        full = full + beta * c
    n = full.shape[0]
    i, j = jnp.mgrid[0:n, 0:n]
    mask = (i >= j) if lower else (i <= j)
    return jnp.where(mask, full, full.T)


def dtrsm(a: jnp.ndarray, b: jnp.ndarray, lower: bool = True,
          unit_diag: bool = False, left: bool = True,
          block: int = 64, use_kernel: bool = False,
          interpret: bool = True) -> jnp.ndarray:
    """Solve op(T) X = B (left=True) or X op(T) = B, T triangular, blocked.

    Diagonal blocks use the sequential substitution scan (the serial divider
    chain); off-diagonal updates are GEMMs - the paper's panel/trailing
    structure in miniature - and follow ``use_kernel`` onto the Pallas path.
    """
    if not left:
        # X T = B  <=>  T^T X^T = B^T
        return dtrsm(a.T, b.T, lower=not lower, unit_diag=unit_diag,
                     left=True, block=block, use_kernel=use_kernel,
                     interpret=interpret).T
    n = a.shape[0]
    if n <= block:
        return _trsm_unblocked(a, b, lower=lower, unit_diag=unit_diag)
    blocks = list(range(0, n, block))
    x = jnp.zeros_like(b)
    order = blocks if lower else blocks[::-1]
    for i0 in order:
        i1 = min(i0 + block, n)
        rhs = b[i0:i1]
        if lower and i0 > 0:
            rhs = rhs - dgemm(a[i0:i1, :i0], x[:i0], use_kernel=use_kernel,
                              interpret=interpret)
        elif not lower and i1 < n:
            rhs = rhs - dgemm(a[i0:i1, i1:], x[i1:], use_kernel=use_kernel,
                              interpret=interpret)
        xi = _trsm_unblocked(a[i0:i1, i0:i1], rhs, lower=lower,
                             unit_diag=unit_diag)
        x = x.at[i0:i1].set(xi)
    return x


def _trsm_unblocked(a: jnp.ndarray, b: jnp.ndarray, lower: bool,
                    unit_diag: bool) -> jnp.ndarray:
    n = a.shape[0]
    order = jnp.arange(n) if lower else jnp.arange(n - 1, -1, -1)
    diag = jnp.diagonal(a)
    strict = a - jnp.diag(diag)

    def body(x, i):
        s = b[i] - strict[i] @ x
        xi = s if unit_diag else s / diag[i]
        return x.at[i].set(xi), None

    x, _ = lax.scan(body, jnp.zeros_like(b), order)
    return x
