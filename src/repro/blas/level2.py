"""Level-2 BLAS in JAX.

Cores under un-prefixed names; ``dgemv``/``dger``/``dtrsv`` are
deprecation shims forwarding through :mod:`repro.linalg`. ``gemv`` shares
the BLAS-3 policy mechanism: its matvec core resolves through
:mod:`repro.tune.dispatch` (``reference`` = plain jnp; ``model`` /
``tuned`` route op(A) x through the Pallas GEMM kernel as an (m, n) x
(n, 1) product), so Level-2 configs live in the same registry.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
from jax import lax

from repro.blas._deprecated import warn_once


def gemv(a: jnp.ndarray, x: jnp.ndarray, beta=0.0, y=None,
         alpha=1.0, trans: bool = False, policy: Optional[str] = None,
         use_kernel: Optional[bool] = None, interpret: bool = True,
         registry=None) -> jnp.ndarray:
    """y <- alpha*op(A) x + beta*y (BLAS GEMV core).

    Parameters
    ----------
    a : (m, n) matrix; x : (n,) vector ((m,) when ``trans``). Any float
        dtype (float32/float64; bfloat16 storage).
    trans : bool
        op(A) = A^T when True (BLAS TRANS flag).
    y : (m,) accumuland for the ``beta`` epilogue, optional.
    policy : {"reference", "model", "tuned"}, optional
        ``reference`` is plain jnp; ``model``/``tuned`` run op(A) x
        through the Pallas GEMM kernel as an (m, n) x (n, 1) product, so
        Level-2 configs share the gemm registry entries. ``use_kernel``
        is the deprecated boolean alias (True == "model").

    Returns
    -------
    jnp.ndarray, shape (m,) ((n,) when ``trans``).

    Notes
    -----
    Public front-end: :func:`repro.linalg.gemv` (context-scoped). Oracle:
    ``tests/test_differential_blas.py`` (vs NumPy matvec over a
    shape x dtype x trans grid); per-policy agreement in
    ``tests/test_tune.py``.
    """
    from repro.tune import dispatch as _tune
    ax = _tune.dispatch("gemv", a, x, trans=trans, policy=policy,
                        use_kernel=use_kernel, interpret=interpret,
                        registry=registry)
    out = alpha * ax
    if y is not None:
        out = out + beta * y
    return out


def ger(alpha, x: jnp.ndarray, y: jnp.ndarray, a: jnp.ndarray) -> jnp.ndarray:
    """A <- alpha * x y^T + A (BLAS GER rank-1 update).

    Parameters
    ----------
    x : (m,); y : (n,); a : (m, n), all the same float dtype.

    Returns
    -------
    (m, n) updated matrix. Pure jnp (no policy - the update is a single
    fused outer product). Oracle: ``tests/test_differential_blas.py``.
    """
    return a + alpha * jnp.outer(x, y)


def trsv(a: jnp.ndarray, b: jnp.ndarray, lower: bool = True,
         unit_diag: bool = False) -> jnp.ndarray:
    """Solve op(T) x = b for triangular T via a row-sequential scan.

    The sequential dependence (x_i needs all earlier x_j) is the paper's
    divider-pipe hazard chain: one divide per row, each waiting on the
    previous row's substitution.

    Parameters
    ----------
    a : (n, n) triangular matrix (only the referenced triangle is read);
        b : (n,) or (n, k) RHS. Any float dtype.
    lower : solve the lower (True) or upper (False) triangle.
    unit_diag : assume unit diagonal (LAPACK DIAG="U"); diagonal entries
        are never read when True.

    Returns
    -------
    x with b's shape. Pure jnp scan - no policy; the blocked,
    policy-dispatched form is :func:`repro.blas.level3.trsm`.

    Notes
    -----
    Oracle: ``tests/test_differential_blas.py`` (vs
    ``scipy.linalg.solve_triangular``).
    """
    n = a.shape[0]
    order = jnp.arange(n) if lower else jnp.arange(n - 1, -1, -1)
    diag = jnp.diagonal(a)
    strict = a - jnp.diag(diag)

    def body(x, i):
        s = b[i] - strict[i] @ x
        xi = s if unit_diag else s / diag[i]
        return x.at[i].set(xi), None

    x0 = jnp.zeros_like(b)
    x, _ = lax.scan(body, x0, order)
    return x


# -------------------------- deprecated d-prefixed shims ----------------------

def dgemv(a, x, beta=0.0, y=None, alpha=1.0, trans: bool = False,
          policy: Optional[str] = None, use_kernel: Optional[bool] = None,
          interpret: bool = True, registry=None,
          use_pallas: Optional[bool] = None):
    """Deprecated alias of :func:`repro.linalg.gemv` (old kwargs mapped to
    a per-call context). Warning + bitwise-identity oracle:
    ``tests/test_linalg_deprecation.py``."""
    warn_once("dgemv", "gemv")
    from repro import linalg
    from repro.linalg.context import compat_context
    return linalg.gemv(a, x, y=y, alpha=alpha, beta=beta, trans=trans,
                       context=compat_context(policy, use_kernel, interpret,
                                              registry, use_pallas))


def dger(alpha, x, y, a):
    """Deprecated alias of :func:`repro.linalg.ger`."""
    warn_once("dger", "ger")
    from repro import linalg
    from repro.linalg.context import compat_context
    return linalg.ger(alpha, x, y, a, context=compat_context())


def dtrsv(a, b, lower: bool = True, unit_diag: bool = False):
    """Deprecated alias of :func:`repro.linalg.trsv`."""
    warn_once("dtrsv", "trsv")
    from repro import linalg
    from repro.linalg.context import compat_context
    return linalg.trsv(a, b, lower=lower, unit_diag=unit_diag,
                       context=compat_context())
