"""Level-2 BLAS in JAX."""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def dgemv(a: jnp.ndarray, x: jnp.ndarray, beta=0.0, y=None,
          alpha=1.0, trans: bool = False) -> jnp.ndarray:
    """y <- alpha*op(A) x + beta*y."""
    ax = (a.T if trans else a) @ x
    out = alpha * ax
    if y is not None:
        out = out + beta * y
    return out


def dger(alpha, x: jnp.ndarray, y: jnp.ndarray, a: jnp.ndarray) -> jnp.ndarray:
    """A <- alpha * x y^T + A (rank-1 update)."""
    return a + alpha * jnp.outer(x, y)


def dtrsv(a: jnp.ndarray, b: jnp.ndarray, lower: bool = True,
          unit_diag: bool = False) -> jnp.ndarray:
    """Solve op(T) x = b for triangular T via a row-sequential scan.

    The sequential dependence (x_i needs all earlier x_j) is the paper's
    divider-pipe hazard chain: one divide per row, each waiting on the
    previous row's substitution.
    """
    n = a.shape[0]
    order = jnp.arange(n) if lower else jnp.arange(n - 1, -1, -1)
    diag = jnp.diagonal(a)
    strict = a - jnp.diag(diag)

    def body(x, i):
        s = b[i] - strict[i] @ x
        xi = s if unit_diag else s / diag[i]
        return x.at[i].set(xi), None

    x0 = jnp.zeros_like(b)
    x, _ = lax.scan(body, x0, order)
    return x
