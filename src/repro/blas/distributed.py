"""Sharded multi-device BLAS: SUMMA ``pdgemm`` and ``pdtrsm`` via shard_map.

The paper's thesis - match the DAG's parallel operations to the platform's
compute/memory structure - applied across the device boundary. A 2D
``("x", "y")`` mesh turns the GEMM K reduction into the same picture as the
paper's adder pipeline: ``px * py`` parallel accumulators (one partial C
per device) fed by a serial panel stream, where the "latch overhead" is now
an inter-chip hop instead of a pipeline register.

Layout (SUMMA):

* A ``(m, k)`` is sharded ``P("x", "y")`` - rows over ``x``, the K
  dimension over ``y`` (each device column owns one coarse k-panel of A);
* B ``(k, n)`` is sharded ``P("x", "y")`` - the K dimension over ``x``,
  columns over ``y``;
* C ``(m, n)`` comes out ``P("x", "y")``, no reduction needed.

Each of the ``px * py`` steps broadcasts one fine k-panel of A along the
``y`` ring and the matching panel of B along the ``x`` ring
(:func:`repro.distributed.collectives.ring_bcast` -
``lax.ppermute``-pipelined, one panel per hop), then runs the local
``(m/px, k_f) @ (k_f, n/py)`` update through the *existing* policy
dispatcher - ``reference`` is plain jnp, ``model``/``tuned`` the Pallas MXU
kernel at the config :func:`repro.tune.dispatch.resolve` picks for op
``"pdgemm"`` (registry key carries the mesh component).
:func:`repro.core.codesign.plan_pdgemm` prices the whole schedule.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.distributed.collectives import (CollectiveRecord, emit_record,
                                           ring_bcast)

MESH_AXES = ("x", "y")


def make_blas_mesh(px: int, py: int) -> Mesh:
    """A (px, py) ``("x", "y")`` mesh over the first ``px * py`` devices."""
    import numpy as np
    devs = np.asarray(jax.devices()[: px * py]).reshape(px, py)
    return Mesh(devs, MESH_AXES)


def mesh_key(mesh: Mesh) -> str:
    """Registry mesh component for a BLAS mesh (e.g. ``"x2y4"``)."""
    return "".join(f"{a}{mesh.shape[a]}" for a in mesh.axis_names)


def _mesh_xy(mesh: Mesh):
    if tuple(mesh.axis_names) != MESH_AXES:
        raise ValueError(
            f"distributed BLAS needs a ('x', 'y') mesh; got axes "
            f"{tuple(mesh.axis_names)}")
    return mesh.shape["x"], mesh.shape["y"]


def _pad2(a: jnp.ndarray, r0: int, r1: int) -> jnp.ndarray:
    """Zero-pad a 2-D array so dims are multiples of (r0, r1)."""
    p0 = (-a.shape[0]) % r0
    p1 = (-a.shape[1]) % r1
    if p0 == 0 and p1 == 0:
        return a
    return jnp.pad(a, ((0, p0), (0, p1)))


def _local_update(ap, bp, res, interpret: bool):
    """One SUMMA panel update on the resolved path (jnp or Pallas) - the
    exact executor every other policy-dispatched GEMM uses."""
    from repro.tune.dispatch import _gemm_exec      # lazy: avoid cycle
    return _gemm_exec(ap, bp, res, interpret)


def pdgemm(a: jnp.ndarray, b: jnp.ndarray, mesh: Mesh,
           c: Optional[jnp.ndarray] = None, alpha=1.0, beta=0.0,
           policy: Optional[str] = None, use_kernel: Optional[bool] = None,
           interpret: bool = True, registry=None) -> jnp.ndarray:
    """C <- alpha * A B + beta * C, SUMMA-sharded over a ("x", "y") mesh.

    Parameters
    ----------
    a, b : jnp.ndarray
        Global operands, shapes ``(m, k)`` and ``(k, n)``. Any float dtype
        the single-device :func:`repro.blas.level3.gemm` accepts
        (float32/float64; bfloat16 storage). Internally zero-padded so m,
        n, k divide the mesh tiling; the pad never leaks into the output.
    mesh : jax.sharding.Mesh
        A ``("x", "y")`` mesh (see :func:`make_blas_mesh`). ``(1, 1)``
        degenerates to the single-device kernel path with zero hops.
    c : jnp.ndarray, optional
        ``(m, n)`` accumuland for the ``beta`` epilogue (applied on the
        host layout, outside shard_map, like every repro.blas epilogue).
    policy : {"reference", "model", "tuned"}, optional
        Per-step local updates run plain jnp (``reference``) or the Pallas
        MXU kernel at the config ``resolve("pdgemm", (m, n, k), ...,
        mesh=(px, py))`` picks - ``tuned`` reads the mesh-keyed registry
        entry and cold-starts to ``model``. ``use_kernel`` stays the
        deprecated boolean alias.

    Returns
    -------
    jnp.ndarray
        The global ``(m, n)`` product (sharded ``P("x", "y")`` on exit).

    Notes
    -----
    Differential oracle: ``tests/test_distributed_blas.py`` checks every
    mesh in {(1,1), (2,2), (4,2)} x policy against single-device ``gemm``
    under the shared ``dtype_tolerances``.
    """
    from repro.tune import dispatch as _tune
    px, py = _mesh_xy(mesh)
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    steps = px * py
    res = _tune.resolve("pdgemm", (m, n, k), a.dtype, policy=policy,
                        use_kernel=use_kernel, registry=registry,
                        mesh=(px, py))
    # pad so rows/cols tile the mesh and K splits into px*py equal fine
    # panels (k <= steps * kf, so K always pads to exactly steps * kf)
    kf = -(-max(k, 1) // steps)
    a_p = _pad2(a, px, steps * kf)
    b_p = _pad2(b, steps * kf, py)
    # declare the whole schedule for the static analyzer: the geometry
    # here is exactly what plan_pdgemm prices, so spmd_lint can re-derive
    # the collective term and diff it against the traced ppermutes (CC003)
    emit_record(CollectiveRecord(
        kind="pdgemm", size=steps,
        info={"m": m, "n": n, "k": k, "px": px, "py": py, "kf": kf,
              "itemsize": jnp.dtype(a.dtype).itemsize,
              "dtype": jnp.dtype(a.dtype).name}))
    inner = functools.partial(_summa_inner, px=px, py=py, kf=kf, res=res,
                              interpret=interpret)
    f = shard_map(inner, mesh=mesh,
                  in_specs=(P("x", "y"), P("x", "y")),
                  out_specs=P("x", "y"), check_rep=False)
    out = f(a_p, b_p)[:m, :n]
    out = alpha * out
    if c is not None:
        out = out + beta * c
    return out


def _summa_inner(a, b, *, px: int, py: int, kf: int, res, interpret: bool):
    """Per-device SUMMA body: a (m/px, k/py) A shard holding coarse k-panel
    ``j``; b (k/px, n/py) B shard holding coarse k-panel ``i``. Fine panel
    ``g`` lives at A coarse ``g // px`` offset ``(g % px) * kf`` and B
    coarse ``g // py`` offset ``(g % py) * kf``."""
    acc = jnp.zeros((a.shape[0], b.shape[1]), a.dtype)
    for g in range(px * py):
        a_own, a_off = g // px, (g % px) * kf
        b_own, b_off = g // py, (g % py) * kf
        ap = ring_bcast(a[:, a_off:a_off + kf], "y", py, a_own)
        bp = ring_bcast(b[b_off:b_off + kf, :], "x", px, b_own)
        acc = acc + _local_update(ap, bp, res, interpret)
    return acc


def pdtrsm(a: jnp.ndarray, b: jnp.ndarray, mesh: Mesh, lower: bool = True,
           unit_diag: bool = False, left: bool = True,
           block: Optional[int] = None, policy: Optional[str] = None,
           use_kernel: Optional[bool] = None, interpret: bool = True,
           registry=None) -> jnp.ndarray:
    """Solve op(T) X = B with the right-hand sides sharded over the mesh.

    The substitution chain down T's diagonal is the serial hazard the paper
    cannot parallelize; the RHS columns are the embarrassingly parallel
    axis. So T ``(n, n)`` is replicated and B's columns are sharded over
    the flattened ``("x", "y")`` mesh: every device runs the *blocked*
    single-device :func:`repro.blas.level3.trsm` (policy-dispatched, so
    its off-diagonal GEMMs ride the Pallas path) on its column slab.

    Parameters
    ----------
    a : (n, n) triangular matrix; b : (n, nrhs) RHS (1-D b is treated as
    one column). ``left=False`` solves X op(T) = B by the usual transpose
    identity. ``block``/``policy`` are forwarded to the local trsm.

    Returns
    -------
    jnp.ndarray
        X with B's shape.

    Notes
    -----
    Oracle: ``tests/test_distributed_blas.py`` vs single-device ``trsm``.
    """
    if not left:
        return pdtrsm(a.T, b.T, mesh, lower=not lower, unit_diag=unit_diag,
                      left=True, block=block, policy=policy,
                      use_kernel=use_kernel, interpret=interpret,
                      registry=registry).T
    from repro.blas.level3 import trsm
    px, py = _mesh_xy(mesh)
    ndev = px * py
    vec = b.ndim == 1
    rhs = b[:, None] if vec else b
    nrhs = rhs.shape[1]
    rhs_p = _pad2(rhs, 1, ndev)                     # zero cols solve to zero

    def inner(t, r):
        return trsm(t, r, lower=lower, unit_diag=unit_diag, left=True,
                    block=block, policy=policy, use_kernel=use_kernel,
                    interpret=interpret, registry=registry)

    f = shard_map(inner, mesh=mesh,
                  in_specs=(P(None, None), P(None, ("x", "y"))),
                  out_specs=P(None, ("x", "y")), check_rep=False)
    x = f(a, rhs_p)[:, :nrhs]
    return x[:, 0] if vec else x
