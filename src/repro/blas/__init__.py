from repro.blas import level1, level2, level3
from repro.blas.level1 import (asum, axpy, dasum, daxpy, ddot, dnrm2, dot,
                               drot, dscal, iamax, idamax, nrm2, rot, scal)
from repro.blas.level2 import dgemv, dger, dtrsv, gemv, ger, trsv
from repro.blas.level3 import dgemm, dsyrk, dtrsm, gemm, syrk, trsm
from repro.blas import distributed
from repro.blas.distributed import make_blas_mesh, mesh_key, pdgemm, pdtrsm
