from repro.blas import level1, level2, level3
from repro.blas.level1 import daxpy, ddot, dnrm2, dscal, idamax
from repro.blas.level2 import dgemv, dger, dtrsv
from repro.blas.level3 import dgemm, dsyrk, dtrsm
