from repro.blas import level1, level2, level3
from repro.blas.level1 import daxpy, ddot, dnrm2, dscal, idamax
from repro.blas.level2 import dgemv, dger, dtrsv
from repro.blas.level3 import dgemm, dsyrk, dtrsm
from repro.blas import distributed
from repro.blas.distributed import make_blas_mesh, mesh_key, pdgemm, pdtrsm
