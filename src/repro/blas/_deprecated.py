"""Deprecation machinery for the d-prefixed BLAS shims.

Each old routine warns exactly once per process (per routine name) and
then keeps delegating silently; ``stacklevel`` points the warning at the
*caller* of the shim, not at this module. Tests reset the once-set via
:func:`reset_warned`.
"""
from __future__ import annotations

import warnings

_warned: set = set()


def warn_once(old: str, new: str) -> None:
    """One DeprecationWarning per deprecated routine name per process.

    ``stacklevel=3`` skips this helper and the shim body, landing on the
    shim's caller.
    """
    if old in _warned:
        return
    _warned.add(old)
    warnings.warn(
        f"repro.blas.{old} is deprecated; use repro.linalg.{new}, whose "
        f"policy/registry/mesh come from the active "
        f"repro.linalg.ExecutionContext (this shim keeps its old "
        f"single-device behavior and ignores any context mesh)",
        DeprecationWarning, stacklevel=3)


def reset_warned() -> None:
    """Forget which shims already warned (tests only)."""
    _warned.clear()
