"""internvl2-1b [vlm] - InternViT + qwen2-0.5b backbone [arXiv:2404.16821].

The ViT is a STUB: input_specs() provides 256 precomputed patch embeddings
prepended to the token stream (causal over the full sequence - a recorded
simplification of InternVL's bidirectional image tokens).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b", family="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv=2, head_dim=64,
    d_ff=4864, vocab=151655, act="silu", glu=True, qkv_bias=True,
    rope_theta=1_000_000.0, frontend="vision", num_prefix_tokens=256,
    tie_embeddings=True,
)
