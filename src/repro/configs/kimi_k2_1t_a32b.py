"""kimi-k2-1t-a32b [moe] - trillion-param MoE, 384 experts top-8
[arXiv:2501.kimi2; paper-table]. GQA kv=8 per the assigned spec.

1T total / ~32B active params: trained with 8-bit AdamW moments and bf16
params (no fp32 master - stochastic-rounding assumption recorded in
DESIGN.md); fp32 masters alone would need 4 TB.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv=8, head_dim=128,
    d_ff=2048, vocab=163840, act="silu", glu=True,
    n_experts=384, top_k=8, d_expert=2048, capacity_factor=1.25,
    rope_theta=50_000.0, accum_steps=8, opt_8bit=True, master_fp32=False,
)
