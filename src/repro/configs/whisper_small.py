"""whisper-small [audio] - enc-dec, conv frontend STUB [arXiv:2212.04356].

The mel/conv frontend is stubbed: input_specs() provides precomputed frame
embeddings (B, 1500, d_model). Sinusoidal positions on both sides so the
assigned 32k decode horizon lowers cleanly (DESIGN.md section 4).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="encdec",
    n_layers=12, d_model=768, n_heads=12, n_kv=12, head_dim=64,
    d_ff=3072, vocab=51865, act="gelu", glu=False,
    encoder_layers=12, encoder_seq=1500, frontend="audio",
    pos="sinusoidal",
)
