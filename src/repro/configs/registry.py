"""Architecture registry + the assigned input-shape sets.

Every (arch x shape) cell of the assignment resolves here to
(kind, input ShapeDtypeStructs) where kind is 'train' | 'prefill' | 'decode'.
``decode_*`` / ``long_*`` lower serve_step (one token against a seq_len KV
cache); ``long_500k`` is only defined for sub-quadratic archs (SSM/hybrid) -
full-attention archs report the cell as skipped (DESIGN.md section 4).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.frontends import frontend_tokens

ARCHS = (
    "minitron-8b", "granite-3-8b", "gemma-7b", "mistral-large-123b",
    "whisper-small", "mamba2-130m", "hymba-1.5b", "internvl2-1b",
    "qwen3-moe-235b-a22b", "kimi-k2-1t-a32b",
)

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCHS}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str                   # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec("train_4k", "train", 4_096, 256),
    ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    ShapeSpec("decode_32k", "decode", 32_768, 128),
    ShapeSpec("long_500k", "decode", 524_288, 1),
)

SHAPE_BY_NAME: Dict[str, ShapeSpec] = {s.name: s for s in SHAPES}


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def cell_supported(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Is the (arch, shape) cell defined? Returns (ok, reason_if_not)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full quadratic attention at 512k context is "
                       "infeasible; skipped per assignment for pure "
                       "full-attention archs")
    return True, ""


def input_specs(arch: str, shape_name: str, accum: Optional[int] = None):
    """ShapeDtypeStruct stand-ins for every model input of a cell.

    Returns (kind, specs dict). For 'train', tokens are
    (accum, B/accum, S) when accumulation is on. For 'decode', the specs
    cover the incoming token + cache index; caches are built separately
    (launch.dryrun) since their structure is model-dependent.
    """
    cfg = get_config(arch)
    shape = SHAPE_BY_NAME[shape_name]
    ok, why = cell_supported(cfg, shape)
    if not ok:
        raise ValueError(f"cell ({arch}, {shape_name}) undefined: {why}")
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    bf16 = jnp.bfloat16
    nf = frontend_tokens(cfg)
    if shape.kind == "train":
        a = accum if accum is not None else cfg.accum_steps
        a = max(min(a, b), 1)
        toks = (jax.ShapeDtypeStruct((a, b // a, s), i32) if a > 1
                else jax.ShapeDtypeStruct((b, s), i32))
        specs = {"tokens": toks}
        if nf:
            fshape = ((a, b // a, nf, cfg.d_model) if a > 1
                      else (b, nf, cfg.d_model))
            specs["frames" if cfg.frontend == "audio" else "patches"] = \
                jax.ShapeDtypeStruct(fshape, bf16)
        return "train", specs
    if shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        if nf:
            specs["frames" if cfg.frontend == "audio" else "patches"] = \
                jax.ShapeDtypeStruct((b, nf, cfg.d_model), bf16)
        return "prefill", specs
    # decode: one new token against a seq_len cache
    specs = {"token": jax.ShapeDtypeStruct((b, 1), i32),
             "cache_index": jax.ShapeDtypeStruct((), i32)}
    return "decode", specs


def all_cells():
    """Every defined (arch, shape) cell + the skipped ones with reasons."""
    defined, skipped = [], []
    for a in ARCHS:
        cfg = get_config(a)
        for s in SHAPES:
            ok, why = cell_supported(cfg, s)
            (defined if ok else skipped).append((a, s.name) if ok
                                                else (a, s.name, why))
    return defined, skipped
