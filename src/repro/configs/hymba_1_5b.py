"""hymba-1.5b [hybrid] - parallel attn+mamba heads [arXiv:2411.13676; hf].

32 layers, d=1600, 25 q heads (GQA kv=5, head_dim 64), sliding-window
attention (1024) on local layers with full attention on {0, 15, 31}, an SSM
path per layer (state 16). Hymba's meta tokens are omitted (DESIGN.md).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv=5, head_dim=64,
    d_ff=5504, vocab=32001, act="silu", glu=True,
    ssm_state=16, ssm_head_dim=64, ssm_expand=2, ssm_groups=1,
    ssm_chunk=256, window=1024, global_layers=(0, 15, 31),
)
