"""gemma-7b [dense] - GeGLU, head_dim=256 [arXiv:2403.08295]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b", family="dense",
    n_layers=28, d_model=3072, n_heads=16, n_kv=16, head_dim=256,
    d_ff=24576, vocab=256000, act="gelu", glu=True,      # GeGLU
    rope_theta=10_000.0, tie_embeddings=True, logit_softcap=30.0,
    accum_steps=2,
)
