"""mamba2-130m [ssm] - SSD, attention-free [arXiv:2405.21060].

d_model=768, expand 2 -> d_inner=1536, 24 SSD heads of dim 64,
state N=128, no FFN (d_ff=0): each layer is one Mamba-2 block.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=24, n_kv=24, d_ff=0,
    vocab=50280, ssm_state=128, ssm_head_dim=64, ssm_expand=2,
    ssm_groups=1, ssm_chunk=256, tie_embeddings=True,
)
