"""minitron-8b [dense] - pruned Nemotron [arXiv:2407.14679; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv=8, head_dim=128,
    d_ff=16384, vocab=256000, act="silu", glu=True,
    rope_theta=500_000.0, accum_steps=2,
)
