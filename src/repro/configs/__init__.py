from repro.configs.registry import (ARCHS, SHAPES, all_cells, cell_supported,
                                    get_config, input_specs)
