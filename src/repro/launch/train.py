"""Training launcher: ``python -m repro.launch.train --arch <id> ...``

Production loop shape: sharded state on the mesh, counter-based data
pipeline (each host generates its shard), atomic keep-N checkpointing with
restore-on-start (fault tolerance: a restarted job resumes from the latest
step automatically), heartbeat + straggler detection, gradient accumulation.

On this CPU container you run reduced configs (--layers/--d-model overrides
or --preset small); the full configs are exercised via the dry-run.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.ckpt.manager import CheckpointManager
from repro.configs import registry
from repro.data.pipeline import DataConfig, make_batch
from repro.distributed import sharding as sh
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.models import model_zoo as zoo
from repro.runtime.fault_tolerance import Heartbeat, StragglerDetector
from repro.train import train_state as ts
from repro.train.optimizer import AdamWConfig


def reduce_config(cfg, layers=None, d_model=None, vocab=None, heads=None):
    """Shrink an assigned config to laptop scale, same family/topology."""
    upd = {}
    if layers:
        upd["n_layers"] = layers
        upd["global_layers"] = tuple(
            i for i in cfg.global_layers if i < layers) or ((0,) if cfg.family == "hybrid" else ())
        if cfg.family == "encdec":
            upd["encoder_layers"] = max(2, layers // 2)
    if d_model:
        ratio = d_model / cfg.d_model
        upd["d_model"] = d_model
        upd["d_ff"] = max(32, int(cfg.d_ff * ratio)) if cfg.d_ff else 0
        if cfg.family == "moe":
            upd["d_expert"] = max(32, int((cfg.d_expert or cfg.d_ff) * ratio))
            upd["n_experts"] = min(cfg.n_experts, 8)
            upd["top_k"] = min(cfg.top_k, 2)
    if heads:
        upd["n_heads"] = heads
        upd["n_kv"] = max(1, min(cfg.n_kv, heads))
        upd["head_dim"] = (d_model or cfg.d_model) // heads
    if vocab:
        upd["vocab"] = vocab
    return dataclasses.replace(cfg, **upd)


def train_loop(cfg, opt_cfg, data_cfg, mesh, steps: int, ckpt_dir: str,
               save_interval: int = 50, log_every: int = 10,
               fail_at_step: int = -1, seed: int = 0):
    """Runs (or resumes) training; returns (final metrics, history)."""
    shard_fn = sh.make_shard_fn(mesh)
    mgr = CheckpointManager(ckpt_dir, save_interval=save_interval, keep=3)
    hb = Heartbeat(os.path.join(ckpt_dir, "heartbeat.json"))
    straggler = StragglerDetector()

    state_abs = jax.eval_shape(
        lambda k: ts.init_state(k, cfg, opt_cfg),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    st_specs = sh.state_specs(state_abs, mesh, fsdp=True)
    st_sh = sh.to_shardings(st_specs, mesh)

    restored, start = mgr.restore_latest(state_abs, shardings=st_sh)
    if restored is None:
        with mesh:
            state = jax.jit(
                lambda k: ts.init_state(k, cfg, opt_cfg),
                out_shardings=st_sh)(jax.random.PRNGKey(seed))
        start = 0
    else:
        state = restored
        start = start + 1
        print(f"[train] resumed from step {start - 1}")

    step_fn = jax.jit(ts.make_train_step(cfg, opt_cfg, shard_fn),
                      in_shardings=(st_sh, None), out_shardings=(st_sh, None),
                      donate_argnums=(0,))
    history = []
    accum = max(cfg.accum_steps, 1)
    for step in range(start, steps):
        if step == fail_at_step:
            from repro.runtime.fault_tolerance import SimulatedFailure
            raise SimulatedFailure(f"injected failure at step {step}")
        straggler.start()
        batch = make_batch(cfg, data_cfg, step, accum=accum)
        with mesh:
            state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        straggler.stop(step)
        hb.beat(step)
        history.append(loss)
        if step % log_every == 0 or step == steps - 1:
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e}", flush=True)
        if mgr.should_save(step) or step == steps - 1:
            mgr.save(step, state)
    print(f"[train] straggler report: {straggler.report()}")
    return state, history


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=registry.ARCHS, required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--heads", type=int, default=4)
    ap.add_argument("--vocab", type=int, default=4096)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--mesh", choices=["none", "debug", "pod"],
                    default="debug")
    ap.add_argument("--full-config", action="store_true",
                    help="use the assigned full config (dry-run scale!)")
    args = ap.parse_args()

    cfg = registry.get_config(args.arch)
    if not args.full_config:
        cfg = reduce_config(cfg, args.layers, args.d_model, args.vocab,
                            args.heads)
        cfg = dataclasses.replace(cfg, accum_steps=1, dtype="float32")
    if args.mesh == "pod":
        mesh = make_production_mesh()
    elif args.mesh == "debug":
        n = len(jax.devices())
        mesh = make_debug_mesh(data=max(1, n // 2), model=min(2, n))
    else:
        mesh = make_debug_mesh(data=1, model=1)
    opt_cfg = AdamWConfig(lr=args.lr, eight_bit=cfg.opt_8bit,
                          warmup_steps=max(args.steps // 20, 5),
                          decay_steps=args.steps)
    data_cfg = DataConfig(vocab=cfg.vocab, global_batch=args.batch,
                          seq_len=args.seq)
    _, history = train_loop(cfg, opt_cfg, data_cfg, mesh, args.steps,
                            os.path.join(args.ckpt_dir, cfg.name))
    print(json.dumps({"first_loss": history[0], "last_loss": history[-1]}))


if __name__ == "__main__":
    main()
