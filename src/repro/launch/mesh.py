"""Production mesh construction (DESIGN.md section 5).

Defined as functions - importing this module never touches jax device state.
Single pod: 16 x 16 = 256 chips ("data", "model"); multi-pod: 2 x 16 x 16 =
512 chips with the leading "pod" axis spanning the cross-pod (DCI) links.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

try:  # jax >= 0.5: explicit-sharding axis types
    from jax.sharding import AxisType
except ImportError:  # older jax: meshes are implicitly Auto on every axis
    AxisType = None


def _make_mesh(shape, axes) -> Mesh:
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 2, pod: int = 0) -> Mesh:
    """Small mesh for tests (uses however many devices exist)."""
    if pod:
        return _make_mesh((pod, data, model), ("pod", "data", "model"))
    return _make_mesh((data, model), ("data", "model"))


def mesh_name(mesh: Mesh) -> str:
    return "x".join(f"{k}{v}" for k, v in mesh.shape.items())
