"""Serving launcher: batched prefill + decode with a request queue.

``python -m repro.launch.serve --arch mamba2-130m --requests 8``

Implements the serving loop the decode shapes lower: a continuous-batching-
lite scheduler - requests with different prompt lengths are left-padded into
a batch, prefilled once, then decoded step-by-step with donated caches;
finished sequences are masked out. On the production mesh the same
serve_step runs with sequence-sharded KV caches (launch.dryrun lowers it).

The request loop runs under :func:`repro.linalg.use`, so a caller-supplied
``context`` (e.g. ``ExecutionContext(obs=trace)``) scopes the whole batch:
every routine the models reach through :mod:`repro.linalg` traces into the
ambient :mod:`repro.obs` capture, and the loop itself records
``serve.batch`` / ``serve.prefill`` / ``serve.decode`` spans plus one
``serve.request`` event per finished request.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro import linalg
from repro import obs as _obs
from repro.configs import registry
from repro.launch.train import reduce_config
from repro.models import model_zoo as zoo


@dataclasses.dataclass
class Request:
    prompt: np.ndarray          # (len,) int32
    max_new: int


def serve_batch(params, cfg, requests: List[Request], max_len: int,
                temperature: float = 0.0, seed: int = 0, context=None):
    """Prefill + decode a batch of requests; returns list of token arrays.

    ``context`` scopes the whole batch through :func:`repro.linalg.use`
    (``None`` inherits the ambient context), so an ``obs``-carrying
    context traces every linalg routine the request loop reaches - and
    the serve spans themselves route into the same capture
    (``obs=False`` suppresses an ambient trace for the whole batch).
    """
    import contextlib

    from repro.linalg.context import current, resolved_obs

    with contextlib.ExitStack() as st:
        st.enter_context(linalg.use(context))
        tr = resolved_obs(current())
        if tr is not _obs.current_trace():
            st.enter_context(_obs.capture(tr))
        return _serve_batch(params, cfg, requests, max_len,
                            temperature=temperature, seed=seed)


def _serve_batch(params, cfg, requests: List[Request], max_len: int,
                 temperature: float = 0.0, seed: int = 0):
    b = len(requests)
    plens = np.array([len(r.prompt) for r in requests])
    pmax = int(plens.max())
    toks = np.zeros((b, pmax), np.int32)           # right-aligned prompts
    for i, r in enumerate(requests):
        toks[i, pmax - len(r.prompt):] = r.prompt
    batch = {"tokens": jnp.asarray(toks)}

    with _obs.span("serve.batch", cat="serve", requests=b, max_len=max_len,
                   model=cfg.name, prompt_max=pmax):
        with _obs.span("serve.prefill", cat="serve", batch=b,
                       prompt_max=pmax):
            # prefill the whole padded batch (cache layout matches decode)
            logits, _, _ = zoo.prefill(params, batch, cfg, use_pallas=False)
            caches = zoo.init_caches(params, cfg, b, max_len)
            # replay prompts through decode_step to fill caches (simple +
            # exact; a production server would scatter the prefill KVs
            # directly)
            step = jax.jit(lambda p, t, c, i: zoo.decode_step(p, t, cfg, c, i))
            last = None
            for t in range(pmax):
                last, caches = step(params, jnp.asarray(toks[:, t:t + 1]),
                                    caches, jnp.int32(t))

        key = jax.random.PRNGKey(seed)
        out = [list(r.prompt) for r in requests]
        done = np.zeros(b, bool)
        max_new = max(r.max_new for r in requests)
        t0 = time.perf_counter()
        cur = last
        with _obs.span("serve.decode", cat="serve", batch=b,
                       max_new=max_new) as dec:
            for n in range(max_new):
                lg = cur[:, -1].astype(jnp.float32)
                if temperature > 0:
                    key, sub = jax.random.split(key)
                    nxt = jax.random.categorical(sub, lg / temperature)
                else:
                    nxt = jnp.argmax(lg, -1)
                nxt = np.asarray(nxt, np.int32)
                for i in range(b):
                    if not done[i]:
                        out[i].append(int(nxt[i]))
                        if (len(out[i]) - len(requests[i].prompt)
                                >= requests[i].max_new):
                            done[i] = True
                            _obs.event("serve.request", cat="serve", index=i,
                                       prompt_len=int(plens[i]),
                                       new_tokens=len(out[i]) - int(plens[i]))
                if done.all():
                    break
                cur, caches = step(params, jnp.asarray(nxt)[:, None], caches,
                                   jnp.int32(pmax + n))
            dt = time.perf_counter() - t0
            tok_s = (b * (n + 1)) / max(dt, 1e-9)
            dec.annotate(steps=n + 1, decode_tokens_per_s=tok_s)
    return out, {"decode_tokens_per_s": tok_s, "steps": n + 1}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=registry.ARCHS, default="mamba2-130m")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--d-model", type=int, default=128)
    args = ap.parse_args()

    cfg = reduce_config(registry.get_config(args.arch), args.layers,
                        args.d_model, vocab=512, heads=4)
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = zoo.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    reqs = [Request(rng.integers(0, cfg.vocab, size=rng.integers(4, 12)
                                 ).astype(np.int32), args.max_new)
            for _ in range(args.requests)]
    outs, stats = serve_batch(params, cfg, reqs, max_len=64)
    for i, o in enumerate(outs):
        print(f"req{i}: prompt={len(reqs[i].prompt)} -> {len(o)} tokens")
    print(stats)


if __name__ == "__main__":
    main()
