import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count at first init); 512 placeholder CPU devices let ``make_production_mesh``
build the production meshes. For each cell this driver:

  1. builds the abstract inputs (ShapeDtypeStructs - zero allocation),
  2. jits the right step (train_step / prefill / serve_step) with the
     production in/out shardings,
  3. ``.lower().compile()`` - sharding mismatches, compile-time OOMs, or
     unsupported collectives fail HERE, which is the point,
  4. records memory_analysis + cost_analysis + the parsed collective bytes
     as a Roofline row (EXPERIMENTS.md sections Dry-run / Roofline).

Usage:
  python -m repro.launch.dryrun --arch minitron-8b --shape train_4k
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.core import roofline as rl
from repro.distributed import sharding as sh
from repro.launch.mesh import make_production_mesh, mesh_name
from repro.models import model_zoo as zoo
from repro.models.frontends import frontend_tokens
from repro.train import train_state as ts
from repro.train.optimizer import AdamWConfig

_KEY_SPEC = jax.ShapeDtypeStruct((2,), jnp.uint32)


def _opt_cfg(cfg) -> AdamWConfig:
    return AdamWConfig(eight_bit=cfg.opt_8bit)


def abstract_state(cfg, opt_cfg):
    return jax.eval_shape(lambda k: ts.init_state(k, cfg, opt_cfg), _KEY_SPEC)


def abstract_caches(cfg, batch: int, max_len: int):
    if cfg.family == "encdec":
        params = jax.eval_shape(lambda k: zoo.init(k, cfg), _KEY_SPEC)
        mem = jax.ShapeDtypeStruct((batch, cfg.encoder_seq, cfg.d_model),
                                   jnp.bfloat16)
        return jax.eval_shape(
            lambda p, m: zoo.init_caches(p, cfg, batch, max_len, memory=m),
            params, mem)
    return jax.eval_shape(lambda: zoo.init_caches(None, cfg, batch, max_len))


def lower_cell(arch: str, shape_name: str, mesh, *, accum=None,
               model_axis_residual: bool = False, fsdp: bool = True,
               seq_shard_cache: bool = True, extra_tags=None,
               overrides=None):
    """Lower + compile one cell; returns (compiled, roofline_row).

    ``overrides``: dataclasses.replace kwargs applied to the arch config -
    the hillclimb knobs (remat_policy, accum_steps, dtype, ssm_chunk, ...).
    """
    import dataclasses as _dc
    cfg = registry.get_config(arch)
    if overrides:
        cfg = _dc.replace(cfg, **overrides)
    if accum is None:
        accum = cfg.accum_steps          # overrides-aware default
    shape = registry.SHAPE_BY_NAME[shape_name]
    kind, specs = registry.input_specs(arch, shape_name, accum=accum)
    opt_cfg = _opt_cfg(cfg)
    shard_fn = sh.make_shard_fn(mesh, model_axis_residual=model_axis_residual)
    chips = mesh.size
    n_params = zoo.param_count(cfg)
    n_active = zoo.active_param_count(cfg)
    tokens = shape.global_batch * shape.seq_len

    if kind == "train":
        state_abs = abstract_state(cfg, opt_cfg)
        st_specs = sh.state_specs(state_abs, mesh, fsdp=fsdp)
        st_sh = sh.to_shardings(st_specs, mesh)
        a = cfg.accum_steps if accum is None else accum
        b_specs = sh.batch_specs(specs, mesh, accum=max(a, 1))
        b_sh = sh.to_shardings(b_specs, mesh)
        step = ts.make_train_step(cfg, opt_cfg, shard_fn)
        jitted = jax.jit(step, in_shardings=(st_sh, b_sh),
                         out_shardings=(st_sh, None), donate_argnums=(0,))
        lowered = jitted.lower(state_abs, specs)
        model_flops = 6.0 * n_active * tokens
    elif kind == "prefill":
        params_abs = jax.eval_shape(lambda k: zoo.init(k, cfg), _KEY_SPEC)
        p_specs = sh.params_specs(params_abs, mesh, fsdp=fsdp)
        p_sh = sh.to_shardings(p_specs, mesh)
        b_specs = sh.batch_specs(specs, mesh, accum=1)
        b_sh = sh.to_shardings(b_specs, mesh)

        def prefill_step(params, batch):
            out = zoo.prefill(params, batch, cfg, shard_fn=shard_fn,
                              use_pallas=False)
            return out[0], out[2]                       # logits, caches

        jitted = jax.jit(prefill_step, in_shardings=(p_sh, b_sh))
        lowered = jitted.lower(params_abs, specs)
        model_flops = 2.0 * n_active * tokens
    else:  # decode
        params_abs = jax.eval_shape(lambda k: zoo.init(k, cfg), _KEY_SPEC)
        p_specs = sh.params_specs(params_abs, mesh, fsdp=fsdp)
        p_sh = sh.to_shardings(p_specs, mesh)
        caches_abs = abstract_caches(cfg, shape.global_batch, shape.seq_len)
        c_specs = sh.cache_specs(caches_abs, mesh, seq_shard=seq_shard_cache)
        c_sh = sh.to_shardings(c_specs, mesh)
        dp = sh.batch_axes(mesh)
        tok_spec = sh.batch_specs({"t": specs["token"]}, mesh)["t"]
        tok_sh = sh.to_shardings(tok_spec, mesh)

        def serve_step(params, token, caches, cache_index):
            return zoo.decode_step(params, token, cfg, caches, cache_index,
                                   shard_fn=shard_fn)

        jitted = jax.jit(serve_step,
                         in_shardings=(p_sh, tok_sh, c_sh, None),
                         out_shardings=(None, c_sh),
                         donate_argnums=(2,))
        lowered = jitted.lower(params_abs, specs["token"], caches_abs,
                               specs["cache_index"])
        model_flops = 2.0 * n_active * shape.global_batch

    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0
    extra = {"compile_s": compile_s, "n_params": float(n_params),
             "n_active": float(n_active), "kind": kind,
             **(extra_tags or {})}
    row = rl.from_compiled(arch, shape_name, mesh_name(mesh), chips,
                           compiled, model_flops, extra=extra)
    return compiled, row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=registry.ARCHS)
    ap.add_argument("--shape", choices=[s.name for s in registry.SHAPES])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"],
                    default="both")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--model-axis-residual", action="store_true")
    ap.add_argument("--no-seq-shard-cache", action="store_true")
    args = ap.parse_args()

    meshes = []
    if args.mesh in ("pod", "both"):
        meshes.append(("pod", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multipod", "both"):
        meshes.append(("multipod", make_production_mesh(multi_pod=True)))

    if args.all:
        cells, skipped = registry.all_cells()
        for s in skipped:
            print(f"SKIP {s[0]} x {s[1]}: {s[2]}")
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    os.makedirs(args.out, exist_ok=True)
    for arch, shape in cells:
        for mname, mesh in meshes:
            tag = f"{arch}__{shape}__{mname}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                print(f"CACHED {tag}")
                continue
            print(f"LOWER  {tag} ...", flush=True)
            try:
                compiled, row = lower_cell(
                    arch, shape, mesh, accum=args.accum,
                    model_axis_residual=args.model_axis_residual,
                    seq_shard_cache=not args.no_seq_shard_cache)
                import gzip
                with gzip.open(os.path.join(args.out, tag + ".hlo.gz"),
                               "wt") as f:
                    f.write(compiled.as_text())
                mem = compiled.memory_analysis()
                print(f"  memory_analysis: {mem}")
                cost = compiled.cost_analysis()
                cost = cost[0] if isinstance(cost, list) else cost
                print(f"  flops={cost.get('flops', 0):.3e} "
                      f"bytes={cost.get('bytes accessed', 0):.3e}")
                print(f"  collectives: {row.coll_breakdown}")
                print(f"  terms: compute={row.compute_s * 1e3:.2f}ms "
                      f"memory={row.memory_s * 1e3:.2f}ms "
                      f"collective={row.collective_s * 1e3:.2f}ms "
                      f"dominant={row.dominant} "
                      f"roofline_frac={row.roofline_fraction:.3f}")
                with open(path, "w") as f:
                    json.dump(row.to_dict(), f, indent=1)
            except Exception:
                print(f"FAILED {tag}")
                traceback.print_exc()
                with open(os.path.join(args.out, tag + ".FAILED"), "w") as f:
                    f.write(traceback.format_exc())


if __name__ == "__main__":
    main()
