"""Cycle-level PE + APE simulator (paper section 5, fig. 11).

The paper's Processing Element is a scalar, in-order, single-issue core with
four floating-point units of *configurable pipeline depth* (the experimental
knob), a register file preloaded by an Auxiliary PE (steps 1-2 of the paper's
operating procedure - so compute streams see RF-resident operands).

This simulator executes the SSA instruction streams of
:mod:`repro.core.isa` with an exact in-order stall-on-use scoreboard:

    issue[i] = max(issue[i-1] + 1, ready[src1[i]], ready[src2[i]])
    ready[i] = issue[i] + latency[opcode[i]]

latency is the unit's pipeline depth (units are fully pipelined; composite
ops: FMA = p_mul + p_add chained, DOT4 = p_mul + 2*p_add - a 4-multiplier
front feeding a 2-level adder tree, the paper's "4 multipliers and 3 adders
in a reconfigurable way").

All pipes share one clock whose cycle time is set by the slowest stage,
``max_u(t_p_u / p_u) + t_o`` - deeper pipes raise the clock, stalls cost
cycles: exactly the eq.-2 trade-off, but *measured* instead of modeled.

The scoreboard is a ``lax.scan`` (jitted, vmappable over depth
configurations), so a full depth sweep of a multi-million-instruction GEMM
stream runs in seconds on CPU.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import isa
from repro.core.characterization import T_O, T_P

DEFAULT_DEPTHS = {"mul": 5, "add": 4, "div": 12, "sqrt": 14}


@dataclasses.dataclass(frozen=True)
class PEResult:
    """One simulation outcome at one depth configuration."""

    name: str
    depths: Dict[str, int]
    n_instructions: int
    flops: int
    cycles: int
    stalls: int
    cycle_time: float            # in t_o-normalized time units
    frequency: float             # 1 / cycle_time

    @property
    def cpi(self) -> float:
        return self.cycles / max(self.n_instructions, 1)

    @property
    def tpi(self) -> float:
        """Time per instruction = CPI * cycle_time (the paper's TPI)."""
        return self.cpi * self.cycle_time

    @property
    def time(self) -> float:
        return self.cycles * self.cycle_time

    @property
    def flops_per_time(self) -> float:
        return self.flops / max(self.time, 1e-30)


def _latency_vector(depths: Mapping[str, int]) -> np.ndarray:
    p = {**DEFAULT_DEPTHS, **{k: int(v) for k, v in depths.items()}}
    lat = np.zeros(isa.N_OPCODES, dtype=np.int32)
    lat[isa.NOP] = 1
    lat[isa.MUL] = p["mul"]
    lat[isa.ADD] = p["add"]
    lat[isa.DIV] = p["div"]
    lat[isa.SQRT] = p["sqrt"]
    lat[isa.FMA] = p["mul"] + p["add"]
    lat[isa.DOT4] = p["mul"] + 2 * p["add"]
    return lat


def cycle_time(depths: Mapping[str, int], used: Sequence[str] = ("mul", "add", "div", "sqrt"),
               t_o: float = T_O) -> float:
    """Clock period = slowest pipe stage + latch overhead (paper's equal-
    stage-time assumption across pipes, [18])."""
    p = {**DEFAULT_DEPTHS, **{k: int(v) for k, v in depths.items()}}
    stage = max(T_P[u] / p[u] for u in used) if used else 1.0
    return stage + t_o


@functools.partial(jax.jit, static_argnames=())
def _scoreboard(opcode: jnp.ndarray, src1: jnp.ndarray, src2: jnp.ndarray,
                lat: jnp.ndarray):
    """Exact in-order stall-on-use scoreboard; returns (cycles, stalls)."""
    n = opcode.shape[0]

    def body(carry, x):
        ready, prev_issue, stalls = carry
        op, s1, s2, i = x
        r1 = jnp.where(s1 >= 0, ready[s1], 0)
        r2 = jnp.where(s2 >= 0, ready[s2], 0)
        earliest = jnp.maximum(r1, r2)
        issue = jnp.maximum(prev_issue + 1, earliest)
        fin = issue + lat[op]
        ready = ready.at[i].set(fin)
        stalls = stalls + (issue - prev_issue - 1)
        return (ready, issue, stalls), fin

    init = (jnp.zeros((n,), jnp.int32), jnp.int32(-1), jnp.int32(0))
    xs = (opcode, src1, src2, jnp.arange(n, dtype=jnp.int32))
    (_, _, stalls), fins = lax.scan(body, init, xs)
    return jnp.max(fins), stalls


_scoreboard_sweep = jax.jit(jax.vmap(_scoreboard, in_axes=(None, None, None, 0)))


def simulate(stream: isa.InstrStream, depths: Mapping[str, int] | None = None,
             t_o: float = T_O) -> PEResult:
    """Run one stream at one depth configuration."""
    depths = dict(DEFAULT_DEPTHS, **(depths or {}))
    lat = jnp.asarray(_latency_vector(depths))
    cycles, stalls = _scoreboard(jnp.asarray(stream.opcode),
                                 jnp.asarray(stream.src1),
                                 jnp.asarray(stream.src2), lat)
    used = [k for k, v in stream.census().items() if v > 0]
    ct = cycle_time(depths, used=used or ("mul",), t_o=t_o)
    return PEResult(stream.name, depths, stream.n_instructions, stream.flops,
                    int(cycles), int(stalls), ct, 1.0 / ct)


def sweep(stream: isa.InstrStream, unit: str, depth_values: Sequence[int],
          fixed: Mapping[str, int] | None = None, t_o: float = T_O):
    """Depth sweep of one unit (figs 12-13): vmapped scoreboard, one scan.

    Returns a list of PEResult, one per depth in ``depth_values``.
    """
    fixed = dict(DEFAULT_DEPTHS, **(fixed or {}))
    cfgs = []
    lats = []
    for d in depth_values:
        cfg = dict(fixed)
        cfg[unit] = int(d)
        cfgs.append(cfg)
        lats.append(_latency_vector(cfg))
    lat = jnp.asarray(np.stack(lats))
    cycles, stalls = _scoreboard_sweep(jnp.asarray(stream.opcode),
                                       jnp.asarray(stream.src1),
                                       jnp.asarray(stream.src2), lat)
    used = [k for k, v in stream.census().items() if v > 0]
    out = []
    for cfg, cy, st in zip(cfgs, np.asarray(cycles), np.asarray(stalls)):
        ct = cycle_time(cfg, used=used or ("mul",), t_o=t_o)
        out.append(PEResult(stream.name, cfg, stream.n_instructions,
                            stream.flops, int(cy), int(st), ct, 1.0 / ct))
    return out


def sweep_joint(stream: isa.InstrStream, units: Sequence[str],
                depth_values: Sequence[int],
                fixed: Mapping[str, int] | None = None, t_o: float = T_O):
    """Sweep several units together at the same depth (fig. 12 sweeps adder
    and multiplier jointly; fig. 13 sqrt and divider)."""
    fixed = dict(DEFAULT_DEPTHS, **(fixed or {}))
    cfgs = []
    lats = []
    for d in depth_values:
        cfg = dict(fixed)
        for u in units:
            cfg[u] = int(d)
        cfgs.append(cfg)
        lats.append(_latency_vector(cfg))
    lat = jnp.asarray(np.stack(lats))
    cycles, stalls = _scoreboard_sweep(jnp.asarray(stream.opcode),
                                       jnp.asarray(stream.src1),
                                       jnp.asarray(stream.src2), lat)
    used = [k for k, v in stream.census().items() if v > 0]
    out = []
    for cfg, cy, st in zip(cfgs, np.asarray(cycles), np.asarray(stalls)):
        ct = cycle_time(cfg, used=used or ("mul",), t_o=t_o)
        out.append(PEResult(stream.name, cfg, stream.n_instructions,
                            stream.flops, int(cy), int(st), ct, 1.0 / ct))
    return out


def best_depth(results: Sequence[PEResult], unit: str) -> int:
    """Depth minimizing measured TPI (time, not CPI - CPI alone is monotone
    in depth; the optimum only exists once the faster clock is credited)."""
    best = min(results, key=lambda r: r.tpi)
    return best.depths[unit]
