"""Cycle-level PE + APE simulator (paper section 5, fig. 11).

The paper's Processing Element is a scalar, in-order, single-issue core with
four floating-point units of *configurable pipeline depth* (the experimental
knob), a register file preloaded by an Auxiliary PE (steps 1-2 of the paper's
operating procedure - so compute streams see RF-resident operands).

This simulator executes the SSA instruction streams of
:mod:`repro.core.isa` with an exact in-order stall-on-use scoreboard:

    issue[i] = max(issue[i-1] + 1, ready[src1[i]], ready[src2[i]])
    ready[i] = issue[i] + latency[opcode[i]]

latency is the unit's pipeline depth (units are fully pipelined; composite
ops: FMA = p_mul + p_add chained, DOT4 = p_mul + 2*p_add - a 4-multiplier
front feeding a 2-level adder tree, the paper's "4 multipliers and 3 adders
in a reconfigurable way").

All pipes share one clock whose cycle time is set by the slowest stage,
``max_u(t_p_u / p_u) + t_o`` - deeper pipes raise the clock, stalls cost
cycles: exactly the eq.-2 trade-off, but *measured* instead of modeled.

The scoreboard is a ``lax.scan`` (jitted, vmappable over depth
configurations), so a full depth sweep of a multi-million-instruction GEMM
stream runs in seconds on CPU.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro import arch as _arch
from repro.arch import MachineSpec
from repro.core import isa
from repro.core.characterization import T_O, T_P

# the paper's section-5 experimental optimum = the "paper-pe" machine's FPU
DEFAULT_DEPTHS = dict(_arch.get("paper-pe").fpu.depths)


def _fpu_of(machine):
    """The FPUSpec a simulation prices against (None = "paper-pe" - the
    historical DEFAULT_DEPTHS / characterization T_P / T_O constants)."""
    m = machine if machine is not None else _arch.get("paper-pe")
    return m.fpu


@dataclasses.dataclass(frozen=True)
class PEResult:
    """One simulation outcome at one depth configuration."""

    name: str
    depths: Dict[str, int]
    n_instructions: int
    flops: int
    cycles: int
    stalls: int
    cycle_time: float            # in t_o-normalized time units
    frequency: float             # 1 / cycle_time

    @property
    def cpi(self) -> float:
        return self.cycles / max(self.n_instructions, 1)

    @property
    def tpi(self) -> float:
        """Time per instruction = CPI * cycle_time (the paper's TPI)."""
        return self.cpi * self.cycle_time

    @property
    def time(self) -> float:
        return self.cycles * self.cycle_time

    @property
    def flops_per_time(self) -> float:
        return self.flops / max(self.time, 1e-30)


def _latency_vector(depths: Mapping[str, int],
                    base: Mapping[str, int] = None) -> np.ndarray:
    p = {**(base or DEFAULT_DEPTHS), **{k: int(v) for k, v in depths.items()}}
    lat = np.zeros(isa.N_OPCODES, dtype=np.int32)
    lat[isa.NOP] = 1
    lat[isa.MUL] = p["mul"]
    lat[isa.ADD] = p["add"]
    lat[isa.DIV] = p["div"]
    lat[isa.SQRT] = p["sqrt"]
    lat[isa.FMA] = p["mul"] + p["add"]
    lat[isa.DOT4] = p["mul"] + 2 * p["add"]
    return lat


def cycle_time(depths: Mapping[str, int], used: Sequence[str] = ("mul", "add", "div", "sqrt"),
               t_o: float = T_O, t_p: Mapping[str, float] = None,
               base: Mapping[str, int] = None) -> float:
    """Clock period = slowest pipe stage + latch overhead (paper's equal-
    stage-time assumption across pipes, [18]). ``t_p``/``base`` default to
    the "paper-pe" technology constants / depths."""
    p = {**(base or DEFAULT_DEPTHS), **{k: int(v) for k, v in depths.items()}}
    tp = t_p or T_P
    stage = max(tp[u] / p[u] for u in used) if used else 1.0
    return stage + t_o


@functools.partial(jax.jit, static_argnames=())
def _scoreboard(opcode: jnp.ndarray, src1: jnp.ndarray, src2: jnp.ndarray,
                lat: jnp.ndarray):
    """Exact in-order stall-on-use scoreboard; returns (cycles, stalls)."""
    n = opcode.shape[0]

    def body(carry, x):
        ready, prev_issue, stalls = carry
        op, s1, s2, i = x
        r1 = jnp.where(s1 >= 0, ready[s1], 0)
        r2 = jnp.where(s2 >= 0, ready[s2], 0)
        earliest = jnp.maximum(r1, r2)
        issue = jnp.maximum(prev_issue + 1, earliest)
        fin = issue + lat[op]
        ready = ready.at[i].set(fin)
        stalls = stalls + (issue - prev_issue - 1)
        return (ready, issue, stalls), fin

    init = (jnp.zeros((n,), jnp.int32), jnp.int32(-1), jnp.int32(0))
    xs = (opcode, src1, src2, jnp.arange(n, dtype=jnp.int32))
    (_, _, stalls), fins = lax.scan(body, init, xs)
    return jnp.max(fins), stalls


_scoreboard_sweep = jax.jit(jax.vmap(_scoreboard, in_axes=(None, None, None, 0)))


def simulate(stream: isa.InstrStream, depths: Mapping[str, int] | None = None,
             t_o: float = None,
             machine: MachineSpec | None = None) -> PEResult:
    """Run one stream at one depth configuration.

    ``machine`` supplies the base depths and technology constants
    (``None`` = the "paper-pe" spec, i.e. the historical defaults);
    explicit ``depths`` / ``t_o`` override it.
    """
    fpu = _fpu_of(machine)
    t_o = fpu.t_o if t_o is None else t_o
    depths = dict(fpu.depths, **(depths or {}))
    lat = jnp.asarray(_latency_vector(depths, base=fpu.depths))
    cycles, stalls = _scoreboard(jnp.asarray(stream.opcode),
                                 jnp.asarray(stream.src1),
                                 jnp.asarray(stream.src2), lat)
    used = [k for k, v in stream.census().items() if v > 0]
    ct = cycle_time(depths, used=used or ("mul",), t_o=t_o, t_p=fpu.t_p,
                    base=fpu.depths)
    return PEResult(stream.name, depths, stream.n_instructions, stream.flops,
                    int(cycles), int(stalls), ct, 1.0 / ct)


def sweep(stream: isa.InstrStream, unit: str, depth_values: Sequence[int],
          fixed: Mapping[str, int] | None = None, t_o: float = None,
          machine: MachineSpec | None = None):
    """Depth sweep of one unit (figs 12-13): vmapped scoreboard, one scan.

    Returns a list of PEResult, one per depth in ``depth_values``.
    ``machine`` supplies base depths + technology constants (``None`` =
    "paper-pe", the historical defaults).
    """
    fpu = _fpu_of(machine)
    t_o = fpu.t_o if t_o is None else t_o
    fixed = dict(fpu.depths, **(fixed or {}))
    cfgs = []
    lats = []
    for d in depth_values:
        cfg = dict(fixed)
        cfg[unit] = int(d)
        cfgs.append(cfg)
        lats.append(_latency_vector(cfg, base=fpu.depths))
    lat = jnp.asarray(np.stack(lats))
    cycles, stalls = _scoreboard_sweep(jnp.asarray(stream.opcode),
                                       jnp.asarray(stream.src1),
                                       jnp.asarray(stream.src2), lat)
    used = [k for k, v in stream.census().items() if v > 0]
    out = []
    for cfg, cy, st in zip(cfgs, np.asarray(cycles), np.asarray(stalls)):
        ct = cycle_time(cfg, used=used or ("mul",), t_o=t_o, t_p=fpu.t_p,
                        base=fpu.depths)
        out.append(PEResult(stream.name, cfg, stream.n_instructions,
                            stream.flops, int(cy), int(st), ct, 1.0 / ct))
    return out


def sweep_joint(stream: isa.InstrStream, units: Sequence[str],
                depth_values: Sequence[int],
                fixed: Mapping[str, int] | None = None, t_o: float = None,
                machine: MachineSpec | None = None):
    """Sweep several units together at the same depth (fig. 12 sweeps adder
    and multiplier jointly; fig. 13 sqrt and divider). ``machine`` as in
    :func:`sweep`."""
    fpu = _fpu_of(machine)
    t_o = fpu.t_o if t_o is None else t_o
    fixed = dict(fpu.depths, **(fixed or {}))
    cfgs = []
    lats = []
    for d in depth_values:
        cfg = dict(fixed)
        for u in units:
            cfg[u] = int(d)
        cfgs.append(cfg)
        lats.append(_latency_vector(cfg, base=fpu.depths))
    lat = jnp.asarray(np.stack(lats))
    cycles, stalls = _scoreboard_sweep(jnp.asarray(stream.opcode),
                                       jnp.asarray(stream.src1),
                                       jnp.asarray(stream.src2), lat)
    used = [k for k, v in stream.census().items() if v > 0]
    out = []
    for cfg, cy, st in zip(cfgs, np.asarray(cycles), np.asarray(stalls)):
        ct = cycle_time(cfg, used=used or ("mul",), t_o=t_o, t_p=fpu.t_p,
                        base=fpu.depths)
        out.append(PEResult(stream.name, cfg, stream.n_instructions,
                            stream.flops, int(cy), int(st), ct, 1.0 / ct))
    return out


def best_depth(results: Sequence[PEResult], unit: str) -> int:
    """Depth minimizing measured TPI (time, not CPI - CPI alone is monotone
    in depth; the optimum only exists once the faster clock is credited)."""
    best = min(results, key=lambda r: r.tpi)
    return best.depths[unit]
