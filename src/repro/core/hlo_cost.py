"""Trip-count-aware cost analysis of optimized HLO text.

``compiled.cost_analysis()`` visits each called computation ONCE: a
lax.scan'd 88-layer transformer reports ~1 layer's flops (verified by probe,
see tests/test_hlo_cost.py). Since every model in this framework scans its
layers (and its gradient-accumulation microbatches), the XLA numbers
undercount flops, bytes, and in-loop collectives by the trip count.

This module re-derives the three roofline inputs from the HLO text itself:

  * computations are parsed into symbol tables (op name -> dtype/dims/bytes),
  * ``while`` ops recurse into their body x trip count (trip count recovered
    from the loop condition's compare-against-constant),
  * ``fusion`` ops cost their fused computation's arithmetic (flops) but only
    fusion-boundary operands/results for bytes (fusion internals never touch
    HBM - the same convention HloCostAnalysis uses),
  * ``dot`` flops = 2 * result_elems * contraction_size (parsed from
    lhs_contracting_dims + operand shapes),
  * collective operand bytes are scaled by the enclosing loops' trip counts.

The result is conservative-exact for the programs this framework emits
(scan + fusion + dot + collectives); exotic ops fall back to byte-only
costs.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"pred": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
                "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "s16": 2, "s32": 4,
                "s64": 8, "u8": 1, "u16": 2, "u32": 4, "u64": 8,
                "c64": 8, "c128": 16}
_SHAPE_RE = re.compile(
    r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([^\s=]+)\s*=\s*(.*)$")
_KIND_RE = re.compile(r"\s([a-z][\w-]*)\(")
_NAME_RE = re.compile(r"%([^\s,()]+)")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)")
_ATTR_RE = re.compile(r"(\w+)=%?([\w.\-]+)")
_DIMS_RE = re.compile(r"(\w+_dims)=\{([\d,]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")

_ELEMENTWISE_FLOP_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "log", "tanh", "rsqrt", "sqrt", "power",
    "logistic", "expm1", "log1p", "cosine", "sine", "atan2", "remainder",
    "floor", "ceil", "round-nearest-afz", "round-nearest-even", "erf",
    "compare", "select", "clamp", "convert", "and", "or", "xor", "not",
    "sign", "cbrt",
}
_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
             "bitcast", "after-all", "iota", "reshape", "partition-id",
             "replica-id", "rng-get-and-update-state", "opt-barrier"}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


@dataclasses.dataclass
class OpRec:
    name: str
    kind: str
    dtype: str
    dims: Tuple[int, ...]
    result_bytes: int
    operands: List[str]
    line: str


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0            # CPU-fusion-boundary traffic (upper bound)
    bytes_fused: float = 0.0      # TPU-fusion model: dot/copy/cache/coll
                                  # traffic only; elementwise chains assumed
                                  # fused into matmul epilogues (XLA:TPU does)
    coll: Dict[str, float] = dataclasses.field(default_factory=dict)

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        self.bytes_fused += o.bytes_fused
        for k, v in o.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v
        return self

    def scaled(self, f: float) -> "Cost":
        return Cost(self.flops * f, self.bytes * f, self.bytes_fused * f,
                    {k: v * f for k, v in self.coll.items()})

    @property
    def collective_bytes(self) -> float:
        return sum(self.coll.values())


def _parse_result(rest: str) -> Tuple[str, Tuple[int, ...], int, str]:
    """(dtype, dims, total bytes incl tuple, kind) from an op's rhs text."""
    km = _KIND_RE.search(" " + rest)
    seg = rest[: km.start() - 1] if km else rest
    kind = km.group(1) if km else ""
    total = 0
    first_dtype, first_dims = "", ()
    for dt, dims in _SHAPE_RE.findall(seg):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
        if not first_dtype:
            first_dtype = dt
            first_dims = tuple(int(d) for d in dims.split(",")) if dims else ()
    return first_dtype, first_dims, total, kind


def parse_module(hlo_text: str) -> Dict[str, Dict[str, OpRec]]:
    """computation name -> {op name -> OpRec}. ENTRY registered as 'ENTRY'."""
    comps: Dict[str, Dict[str, OpRec]] = {}
    cur: Optional[Dict[str, OpRec]] = None
    for line in hlo_text.splitlines():
        # computation headers sit at column 0: "%name (args...) -> type {"
        if (line and not line[0].isspace() and line.rstrip().endswith("{")
                and "->" in line):
            cm = _COMP_RE.match(line)
            if cm:
                name = cm.group(2)
                cur = comps.setdefault(name, {})
                if cm.group(1):                  # ENTRY alias
                    comps["ENTRY"] = cur
            continue
        if cur is None:
            continue
        d = _DEF_RE.match(line)
        if not d:
            continue
        name, rest = d.group(1), d.group(2)
        dtype, dims, rbytes, kind = _parse_result(rest)
        open_i = rest.find(kind + "(")
        region = ""
        if open_i >= 0:
            region = _balanced(rest, open_i + len(kind))
        operands = _NAME_RE.findall(region)
        cur[name] = OpRec(name, kind, dtype, dims, rbytes, operands, line)
    return comps


def _balanced(text: str, open_idx: int) -> str:
    depth = 0
    for j in range(open_idx, len(text)):
        if text[j] == "(":
            depth += 1
        elif text[j] == ")":
            depth -= 1
            if depth == 0:
                return text[open_idx + 1:j]
    return text[open_idx + 1:]


def _attr(line: str, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w.\-]+)", line)
    return m.group(1) if m else None


def _trip_count(cond: Dict[str, OpRec]) -> int:
    """Loop bound from the condition's compare-against-constant."""
    for rec in cond.values():
        if rec.kind == "compare":
            for op in rec.operands:
                target = cond.get(op)
                if target is not None:
                    m = _CONST_RE.search(target.line)
                    if m:
                        return max(int(m.group(1)), 1)
    # fallback: any scalar integer constant in the condition
    best = 1
    for rec in cond.values():
        m = _CONST_RE.search(rec.line)
        if m:
            best = max(best, int(m.group(1)))
    return best


def _dot_flops(rec: OpRec, table: Dict[str, OpRec]) -> float:
    result_elems = 1
    for d in rec.dims:
        result_elems *= d
    lhs = table.get(rec.operands[0]) if rec.operands else None
    contract = 1
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rec.line)
    if lhs is not None and m and m.group(1):
        for i in m.group(1).split(","):
            idx = int(i)
            if idx < len(lhs.dims):
                contract *= lhs.dims[idx]
    return 2.0 * result_elems * contract


def _fusion_flops(comp: Dict[str, OpRec], comps, seen) -> float:
    """Arithmetic inside a fused computation (bytes are boundary-only)."""
    fl = 0.0
    for rec in comp.values():
        if rec.kind == "dot":
            fl += _dot_flops(rec, comp)
        elif rec.kind in _ELEMENTWISE_FLOP_OPS:
            n = 1
            for d in rec.dims:
                n *= d
            fl += n
        elif rec.kind == "reduce":
            src = comp.get(rec.operands[0]) if rec.operands else None
            if src is not None:
                n = 1
                for d in src.dims:
                    n *= d
                fl += n
        elif rec.kind == "fusion":
            callee = _attr(rec.line, "calls")
            if callee and callee in comps and callee not in seen:
                fl += _fusion_flops(comps[callee], comps, seen | {callee})
    return fl


def cost_of(comps: Dict[str, Dict[str, OpRec]], comp_name: str = "ENTRY",
            _depth: int = 0) -> Cost:
    comp = comps.get(comp_name, {})
    total = Cost()
    if _depth > 32:
        return total
    for rec in comp.values():
        k = rec.kind
        if k in _FREE_OPS or not k:
            continue
        if k == "while":
            body = _attr(rec.line, "body")
            cond = _attr(rec.line, "condition")
            trips = _trip_count(comps.get(cond, {})) if cond else 1
            if body:
                total += cost_of(comps, body, _depth + 1).scaled(trips)
            continue
        if k == "conditional":
            branches = re.findall(r"%([\w.\-]+)", rec.line.split("branch", 1)[-1]) \
                if "branch" in rec.line else []
            if branches:
                total += cost_of(comps, branches[0], _depth + 1)
            continue
        if k in ("call", "async-start"):
            callee = _attr(rec.line, "to_apply") or _attr(rec.line, "calls")
            if callee:
                total += cost_of(comps, callee, _depth + 1)
            continue
        # bytes: operands + result at this op's boundary. In-place update
        # ops move only the update, not the aliased buffer (XLA DUS is
        # in-place; charging the whole KV cache per decode write would be
        # off by ~S). Gathers/slices read what they produce, not the source.
        if k == "dynamic-update-slice":
            upd = comp.get(rec.operands[1]) if len(rec.operands) > 1 else None
            op_bytes = 2 * (upd.result_bytes if upd else 0)
        elif k in ("dynamic-slice", "gather", "slice"):
            op_bytes = 2 * rec.result_bytes
        elif k in ("broadcast", "iota"):
            op_bytes = rec.result_bytes
        elif k == "scatter":
            upd = comp.get(rec.operands[-1]) if rec.operands else None
            op_bytes = 2 * (upd.result_bytes if upd else rec.result_bytes)
        else:
            op_bytes = rec.result_bytes
            for op in rec.operands:
                src = comp.get(op)
                if src is not None:
                    op_bytes += src.result_bytes
        base = k[:-6] if k.endswith("-start") else k
        if base in _COLLECTIVES:
            operand_bytes = sum(comp[o].result_bytes for o in rec.operands
                                if o in comp)
            total += Cost(0.0, op_bytes, op_bytes,
                          {base: float(operand_bytes)})
            continue
        if k == "fusion":
            callee = _attr(rec.line, "calls")
            fused = comps.get(callee, {}) if callee else {}
            fl = _fusion_flops(fused, comps, {callee}) if callee else 0.0
            # in-place DUS inside the fusion: replace the aliased full-buffer
            # parameter's bytes with 2x the update size
            for frec in fused.values():
                if frec.kind != "dynamic-update-slice" or not frec.operands:
                    continue
                target = fused.get(frec.operands[0])
                upd = (fused.get(frec.operands[1])
                       if len(frec.operands) > 1 else None)
                if target is not None and target.kind == "parameter":
                    op_bytes -= target.result_bytes
                    # the fusion result includes the aliased buffer too
                    op_bytes -= min(rec.result_bytes, target.result_bytes)
                    op_bytes += 2 * (upd.result_bytes if upd else 0)
            has_dot = any(fr.kind in ("dot", "convolution")
                          for fr in fused.values())
            total += Cost(fl, max(op_bytes, 0),
                          max(op_bytes, 0) if has_dot else 0.0)
            continue
        if k == "dot":
            total += Cost(_dot_flops(rec, comp), op_bytes, op_bytes)
            continue
        if k in _ELEMENTWISE_FLOP_OPS:
            n = 1
            for d in rec.dims:
                n *= d
            total += Cost(float(n), op_bytes, 0.0)   # fuses on TPU
            continue
        if k == "reduce":
            src = comp.get(rec.operands[0]) if rec.operands else None
            n = 1
            for d in (src.dims if src else rec.dims):
                n *= d
            total += Cost(float(n), op_bytes, 0.0)   # fuses with producer
            continue
        # default: byte-only (copy / slice / scatter / custom-call / sort...)
        fused_b = op_bytes if k in (
            "copy", "concatenate", "custom-call", "sort", "scatter",
            "dynamic-update-slice", "dynamic-slice", "gather", "slice",
            "pad") else 0.0
        total += Cost(0.0, op_bytes, fused_b)
    return total


def analyze(hlo_text: str) -> Cost:
    """Trip-count-aware (flops, bytes, collective bytes) of a module."""
    return cost_of(parse_module(hlo_text))
