"""Workload characterization of BLAS and LAPACK (paper section 4).

For each routine the paper characterizes, this module produces the parameters
the analytical model of :mod:`repro.core.pipeline_model` needs, per
floating-point operation class K = {mul, add, sqrt, div}:

  * ``N_iI`` - instruction count issued to pipe ``i``,
  * ``N_iH`` - dependency-hazard count seen by pipe ``i``,
  * ``gamma_i`` - mean exposed fraction of the pipe delay per hazard.

The counts are *symbolic* (closed-form in the problem size), mirroring the
paper's DAG arguments:

  ddot(n)      n muls, all independent (N_HM = 0); n-1 adds. With a tree
               schedule the adds form ceil(log2 n) dependent levels; with the
               naive sequential accumulation every add depends on the previous
               one (N_HA = n-2 back-to-back dependences). Both schedules are
               exposed - the schedule is exactly the knob the TPU adaptation
               turns (accumulator count U interpolates between them).
  dgemv(m,n)   m inner products of length n.
  dgemm(m,n,k) m*n inner products of length k; the paper notes compiler
               optimizations (register blocking / unrolling) reduce the
               dependency hazards -> we model an unroll factor ``u`` that
               divides the add-chain hazards.
  dgeqrf(n)    Householder QR: ~4/3 n^3 mul+add (GEMM-dominated trailing
               update), O(n^2) div, O(n) sqrt on the critical panel path; the
               sqrt/div streams are serial (hazard ratio ~ 1).
  dgetrf(n)    LU with partial pivoting: ~1/3 n^3 muls and adds, n(n-1)/2 divs
               (column scaling, serial per column step), no sqrt.
  dpotrf(n)    Cholesky: ~1/6 n^3 mul+add, n(n+1)/2 div, n sqrt, serial
               sqrt/div chain (every step waits on the diagonal sqrt).

These feed (a) the optimum-pipeline-depth solver (eq. 7), (b) the PE
instruction-stream compilers in :mod:`repro.core.isa` (which realize the same
DAGs literally, so the symbolic counts are testable against the enumerated
streams), and (c) the TPU codesign layer.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

from repro import arch as _arch
from repro.arch import FPUSpec
from repro.core.pipeline_model import OP_CLASSES, PipeParams, p_opt, p_opt_int

# Default technology constants (relative units) = the "paper-pe" machine's
# FPUSpec.  t_p is the latch-free logic delay of each unit; double-precision
# div/sqrt logic is much deeper than mul/add (iterative units); t_o is
# per-stage latch overhead. Values follow the FO4-style ratios used by
# Hartstein-Puzak [19]. Every characterize_* function takes ``fpu=`` (an
# :class:`repro.arch.FPUSpec`) to characterize against a different machine.
_PAPER_FPU = _arch.get("paper-pe").fpu
T_O = _PAPER_FPU.t_o            # latch overhead (FO4)
T_P = dict(_PAPER_FPU.t_p)


@dataclasses.dataclass(frozen=True)
class WorkloadProfile:
    """Per-op-class (N_iI, N_iH, gamma_i) census of one routine instance."""

    name: str
    pipes: Dict[str, PipeParams]
    flops: float                      # useful FLOPs of the routine
    critical_path: float              # dependent-op chain length (for info)

    def optimal_depths(self, p_min: int = 1, p_max: int = 64) -> Dict[str, int]:
        """Integer optimal depth per pipe via direct eq.-2 evaluation."""
        out = {}
        for k, pp in self.pipes.items():
            if pp.n_i <= 0:
                continue
            out[k] = p_opt_int(pp, p_min=p_min, p_max=p_max)
        return out

    def popt_closed_form(self) -> Dict[str, float]:
        """Closed-form eq.-7 optimum per pipe (inf where hazard-free)."""
        return {
            k: float(
                p_opt(n_i=pp.n_i, n_h=pp.n_h, gamma=pp.gamma, t_p=pp.t_p, t_o=pp.t_o)
            )
            for k, pp in self.pipes.items()
            if pp.n_i > 0
        }

    def hazard_ratios(self) -> Dict[str, float]:
        return {
            k: (pp.n_h / pp.n_i if pp.n_i else 0.0) for k, pp in self.pipes.items()
        }


def _pipes(nm=0, hm=0, na=0, ha=0, nd=0, hd=0, ns=0, hs=0, gamma=0.5,
           fpu: FPUSpec = None) -> Dict[str, PipeParams]:
    """Per-class PipeParams at a census; ``fpu`` supplies the technology
    constants (t_p / t_o), defaulting to the paper-pe spec."""
    f = fpu if fpu is not None else _PAPER_FPU
    g = gamma if isinstance(gamma, dict) else {k: gamma for k in OP_CLASSES}
    return {
        k: PipeParams(n_i=n, n_h=h, gamma=g[k], t_p=f.t_p[k], t_o=f.t_o)
        for k, n, h in (("mul", nm, hm), ("add", na, ha),
                        ("div", nd, hd), ("sqrt", ns, hs))
    }


# ---------------------------------------------------------------------------
# BLAS level 1-3 (paper section 4.1)
# ---------------------------------------------------------------------------

def characterize_ddot(n: int, schedule: str = "tree", accumulators: int = 1,
                      fpu: FPUSpec = None) -> WorkloadProfile:
    """Inner product of two n-vectors (paper fig. 5).

    muls: n, all independent -> N_HM = 0 ("considering only dependency
    hazards, there will be no hazards in the multiplier pipeline").
    adds: n-1.  ``schedule``:
      * 'tree'       - balanced reduction: hazards only along the ceil(log2 n)
                       levels whose operands are produced by the level below.
      * 'sequential' - single running sum: every add waits on the previous one.
      * 'strided'    - ``accumulators`` parallel partial sums (the TPU/codesign
                       schedule): the serial chain shrinks by the accumulator
                       count; a final tree of size U combines the partials.
    """
    if n < 2:
        raise ValueError("n >= 2 required")
    n_mul, n_add = n, n - 1
    if schedule == "tree":
        # at each tree level every add consumes results of the previous level;
        # the *stall-relevant* dependences are one per level transition per op
        # stream position -> hazards ~= number of adds whose operands were
        # produced fewer than `depth` issue slots earlier. For the in-order
        # scalar PE this is the adds of all levels above the first.
        h_add = max(n_add - _ceil_div(n, 2), 0)          # adds not in level 0
        crit = math.ceil(math.log2(n)) + 1               # mul + add tree
    elif schedule == "sequential":
        h_add = max(n_add - 1, 0)
        crit = 1 + n_add
    elif schedule == "strided":
        u = max(int(accumulators), 1)
        per_chain = _ceil_div(n, u) - 1                   # adds per partial sum
        h_add = max(u * max(per_chain - 1, 0), 0) + max(u - 1, 0)
        crit = 1 + per_chain + math.ceil(math.log2(max(u, 2)))
    else:
        raise ValueError(f"unknown schedule {schedule!r}")
    pipes = _pipes(nm=n_mul, hm=0, na=n_add, ha=h_add, fpu=fpu)
    return WorkloadProfile("ddot", pipes, flops=2.0 * n - 1, critical_path=crit)


def characterize_dgemv(m: int, n: int, schedule: str = "tree", accumulators: int = 1,
                       fpu: FPUSpec = None) -> WorkloadProfile:
    """y = A x, A m-by-n: m independent inner products of length n.

    Independent rows interleave freely, so the *effective* hazard count per
    row is divided by the number of rows that fit in the issue window; the
    paper models this as the compiler-driven hazard reduction. We keep the
    conservative per-row census and expose interleaving via `accumulators`.
    """
    row = characterize_ddot(n, schedule=schedule, accumulators=accumulators,
                            fpu=fpu)
    pipes = {
        k: dataclasses.replace(pp, n_i=pp.n_i * m, n_h=pp.n_h * m)
        for k, pp in row.pipes.items()
    }
    return WorkloadProfile("dgemv", pipes, flops=m * (2.0 * n - 1), critical_path=row.critical_path)


def characterize_dgemm(m: int, n: int, k: int, unroll: int = 4,
                       fpu: FPUSpec = None) -> WorkloadProfile:
    """C = A B: m*n inner products of length k (paper eq. 10).

    "due to compiler optimizations the dependency hazards reduce" [23]: with
    register blocking of ``unroll`` independent C elements in flight, only a
    1/unroll fraction of the add-chain dependences can stall the adder pipe.
    """
    n_mul = m * n * k
    n_add = m * n * (k - 1)
    base_h = m * n * max(k - 2, 0)          # sequential chains per C element
    h_add = base_h / max(unroll, 1)
    pipes = _pipes(nm=n_mul, hm=0, na=n_add, ha=h_add, fpu=fpu)
    return WorkloadProfile("dgemm", pipes, flops=2.0 * m * n * k, critical_path=1 + (k - 1))


# ---------------------------------------------------------------------------
# LAPACK (paper section 4.2)
# ---------------------------------------------------------------------------

def characterize_dgeqrf(n: int, unroll: int = 4,
                        fpu: FPUSpec = None) -> WorkloadProfile:
    """Householder QR of an n-by-n matrix (DGEQRF).

    Counts (standard, e.g. Golub & Van Loan):
      mul/add ~ 4/3 n^3 (dominated by trailing-matrix GEMM updates),
      div ~ n^2/2 (vector scaling per panel column), sqrt ~ 2n (column norm +
      Householder beta per column).  The panel path is serial: every column's
      sqrt depends on the norm reduction, every scale div depends on the sqrt
      -> hazard ratio ~1 for sqrt and high for div (paper: "There is always
      dependency in the square root operation that stalls the program
      execution. The ratios N_HD/N_ID and N_HS/N_IS are observed to be high").
    """
    nf = float(n)
    n_mul = (4.0 / 3.0) * nf**3
    n_add = (4.0 / 3.0) * nf**3
    n_div = nf * nf / 2.0
    n_sqrt = 2.0 * nf
    h_add = (n_mul - n_add / 2) / max(unroll, 1) * 0.5   # GEMM-like chains
    h_div = 0.8 * n_div                                   # panel-serial
    h_sqrt = max(n_sqrt - 1.0, 0.0)                       # fully serial
    pipes = _pipes(nm=n_mul, hm=0, na=n_add, ha=h_add, nd=n_div, hd=h_div,
                   ns=n_sqrt, hs=h_sqrt,
                   gamma=dict((fpu or _PAPER_FPU).gamma), fpu=fpu)
    return WorkloadProfile("dgeqrf", pipes, flops=(4.0 / 3.0) * nf**3,
                           critical_path=3.0 * nf)


def characterize_dgetrf(n: int, unroll: int = 4,
                        fpu: FPUSpec = None) -> WorkloadProfile:
    """LU with partial pivoting (DGETRF): ~n^3/3 mul+add, n(n-1)/2 serial divs.

    "the occurrence of division instruction in the program is similar to the
    square root/divider in the QR factorization" - same hazard structure for
    the divider, no sqrt pipe.
    """
    nf = float(n)
    n_mul = nf**3 / 3.0
    n_add = nf**3 / 3.0
    n_div = nf * (nf - 1) / 2.0
    h_add = n_add * 0.5 / max(unroll, 1)
    h_div = 0.8 * n_div
    pipes = _pipes(nm=n_mul, hm=0, na=n_add, ha=h_add, nd=n_div, hd=h_div,
                   gamma=dict((fpu or _PAPER_FPU).gamma), fpu=fpu)
    return WorkloadProfile("dgetrf", pipes, flops=(2.0 / 3.0) * nf**3,
                           critical_path=2.0 * nf)


def characterize_dpotrf(n: int, unroll: int = 4,
                        fpu: FPUSpec = None) -> WorkloadProfile:
    """Cholesky (DPOTRF): ~n^3/6 mul+add, n(n+1)/2 div, n serial sqrts."""
    nf = float(n)
    n_mul = nf**3 / 6.0
    n_add = nf**3 / 6.0
    n_div = nf * (nf + 1) / 2.0
    n_sqrt = nf
    pipes = _pipes(nm=n_mul, hm=0, na=n_add, ha=n_add * 0.5 / max(unroll, 1),
                   nd=n_div, hd=0.8 * n_div, ns=n_sqrt, hs=max(n_sqrt - 1, 0),
                   gamma=dict((fpu or _PAPER_FPU).gamma), fpu=fpu)
    return WorkloadProfile("dpotrf", pipes, flops=nf**3 / 3.0, critical_path=2.0 * nf)


ROUTINES = {
    "ddot": characterize_ddot,
    "dgemv": characterize_dgemv,
    "dgemm": characterize_dgemm,
    "dgeqrf": characterize_dgeqrf,
    "dgetrf": characterize_dgetrf,
    "dpotrf": characterize_dpotrf,
}


def characterization_table(n: int = 100) -> Dict[str, Dict[str, float]]:
    """The paper's section-4 summary: hazard ratios + optimal depths per routine."""
    profiles = {
        "ddot": characterize_ddot(n * n),
        "dgemv": characterize_dgemv(n, n),
        "dgemm": characterize_dgemm(n, n, n),
        "dgeqrf": characterize_dgeqrf(n),
        "dgetrf": characterize_dgetrf(n),
        "dpotrf": characterize_dpotrf(n),
    }
    table = {}
    for name, prof in profiles.items():
        row: Dict[str, float] = {}
        ratios = prof.hazard_ratios()
        depths = prof.optimal_depths()
        for k in OP_CLASSES:
            row[f"NH/NI_{k}"] = ratios.get(k, 0.0)
            row[f"popt_{k}"] = float(depths.get(k, float("nan")))
        table[name] = row
    return table


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)
