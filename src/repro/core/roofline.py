"""Three-term roofline analysis from AOT-compiled artifacts.

This container is CPU-only; TPU v5e is the *target*. The dry-run lowers and
compiles every (arch x shape x mesh) cell, and this module turns the compiled
artifact into the report the task requires:

    compute term    = HLO_FLOPs      / (chips * PEAK_BF16_FLOPS)
    memory term     = HLO_bytes      / (chips * HBM_BW)
    collective term = collective_bytes / (chips * ICI_BW)

``compiled.cost_analysis()`` provides FLOPs and bytes. Collective bytes are
not in cost_analysis, so :func:`collective_bytes` parses the optimized HLO
text and sums the operand sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute (async -start forms
included; -done forms skipped to avoid double counting).

Device-count semantics: on the forced-host-platform CPU backend,
``cost_analysis`` reports the *per-partition* program (SPMD - one module for
all devices), so FLOPs/bytes are per-chip already; the dry-run verifies this
with a 1-vs-4-device probe (see tests/test_roofline.py) and records the
outcome. Collective operand sizes parsed from the HLO are likewise the
per-participant shard sizes.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, Optional

from repro import arch as _arch
from repro.core.codesign import HBM_BW, ICI_BW, PEAK_BF16_FLOPS

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"\b(pred|bf16|f16|f32|f64|f8e4m3fn|f8e5m2|s8|s16|s32|s64|u8|u16|u32|u64)\[([\d,]*)\]")
_DTYPE_BYTES = {"pred": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
                "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "s16": 2, "s32": 4,
                "s64": 8, "u8": 1, "u16": 2, "u32": 4, "u64": 8}
# op-kind position in an HLO line: "%name = <shape> <kind>(<operands>)...";
# the result type may be a tuple with spaces (async -start forms), hence the
# lazy any-match. "-done" forms never match (no '(' right after the kind).
_OP_RE = re.compile(
    r"=\s+.*?\s(" + "|".join(_COLLECTIVES) + r")(-start)?\(")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _operand_region(line: str, open_idx: int) -> str:
    """Balanced-paren scan from ``open_idx`` (the op-kind's '(')."""
    depth = 0
    for j in range(open_idx, len(line)):
        c = line[j]
        if c == "(":
            depth += 1
        elif c == ")":
            depth -= 1
            if depth == 0:
                return line[open_idx + 1:j]
    return line[open_idx + 1:]


_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([^\s=]+)\s*=\s*(.*)$")
_KIND_RE = re.compile(r"\s([a-z][\w-]*)\(")
_NAME_RE = re.compile(r"%([^\s,()]+)")


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum of *operand* bytes per collective kind across the module.

    Optimized HLO prints operands by name only, so we build a per-computation
    symbol table (name -> result bytes) and resolve collective operands
    against it. Async ``-start`` forms are counted; ``-done`` forms skipped
    (they would double count).
    """
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    block = 0
    table: Dict[tuple, int] = {}
    pending = []                           # (kind, block, [operand names])
    for line in hlo_text.splitlines():
        stripped = line.rstrip()
        if stripped.endswith("{") and not line.startswith(" "):
            block += 1                     # new computation scope
        d = _DEF_RE.match(line)
        if not d:
            continue
        name, rest = d.group(1), d.group(2)
        km = _KIND_RE.search(" " + rest)
        # result-type segment = text before the op kind token
        seg = rest[: km.start() - 1] if km else rest
        table[(block, name)] = _shape_bytes(seg)
        m = _OP_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        region = _operand_region(line, m.end() - 1)  # m.end()-1 is the '('
        ops = _NAME_RE.findall(region)
        pending.append((kind, block, ops))
    for kind, blk, ops in pending:
        for op in ops:
            out[kind] += table.get((blk, op), 0)
    return out


@dataclasses.dataclass(frozen=True)
class Roofline:
    """One cell's roofline report (all terms in seconds per step)."""

    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float               # per chip
    hlo_bytes: float               # per chip
    coll_bytes: float              # per chip (sum over collectives)
    coll_breakdown: Dict[str, int]
    model_flops: float             # 6*N*D (train) or 2*N_active*tokens (serve), global
    bytes_per_device: float        # from memory_analysis (peak temp + args)
    extra: Dict[str, float] = dataclasses.field(default_factory=dict)
    machine: Optional[str] = None  # registered machine name (None = default)

    def machine_spec(self):
        """The :class:`repro.arch.MachineSpec` this report prices against.

        A machine name not registered in this process (e.g. a report
        written by a process that registered a custom spec) degrades to
        the default machine instead of raising - loaded reports must
        always be readable."""
        try:
            return _arch.get(self.machine or _arch.DEFAULT_MACHINE)
        except ValueError:
            return _arch.get(_arch.DEFAULT_MACHINE)

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / self.machine_spec().pe.peak_flops

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / self.machine_spec().memory.hbm_bw

    @property
    def collective_s(self) -> float:
        return self.coll_bytes / self.machine_spec().memory.ici_bw

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Max-term bound (perfect overlap of the other two)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flop_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (global): < 1 means remat/redundant work,
        > 1 means the compiler did *less* than the naive count (e.g. fused
        away or the model count overestimates)."""
        total_hlo = self.hlo_flops * self.chips
        return self.model_flops / total_hlo if total_hlo else float("nan")

    @property
    def roofline_fraction(self) -> float:
        """Achievable MFU bound at this schedule: useful flops / (chips *
        peak * step_time). This is the score-bearing number."""
        t = self.step_time_s
        if t <= 0:
            return 0.0
        return self.model_flops / (
            self.chips * self.machine_spec().pe.peak_flops * t)

    @property
    def modeled_gflops_per_w(self) -> float:
        """The paper's energy score at this schedule: per-chip useful
        Gflop/s over the machine's modeled power (FLOP + HBM energy +
        static) - comparable across registered machines."""
        t = self.step_time_s
        if t <= 0:
            return 0.0
        gflops = self.model_flops / (self.chips * t) / 1e9
        return self.machine_spec().gflops_per_w(
            gflops, hbm_bytes_per_s=self.hlo_bytes / t)

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d.update(compute_s=self.compute_s, memory_s=self.memory_s,
                 collective_s=self.collective_s, dominant=self.dominant,
                 useful_flop_ratio=self.useful_flop_ratio,
                 roofline_fraction=self.roofline_fraction,
                 step_time_s=self.step_time_s,
                 machine=self.machine or _arch.DEFAULT_MACHINE,
                 gflops_per_w=self.modeled_gflops_per_w)
        return d


def from_compiled(arch: str, shape: str, mesh_name: str, chips: int,
                  compiled, model_flops: float,
                  extra: Optional[Dict[str, float]] = None,
                  trip_aware: bool = True,
                  machine: Optional[str] = None) -> Roofline:
    """Build a Roofline from a jax AOT ``compiled`` object.

    ``trip_aware=True`` derives flops/bytes/collectives from the
    trip-count-aware HLO walk (core.hlo_cost): XLA's cost_analysis counts
    while-loop bodies once, undercounting scanned models by ~n_layers
    (probe: tests/test_hlo_cost.py). The raw XLA numbers are kept in
    ``extra`` for reference.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, list):       # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = ""
    extra = dict(extra or {})
    if trip_aware and hlo:
        from repro.core import hlo_cost
        c = hlo_cost.analyze(hlo)
        extra["xla_flops"] = flops
        extra["xla_bytes"] = byts
        extra["bytes_unfused"] = c.bytes
        # memory term uses the TPU-fusion traffic model (dot/copy/cache/
        # collective boundaries; elementwise fuses into matmul epilogues)
        flops, byts = c.flops, c.bytes_fused
        coll = {k: int(v) for k, v in c.coll.items()}
        for k in _COLLECTIVES:
            coll.setdefault(k, 0)
    else:
        coll = collective_bytes(hlo)
    mem = compiled.memory_analysis()
    bytes_per_dev = float(
        getattr(mem, "argument_size_in_bytes", 0)
        + getattr(mem, "output_size_in_bytes", 0)
        - getattr(mem, "alias_size_in_bytes", 0)
        + getattr(mem, "temp_size_in_bytes", 0))
    return Roofline(arch=arch, shape=shape, mesh=mesh_name, chips=chips,
                    hlo_flops=flops, hlo_bytes=byts,
                    coll_bytes=float(sum(coll.values())),
                    coll_breakdown=coll, model_flops=model_flops,
                    bytes_per_device=bytes_per_dev, extra=extra,
                    machine=machine)


def advice(r: Roofline) -> str:
    """One sentence on what would move the dominant term down."""
    if r.dominant == "compute":
        if r.useful_flop_ratio < 0.6:
            return ("compute-bound with low useful-flop ratio "
                    f"({r.useful_flop_ratio:.2f}): cut remat recompute or "
                    "redundant einsum transposes before touching sharding.")
        return ("compute-bound near the useful-flop floor: only weaker remat, "
                "lower-precision matmuls, or more chips move this term.")
    if r.dominant == "memory":
        return ("HBM-bound: raise arithmetic intensity - larger fused blocks, "
                "bf16 (not fp32) residents, fewer activation round-trips "
                "(fuse norms/activations into the matmul epilogue).")
    return ("collective-bound: reshard to shrink the traffic (e.g. move the "
            "sharded axis so the big all-gather becomes a reduce-scatter of "
            "the small side), overlap collectives with per-layer compute, or "
            "quantize the gradient all-reduce.")


def save_json(path: str, rooflines) -> None:
    with open(path, "w") as f:
        json.dump([r.to_dict() for r in rooflines], f, indent=1)


def load_json(path: str):
    with open(path) as f:
        rows = json.load(f)
    out = []
    for d in rows:
        keep = {k: d[k] for k in ("arch", "shape", "mesh", "chips", "hlo_flops",
                                  "hlo_bytes", "coll_bytes", "coll_breakdown",
                                  "model_flops", "bytes_per_device", "extra")}
        keep["machine"] = d.get("machine")      # pre-arch files resolve too
        out.append(Roofline(**keep))
    return out
