"""repro.core - the paper's contribution.

Analytical pipeline-depth model (eqs 1-7), BLAS/LAPACK workload
characterization, the configurable-depth PE simulator, the synthesis model
(Tables 1-2), and the TPU codesign adaptation.
"""
from repro.core import characterization, codesign, isa, jaxpr_census, pe
from repro.core import pipeline_model, roofline, synthesis
from repro.core.characterization import (WorkloadProfile, characterize_ddot,
                                         characterize_dgemm,
                                         characterize_dgemv,
                                         characterize_dgeqrf,
                                         characterize_dgetrf,
                                         characterize_dpotrf)
from repro.core.codesign import (optimal_accumulators, plan_attention,
                                 plan_gemm, plan_ssd)
from repro.core.jaxpr_census import census_of
from repro.core.pipeline_model import PipeParams, p_opt, p_opt_int, tpi
from repro.core.roofline import Roofline, collective_bytes, from_compiled
