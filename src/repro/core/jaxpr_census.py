"""Op-class census of arbitrary jitted functions - the paper's section 4,
made mechanical.

The paper characterizes ddot/dgemv/dgemm/DGEQRF/DGETRF by hand-counting the
instructions and dependency hazards per floating-point class {mul, add, sqrt,
div}. For the model zoo we cannot hand-count 94-layer MoE training steps, so
this module derives the same parameters from the *jaxpr* of any function:

  * ``N_iI``  - elementwise op counts per class (dot_general/conv unrolled
    into their mul+add volumes, reductions into adds),
  * ``N_iH``  - a program-order dependence proxy: elements of an operand
    produced by the *immediately preceding* equation stall an in-order pipe
    (back-to-back dependence), plus loop-carried scan dependences which are
    serial by construction,
  * ``gamma_i`` - exposure fractions, defaulted per class from the paper's
    section-4 fits (mul 0.5 / add 0.5 / div 0.8 / sqrt 0.9) since jaxprs
    carry no timing,
  * critical path - longest equation chain (unit weight), the DAG depth the
    paper reads off fig. 5.

The census converts to a :class:`repro.core.characterization.WorkloadProfile`
so the whole paper pipeline (eq. 7 depths, codesign knobs) applies to every
architecture in the zoo. Transcendentals (exp/tanh/erf/log), which BLAS and
LAPACK lack but softmax/GeLU introduce, are counted in an ``exp`` class and
mapped onto the paper's divider pipe (iterative, long-latency unit) - an
extension recorded in DESIGN.md.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict

import jax
import numpy as np
from jax.extend import core as jcore

from repro.core.characterization import T_O, T_P, WorkloadProfile
from repro.core.pipeline_model import PipeParams

CLASSES = ("mul", "add", "div", "sqrt", "exp")
DEFAULT_GAMMA = {"mul": 0.5, "add": 0.5, "div": 0.8, "sqrt": 0.9, "exp": 0.8}

_ELEMWISE = {
    "mul": "mul",
    "add": "add", "sub": "add", "max": "add", "min": "add", "neg": "add",
    "add_any": "add",
    "div": "div", "rem": "div",
    "sqrt": "sqrt", "rsqrt": "sqrt",
    "exp": "exp", "log": "exp", "tanh": "exp", "logistic": "exp",
    "erf": "exp", "exp2": "exp", "log1p": "exp", "expm1": "exp",
    "pow": "exp", "cos": "exp", "sin": "exp",
}
_REDUCES = {"reduce_sum": "add", "reduce_max": "add", "reduce_min": "add",
            "argmax": "add", "argmin": "add", "cumsum": "add",
            "cumlogsumexp": "exp", "reduce_prod": "mul", "cummax": "add"}


def _size(aval) -> float:
    try:
        return float(np.prod(aval.shape)) if aval.shape else 1.0
    except Exception:
        return 1.0


def _dot_general_flops(eqn) -> float:
    """mul count of a dot_general = prod(batch)*prod(lhs free)*prod(rhs free)*prod(contract)."""
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    batch = math.prod(lhs.shape[d] for d in lb) if lb else 1
    contract = math.prod(lhs.shape[d] for d in lc) if lc else 1
    lfree = math.prod(lhs.shape[d] for d in range(len(lhs.shape)) if d not in set(lc) | set(lb))
    rfree = math.prod(rhs.shape[d] for d in range(len(rhs.shape)) if d not in set(rc) | set(rb))
    return float(batch * lfree * rfree * contract)


@dataclasses.dataclass
class Census:
    """Accumulated per-class counts for one traced function."""

    name: str
    n_i: Dict[str, float]
    n_h: Dict[str, float]
    critical_path: float
    flops: float
    n_eqns: int

    def hazard_ratios(self) -> Dict[str, float]:
        return {k: (self.n_h[k] / self.n_i[k] if self.n_i[k] else 0.0)
                for k in CLASSES}

    def to_profile(self, gamma: Dict[str, float] | None = None) -> WorkloadProfile:
        """Fold the census into the paper's four-pipe parameter space
        (``exp`` rides the divider pipe: both are long-latency iterative)."""
        g = dict(DEFAULT_GAMMA, **(gamma or {}))
        ni = dict(self.n_i)
        nh = dict(self.n_h)
        ni["div"] = ni["div"] + ni.pop("exp")
        nh["div"] = nh["div"] + nh.pop("exp")
        pipes = {
            k: PipeParams(n_i=ni[k], n_h=nh[k], gamma=g[k], t_p=T_P[k], t_o=T_O)
            for k in ("mul", "add", "div", "sqrt")
        }
        return WorkloadProfile(self.name, pipes, flops=self.flops,
                               critical_path=self.critical_path)


def _walk(jaxpr, acc: Census, mult: float, depth_in: Dict[Any, float]) -> float:
    """Accumulate counts over one (sub)jaxpr; returns the jaxpr's DAG depth."""
    depth: Dict[Any, float] = dict(depth_in)

    def var_depth(v) -> float:
        if isinstance(v, jcore.Literal):
            return 0.0
        return depth.get(v, 0.0)

    prev_outs: set = set()
    max_depth = 0.0
    for eqn in jaxpr.eqns:
        pname = eqn.primitive.name
        out_sz = sum(_size(ov.aval) for ov in eqn.outvars)
        in_depth = max([var_depth(v) for v in eqn.invars], default=0.0)
        cls = None
        count = 0.0
        if pname == "dot_general":
            muls = _dot_general_flops(eqn) * mult
            acc.n_i["mul"] += muls
            acc.n_i["add"] += muls          # one accumulate per product
            acc.flops += 2 * muls
            # MXU-style: the k-reduction is a hardware tree; residual hazards
            # are per output element (one chain join each).
            acc.n_h["add"] += sum(_size(ov.aval) for ov in eqn.outvars) * mult
            cls = "mul"
        elif pname in ("conv_general_dilated",):
            # treat like a dot over the patch volume
            out = eqn.outvars[0].aval
            rhs = eqn.invars[1].aval
            patch = math.prod(rhs.shape[:-1]) if rhs.shape else 1
            muls = _size(out) * patch * mult
            acc.n_i["mul"] += muls
            acc.n_i["add"] += muls
            acc.flops += 2 * muls
            cls = "mul"
        elif pname in _ELEMWISE:
            cls = _ELEMWISE[pname]
            count = out_sz * mult
            acc.n_i[cls] += count
            acc.flops += count
        elif pname in _REDUCES:
            cls = _REDUCES[pname]
            in_sz = _size(eqn.invars[0].aval)
            count = max(in_sz - out_sz, out_sz) * mult
            acc.n_i[cls] += count
            acc.flops += count
            # a reduction is a dependence tree: log2(fan-in) serial levels.
            fan = max(in_sz / max(out_sz, 1.0), 2.0)
            acc.n_h[cls] += out_sz * math.log2(fan) * mult
        elif pname == "integer_pow":
            cls = "mul"
            count = out_sz * mult * max(abs(eqn.params.get("y", 2)) - 1, 1)
            acc.n_i[cls] += count
            acc.flops += count
        elif pname in ("scan", "while"):
            inner = eqn.params.get("jaxpr")
            length = eqn.params.get("length", 1) if pname == "scan" else 8
            if inner is not None:
                sub = inner.jaxpr if hasattr(inner, "jaxpr") else inner
                body_depth = _walk(sub, acc, mult * length, {})
                # loop-carried dependences are serial across iterations:
                n_carry = eqn.params.get("num_carry", 0)
                carry_sz = sum(_size(v.aval) for v in eqn.invars[:n_carry]) if n_carry else 0.0
                acc.n_h["add"] += carry_sz * max(length - 1, 0) * mult
                in_depth += body_depth * length
        elif pname in ("pjit", "custom_jvp_call", "custom_vjp_call",
                       "custom_vjp_call_jaxpr", "remat", "remat2",
                       "checkpoint", "closed_call", "core_call",
                       "custom_partitioning"):
            inner = (eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
                     or eqn.params.get("fun_jaxpr"))
            if inner is not None:
                sub = inner.jaxpr if hasattr(inner, "jaxpr") else inner
                in_depth += _walk(sub, acc, mult, {})
        elif pname == "cond":
            branches = eqn.params.get("branches", ())
            if branches:
                ds = [_walk(b.jaxpr if hasattr(b, "jaxpr") else b, acc,
                            mult / len(branches), {}) for b in branches]
                in_depth += max(ds, default=0.0)
        # back-to-back dependence proxy: operand produced by previous eqn.
        if cls is not None:
            if any((not isinstance(v, jcore.Literal)) and v in prev_outs
                   for v in eqn.invars):
                acc.n_h[cls] += min(out_sz, 1.0) * mult if count == 0 else count
        d = in_depth + 1.0
        for ov in eqn.outvars:
            depth[ov] = d
        max_depth = max(max_depth, d)
        prev_outs = set(ov for ov in eqn.outvars)
        acc.n_eqns += 1
    return max_depth


def census_of(fn: Callable, *args, name: str | None = None, **kwargs) -> Census:
    """Trace ``fn`` (abstractly - ShapeDtypeStructs fine) and census it."""
    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    acc = Census(name or getattr(fn, "__name__", "fn"),
                 {k: 0.0 for k in CLASSES}, {k: 0.0 for k in CLASSES},
                 0.0, 0.0, 0)
    acc.critical_path = _walk(closed.jaxpr, acc, 1.0, {})
    # hazards can't exceed instructions in any class
    for k in CLASSES:
        acc.n_h[k] = min(acc.n_h[k], acc.n_i[k])
    return acc


def report(census: Census) -> str:
    prof = census.to_profile()
    lines = [f"census[{census.name}]: eqns={census.n_eqns} flops={census.flops:.3e} "
             f"critical_path={census.critical_path:.0f}"]
    depths = prof.optimal_depths()
    for k in CLASSES:
        if census.n_i[k] <= 0:
            continue
        ratio = census.n_h[k] / census.n_i[k]
        pk = "div" if k == "exp" else k
        lines.append(f"  {k:>4}: N_I={census.n_i[k]:.3e} N_H/N_I={ratio:.4f} "
                     f"p_opt={depths.get(pk, float('nan'))}")
    return "\n".join(lines)
