"""Synthesis-level power/area model - reproduces the paper's Tables 1 and 2.

The paper synthesizes its enhanced PE (4 multipliers + 3 adders reconfigured
behind a DOT4 instruction, 16 KB dual-ported SRAM) and compares against the
LAP-PE of Pedram et al. [2][5][21] at four operating points. Table 1 gives
area and power; Table 2 derives GFlops/mm^2 and GFlops/W.

This module encodes the published operating points, *derives* Table 2 from
Table 1 (GFlops = flops-per-cycle x frequency; LAP-PE retires an FMAC = 2
flops/cycle, the PE retires a DOT4 = 7 flops/cycle), checks the derivation
against the published numbers, and fits a dynamic+leakage power model so the
comparison extends to any frequency:

    P(f) = c_dyn * f * V(f)^2 + P_leak,   V(f) = v0 + v1 * f   (DVFS line)

It also evaluates the abstract's headline claims (1.1-1.5x GFlops/W,
1.9-2.1x GFlops/mm^2); the actual Table-2 GFlops/W ratios span 0.95x-1.66x,
which EXPERIMENTS.md records as a paper-internal discrepancy.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

FLOPS_PER_CYCLE = {"lap-pe": 2.0, "pe": 7.0}   # FMAC vs DOT4


@dataclasses.dataclass(frozen=True)
class OperatingPoint:
    """One Table-1 row. Powers in mW, area in mm^2, speed in GHz."""

    design: str
    speed_ghz: float
    area_mm2: float
    mem_mw: float
    fmac_mw: float
    total_mw: float

    @property
    def gflops(self) -> float:
        return FLOPS_PER_CYCLE[self.design] * self.speed_ghz

    @property
    def gflops_per_mm2(self) -> float:
        return self.gflops / self.area_mm2

    @property
    def gflops_per_watt(self) -> float:
        return self.gflops / (self.total_mw * 1e-3)


# Paper Table 1 (16 KB dual-ported SRAM, double precision).
TABLE1: List[OperatingPoint] = [
    OperatingPoint("lap-pe", 1.81, 0.181, 13.25, 105.5, 118.7),
    OperatingPoint("lap-pe", 0.95, 0.174, 6.95, 31.0, 38.0),
    OperatingPoint("lap-pe", 0.33, 0.167, 2.41, 6.0, 8.4),
    OperatingPoint("lap-pe", 0.20, 0.169, 1.46, 3.4, 4.8),
    OperatingPoint("pe", 1.81, 0.301, 26.50, 422.0, 448.5),
    OperatingPoint("pe", 0.95, 0.280, 13.90, 124.0, 137.9),
    OperatingPoint("pe", 0.33, 0.273, 4.82, 24.0, 28.82),
    OperatingPoint("pe", 0.20, 0.275, 2.92, 13.6, 16.5),
]

# Paper Table 2 (published, for cross-checking the derivation).
TABLE2_PUBLISHED = {
    # speed: (lap_gflops_mm2, lap_gflops_w, pe_gflops_mm2, pe_gflops_w)
    1.81: (19.92, 29.7, 42.09, 28.24),
    0.95: (10.92, 46.4, 23.75, 48.54),
    0.33: (3.95, 57.8, 8.46, 82.5),
    0.20: (2.37, 51.1, 5.09, 84.84),
}


def derive_table2() -> Dict[float, Dict[str, float]]:
    """Table 2 derived from Table 1 + flops/cycle. Keys are speeds in GHz."""
    out: Dict[float, Dict[str, float]] = {}
    for op in TABLE1:
        row = out.setdefault(op.speed_ghz, {})
        row[f"{op.design}_gflops_mm2"] = op.gflops_per_mm2
        row[f"{op.design}_gflops_w"] = op.gflops_per_watt
    return out


def efficiency_ratios() -> Dict[str, Dict[float, float]]:
    """PE : LAP-PE ratios per operating point (the abstract's claims)."""
    t2 = derive_table2()
    area = {s: r["pe_gflops_mm2"] / r["lap-pe_gflops_mm2"] for s, r in t2.items()}
    watt = {s: r["pe_gflops_w"] / r["lap-pe_gflops_w"] for s, r in t2.items()}
    return {"gflops_per_mm2": area, "gflops_per_watt": watt}


@dataclasses.dataclass(frozen=True)
class PowerModel:
    """P(f) = c_dyn * f * (v0 + v1 f)^2 + p_leak, least-squares fit."""

    design: str
    c_dyn: float
    v0: float
    v1: float
    p_leak: float

    def power_mw(self, f_ghz: float) -> float:
        v = self.v0 + self.v1 * f_ghz
        return self.c_dyn * f_ghz * v * v + self.p_leak

    def gflops_per_watt(self, f_ghz: float) -> float:
        return FLOPS_PER_CYCLE[self.design] * f_ghz / (self.power_mw(f_ghz) * 1e-3)


def fit_power_model(design: str) -> PowerModel:
    """Fit the DVFS model to the design's Table-1 points.

    With the voltage line fixed to a typical 28nm DVFS range
    (0.6 V at idle-clock to ~1.0 V at max), c_dyn and p_leak are a linear
    least-squares fit - two parameters, four points.
    """
    pts = [p for p in TABLE1 if p.design == design]
    f = np.array([p.speed_ghz for p in pts])
    p_tot = np.array([p.total_mw for p in pts])
    fmax = f.max()
    v0, v1 = 0.6, 0.4 / fmax          # V(fmax) = 1.0
    basis = f * (v0 + v1 * f) ** 2
    A = np.stack([basis, np.ones_like(f)], axis=1)
    (c_dyn, p_leak), *_ = np.linalg.lstsq(A, p_tot, rcond=None)
    return PowerModel(design, float(c_dyn), v0, v1, float(max(p_leak, 0.0)))


def energy_per_flop_pj(design: str, f_ghz: float) -> float:
    """Model-predicted energy per double-precision flop in picojoules."""
    m = fit_power_model(design)
    watts = m.power_mw(f_ghz) * 1e-3
    flops_per_s = FLOPS_PER_CYCLE[design] * f_ghz * 1e9
    return watts / flops_per_s * 1e12


def check_table2(tol: float = 0.06) -> Dict[str, Dict[str, float]]:
    """Compare our derived Table 2 against the published one.

    Both GFlops/mm^2 columns and the PE GFlops/W column derive from Table 1
    exactly (within rounding; ``tol`` = 6%) and are *asserted*. The LAP-PE
    GFlops/W column below 0.95 GHz does **not** follow from the paper's own
    Table 1 (e.g. 2 x 0.33 GFlops / 8.4 mW = 78.6, published 57.8) - a
    paper-internal inconsistency, presumably power numbers taken from Pedram
    et al. directly. Those cells are returned under ``"discrepant"`` and
    recorded in EXPERIMENTS.md rather than force-fitted.
    """
    derived = derive_table2()
    checked: Dict[str, float] = {}
    discrepant: Dict[str, float] = {}
    for speed, (lm, lw, pm, pw) in TABLE2_PUBLISHED.items():
        d = derived[speed]
        checked[f"lap_mm2@{speed}"] = abs(d["lap-pe_gflops_mm2"] - lm) / lm
        checked[f"pe_mm2@{speed}"] = abs(d["pe_gflops_mm2"] - pm) / pm
        checked[f"pe_w@{speed}"] = abs(d["pe_gflops_w"] - pw) / pw
        lap_w_err = abs(d["lap-pe_gflops_w"] - lw) / lw
        (checked if lap_w_err <= tol else discrepant)[f"lap_w@{speed}"] = lap_w_err
    worst = max(checked.values())
    if worst > tol:
        bad = {k: v for k, v in checked.items() if v > tol}
        raise AssertionError(f"Table 2 derivation off beyond {tol:.0%}: {bad}")
    return {"checked": checked, "discrepant": discrepant}
