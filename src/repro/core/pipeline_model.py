"""The paper's analytical pipeline model (section 3, equations 1-7).

Implements the Hartstein-Puzak-derived time-per-instruction (TPI) model the
paper builds on, extended per the paper to one pipe per floating-point
operation class (multiply / add / sqrt / divide), and the closed-form optimal
pipeline depth.

Notation (paper eq. 2):

    T / N_I = (t_o + gamma * N_H * t_p / N_I)      # depth-independent
            + (t_p / p)                            # ~ 1/p  (busy time)
            + (gamma * N_H * t_o * p / N_I)        # ~ p    (hazard penalty)

    p_opt^2 = N_I * t_p / (gamma * N_H * t_o)      # eq. 3

All functions are pure jnp and differentiable, so curves (figures 2-4, 6-8,
10) are produced by vmapping over parameter grids, and p_opt can also be
recovered by autodiff as a cross-check (see tests).
"""
from __future__ import annotations

import dataclasses
from typing import Mapping

import jax
import jax.numpy as jnp

# The paper's four floating-point instruction classes, K = {M, A, S, D}.
OP_CLASSES = ("mul", "add", "sqrt", "div")


@dataclasses.dataclass(frozen=True)
class PipeParams:
    """Parameters of one pipe (one FP operation class) in the model.

    Attributes:
      n_i:   N_iI, number of instructions issued to this pipe.
      n_h:   N_iH, number of (dependency) hazards seen by this pipe.
      gamma: mean fraction of the total pipe delay exposed per hazard
             (paper: gamma = (1/N_H) * sum beta_h).
      t_p:   total latch-free logic delay of the unit (seconds or FO4s --
             the model only needs t_p/t_o consistent).
      t_o:   per-stage latch overhead for the technology node.
    """

    n_i: float
    n_h: float
    gamma: float
    t_p: float = 1.0
    t_o: float = 0.05

    def replace(self, **kw) -> "PipeParams":
        return dataclasses.replace(self, **kw)


def tpi(p, *, n_i, n_h, gamma, t_p=1.0, t_o=0.05):
    """Time-per-instruction of one pipe at depth ``p`` (paper eq. 2).

    Vectorized: every argument may be an array; standard broadcasting applies.
    ``n_h == 0`` (the paper's ddot multiplier pipe, gamma -> inf irrelevant)
    degrades gracefully to the hazard-free ``t_o + t_p / p`` curve.
    """
    p = jnp.asarray(p, dtype=jnp.float64 if jax.config.jax_enable_x64 else jnp.float32)
    hazard_rate = jnp.where(n_i > 0, n_h / jnp.maximum(n_i, 1), 0.0)
    fixed = t_o + gamma * hazard_rate * t_p
    busy = t_p / p
    penalty = gamma * hazard_rate * t_o * p
    return fixed + busy + penalty


def tpi_pipe(p, params: PipeParams):
    return tpi(p, n_i=params.n_i, n_h=params.n_h, gamma=params.gamma,
               t_p=params.t_p, t_o=params.t_o)


def total_time(p, params: PipeParams):
    """Total pipe time T = TPI * N_I (paper eq. 1 split into busy/non-busy)."""
    return tpi_pipe(p, params) * params.n_i


def p_opt(*, n_i, n_h, gamma, t_p=1.0, t_o=0.05):
    """Closed-form optimal pipeline depth (paper eq. 3 / eq. 7).

    p_opt^2 = N_I * t_p / (gamma * N_H * t_o).

    For hazard-free streams (N_H == 0) the model's optimum is unbounded; we
    return +inf there (the paper: "for multiplier, [the] theoretical curve
    ... becomes a flat horizontal line as we increase the pipeline depth").
    """
    n_h = jnp.asarray(n_h, dtype=jnp.float32)
    denom = gamma * n_h * t_o
    return jnp.where(denom > 0, jnp.sqrt(jnp.asarray(n_i, jnp.float32) * t_p / jnp.maximum(denom, 1e-30)), jnp.inf)


def p_opt_pipe(params: PipeParams):
    return p_opt(n_i=params.n_i, n_h=params.n_h, gamma=params.gamma,
                 t_p=params.t_p, t_o=params.t_o)


def p_opt_int(params: PipeParams, p_min: int = 1, p_max: int = 64) -> int:
    """Best integer depth in [p_min, p_max] by direct evaluation of eq. 2.

    The paper notes the curve is 'fairly flat around optimum'; for hardware
    you need an integer, and for hazard-free pipes the deepest allowed depth
    is returned (monotone improvement).
    """
    grid = jnp.arange(p_min, p_max + 1)
    vals = tpi_pipe(grid, params)
    return int(grid[int(jnp.argmin(vals))])


def tpi_multi(depths: Mapping[str, float], pipes: Mapping[str, PipeParams]):
    """Aggregate TPI over the four-pipe model (paper eq. 6).

    TPI = sum_i T_i / N_I  with T_i the pipe-i total time. (The paper writes
    sum_i T_i/N_iI; summing pipe times against the global instruction count
    gives the machine-level time per instruction, which is what figures 12-13
    plot as CPI once divided by the cycle time. We expose both.)
    """
    n_total = sum(float(p.n_i) for p in pipes.values())
    total = 0.0
    for name, pp in pipes.items():
        if pp.n_i <= 0:
            continue
        total = total + total_time(depths[name], pp)
    return total / max(n_total, 1.0)


def throughput(depths: Mapping[str, float], pipes: Mapping[str, PipeParams]):
    """Stall-free throughput G = sum_i 1/T_i of the k-pipe machine ([10])."""
    g = 0.0
    for name, pp in pipes.items():
        stage_time = pp.t_p / depths[name] + pp.t_o
        g = g + 1.0 / stage_time
    return g


# ---------------------------------------------------------------------------
# Figure generators (used by benchmarks + tests; each returns plain arrays)
# ---------------------------------------------------------------------------

def figure2_curves(p_values=(2, 4, 6, 8),
                   hazard_ratios=(0.1, 0.01, 0.001),
                   n_i_grid=None):
    """Fig. 2 - TPI vs workload size for fixed depths/hazard ratios.

    Returns dict[(p, ratio)] -> (n_i_grid, tpi array). TPI saturates with
    workload size; deeper pipes saturate to lower TPI (higher frequency).
    """
    if n_i_grid is None:
        n_i_grid = jnp.logspace(2, 7, 64)
    out = {}
    for p in p_values:
        for r in hazard_ratios:
            out[(p, r)] = (n_i_grid, tpi(p, n_i=n_i_grid, n_h=r * n_i_grid, gamma=0.5))
    return out


def figure3_curves(hazard_ratios=(0.1, 0.01, 0.001, 0.2, 0.4, 0.6, 0.8),
                   p_grid=None, n_i=1e6):
    """Fig. 3 - TPI vs pipeline depth for varying hazard ratios."""
    if p_grid is None:
        p_grid = jnp.arange(1, 41)
    return {r: (p_grid, tpi(p_grid, n_i=n_i, n_h=r * n_i, gamma=0.5))
            for r in hazard_ratios}


def figure4_curves(gammas=(0.1, 0.2, 0.4, 0.6, 0.8), p_grid=None,
                   n_i=1e6, hazard_ratio=0.01):
    """Fig. 4 - TPI vs pipeline depth for varying gamma."""
    if p_grid is None:
        p_grid = jnp.arange(1, 41)
    return {g: (p_grid, tpi(p_grid, n_i=n_i, n_h=hazard_ratio * n_i, gamma=g))
            for g in gammas}
