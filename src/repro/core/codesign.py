"""TPU adaptation of the paper's codesign methodology.

The paper picks RTL pipeline register counts from a workload model. On TPU
the hardware pipelines are fixed, but the *same equation* governs three
software-visible micro-architectural knobs, and this module sets them
analytically (DESIGN.md section 2 maps each one):

1. **Accumulator count U** for reduction loops. A dependent FP-add chain on
   the VPU exposes the add latency L exactly like an under-filled pipeline;
   U parallel partial sums fill the latency window like p pipeline slots.
   The cost of a length-n reduction with U accumulators is

       t(U) = n * max(1, L/U) + L * ceil(log2 U) + c_o * U

   (steady-state issue, final combine tree, bookkeeping/register overhead) -
   eq. 2's three terms with p -> U, t_p -> L, t_o -> c_o; the unconstrained
   minimum sits at U ~ L, the paper's p_opt once hazards saturate.

2. **Pallas block shapes.** The HBM->VMEM grid pipeline is a software
   pipeline: its "depth" is the grid length, its "latch overhead" the
   per-step DMA setup. Fig. 2's saturation (small workloads never amortize
   pipeline fill) becomes: choose blocks so the grid has enough steps to
   reach steady state, subject to VMEM capacity and MXU alignment.

3. **Collective schedule depth** (number of microbatch chunks overlapping
   compute with reduce-scatter) - same fill/overhead trade-off; used by
   train/grad.py.

Every planner takes ``machine=`` (a :class:`repro.arch.MachineSpec`;
``None`` = the ambient :func:`repro.arch.current_machine`, default
``"tpu-like"``), so the whole codesign layer is parameterized by a
swappable machine instead of import-time globals. The module-level
constants below are the ``"tpu-like"`` spec's values - kept so existing
callers and the default-machine planner outputs stay bit-identical.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

from repro import arch as _arch
from repro.arch import MachineSpec

# ------------------- TPU v5e constants (= the "tpu-like" spec) --------------
_TPU = _arch.get(_arch.DEFAULT_MACHINE)
PEAK_BF16_FLOPS = _TPU.pe.peak_flops      # per chip
HBM_BW = _TPU.memory.hbm_bw               # bytes/s per chip
ICI_BW = _TPU.memory.ici_bw               # bytes/s per link (task constants)
VMEM_BYTES = _TPU.memory.vmem_bytes       # usable VMEM budget we plan against
MXU = _TPU.pe.mxu                         # systolic array edge
SUBLANE = _TPU.pe.sublane                 # VPU sublanes (fp32)
LANE = _TPU.pe.lane                       # VPU lanes
VPU_ADD_LATENCY = _TPU.fpu.add_latency    # cycles, dependent-add chain
VREG_BUDGET = _TPU.pe.vreg_budget         # architectural vector registers
ACC_OVERHEAD = _TPU.fpu.acc_overhead      # c_o: issue slots of bookkeeping
                                          # per extra accumulator
PIPELINE_FILL_S = _TPU.memory.pipeline_fill_s   # per grid-step fill (fig. 2)


# every planner resolves machine= through the one shared arch helper
_machine = _arch.resolve_machine


def resolve_dtype_bytes(dtype=None, dtype_bytes: Optional[int] = None,
                        machine: Optional[MachineSpec] = None) -> int:
    """The one shared dtype-width default for every planner.

    Precedence: an explicit ``dtype`` (itemsize), then an explicit
    ``dtype_bytes``, then the machine's native compute dtype (bfloat16 ->
    2 for ``"tpu-like"``, float64 -> 8 for ``"paper-pe"``). This replaces
    the historical per-planner defaults (``plan_gemm`` assumed 2 while
    ``plan_factorization``/``plan_trsm`` assumed 4).
    """
    if dtype is not None:
        import numpy as np
        try:
            return int(np.dtype(dtype).itemsize)
        except TypeError:
            import jax.numpy as jnp
            return int(jnp.dtype(dtype).itemsize)
    if dtype_bytes is not None:
        return int(dtype_bytes)
    return _machine(machine).dtype_bytes()


def reduction_cost(n: float, u: int, latency: Optional[float] = None,
                   overhead: Optional[float] = None,
                   machine: Optional[MachineSpec] = None) -> float:
    """Issue-slot cost of reducing n elements with u parallel accumulators.

    ``latency``/``overhead`` default to the machine's dependent-add chain
    latency and accumulator bookkeeping cost.
    """
    m = _machine(machine)
    latency = m.fpu.add_latency if latency is None else latency
    overhead = m.fpu.acc_overhead if overhead is None else overhead
    u = max(1, int(u))
    steady = n * max(1.0, latency / u)
    combine = latency * math.ceil(math.log2(u)) if u > 1 else 0.0
    return steady + combine + overhead * u


def optimal_accumulators(n: float, latency: Optional[float] = None,
                         overhead: Optional[float] = None,
                         max_u: Optional[int] = None,
                         power_of_two: bool = True,
                         machine: Optional[MachineSpec] = None) -> int:
    """U minimizing :func:`reduction_cost` - the eq.-3 analogue on TPU.

    For large n the optimum is U ~ latency (fill the add pipe); for tiny n
    the combine tree + overhead terms pull it back - same shape as the
    paper's fig. 3 curves. Defaults (latency, overhead, register budget)
    come from ``machine``.
    """
    m = _machine(machine)
    latency = m.fpu.add_latency if latency is None else latency
    overhead = m.fpu.acc_overhead if overhead is None else overhead
    max_u = m.pe.vreg_budget // 2 if max_u is None else max_u
    candidates = range(1, max_u + 1)
    if power_of_two:
        candidates = [1 << k for k in range(0, max_u.bit_length()) if (1 << k) <= max_u]
    best = min(candidates, key=lambda u: reduction_cost(n, u, latency, overhead))
    return int(best)


def _acc_bytes(dtype_bytes: int) -> int:
    """Bytes/elem of the kernel's VMEM accumulator: per-precision, matching
    kernels.gemm.accumulator_dtype (f64 operands -> f64 accumulator, all
    narrower dtypes -> f32)."""
    return 8 if dtype_bytes >= 8 else 4


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _round_down_pow2(x: int) -> int:
    return 1 << max(x.bit_length() - 1, 0)


@dataclasses.dataclass(frozen=True)
class GemmPlan:
    """Pallas GEMM tiling picked by the model.

    ``ridge`` is the machine's compute/memory roofline knee
    (peak_flops / hbm_bw); it defaults to the "tpu-like" value so plans
    built without a machine keep the historical semantics.
    """

    bm: int
    bn: int
    bk: int
    accumulators: int             # U for the k-loop partials
    grid: Tuple[int, int, int]
    vmem_bytes: int
    arithmetic_intensity: float   # flops / HBM byte at this tiling
    ridge: float = PEAK_BF16_FLOPS / HBM_BW

    @property
    def compute_bound(self) -> bool:
        return self.arithmetic_intensity >= self.ridge


def plan_gemm(m: int, n: int, k: int, dtype_bytes: Optional[int] = None,
              vmem_budget: Optional[int] = None,
              min_grid_steps: int = 4, dtype=None,
              machine: Optional[MachineSpec] = None) -> GemmPlan:
    """Choose (bm, bn, bk) for C[m,n] += A[m,k] B[k,n] on the MXU.

    Policy (each clause is one paper concept):
      * MXU alignment: all block dims multiples of the machine's systolic
        edge (clamped to the padded problem) - full-tile occupancy.
      * VMEM capacity: A-, B-blocks double-buffered + fp32 accumulator block
        must fit the budget - the RF/LM capacity constraint of the PE/APE.
      * Grid length >= min_grid_steps so the HBM->VMEM software pipeline
        reaches steady state (fig. 2 saturation).
      * Maximize bm*bn (arithmetic intensity ~ harmonic mean of block dims),
        then bk.

    ``dtype`` overrides ``dtype_bytes``; both default to the machine's
    native dtype (:func:`resolve_dtype_bytes`). ``machine`` parameterizes
    the alignment, capacity, and roofline terms.
    """
    mach = _machine(machine)
    dtype_bytes = resolve_dtype_bytes(dtype, dtype_bytes, mach)
    vmem_budget = mach.memory.vmem_bytes if vmem_budget is None else vmem_budget
    mxu = mach.pe.mxu
    ridge = mach.pe.peak_flops / mach.memory.hbm_bw
    pm, pn, pk = (_round_up(max(d, 1), mxu) for d in (m, n, k))
    best: Optional[GemmPlan] = None
    cands = [mxu, 2 * mxu, 4 * mxu, 8 * mxu]
    for bm in cands:
        if bm > pm and bm != mxu:
            continue
        for bn in cands:
            if bn > pn and bn != mxu:
                continue
            for bk in (4 * mxu, 8 * mxu, 16 * mxu, 2 * mxu, mxu):
                if bk > pk and bk != mxu:
                    continue
                bm_, bn_, bk_ = min(bm, pm), min(bn, pn), min(bk, pk)
                # double-buffered A and B blocks + per-precision C accumulator
                vmem = 2 * (bm_ * bk_ + bk_ * bn_) * dtype_bytes \
                    + bm_ * bn_ * _acc_bytes(dtype_bytes)
                if vmem > vmem_budget:
                    continue
                # grid covers the block-padded problem (kernel pads inputs
                # to block multiples, not just MXU multiples)
                grid = (-(-m // bm_), -(-n // bn_), -(-k // bk_))
                steps = grid[0] * grid[1] * grid[2]
                if steps < min_grid_steps and (bm_, bn_, bk_) != (mxu, mxu, mxu):
                    continue
                ai = (2 * bm_ * bn_ * bk_) / ((bm_ * bk_ + bk_ * bn_) * dtype_bytes
                                              + bm_ * bn_ * dtype_bytes / max(grid[2], 1))
                cand = GemmPlan(bm_, bn_, bk_,
                                optimal_accumulators(bk_ // mxu, max_u=8,
                                                     machine=mach),
                                grid, vmem, ai, ridge)
                key = (cand.arithmetic_intensity, bk_)
                if best is None or key > (best.arithmetic_intensity, best.bk):
                    best = cand
    if best is None:  # degenerate tiny problem: single MXU tile
        bm_, bn_, bk_ = min(mxu, pm), min(mxu, pn), min(mxu, pk)
        vmem = 2 * (bm_ * bk_ + bk_ * bn_) * dtype_bytes \
            + bm_ * bn_ * _acc_bytes(dtype_bytes)
        ai = (2 * bm_ * bn_ * bk_) / ((bm_ * bk_ + bk_ * bn_ + bm_ * bn_) * dtype_bytes)
        best = GemmPlan(bm_, bn_, bk_, 1,
                        (-(-m // bm_), -(-n // bn_), -(-k // bk_)), vmem, ai,
                        ridge)
    return best


def plan_from_blocks(m: int, n: int, k: int, bm: int, bn: int, bk: int,
                     dtype_bytes: Optional[int] = None, dtype=None,
                     machine: Optional[MachineSpec] = None) -> GemmPlan:
    """Rebuild a full :class:`GemmPlan` from explicit block dims.

    This is how registry entries (``{"bm","bn","bk"}``) and sweep
    candidates become executable plans: grid, VMEM footprint, and
    arithmetic intensity are re-derived exactly as :func:`plan_gemm`
    derives them for its own picks. ``dtype`` overrides ``dtype_bytes``.
    """
    mach = _machine(machine)
    dtype_bytes = resolve_dtype_bytes(dtype, dtype_bytes, mach)
    bm_, bn_, bk_ = (max(int(b), 1) for b in (bm, bn, bk))
    grid = (-(-m // bm_), -(-n // bn_), -(-k // bk_))
    vmem = 2 * (bm_ * bk_ + bk_ * bn_) * dtype_bytes \
        + bm_ * bn_ * _acc_bytes(dtype_bytes)
    ai = (2 * bm_ * bn_ * bk_) / ((bm_ * bk_ + bk_ * bn_) * dtype_bytes
                                  + bm_ * bn_ * dtype_bytes / max(grid[2], 1))
    return GemmPlan(bm_, bn_, bk_,
                    optimal_accumulators(bk_ // mach.pe.mxu, max_u=8,
                                         machine=mach),
                    grid, vmem, ai, mach.pe.peak_flops / mach.memory.hbm_bw)


# ----------------------------- distributed GEMM ----------------------------

@dataclasses.dataclass(frozen=True)
class PdgemmPlan:
    """SUMMA pdgemm schedule on a (px, py) mesh: per-step local tiling plus
    the roofline extended with a per-hop collective term.

    The mesh is the paper's 'more parallel accumulators' applied across
    devices: the global K reduction is split into ``steps`` panel updates,
    each a local GEMM (``local`` - planned exactly like the single-device
    kernel) fed by ring broadcasts whose per-hop bytes are priced against
    the inter-chip link, the way :mod:`repro.core.roofline` prices
    collective bytes against the machine's ICI bandwidth.
    """

    px: int
    py: int
    steps: int                    # SUMMA panel steps = px * py
    k_fine: int                   # k-panel width per step
    local: GemmPlan               # tiling of one local panel update
    compute_s: float              # per-device GEMM flops under the roofline
    collective_s: float           # per-device ring-broadcast bytes / ici_bw
    collective_bytes: int         # on-wire bytes per device, all steps

    @property
    def modeled_time(self) -> float:
        return max(self.compute_s, self.collective_s)

    @property
    def collective_bound(self) -> bool:
        return self.collective_s > self.compute_s


def plan_pdgemm(m: int, n: int, k: int, px: int, py: int,
                dtype_bytes: Optional[int] = None, dtype=None,
                machine: Optional[MachineSpec] = None) -> PdgemmPlan:
    """Plan the SUMMA ``pdgemm`` on a (px, py) mesh.

    Per step (one of ``px * py`` fine k-panels) each device receives an
    A-panel over a ``py``-ring and a B-panel over a ``px``-ring
    (:func:`repro.distributed.collectives.ring_bcast`), then runs a local
    ``(m/px, k_fine) @ (k_fine, n/py)`` update on the Pallas path. The
    collective term sums the per-hop bytes of both rings
    (``ring_bcast_bytes``) over all steps against the machine's ICI
    bandwidth; the compute term is the local flops under the
    single-device roofline at the ``local`` tiling. ``modeled_time`` is
    their max (overlap assumed), so the plan exposes where the mesh stops
    paying - the cross-device analogue of fig. 2's pipeline-fill
    saturation.
    """
    from repro.distributed.collectives import ring_bcast_bytes
    mach = _machine(machine)
    dtype_bytes = resolve_dtype_bytes(dtype, dtype_bytes, mach)
    px, py = max(int(px), 1), max(int(py), 1)
    steps = px * py
    m_l = -(-max(m, 1) // px)
    n_l = -(-max(n, 1) // py)
    k_f = max(-(-max(k, 1) // steps), 1)
    local = plan_gemm(m_l, n_l, k_f, dtype_bytes=dtype_bytes, machine=mach)
    flops = 2.0 * m_l * n_l * k_f * steps
    rate = min(mach.pe.peak_flops,
               local.arithmetic_intensity * mach.memory.hbm_bw)
    compute_s = flops / rate + steps * mach.memory.pipeline_fill_s
    a_panel = m_l * k_f * dtype_bytes
    b_panel = k_f * n_l * dtype_bytes
    coll_bytes = steps * (ring_bcast_bytes(a_panel, py)
                          + ring_bcast_bytes(b_panel, px))
    return PdgemmPlan(px, py, steps, k_f, local, compute_s,
                      coll_bytes / mach.memory.ici_bw, coll_bytes)


# ------------------------- blocked-factorization plans ----------------------
# Serial-chain cycles exposed per panel column: the paper's section-4.2
# hazard profile per routine, priced at the machine's per-class pipeline
# depths. potrf: sqrt then a dependent div per column; getrf: pivot-compare
# (adder) + div; geqrf: norm-sqrt, alpha-add, div scale, tau div.
def _panel_chain_cycles(mach: MachineSpec) -> Dict[str, int]:
    d = mach.fpu.depths
    return {"potrf": d["sqrt"] + d["div"],
            "getrf": d["add"] + d["div"],
            "geqrf": d["sqrt"] + d["add"] + 2 * d["div"]}


_PANEL_CHAIN_CYCLES = _panel_chain_cycles(_TPU)
# flops(n) ~ coeff * n^3 for the square factorization. Public alias below:
# benchmarks derive Gflop/s from the same table the model plans with.
_FACTOR_FLOP_COEFF = {"potrf": 1.0 / 3.0, "getrf": 2.0 / 3.0, "geqrf": 4.0 / 3.0}
FACTOR_FLOP_COEFF = _FACTOR_FLOP_COEFF
MXU_CLOCK = _TPU.pe.mxu_clock             # cycles/s implied by peak
VPU_FLOPS = _TPU.pe.vpu_flops             # vector (non-MXU) peak


@dataclasses.dataclass(frozen=True)
class FactorizationPlan:
    """Panel width + trailing-update GEMM tiling for a blocked factorization."""

    kind: str                     # "potrf" | "getrf" | "geqrf"
    block: int                    # panel width nb (the LAPACK NB)
    gemm: GemmPlan                # plan for the widest trailing update
    panel_time: float             # modeled seconds in serial panels
    trailing_time: float          # modeled seconds in GEMM trailing updates
    batch: int = 1

    @property
    def modeled_time(self) -> float:
        return self.panel_time + self.trailing_time

    @property
    def panel_fraction(self) -> float:
        t = self.modeled_time
        return self.panel_time / t if t > 0 else 0.0


def _factorization_time(n: int, nb: int, kind: str, dtype_bytes: int,
                        batch: int, mach: MachineSpec) -> Tuple[float, float]:
    """(panel_s, trailing_s) for one size-n factorization at panel width nb.

    Panel: the unblocked path is hazard-bound — per column, a serial
    sqrt/div chain of the machine's per-class depths (eq.-2's exposed
    latency, unhidable by ILP) plus its rank-1 update flops at VPU rate.
    Trailing: DGEMM under the roofline — the k-extent of the update IS the
    panel width, so arithmetic intensity (and hence the achieved fraction of
    peak) grows with nb until the peak/hbm_bw knee; each panel step also
    pays one software-pipeline fill (fig. 2's unamortized-fill region).
    """
    chain = _panel_chain_cycles(mach)[kind] / mach.pe.mxu_clock
    coeff = _FACTOR_FLOP_COEFF[kind]
    fill = mach.memory.pipeline_fill_s
    panel_s = 0.0
    trailing_s = 0.0
    for j0 in range(0, n, nb):
        b = min(nb, n - j0)
        m = n - j0
        panel_s += b * chain + (coeff * 3.0) * m * b * b / mach.pe.vpu_flops \
            + fill
        rest = n - j0 - b
        if rest <= 0:
            continue
        # trailing update ~ (rest x b) @ (b x rest) (potrf/getrf) or the
        # compact-WY triple product (geqrf ~ 2x that)
        gf = 2.0 if kind == "geqrf" else 1.0
        flops = gf * 2.0 * rest * b * rest
        bytes_moved = gf * (2 * rest * b + 2 * rest * rest) * dtype_bytes
        ai = flops / bytes_moved
        rate = min(mach.pe.peak_flops, ai * mach.memory.hbm_bw)
        trailing_s += flops / rate + fill
    return batch * panel_s, batch * trailing_s


def plan_factorization(n: int, kind: str = "potrf",
                       dtype_bytes: Optional[int] = None,
                       batch: int = 1,
                       candidates: Tuple[int, ...] = (8, 16, 32, 64, 128),
                       dtype=None,
                       machine: Optional[MachineSpec] = None) -> FactorizationPlan:
    """Pick the panel width NB for a blocked right-looking factorization.

    Same trade-off as the paper's pipeline-depth equation: the panel is the
    serial (hazard) term that grows with NB, the trailing update is the
    throughput term whose GEMM efficiency grows with NB (arithmetic
    intensity ~ NB until the roofline knee). The minimum of the summed model
    is the software analogue of eq. 3's p_opt.
    """
    if kind not in _FACTOR_FLOP_COEFF:
        raise ValueError(f"unknown factorization kind: {kind!r}")
    mach = _machine(machine)
    dtype_bytes = resolve_dtype_bytes(dtype, dtype_bytes, mach)
    n = max(int(n), 1)
    best_nb, best_t = None, None
    for nb in candidates:
        if nb > n and best_nb is not None:
            continue
        nb_ = min(nb, n)
        p, t = _factorization_time(n, nb_, kind, dtype_bytes, batch, mach)
        if best_t is None or p + t < best_t:
            best_nb, best_t = nb_, p + t
    rest = max(n - best_nb, 1)
    gemm = plan_gemm(rest, rest, best_nb, dtype_bytes=dtype_bytes,
                     machine=mach)
    p, t = _factorization_time(n, best_nb, kind, dtype_bytes, batch, mach)
    return FactorizationPlan(kind, best_nb, gemm, p, t, batch=batch)


def modeled_factorization_time(n: int, kind: str = "potrf",
                               block: Optional[int] = None,
                               dtype_bytes: Optional[int] = None,
                               batch: int = 1, dtype=None,
                               machine: Optional[MachineSpec] = None) -> float:
    """Modeled seconds of one blocked factorization at a *fixed* panel
    width (``block=None`` = the model's own pick). This is the modeled_s
    the benchmark rows' ``model_residual`` compares the measured median
    against: same panel/trailing decomposition as
    :func:`plan_factorization`, evaluated at the block the bench actually
    ran."""
    if kind not in _FACTOR_FLOP_COEFF:
        raise ValueError(f"unknown factorization kind: {kind!r}")
    mach = _machine(machine)
    dtype_bytes = resolve_dtype_bytes(dtype, dtype_bytes, mach)
    n = max(int(n), 1)
    if block is None:
        return plan_factorization(n, kind=kind, dtype_bytes=dtype_bytes,
                                  batch=batch, machine=mach).modeled_time
    nb = min(max(int(block), 1), n)
    p, t = _factorization_time(n, nb, kind, dtype_bytes, batch, mach)
    return p + t


@dataclasses.dataclass(frozen=True)
class TrsmPlan:
    """Diagonal-block width for the blocked triangular solve."""

    block: int
    panel_time: float             # modeled seconds in serial substitutions
    trailing_time: float          # modeled seconds in off-diagonal GEMMs

    @property
    def modeled_time(self) -> float:
        return self.panel_time + self.trailing_time


def plan_trsm(n: int, nrhs: int = 1, dtype_bytes: Optional[int] = None,
              candidates: Tuple[int, ...] = (16, 32, 64, 128),
              dtype=None,
              machine: Optional[MachineSpec] = None) -> TrsmPlan:
    """Pick the diagonal-block width for the blocked TRSM.

    Same structure as :func:`plan_factorization`: the diagonal substitution
    scan is the serial divider-hazard chain (one dependent div per row, a
    block-wide AXPY at VPU rate - work that grows with the block); the
    off-diagonal updates are GEMMs whose per-panel pipeline fill shrinks as
    the block grows. The modeled minimum is eq. 3's p_opt in software.
    ``dtype`` overrides ``dtype_bytes``.
    """
    mach = _machine(machine)
    dtype_bytes = resolve_dtype_bytes(dtype, dtype_bytes, mach)
    n = max(int(n), 1)
    nrhs = max(int(nrhs), 1)
    # pivotless div chain
    chain = _panel_chain_cycles(mach)["getrf"] / mach.pe.mxu_clock
    fill = mach.memory.pipeline_fill_s
    best: Optional[TrsmPlan] = None
    for b in candidates:
        b_ = min(b, n)
        steps = -(-n // b_)
        # serial part: n dependent divides + the in-block AXPYs at VPU rate
        panel = n * chain + 2.0 * n * b_ * nrhs / mach.pe.vpu_flops \
            + steps * fill
        # off-diagonal GEMMs: ~ n*(n-b)/2 * nrhs MACs under the roofline
        flops = max(n - b_, 0) * n * nrhs
        if flops > 0:
            bytes_moved = (max(n - b_, 0) * b_ + 2 * n * nrhs) * dtype_bytes
            ai = flops / max(bytes_moved, 1)
            rate = min(mach.pe.peak_flops, ai * mach.memory.hbm_bw)
            trailing = flops / rate + steps * fill
        else:
            trailing = 0.0
        cand = TrsmPlan(b_, panel, trailing)
        if best is None or cand.modeled_time < best.modeled_time:
            best = cand
        if b_ >= n:
            break
    return best


# ------------------------------- fused chains -------------------------------
# FBLAS-style streaming composition (1907.07929): when consecutive tile
# stages share an intermediate, keeping it resident in VMEM deletes its HBM
# round trip. The chain plan prices both executions - staged (each stage
# pays its own reads/writes plus a pipeline fill) vs. streamed (one fused
# kernel, the intermediate never leaves VMEM) - so the dispatcher can pick.

FUSED_CHAIN_KINDS = ("gemm+epilogue", "trsm+gemm")

# extra VPU flops per output element (the bias add is priced separately);
# only the roofline term consumes these, so coarse integers suffice
EPILOGUE_FLOP_COST = {"none": 0, "relu": 1, "gelu": 8}


@dataclasses.dataclass(frozen=True)
class FusedChainPlan:
    """Fused vs. staged pricing of one two-stage tile chain.

    ``gemm+epilogue``: C = act(A B + bias); the staged path writes A B to
    HBM and re-reads it for the epilogue pass. ``trsm+gemm``: the blocked
    factorizations' trailing pair X = L11^{-1} AP then C -= B X (lu form)
    or C -= X^T X (syrk form); the staged path round-trips X through HBM.
    """

    kind: str                     # one of FUSED_CHAIN_KINDS
    form: str                     # epilogue name | "lu" | "syrk"
    gemm: GemmPlan                # tiling of the GEMM stage
    block: int                    # fused-kernel row-block height
    vmem_bytes: int               # fused kernel's resident VMEM footprint
    fits_vmem: bool               # vmem_bytes <= the machine budget
    unfused_hbm_bytes: int        # modeled HBM traffic, staged execution
    fused_hbm_bytes: int          # modeled HBM traffic, streamed execution
    unfused_time: float           # roofline seconds, staged (2 fills)
    fused_time: float             # roofline seconds, streamed (1 fill)

    @property
    def hbm_bytes_saved(self) -> int:
        return max(self.unfused_hbm_bytes - self.fused_hbm_bytes, 0)

    @property
    def fused_wins(self) -> bool:
        """Fuse iff the streamed kernel fits VMEM and the model says it is
        no slower - the streaming analogue of eq. 3's p_opt decision."""
        return self.fits_vmem and self.fused_time <= self.unfused_time


def _stage_time(flops: float, bytes_moved: float, mach: MachineSpec) -> float:
    """Roofline seconds of one kernel stage (compute vs. HBM stream max)."""
    return max(flops / mach.pe.peak_flops,
               bytes_moved / mach.memory.hbm_bw)


def plan_fused_chain(kind: str, m: int, n: int, k: int,
                     dtype_bytes: Optional[int] = None, dtype=None,
                     epilogue: str = "none", has_bias: bool = True,
                     form: str = "lu",
                     machine: Optional[MachineSpec] = None) -> FusedChainPlan:
    """Price a two-stage tile chain fused vs. staged.

    ``kind="gemm+epilogue"``: (m, n, k) is the GEMM problem; ``epilogue``
    / ``has_bias`` shape the second stage. ``kind="trsm+gemm"``: the
    trailing update C[m, n] consuming X = L11^{-1} AP with panel width k
    (the LAPACK NB); ``form="lu"`` reads a separate B[m, k] (getrf),
    ``form="syrk"`` reuses X as both GEMM operands (potrf, m == n).
    The GEMM-stage tiling reuses :func:`plan_gemm`; the solve-stage time
    reuses :func:`plan_trsm` - both at the chain's machine and dtype.
    """
    if kind not in FUSED_CHAIN_KINDS:
        raise ValueError(f"unknown fused chain {kind!r}; "
                         f"expected one of {FUSED_CHAIN_KINDS}")
    mach = _machine(machine)
    db = resolve_dtype_bytes(dtype, dtype_bytes, mach)
    fill = mach.memory.pipeline_fill_s
    budget = mach.memory.vmem_bytes
    m, n, k = max(int(m), 1), max(int(n), 1), max(int(k), 1)
    g = plan_gemm(m, n, k, dtype_bytes=db, machine=mach)
    if kind == "gemm+epilogue":
        if epilogue not in EPILOGUE_FLOP_COST:
            raise ValueError(f"unknown epilogue {epilogue!r}; expected one "
                             f"of {tuple(EPILOGUE_FLOP_COST)}")
        bias_bytes = n * db if has_bias else 0
        gemm_bytes = (m * k + k * n + m * n) * db
        epi_flops = (EPILOGUE_FLOP_COST[epilogue]
                     + (1 if has_bias else 0)) * m * n
        epi_bytes = 2 * m * n * db + bias_bytes    # re-read + re-write C
        unfused_b = gemm_bytes + epi_bytes
        fused_b = gemm_bytes + bias_bytes          # C written exactly once
        unfused_t = _stage_time(2.0 * m * n * k, gemm_bytes, mach) \
            + _stage_time(epi_flops, epi_bytes, mach) + 2 * fill
        fused_t = _stage_time(2.0 * m * n * k + epi_flops, fused_b, mach) \
            + fill
        # the epilogue streams one bias block alongside the GEMM's blocks
        vmem = g.vmem_bytes + g.bn * db
        return FusedChainPlan(kind, epilogue, g, g.bm, int(vmem),
                              vmem <= budget, int(unfused_b), int(fused_b),
                              unfused_t, fused_t)
    # trsm+gemm
    if form not in ("lu", "syrk"):
        raise ValueError(f"unknown trsm+gemm form {form!r}; "
                         f"expected 'lu' or 'syrk'")
    t = plan_trsm(k, n, dtype_bytes=db, machine=mach)
    x_bytes = k * n * db
    solve_bytes = k * k * db + k * n * db + x_bytes   # L11 + AP in, X out
    b_bytes = 0 if form == "syrk" else m * k * db
    x_reread = 2 * x_bytes if form == "syrk" else x_bytes
    gemm_flops = 2.0 * m * n * k
    unfused_gemm_b = x_reread + b_bytes + 2 * m * n * db
    fused_gemm_b = b_bytes + 2 * m * n * db           # X stays in VMEM
    solve_t = t.modeled_time
    unfused_t = solve_t + _stage_time(gemm_flops, unfused_gemm_b, mach) \
        + 2 * fill
    fused_t = solve_t + _stage_time(gemm_flops, fused_gemm_b, mach) + fill
    # fused-kernel residency: L11 + AP + X (operand and accumulator-width
    # copies, full n width - the solve cannot be column-tiled) plus one
    # C/O row block (and the B row block for the lu form)
    bm = min(g.bm, _round_up(m, max(mach.pe.sublane, 1)))
    acc = _acc_bytes(db)
    vmem = (k * k + k * n) * db + k * n * acc + k * n * db \
        + bm * n * (db + acc) + (bm * k * db if form == "lu" else 0)
    return FusedChainPlan(kind, form, g, bm, int(vmem), vmem <= budget,
                          int(solve_bytes + unfused_gemm_b),
                          int(solve_bytes + fused_gemm_b),
                          unfused_t, fused_t)


@dataclasses.dataclass(frozen=True)
class AttentionPlan:
    """Flash-attention tiling: KV blocks stream through VMEM; the online
    softmax running (m, l, o) triple is the dependent accumulator chain."""

    block_q: int
    block_k: int
    grid_kv: int
    vmem_bytes: int


def plan_attention(seq_q: int, seq_k: int, head_dim: int,
                   dtype_bytes: int = 2,
                   vmem_budget: Optional[int] = None,
                   machine: Optional[MachineSpec] = None) -> AttentionPlan:
    """KV/Q block sizes for the streaming-softmax kernel.

    The online-softmax rescale is a serial dependence per KV block (the
    paper's hazard): larger block_k amortizes it (fewer rescales) at the
    cost of VMEM; block_q adds independent rows (free ILP, like dgemv's
    independent inner products).
    """
    mach = _machine(machine)
    vmem_budget = mach.memory.vmem_bytes if vmem_budget is None else vmem_budget
    lane, sublane = mach.pe.lane, mach.pe.sublane
    hd = _round_up(head_dim, lane)
    block_q = min(_round_up(min(seq_q, 512), sublane),
                  _round_up(seq_q, sublane))
    block_k = 1024
    while block_k > 128:
        # q, k, v blocks (double-buffered k/v) + scores + fp32 o/m/l
        vmem = (block_q * hd * dtype_bytes + 2 * 2 * block_k * hd * dtype_bytes
                + block_q * block_k * 4 + block_q * (hd + 2) * 4)
        if vmem <= vmem_budget:
            break
        block_k //= 2
    block_k = min(block_k, _round_up(seq_k, lane))
    vmem = (block_q * hd * dtype_bytes + 2 * 2 * block_k * hd * dtype_bytes
            + block_q * block_k * 4 + block_q * (hd + 2) * 4)
    return AttentionPlan(block_q, block_k, -(-seq_k // block_k), vmem)


@dataclasses.dataclass(frozen=True)
class SSDPlan:
    """Mamba-2 SSD chunking: the cross-chunk state recurrence is the serial
    hazard chain; chunk size trades recurrence steps against the quadratic
    within-chunk term - the same busy/non-busy split as eq. 1."""

    chunk: int
    n_chunks: int
    vmem_bytes: int


def plan_ssd(seq: int, heads: int, head_dim: int, state: int,
             dtype_bytes: int = 2, vmem_budget: Optional[int] = None,
             machine: Optional[MachineSpec] = None) -> SSDPlan:
    """Chunk length for the SSD scan.

    Within-chunk cost ~ c^2 * d (quadratic, parallel); cross-chunk cost is a
    serial chain of length seq/c with latency ~ state update. Minimizing
    c^2*d*(seq/c) + (seq/c)*L gives c* ~ sqrt-ish; we clamp to VMEM and
    hardware alignment, defaulting to the canonical 256 where it fits.
    """
    mach = _machine(machine)
    vmem_budget = mach.memory.vmem_bytes if vmem_budget is None else vmem_budget
    sublane = mach.pe.sublane
    best_c = 256
    for c in (256, 128, 64):
        vmem = (c * head_dim * dtype_bytes * 3 + c * c * 4
                + head_dim * state * 4 + c * state * dtype_bytes * 2)
        if vmem <= vmem_budget and c <= max(seq, 64):
            best_c = c
            break
    best_c = min(best_c, max(_round_up(seq, sublane), sublane))
    vmem = (best_c * head_dim * dtype_bytes * 3 + best_c * best_c * 4
            + head_dim * state * 4 + best_c * state * dtype_bytes * 2)
    return SSDPlan(best_c, -(-seq // best_c), vmem)


def characterize_and_plan(profile, machine: Optional[MachineSpec] = None) -> Dict[str, object]:
    """End-to-end: a WorkloadProfile -> TPU kernel knobs.

    The paper's p_opt for the adder pipe becomes the accumulator count; the
    mul pipe's hazard-freedom means the MXU side has no knob (it is always
    saturable, the 'flat curve' of section 4.1).
    """
    add = profile.pipes.get("add")
    n = float(add.n_i) if add else 0.0
    return {
        "accumulators": optimal_accumulators(max(n, 1.0), machine=machine),
        "hazard_ratios": profile.hazard_ratios(),
        "popt": profile.popt_closed_form(),
    }
